"""Quickstart: the Clover public API in ~60 lines.

  1. pick a model family with quality variants,
  2. build a configuration graph,
  3. evaluate accuracy / carbon / latency at an arrival rate,
  4. run one carbon-aware optimization invocation,
  5. watch the controller react to carbon-intensity changes.

Run:  PYTHONPATH=src python examples/quickstart.py
"""
import random
import sys, os
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.core import annealing as SA
from repro.core import carbon as CB
from repro.core import catalog as CAT
from repro.core import config_graph as CG
from repro.core import controller as CTRL
from repro.core import objective as OBJ
from repro.core import schemes as SCH

# 1. a model family: EfficientNet-B1..B7 with published accuracy/FLOPs
variants = CAT.get_family("efficientnet")
print("variants:", [(v.name, v.accuracy, f"{v.flops_g}GF") for v in variants])

# 2. the carbon-unaware baseline: highest quality on unpartitioned blocks
ctx = SCH.SchemeContext("efficientnet", variants, n_blocks=2, arrival_rps=0.0,
                        obj_cfg=None, sa_cfg=SA.SAConfig(),
                        rng=random.Random(0))
base = SCH.base_config(ctx)
arrival = OBJ.evaluate(base, variants, 1e-9).capacity_rps * 0.7
base_res = OBJ.evaluate(base, variants, arrival)
print(f"\nBASE: accuracy={base_res.accuracy:.3f} "
      f"energy/req={base_res.energy_per_req_j:.1f}J "
      f"p95={base_res.p95_latency_s*1e3:.1f}ms")

# 3. the optimization objective (Eq. 1-5)
obj = OBJ.ObjectiveConfig(lam=0.1, a_base=base_res.accuracy,
                          c_base=base_res.carbon_per_req_g(380.0),
                          l_tail_s=base_res.p95_latency_s)
ctx.obj_cfg, ctx.arrival_rps = obj, arrival

# 4. one Clover invocation at high carbon intensity
out = SA.anneal(base, variants, ctx.evaluator(), ci=350.0, obj_cfg=obj,
                rng=random.Random(0))
best = OBJ.evaluate(out.best, variants, arrival)
print(f"\nCLOVER @ci=350: f={out.best_f:.2f} after {out.n_evals} evaluations")
print(f"  config: {dict(out.best.edges)}")
print(f"  accuracy={best.accuracy:.3f} ({(best.accuracy/base_res.accuracy-1)*100:+.2f}%)"
      f" energy/req={best.energy_per_req_j:.1f}J "
      f"({(1-best.energy_per_req_j/base_res.energy_per_req_j)*100:.0f}% saved)"
      f" p95={best.p95_latency_s*1e3:.1f}ms (SLA {obj.l_tail_s*1e3:.1f}ms)")

# 5. the controller reacts to the grid
trace = CB.make_trace("CISO-March", hours=24)
ctrl = CTRL.Controller(SCH.make_scheme("CLOVER"), ctx)
ctrl.start(0.0, trace.at(0.0))
reconfigs = 0
for t in range(0, int(trace.duration_s), 600):
    cfg, outcome = ctrl.maybe_reoptimize(float(t), trace.at(float(t)))
    if outcome is not None:
        reconfigs += 1
print(f"\ncontroller: {reconfigs} re-optimizations over 24 h "
      f"(CI threshold 5%); final config {dict(ctrl.config.edges)}")
