"""Carbon-trace sweep: Clover vs all competing schemes across three grids
and the λ trade-off knob (paper Figs. 10/14/16 in one script).

Run:  PYTHONPATH=src python examples/carbon_sweep.py [--hours 12]
"""
import argparse
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.core import carbon as CB
from repro.serving import simulator as SIM


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--hours", type=float, default=12.0)
    ap.add_argument("--family", default="efficientnet")
    args = ap.parse_args()

    print(f"=== scheme comparison, {args.family}, CISO-March {args.hours:.0f}h ===")
    tr = CB.make_trace("CISO-March", hours=args.hours)
    reports = SIM.compare_schemes(args.family, tr, sim=SIM.SimConfig(n_blocks=4))
    sv = SIM.savings_vs_base(reports)
    print(f"{'scheme':8s} {'carbon↓%':>9s} {'Δacc%':>7s} {'p95/SLA':>8s} {'opt%':>6s}")
    for name, v in sv.items():
        print(f"{name:8s} {v['carbon_saving_pct']:9.1f} "
              f"{v['accuracy_delta_pct']:7.2f} {v['p95_vs_sla']:8.2f} "
              f"{v['opt_time_frac_pct']:6.2f}")

    print("\n=== geographic robustness (CLOVER vs BASE) ===")
    for region in ("CISO-March", "CISO-September", "ESO-March"):
        tr = CB.make_trace(region, hours=args.hours)
        rep = SIM.compare_schemes(args.family, tr, schemes=("BASE", "CLOVER"),
                                  sim=SIM.SimConfig(n_blocks=4))
        v = SIM.savings_vs_base(rep)["CLOVER"]
        print(f"{region:16s} carbon↓ {v['carbon_saving_pct']:5.1f}%  "
              f"Δacc {v['accuracy_delta_pct']:+.2f}%  p95/SLA {v['p95_vs_sla']:.2f}")

    print("\n=== λ sweep (carbon-vs-accuracy weighting) ===")
    tr = CB.make_trace("CISO-March", hours=args.hours)
    for lam in (0.1, 0.5, 0.9):
        rep = SIM.compare_schemes(args.family, tr, schemes=("BASE", "CLOVER"),
                                  sim=SIM.SimConfig(n_blocks=4, lam=lam))
        v = SIM.savings_vs_base(rep)["CLOVER"]
        print(f"λ={lam:.1f}: carbon↓ {v['carbon_saving_pct']:5.1f}%  "
              f"Δacc {v['accuracy_delta_pct']:+.2f}%")


if __name__ == "__main__":
    main()
