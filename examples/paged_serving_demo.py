"""Paged-KV serving demo: block arena + radix prefix sharing + chunked
prefill + OPEN-LOOP (Poisson) arrivals on the real-execution engine.

A chat-style workload — every prompt opens with the same system preamble,
user turns vary wildly in length — is exactly where the slotted cache
strands memory: a 12-token question reserves the same ``max_len`` slot as a
300-token document.  The paged engine admits on block availability, prefills
one chunk per tick (decoding neighbours never stall), and serves the shared
preamble from the radix cache after its first appearance.

Run:  PYTHONPATH=src python examples/paged_serving_demo.py [--requests 18]
"""
import argparse
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-1.7b")
    ap.add_argument("--requests", type=int, default=18)
    ap.add_argument("--new-tokens", type=int, default=8)
    ap.add_argument("--preamble", type=int, default=48)
    args = ap.parse_args()

    import jax.numpy as jnp
    from repro.configs import get_smoke_config
    from repro.core import config_graph as CG
    from repro.serving import engine as ENG
    from repro.serving.api import serve_prompts as serve

    base = get_smoke_config(args.arch).with_(n_layers=4, dtype=jnp.float32)
    family = ENG.build_engine_family(base, fracs=(1.0,))
    g = CG.ConfigGraph.from_dict(base.name, {("x1", 16): 1})

    rng = np.random.default_rng(0)
    preamble = rng.integers(0, base.vocab_size,
                            size=args.preamble).astype(np.int32)
    lens = (12, 64, 160)
    prompts = []
    for i in range(args.requests):
        turn = rng.integers(0, base.vocab_size,
                            size=lens[i % len(lens)]).astype(np.int32)
        prompts.append(np.concatenate([preamble, turn]))
    max_len = args.preamble + max(lens) + args.new_tokens + 16

    print(f"=== paged KV serving demo ({args.arch}, "
          f"{args.requests} chat requests, shared {args.preamble}-token "
          f"preamble) ===")
    eng = ENG.RealEngine(family, n_slots=4, max_len=max_len,
                         kv_layout="paged", block_size=16, max_seqs=12,
                         chunk_blocks=4)
    eng.configure(g)
    inst = eng.instances[0]
    print(f"arena: {inst.alloc.num_allocatable} × {inst.block_size}-token "
          f"blocks (= 4 slotted slots of {max_len})")

    # closed loop: everything arrives at once — makespan + packing
    m = serve(eng, prompts, args.new_tokens)
    print(f"\nclosed loop : {m['tokens_per_s']:7.1f} tok/s  "
          f"J/token={m['j_per_token']:.3f}  "
          f"admitted={m['mean_admitted']:.1f} seqs  "
          f"blocks peak={m['blocks_peak']}  "
          f"prefix hits={m['prefix_hit_tokens']} tokens "
          f"({m['prefill_chunks']} chunked prefills)")

    # open loop: Poisson arrivals at ~60% of the measured saturation rate —
    # now queueing delay and TTFT are real, per-request quantities
    sat = m["tokens_per_s"] / args.new_tokens
    mo = eng.serve_poisson(rate_rps=0.6 * sat, n_requests=args.requests,
                           prompt_lens=[args.preamble + L for L in lens],
                           n_new=args.new_tokens, seed=1)
    print(f"open loop   : offered {mo['offered_rps']:.1f} rps "
          f"(0.6× saturation)  p95={mo['p95_s']*1e3:.1f}ms  "
          f"queue-delay p95={mo['queue_delay_p95_s']*1e3:.1f}ms  "
          f"TTFT p95={mo['ttft_p95_s']*1e3:.1f}ms")

    # the radix cache persists across serves: the same preamble now hits
    m2 = serve(eng, prompts, args.new_tokens)
    print(f"second pass : {m2['tokens_per_s']:7.1f} tok/s  "
          f"prefix hits={m2['prefix_hit_tokens']} tokens "
          f"({m2['prefill_chunks']} chunked prefills)")
    print("\nOK — paged arena, radix prefix sharing, chunked prefill and "
          "open-loop queueing metrics on real JAX execution.")


if __name__ == "__main__":
    main()
