"""One workload, every backend: the unified request/response serving API.

Builds a two-class fleet workload (interactive Poisson stream + deferrable
deadline jobs, ``fleet.workload.request_stream``) as typed
``InferenceRequest``s and drives the SAME requests through every
``ServingBackend`` implementation:

  * the real continuous-batching engine on the slotted KV cache,
  * the real engine on the paged arena (priority policy + decode-time
    preemption enabled),
  * the per-request DES (FIFO and EDF),
  * the analytic fluid-window model.

Each backend returns ``InferenceResponse``s carrying per-request latency,
TTFT, attributed joules and gCO2 (occupancy-weighted tick energy × the
window CI) and preemption counts; the two real layouts must agree
token-for-token and every backend's per-request energy must sum to its
engine total.

Run:  PYTHONPATH=src python examples/unified_api_demo.py
"""
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np  # noqa: E402


def main():
    import jax.numpy as jnp
    from repro.configs import get_smoke_config
    from repro.core import catalog as CAT
    from repro.core import config_graph as CG
    from repro.fleet import workload as WL
    from repro.serving import engine as ENG
    from repro.serving.api import ServingBackend, serve_workload, \
        summarize_responses
    from repro.serving.backends import FluidBackend
    from repro.serving.queue import DESBackend, DESConfig

    ci = 380.0
    base = get_smoke_config("qwen3-1.7b").with_(n_layers=2, dtype=jnp.float32)
    family = ENG.build_engine_family(base, fracs=(1.0,))
    g = CG.ConfigGraph.from_dict(base.name, {("x1", 16): 1})

    # a 2-hour fleet workload compressed onto a ~2-second demo clock
    fleet_wl = WL.make_workload(interactive_rps=2.0, duration_s=2 * 3600.0,
                                deferrable_frac=0.3, n_jobs=3,
                                min_slack_s=1800.0, max_slack_s=3600.0,
                                seed=0)
    requests = WL.request_stream(fleet_wl, 2 * 3600.0,
                                 vocab_size=base.vocab_size,
                                 prompt_lens=(6, 12, 24), n_new=6,
                                 time_scale=1.0 / 3600.0, max_interactive=10,
                                 seed=0)
    n_int = sum(r.slo == "interactive" for r in requests)
    print(f"=== unified serving API demo: {len(requests)} requests "
          f"({n_int} interactive + {len(requests) - n_int} deferrable "
          f"w/ deadlines) ===")

    def fresh_requests():
        import dataclasses as dc
        return [dc.replace(r, prompt=r.prompt.copy()) for r in requests]

    backends = {}
    eng_s = ENG.RealEngine(family, n_slots=4, max_len=48, ci_g_per_kwh=ci)
    eng_s.configure(g)
    backends["real/slotted fifo"] = eng_s
    eng_p = ENG.RealEngine(family, n_slots=4, max_len=48, kv_layout="paged",
                           block_size=8, max_seqs=8, policy="priority",
                           preemption=True, ci_g_per_kwh=ci)
    eng_p.configure(g)
    backends["real/paged prio+preempt"] = eng_p
    des_g = CG.ConfigGraph.from_dict("efficientnet", {("B3", 1): 1})
    variants = CAT.get_family("efficientnet")
    backends["des fifo"] = DESBackend(des_g, variants,
                                      DESConfig(jitter_sigma=0.0),
                                      policy="fifo", ci_g_per_kwh=ci)
    backends["des edf"] = DESBackend(des_g, variants,
                                     DESConfig(jitter_sigma=0.0),
                                     policy="edf", ci_g_per_kwh=ci)
    backends["fluid"] = FluidBackend(des_g, variants, sla_target_s=1.0,
                                     window_s=0.5, ci_g_per_kwh=ci)

    print(f"{'backend':24s} {'served':>6s} {'p95_ms':>8s} {'ttft_ms':>8s} "
          f"{'J':>8s} {'gCO2':>8s} {'miss':>4s} {'preempt':>7s}")
    results = {}
    for name, backend in backends.items():
        assert isinstance(backend, ServingBackend)
        responses = serve_workload(backend, fresh_requests())
        s = summarize_responses(responses)
        total_j = backend.stats().get("energy_j", s["energy_j"])
        assert abs(s["energy_j"] - total_j) < 1e-6 * max(total_j, 1), \
            "per-request joules must sum to the engine total"
        results[name] = responses
        print(f"{name:24s} {s['served']:6d} {s['p95_s'] * 1e3:8.1f} "
              f"{s.get('interactive_ttft_p95_s', 0.0) * 1e3:8.1f} "
              f"{s['energy_j']:8.1f} {s['carbon_g']:8.4f} "
              f"{s['deadline_misses']:4d} {s['preemptions']:7d}")

    outs_s, outs_p = eng_s.last_outputs, eng_p.last_outputs
    for rid in outs_s:
        np.testing.assert_array_equal(outs_s[rid], outs_p[rid])
    print("\nOK — every backend ran the identical typed workload through "
          "submit/drain;\nreal slotted and paged outputs are "
          "token-identical, energy attribution is exact.")


if __name__ == "__main__":
    main()
