"""Fault tolerance + elastic scaling at the serving layer: a serving block
fails mid-trace; the controller shrinks the configuration via graph
additivity (paper §4.2), re-optimizes for the reduced fleet, and the SLA
recovers — then the block returns and capacity is restored the same way.

Run:  PYTHONPATH=src python examples/elastic_failure.py
"""
import os
import random
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.core import annealing as SA
from repro.core import carbon as CB
from repro.core import controller as CTRL
from repro.core import objective as OBJ
from repro.core import schemes as SCH
from repro.serving import simulator as SIM


def main():
    sim = SIM.SimConfig(n_blocks=4)
    ctx, arrival = SIM.make_context("efficientnet", sim)
    trace = CB.make_trace("CISO-March", hours=12)
    ctrl = CTRL.Controller(SCH.make_scheme("CLOVER"), ctx)
    ctrl.start(0.0, trace.at(0.0))

    def status(tag, t):
        res = OBJ.evaluate(ctrl.config, ctx.variants, arrival)
        ok = "meets SLA" if res.p95_latency_s <= ctx.obj_cfg.l_tail_s else "VIOLATES SLA"
        print(f"[{tag:22s}] blocks={ctx.n_blocks} chips={ctrl.config.total_chips} "
              f"capacity={res.capacity_rps:7.0f}rps rho={res.rho:5.2f} "
              f"p95={res.p95_latency_s*1e3:6.1f}ms ({ok}) "
              f"E/req={res.energy_per_req_j:5.1f}J acc={res.accuracy:.3f}")
        return res

    status("steady state", 0.0)

    # --- block failure: hardware drops out ----------------------------------
    print("\n!! block failure (1 of 4 serving blocks lost)")
    ctrl.scale_blocks(-1)                      # additivity: per-block quotient removed
    res = status("post-failure, pre-opt", 3600.0)
    # controller reacts: re-optimize for the reduced fleet at current CI
    ctrl.last_opt_ci = None                    # failure forces re-invocation
    cfg, outcome = ctrl.maybe_reoptimize(3600.0, trace.at(3600.0))
    res2 = status("post-failure, re-opt", 3600.0 + (outcome.duration_s if outcome else 0))
    assert res2.p95_latency_s <= ctx.obj_cfg.l_tail_s * 1.05, "SLA must recover"
    print(f"   re-optimization: {outcome.n_evals} evaluations, "
          f"{outcome.duration_s:.0f}s; config {dict(cfg.edges)}")

    # --- block repair: capacity restored -------------------------------------
    print("\n>> block repaired (back to 4)")
    ctrl.scale_blocks(+1)
    ctrl.last_opt_ci = None
    cfg, outcome = ctrl.maybe_reoptimize(7200.0, trace.at(7200.0))
    res3 = status("post-repair, re-opt", 7200.0)
    assert res3.p95_latency_s <= ctx.obj_cfg.l_tail_s * 1.05
    print("\nOK — failure absorbed and recovered through graph additivity + "
          "re-optimization; no configuration was rebuilt from scratch.")


if __name__ == "__main__":
    main()
