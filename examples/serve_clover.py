"""End-to-end driver: serve a REAL (reduced) LM with continuous batching
under Clover's carbon-aware control — actual JAX prefill/decode on this host,
slotted KV caches, measured latencies, warm reconfiguration.

This is the inference-serving end-to-end example the paper's kind dictates
(its training counterpart is repro/launch/train.py).

Run:  PYTHONPATH=src python examples/serve_clover.py [--requests 24]
"""
import argparse
import os
import random
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-1.7b")
    ap.add_argument("--requests", type=int, default=24)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--new-tokens", type=int, default=6)
    args = ap.parse_args()

    import jax.numpy as jnp
    from repro.configs import get_smoke_config
    from repro.core import annealing as SA
    from repro.core import carbon as CB
    from repro.core import config_graph as CG
    from repro.core import objective as OBJ
    from repro.serving import engine as ENG
    from repro.serving.api import serve_prompts as serve

    print(f"=== Clover real-execution serving demo ({args.arch} ladder, "
          f"continuous batching × {args.slots} slots) ===")
    base_cfg = get_smoke_config(args.arch).with_(n_layers=12, dtype=jnp.float32)
    family = ENG.build_engine_family(base_cfg, fracs=(1.0, 0.5, 1.0 / 6))
    variants = [ev.variant for ev in family]
    for ev in family:
        print(f"  variant {ev.variant.name}: {ev.cfg.n_layers} layers, "
              f"{ev.variant.params_m:.2f}M params, acc proxy {ev.variant.accuracy}")

    eng = ENG.RealEngine(family, n_slots=args.slots,
                         max_len=8 + args.new_tokens + 2)
    rng = np.random.default_rng(0)
    prompts = [rng.integers(0, base_cfg.vocab_size, size=(1, 6)).astype(np.int32)
               for _ in range(args.requests)]

    # --- BASE: highest quality on the whole block --------------------------------
    g_base = CG.ConfigGraph.from_dict(base_cfg.name, {("x1", 16): 1})
    t_cold = eng.configure(g_base)
    serve(eng, prompts[:args.slots], args.new_tokens)        # warm the path
    m_base = serve(eng, prompts, args.new_tokens)
    print(f"\nBASE   : p95={m_base['p95_s']*1e3:7.1f}ms "
          f"energy={m_base['energy_j']:8.1f}J acc={m_base['mean_accuracy']:.3f} "
          f"{m_base['tokens_per_s']:7.1f} tok/s "
          f"occ={m_base['mean_occupancy']:.2f} (cold configure {t_cold:.2f}s)")

    # --- Clover: optimize against REAL measured latencies/energy -----------------
    trace = CB.make_trace("CISO-March", hours=2)
    obj = OBJ.ObjectiveConfig(
        lam=0.6, a_base=m_base["mean_accuracy"],
        c_base=m_base["energy_j"] / m_base["served"] / 3.6e6 * 380 * 1.5,
        l_tail_s=m_base["p95_s"] * 1.5)
    probe = prompts[:6]

    def evaluator(graph):
        eng.configure(graph)          # warm after the first visit to a config
        m = serve(eng, probe, args.new_tokens)
        return OBJ.EvalResult(m["mean_accuracy"], 1.0 / max(m["p50_s"], 1e-9),
                              0.5, m["p95_s"], 0.0, m["energy_j"] / m["served"])

    for ci in (trace.at(0), trace.at(12 * 3600)):
        out = SA.anneal(g_base, variants, evaluator, ci=ci, obj_cfg=obj,
                        sa_cfg=SA.SAConfig(stale_limit=6, eval_window_s=0.0),
                        rng=random.Random(1))
        t_re = eng.configure(out.best)
        m = serve(eng, prompts, args.new_tokens)
        save = (1 - m["energy_j"] / m_base["energy_j"]) * 100
        print(f"CLOVER @ci={ci:5.0f}: cfg={dict(out.best.edges)} "
              f"p95={m['p95_s']*1e3:7.1f}ms energy={m['energy_j']:8.1f}J "
              f"acc={m['mean_accuracy']:.3f} {m['tokens_per_s']:7.1f} tok/s "
              f"({save:+.0f}% energy, {out.n_evals} real evals, "
              f"reconfig {t_re*1e3:.1f}ms warm)")
    print("\nOK — Clover reconfigured a live continuous-batching JAX engine "
          "end to end.")


if __name__ == "__main__":
    main()
