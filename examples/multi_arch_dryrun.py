"""Multi-architecture launcher demo: select any assigned architecture by id,
build its production train/serve step against the pod mesh, and report the
compiled memory/flop/collective profile — the `--arch` surface of the
framework (subset of the full dry-run for interactive use).

Run:  PYTHONPATH=src python examples/multi_arch_dryrun.py --arch glm4-9b \
          --shape decode_32k
(Heavy: builds the 256-device mesh via forced host devices in a subprocess-
safe way — this example sets XLA_FLAGS itself and must run standalone.)
"""
import os

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=512")

import argparse
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))


def main():
    from repro.configs import ARCHS
    from repro.configs import shapes as SH
    from repro.launch import dryrun

    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-1.7b", choices=ARCHS)
    ap.add_argument("--shape", default="decode_32k", choices=list(SH.SHAPES))
    ap.add_argument("--multi-pod", action="store_true")
    args = ap.parse_args()

    res = dryrun.run_cell(args.arch, args.shape, args.multi_pod)
    if res.get("skipped"):
        print(f"skipped: {res['reason']}")
        return
    print(f"\ncompiled {args.arch} × {args.shape} on {res['mesh']} "
          f"({res['devices']} chips):")
    print(f"  dot FLOPs/device : {res['dot_flops_per_device']:.3e}")
    print(f"  peak HBM/device  : {res['memory']['peak_per_device_bytes']/2**30:.2f} GiB")
    print(f"  collective bytes : {res['collectives']['total_collective_bytes']/2**20:.1f} MiB/device")
    for kind, n in res["collectives"]["collective_counts"].items():
        if n:
            print(f"    {kind:20s} ×{n:.0f}")


if __name__ == "__main__":
    main()
