"""Carbon-aware fleet demo: CI forecasting + deferrable-work shifting +
multi-region routing vs the best single-region Clover deployment.

Three regions (CISO-March, CISO-September, ESO-March) each run their own
Clover controller; a global router chases the cleanest grid, a shifting
scheduler packs deferrable batch jobs into forecast low-carbon windows, and
elastic block scaling (down to full suspend) keeps utilization tight.  The
baseline is the strongest non-fleet comparator: one Clover cluster in the
single best region carrying the identical work mix.

Run:  PYTHONPATH=src python examples/fleet_shift.py [--hours 48] [--seed 0]
"""
import argparse
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.core import carbon as CB
from repro.fleet import fleet_sim as FS
from repro.fleet import forecast as FC

REGIONS = ("CISO-March", "CISO-September", "ESO-March")
WARMUP_H = 24.0          # forecaster history before the simulated span


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--hours", type=float, default=48.0,
                    help="simulated serving horizon (after 24h warmup)")
    ap.add_argument("--family", default="efficientnet")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    traces = {r: CB.make_trace(r, hours=WARMUP_H + args.hours)
              for r in REGIONS}
    cfg = FS.FleetConfig(warmup_s=WARMUP_H * 3600.0, seed=args.seed)

    print(f"=== forecaster backtest (6h horizon, {WARMUP_H:.0f}h+ history) ===")
    for region, tr in traces.items():
        for name in ("persistence", "harmonic", "ensemble"):
            bt = FC.backtest(FC.make_forecaster(name, tr), 6 * 3600.0,
                             t_start=WARMUP_H * 3600.0)
            print(f"{region:16s} {name:12s} MAE {bt.mae:6.1f}  "
                  f"MAPE {bt.mape * 100:5.1f}%")

    print(f"\n=== single-region CLOVER baselines ({args.hours:.0f}h, "
          f"interactive + deferrable served on arrival) ===")
    out = FS.compare_fleet_vs_single(args.family, traces, cfg)
    singles = out["singles"]
    for region, rep in singles.items():
        print(f"{region:16s} carbon/req {rep.carbon_per_req_g() * 1e3:7.4f} mg  "
              f"acc {rep.accuracy:.3f}  p95/SLA "
              f"{rep.p95_latency_s / rep.sla_target_s:.2f}")
    best = out["best_single"]
    best_cpr = singles[best].carbon_per_req_g()
    print(f"best single region: {best} "
          f"({best_cpr * 1e3:.4f} mg/req)")

    fleet = out["fleet"]
    print(f"\n=== fleet: forecast + shifting + routing + elastic scaling ===")
    for name, r in fleet.regions.items():
        print(f"{name:16s} carbon {r.carbon_g / 1e3:7.2f} kg  "
              f"interactive {r.served_interactive / 1e6:6.2f} M  "
              f"deferrable {r.served_deferrable / 1e6:5.2f} M  "
              f"invocations {r.n_invocations} "
              f"({r.n_predictive} predictive)")
    print(f"fleet carbon/req  {fleet.carbon_per_req_g() * 1e3:.4f} mg "
          f"(accuracy {fleet.accuracy:.3f})")
    print(f"interactive p95   {fleet.p95_s * 1e3:.1f} ms vs SLA "
          f"{fleet.sla_target_s * 1e3:.1f} ms "
          f"({'OK' if fleet.p95_s <= fleet.sla_target_s else 'VIOLATED'})")
    print(f"deferrable jobs   {fleet.jobs_total - len(fleet.deadline_misses)}"
          f"/{fleet.jobs_total} deadlines met"
          + (f"  MISSED: {fleet.deadline_misses}"
             if fleet.deadline_misses else ""))

    saving = (1.0 - fleet.carbon_per_req_g() / best_cpr) * 100.0
    print(f"\nfleet vs best single region: {saving:+.1f}% carbon/request"
          f" ({'fleet wins' if saving > 0 else 'fleet LOSES'})")
    if args.hours < 24.0:
        print("note: horizons under one diurnal cycle have no solar valley "
              "to shift into or route toward — the fleet's levers need "
              "--hours >= 24 to pay for its idle floor")
    ok = (saving > 0 and fleet.p95_s <= fleet.sla_target_s
          and not fleet.deadline_misses)
    print("RESULT:", "PASS" if ok else "FAIL")
    return 0 if ok else 1


if __name__ == "__main__":
    sys.exit(main())
