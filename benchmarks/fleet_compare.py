"""Fleet-layer benchmark: {single-region CLOVER} vs {fleet + forecast +
shifting + routing} carbon-per-request on the three bundled regions, plus an
ablation over the fleet's levers.

Prints one CSV row per configuration and writes the table to
benchmarks/out/fleet_compare.csv.

Usage:  PYTHONPATH=src python -m benchmarks.fleet_compare [--hours 24] [--fast]
"""
from __future__ import annotations

import argparse
import csv
import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

OUT_DIR = os.path.join(os.path.dirname(__file__), "out")
REGIONS = ("CISO-March", "CISO-September", "ESO-March")


def run(hours: float, family: str, seed: int):
    from repro.core import carbon as CB
    from repro.fleet import fleet_sim as FS

    warmup = 24 * 3600.0
    traces = {r: CB.make_trace(r, hours=24.0 + hours) for r in REGIONS}
    rows = []

    base_cfg = dict(warmup_s=warmup, seed=seed)
    singles = {r: FS.single_region_baseline(family, tr,
                                            FS.FleetConfig(**base_cfg))
               for r, tr in traces.items()}
    for r, rep in singles.items():
        rows.append({"config": f"single:{r}",
                     "carbon_per_req_mg": rep.carbon_per_req_g() * 1e3,
                     "accuracy": rep.accuracy,
                     "p95_over_sla": rep.p95_latency_s / rep.sla_target_s,
                     "deadline_misses": "",
                     "carbon_kg": rep.carbon_g / 1e3})

    ablations = [
        ("fleet:full", {}),
        ("fleet:no-shift", {"shifting_on": False}),
        ("fleet:no-route", {"routing_on": False}),
        ("fleet:no-predict", {"predictive_on": False}),
        ("fleet:no-elastic", {"elastic": False}),
        ("fleet:lp-shifter", {"shifter": "lp"}),
    ]
    for name, kw in ablations:
        cfg = FS.FleetConfig(**base_cfg, **kw)
        rep = FS.run_fleet(family, traces, cfg)
        rows.append({"config": name,
                     "carbon_per_req_mg": rep.carbon_per_req_g() * 1e3,
                     "accuracy": rep.accuracy,
                     "p95_over_sla": rep.p95_s / rep.sla_target_s,
                     "deadline_misses": len(rep.deadline_misses),
                     "carbon_kg": rep.carbon_g / 1e3})
    return rows


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--hours", type=float, default=24.0)
    ap.add_argument("--family", default="efficientnet")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--fast", action="store_true",
                    help="12h horizon for smoke runs")
    args = ap.parse_args()
    hours = 12.0 if args.fast else args.hours

    t0 = time.time()
    rows = run(hours, args.family, args.seed)
    dt = time.time() - t0

    os.makedirs(OUT_DIR, exist_ok=True)
    path = os.path.join(OUT_DIR, "fleet_compare.csv")
    with open(path, "w", newline="") as f:
        w = csv.DictWriter(f, fieldnames=list(rows[0].keys()))
        w.writeheader()
        w.writerows(rows)

    best_single = min((r for r in rows if r["config"].startswith("single")),
                      key=lambda r: r["carbon_per_req_mg"])
    print(f"{'config':20s} {'mg/req':>8s} {'acc':>6s} {'p95/SLA':>8s} "
          f"{'misses':>7s}")
    for r in rows:
        save = (1 - r["carbon_per_req_mg"]
                / best_single["carbon_per_req_mg"]) * 100
        print(f"{r['config']:20s} {r['carbon_per_req_mg']:8.4f} "
              f"{r['accuracy']:6.3f} {r['p95_over_sla']:8.2f} "
              f"{str(r['deadline_misses']):>7s}  ({save:+.1f}% vs best single)")
    print(f"# wall {dt:.1f}s → {path}")


if __name__ == "__main__":
    main()
