"""Shared machine-readable benchmark emission (perf trajectory across PRs).

Every engine benchmark merges its section into the ROOT-LEVEL
``BENCH_engine.json`` — one top-level key per script, so re-running one
benchmark never clobbers another's numbers, and the file sits where a
cross-commit diff naturally finds it (``benchmarks/run.py --json``
refreshes it from the harness).  The schema per section is flat scalars
only (tokens/s, J/token, TTFT p95, blocks-in-use peak, …): trivially
diffable between commits.
"""
from __future__ import annotations

import json
import os
from typing import Dict

OUT_DIR = os.path.join(os.path.dirname(__file__), "out")
BENCH_PATH = os.path.abspath(
    os.path.join(os.path.dirname(__file__), "..", "BENCH_engine.json"))


def update_bench_json(section: str, payload: Dict) -> str:
    """Merge ``payload`` under ``section`` in the root BENCH_engine.json."""
    data: Dict = {}
    if os.path.exists(BENCH_PATH):
        try:
            with open(BENCH_PATH) as f:
                data = json.load(f)
        except (json.JSONDecodeError, OSError):
            data = {}
    data[section] = payload
    with open(BENCH_PATH, "w") as f:
        json.dump(data, f, indent=2, sort_keys=True)
        f.write("\n")
    return BENCH_PATH
