"""Shared machine-readable benchmark emission (perf trajectory across PRs).

Every engine benchmark merges its section into the ROOT-LEVEL
``BENCH_engine.json`` — one top-level key per script, so re-running one
benchmark never clobbers another's numbers, and the file sits where a
cross-commit diff naturally finds it (``benchmarks/run.py --json``
refreshes it from the harness).  The schema per section is flat scalars
only (tokens/s, J/token, TTFT p95, blocks-in-use peak, …): trivially
diffable between commits.

On top of the snapshot, a TRAJECTORY guard: before a section's numbers
overwrite the previous ``BENCH_engine.json`` entry, :func:`check_trajectory`
compares the keys in :data:`TRAJECTORY_KEYS` against the previous run and
flags regressions beyond 10 % (warn by default; ``run.py
--fail-on-regress`` turns them fatal), and :func:`append_history` appends
every run's key numbers to ``benchmarks/out/BENCH_history.jsonl`` so the
full per-run trajectory survives the snapshot's overwrites.
"""
from __future__ import annotations

import json
import os
import time
from typing import Dict, List, Tuple

OUT_DIR = os.path.join(os.path.dirname(__file__), "out")
BENCH_PATH = os.path.abspath(
    os.path.join(os.path.dirname(__file__), "..", "BENCH_engine.json"))
HISTORY_PATH = os.path.join(OUT_DIR, "BENCH_history.jsonl")

# the guarded metrics per section: (key, direction, absolute slack).
# direction "higher" = regression when the new value drops >10 % below the
# previous run; "lower" = regression when it rises >10 % above.  The slack
# is an absolute floor below which noise never counts as a regression
# (overhead percentages jitter a couple of points run to run).
TRAJECTORY_KEYS: Dict[str, List[Tuple[str, str, float]]] = {
    "observability_telemetry": [
        ("paged_tokens_per_s", "higher", 0.0),
        ("slotted_tokens_per_s", "higher", 0.0),
        ("paged_vs_slotted_ratio", "higher", 0.0),
        ("telemetry_overhead_pct", "lower", 2.0),
        ("plane_overhead_pct", "lower", 2.0),
    ],
    "decode_hotpath": [
        ("tokens_per_s_pipelined", "higher", 0.0),
        ("pipelined_vs_slotted_ratio", "higher", 0.0),
    ],
    "mixed_quality_serving": [
        ("governed_carbon_g_per_req", "lower", 0.0),
        ("governed_mean_accuracy", "higher", 0.0),
    ],
    "disagg_serving": [
        ("token_parity", "higher", 0.0),
        ("prefill_throughput_ratio", "higher", 0.0),
        ("tokens_per_s_disagg", "higher", 0.0),
        ("role_conservation", "higher", 0.0),
    ],
}

# per-section override of the default 10 % trajectory tolerance: sections
# whose numbers have proven stable run the guard tighter
SECTION_TOL: Dict[str, float] = {
    "decode_hotpath": 0.07,
    "mixed_quality_serving": 0.07,
}


def update_bench_json(section: str, payload: Dict) -> str:
    """Merge ``payload`` under ``section`` in the root BENCH_engine.json."""
    data: Dict = {}
    if os.path.exists(BENCH_PATH):
        try:
            with open(BENCH_PATH) as f:
                data = json.load(f)
        except (json.JSONDecodeError, OSError):
            data = {}
    data[section] = payload
    with open(BENCH_PATH, "w") as f:
        json.dump(data, f, indent=2, sort_keys=True)
        f.write("\n")
    return BENCH_PATH


def previous_section(section: str) -> Dict:
    """The section's numbers from the current (pre-overwrite)
    ``BENCH_engine.json`` — call BEFORE :func:`update_bench_json`."""
    if not os.path.exists(BENCH_PATH):
        return {}
    try:
        with open(BENCH_PATH) as f:
            return json.load(f).get(section, {}) or {}
    except (json.JSONDecodeError, OSError):
        return {}


def check_trajectory(section: str, payload: Dict,
                     tol: float = 0.10) -> List[str]:
    """Compare ``payload`` against the previous run of ``section``; returns
    human-readable regression messages (empty = clean).  Only keys listed
    in :data:`TRAJECTORY_KEYS` are guarded; a key absent from either side
    is skipped (new metrics don't fail their first run).  ``tol`` is the
    default tolerance; :data:`SECTION_TOL` overrides it per section."""
    prev = previous_section(section)
    tol = SECTION_TOL.get(section, tol)
    msgs: List[str] = []
    for key, direction, slack in TRAJECTORY_KEYS.get(section, []):
        if key not in prev or key not in payload:
            continue
        old, new = float(prev[key]), float(payload[key])
        if direction == "higher":
            if old > 0 and new < old * (1.0 - tol) and old - new > slack:
                msgs.append(f"{section}.{key}: {new:.3f} < {old:.3f} "
                            f"(-{(1 - new / old) * 100.0:.1f}%)")
        else:
            base = max(abs(old), 1e-9)
            if new > old * (1.0 + tol) and new - old > slack:
                msgs.append(f"{section}.{key}: {new:.3f} > {old:.3f} "
                            f"(+{(new - old) / base * 100.0:.1f}%)")
    return msgs


def append_history(section: str, payload: Dict) -> str:
    """Append one ``{"ts", "section", "metrics"}`` line to the history
    JSONL — the per-run trajectory the snapshot file overwrites."""
    os.makedirs(OUT_DIR, exist_ok=True)
    keys = [k for k, _, _ in TRAJECTORY_KEYS.get(section, [])]
    metrics = {k: payload[k] for k in keys if k in payload} or payload
    rec = {"ts": time.strftime("%Y-%m-%dT%H:%M:%S"), "section": section,
           "metrics": metrics}
    with open(HISTORY_PATH, "a") as f:
        f.write(json.dumps(rec, sort_keys=True) + "\n")
    return HISTORY_PATH
