"""Disaggregated-serving bench body (subprocess of benchmarks/run.py).

Runs on 8 forced host devices (XLA_FLAGS set below, BEFORE jax imports —
the parent harness stays at 1 device) and prints one JSON dict on the last
stdout line.  Three measurements on a ("data","model")=(4,2) mesh:

  1. PARITY — a mixed-length shared-prefix workload through a sharded
     monolithic paged engine and a sharded prefill/decode DisaggEngine:
     greedy outputs must be token-identical, every sequence must hand off
     exactly once, and the per-role joules split (session stats AND every
     response's ``energy_by_role``) must conserve exactly;
  2. ATTRIBUTION — with a fixed carbon intensity, the role energy split
     exposes per-phase carbon (prefill/decode/handoff gCO2 summing to the
     session total) — the number CI-aware pool placement acts on;
  3. PREFILL THROUGHPUT — a prompt-heavy (max_new_tokens=1) workload runs
     entirely on the prefill pool; its prompt-tokens/s must not fall below
     the monolithic engine's on the same workload at equal chips per
     worker (best-of-3 warm sessions each; the prefill-role tick skips the
     decode dispatch machinery, so the split must not cost prefill
     throughput).
"""
import json
import os
import sys

if __name__ == "__main__":
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"

import numpy as np

CI_G_PER_KWH = 300.0


def main() -> int:
    import jax.numpy as jnp
    from repro.configs import get_smoke_config
    from repro.core import config_graph as CG
    from repro.launch.mesh import make_mesh_for
    from repro.obs.validate import check_disagg_conservation
    from repro.serving import engine as ENG
    from repro.serving.api import InferenceRequest, serve_workload

    cfg = get_smoke_config("qwen3-1.7b").with_(n_layers=2, dtype=jnp.float32)
    fam = ENG.build_engine_family(cfg, fracs=(1.0,))
    graph = CG.ConfigGraph.from_dict(cfg.name, {("x1", 16): 1})
    mesh = make_mesh_for(8, model_parallel=2)

    rng = np.random.default_rng(0)
    pre = rng.integers(0, cfg.vocab_size, size=12).astype(np.int32)
    prompts = [np.concatenate(
        [pre, rng.integers(0, cfg.vocab_size, size=n).astype(np.int32)])
        for n in (6, 14, 9, 22, 6, 11)]
    n_new = 8

    def build(**kw):
        eng = ENG.RealEngine(fam, n_slots=4, max_len=64, kv_layout="paged",
                             block_size=8, max_seqs=4, mesh=mesh,
                             ci_g_per_kwh=CI_G_PER_KWH, **kw)
        eng.configure(graph)
        return eng

    def reqs():
        return [InferenceRequest(rid=i, prompt=p, max_new_tokens=n_new)
                for i, p in enumerate(prompts)]

    # --- 1+2: parity + per-role attribution ------------------------------
    mono = build()
    rm = {r.rid: r for r in serve_workload(mono, reqs())}
    sm = mono.stats()
    dis = build(roles={"prefill": 1, "decode": 1})
    rd = {r.rid: r for r in serve_workload(dis, reqs())}
    sd = dis.stats()

    parity = set(rm) == set(rd) and all(
        np.array_equal(rm[rid].tokens, rd[rid].tokens) for rid in rm)
    if not parity:
        raise RuntimeError("disagg outputs diverged from the monolithic "
                           "engine (token parity broken)")
    if sd["handoffs"] != len(prompts):
        raise RuntimeError(f"expected {len(prompts)} handoffs, got "
                           f"{sd['handoffs']}")
    check_disagg_conservation(sd)
    check_disagg_conservation(sm)
    for r in rd.values():
        if abs(sum(r.energy_by_role.values()) - r.energy_j) \
                > 1e-9 * max(r.energy_j, 1e-12):
            raise RuntimeError(f"rid {r.rid}: energy_by_role does not sum "
                               f"to energy_j")
    # per-phase carbon: role joules × the serving window's intensity
    carbon = {role: sd[f"{role}_energy_j"] / 3.6e6 * CI_G_PER_KWH
              for role in ("prefill", "decode", "handoff")}
    if abs(sum(carbon.values()) - sd["carbon_g"]) \
            > 1e-9 * max(sd["carbon_g"], 1e-12):
        raise RuntimeError("per-phase carbon does not sum to the session "
                           "total")

    # --- 3: prefill-pool throughput vs monolithic ------------------------
    pf_prompts = [rng.integers(0, cfg.vocab_size, size=n).astype(np.int32)
                  for n in (24, 40, 32, 24, 40, 32, 24, 32)]
    pf_tokens = sum(len(p) for p in pf_prompts)

    def prefill_tps(eng):
        best = 0.0
        for _ in range(3):
            m = eng._serve_prompts(pf_prompts, n_new=1)
            assert m["served"] == len(pf_prompts)
            best = max(best, pf_tokens / max(m["wall_s"], 1e-9))
        return best

    tps_mono_pf = prefill_tps(mono)
    tps_dis_pf = prefill_tps(dis)     # n_new=1 → runs on the prefill pool
    ratio = tps_dis_pf / max(tps_mono_pf, 1e-9)
    if ratio < 0.95:
        raise RuntimeError(
            f"prefill pool lost throughput vs monolithic at equal chips: "
            f"{tps_dis_pf:.1f} vs {tps_mono_pf:.1f} tok/s (ratio "
            f"{ratio:.3f})")

    print(json.dumps({
        "token_parity": int(parity),
        "handoffs": int(sd["handoffs"]),
        "handoff_pages": int(sd["handoff_pages"]),
        "tokens_per_s_disagg": round(sd["tokens_per_s"], 1),
        "tokens_per_s_monolithic": round(sm["tokens_per_s"], 1),
        "prefill_tokens_per_s_disagg": round(tps_dis_pf, 1),
        "prefill_tokens_per_s_monolithic": round(tps_mono_pf, 1),
        "prefill_throughput_ratio": round(ratio, 3),
        "prefill_energy_j": round(sd["prefill_energy_j"], 4),
        "decode_energy_j": round(sd["decode_energy_j"], 4),
        "handoff_energy_j": round(sd["handoff_energy_j"], 4),
        "prefill_carbon_g": carbon["prefill"],
        "decode_carbon_g": carbon["decode"],
        "handoff_carbon_g": carbon["handoff"],
        "carbon_g_total": sd["carbon_g"],
        "role_conservation": 1,
    }))
    return 0


if __name__ == "__main__":
    sys.exit(main())
