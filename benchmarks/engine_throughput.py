"""Continuous batching vs batch-1 serving on the real-execution engine.

For every variant of the engine ladder, serve the same request set through
the SAME instance graph twice — once with a single KV-cache slot (the old
batch-1 engine's serial behaviour) and once with the full slotted cache —
and compare measured tokens/s, J/token and p95.  Greedy decoding is
deterministic, so both modes emit identical tokens: the comparison is at
strictly equal quality.

Writes ``benchmarks/out/engine_throughput.csv`` (one row per variant × mode)
for the perf trajectory, merges the headline numbers (tokens/s, J/token,
TTFT p95, blocks-in-use peak) into ``benchmarks/out/BENCH_engine.json`` so
the trajectory is machine-readable across PRs, and prints the repo's
``name,us_per_call,derived`` one-line-per-benchmark contract with the
continuous/batch-1 speedup as the derived value.

Usage:  PYTHONPATH=src python benchmarks/engine_throughput.py
            [--requests 16] [--new-tokens 8] [--slots 8] [--layers 8]
"""
from __future__ import annotations

import argparse
import csv
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

OUT_DIR = os.path.join(os.path.dirname(__file__), "out")


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-1.7b")
    ap.add_argument("--layers", type=int, default=8)
    ap.add_argument("--requests", type=int, default=16)
    ap.add_argument("--new-tokens", type=int, default=16)
    ap.add_argument("--slots", type=int, default=8)
    ap.add_argument("--prompt-len", type=int, default=6)
    ap.add_argument("--reps", type=int, default=3,
                    help="measured repetitions; best tokens/s wins (damps "
                         "CPU scheduling noise)")
    args = ap.parse_args()

    import jax.numpy as jnp
    import numpy as np

    from repro.configs import get_smoke_config
    from repro.core import config_graph as CG
    from repro.serving import engine as ENG
    from repro.serving.api import serve_prompts as serve

    base = get_smoke_config(args.arch).with_(n_layers=args.layers,
                                             dtype=jnp.float32)
    family = ENG.build_engine_family(base, fracs=(1.0, 0.5, 0.25))
    rng = np.random.default_rng(0)
    prompts = [rng.integers(0, base.vocab_size,
                            size=(1, args.prompt_len)).astype(np.int32)
               for _ in range(args.requests)]
    max_len = args.prompt_len + args.new_tokens + 2

    from _bench_json import update_bench_json

    rows = []
    bench = {}
    for ev in family:
        g = CG.ConfigGraph.from_dict(base.name, {(ev.variant.name, 16): 1})
        per_mode = {}
        for mode, n_slots in (("batch1", 1), ("continuous", args.slots)):
            eng = ENG.RealEngine(family, n_slots=n_slots, max_len=max_len)
            eng.configure(g)
            serve(eng, prompts, args.new_tokens)              # jit warmup
            m = None
            for _ in range(args.reps):
                mi = serve(eng, prompts, args.new_tokens)
                if m is None or mi["tokens_per_s"] > m["tokens_per_s"]:
                    m = mi
            per_mode[mode] = m
            rows.append({
                "variant": ev.variant.name,
                "n_layers": ev.cfg.n_layers,
                "mode": mode,
                "n_slots": n_slots,
                "requests": m["served"],
                "tokens": m["tokens"],
                "wall_s": round(m["wall_s"], 6),
                "tokens_per_s": round(m["tokens_per_s"], 2),
                "j_per_token": round(m["j_per_token"], 5),
                "p50_s": round(m["p50_s"], 6),
                "p95_s": round(m["p95_s"], 6),
                "mean_occupancy": round(m["mean_occupancy"], 3),
                "energy_j": round(m["energy_j"], 3),
            })
        b1, cb = per_mode["batch1"], per_mode["continuous"]
        speedup = cb["tokens_per_s"] / max(b1["tokens_per_s"], 1e-9)
        energy_saving = 1.0 - cb["j_per_token"] / max(b1["j_per_token"], 1e-12)
        us = cb["wall_s"] / max(cb["tokens"], 1) * 1e6
        bench[ev.variant.name] = {
            "tokens_per_s": round(cb["tokens_per_s"], 2),
            "j_per_token": round(cb["j_per_token"], 5),
            "ttft_p95_s": round(cb.get("ttft_p95_s", 0.0), 6),
            "blocks_peak": cb.get("blocks_peak", 0),
            "p95_s": round(cb["p95_s"], 6),
            "speedup_vs_batch1": round(speedup, 3),
        }
        print(f"engine_throughput_{ev.variant.name},{us:.1f},"
              f"speedup={speedup:.2f}x j_saving={energy_saving * 100:.0f}%")

    os.makedirs(OUT_DIR, exist_ok=True)
    path = os.path.join(OUT_DIR, "engine_throughput.csv")
    with open(path, "w", newline="") as f:
        w = csv.DictWriter(f, fieldnames=list(rows[0]))
        w.writeheader()
        w.writerows(rows)
    print(f"wrote {path} ({len(rows)} rows)")
    jpath = update_bench_json("engine_throughput", bench)
    print(f"updated {jpath}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
