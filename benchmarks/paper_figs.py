"""One benchmark per paper figure/table (DESIGN.md §6 index).

Each function returns (derived_dict, csv_rows); benchmarks/run.py times them
and emits the ``name,us_per_call,derived`` CSV contract.
"""
from __future__ import annotations

import itertools
from typing import Dict, List, Tuple

import numpy as np

from repro.core import carbon as CB
from repro.core import catalog as CAT
from repro.core import config_graph as CG
from repro.core import objective as OBJ
from repro.core import perf_model as PM
from repro.core import slices as SL
from repro.serving import simulator as SIM

TRACE_HOURS = 48.0
N_BLOCKS = 4
APPS = ("efficientnet", "albert", "yolov5")

_trace_cache: Dict[str, CB.CarbonTrace] = {}
_report_cache: Dict[tuple, SIM.SimReport] = {}


def trace(region="CISO-March", hours=TRACE_HOURS):
    key = f"{region}:{hours}"
    if key not in _trace_cache:
        _trace_cache[key] = CB.make_trace(region, hours=hours)
    return _trace_cache[key]


def report(scheme, family, region="CISO-March", hours=TRACE_HOURS, **simkw):
    key = (scheme, family, region, hours, tuple(sorted(simkw.items())))
    if key not in _report_cache:
        _report_cache[key] = SIM.run_trace(
            scheme, family, trace(region, hours),
            SIM.SimConfig(n_blocks=N_BLOCKS, **simkw))
    return _report_cache[key]


# =============================================================================
# Fig. 2 — mixed-quality frontier (carbon saving vs accuracy)
# =============================================================================
def fig02_mixed_quality():
    """Two frontiers: (a) unpartitioned mixed-quality (the paper's Fig. 2
    setting — each block hosts one variant on all 16 chips); (b) the full
    mixed-quality × partitioning space Clover actually exploits.  On TPU the
    unpartitioned span is narrower than the paper's A100 measurement (flatter
    busy-power curve — DESIGN.md §2 changed assumptions); partitioning
    recovers the paper's 60–80 % range."""
    variants = CAT.get_family("efficientnet")
    base = CG.ConfigGraph.uniform("efficientnet", "B7", 16, N_BLOCKS)
    arrival = OBJ.evaluate(base, variants, 1e-9).capacity_rps * 0.7
    res_base = OBJ.evaluate(base, variants, arrival)
    rows = []
    names = [v.name for v in variants]
    for mix in itertools.combinations_with_replacement(names, N_BLOCKS):
        w: Dict = {}
        for m in mix:
            w[(m, 16)] = w.get((m, 16), 0) + 1
        g = CG.ConfigGraph.from_dict("efficientnet", w)
        r = OBJ.evaluate(g, variants, arrival)
        save = (1 - r.energy_per_req_j / res_base.energy_per_req_j) * 100
        rows.append(("unpartitioned", ",".join(mix), save,
                     r.accuracy / res_base.accuracy))
    # (b) mixed quality × slice sizes (uniform per block over the catalog)
    for part in SL.partition_catalog():
        sizes = sorted(set(part), reverse=True)
        for choice in itertools.product(names, repeat=len(sizes)):
            vmap = dict(zip(sizes, choice))
            w = {}
            for s in part:
                e = (vmap[s], s)
                w[e] = w.get(e, 0) + N_BLOCKS
            g = CG.ConfigGraph.from_dict("efficientnet", w)
            r = OBJ.evaluate(g, variants, arrival)
            save = (1 - r.energy_per_req_j / res_base.energy_per_req_j) * 100
            rows.append(("partitioned", "|".join(f"{v}@{s}c" for s, v in vmap.items()),
                         save, r.accuracy / res_base.accuracy))
    def best_at(loss):
        ok = [r for r in rows if r[3] >= 1 - loss]
        return max((r[2] for r in ok), default=0.0)
    derived = {
        "n_points": len(rows),
        "unpartitioned_max_saving_pct": max(r[2] for r in rows
                                            if r[0] == "unpartitioned"),
        "max_saving_at_5pct_loss": round(best_at(0.05), 1),
        "max_saving_at_10pct_loss": round(best_at(0.10), 1),
    }
    csv = [("space", "mix", "carbon_saving_pct", "rel_accuracy")] + rows
    return derived, csv


# =============================================================================
# Fig. 3 — GPU partitioning: carbon vs latency (same variant, C1/C2/C3)
# =============================================================================
def fig03_partitioning():
    variants = CAT.get_family("efficientnet")
    v = variants[2]                      # B5, fixed quality (paper keeps variant fixed)
    configs = {"C1": (16,), "C2": (8, 4, 2, 1, 1), "C3": (1,) * 16}
    base_g = CG.ConfigGraph.uniform("efficientnet", v.name, 16, N_BLOCKS)
    arrival = OBJ.evaluate(base_g, variants, 1e-9).capacity_rps * 0.7
    rows, derived = [], {}
    base_carbon = base_lat = None
    for name, part in configs.items():
        w: Dict = {}
        for chips in part:
            w[(v.name, chips)] = w.get((v.name, chips), 0) + N_BLOCKS
        g = CG.ConfigGraph.from_dict("efficientnet", w)
        r = OBJ.evaluate(g, variants, arrival)
        lat = PM.cached_point(v, min(part)).latency_s
        if name == "C1":
            base_carbon, base_lat = r.energy_per_req_j, lat
        rows.append((name, r.energy_per_req_j, lat, r.p95_latency_s))
    derived["carbon_reduction_C3_vs_C1_pct"] = \
        (1 - rows[2][1] / rows[0][1]) * 100
    derived["latency_increase_C3_vs_C1_x"] = rows[2][2] / rows[0][2]
    csv = [("config", "energy_per_req_j", "slice_latency_s", "p95_s")] + rows
    return derived, csv


# =============================================================================
# Fig. 8 — carbon traces used for evaluation
# =============================================================================
def fig08_traces():
    rows = [("region", "min_gco2", "max_gco2", "mean_gco2", "max_halfday_swing")]
    derived = {}
    for region in ("CISO-March", "CISO-September", "ESO-March"):
        tr = trace(region)
        half = int(12 * 3600 / (tr.times_s[1] - tr.times_s[0]))
        swing = max(np.ptp(tr.intensity[i:i + half])
                    for i in range(0, len(tr.intensity) - half, half))
        rows.append((region, tr.intensity.min(), tr.intensity.max(),
                     tr.mean(), swing))
        derived[f"{region}_swing"] = round(float(swing), 1)
    return derived, rows


# =============================================================================
# Fig. 9 — Clover vs BASE per application (48 h CISO-March)
# =============================================================================
def fig09_effectiveness():
    rows = [("app", "carbon_saving_pct", "accuracy_delta_pct", "p95_vs_sla")]
    savings, dacc = [], []
    for app in APPS:
        base = report("BASE", app)
        clv = report("CLOVER", app)
        s = (1 - clv.carbon_per_req_g() / base.carbon_per_req_g()) * 100
        da = (clv.accuracy - base.accuracy) / base.accuracy * 100
        rows.append((app, s, da, clv.p95_latency_s / clv.sla_target_s))
        savings.append(s)
        dacc.append(da)
    derived = {"mean_carbon_saving_pct": float(np.mean(savings)),
               "mean_accuracy_delta_pct": float(np.mean(dacc)),
               "all_sla_met": all(r[3] <= 1.05 for r in rows[1:])}
    return derived, rows


# =============================================================================
# Fig. 10 — scheme comparison (accuracy gain vs carbon saved)
# =============================================================================
def fig10_schemes():
    rows = [("app", "scheme", "carbon_saving_pct", "accuracy_delta_pct", "f")]
    derived = {}
    for app in APPS:
        base = report("BASE", app)
        for scheme in ("CO2OPT", "BLOVER", "CLOVER", "ORACLE"):
            r = report(scheme, app)
            s = (1 - r.carbon_per_req_g() / base.carbon_per_req_g()) * 100
            da = (r.accuracy - base.accuracy) / base.accuracy * 100
            rows.append((app, scheme, s, da, 0.1 * s + 0.9 * da))
        f = {sch: next(r[4] for r in rows[1:]
                       if r[0] == app and r[1] == sch)
             for sch in ("CO2OPT", "BLOVER", "CLOVER", "ORACLE")}
        derived[f"{app}_clover_vs_oracle"] = round(f["CLOVER"] / max(f["ORACLE"], 1e-9), 3)
        derived[f"{app}_clover_beats_blover"] = bool(f["CLOVER"] > f["BLOVER"])
    return derived, rows


# =============================================================================
# Fig. 11 — objective over time
# =============================================================================
def fig11_objective_timeline():
    rows = [("scheme", "t_s", "f")]
    derived = {}
    for scheme in ("CO2OPT", "BLOVER", "CLOVER", "ORACLE"):
        r = report(scheme, "efficientnet")
        tl = r.timeline
        for i in range(0, len(tl["t"]), 30):
            rows.append((scheme, float(tl["t"][i]), float(tl["f"][i])))
        derived[f"{scheme}_mean_f"] = round(float(np.mean(tl["f"])), 2)
    derived["clover_tracks_oracle"] = bool(
        derived["CLOVER_mean_f"] >= 0.75 * derived["ORACLE_mean_f"])
    return derived, rows


# =============================================================================
# Fig. 12 — optimization overhead + SLA-compliant evaluations
# =============================================================================
def fig12_overhead():
    rows = [("scheme", "opt_time_pct", "n_evals", "evals_sla_ok_pct")]
    derived = {}
    for scheme in ("BLOVER", "CLOVER"):
        r = report(scheme, "efficientnet")
        ok_pct = r.evals_sla_ok / max(r.n_evals, 1) * 100
        rows.append((scheme, r.opt_time_frac * 100, r.n_evals, ok_pct))
        derived[f"{scheme.lower()}_opt_pct"] = round(r.opt_time_frac * 100, 2)
        derived[f"{scheme.lower()}_evals"] = r.n_evals
        derived[f"{scheme.lower()}_evals_sla_ok_pct"] = round(ok_pct, 1)
    derived["clover_fewer_evals"] = bool(
        derived["clover_evals"] <= derived["blover_evals"])
    derived["clover_more_compliant"] = bool(
        derived["clover_evals_sla_ok_pct"] >= derived["blover_evals_sla_ok_pct"])
    return derived, rows


# =============================================================================
# Fig. 13 — SA trajectory of selected invocations
# =============================================================================
def fig13_trajectory():
    import random
    from repro.core import annealing as SA
    from repro.core import schemes as SCH
    ctx, arrival = SIM.make_context("efficientnet", SIM.SimConfig(n_blocks=N_BLOCKS))
    ev = ctx.evaluator()
    rows = [("invocation", "eval_idx", "f", "sla_ok")]
    start = SCH.base_config(ctx)
    outs = []
    for i, ci in enumerate((350.0, 250.0, 120.0)):
        out = SA.anneal(start, ctx.variants, ev, ci, ctx.obj_cfg, ctx.sa_cfg,
                        rng=random.Random(i))
        for j, e in enumerate(out.evaluations):
            rows.append((i + 1, j, e.f, e.sla_ok))
        start = out.best
        outs.append(out)
    derived = {
        "inv1_evals": outs[0].n_evals,
        "inv3_evals": outs[2].n_evals,
        "later_invocations_more_compliant": bool(
            outs[2].sla_compliant_frac >= outs[0].sla_compliant_frac),
    }
    return derived, rows


# =============================================================================
# Fig. 14 — λ sweep + accuracy-loss threshold mode
# =============================================================================
def fig14_lambda(hours=12.0):
    rows = [("mode", "value", "carbon_saving_pct", "accuracy_delta_pct")]
    base = report("BASE", "efficientnet", hours=hours)
    derived = {}
    saves = []
    for lam in (0.1, 0.5, 0.9):
        r = report("CLOVER", "efficientnet", hours=hours, lam=lam)
        s = (1 - r.carbon_per_req_g() / base.carbon_per_req_g()) * 100
        da = (r.accuracy - base.accuracy) / base.accuracy * 100
        rows.append(("lambda", lam, s, da))
        saves.append(s)
    derived["saving_monotone_in_lambda"] = bool(
        saves[0] <= saves[1] + 2 and saves[1] <= saves[2] + 2)
    for thr in (0.2, 0.8):
        r = report("CLOVER", "efficientnet", hours=hours,
                   accuracy_threshold_pct=thr)
        s = (1 - r.carbon_per_req_g() / base.carbon_per_req_g()) * 100
        da = (r.accuracy - base.accuracy) / base.accuracy * 100
        rows.append(("acc_threshold", thr, s, da))
        derived[f"thr{thr}_saving"] = round(s, 1)
        derived[f"thr{thr}_dacc_ok"] = bool(-da <= thr + 0.05)
    return derived, rows


# =============================================================================
# Fig. 15 — consolidation: fewer blocks under Clover still meet the SLA
# =============================================================================
def fig15_consolidation(hours=6.0):
    """Provisioning fewer blocks at fixed offered load (paper Fig. 15).

    Clover's consolidated configurations come from the *elastic-scaling path*
    the paper's additivity property enables (§4.2): the converged 4-block
    configuration's per-block quotient is kept when blocks are removed
    (Controller.scale_blocks), exactly how an operator would shrink the
    fleet — not a cold restart at 1 block."""
    import random
    from repro.core import annealing as SA
    from repro.core import controller as CTRL
    from repro.core import schemes as SCH
    ctx, arrival = SIM.make_context("efficientnet", SIM.SimConfig(n_blocks=N_BLOCKS))
    base_eval = OBJ.evaluate(SCH.base_config(ctx), ctx.variants, arrival)
    sla = ctx.obj_cfg.l_tail_s
    # converge Clover once at 4 blocks (big budget)
    big = SA.SAConfig(stale_limit=25, time_limit_s=600.0)
    out = SA.anneal(SCH.base_config(ctx), ctx.variants, ctx.evaluator(), 250.0,
                    ctx.obj_cfg, big, rng=random.Random(0))
    ctrl = CTRL.Controller(SCH.make_scheme("BASE"), ctx)
    ctrl.config = out.best

    rows = [("scheme", "n_blocks", "p95_vs_sla", "carbon_saving_pct")]
    derived = {}
    for nb in (N_BLOCKS, 2, 1):
        # BASE shrunk: highest-quality unpartitioned on nb blocks
        gb = CG.ConfigGraph.uniform("efficientnet",
                                    CAT.best_variant(ctx.variants).name,
                                    16, nb)
        rb = OBJ.evaluate(gb, ctx.variants, arrival)
        rows.append(("BASE", nb, rb.p95_latency_s / sla,
                     (1 - rb.energy_per_req_j / base_eval.energy_per_req_j) * 100))
        derived[f"BASE_{nb}blocks_sla_ratio"] = round(
            min(rb.p95_latency_s / sla, 1e6), 2)
        # CLOVER scaled via additivity
        per_block = {e: max(w // N_BLOCKS, 1) for e, w in out.best.edges}
        gq = CG.ConfigGraph.from_dict("efficientnet",
                                      {e: w * nb for e, w in per_block.items()})
        rc = OBJ.evaluate(gq, ctx.variants, arrival)
        rows.append(("CLOVER", nb, rc.p95_latency_s / sla,
                     (1 - rc.energy_per_req_j / base_eval.energy_per_req_j) * 100))
        derived[f"CLOVER_{nb}blocks_sla_ratio"] = round(
            min(rc.p95_latency_s / sla, 1e6), 2)
    derived["clover_meets_sla_at_quarter_capacity"] = bool(
        derived["CLOVER_1blocks_sla_ratio"] <= 1.1)
    derived["base_violates_when_shrunk"] = bool(
        derived["BASE_1blocks_sla_ratio"] > derived["BASE_4blocks_sla_ratio"])
    return derived, rows


# =============================================================================
# Fig. 16 — geographies / seasons
# =============================================================================
def fig16_geo(hours=24.0):
    rows = [("region", "app", "carbon_saving_pct", "accuracy_delta_pct")]
    derived = {}
    for region in ("CISO-March", "CISO-September", "ESO-March"):
        saves = []
        for app in APPS:
            base = report("BASE", app, region=region, hours=hours)
            r = report("CLOVER", app, region=region, hours=hours)
            s = (1 - r.carbon_per_req_g() / base.carbon_per_req_g()) * 100
            da = (r.accuracy - base.accuracy) / base.accuracy * 100
            rows.append((region, app, s, da))
            saves.append(s)
        derived[f"{region}_mean_saving"] = round(float(np.mean(saves)), 1)
    derived["effective_everywhere"] = all(
        v > 30 for k, v in derived.items() if k.endswith("_mean_saving"))
    return derived, rows


# =============================================================================
# §5.2.1 — ChatGPT-scale savings estimate
# =============================================================================
def table_chatgpt_estimate():
    base = report("BASE", "albert")
    clv = report("CLOVER", "albert")
    per_req_saving_g = base.carbon_per_req_g() - clv.carbon_per_req_g()
    visitors = 25e6
    kg_per_day = per_req_saving_g * visitors / 1000.0
    km_gasoline_car = kg_per_day / 0.251       # EPA: ~251 gCO2/km
    derived = {"saving_g_per_request": round(per_req_saving_g, 4),
               "kg_co2_per_day_25M_requests": round(kg_per_day, 1),
               "equiv_gasoline_car_km_per_day": round(km_gasoline_car, 0)}
    rows = [("metric", "value")] + [(k, v) for k, v in derived.items()]
    return derived, rows


# =============================================================================
# Beyond-paper: Clover over the assigned LM architecture ladders
# =============================================================================
def table_lm_serving(hours=12.0):
    """The paper's technique applied to the assigned-pool LM architectures:
    each arch becomes a Clover family via its AutoML-style depth ladder
    (core/catalog.lm_ladder) — carbon-aware LLM serving across model classes
    (dense / MoE / SSM / hybrid).  Demonstrates DESIGN.md §Arch-applicability:
    no assigned architecture is inapplicable to the serving technique."""
    rows = [("arch", "family_kind", "carbon_saving_pct", "accuracy_delta_pct",
             "p95_vs_sla", "opt_time_pct")]
    derived = {}
    archs = (("qwen3-1.7b", "dense"), ("qwen3-moe-30b-a3b", "moe"),
             ("mamba2-2.7b", "ssm"), ("zamba2-2.7b", "hybrid"),
             ("glm4-9b", "dense"))
    for arch, kind in archs:
        base = report("BASE", arch, hours=hours)
        clv = report("CLOVER", arch, hours=hours)
        s = (1 - clv.carbon_per_req_g() / base.carbon_per_req_g()) * 100
        da = (clv.accuracy - base.accuracy) / base.accuracy * 100
        rows.append((arch, kind, s, da, clv.p95_latency_s / clv.sla_target_s,
                     clv.opt_time_frac * 100))
        derived[f"{arch}_saving"] = round(s, 1)
    derived["all_sla_met"] = all(r[4] <= 1.05 for r in rows[1:])
    # LM ladders span a narrower latency/energy range than the CNN families
    # (every variant is a large always-busy model), so savings are smaller
    # than the paper apps' — the mechanism still transfers to every family.
    derived["all_save_carbon"] = all(r[2] > 5 for r in rows[1:])
    return derived, rows
