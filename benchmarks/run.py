"""Benchmark harness — one function per paper table/figure + roofline tables.

Prints the ``name,us_per_call,derived`` CSV contract (one line per benchmark)
and writes the full per-figure CSVs to benchmarks/out/.

Usage:  PYTHONPATH=src python -m benchmarks.run [--only fig09] [--fast]
"""
from __future__ import annotations

import argparse
import csv
import gc
import json
import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

OUT_DIR = os.path.join(os.path.dirname(__file__), "out")


def _benchmarks(fast: bool):
    from benchmarks import paper_figs as F
    if fast:
        F.TRACE_HOURS = 6.0
    items = [
        ("fig02_mixed_quality", F.fig02_mixed_quality),
        ("fig03_partitioning", F.fig03_partitioning),
        ("fig08_traces", F.fig08_traces),
        ("fig09_effectiveness", F.fig09_effectiveness),
        ("fig10_schemes", F.fig10_schemes),
        ("fig11_objective_timeline", F.fig11_objective_timeline),
        ("fig12_overhead", F.fig12_overhead),
        ("fig13_trajectory", F.fig13_trajectory),
        ("fig14_lambda", F.fig14_lambda),
        ("fig15_consolidation", F.fig15_consolidation),
        ("fig16_geo", F.fig16_geo),
        ("table_chatgpt", F.table_chatgpt_estimate),
        ("table_lm_serving", F.table_lm_serving),
        ("roofline_baseline", _roofline_bench),
        ("carbon_policy_serving", _carbon_policy_bench),
        ("observability_telemetry", _observability_bench),
        ("decode_hotpath", _decode_hotpath_bench),
        ("mixed_quality_serving", _mixed_quality_bench),
        ("disagg_serving", _disagg_bench),
    ]
    return items


def _roofline_bench():
    """Roofline terms for every compiled dry-run cell (single-pod mesh)."""
    from repro.launch import roofline as RL
    path = os.path.join(os.path.dirname(__file__), "..", "dryrun_results.json")
    if not os.path.exists(path):
        return {"skipped": "run repro.launch.dryrun first"}, [("missing",)]
    rows = RL.analyze_file(path, mesh="16x16")
    csv_rows = [("arch", "shape", "t_compute_s", "t_memory_s", "t_coll_s",
                 "dominant", "useful_ratio", "roofline_frac", "mem_gib")]
    for r in rows:
        csv_rows.append((r["arch"], r["shape"], r["t_compute_s"],
                         r["t_memory_s"], r["t_collective_s"], r["dominant"],
                         round(r["useful_flops_ratio"], 3),
                         round(r["roofline_fraction"], 4),
                         round(r["mem_footprint_gib"], 2)))
    dom = {}
    for r in rows:
        dom[r["dominant"]] = dom.get(r["dominant"], 0) + 1
    derived = {"cells": len(rows), "dominant_counts": dom,
               "median_roofline_frac": round(
                   sorted(x["roofline_fraction"] for x in rows)[len(rows) // 2], 4)}
    return derived, csv_rows


def _carbon_policy_bench():
    """Forecast-driven carbon scheduling vs the raw-trace threshold policy,
    plus partial swap-in page savings — the PR-5 control-plane numbers.

    Stage 1 (DES, diurnal trace): deferrable work arriving on the morning
    CI decline under (a) ``CarbonAwarePolicy`` with a raw trace lookup and
    threshold release and (b) ``CarbonForecastPolicy`` scheduling for the
    forecast valley inside the deadline runway (``fleet.forecast`` ensemble
    through ``ForecastCIFn``).  Both must meet every deadline and hold the
    interactive SLA; the forecast policy must come back with lower
    gCO2/request.

    Stage 2 (real paged engine): an overcommitted arena forces decode-time
    preemption with a shared prompt preamble in the radix tree; partial
    swap-in must restore strictly fewer pages than a full restore while
    emitting token-identical greedy outputs vs a never-preempted reference.
    """
    import numpy as np

    from repro.core import carbon as CB
    from repro.core import catalog as CAT
    from repro.core import config_graph as CG
    from repro.fleet.forecast import EnsembleForecaster, ForecastCIFn
    from repro.serving import queue as Q
    from repro.serving.api import DEFERRABLE, INTERACTIVE, InferenceRequest, \
        serve_workload
    from repro.serving.policies import CarbonAwarePolicy, CarbonForecastPolicy

    # --- stage 1: forecast valley vs raw threshold (DES, diurnal) -----------
    trace = CB.make_trace("CISO-March", hours=72, seed=3)
    t0 = 36 * 3600.0
    ts = np.arange(t0, t0 + 24 * 3600.0, 600.0)
    t_valley = float(ts[int(np.argmin([trace.at(float(t)) for t in ts]))])
    arrival = t_valley - 9 * 3600.0
    deadline = t_valley + 4 * 3600.0
    threshold = trace.mean()     # the raw policy's natural operating point
    # deferrable entries model BATCH jobs (the fleet's jobs carry ~1e5
    # requests each): max_new_tokens scales DES service time, so one entry
    # is ~60 s of busy drain — enough busy joules that the policy's choice
    # of serving window is visible over the session's idle floor
    n_defer, n_inter = 48, 12
    defer_tokens = 80_000
    inter_gap = (deadline - arrival) / n_inter

    def reqs():
        out = [InferenceRequest(rid=i, prompt=[1],
                                max_new_tokens=defer_tokens,
                                arrival_s=arrival, slo=DEFERRABLE,
                                deadline_s=deadline) for i in range(n_defer)]
        out += [InferenceRequest(rid=n_defer + i, prompt=[1],
                                 max_new_tokens=8,
                                 arrival_s=arrival + inter_gap * i,
                                 slo=INTERACTIVE) for i in range(n_inter)]
        return out

    # two instances: one absorbs the interactive stream while the other
    # drains released batch work, as the fleet's spare capacity would
    des_g = CG.ConfigGraph.from_dict("efficientnet", {("B3", 1): 2})
    variants = CAT.get_family("efficientnet")
    est_svc = 0.006 * defer_tokens / 8.0
    policies = {
        "carbon_raw": CarbonAwarePolicy(lambda now: trace.at(now or 0.0),
                                        ci_threshold=threshold,
                                        est_service_s=est_svc,
                                        deadline_margin_s=1800.0),
        "carbon_forecast": CarbonForecastPolicy(
            ForecastCIFn(EnsembleForecaster(trace)),
            horizon_s=8 * 3600.0, step_s=1800.0,
            est_service_s=est_svc, deadline_margin_s=1800.0),
    }
    rows = [("stage", "metric", "value")]
    stats = {}
    for name, pol in policies.items():
        des = Q.DESBackend(des_g, variants, Q.DESConfig(jitter_sigma=0.0),
                           policy=pol, ci_g_per_kwh=trace.at,
                           hold_retry_s=300.0)
        responses = serve_workload(des, reqs())
        m = des.stats()
        inter_worst = max(r.latency_s for r in responses
                          if r.slo == INTERACTIVE)
        m["interactive_worst_s"] = inter_worst
        stats[name] = m
        rows += [("des", f"{name}_carbon_g_per_req",
                  round(m["carbon_g_per_req"], 4)),
                 ("des", f"{name}_deadline_misses", m["deadline_misses"]),
                 ("des", f"{name}_interactive_worst_s",
                  round(inter_worst, 3))]
    saving = (1.0 - stats["carbon_forecast"]["carbon_g_per_req"]
              / max(stats["carbon_raw"]["carbon_g_per_req"], 1e-12)) * 100
    # equal SLA attainment: zero deadline misses under both, and the
    # interactive stream's worst case stayed in the same band
    sla_equal = int(stats["carbon_raw"]["deadline_misses"] == 0
                    and stats["carbon_forecast"]["deadline_misses"] == 0
                    and stats["carbon_forecast"]["interactive_worst_s"]
                    <= stats["carbon_raw"]["interactive_worst_s"] + est_svc)

    # --- stage 2: partial swap-in pages saved (real paged engine) -----------
    import jax.numpy as jnp

    from repro.configs import get_smoke_config
    from repro.serving import engine as ENG
    base = get_smoke_config("qwen3-1.7b").with_(n_layers=2,
                                                dtype=jnp.float32)
    family = ENG.build_engine_family(base, fracs=(1.0,))
    g = CG.ConfigGraph.from_dict(base.name, {("x1", 16): 1})
    rng = np.random.default_rng(5)
    pre = rng.integers(0, base.vocab_size, size=16).astype(np.int32)
    prompts = [np.concatenate([pre, rng.integers(0, base.vocab_size, size=6)
                               .astype(np.int32)]) for _ in range(4)]
    ref = ENG.RealEngine(family, n_slots=2, max_len=64, kv_layout="paged",
                         block_size=8, max_seqs=4, n_blocks=41)
    ref.configure(g)
    ref._serve_prompts(prompts, n_new=16)
    eng = ENG.RealEngine(family, n_slots=2, max_len=64, kv_layout="paged",
                         block_size=8, max_seqs=4, n_blocks=14,
                         preemption=True)
    eng.configure(g)
    m_swap = eng._serve_prompts(prompts, n_new=16)
    parity = int(all(
        np.array_equal(ref.last_outputs[rid], eng.last_outputs[rid])
        for rid in ref.last_outputs))
    full_pages = (m_swap["swapin_pages_copied"]
                  + m_swap["partial_swapin_pages_saved"])
    # the scenario must keep its teeth: if a retuned arena stops preempting
    # (or parity breaks) this benchmark must FAIL, not record zeros
    if m_swap["preemptions"] < 1 or full_pages < 1 or not parity:
        raise RuntimeError(
            f"partial swap-in scenario degenerated: preemptions="
            f"{m_swap['preemptions']}, restore pages={full_pages}, "
            f"parity={parity}")
    rows += [("engine", "preemptions", m_swap["preemptions"]),
             ("engine", "swapin_pages_full_restore", full_pages),
             ("engine", "swapin_pages_copied", m_swap["swapin_pages_copied"]),
             ("engine", "partial_swapin_pages_saved",
              m_swap["partial_swapin_pages_saved"]),
             ("engine", "swapin_token_parity", parity)]
    derived = {
        "carbon_g_per_req_raw": round(
            stats["carbon_raw"]["carbon_g_per_req"], 4),
        "carbon_g_per_req_forecast": round(
            stats["carbon_forecast"]["carbon_g_per_req"], 4),
        "forecast_saving_pct": round(saving, 2),
        "sla_equal_deadlines_met": sla_equal,
        "preemptions": int(m_swap["preemptions"]),
        "partial_swapin_pages_saved": int(
            m_swap["partial_swapin_pages_saved"]),
        "swapin_pages_copied": int(m_swap["swapin_pages_copied"]),
        "swapin_token_parity": parity,
    }
    return derived, rows


def _observability_bench():
    """Unified-telemetry acceptance numbers (PR-6 observability layer).

    Stage 1 (shared workload, three backends): one camel-shaped request
    stream runs through the DES backend, the fluid backend, and the real
    paged engine, each with the full telemetry bundle.  All three must
    expose the *identical* metric-name set (the shared CATALOG), and each
    trace must pass the conservation validator — every span closed,
    span-attributed joules equal to the backend's session energy total.
    The engine trace is exported to ``benchmarks/out/trace_engine.json``
    (Perfetto-loadable) and schema-checked.

    Stage 2 (overhead gate): the same compiled paged engine serves the same
    prompts with telemetry detached vs attached (best of ``reps`` runs
    each); tracing + metrics may cost at most ``OVERHEAD_GATE_PCT`` of
    tokens/s, else this benchmark FAILS.

    Stage 3 (layout regression gate): slotted vs paged at equal batch
    (n_slots == max_seqs == 4, identical prompts/compiled family).  Paged
    tokens/s below ``PAGED_GATE_FRAC`` × slotted fails the run — the gate
    that catches a paged-attention throughput regression riding in on an
    unrelated change.  Both gate values land in BENCH_engine.json via
    ``--json``.

    Stage 4 (fleet-plane gates, PR-8 observability plane): (a) the three
    stage-1 registries plus a fleet rollup over them must expose the
    IDENTICAL OpenMetrics family-name set, each exposition round-tripping
    exactly; (b) the TOTAL plane cost — phase profiling (telemetry
    attached), exposition + parse-validation, and a fleet-rollup merge per
    session — may cost at most ``OVERHEAD_GATE_PCT`` of tokens/s vs the
    bare engine (``plane_overhead_pct`` in BENCH_engine.json).
    """
    import numpy as np

    from repro.core import catalog as CAT
    from repro.core import config_graph as CG
    from repro.fleet.workload import shaped_request_stream
    from repro.obs import CATALOG, CarbonFeed, FleetRollup, Telemetry, \
        TraceRecorder, parse_openmetrics, to_openmetrics, \
        validate_chrome_events, validate_trace
    from repro.obs.export import render_families
    from repro.serving import queue as Q
    from repro.serving.api import serve_workload
    from repro.serving.backends import FluidBackend

    OVERHEAD_GATE_PCT = 5.0
    # the pipelined device-resident decode loop (fused dispatches, async
    # readback, event-bound uploads) lifted the measured equal-batch ratio
    # from 0.70-0.98 (synchronous loop) to >= 1.0 on the CPU smoke config;
    # the gate sits under the new noise floor so a regression in either the
    # paged kernel or the hot path trips it
    PAGED_GATE_FRAC = 0.85

    import jax.numpy as jnp

    from repro.configs import get_smoke_config
    from repro.serving import engine as ENG
    base = get_smoke_config("qwen3-1.7b").with_(n_layers=2,
                                                dtype=jnp.float32)
    ci = 220.0

    def workload():
        return shaped_request_stream(16, 1.0, vocab_size=base.vocab_size,
                                     shape="camel", prompt_lens=(6, 10),
                                     n_new=8, seed=11)

    def bundle(backend):
        return Telemetry(tracer=TraceRecorder(backend),
                         feed=CarbonFeed(lambda t: ci, interval_s=30.0,
                                         region=backend),
                         backend=backend)

    # --- stage 1: shared workload, three backends, one metric namespace ----
    variants = CAT.get_family("efficientnet")
    des_g = CG.ConfigGraph.from_dict("efficientnet", {("B3", 1): 1})
    tel_des = bundle("des")
    des = Q.DESBackend(des_g, variants, Q.DESConfig(jitter_sigma=0.0),
                       ci_g_per_kwh=ci, telemetry=tel_des)
    serve_workload(des, workload())
    m_des = des.stats()
    validate_trace(tel_des.tracer, expect_energy_j=m_des["energy_j"],
                   expect_requests=int(m_des["served"]))

    tel_fluid = bundle("fluid")
    fluid = FluidBackend(des_g, variants, sla_target_s=2.0, window_s=0.25,
                         ci_g_per_kwh=ci, telemetry=tel_fluid)
    serve_workload(fluid, workload())
    m_fluid = fluid.stats()
    validate_trace(tel_fluid.tracer, expect_energy_j=m_fluid["energy_j"],
                   expect_requests=int(m_fluid["served"]))

    family = ENG.build_engine_family(base, fracs=(1.0,))
    g = CG.ConfigGraph.from_dict(base.name, {("x1", 16): 1})
    tel_real = bundle("real-paged")
    eng = ENG.RealEngine(family, n_slots=4, max_len=48, kv_layout="paged",
                         block_size=8, max_seqs=4, n_blocks=28,
                         ci_g_per_kwh=ci, telemetry=tel_real)
    eng.configure(g)
    serve_workload(eng, workload())
    m_eng = eng.stats()
    validate_trace(tel_real.tracer, expect_energy_j=m_eng["energy_j"],
                   expect_requests=int(m_eng["served"]))
    trace_path = os.path.join(OUT_DIR, "trace_engine.json")
    tel_real.tracer.to_chrome_trace(trace_path)
    with open(trace_path) as f:
        n_events = validate_chrome_events(json.load(f)["traceEvents"])

    name_sets = [des.registry.names(), fluid.registry.names(),
                 eng.last_registry.names()]
    if not all(s == set(CATALOG) for s in name_sets):
        raise RuntimeError(f"metric namespaces diverged: "
                           f"{[sorted(s ^ set(CATALOG)) for s in name_sets]}")
    tol = 1e-6 * m_eng["energy_j"]
    if abs(tel_real.feed.energy_j_total + tel_real.feed.pending_energy_j
           - m_eng["energy_j"]) > tol:
        raise RuntimeError("carbon feed diverged from engine energy total")

    # --- stage 2: telemetry + full-plane overhead on the warm engine -------
    rng = np.random.default_rng(7)
    prompts = [rng.integers(0, base.vocab_size, size=6).astype(np.int32)
               for _ in range(24)]

    def best_tps(e, reps=3):
        best = 0.0
        gc.collect()
        gc.disable()
        try:
            for _ in range(reps):
                best = max(best, e._serve_prompts(prompts, n_new=32)
                           ["tokens_per_s"])
        finally:
            gc.enable()
        return best

    def plane_scrape(e):
        """One full scrape against the session registry — OpenMetrics
        export + parse validation + fleet-rollup merge — returning its
        wall seconds, charged against the session it scraped."""
        t0 = time.perf_counter()
        text = to_openmetrics(e.last_registry)
        parse_openmetrics(text)
        roll = FleetRollup()
        roll.add(e.last_registry, region="bench")
        roll.merged()
        return time.perf_counter() - t0

    # The three modes are INTERLEAVED rep by rep: sessions here are short
    # (~0.1 s), and machine drift between unpaired best-of runs taken
    # minutes apart swamps the ~1 ms scrape cost.  Back-to-back sessions
    # see the same machine state, so best-of per mode compares cleanly.
    # GC stays off inside the loop — collector pauses are the dominant
    # session-to-session jitter at this wall length.  One re-measure on a
    # gate miss rejects one-off machine hiccups without loosening the gate.
    def measure_modes(reps=5):
        best = {"off": 0.0, "on": 0.0, "plane": 0.0}
        gc.disable()
        try:
            for _ in range(reps):
                # collect before EVERY session (outside the timed wall):
                # with gc off, garbage accumulates, and without the
                # per-session collect the later modes in each rep would
                # systematically run on a fatter heap than the first.
                gc.collect()
                eng.telemetry = None
                best["off"] = max(
                    best["off"],
                    eng._serve_prompts(prompts, n_new=32)["tokens_per_s"])
                gc.collect()
                eng.telemetry = tel_real           # phase profiling live
                best["on"] = max(
                    best["on"],
                    eng._serve_prompts(prompts, n_new=32)["tokens_per_s"])
                gc.collect()
                m = eng._serve_prompts(prompts, n_new=32)
                plane_s = plane_scrape(eng)
                best["plane"] = max(
                    best["plane"], m["tokens"] / (m["wall_s"] + plane_s))
        finally:
            gc.enable()
        return best

    eng._serve_prompts(prompts, n_new=32)          # warm all shapes
    for attempt in range(2):
        best_mode = measure_modes()
        tps_paged = best_mode["off"]               # doubles as the gate run
        tps_on = best_mode["on"]
        tps_plane = best_mode["plane"]
        overhead_pct = (1.0 - tps_on / tps_paged) * 100.0
        if (overhead_pct <= OVERHEAD_GATE_PCT
                and (1.0 - tps_plane / tps_paged) * 100.0
                <= OVERHEAD_GATE_PCT):
            break
    if overhead_pct > OVERHEAD_GATE_PCT:
        raise RuntimeError(f"telemetry overhead {overhead_pct:.1f}% exceeds "
                           f"{OVERHEAD_GATE_PCT}% gate "
                           f"({tps_on:.0f} vs {tps_paged:.0f} tokens/s)")

    # --- stage 3: equal-batch paged vs slotted regression gate -------------
    slot = ENG.RealEngine(family, n_slots=4, max_len=48, ci_g_per_kwh=ci)
    slot.configure(g)
    slot._serve_prompts(prompts, n_new=32)         # warm
    tps_slot = best_tps(slot)
    ratio = tps_paged / max(tps_slot, 1e-9)
    if ratio < PAGED_GATE_FRAC:
        raise RuntimeError(
            f"paged layout regressed: {tps_paged:.0f} tokens/s is "
            f"{ratio:.3f}× slotted ({tps_slot:.0f}) at equal batch — "
            f"gate {PAGED_GATE_FRAC}")

    # --- stage 4a: exporter family parity across backends + fleet ----------
    regs = {"des": des.registry, "fluid": fluid.registry,
            "real-paged": eng.last_registry}
    rollup = FleetRollup()
    for rname, reg in regs.items():
        rollup.add(reg, region=rname)
    family_sets = {}
    for rname, reg in {**regs, "fleet": rollup}.items():
        text = to_openmetrics(reg)
        fams = parse_openmetrics(text)
        if render_families(fams) != text:
            raise RuntimeError(f"{rname}: OpenMetrics round-trip diverged")
        family_sets[rname] = frozenset(fams)
    if len(set(family_sets.values())) != 1:
        raise RuntimeError(
            f"exporter family sets diverged across backends/fleet: "
            f"{ {a: sorted(family_sets[a] ^ family_sets['fleet']) for a in family_sets} }")
    n_families = len(family_sets["fleet"])

    # --- stage 4b: TOTAL plane overhead gate (measured in the stage-2
    # interleaved loop: telemetry attached + one full scrape per session) --
    plane_overhead_pct = (1.0 - tps_plane / tps_paged) * 100.0
    if plane_overhead_pct > OVERHEAD_GATE_PCT:
        raise RuntimeError(
            f"observability plane overhead {plane_overhead_pct:.1f}% "
            f"exceeds {OVERHEAD_GATE_PCT}% gate "
            f"({tps_plane:.0f} vs {tps_paged:.0f} tokens/s)")
    phase_samples = sum(
        m.count for _, _, m in eng.last_registry.labeled_series(
            "phase_latency_s"))
    if phase_samples <= 0:
        raise RuntimeError("phase profiler recorded no samples with "
                           "telemetry attached")

    rows = [("stage", "metric", "value"),
            ("shared", "backends_conserving", 3),
            ("shared", "metric_names", len(CATALOG)),
            ("shared", "chrome_events", n_events),
            ("shared", "des_energy_j", round(m_des["energy_j"], 3)),
            ("shared", "fluid_energy_j", round(m_fluid["energy_j"], 3)),
            ("shared", "engine_energy_j", round(m_eng["energy_j"], 3)),
            ("overhead", "tokens_per_s_telemetry_off", round(tps_paged, 1)),
            ("overhead", "tokens_per_s_telemetry_on", round(tps_on, 1)),
            ("overhead", "overhead_pct", round(overhead_pct, 2)),
            ("layout_gate", "paged_tokens_per_s", round(tps_paged, 1)),
            ("layout_gate", "slotted_tokens_per_s", round(tps_slot, 1)),
            ("layout_gate", "paged_vs_slotted_ratio", round(ratio, 3)),
            ("layout_gate", "gate_frac", PAGED_GATE_FRAC),
            ("fleet_plane", "openmetrics_families", n_families),
            ("fleet_plane", "exporter_family_parity", 1),
            ("fleet_plane", "tokens_per_s_full_plane", round(tps_plane, 1)),
            ("fleet_plane", "plane_overhead_pct",
             round(plane_overhead_pct, 2)),
            ("fleet_plane", "phase_samples", int(phase_samples))]
    derived = {
        "metric_names_match": 1,
        "conservation_backends": 3,
        "chrome_events": int(n_events),
        "telemetry_overhead_pct": round(overhead_pct, 2),
        "overhead_gate_pct": OVERHEAD_GATE_PCT,
        "paged_tokens_per_s": round(tps_paged, 1),
        "slotted_tokens_per_s": round(tps_slot, 1),
        "paged_vs_slotted_ratio": round(ratio, 3),
        "paged_gate_frac": PAGED_GATE_FRAC,
        "openmetrics_families": int(n_families),
        "exporter_family_parity": 1,
        "plane_overhead_pct": round(plane_overhead_pct, 2),
        "phase_samples": int(phase_samples),
    }
    return derived, rows


def _decode_hotpath_bench():
    """Device-resident decode hot-path breakdown (pipelined paged loop).

    Three engines serve the SAME equal-batch closed-loop workload (4 rows
    × 32 new tokens, fully reserved tables, preemption off): the slotted
    baseline, the pipelined paged engine (device-resident loop state,
    fused multi-step dispatches, async token readback), and the
    synchronous paged reference (``decode_pipeline=False``) — the
    pre-pipelining loop kept as the greedy-parity oracle.  Emits the
    per-tick dispatch breakdown (landed steps per jitted dispatch, H2D
    uploads and blocking host round-trips per step) plus the tokens/s of
    all three loops; ``--json`` lands it in BENCH_engine.json.

    Deterministic gates (counter-based, immune to timing noise): the run
    FAILS unless (a) pipelined greedy outputs are token-identical to the
    synchronous reference, (b) fused dispatch engaged (strictly fewer
    dispatches than landed steps), and (c) pipelined steady-state decode
    kept uploads event-bound — zero per-tick H2D traffic, i.e. far under
    the reference loop's fixed 4-upload-per-step rate.
    """
    import numpy as np

    import jax.numpy as jnp

    from repro.configs import get_smoke_config
    from repro.core import config_graph as CG
    from repro.serving import engine as ENG

    base = get_smoke_config("qwen3-1.7b").with_(n_layers=2,
                                                dtype=jnp.float32)
    family = ENG.build_engine_family(base, fracs=(1.0,))
    g = CG.ConfigGraph.from_dict(base.name, {("x1", 16): 1})
    rng = np.random.default_rng(9)
    prompts = [rng.integers(0, base.vocab_size, size=6).astype(np.int32)
               for _ in range(4)]
    n_new = 32

    def build(**kw):
        e = ENG.RealEngine(family, n_slots=4, max_len=48, block_size=8,
                           max_seqs=4, n_blocks=28, **kw)
        e.configure(g)
        e._serve_prompts(prompts, n_new=n_new)       # warm every shape
        return e

    def best(e, reps=3):
        m_best = None
        for _ in range(reps):
            m = e._serve_prompts(prompts, n_new=n_new)
            if m_best is None or m["tokens_per_s"] > m_best["tokens_per_s"]:
                m_best = m
        return m_best

    pipe = build(kv_layout="paged")
    m_pipe = best(pipe)
    out_pipe = {r: t.copy() for r, t in pipe.last_outputs.items()}
    sync = build(kv_layout="paged", decode_pipeline=False)
    m_sync = best(sync)
    parity = int(len(out_pipe) == len(sync.last_outputs) and all(
        np.array_equal(out_pipe[r], sync.last_outputs[r]) for r in out_pipe))
    slot = build()
    m_slot = best(slot)

    steps = max(int(m_pipe["decode_steps"]), 1)
    steps_sync = max(int(m_sync["decode_steps"]), 1)
    spd = round(steps / max(m_pipe["decode_dispatches"], 1), 2)
    h2d_pipe = round(m_pipe["h2d_transfers"] / steps, 3)
    h2d_sync = round(m_sync["h2d_transfers"] / steps_sync, 3)
    syncs_pipe = round(m_pipe["host_syncs"] / steps, 3)
    syncs_sync = round(m_sync["host_syncs"] / steps_sync, 3)
    if not parity:
        raise RuntimeError("pipelined decode diverged from the synchronous "
                           "reference loop (greedy parity broken)")
    if m_pipe["decode_dispatches"] >= m_pipe["decode_steps"]:
        raise RuntimeError(
            f"fused dispatch never engaged: {m_pipe['decode_dispatches']} "
            f"dispatches for {m_pipe['decode_steps']} steps")
    if h2d_pipe >= 1.0:
        raise RuntimeError(
            f"steady-state decode is re-uploading loop state: "
            f"{h2d_pipe} H2D transfers/step (reference loop: {h2d_sync})")
    rows = [("stage", "metric", "value"),
            ("dispatch", "decode_steps", int(m_pipe["decode_steps"])),
            ("dispatch", "decode_dispatches",
             int(m_pipe["decode_dispatches"])),
            ("dispatch", "steps_per_dispatch", spd),
            ("traffic", "h2d_per_step_pipelined", h2d_pipe),
            ("traffic", "h2d_per_step_sync", h2d_sync),
            ("traffic", "host_syncs_per_step_pipelined", syncs_pipe),
            ("traffic", "host_syncs_per_step_sync", syncs_sync),
            ("throughput", "tokens_per_s_pipelined",
             round(m_pipe["tokens_per_s"], 1)),
            ("throughput", "tokens_per_s_sync_reference",
             round(m_sync["tokens_per_s"], 1)),
            ("throughput", "tokens_per_s_slotted",
             round(m_slot["tokens_per_s"], 1)),
            ("throughput", "greedy_parity_vs_reference", parity)]
    derived = {
        "steps_per_dispatch": spd,
        "h2d_per_step_pipelined": h2d_pipe,
        "h2d_per_step_sync": h2d_sync,
        "host_syncs_per_step_pipelined": syncs_pipe,
        "host_syncs_per_step_sync": syncs_sync,
        "tokens_per_s_pipelined": round(m_pipe["tokens_per_s"], 1),
        "tokens_per_s_sync_reference": round(m_sync["tokens_per_s"], 1),
        "tokens_per_s_slotted": round(m_slot["tokens_per_s"], 1),
        "pipelined_vs_sync_speedup": round(
            m_pipe["tokens_per_s"] / max(m_sync["tokens_per_s"], 1e-9), 3),
        "pipelined_vs_slotted_ratio": round(
            m_pipe["tokens_per_s"] / max(m_slot["tokens_per_s"], 1e-9), 3),
        "greedy_parity_vs_reference": parity,
    }
    return derived, rows


def _mixed_quality_bench():
    """Carbon/accuracy Pareto sweep of the mixed-quality request path
    (PR-9 quality selectors, ``serving.quality``).

    One diurnal-trace DES workload (deferrable batch entries + an
    interactive stream spread over 24 h, fifo policy so per-request
    quality is the ONLY lever) runs under four operating points:

      * ``off``      — no selector, an all-best pool (``B3 × 2``): today's
        deployment, the accuracy ceiling and the carbon worst case;
      * ``static``   — per-class pinning (deferrable → B1) on a mixed
        ``B1 + B3`` pool of the same total chips;
      * ``greedy``   — dirty-grid downshifter: deferrable ride B1 whenever
        the nowcast CI is above the trace mean, B3 when the grid is clean;
      * ``governed`` — the greedy downshifter behind the accuracy-floor
        governor (deferrable windowed mean ≥ 0.80, between B1's 0.791 and
        B3's 0.816 — the floor genuinely binds).

    Emits one (gCO2/request, mean served accuracy) Pareto point per mode.
    Deterministic gates: every mode meets every deadline at equal
    interactive attainment, the governed point beats ``off`` on
    gCO2/request, its per-class windowed accuracy holds the floor, and at
    least one governed decision actually downshifted (the scenario keeps
    its teeth).  ``--json`` lands the sweep in BENCH_engine.json, where
    the trajectory guard watches the governed point.
    """
    from repro.core import carbon as CB
    from repro.core import catalog as CAT
    from repro.core import config_graph as CG
    from repro.serving import queue as Q
    from repro.serving.api import DEFERRABLE, INTERACTIVE, InferenceRequest, \
        serve_workload
    from repro.serving.quality import make_selector

    trace = CB.make_trace("CISO-March", hours=72, seed=3)
    t0 = 24 * 3600.0                   # skip the trace's warm-up day
    span = 24 * 3600.0
    dirty = trace.mean()               # the downshifters' threshold
    variants = CAT.get_family("efficientnet")
    n_defer, n_inter = 48, 24
    defer_tokens = 80_000              # ~60 s of B3 busy drain per entry

    def reqs():
        gap_d, gap_i = span / n_defer, span / n_inter
        out = [InferenceRequest(rid=i, prompt=[1],
                                max_new_tokens=defer_tokens,
                                arrival_s=t0 + gap_d * i, slo=DEFERRABLE,
                                deadline_s=t0 + gap_d * i + 4 * 3600.0)
               for i in range(n_defer)]
        out += [InferenceRequest(rid=n_defer + i, prompt=[1],
                                 max_new_tokens=8,
                                 arrival_s=t0 + gap_i * i, slo=INTERACTIVE)
                for i in range(n_inter)]
        return out

    floor = 0.80
    pool_off = CG.ConfigGraph.from_dict("efficientnet", {("B3", 1): 2})
    pool_mix = CG.ConfigGraph.from_dict("efficientnet",
                                        {("B1", 1): 1, ("B3", 1): 1})
    modes = {
        "off": (pool_off, None),
        "static": (pool_mix, make_selector(
            "static", pins={DEFERRABLE: "B1"})),
        "greedy": (pool_mix, make_selector(
            "greedy", ci_fn=trace.at, dirty_threshold_g=dirty)),
        "governed": (pool_mix, make_selector(
            "governed", ci_fn=trace.at, dirty_threshold_g=dirty,
            floors={DEFERRABLE: floor})),
    }
    inter_target_s = 180.0             # generous: attainment must be equal,
                                       # not tight — quality is the lever
    rows = [("mode", "carbon_g_per_req", "mean_accuracy",
             "deferrable_accuracy", "interactive_accuracy",
             "interactive_attainment", "deadline_misses")]
    point = {}
    for mode, (g, sel) in modes.items():
        des = Q.DESBackend(g, variants, Q.DESConfig(jitter_sigma=0.0),
                           policy="fifo", ci_g_per_kwh=trace.at,
                           quality_selector=sel)
        responses = serve_workload(des, reqs())
        m = des.stats()
        by = {}
        for r in responses:
            by.setdefault(r.slo, []).append(r.accuracy)
        acc = {slo: sum(a) / len(a) for slo, a in by.items()}
        inter = [r.latency_s for r in responses if r.slo == INTERACTIVE]
        attain = sum(1 for l in inter if l <= inter_target_s) / len(inter)
        point[mode] = {
            "carbon_g_per_req": m["carbon_g_per_req"],
            "mean_accuracy": m["mean_accuracy"],
            "deferrable_accuracy": acc[DEFERRABLE],
            "interactive_accuracy": acc[INTERACTIVE],
            "interactive_attainment": attain,
            "deadline_misses": int(m["deadline_misses"]),
            "downshifts": (sum(1 for _, _, why in sel.decision_sequence()
                               if why in ("downshift", "pressure"))
                           if sel is not None else 0),
        }
        rows.append((mode, round(m["carbon_g_per_req"], 4),
                     round(m["mean_accuracy"], 4),
                     round(acc[DEFERRABLE], 4), round(acc[INTERACTIVE], 4),
                     round(attain, 4), int(m["deadline_misses"])))
    gov, off = point["governed"], point["off"]
    # the gates that keep the sweep honest
    misses = {m: p["deadline_misses"] for m, p in point.items()}
    if any(misses.values()):
        raise RuntimeError(
            f"deadline misses under the mixed-quality sweep: {misses}")
    if any(p["interactive_attainment"] < off["interactive_attainment"]
           for p in point.values()):
        raise RuntimeError("a selector mode lost interactive attainment vs "
                           "the no-selector baseline")
    if gov["carbon_g_per_req"] >= off["carbon_g_per_req"]:
        raise RuntimeError(
            f"governed selector failed to cut gCO2/request: "
            f"{gov['carbon_g_per_req']:.4f} vs off "
            f"{off['carbon_g_per_req']:.4f}")
    if gov["deferrable_accuracy"] < floor \
            or gov["interactive_accuracy"] < floor:
        raise RuntimeError(f"governed accuracy broke the {floor} floor: "
                           f"{gov}")
    if gov["downshifts"] < 1:
        raise RuntimeError("governed scenario degenerated: no downshift "
                           "ever happened (the grid never looked dirty)")
    derived = {f"{mode}_{k}": round(v, 4)
               for mode, p in point.items() for k, v in p.items()}
    derived.update({
        "pareto_points": len(point),
        "accuracy_floor": floor,
        "governed_vs_off_saving_pct": round(
            (1.0 - gov["carbon_g_per_req"] / off["carbon_g_per_req"]) * 100,
            2),
    })
    return derived, rows


def _disagg_bench():
    """Multi-device sharded serving with prefill/decode disaggregation
    (serving.disagg on a ("data","model") mesh, PR 10).

    The measurement needs 8 host devices, so the body runs in a subprocess
    (``benchmarks/disagg_serving.py`` sets XLA_FLAGS before jax imports —
    this harness stays at 1 device) and prints its numbers as one JSON
    line.  The subprocess enforces the hard gates itself (token parity of
    disagg vs monolithic on the sharded mesh, exact per-role joules/carbon
    conservation, prefill-pool throughput ≥ the monolithic engine's at
    equal chips); a gate failure is a nonzero exit surfaced here."""
    import subprocess

    script = os.path.join(os.path.dirname(__file__), "disagg_serving.py")
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.abspath(
        os.path.join(os.path.dirname(__file__), "..", "src")) \
        + os.pathsep + env.get("PYTHONPATH", "")
    out = subprocess.run([sys.executable, script], capture_output=True,
                         text=True, timeout=1800, env=env)
    if out.returncode != 0:
        raise RuntimeError(f"disagg bench failed:\n{out.stdout[-2000:]}\n"
                           f"{out.stderr[-2000:]}")
    derived = json.loads(out.stdout.strip().splitlines()[-1])
    rows = [("metric", "value")] + sorted(derived.items())
    return derived, rows


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None)
    ap.add_argument("--fast", action="store_true",
                    help="6 h traces instead of 48 h")
    ap.add_argument("--json", action="store_true",
                    help="also merge each benchmark's derived dict into the "
                         "root-level BENCH_engine.json (via _bench_json), "
                         "keyed by benchmark name — the cross-PR perf "
                         "trajectory file")
    ap.add_argument("--fail-on-regress", action="store_true",
                    help="turn >10%% bench-trajectory regressions vs the "
                         "previous BENCH_engine.json (tokens/s, paged/"
                         "slotted ratio, overheads) from warnings into "
                         "failures")
    args = ap.parse_args(argv)

    os.makedirs(OUT_DIR, exist_ok=True)
    try:                                           # python -m benchmarks.run
        from benchmarks import _bench_json as BJ
    except ImportError:                            # python benchmarks/run.py
        import _bench_json as BJ
    print("name,us_per_call,derived")
    failures = 0
    for name, fn in _benchmarks(args.fast):
        if args.only and args.only not in name:
            continue
        t0 = time.perf_counter()
        try:
            derived, rows = fn()
        except Exception as e:  # pragma: no cover
            failures += 1
            print(f"{name},ERROR,{e!r}", flush=True)
            continue
        us = (time.perf_counter() - t0) * 1e6
        with open(os.path.join(OUT_DIR, f"{name}.csv"), "w", newline="") as f:
            csv.writer(f).writerows(rows)
        # trajectory guard BEFORE the snapshot overwrites the previous run:
        # warn (or fail) on >10% regressions of the guarded keys, and append
        # this run's numbers to the history JSONL either way
        regressions = BJ.check_trajectory(name, derived)
        for msg in regressions:
            print(f"{name},REGRESSION,\"{msg}\"", flush=True)
        if regressions and args.fail_on_regress:
            failures += 1
        BJ.append_history(name, {**derived, "us_per_call": round(us)})
        if args.json:
            BJ.update_bench_json(name, {**derived, "us_per_call": round(us)})
        print(f"{name},{us:.0f},\"{json.dumps(derived)}\"", flush=True)
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())
