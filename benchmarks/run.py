"""Benchmark harness — one function per paper table/figure + roofline tables.

Prints the ``name,us_per_call,derived`` CSV contract (one line per benchmark)
and writes the full per-figure CSVs to benchmarks/out/.

Usage:  PYTHONPATH=src python -m benchmarks.run [--only fig09] [--fast]
"""
from __future__ import annotations

import argparse
import csv
import json
import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

OUT_DIR = os.path.join(os.path.dirname(__file__), "out")


def _benchmarks(fast: bool):
    from benchmarks import paper_figs as F
    if fast:
        F.TRACE_HOURS = 6.0
    items = [
        ("fig02_mixed_quality", F.fig02_mixed_quality),
        ("fig03_partitioning", F.fig03_partitioning),
        ("fig08_traces", F.fig08_traces),
        ("fig09_effectiveness", F.fig09_effectiveness),
        ("fig10_schemes", F.fig10_schemes),
        ("fig11_objective_timeline", F.fig11_objective_timeline),
        ("fig12_overhead", F.fig12_overhead),
        ("fig13_trajectory", F.fig13_trajectory),
        ("fig14_lambda", F.fig14_lambda),
        ("fig15_consolidation", F.fig15_consolidation),
        ("fig16_geo", F.fig16_geo),
        ("table_chatgpt", F.table_chatgpt_estimate),
        ("table_lm_serving", F.table_lm_serving),
        ("roofline_baseline", _roofline_bench),
    ]
    return items


def _roofline_bench():
    """Roofline terms for every compiled dry-run cell (single-pod mesh)."""
    from repro.launch import roofline as RL
    path = os.path.join(os.path.dirname(__file__), "..", "dryrun_results.json")
    if not os.path.exists(path):
        return {"skipped": "run repro.launch.dryrun first"}, [("missing",)]
    rows = RL.analyze_file(path, mesh="16x16")
    csv_rows = [("arch", "shape", "t_compute_s", "t_memory_s", "t_coll_s",
                 "dominant", "useful_ratio", "roofline_frac", "mem_gib")]
    for r in rows:
        csv_rows.append((r["arch"], r["shape"], r["t_compute_s"],
                         r["t_memory_s"], r["t_collective_s"], r["dominant"],
                         round(r["useful_flops_ratio"], 3),
                         round(r["roofline_fraction"], 4),
                         round(r["mem_footprint_gib"], 2)))
    dom = {}
    for r in rows:
        dom[r["dominant"]] = dom.get(r["dominant"], 0) + 1
    derived = {"cells": len(rows), "dominant_counts": dom,
               "median_roofline_frac": round(
                   sorted(x["roofline_fraction"] for x in rows)[len(rows) // 2], 4)}
    return derived, csv_rows


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None)
    ap.add_argument("--fast", action="store_true",
                    help="6 h traces instead of 48 h")
    ap.add_argument("--json", action="store_true",
                    help="also merge each benchmark's derived dict into the "
                         "root-level BENCH_engine.json (via _bench_json), "
                         "keyed by benchmark name — the cross-PR perf "
                         "trajectory file")
    args = ap.parse_args(argv)

    os.makedirs(OUT_DIR, exist_ok=True)
    print("name,us_per_call,derived")
    failures = 0
    for name, fn in _benchmarks(args.fast):
        if args.only and args.only not in name:
            continue
        t0 = time.perf_counter()
        try:
            derived, rows = fn()
        except Exception as e:  # pragma: no cover
            failures += 1
            print(f"{name},ERROR,{e!r}", flush=True)
            continue
        us = (time.perf_counter() - t0) * 1e6
        with open(os.path.join(OUT_DIR, f"{name}.csv"), "w", newline="") as f:
            csv.writer(f).writerows(rows)
        if args.json:
            try:                                   # python -m benchmarks.run
                from benchmarks._bench_json import update_bench_json
            except ImportError:                    # python benchmarks/run.py
                from _bench_json import update_bench_json
            update_bench_json(name, {**derived, "us_per_call": round(us)})
        print(f"{name},{us:.0f},\"{json.dumps(derived)}\"", flush=True)
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())
