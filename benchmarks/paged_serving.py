"""Paged vs slotted KV serving at EQUAL arena memory (PR-3 acceptance).

Workload: a mixed 16/128/512-token prompt set sharing a common system-prompt
prefix — exactly the shape that strands slotted memory (every slot reserves
``max_len`` tokens, so a 16-token prompt wastes ~97% of its slot) and that
paging + radix prefix sharing exploits.  Both engines serve the same request
set closed-loop through the SAME instance graph; greedy decoding makes the
outputs token-identical, so every comparison is at strictly equal quality.

Acceptance gates printed at the end (and persisted to BENCH_engine.json):

  * sustained admitted concurrency (mean sequences holding cache memory
    per tick) ≥ 1.5× the slotted engine's at equal arena bytes;
  * J/token no worse than slotted;
  * open-loop (Poisson) run at 0.7× the measured saturation rate reports
    finite queueing delay with p95 within the derived SLA;
  * PREEMPTION stage (PR 4): an overcommitted arena under mixed-priority
    Poisson arrivals (background long-decode jobs + interactive shorts) —
    interactive p95 TTFT with decode-time preemption enabled must be no
    worse than with the conservative whole-sequence reservation.

Usage:  PYTHONPATH=src python benchmarks/paged_serving.py
            [--layers 4] [--requests 18] [--new-tokens 24] [--slots 4]
            [--block-size 16] [--prompt-lens 16,128,512]
            [--no-preempt-stage]
"""
from __future__ import annotations

import argparse
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from _bench_json import update_bench_json  # noqa: E402


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-1.7b")
    ap.add_argument("--layers", type=int, default=4)
    ap.add_argument("--requests", type=int, default=18)
    ap.add_argument("--new-tokens", type=int, default=24,
                help="decode length per request: the decode-heavy regime "
                     "is where paging pays (short generations are "
                     "prefill-dispatch-bound on CPU)")
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--block-size", type=int, default=16)
    ap.add_argument("--chunk-blocks", type=int, default=8,
                    help="prefill chunk size in blocks (bigger chunks "
                         "amortize per-call dispatch on long prompts; "
                         "smaller chunks interleave with decode more finely)")
    ap.add_argument("--prompt-lens", default="16,128,512")
    ap.add_argument("--shared-prefix", type=int, default=64,
                    help="prompts >= this length share a prefix this long")
    ap.add_argument("--reps", type=int, default=3,
                    help="measured repetitions; best tokens/s wins (damps "
                         "CPU scheduling noise)")
    ap.add_argument("--open-loop-requests", type=int, default=0,
                    help="0 disables the open-loop stage (the slow test "
                         "runs it; closed-loop gates stand alone)")
    ap.add_argument("--no-preempt-stage", action="store_true",
                    help="skip the overcommit/preemption TTFT comparison")
    args = ap.parse_args()

    import jax.numpy as jnp
    import numpy as np

    from repro.configs import get_smoke_config
    from repro.core import config_graph as CG
    from repro.serving import engine as ENG
    from repro.serving.api import InferenceRequest, serve_workload

    prompt_lens = [int(x) for x in args.prompt_lens.split(",")]
    base = get_smoke_config(args.arch).with_(n_layers=args.layers,
                                             dtype=jnp.float32)
    family = ENG.build_engine_family(base, fracs=(1.0,))
    g = CG.ConfigGraph.from_dict(base.name, {("x1", 16): 1})
    max_len = max(prompt_lens) + args.new_tokens + args.block_size

    rng = np.random.default_rng(0)
    shared = rng.integers(0, base.vocab_size,
                          size=args.shared_prefix).astype(np.int32)
    prompts = []
    for i in range(args.requests):
        L = prompt_lens[i % len(prompt_lens)]
        p = rng.integers(0, base.vocab_size, size=L).astype(np.int32)
        if L >= args.shared_prefix:
            p[:args.shared_prefix] = shared
        prompts.append(p)

    def run_once(eng, reqs):
        serve_workload(eng, reqs)
        return eng.stats()

    def requests_for(prompts_, n_new):
        return [InferenceRequest(rid=i, prompt=p, max_new_tokens=n_new)
                for i, p in enumerate(prompts_)]

    def measure(kv_layout):
        kw = dict(n_slots=args.slots, max_len=max_len, kv_layout=kv_layout,
                  block_size=args.block_size, max_seqs=4 * args.slots,
                  chunk_blocks=args.chunk_blocks)
        warm = ENG.RealEngine(family, **kw)                # jit warmup pass
        warm.configure(g)
        run_once(warm, requests_for(prompts, args.new_tokens))
        # measure on FRESH engines: compiled fns live on the shared family,
        # but allocator/prefix state starts cold — each rep shows real
        # prefill plus sharing of the common prefix, not a second pass
        # serving last rep's fully-cached prompts.  Best tokens/s wins.
        best_eng, best = None, None
        for _ in range(args.reps):
            eng = ENG.RealEngine(family, **kw)
            eng.configure(g)
            m = run_once(eng, requests_for(prompts, args.new_tokens))
            if best is None or m["tokens_per_s"] > best["tokens_per_s"]:
                best_eng, best = eng, m
        return best_eng, best

    eng_s, m_s = measure("slotted")
    eng_p, m_p = measure("paged")

    # greedy parity: identical tokens at equal quality, or the comparison
    # is meaningless
    mismatch = sum(
        not np.array_equal(eng_s.last_outputs[r], eng_p.last_outputs[r])
        for r in eng_s.last_outputs)
    conc_ratio = m_p["mean_admitted"] / max(m_s["mean_admitted"], 1e-9)
    j_ratio = m_p["j_per_token"] / max(m_s["j_per_token"], 1e-12)
    arena_tokens = args.slots * max_len

    print(f"arena: {arena_tokens} KV tokens each "
          f"(slotted {args.slots}×{max_len}; paged "
          f"{eng_p.n_blocks - 1}×{args.block_size} blocks)")
    for name, m in (("slotted", m_s), ("paged", m_p)):
        print(f"  {name:8s} tokens/s={m['tokens_per_s']:8.1f}  "
              f"J/token={m['j_per_token']:8.4f}  "
              f"admitted={m['mean_admitted']:5.2f}  "
              f"ttft_p95={m['ttft_p95_s'] * 1e3:7.1f}ms  "
              f"blocks_peak={m['blocks_peak']}")
    print(f"  prefix-hit tokens: {m_p['prefix_hit_tokens']} "
          f"(chunked prefills: {m_p['prefill_chunks']})")

    ok_parity = mismatch == 0
    ok_conc = conc_ratio >= 1.5
    ok_energy = j_ratio <= 1.0 + 1e-6
    payload = {
        "tokens_per_s_paged": round(m_p["tokens_per_s"], 2),
        "tokens_per_s_slotted": round(m_s["tokens_per_s"], 2),
        "j_per_token_paged": round(m_p["j_per_token"], 5),
        "j_per_token_slotted": round(m_s["j_per_token"], 5),
        "ttft_p95_s_paged": round(m_p["ttft_p95_s"], 6),
        "ttft_p95_s_slotted": round(m_s["ttft_p95_s"], 6),
        "blocks_peak": m_p["blocks_peak"],
        "concurrency_ratio": round(conc_ratio, 3),
        "prefix_hit_tokens": int(m_p["prefix_hit_tokens"]),
        "token_parity": bool(ok_parity),
    }

    if args.open_loop_requests > 0:
        n_new = args.new_tokens
        sat_rps = m_p["tokens_per_s"] / n_new
        mo = eng_p.serve_poisson(rate_rps=0.7 * sat_rps,
                                 n_requests=args.open_loop_requests,
                                 prompt_lens=prompt_lens, n_new=n_new,
                                 seed=1)
        print(f"  open-loop @0.7×sat ({0.7 * sat_rps:.1f} rps): "
              f"p95={mo['p95_s'] * 1e3:.1f}ms "
              f"queue_delay_p95={mo['queue_delay_p95_s'] * 1e3:.1f}ms "
              f"ttft_p95={mo['ttft_p95_s'] * 1e3:.1f}ms")
        payload.update({
            "open_loop_rps": round(0.7 * sat_rps, 2),
            "open_loop_p95_s": round(mo["p95_s"], 6),
            "open_loop_queue_delay_p95_s": round(mo["queue_delay_p95_s"], 6),
            "open_loop_ttft_p95_s": round(mo["ttft_p95_s"], 6),
        })

    ok_preempt = True
    if not args.no_preempt_stage:
        # --- preemption stage: overcommitted arena, mixed-priority Poisson -
        # background jobs (priority 0, long decode) land first and would
        # monopolize the arena; interactive requests (priority 1, short)
        # arrive Poisson on top.  Same requests, same priority policy, same
        # (too small) arena — the only difference is decode-time preemption
        # vs the conservative whole-sequence reservation.
        bs = 8
        bg_new, int_new = 4 * bs, bs
        # 5 background jobs grow to 5 × 6 = 30 blocks against 24: decode
        # MUST preempt once the tables fill (the 5th never even admits under
        # whole-sequence reservation until a completion frees its 6 blocks)
        n_bg, n_int = 5, 10
        rng_p = np.random.default_rng(7)
        arrivals = np.cumsum(rng_p.exponential(0.05, n_int))
        # ONE workload, drawn once — both arms (and their warmups) serve
        # byte-identical prompts on the same arrival schedule
        master = []
        for i in range(n_bg):
            master.append(InferenceRequest(
                rid=i, prompt=rng_p.integers(0, base.vocab_size, size=2 * bs
                                             ).astype(np.int32),
                max_new_tokens=bg_new, priority=0, arrival_s=0.0))
        for i in range(n_int):
            master.append(InferenceRequest(
                rid=n_bg + i,
                prompt=rng_p.integers(0, base.vocab_size, size=bs
                                      ).astype(np.int32),
                max_new_tokens=int_new, priority=1,
                arrival_s=float(arrivals[i])))

        def preempt_requests():
            import dataclasses as dc
            return [dc.replace(r, prompt=r.prompt.copy()) for r in master]

        # arena sized so the 4 background whole-sequence reservations
        # (4 × 6 blocks) consume it EXACTLY: under the conservative scheme
        # every interactive arrival waits for a background completion, while
        # preemption admits them immediately and swaps background pages out
        # under decode pressure
        overcommit_kw = dict(
            n_slots=args.slots, max_len=6 * bs + bs, kv_layout="paged",
            block_size=bs, n_blocks=25, max_seqs=8, policy="priority",
            prefix_caching=False)
        ttft = {}
        pre_count = {}
        for preempt in (False, True):
            eng = ENG.RealEngine(family, preemption=preempt, **overcommit_kw)
            eng.configure(g)
            serve_workload(eng, preempt_requests())       # warm the shapes
            eng.configure(g)                              # fresh arena state
            resp = serve_workload(eng, preempt_requests())
            inter = [r for r in resp if r.priority == 1]
            from repro.serving.scheduler import latency_percentile
            ttft[preempt] = latency_percentile([r.ttft_s for r in inter],
                                               95.0)
            pre_count[preempt] = eng.stats()["preemptions"]
        ok_preempt = ttft[True] <= ttft[False] * 1.05 + 5e-3
        print(f"  preemption stage (overcommit, priority policy): "
              f"interactive ttft_p95 reserve={ttft[False] * 1e3:.1f}ms "
              f"preempt={ttft[True] * 1e3:.1f}ms "
              f"({pre_count[True]} preemptions)")
        payload.update({
            "preempt_ttft_p95_s": round(ttft[True], 6),
            "reserve_ttft_p95_s": round(ttft[False], 6),
            "preemptions": int(pre_count[True]),
        })

    jpath = update_bench_json("paged_serving", payload)
    print(f"updated {jpath}")

    us = m_p["wall_s"] / max(m_p["tokens"], 1) * 1e6
    print(f"paged_serving,{us:.1f},conc={conc_ratio:.2f}x "
          f"j_ratio={j_ratio:.2f} parity={'OK' if ok_parity else 'FAIL'}")
    if not (ok_parity and ok_conc and ok_energy and ok_preempt):
        print(f"ACCEPTANCE FAIL: parity={ok_parity} "
              f"concurrency {conc_ratio:.2f}x (need >=1.5) "
              f"j_ratio {j_ratio:.2f} (need <=1.0) "
              f"preempt_ttft_ok={ok_preempt}")
        return 1
    print(f"ACCEPTANCE OK: {conc_ratio:.2f}x concurrency, "
          f"{(1 - j_ratio) * 100:.0f}% lower J/token, token parity exact"
          + ("" if args.no_preempt_stage
             else ", preemption ttft no worse under overcommit"))
    return 0


if __name__ == "__main__":
    sys.exit(main())
