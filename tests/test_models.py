"""Per-architecture smoke tests: every assigned arch (reduced config) runs a
forward/train step and a decode step on CPU with shape + finiteness asserts,
and the KV-cache decode path agrees with the full forward."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, get_config, get_smoke_config
from repro.models import registry as R
from repro.models import transformer as T
from repro.train import train_loop as TL

KEY = jax.random.PRNGKey(0)
B, S = 2, 16


def _batch(cfg):
    toks = jax.random.randint(KEY, (B, S), 0, cfg.vocab_size)
    batch = {"tokens": toks, "labels": jnp.roll(toks, -1, axis=1)}
    if cfg.mrope_sections:
        batch["mrope_positions"] = T.default_mrope_positions(B, S)
    if cfg.n_enc_layers:
        batch["src_embeds"] = jax.random.normal(KEY, (B, 8, cfg.d_model))
    return batch


@pytest.mark.parametrize("arch", ARCHS)
def test_smoke_forward_and_decode(arch):
    cfg = get_smoke_config(arch)
    params = R.init_params(KEY, cfg)
    batch = _batch(cfg)
    logits, aux = R.forward(params, batch, cfg, train=True)
    assert logits.shape == (B, S, cfg.padded_vocab)
    assert jnp.isfinite(logits.astype(jnp.float32)).all(), f"{arch}: non-finite fwd"
    assert jnp.isfinite(aux)

    cache = R.make_cache(params, cfg, B, S + 4, dtype=jnp.float32,
                         src_embeds=batch.get("src_embeds"))
    db = {"tokens": batch["tokens"][:, :1]}
    if cfg.mrope_sections:
        db["mrope_positions"] = batch["mrope_positions"][:, :, :1]
    lg, cache2 = R.decode_step(params, cache, db, cfg)
    assert lg.shape == (B, cfg.padded_vocab)
    assert jnp.isfinite(lg.astype(jnp.float32)).all(), f"{arch}: non-finite decode"
    assert int(cache2["pos"]) == 1


@pytest.mark.parametrize("arch", ARCHS)
def test_smoke_train_step_reduces_shape(arch):
    cfg = get_smoke_config(arch)
    params = R.init_params(KEY, cfg)
    batch = _batch(cfg)
    loss, metrics = TL.lm_loss(params, batch, cfg)
    assert jnp.isfinite(loss)
    assert loss > 0


@pytest.mark.parametrize("arch", ARCHS)
def test_decode_matches_forward(arch):
    cfg = get_smoke_config(arch)
    if cfg.is_moe:
        # exactness needs drop-free routing in BOTH paths (decode is always
        # drop-free; the full forward needs headroom)
        cfg = cfg.with_(capacity_factor=8.0)
    params = R.init_params(KEY, cfg)
    batch = _batch(cfg)
    logits_full, _ = R.forward(params, batch, cfg)
    cache = R.make_cache(params, cfg, B, S + 4, dtype=jnp.float32,
                         src_embeds=batch.get("src_embeds"))
    outs = []
    for t in range(S):
        db = {"tokens": batch["tokens"][:, t:t + 1]}
        if cfg.mrope_sections:
            db["mrope_positions"] = batch["mrope_positions"][:, :, t:t + 1]
        lg, cache = R.decode_step(params, cache, db, cfg)
        outs.append(lg)
    dec = jnp.stack(outs, axis=1)
    np.testing.assert_allclose(np.asarray(logits_full, np.float32),
                               np.asarray(dec, np.float32), rtol=5e-3, atol=5e-3)


# --- full-config structural checks (no allocation) ---------------------------
PUBLISHED_PARAMS_B = {
    "qwen3-moe-30b-a3b": 30.5, "qwen2-moe-a2.7b": 14.3, "qwen3-1.7b": 1.7,
    "glm4-9b": 9.4, "gemma3-27b": 27.0, "qwen2-0.5b": 0.49,
    "zamba2-2.7b": 2.7, "qwen2-vl-7b": 7.6, "mamba2-2.7b": 2.7,
    "seamless-m4t-large-v2": 1.6,
}


@pytest.mark.parametrize("arch", ARCHS)
def test_full_config_param_count_matches_published(arch):
    cfg = get_config(arch)
    got = cfg.param_count() / 1e9
    expect = PUBLISHED_PARAMS_B[arch]
    assert abs(got - expect) / expect < 0.15, (arch, got, expect)


@pytest.mark.parametrize("arch", ARCHS)
def test_abstract_params_no_allocation(arch):
    from repro.launch import steps
    cfg = get_config(arch)
    sds = steps.abstract_params(cfg)
    n = sum(int(np.prod(l.shape)) for l in jax.tree.leaves(sds))
    assert n > 0.8 * cfg.param_count()   # padded vocab can exceed slightly


def test_vlm_patch_merge():
    emb = jnp.zeros((1, 6, 4))
    patches = jnp.ones((1, 2, 4)) * jnp.array([[[1.0], [2.0]]])
    mask = jnp.array([[False, True, False, True, False, False]])
    out = T.merge_patch_embeds(emb, patches, mask)
    assert float(out[0, 1, 0]) == 1.0 and float(out[0, 3, 0]) == 2.0
    assert float(out[0, 0, 0]) == 0.0


@pytest.mark.parametrize("arch", ["qwen3-1.7b", "gemma3-27b", "glm4-9b"])
def test_split_cache_decode_matches_regular(arch):
    """Append-buffer decode (§Perf, cfg.decode_window) == classic DUS cache."""
    cfg = get_smoke_config(arch)
    params = R.init_params(KEY, cfg)
    toks = jax.random.randint(KEY, (B, S), 0, cfg.vocab_size)

    cache_a = R.make_cache(params, cfg, B, S + 4, dtype=jnp.float32)
    cfg_b = cfg.with_(decode_window=S + 4)
    cache_b = R.make_cache(params, cfg_b, B, S + 4, dtype=jnp.float32)
    for t in range(S):
        db = {"tokens": toks[:, t:t + 1]}
        la, cache_a = R.decode_step(params, cache_a, db, cfg)
        lb, cache_b = R.decode_step(params, cache_b, db, cfg_b)
        np.testing.assert_allclose(np.asarray(la, np.float32),
                                   np.asarray(lb, np.float32),
                                   rtol=2e-4, atol=2e-4)


def test_optimized_configs_still_run():
    """Every §Perf optimized override keeps the smoke model numerically OK."""
    from repro.configs import OPTIMIZED_OVERRIDES
    for arch, ov in OPTIMIZED_OVERRIDES.items():
        ov = {k: v for k, v in ov.items() if k != "seq_parallel"}  # needs mesh
        cfg = get_smoke_config(arch).with_(**ov)
        params = R.init_params(KEY, cfg)
        batch = _batch(cfg)
        loss, _ = TL.lm_loss(params, batch, cfg)
        assert jnp.isfinite(loss), arch
