"""Multi-device integration tests.  Each scenario runs in a subprocess so the
forced host-device count never leaks into this process (see conftest note)."""
import os
import subprocess
import sys

import pytest

_HERE = os.path.dirname(__file__)
_SRC = os.path.abspath(os.path.join(_HERE, "..", "src"))


def _run(name: str, timeout: int = 600):
    env = dict(os.environ)
    env["PYTHONPATH"] = _SRC + os.pathsep + env.get("PYTHONPATH", "")
    out = subprocess.run(
        [sys.executable, os.path.join(_HERE, "multidev_scenarios.py"), name],
        capture_output=True, text=True, timeout=timeout, env=env)
    assert out.returncode == 0, f"{name} failed:\n{out.stdout}\n{out.stderr}"
    assert "SCENARIO OK" in out.stdout


def test_lower_all_smoke_shapes():
    _run("lower_all_smoke_shapes")


def test_ddp_compressed_training():
    _run("ddp_compressed_training")


def test_elastic_checkpoint_restore():
    _run("elastic_checkpoint_restore")


def test_gspmd_vs_single_device_numerics():
    _run("gspmd_vs_single_device_numerics")


def test_seq_sharded_decode_numerics():
    _run("seq_sharded_decode_numerics")


def test_sharded_paged_decode_parity():
    _run("sharded_paged_decode_parity")


def test_disagg_vs_monolithic_parity():
    _run("disagg_vs_monolithic_parity")


def test_disagg_smoke():
    _run("disagg_smoke")
