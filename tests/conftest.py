# NOTE: XLA_FLAGS / host-device-count is deliberately NOT set here — smoke
# tests and benchmarks must see the real single CPU device.  Tests that need
# a multi-device mesh launch a subprocess with the flag set before jax import
# (see tests/multidev/_runner.py).
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import pytest  # noqa: E402


@pytest.fixture(scope="session")
def rng_key():
    import jax
    return jax.random.PRNGKey(0)
