"""Pallas kernel validation: shape/dtype sweeps, allclose vs pure-jnp oracles
(interpret mode executes the kernel body on CPU)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops
from repro.kernels import ref as REF
from repro.models import ssm as SSM

KEY = jax.random.PRNGKey(11)


def _tols(dtype):
    return (2e-2, 2e-2) if dtype == jnp.bfloat16 else (3e-5, 3e-5)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("b,sq,skv,H,K,dh,causal,window", [
    (2, 256, 256, 4, 2, 64, True, 0),
    (1, 128, 256, 8, 8, 64, False, 0),
    (2, 128, 128, 4, 1, 128, True, 64),
    (1, 512, 512, 2, 2, 64, True, 128),
])
def test_flash_attention_sweep(b, sq, skv, H, K, dh, causal, window, dtype):
    q = jax.random.normal(KEY, (b, sq, H, dh), dtype)
    k = jax.random.normal(jax.random.fold_in(KEY, 1), (b, skv, K, dh), dtype)
    v = jax.random.normal(jax.random.fold_in(KEY, 2), (b, skv, K, dh), dtype)
    out = ops.flash_attention(q, k, v, causal=causal, window=window)
    ref = REF.flash_attention_ref(q, k, v, causal=causal, window=window)
    rtol, atol = _tols(dtype)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32), rtol=rtol, atol=atol)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("b,S,H,K,dh,length", [
    (2, 512, 8, 2, 64, 300),
    (1, 256, 4, 4, 128, 256),
    (3, 512, 6, 1, 64, 17),
    (1, 1024, 2, 2, 64, 1000),
])
def test_decode_attention_sweep(b, S, H, K, dh, length, dtype):
    q = jax.random.normal(KEY, (b, H, dh), dtype)
    kc = jax.random.normal(jax.random.fold_in(KEY, 1), (b, S, K, dh), dtype)
    vc = jax.random.normal(jax.random.fold_in(KEY, 2), (b, S, K, dh), dtype)
    out = ops.decode_attention(q, kc, vc, length)
    ref = REF.decode_attention_ref(q, kc, vc, length)
    rtol, atol = _tols(dtype)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32), rtol=rtol, atol=atol)


@pytest.mark.parametrize("b,s,H,P,G,N,chunk", [
    (2, 64, 4, 8, 2, 16, 16),
    (1, 128, 2, 64, 1, 64, 32),
    (2, 96, 3, 16, 3, 8, 24),
    (1, 256, 2, 32, 1, 128, 128),
])
def test_ssd_kernel_sweep(b, s, H, P, G, N, chunk):
    x = jax.random.normal(KEY, (b, s, H, P))
    dt = jax.nn.softplus(jax.random.normal(jax.random.fold_in(KEY, 4), (b, s, H)))
    A = -jnp.exp(jax.random.normal(jax.random.fold_in(KEY, 5), (H,)))
    B = jax.random.normal(jax.random.fold_in(KEY, 6), (b, s, G, N))
    C = jax.random.normal(jax.random.fold_in(KEY, 7), (b, s, G, N))
    y1, s1 = ops.ssd_chunked(x, dt, A, B, C, chunk=chunk)
    y2, s2 = REF.ssd_ref(x, dt, A, B, C, chunk)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), rtol=2e-3, atol=2e-3)
    np.testing.assert_allclose(np.asarray(s1), np.asarray(s2), rtol=2e-3, atol=2e-3)


def test_ssd_chunked_equals_sequential_recurrence():
    """The chunked dual form equals the exact token-by-token recurrence."""
    b, s, H, P, G, N = 2, 48, 4, 8, 2, 16
    x = jax.random.normal(KEY, (b, s, H, P))
    dt = jax.nn.softplus(jax.random.normal(jax.random.fold_in(KEY, 1), (b, s, H)))
    A = -jnp.exp(jax.random.normal(jax.random.fold_in(KEY, 2), (H,)))
    B = jax.random.normal(jax.random.fold_in(KEY, 3), (b, s, G, N))
    C = jax.random.normal(jax.random.fold_in(KEY, 4), (b, s, G, N))
    y1, s1 = REF.ssd_ref(x, dt, A, B, C, chunk=16)
    y2, s2 = SSM.ssd_sequential_ref(x, dt, A, B, C)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), rtol=3e-4, atol=3e-4)
    np.testing.assert_allclose(np.asarray(s1), np.asarray(s2), rtol=3e-4, atol=3e-4)


def test_flash_matches_model_blocked_attention():
    """The model's memory-bounded attention path == the kernel semantics."""
    from repro.models import layers as L
    b, s, H, K, dh = 2, 128, 4, 2, 64
    q = jax.random.normal(KEY, (b, s, H, dh))
    k = jax.random.normal(jax.random.fold_in(KEY, 1), (b, s, K, dh))
    v = jax.random.normal(jax.random.fold_in(KEY, 2), (b, s, K, dh))
    a = L.blocked_attention(q, k, v, causal=True, block_q=32)
    bref = REF.flash_attention_ref(q, k, v, causal=True)
    np.testing.assert_allclose(np.asarray(a), np.asarray(bref), rtol=3e-5, atol=3e-5)
