"""Fleet subsystem: workload, shifting, routing, fleet simulation.

Fast smoke tests run in tier-1; the 48 h end-to-end acceptance runs are
marked ``slow`` (run them with ``pytest -m slow`` or ``-m "slow or not
slow"``) so tier-1 wall-clock stays bounded."""
import numpy as np
import pytest

from repro.core import carbon as CB
from repro.core import controller as CTRL
from repro.core import schemes as SCH
from repro.fleet import fleet_sim as FS
from repro.fleet import forecast as FC
from repro.fleet import router as RT
from repro.fleet import shifting as SH
from repro.fleet import workload as WL
from repro.serving import simulator as SIM

REGIONS = ("CISO-March", "CISO-September", "ESO-March")


# =============================================================================
# workload
# =============================================================================
def test_workload_volume_and_slack():
    wl = WL.make_workload(100.0, 48 * 3600.0, deferrable_frac=0.25,
                          n_jobs=8, seed=3)
    assert wl.deferrable_work == pytest.approx(0.25 * 100.0 * 48 * 3600.0)
    for j in wl.jobs:
        assert j.slack_s >= 6 * 3600.0 - 1e-6
        assert j.deadline_s <= 48 * 3600.0 + 1e-6
        assert j.arrival_s >= 0.0
    assert wl.total_work(48 * 3600.0) == pytest.approx(
        100.0 * 48 * 3600.0 * 1.25)


# =============================================================================
# shifting
# =============================================================================
def _slots_two_regions():
    # clean region: cheap but small; dirty region: expensive but huge
    slots = []
    for k in range(8):
        slots.append(SH.Slot("clean", k * 1800.0, 1800.0, 10.0, 100.0, 500.0))
        slots.append(SH.Slot("dirty", k * 1800.0, 1800.0, 1000.0, 400.0, 500.0))
    return slots


def test_greedy_shift_prefers_low_ci_and_respects_caps():
    jobs = [WL.DeferrableJob("a", 0.0, 30000.0, 4 * 3600.0)]
    plan = SH.greedy_shift(jobs, _slots_two_regions())
    assert plan.feasible
    by_region = {}
    for a in plan.allocations:
        by_region[a.region] = by_region.get(a.region, 0.0) + a.work_req
    # clean slots fill to capacity (8 × 10 rps × 1800 s = 144k > 30k, but
    # only slots ending before the deadline qualify: 8 slots all do)
    assert by_region.get("clean", 0.0) == pytest.approx(30000.0)
    # per-slot capacity never exceeded
    used = plan.by_slot()
    for s in _slots_two_regions():
        assert used.get((s.region, s.t0), 0.0) <= s.capacity_req + 1e-6


def test_greedy_shift_respects_deadlines():
    work = 5e6     # exceeds the 3.636M requests available before the deadline
    jobs = [WL.DeferrableJob("tight", 0.0, work, 3600.0)]
    plan = SH.greedy_shift(jobs, _slots_two_regions())
    for a in plan.allocations:
        assert a.t0 + a.dur_s <= 3600.0 + 1e-6
    # 2 feasible slot-pairs × (10 + 1000) rps × 1800 s = 3.636M → partial
    placed = plan.placed_work
    assert placed == pytest.approx((10.0 + 1000.0) * 3600.0, rel=1e-6)
    assert plan.unplaced["tight"] == pytest.approx(work - placed, rel=1e-6)


def test_lp_shift_at_least_as_cheap_as_greedy():
    pytest.importorskip("scipy")
    rng = np.random.default_rng(0)
    slots = [SH.Slot(f"r{i % 3}", (i // 3) * 1800.0, 1800.0,
                     float(rng.uniform(5, 50)), float(rng.uniform(80, 400)),
                     500.0) for i in range(30)]
    jobs = [WL.DeferrableJob(f"j{k}", 0.0, 40000.0,
                             (k + 3) * 3600.0) for k in range(4)]
    g = SH.greedy_shift(jobs, slots)
    lp = SH.lp_shift(jobs, slots)
    assert lp.placed_work >= g.placed_work - 1e-6
    if g.feasible and lp.feasible:
        assert (lp.forecast_carbon_g(slots)
                <= g.forecast_carbon_g(slots) * (1 + 1e-9))


# =============================================================================
# routing
# =============================================================================
def _snap(name, cap, energy, ci, delay=0.0, p95=0.005):
    return RT.RegionSnapshot(name, cap, energy, ci, delay,
                             lambda rate: p95 * (1 + rate / cap))


def test_router_prefers_clean_region_within_caps():
    snaps = [_snap("dirty", 1000.0, 500.0, 400.0),
             _snap("clean", 1000.0, 500.0, 100.0)]
    d = RT.route_interactive(500.0, snaps, sla_s=1.0, max_rho=0.85)
    assert d.rate("clean") == pytest.approx(500.0)
    assert d.rate("dirty") == 0.0
    assert d.overflow_rps == 0.0


def test_router_caps_at_max_rho_and_spills():
    snaps = [_snap("clean", 400.0, 500.0, 100.0),
             _snap("dirty", 1000.0, 500.0, 400.0)]
    d = RT.route_interactive(500.0, snaps, sla_s=1.0, max_rho=0.85)
    assert d.rate("clean") == pytest.approx(0.85 * 400.0)
    assert d.rate("dirty") == pytest.approx(500.0 - 0.85 * 400.0)


def test_router_latency_budget_excludes_far_region():
    snaps = [_snap("far-clean", 1000.0, 500.0, 100.0, delay=0.9, p95=0.2),
             _snap("near-dirty", 1000.0, 500.0, 400.0, delay=0.0, p95=0.2)]
    d = RT.route_interactive(300.0, snaps, sla_s=1.0, max_rho=0.85)
    # far region p95(0.2·(1+ρ)) + 0.9 delay > 1.0 SLA for any useful rate
    assert d.rate("far-clean") < d.rate("near-dirty")


def test_router_hysteresis_keeps_incumbent_on_near_tie():
    snaps = [_snap("a", 1000.0, 500.0, 100.0),
             _snap("b", 1000.0, 500.0, 102.0)]   # 2% dirtier
    d = RT.route_interactive(500.0, snaps, sla_s=1.0,
                             prev_rates={"b": 500.0}, hysteresis=0.05)
    assert d.rate("b") == pytest.approx(500.0)   # stickiness wins the near-tie


def test_router_overload_spreads_and_reports_overflow():
    snaps = [_snap("a", 100.0, 500.0, 100.0), _snap("b", 100.0, 500.0, 200.0)]
    d = RT.route_interactive(500.0, snaps, sla_s=1.0, max_rho=0.85)
    assert d.overflow_rps > 0
    assert sum(d.rates.values()) == pytest.approx(500.0)


def test_router_egress_carbon_flips_routing():
    """A cleaner grid behind a carbon-expensive network path loses to a
    dirtier local region once egress dominates compute carbon: at 500 J and
    a 2:1 grid-CI gap the compute spread is ~0.016 gCO2/req, so 0.5 GB over
    a 0.1 gCO2/GB path (0.05 g) flips the water-fill order."""
    def snaps(egress):
        clean_far = RT.RegionSnapshot(
            "clean-far", 1000.0, 500.0, 100.0, 0.0,
            lambda r: 0.005 * (1 + r / 1000.0),
            egress_gb_per_req=0.5, egress_g_per_gb=egress)
        dirty_near = RT.RegionSnapshot(
            "dirty-near", 1000.0, 500.0, 200.0, 0.0,
            lambda r: 0.005 * (1 + r / 1000.0))
        return [clean_far, dirty_near]

    base = RT.route_interactive(500.0, snaps(0.0), sla_s=1.0)
    assert base.rate("clean-far") == pytest.approx(500.0)   # grid CI decides
    flipped = RT.route_interactive(500.0, snaps(0.1), sla_s=1.0)
    assert flipped.rate("dirty-near") == pytest.approx(500.0)
    assert flipped.rate("clean-far") == 0.0
    # the snapshot exposes both terms so the flip is auditable
    s = snaps(0.1)[0]
    assert s.egress_g_per_req() > s.carbon_g_per_req()


def test_router_data_gravity_caps_clean_region():
    """Data residency is a hard cap: the cleanest region only takes its
    gravity allowance, the remainder water-fills onward — and overload
    spreading respects the cap too."""
    clean = RT.RegionSnapshot("clean", 1000.0, 500.0, 100.0, 0.0,
                              lambda r: 0.005, gravity_cap_rps=100.0)
    dirty = RT.RegionSnapshot("dirty", 1000.0, 500.0, 400.0, 0.0,
                              lambda r: 0.005)
    d = RT.route_interactive(500.0, [clean, dirty], sla_s=1.0, max_rho=0.85)
    assert d.rate("clean") == pytest.approx(100.0)
    assert d.rate("dirty") == pytest.approx(400.0)
    # overload beyond every SLA/rho cap: gravity is HARD — the capped
    # region takes nothing past its allowance, the spill lands on the
    # region with remaining headroom, and total demand is conserved
    d2 = RT.route_interactive(2000.0, [clean, dirty], sla_s=1.0, max_rho=0.85)
    assert d2.overflow_rps > 0
    assert d2.rate("clean") == pytest.approx(100.0)
    assert sum(d2.rates.values()) == pytest.approx(2000.0)


# =============================================================================
# queue rebalancer migration cost
# =============================================================================
class _StubServer:
    def __init__(self):
        self.defer_backlog = 0.0


class _StubRegion:
    """Duck-typed stand-in for fleet_sim._Region as the rebalancer sees it."""

    def __init__(self, name, int_rate, trace):
        self.name = name
        self.int_rate = int_rate
        self.queue = []
        self.server = _StubServer()
        self.acct = CB.CarbonAccountant(trace)

    def enqueue(self, deadline_s, job_id, work):
        self.queue.append([deadline_s, job_id, work])
        self.queue.sort()


def _flat_trace(ci=300.0, hours=24.0):
    t = np.arange(0, hours * 3600.0 + 1, 1800.0)
    return CB.CarbonTrace("flat", t, np.full_like(t, ci))


def test_rebalance_charges_migration_energy_and_moves():
    """An EDF-infeasible entry migrates to a destination that can actually
    drain it, and the checkpoint/transfer energy is charged to the source
    (moves were free in PR 1)."""
    src = _StubRegion("src", int_rate=95.0, trace=_flat_trace())
    dst = _StubRegion("dst", int_rate=0.0, trace=_flat_trace())
    # ~14 rps of drain needed; src has 3.5 rps of headroom, dst has 70
    src.queue = [[3600.0, "job", 50_000.0]]
    src.server.defer_backlog = 50_000.0
    caps = {"src": 100.0, "dst": 100.0}
    cfg = FS.FleetConfig(migrate_overhead_s=60.0, migrate_j_per_req=0.05)
    FS._rebalance_queues([src, dst], 0.0, caps, cfg=cfg)
    assert not src.queue and dst.queue           # moved, and stayed moved
    assert src.acct.energy_j == pytest.approx(50_000.0 * 0.05)
    assert src.acct.carbon_g > 0
    assert dst.server.defer_backlog == pytest.approx(50_000.0)
    assert src.server.defer_backlog == 0.0


def test_rebalance_skips_move_that_no_longer_pays_off():
    """A move only pays off if the destination can still make the deadline
    AFTER the checkpoint/re-stage delay: with the overhead eating the
    runway the entry stays put and no cost is charged — while the same
    entry under free moves (cfg=None, the PR-1 behaviour) migrates."""
    def fresh():
        src = _StubRegion("src", int_rate=95.0, trace=_flat_trace())
        dst = _StubRegion("dst", int_rate=0.0, trace=_flat_trace())
        src.queue = [[600.0, "job", 20_000.0]]   # 33 rps needed: dst-feasible
        src.server.defer_backlog = 20_000.0
        return src, dst
    caps = {"src": 100.0, "dst": 100.0}
    src, dst = fresh()
    # overhead eats the runway: 600 s deadline - 550 s re-stage < a minute
    cfg = FS.FleetConfig(migrate_overhead_s=550.0, migrate_j_per_req=0.05)
    FS._rebalance_queues([src, dst], 0.0, caps, cfg=cfg)
    assert src.queue and not dst.queue           # stayed
    assert src.acct.energy_j == 0.0              # no cost charged
    # identical situation with free instant moves DOES migrate
    src, dst = fresh()
    FS._rebalance_queues([src, dst], 0.0, caps, cfg=None)
    assert not src.queue and dst.queue


def test_fleet_region_engine_kv_layout_plumbing():
    """FleetConfig.engine_kv_layout reaches each region's RealEngine: the
    fleet's real backend inherits the paged KV pool through the same
    Controller.maybe_reoptimize path with no further wiring."""
    pytest.importorskip("jax")
    from repro.serving import backends as BK
    cfg = FS.FleetConfig(backend="real", engine_kv_layout="paged")
    fam = BK.build_real_family(cfg.engine_arch, cfg.engine_layers,
                               fracs=(1.0,), seed=cfg.seed)
    region = FS._Region("r0", CB.make_trace("CISO-March", hours=2),
                        fam[0].variant.family, cfg, engine_family=fam)
    assert region.server.engine.kv_layout == "paged"


def test_fleet_region_engine_topology_builds_disagg():
    """FleetConfig.engine_topology (region → (prefill, decode) workers)
    makes that region's engine a DisaggEngine while unlisted regions stay
    monolithic; probe_window drives the split engine unchanged through
    ServingBackend, every probe hands off, and the role split conserves."""
    pytest.importorskip("jax")
    from repro.core import config_graph as CG
    from repro.obs.validate import check_disagg_conservation
    from repro.serving import backends as BK
    from repro.serving import engine as ENG
    from repro.serving.disagg import DisaggEngine
    cfg = FS.FleetConfig(backend="real", engine_kv_layout="paged",
                         engine_topology={"r0": (1, 1)})
    fam = BK.build_real_family(cfg.engine_arch, cfg.engine_layers,
                               fracs=(1.0,), seed=cfg.seed)
    trace = CB.make_trace("CISO-March", hours=2)
    region = FS._Region("r0", trace, fam[0].variant.family, cfg,
                        engine_family=fam)
    assert isinstance(region.server.engine, DisaggEngine)
    assert region.server.engine.roles == {"prefill": 1, "decode": 1}
    other = FS._Region("r1", trace, fam[0].variant.family, cfg,
                       engine_family=fam)
    assert type(other.server.engine) is ENG.RealEngine
    g = CG.ConfigGraph.uniform(fam[0].variant.family, "x1", 16, 1)
    m = region.server.probe_window(g, 1800.0)
    assert m is not None and m["served"] == cfg.probe_requests
    assert m["handoffs"] == cfg.probe_requests
    check_disagg_conservation(m)
    # the split needs the paged arena (block handoff): anything else is a
    # config error at region build
    bad = FS.FleetConfig(backend="real", engine_kv_layout="slotted",
                         engine_topology={"r0": (1, 1)})
    with pytest.raises(AssertionError, match="paged"):
        FS._Region("r0", trace, fam[0].variant.family, bad,
                   engine_family=fam)


def test_fleet_region_forecast_policy_probe_end_to_end():
    """FleetConfig.engine_policy='carbon_forecast' builds the region's
    engine policy over the REGION'S forecaster (ForecastCIFn, not a raw
    trace lookup), plumbs horizon/threshold through, and probe_window
    re-anchors the ci_fn epoch to the window's trace time while serving a
    mixed interactive+deferrable probe batch on real execution."""
    pytest.importorskip("jax")
    from repro.core import config_graph as CG
    from repro.serving import backends as BK
    from repro.serving.policies import CarbonForecastPolicy
    cfg = FS.FleetConfig(backend="real", engine_policy="carbon_forecast",
                         engine_policy_horizon_s=1800.0,
                         engine_ci_threshold_g=250.0,
                         probe_deferrable_frac=0.5, probe_deadline_s=1.0)
    fam = BK.build_real_family(cfg.engine_arch, cfg.engine_layers,
                               fracs=(1.0,), seed=cfg.seed)
    trace = CB.make_trace("CISO-March", hours=2)
    region = FS._Region("r0", trace, fam[0].variant.family, cfg,
                        engine_family=fam)
    pol = region.server.engine.policy
    assert isinstance(pol, CarbonForecastPolicy)
    assert pol.ci_threshold == 250.0
    # the probe session's deadline runway maps onto the configured trace
    # horizon: horizon in session seconds, ci_fn scales session → trace
    assert pol.horizon_s == cfg.probe_deadline_s
    assert pol.ci_fn.time_scale == pytest.approx(1800.0
                                                 / cfg.probe_deadline_s)
    # a hold can never turn a probe into a miss: force-release fires while
    # half the deadline budget remains
    assert pol.deadline_margin_s == pytest.approx(0.5 * cfg.probe_deadline_s)
    assert pol.ci_fn.forecaster is region.forecaster
    assert region.server.ci_fn is pol.ci_fn
    g = CG.ConfigGraph.uniform(fam[0].variant.family, "x1", 16, 1)
    t_window = 1800.0
    m = region.server.probe_window(g, t_window)
    assert m is not None and m["served"] == cfg.probe_requests
    assert pol.ci_fn.t0 == t_window          # epoch anchored to the window
    # mixed probe batch: the deferrable half carried deadlines and flowed
    # through the hold/release path on a real engine
    slos = [r.slo for r in region.server.engine.last_responses]
    assert slos.count("deferrable") == cfg.probe_requests // 2
    assert region.server.real_served == cfg.probe_requests
    assert region.server.real_carbon_g > 0.0


# =============================================================================
# controller predictive trigger
# =============================================================================
class _RampForecaster:
    def __init__(self, ci_future):
        self.ci_future = ci_future

    def predict(self, t, horizon_s):
        return self.ci_future


def test_predictive_trigger_fires_before_reactive():
    ctx, _ = SIM.make_context("efficientnet", SIM.SimConfig(n_blocks=1))
    fc = _RampForecaster(300.0)
    c = CTRL.Controller(SCH.make_scheme("CLOVER"), ctx, forecaster=fc)
    c.start(0.0, 300.0)
    assert not c.should_reoptimize(300.0, t=0.0)   # flat obs + flat forecast
    fc.ci_future = 400.0       # forecast swings; observation still flat
    assert c.should_reoptimize(300.0, t=60.0)
    cfg, outcome = c.maybe_reoptimize(60.0, 300.0)
    inv = c.invocations[-1]
    assert inv.predictive
    # optimized against the blend of current and forecast CI
    assert 300.0 < inv.ci < 400.0


def test_predictive_trigger_no_ping_pong():
    """After a predictive re-optimization, a *stable* observation/forecast
    pair must not re-trip the trigger: storing the blend while triggering on
    raw observed CI would alternate predictive/reactive invocations every
    window for as long as forecast and observation disagree."""
    ctx, _ = SIM.make_context("efficientnet", SIM.SimConfig(n_blocks=1))
    c = CTRL.Controller(SCH.make_scheme("CLOVER"), ctx,
                        forecaster=_RampForecaster(400.0))
    c.start(0.0, 300.0)
    c.maybe_reoptimize(60.0, 300.0)          # predictive invocation
    n = len(c.invocations)
    for k in range(10):                      # flat obs + flat forecast
        c.maybe_reoptimize(120.0 + 60.0 * k, 300.0)
    assert len(c.invocations) == n


def test_predictive_trigger_silent_without_forecaster():
    ctx, _ = SIM.make_context("efficientnet", SIM.SimConfig(n_blocks=1))
    c = CTRL.Controller(SCH.make_scheme("CLOVER"), ctx)
    c.start(0.0, 300.0)
    assert not c.should_reoptimize(302.0, t=0.0)


# =============================================================================
# fleet simulation (smoke: short horizon; acceptance: slow)
# =============================================================================
def _short_traces(hours=30.0, seed=7):
    return {r: CB.make_trace(r, hours=hours, seed=seed) for r in REGIONS}


def test_fleet_smoke_serves_and_meets_deadlines():
    traces = _short_traces()
    cfg = FS.FleetConfig(warmup_s=24 * 3600.0, n_jobs=4,
                         min_slack_s=2 * 3600.0, max_slack_s=4 * 3600.0,
                         plan_horizon_s=6 * 3600.0)
    rep = FS.run_fleet("efficientnet", traces, cfg)
    assert rep.served_interactive > 0
    assert rep.served_deferrable > 0
    # all interactive demand served (no residual backlog beyond one window)
    total_int = sum(r.served_interactive for r in rep.regions.values())
    assert total_int == pytest.approx(rep.served_interactive)
    assert rep.p95_s <= rep.sla_target_s
    assert not rep.deadline_misses
    assert rep.overflow_req == 0.0


def test_fleet_suspends_unused_regions():
    traces = _short_traces()
    cfg = FS.FleetConfig(warmup_s=24 * 3600.0, n_jobs=2,
                         min_slack_s=2 * 3600.0, max_slack_s=4 * 3600.0,
                         plan_horizon_s=6 * 3600.0)
    rep = FS.run_fleet("efficientnet", traces, cfg)
    # the dirtiest region should spend most of the short window suspended —
    # an always-on 1-block region would burn ~1.2 kg over these 6 h
    assert min(r.carbon_g for r in rep.regions.values()) < 1000.0


@pytest.mark.slow
def test_fleet_beats_best_single_region_48h():
    """ISSUE 1 acceptance: on the three bundled regions over 48 h, fleet
    {forecast + shifting + routing} beats the best single-region CLOVER on
    carbon/request with p95 within SLA and all deadlines met."""
    traces = {r: CB.make_trace(r, hours=72.0) for r in REGIONS}
    cfg = FS.FleetConfig(warmup_s=24 * 3600.0)
    out = FS.compare_fleet_vs_single("efficientnet", traces, cfg)
    fleet, singles = out["fleet"], out["singles"]
    best = singles[out["best_single"]]
    assert fleet.carbon_per_req_g() < best.carbon_per_req_g()
    assert fleet.p95_s <= fleet.sla_target_s
    assert not fleet.deadline_misses


@pytest.mark.slow
def test_fleet_ablation_ordering_48h():
    """Routing and elastic scaling are the load-bearing levers: removing
    either must cost carbon vs the full fleet."""
    traces = {r: CB.make_trace(r, hours=72.0) for r in REGIONS}
    base = FS.run_fleet("efficientnet", traces,
                        FS.FleetConfig(warmup_s=24 * 3600.0))
    no_route = FS.run_fleet("efficientnet", traces,
                            FS.FleetConfig(warmup_s=24 * 3600.0,
                                           routing_on=False))
    no_elastic = FS.run_fleet("efficientnet", traces,
                              FS.FleetConfig(warmup_s=24 * 3600.0,
                                             elastic=False))
    assert base.carbon_per_req_g() < no_route.carbon_per_req_g()
    assert base.carbon_per_req_g() < no_elastic.carbon_per_req_g()


# =============================================================================
# real CSV trace ingestion → forecaster backtests
# =============================================================================
EM_FIXTURE = __file__.rsplit("/", 1)[0] + "/fixtures/electricitymaps_sample.csv"


def test_load_electricitymaps_csv_fixture():
    """ElectricityMaps-style export: ISO timestamps, extra columns, a gap
    row, irregular spacing — loads into a rebased piecewise-linear trace."""
    tr = CB.load_trace_csv(EM_FIXTURE, name="em-ciso")
    assert tr.name == "em-ciso"
    assert tr.times_s[0] == 0.0
    assert (np.diff(tr.times_s) > 0).all()
    # 25 rows, one with a blank intensity cell → 24 samples
    assert len(tr.times_s) == 24
    assert tr.duration_s == pytest.approx(24 * 3600.0)
    # irregular spacing survives (the 03:30 / 09:15 / 20:30 stamps)
    assert len(set(np.round(np.diff(tr.times_s), 3))) > 2
    # diurnal solar valley is present and interpolation works mid-gap
    assert tr.intensity.min() < 100.0 < 300.0 < tr.intensity.max() + 1e-9
    assert 231.8 < tr.at(4.5 * 3600.0 + 1800.0) < 249.3   # inside the gap


def test_load_trace_csv_explicit_columns(tmp_path):
    path = tmp_path / "t.csv"
    path.write_text("when,zone,gco2eq\n10,CA,100\n0,CA,300\n20,CA,\n"
                    "30,CA,50\n")
    tr = CB.load_trace_csv(str(path), time_col="when", ci_col="gco2eq")
    np.testing.assert_allclose(tr.times_s, [0.0, 10.0, 30.0])  # sorted, gap dropped
    np.testing.assert_allclose(tr.intensity, [300.0, 100.0, 50.0])


def test_backtest_csv_on_real_trace():
    """Forecaster evaluation wired to real CSV traces: every member scores
    a finite MAE on the fixture and persistence degrades with horizon."""
    tab = FC.backtest_csv(EM_FIXTURE, horizons_s=(1800.0, 3600.0))
    assert set(tab) == {"persistence", "harmonic", "ensemble"}
    for by_h in tab.values():
        for rep in by_h.values():
            assert rep.n > 0 and np.isfinite(rep.mae) and rep.mae >= 0.0
    p = tab["persistence"]
    assert p[3600.0].mae >= p[1800.0].mae


# =============================================================================
# real-execution engine backend (ISSUE 2 acceptance)
# =============================================================================
@pytest.mark.slow
def test_fleet_real_engine_backend_short_horizon():
    """ISSUE 2 acceptance: a short-horizon fleet run drives per-region
    continuous-batching RealEngines through Controller.maybe_reoptimize —
    warm reconfigurations, real probe batches every window — and the
    measured p95 stays within the real SLA (1.5× the measured BASE p95 of
    the same engine ladder, the same derivation serve_clover uses)."""
    import importlib
    importlib.import_module("jax")        # real backend needs jax
    from repro.core import config_graph as CG
    from repro.serving import backends as BK
    from repro.serving import engine as ENG

    cfg = FS.FleetConfig(n_blocks=1, window_s=600.0, backend="real",
                         deferrable_frac=0.1, n_jobs=2,
                         min_slack_s=1800.0, max_slack_s=3600.0)
    # measured real SLA reference: BASE (x1 on the full block), warm
    fam = BK.build_real_family(cfg.engine_arch, cfg.engine_layers,
                               seed=cfg.seed)
    eng = ENG.RealEngine(fam, n_slots=cfg.engine_slots,
                         max_len=cfg.engine_max_len)
    eng.configure(CG.ConfigGraph.uniform(fam[0].variant.family, "x1", 16,
                                         cfg.n_blocks))
    rng = np.random.default_rng(0)
    vocab = fam[0].cfg.vocab_size
    prompts = [rng.integers(0, vocab, size=(1, cfg.probe_prompt_len)
                            ).astype(np.int32)
               for _ in range(cfg.probe_requests)]
    eng._serve_prompts(prompts, n_new=cfg.probe_new_tokens)          # compile warmup
    base = min((eng._serve_prompts(prompts, n_new=cfg.probe_new_tokens)
                for _ in range(3)), key=lambda m: m["p95_s"])
    # serve_clover derives its SLA as 1.5× measured BASE p95; here the p95
    # is taken over ~50 wall-clock probe batches on a shared CPU host, whose
    # tail carries O(30 ms) OS-scheduler hiccups — the 3× factor plus an
    # absolute allowance keeps this a regression gate (a return to serial
    # batch-1 serving or prompt replay shows up at 5-10×) without flaking
    # on scheduler noise
    real_sla_s = max(3.0 * base["p95_s"], base["p95_s"] + 0.05)

    traces = {r: CB.make_trace(r, hours=2.0, seed=3)
              for r in ("CISO-March", "ESO-March")}
    rep = FS.run_fleet("efficientnet", traces, cfg)

    assert rep.real_served > 0, "no real requests executed"
    assert rep.deadlines_met
    reconfigs = sum(r.real_reconfigs for r in rep.regions.values())
    assert reconfigs >= 2, "controller never reconfigured a real engine"
    for r in rep.regions.values():
        if r.real_served:
            assert r.real_energy_j > 0.0
    assert rep.real_p95_s > 0.0
    assert rep.real_p95_s <= real_sla_s, (rep.real_p95_s, real_sla_s)
