"""Device-resident decode hot path: greedy parity of the pipelined loop
(device-fed fused dispatch, async readback, event-bound uploads) against the
synchronous reference loop across admission/release, preemption, and partial
swap-in; fused-step bit-exactness at the model level; compile-once retrace
accounting; and the steady-state host-traffic regression gates."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.core import config_graph as CG
from repro.models import registry as R
from repro.serving import engine as ENG

CFG = get_smoke_config("qwen3-1.7b").with_(n_layers=2, dtype=jnp.float32)


@pytest.fixture(scope="module")
def family():
    return ENG.build_engine_family(CFG, fracs=(1.0,))


def _graph():
    return CG.ConfigGraph.from_dict(CFG.name, {("x1", 16): 1})


def _prompts(lens, seed=0, shared=0):
    rng = np.random.default_rng(seed)
    pre = rng.integers(0, CFG.vocab_size, size=shared).astype(np.int32)
    out = []
    for n in lens:
        p = rng.integers(0, CFG.vocab_size, size=int(n)).astype(np.int32)
        if shared:
            p = np.concatenate([pre, p])
        out.append(p)
    return out


def _pair(family, **kw):
    """(pipelined, synchronous-reference) engines with identical layout."""
    mk = lambda pipe: ENG.RealEngine(family, n_slots=4, max_len=48,
                                     kv_layout="paged", block_size=8,
                                     max_seqs=4, decode_pipeline=pipe, **kw)
    pipe, sync = mk(True), mk(False)
    pipe.configure(_graph())
    sync.configure(_graph())
    return pipe, sync


def _assert_same_outputs(a: ENG.RealEngine, b: ENG.RealEngine):
    assert set(a.last_outputs) == set(b.last_outputs)
    for rid in a.last_outputs:
        np.testing.assert_array_equal(a.last_outputs[rid],
                                      b.last_outputs[rid])


# =============================================================================
# fused multi-step decode: bit-exact vs host-fed single steps (model level)
# =============================================================================
def test_decode_paged_multi_matches_single_steps(family):
    """``decode_paged_multi`` (lax.fori_loop with on-device greedy feedback)
    must be BIT-identical to k host-fed ``decode_paged`` calls — the
    property that lets the engine fuse dispatches without ever changing
    tokens, including an inactive row whose state must not move."""
    ev = family[0]
    k_steps, bs, nb = 4, 8, 12
    arena0 = R.make_block_arena(ev.cfg, nb, bs, dtype=jnp.float32)
    rng = np.random.default_rng(3)
    arena0 = {
        key: jnp.asarray(rng.standard_normal(v.shape) * 0.02, v.dtype)
        for key, v in arena0.items()}
    b = 3
    n_pages = 3                                  # headroom for k more tokens
    tables = jnp.asarray(
        rng.permutation(np.arange(1, nb))[:b * n_pages]
        .reshape(b, n_pages).astype(np.int32))
    lengths = jnp.asarray(np.array([5, 9, 7], np.int32))
    active = jnp.asarray(np.array([True, True, False]))
    nxt0 = jnp.asarray(rng.integers(1, ev.cfg.vocab_size,
                                    size=(b, 1)).astype(np.int32))

    toks_m, _, nxt_m, ln_m = R.decode_paged_multi(
        ev.params, {k: v for k, v in arena0.items()}, {"tokens": nxt0},
        ev.cfg, tables, lengths, active, k_steps)

    arena = {k: v for k, v in arena0.items()}
    cur, ln = nxt0, lengths
    toks_ref = []
    for _ in range(k_steps):
        logits, arena = R.decode_paged(ev.params, arena, {"tokens": cur},
                                       ev.cfg, tables, ln, active)
        tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        toks_ref.append(np.asarray(tok))
        cur = jnp.where(active[:, None], tok[:, None], cur)
        ln = ln + active.astype(jnp.int32)

    np.testing.assert_array_equal(np.asarray(toks_m), np.stack(toks_ref))
    np.testing.assert_array_equal(np.asarray(nxt_m), np.asarray(cur))
    np.testing.assert_array_equal(np.asarray(ln_m), np.asarray(ln))


# =============================================================================
# greedy parity: pipelined loop vs synchronous reference
# =============================================================================
def test_pipelined_parity_admission_release(family):
    """Mixed prompt lengths with staggered completions (different n_new via
    mixed lengths): admissions, releases, and bucket changes all force
    event re-uploads mid-stream — outputs must not change."""
    prompts = _prompts((6, 14, 9, 22, 6, 11), seed=1)
    pipe, sync = _pair(family)
    m_pipe = pipe._serve_prompts(prompts, n_new=10)
    m_sync = sync._serve_prompts(prompts, n_new=10)
    _assert_same_outputs(pipe, sync)
    assert m_pipe["served"] == m_sync["served"] == len(prompts)
    assert m_pipe["tokens"] == m_sync["tokens"]
    # batched step counts may differ by a tick or two (completions LAND one
    # tick later, shifting re-admission packing) — tokens must not; with
    # staggered lifetimes some row always has remaining < fused_steps, so
    # fusion correctly stays out (every dispatch lands exactly one step)
    assert m_pipe["decode_dispatches"] == m_pipe["decode_steps"]
    assert m_sync["decode_dispatches"] == m_sync["decode_steps"]


def test_pipelined_parity_preemption_and_partial_swapin(family):
    """The hard case: an overcommitted arena forces decode-time preemption
    (staged async swap-out, partial swap-in through the radix tree) while
    in-flight pipelined work must be landed before every victim snapshot.
    Greedy outputs must equal the synchronous reference's exactly."""
    prompts = _prompts((6, 6, 6, 6), seed=5, shared=16)
    pipe, sync = _pair(family, n_blocks=14, preemption=True)
    m_pipe = pipe._serve_prompts(prompts, n_new=16)
    m_sync = sync._serve_prompts(prompts, n_new=16)
    _assert_same_outputs(pipe, sync)
    assert m_pipe["preemptions"] >= 1 and m_sync["preemptions"] >= 1
    # a restore actually happened (pages copied or tree-resident)
    assert (m_pipe["swapin_pages_copied"]
            + m_pipe["partial_swapin_pages_saved"]) >= 1
    # swap churn reclaimed fully in both loops
    for eng in (pipe, sync):
        inst = eng.instances[0]
        inst.alloc.check()
        assert all(s is None for s in inst.rows)
        assert not inst._inflight and not inst._pending_first


# =============================================================================
# compile accounting: one trace per (row bucket, k), never after warmup
# =============================================================================
def test_fused_decode_compiles_once_per_bucket(family):
    """Warmup seeds every (row-bucket, k) fused-decode shape; serving —
    including a second warm session at a different concurrency — must
    never retrace."""
    eng = ENG.RealEngine(family, n_slots=4, max_len=48, kv_layout="paged",
                         block_size=8, max_seqs=4)
    eng.configure(_graph())
    inst = eng.instances[0]
    for B in (1, 2, 4):
        for k in (1, inst.fused_steps):
            assert ("decode_multi", B, k) in inst._shapes
    m1 = eng._serve_prompts(_prompts((6, 6, 6, 6), seed=2), n_new=12)
    assert m1["compile_retraces"] == 0
    m2 = eng._serve_prompts(_prompts((6, 9), seed=4), n_new=12)
    assert m2["compile_retraces"] == 0
    assert m1["decode_dispatches"] < m1["decode_steps"]  # fusion engaged


# =============================================================================
# steady-state host traffic: zero per-tick uploads, zero blocking syncs
# =============================================================================
def test_steady_state_decode_has_no_per_tick_host_traffic(family):
    """The regression gate behind the hot path: in steady-state decode the
    pipelined loop adds ZERO H2D uploads per tick (uploads stay bound to
    events — here 2 per prefill chunk plus one 4-buffer upload per event)
    and ZERO blocking host round-trips, while the synchronous reference
    pays its fixed per-step freight."""
    prompts = _prompts((6, 6, 6, 6), seed=7)
    pipe, sync = _pair(family)
    m_pipe = pipe._serve_prompts(prompts, n_new=32)
    m_sync = sync._serve_prompts(prompts, n_new=32)
    _assert_same_outputs(pipe, sync)
    steps = m_pipe["decode_steps"]
    assert steps >= 30
    # every pipelined upload is accounted to an EVENT — a prefill chunk
    # (2 transfers) or a 4-buffer loop-state push after an activation /
    # release wave (at most one per admission + one per completion wave) —
    # never to a steady-state tick; the synchronous loop pays 4 per step
    n_events = len(prompts) + len(prompts)
    event_budget = 2 * m_pipe["prefill_chunks"] + 4 * n_events
    assert m_pipe["h2d_transfers"] <= event_budget
    assert m_pipe["h2d_transfers"] * 3 < m_sync["h2d_transfers"]
    assert m_sync["h2d_transfers"] >= 4 * m_sync["decode_steps"]
    # overlapped landings only: no same-tick blocking readback
    assert m_pipe["host_syncs"] == 0
    assert m_sync["host_syncs"] >= m_sync["decode_steps"]
    # fused dispatch: one jitted call covers fused_steps model steps
    assert m_pipe["decode_dispatches"] * 2 <= m_pipe["decode_steps"]
