"""Unified telemetry layer: the shared metrics registry (one CATALOG across
real/DES/fluid backends, nearest-rank percentiles identical to the legacy
scheduler path), request-lifecycle tracing with the conservation invariant
(every span closes; span-attributed joules equal the session total, incl.
preemption + partial swap-in), the streaming carbon feed (accountant-exact
totals, controller consumption), policy-hold accounting on responses, and
the shaped load generators."""
import json

import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.core import carbon as CB
from repro.core import catalog as CAT
from repro.core import config_graph as CG
from repro.fleet.workload import WORKLOAD_SHAPES, shaped_arrival_times, \
    shaped_request_stream
from repro.obs import CATALOG, CarbonFeed, MetricsRegistry, Telemetry, \
    TraceRecorder, validate_chrome_events, validate_trace
from repro.obs.metrics import nearest_rank_percentile
from repro.serving import engine as ENG
from repro.serving import queue as Q
from repro.serving.api import DEFERRABLE, INTERACTIVE, InferenceRequest, \
    serve_workload
from repro.serving.policies import CarbonAwarePolicy
from repro.serving.scheduler import latency_percentile

CFG = get_smoke_config("qwen3-1.7b").with_(n_layers=2, dtype=jnp.float32)
VARIANTS = CAT.get_family("efficientnet")
DES_G = CG.ConfigGraph.from_dict("efficientnet", {("B3", 1): 1})


@pytest.fixture(scope="module")
def family():
    return ENG.build_engine_family(CFG, fracs=(1.0,))


def _graph():
    return CG.ConfigGraph.from_dict(CFG.name, {("x1", 16): 1})


def _bundle(backend):
    return Telemetry(tracer=TraceRecorder(backend),
                     feed=CarbonFeed(300.0, interval_s=1e9, region=backend),
                     backend=backend)


# =============================================================================
# metrics registry
# =============================================================================
def test_percentiles_match_legacy_scheduler_exactly():
    rng = np.random.default_rng(0)
    for n in (1, 2, 7, 100):
        vals = rng.exponential(1.0, size=n).tolist()
        for q in (0.0, 50.0, 90.0, 95.0, 99.0, 100.0):
            assert nearest_rank_percentile(vals, q) == \
                latency_percentile(vals, q), (n, q)
    assert nearest_rank_percentile([], 95.0) == 0.0


def test_registry_standard_catalog_and_kind_safety():
    reg = MetricsRegistry.standard("x")
    assert reg.names() == set(CATALOG)
    reg.counter("requests_served").inc(3)
    assert reg.value("requests_served") == 3
    with pytest.raises(AssertionError):
        reg.histogram("requests_served")      # kind mismatch
    with pytest.raises(AssertionError):
        reg.counter("energy_j").inc(-1.0)     # counters are monotonic
    g = reg.gauge("blocks_in_use")
    g.set(5.0), g.set(2.0)
    assert g.value == 2.0 and g.peak == 5.0
    h = reg.histogram("latency_s")
    for v in (3.0, 1.0, 2.0):
        h.observe(v)
    snap = reg.snapshot()
    assert snap["latency_s_count"] == 3 and snap["latency_s_mean"] == 2.0
    assert snap["latency_s_p50"] == 2.0 and snap["blocks_in_use_peak"] == 5.0


# =============================================================================
# trace recorder + validators
# =============================================================================
def test_tracer_lifecycle_export_and_conservation_checks(tmp_path):
    tr = TraceRecorder("unit")
    sid = tr.open_span("request", 0.0, rid=0)
    tr.instant("admit", 0.1, rid=0)
    tr.counter("blocks_in_use", 0.2, 4)
    tr.close_span(sid, 1.0)
    tr.annotate(sid, energy_j=2.5, carbon_g=0.1)
    tr.span("request", 0.5, 2.0, rid=1, energy_j=1.5)   # retroactive
    s = validate_trace(tr, expect_energy_j=4.0, expect_requests=2)
    assert s["requests"] == 2 and s["energy_j"] == 4.0

    with pytest.raises(AssertionError):     # a joule went missing
        validate_trace(tr, expect_energy_j=5.0)
    dangling = tr.open_span("preempted", 2.5, rid=1)
    with pytest.raises(AssertionError):     # unclosed span
        validate_trace(tr)
    tr.close_span(dangling, 3.0, pages=2)

    jl = tmp_path / "t.jsonl"
    ct = tmp_path / "t.json"
    tr.to_jsonl(str(jl))
    assert len(jl.read_text().splitlines()) == len(tr.records)
    tr.to_chrome_trace(str(ct))
    doc = json.loads(ct.read_text())
    n = validate_chrome_events(doc["traceEvents"])
    assert n == len(tr.records)             # every record became an event
    # rid tracks are tid = rid + 1; the counter lands on the engine track 0
    x = [e for e in doc["traceEvents"] if e["ph"] == "X"]
    assert {e["tid"] for e in x} == {1, 2}
    assert all(e["dur"] >= 0 for e in x)
    with pytest.raises(AssertionError):
        validate_chrome_events([{"ph": "X", "name": "no_ts"}])


# =============================================================================
# shaped load generators
# =============================================================================
def test_shaped_arrivals_follow_their_density():
    D, n = 100.0, 4000
    for shape in WORKLOAD_SHAPES:
        t = shaped_arrival_times(n, D, shape, seed=1)
        assert len(t) == n and np.all(np.diff(t) >= 0)
        assert t.min() >= 0.0 and t.max() <= D
    lin = shaped_arrival_times(n, D, "linear", seed=1)
    assert lin.mean() > 0.55 * D            # mass shifts late on the ramp
    peak = shaped_arrival_times(n, D, "peak", seed=1)
    assert abs(peak.mean() - 0.5 * D) < 0.05 * D
    assert peak.std() < 0.25 * D            # tighter than uniform (0.29 D)
    camel = shaped_arrival_times(n, D, "camel", seed=1)

    def frac(t, lo, hi):
        return float(np.mean((t >= lo * D) & (t < hi * D)))
    # bimodal: the humps carry more mass than the saddle between them
    assert frac(camel, 0.15, 0.35) > 1.5 * frac(camel, 0.45, 0.55) * 2.0
    with pytest.raises(ValueError):
        shaped_arrival_times(10, D, "sawtooth")


def test_shaped_request_stream_carries_deadlines():
    reqs = shaped_request_stream(12, 60.0, vocab_size=100, shape="camel",
                                 slo=DEFERRABLE, priority=0,
                                 deadline_slack_s=300.0, seed=4)
    assert [r.rid for r in reqs] == list(range(12))
    for r in reqs:
        assert r.slo == DEFERRABLE and r.priority == 0
        assert r.deadline_s == pytest.approx(r.arrival_s + 300.0)
    assert all(r.deadline_s is None for r in
               shaped_request_stream(3, 60.0, vocab_size=100))


# =============================================================================
# carbon feed
# =============================================================================
def test_feed_totals_equal_accountant_exactly():
    trace = CB.make_trace("CISO-March", hours=6.0)
    feed = CarbonFeed(trace.at, interval_s=600.0, region="r",
                      pue=CB.PUE_DEFAULT)
    acct = CB.CarbonAccountant(trace, feed=feed)
    seen = []
    feed.subscribe(seen.append)
    rng = np.random.default_rng(0)
    t = 0.0
    for _ in range(40):
        dt = float(rng.uniform(30.0, 400.0))
        acct.add(t, dt, power_w=float(rng.uniform(100.0, 5000.0)))
        t += dt
    feed.flush(t, sla_ok_frac=0.97)
    # conservation by construction: bit-identical totals, not approx
    assert feed.energy_j_total == acct.energy_j
    assert feed.carbon_g_total == acct.carbon_g
    assert feed.pending_energy_j == 0.0
    assert feed.snapshots and seen == feed.snapshots
    assert feed.latest().sla_ok_frac == 0.97
    assert sum(s.energy_j for s in feed.snapshots) == feed.energy_j_total
    for s in feed.snapshots[:-1]:
        assert s.window_s >= 600.0          # emitted on the measure interval


def test_feed_sampler_integrates_power():
    feed = CarbonFeed(500.0, interval_s=1e9, pue=1.0)
    feed.sample(0.0, 200.0)                 # anchors the clock only
    feed.sample(10.0, 200.0)
    feed.sample(20.0, 100.0)
    snap = feed.flush(20.0)
    assert snap.energy_j == pytest.approx(200.0 * 10 + 100.0 * 10)
    assert snap.carbon_g == pytest.approx(snap.energy_j / 3.6e6 * 500.0)


def test_controller_consumes_feed_snapshots():
    from repro.core import controller as CTRL
    from repro.core import schemes as SCH
    from repro.serving import simulator as SIM
    ctx, _ = SIM.make_context("efficientnet", SIM.SimConfig(n_blocks=1))
    c = CTRL.Controller(SCH.make_scheme("CLOVER"), ctx)
    c.start(0.0, 300.0)
    with pytest.raises(AssertionError):     # no ci AND no feed: refuse
        c.maybe_reoptimize(600.0)
    feed = CarbonFeed(120.0, interval_s=60.0, region="r")
    c.feed = feed
    feed.record_segment(540.0, 60.0, 1000.0)
    feed.flush(600.0)
    n0 = len(c.invocations)
    cfg, outcome = c.maybe_reoptimize(600.0)        # ci read from the feed
    assert len(c.invocations) == n0 + 1 and outcome is not None
    assert c.invocations[-1].ci == pytest.approx(120.0)
    # explicit ci still wins over the feed
    assert c.maybe_reoptimize(1200.0, 120.0)[1] is None


# =============================================================================
# DES backend: hold accounting + trace conservation + catalog parity
# =============================================================================
def test_des_holds_carry_reason_and_trace_conserves():
    pol = CarbonAwarePolicy(lambda now: 500.0 if (now or 0) < 90.0 else 50.0,
                            ci_threshold=200.0, est_service_s=1.0)
    tel = _bundle("des")
    des = Q.DESBackend(DES_G, VARIANTS, Q.DESConfig(jitter_sigma=0.0),
                       policy=pol, ci_g_per_kwh=300.0, hold_retry_s=10.0,
                       telemetry=tel)
    reqs = [InferenceRequest(rid=0, prompt=[1], arrival_s=0.0,
                             slo=DEFERRABLE, deadline_s=10_000.0),
            InferenceRequest(rid=1, prompt=[1], arrival_s=1.0,
                             slo=INTERACTIVE),
            InferenceRequest(rid=2, prompt=[1], arrival_s=2.0,
                             slo=DEFERRABLE, deadline_s=10_000.0)]
    responses = {r.rid: r for r in serve_workload(des, reqs)}
    m = des.stats()

    for rid in (0, 2):                      # held through the dirty spell
        r = responses[rid]
        assert r.release_reason == "threshold"
        assert r.held_s > 0.0
        assert r.held_s <= r.queue_delay_s + 1e-9
        assert r.t_finish >= 90.0
    assert responses[1].release_reason is None      # interactive never held
    assert responses[1].held_s == 0.0

    assert des.registry.names() == set(CATALOG)
    assert des.registry.value("holds_released") == 2
    assert des.registry.histogram("held_s").count == 2
    validate_trace(tel.tracer, expect_energy_j=m["energy_j"],
                   expect_requests=3)
    holds = tel.tracer.spans("hold")
    assert len(holds) == 2
    assert all(h["args"]["reason"] == "threshold" for h in holds)
    assert len(tel.tracer.spans("service")) == 3
    tel.feed.flush(m["wall_s"])
    assert tel.feed.energy_j_total == pytest.approx(m["energy_j"],
                                                    rel=1e-12)


def test_validate_cli_runs_clean():
    from repro.obs import validate as V
    assert V.main() == 0


# =============================================================================
# three backends, one metric namespace (shared workload)
# =============================================================================
def test_metric_name_parity_across_real_des_fluid(family):
    from repro.serving.backends import FluidBackend

    def workload():
        return shaped_request_stream(6, 0.3, vocab_size=CFG.vocab_size,
                                     shape="peak", prompt_lens=(6, 10),
                                     n_new=4, seed=2)

    eng = ENG.RealEngine(family, n_slots=2, max_len=32, ci_g_per_kwh=300.0)
    eng.configure(_graph())
    serve_workload(eng, workload())
    des = Q.DESBackend(DES_G, VARIANTS, Q.DESConfig(jitter_sigma=0.0),
                       ci_g_per_kwh=300.0)
    serve_workload(des, workload())
    fluid = FluidBackend(DES_G, VARIANTS, sla_target_s=2.0, window_s=0.25,
                         ci_g_per_kwh=300.0)
    serve_workload(fluid, workload())

    regs = {"real": eng.last_registry, "des": des.registry,
            "fluid": fluid.registry}
    for name, reg in regs.items():
        assert reg.names() == set(CATALOG), name
        assert reg.value("requests_served") == 6, name
        assert reg.value("energy_j") > 0.0, name
        assert reg.histogram("latency_s").count == 6, name
        assert reg.gauge("wall_s").value > 0.0, name
    # same nearest-rank arithmetic everywhere: the stats views agree with
    # their registries bit-for-bit
    assert eng.stats()["p95_s"] == \
        eng.last_registry.histogram("latency_s").percentile(95.0)
    assert des.stats()["p95_s"] == \
        des.registry.histogram("latency_s").percentile(95.0)


# =============================================================================
# real engine: conservation through preemption + partial swap-in
# =============================================================================
def test_engine_trace_conserves_through_preemption_and_swapin(family):
    rng = np.random.default_rng(5)
    pre = rng.integers(0, CFG.vocab_size, size=16).astype(np.int32)
    prompts = [np.concatenate([pre, rng.integers(0, CFG.vocab_size, size=6)
                               .astype(np.int32)]) for _ in range(4)]
    tel = _bundle("real-paged")
    eng = ENG.RealEngine(family, n_slots=2, max_len=64, kv_layout="paged",
                         block_size=8, max_seqs=4, n_blocks=14,
                         preemption=True, ci_g_per_kwh=300.0, telemetry=tel)
    eng.configure(_graph())
    m = eng._serve_prompts(prompts, n_new=16)
    assert m["preemptions"] >= 1, "arena did not force preemption"
    assert m["partial_swapin_pages_saved"] >= 1

    s = validate_trace(tel.tracer, expect_energy_j=m["energy_j"],
                       expect_requests=4)
    assert s["carbon_g"] == pytest.approx(m["carbon_g"], rel=1e-9)
    tr = tel.tracer
    pre_spans = tr.spans("preempted")       # opened at swap-out, closed at
    assert len(pre_spans) == m["preemptions"]          # partial swap-in
    assert all(p["t1"] > p["t0"] and "pages" in p["args"]
               for p in pre_spans)
    assert len(tr.instants("swap_out")) == m["preemptions"]
    assert len(tr.instants("swap_in")) == m["preemptions"]
    assert len(tr.spans("prefill_chunk")) == m["prefill_chunks"]
    assert len(tr.spans("decode_tick")) == m["decode_steps"]
    occupants = [d["args"]["rids"] for d in tr.spans("decode_tick")]
    assert any(len(o) > 1 for o in occupants)   # batched ticks, one event

    reg = eng.last_registry
    assert reg.names() == set(CATALOG)
    assert reg.value("preemptions") == m["preemptions"]
    assert reg.value("swapin_pages_saved") == m["partial_swapin_pages_saved"]
    assert reg.gauge("blocks_in_use").peak == m["blocks_peak"]
    tel.feed.flush(m["wall_s"])
    assert tel.feed.energy_j_total == pytest.approx(m["energy_j"],
                                                    rel=1e-12)


def test_engine_compile_retrace_counter(family):
    eng = ENG.RealEngine(family, n_slots=2, max_len=48, ci_g_per_kwh=300.0)
    eng.configure(_graph())
    rng = np.random.default_rng(1)
    prompts = [rng.integers(0, CFG.vocab_size, size=L).astype(np.int32)
               for L in (4, 10, 24)]
    m = eng._serve_prompts(prompts, n_new=4)
    # warmup compiled every serve bucket: in-bucket traffic never retraces
    assert m["compile_retraces"] == 0
    assert eng.last_registry.value("compile_retraces") == 0

    class _Inst:                            # the counter itself, unit-level
        pass
    d = _Inst()
    d._shapes, d.retraces = {("decode",)}, 0
    ENG._note_shape(d, ("decode",))         # known shape: no retrace
    assert d.retraces == 0
    ENG._note_shape(d, ("prefill", 64))     # novel shape: counted once
    ENG._note_shape(d, ("prefill", 64))
    assert d.retraces == 1


# =============================================================================
# engine phase profiling + per-tick counter tracks + exporter parity
# =============================================================================
def test_engine_phase_profiling_and_counter_tracks(family, tmp_path):
    tel = _bundle("real-paged")
    eng = ENG.RealEngine(family, n_slots=2, max_len=48, kv_layout="paged",
                         block_size=8, max_seqs=4, n_blocks=20,
                         ci_g_per_kwh=300.0, telemetry=tel)
    eng.configure(_graph())
    rng = np.random.default_rng(3)
    prompts = [rng.integers(0, CFG.vocab_size, size=6).astype(np.int32)
               for _ in range(4)]
    eng._serve_prompts(prompts, n_new=8)
    reg = eng.last_registry
    assert reg.labels.get("kv_layout") == "paged"
    phases = {d["phase"]: h for _, d, h in
              reg.labeled_series("phase_latency_s")}
    assert {"prefill_chunk", "decode_dispatch", "decode_land"} <= set(phases)
    assert all(h.count > 0 and h.sum >= 0.0 for h in phases.values())
    # per-request slo_class children recorded alongside the parents
    assert any(d.get("slo_class") for _, d, _ in
               reg.labeled_series("latency_s"))
    # the chrome export carries the per-tick counter tracks
    ct = tmp_path / "t.json"
    tel.tracer.to_chrome_trace(str(ct))
    doc = json.loads(ct.read_text())
    counters = [e for e in doc["traceEvents"] if e["ph"] == "C"]
    by_name = {}
    for e in counters:
        by_name.setdefault(e["name"], []).append(e)
    assert {"blocks_in_use", "occupied_rows", "power_w"} <= set(by_name)
    # one sample per engine tick on every track, power always > 0 (the
    # idle floor), and occupancy actually moved during the session
    n_ticks = {n: len(v) for n, v in by_name.items()
               if n in ("blocks_in_use", "occupied_rows", "power_w")}
    assert len(set(n_ticks.values())) == 1
    assert all(next(iter(e["args"].values())) > 0.0
               for e in by_name["power_w"])
    occ = [next(iter(e["args"].values())) for e in by_name["occupied_rows"]]
    assert max(occ) > 0.0


def test_engine_detached_profiler_records_nothing(family):
    eng = ENG.RealEngine(family, n_slots=2, max_len=48, kv_layout="paged",
                         block_size=8, max_seqs=4, n_blocks=20,
                         ci_g_per_kwh=300.0)          # no telemetry bundle
    eng.configure(_graph())
    rng = np.random.default_rng(3)
    prompts = [rng.integers(0, CFG.vocab_size, size=6).astype(np.int32)
               for _ in range(2)]
    eng._serve_prompts(prompts, n_new=4)
    assert eng.profiler.registry is None
    assert list(eng.last_registry.labeled_series("phase_latency_s")) == []


def test_exporter_family_parity_includes_real_engine(family):
    from repro.obs import FleetRollup, parse_openmetrics, to_openmetrics
    from repro.serving.backends import FluidBackend

    def workload():
        return shaped_request_stream(6, 0.3, vocab_size=CFG.vocab_size,
                                     shape="peak", prompt_lens=(6, 10),
                                     n_new=4, seed=2)

    eng = ENG.RealEngine(family, n_slots=2, max_len=32, ci_g_per_kwh=300.0)
    eng.configure(_graph())
    serve_workload(eng, workload())
    des = Q.DESBackend(DES_G, VARIANTS, Q.DESConfig(jitter_sigma=0.0),
                       ci_g_per_kwh=300.0)
    serve_workload(des, workload())
    fluid = FluidBackend(DES_G, VARIANTS, sla_target_s=2.0, window_s=0.25,
                         ci_g_per_kwh=300.0)
    serve_workload(fluid, workload())

    regs = {"real": eng.last_registry, "des": des.registry,
            "fluid": fluid.registry}
    rollup = FleetRollup()
    for rname, reg in regs.items():
        rollup.add(reg, region=rname)
    sets = {rname: frozenset(parse_openmetrics(to_openmetrics(reg)))
            for rname, reg in {**regs, "fleet": rollup}.items()}
    assert len(set(sets.values())) == 1, \
        {a: sorted(sets[a] ^ sets["fleet"]) for a in sets}
    rollup.conservation(("energy_j", "carbon_g", "requests_served"))


# =============================================================================
# fleet: per-region feeds stream accountant-exact totals
# =============================================================================
def test_fleet_region_feeds_match_accounting():
    from repro.fleet import fleet_sim as FS
    traces = {r: CB.make_trace(r, hours=30.0, seed=2)
              for r in ("CISO-March", "ESO-March")}
    cfg = FS.FleetConfig(warmup_s=24 * 3600.0, n_jobs=2,
                         min_slack_s=2 * 3600.0, max_slack_s=4 * 3600.0,
                         plan_horizon_s=6 * 3600.0)
    rep = FS.run_fleet("efficientnet", traces, cfg)
    for name, r in rep.regions.items():
        assert r.feed_snapshots >= 1, name
        assert r.feed_energy_j == pytest.approx(r.energy_j, rel=1e-9), name
        assert r.feed_carbon_g == pytest.approx(r.carbon_g, rel=1e-9), name
    # the report ships a fleet rollup whose totals conserve bit-exactly
    # over the per-region registries and match the region reports
    assert rep.rollup is not None
    totals = rep.rollup.conservation(("energy_j", "carbon_g"))
    assert set(rep.rollup.regions) == set(traces)
    assert totals["energy_j"] == pytest.approx(
        sum(r.energy_j for r in rep.regions.values()), rel=1e-12)
    fleet = rep.rollup.merged()
    regions_seen = {d["region"] for _, d, _ in fleet.labeled_series()
                    if "region" in d}
    assert regions_seen == set(traces)
