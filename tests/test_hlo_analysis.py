"""Loop-aware HLO analyzer validation against hand-computable programs.

Runs in a subprocess where multiple host devices are needed (collective test);
the matmul trip-count test runs inline on 1 device.
"""
import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import pytest

from repro.launch import hlo_analysis as HA


def test_scan_matmul_flops_exact():
    def f(x, w):
        def body(x, _):
            return jnp.tanh(x @ w), None
        y, _ = jax.lax.scan(body, x, None, length=7)
        return y
    m = n = k = 64
    c = jax.jit(f).lower(jax.ShapeDtypeStruct((m, k), jnp.float32),
                         jax.ShapeDtypeStruct((k, n), jnp.float32)).compile()
    st = HA.analyze(c.as_text(), 1)
    assert st.dot_flops == pytest.approx(7 * 2 * m * n * k, rel=1e-6)


def test_nested_scan_flops_exact():
    def f(x, w):
        def outer(x, _):
            def inner(x, _):
                return x @ w, None
            y, _ = jax.lax.scan(inner, x, None, length=3)
            return y, None
        y, _ = jax.lax.scan(outer, x, None, length=5)
        return y
    c = jax.jit(f).lower(jax.ShapeDtypeStruct((32, 32), jnp.float32),
                         jax.ShapeDtypeStruct((32, 32), jnp.float32)).compile()
    st = HA.analyze(c.as_text(), 1)
    assert st.dot_flops == pytest.approx(15 * 2 * 32 ** 3, rel=1e-6)


def test_no_loop_dot():
    c = jax.jit(lambda a, b: a @ b).lower(
        jax.ShapeDtypeStruct((16, 8), jnp.float32),
        jax.ShapeDtypeStruct((8, 4), jnp.float32)).compile()
    st = HA.analyze(c.as_text(), 1)
    assert st.dot_flops == pytest.approx(2 * 16 * 8 * 4, rel=1e-6)


def test_collectives_in_scan_counted_with_trip(tmp_path):
    script = textwrap.dedent("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import jax, jax.numpy as jnp
        from jax.experimental.shard_map import shard_map
        from jax.sharding import PartitionSpec as P
        from repro.launch import hlo_analysis as HA
        mesh = jax.make_mesh((8,), ("d",))
        def g(x):
            def inner(x):
                def body(c, _):
                    s = jax.lax.psum(c, "d")
                    return c + 0 * s, s
                y, ys = jax.lax.scan(body, x, None, length=5)
                return y + ys.sum(0)
            return shard_map(inner, mesh=mesh, in_specs=P("d"), out_specs=P("d"))(x)
        c2 = jax.jit(g).lower(jax.ShapeDtypeStruct((8, 128), jnp.float32)).compile()
        st2 = HA.analyze(c2.as_text(), 8)
        expect = 5 * 2 * (7 / 8) * 128 * 4
        assert abs(st2.coll_bytes["all-reduce"] - expect) < 1e-6, st2.coll_bytes
        assert st2.coll_counts["all-reduce"] == 5
        print("SCENARIO OK")
    """)
    p = tmp_path / "coll.py"
    p.write_text(script)
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.abspath(
        os.path.join(os.path.dirname(__file__), "..", "src")) + os.pathsep + env.get("PYTHONPATH", "")
    out = subprocess.run([sys.executable, str(p)], capture_output=True,
                         text=True, timeout=300, env=env)
    assert out.returncode == 0, out.stderr
    assert "SCENARIO OK" in out.stdout
