"""Objective (Eq. 1-5), SA energy (Eq. 6-7), and annealing behaviour."""
import random

import pytest

from repro.core import annealing as SA
from repro.core import carbon as CB
from repro.core import catalog as CAT
from repro.core import config_graph as CG
from repro.core import objective as OBJ
from repro.core import schemes as SCH

VARIANTS = CAT.get_family("efficientnet")


def _obj(lam=0.1, a_base=0.843, c_base=1.0, l_tail=0.05):
    return OBJ.ObjectiveConfig(lam=lam, a_base=a_base, c_base=c_base,
                               l_tail_s=l_tail)


def test_fig6_preference_flips_with_carbon_intensity():
    """Paper Fig. 6: with λ=0.1, the low-energy config A wins at ci=500 but
    the high-accuracy config B wins at ci=100."""
    cfg = _obj(lam=0.1, c_base=1000.0)
    # synthetic EvalResults with the figure's numbers (E(x)·ci built in)
    A = OBJ.EvalResult(accuracy=0.96 * cfg.a_base, capacity_rps=10, rho=0.5,
                       p95_latency_s=0.01, power_w=0, energy_per_req_j=0.4 * 3.6e6 / cfg.pue)
    Bc = OBJ.EvalResult(accuracy=0.98 * cfg.a_base, capacity_rps=10, rho=0.5,
                        p95_latency_s=0.01, power_w=0, energy_per_req_j=1.2 * 3.6e6 / cfg.pue)
    f_A_hi = OBJ.objective_f(A, 500.0, cfg)
    f_B_hi = OBJ.objective_f(Bc, 500.0, cfg)
    f_A_lo = OBJ.objective_f(A, 100.0, cfg)
    f_B_lo = OBJ.objective_f(Bc, 100.0, cfg)
    assert f_A_hi > f_B_hi, "config A must win at high carbon intensity"
    assert f_B_lo > f_A_lo, "config B must win at low carbon intensity"
    # The paper's worked values: A@500 = 4.4, A@100 = 6.0, B@100 = 7.0 all
    # reproduce exactly from Eq. 3.  B@500 is printed as 3.2 in Fig. 6 but
    # Eq. 3 gives 0.1·40 + 0.9·(−2) = 2.2 — an arithmetic typo in the paper
    # (the preference ordering is unaffected); we assert the Eq.-3 value.
    assert abs(f_A_hi - 4.4) < 0.1 and abs(f_B_hi - 2.2) < 0.1
    assert abs(f_A_lo - 6.0) < 0.1 and abs(f_B_lo - 7.0) < 0.1


def test_delta_accuracy_nonpositive():
    cfg = _obj()
    g = SCH.base_config(SCH.SchemeContext("efficientnet", VARIANTS, 1, 10.0,
                                          cfg, SA.SAConfig(), random.Random(0)))
    res = OBJ.evaluate(g, VARIANTS, 10.0)
    assert OBJ.delta_accuracy(res.accuracy, cfg) <= 1e-9


def test_sa_energy_sla_scaling():
    cfg = _obj(l_tail=0.05)
    ok = OBJ.EvalResult(0.8, 10, 0.5, 0.04, 100, 10.0)
    bad = OBJ.EvalResult(0.8, 10, 0.5, 0.10, 100, 10.0)
    f_ok = OBJ.objective_f(ok, 300, cfg)
    assert OBJ.sa_energy(ok, 300, cfg) == pytest.approx(-f_ok)
    # violating config is scaled by L_tail/L (Eq. 6)
    assert OBJ.sa_energy(bad, 300, cfg) == pytest.approx(-OBJ.objective_f(bad, 300, cfg) * 0.5)


def test_accuracy_threshold_wall():
    cfg = _obj()
    cfg = OBJ.ObjectiveConfig(**{**cfg.__dict__, "max_accuracy_loss_pct": 0.5})
    res = OBJ.EvalResult(cfg.a_base * 0.95, 10, 0.5, 0.01, 100, 1.0)  # -5 %
    assert OBJ.objective_f(res, 300, cfg) < -1e5


def test_evaluate_monotone_in_quality():
    """Higher-quality uniform config ⇒ higher accuracy and higher energy."""
    prev_acc = prev_e = -1.0
    for v in VARIANTS:
        g = CG.ConfigGraph.uniform("efficientnet", v.name, 16, 2)
        r = OBJ.evaluate(g, VARIANTS, 10.0)
        assert r.accuracy > prev_acc
        assert r.energy_per_req_j > prev_e * 0.99
        prev_acc, prev_e = r.accuracy, r.energy_per_req_j


def test_annealing_improves_and_terminates():
    rng = random.Random(0)
    ctx = SCH.SchemeContext("efficientnet", VARIANTS, 2, 0.0, None,
                            SA.SAConfig(), rng)
    start = SCH.base_config(ctx)
    arrival = OBJ.evaluate(start, VARIANTS, 1e-9).capacity_rps * 0.7
    base_res = OBJ.evaluate(start, VARIANTS, arrival)
    obj = OBJ.ObjectiveConfig(lam=0.1, a_base=base_res.accuracy,
                              c_base=base_res.carbon_per_req_g(380.0),
                              l_tail_s=base_res.p95_latency_s)
    out = SA.anneal(start, VARIANTS, lambda g: OBJ.evaluate(g, VARIANTS, arrival),
                    ci=300.0, obj_cfg=obj, rng=rng)
    f_start = OBJ.objective_f(base_res, 300.0, obj)
    assert out.best_f >= f_start, "SA must not end below the start"
    assert out.best_f > f_start + 1.0, "SA should find real carbon savings"
    assert out.duration_s <= SA.SAConfig().time_limit_s + 1e-9
    assert out.n_evals >= 2
    # best config meets SLA
    best_res = OBJ.evaluate(out.best, VARIANTS, arrival)
    assert OBJ.meets_sla(best_res, obj)


def test_annealing_warm_start_converges_faster():
    rng = random.Random(1)
    ctx = SCH.SchemeContext("efficientnet", VARIANTS, 2, 0.0, None,
                            SA.SAConfig(), rng)
    start = SCH.base_config(ctx)
    arrival = OBJ.evaluate(start, VARIANTS, 1e-9).capacity_rps * 0.7
    base_res = OBJ.evaluate(start, VARIANTS, arrival)
    obj = OBJ.ObjectiveConfig(lam=0.1, a_base=base_res.accuracy,
                              c_base=base_res.carbon_per_req_g(380.0),
                              l_tail_s=base_res.p95_latency_s)
    ev = lambda g: OBJ.evaluate(g, VARIANTS, arrival)
    first = SA.anneal(start, VARIANTS, ev, 300.0, obj, rng=rng)
    second = SA.anneal(first.best, VARIANTS, ev, 310.0, obj, rng=rng)
    assert second.best_f >= first.best_f - 1e-6
