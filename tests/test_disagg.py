"""Prefill/decode disaggregated serving (serving.disagg) and the mesh
plumbing underneath it: RealEngine(roles=...) dispatch, handoff token
parity with the monolithic engine, per-role energy conservation,
decode-side preemption after handoff, the paged-arena sharding rule's
explicit non-divisible error, and make_mesh_for sizing.

Single-device tier-1 coverage; the 8-host-device parity scenarios live in
multidev_scenarios.py (sharded_paged_decode_parity / disagg_vs_monolithic
/ disagg_smoke) and the carbon/throughput acceptance numbers in the
``disagg_serving`` bench stage.
"""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.core import config_graph as CG
from repro.launch.mesh import make_mesh_for
from repro.obs.validate import check_disagg_conservation
from repro.serving import engine as ENG
from repro.serving.api import InferenceRequest, serve_workload
from repro.serving.disagg import BlockHandoff, DisaggEngine
from repro.sharding import rules as SR

CFG = get_smoke_config("qwen3-1.7b").with_(n_layers=2, dtype=jnp.float32)


@pytest.fixture(scope="module")
def family():
    return ENG.build_engine_family(CFG, fracs=(1.0,))


def _graph():
    return CG.ConfigGraph.from_dict(CFG.name, {("x1", 16): 1})


def _prompts(lens, seed=3):
    rng = np.random.default_rng(seed)
    return [rng.integers(0, CFG.vocab_size, size=L).astype(np.int32)
            for L in lens]


def _requests(prompts, n_new=6, **kw):
    return [InferenceRequest(rid=i, prompt=p, max_new_tokens=n_new, **kw)
            for i, p in enumerate(prompts)]


class _FakeMesh:
    """arena_spec only reads mesh.shape — enough to unit-test the rule on a
    one-device box (real meshes are exercised in multidev_scenarios.py)."""

    def __init__(self, **axes):
        self.shape = dict(axes)


# =============================================================================
# construction / dispatch
# =============================================================================
def test_roles_kwarg_builds_disagg_engine(family):
    eng = ENG.RealEngine(family, n_slots=2, max_len=32, kv_layout="paged",
                         roles={"prefill": 1, "decode": 1})
    assert isinstance(eng, DisaggEngine)
    assert eng.roles == {"prefill": 1, "decode": 1}
    # tuple shorthand normalizes; roles=None stays a plain RealEngine
    eng2 = ENG.RealEngine(family, kv_layout="paged", roles=(2, 1))
    assert isinstance(eng2, DisaggEngine) and eng2.roles["prefill"] == 2
    mono = ENG.RealEngine(family, kv_layout="paged", roles=None)
    assert type(mono) is ENG.RealEngine
    with pytest.raises(AssertionError):
        ENG.RealEngine(family, kv_layout="slotted", roles=(1, 1))
    with pytest.raises(AssertionError):
        ENG.RealEngine(family, kv_layout="paged", roles={"prefill": 1})


def test_configure_builds_role_split_workers(family):
    eng = ENG.RealEngine(family, n_slots=2, max_len=32, kv_layout="paged",
                         roles={"prefill": 2, "decode": 1})
    eng.configure(_graph())
    roles = sorted(i.role for i in eng.instances)
    assert roles == ["decode", "prefill", "prefill"]
    # role profilers are distinct and role-tagged (phase latency splits
    # prefill-pool vs decode-pool in the exposition)
    assert eng.profilers["prefill"].role == "prefill"
    assert eng.profilers["decode"].role == "decode"
    for inst in eng.instances:
        assert inst.profiler is eng.profilers[inst.role]


# =============================================================================
# token parity + conservation
# =============================================================================
def test_disagg_token_parity_and_role_conservation(family):
    prompts = _prompts((7, 13, 5, 9, 11, 6), seed=0)

    mono = ENG.RealEngine(family, n_slots=2, max_len=32, kv_layout="paged")
    mono.configure(_graph())
    rm = {r.rid: r for r in serve_workload(mono, _requests(prompts))}
    sm = mono.stats()

    dis = ENG.RealEngine(family, n_slots=2, max_len=32, kv_layout="paged",
                         roles={"prefill": 1, "decode": 1})
    dis.configure(_graph())
    rd = {r.rid: r for r in serve_workload(dis, _requests(prompts))}
    sd = dis.stats()

    for rid in rm:
        np.testing.assert_array_equal(rm[rid].tokens, rd[rid].tokens)

    # every request was handed off exactly once, pages moved with them
    assert sd["handoffs"] == len(prompts)
    assert sd["handoff_pages"] >= len(prompts)
    assert sm["handoffs"] == 0 and sm["handoff_pages"] == 0

    # per-role joules: disagg splits, monolithic carries "both"; both
    # shapes conserve against the session total exactly
    check_disagg_conservation(sd)
    check_disagg_conservation(sm)
    assert sd["prefill_energy_j"] > 0 and sd["decode_energy_j"] > 0
    assert sd["handoff_energy_j"] > 0 and sd["both_energy_j"] == 0.0
    assert sm["both_energy_j"] == sm["energy_j"]
    assert sm["prefill_energy_j"] == sm["decode_energy_j"] == 0.0

    # per-response role split sums to each response's energy_j
    for r in rd.values():
        assert set(r.energy_by_role) <= {"prefill", "decode", "handoff"}
        assert sum(r.energy_by_role.values()) == \
            pytest.approx(r.energy_j, rel=1e-9)
    for r in rm.values():
        assert set(r.energy_by_role) == {"both"}
        assert r.energy_by_role["both"] == pytest.approx(r.energy_j,
                                                         rel=1e-9)


def test_disagg_decode_preemption_token_identical(family):
    """Decode-side preemption after handoff: a starved decode arena swaps
    victims out and restores them bit-exactly — outputs match a monolithic
    engine with a roomy arena (preemption- AND handoff-invariance)."""
    prompts = _prompts((6, 6, 6, 6), seed=5)
    n_new = 20

    ref = ENG.RealEngine(family, n_slots=2, max_len=48, kv_layout="paged",
                         block_size=8, max_seqs=4, n_blocks=33)
    ref.configure(_graph())
    ref._serve_prompts(prompts, n_new=n_new)
    assert ref.stats()["preemptions"] == 0

    eng = ENG.RealEngine(family, n_slots=2, max_len=48, kv_layout="paged",
                         block_size=8, max_seqs=4, n_blocks=9,
                         preemption=True, prefix_caching=False,
                         roles={"prefill": 1, "decode": 1})
    eng.configure(_graph())
    responses = serve_workload(eng, _requests(prompts, n_new=n_new))
    m = eng.stats()
    assert m["preemptions"] >= 1, "starved decode arena must preempt"
    assert m["handoffs"] == len(prompts)
    assert m["served"] == len(prompts)
    for rid, toks in ref.last_outputs.items():
        np.testing.assert_array_equal(toks, eng.last_outputs[rid])
    # handoffs are planned swaps: they never count as preemptions
    assert sum(r.preemptions for r in responses) == m["preemptions"]
    check_disagg_conservation(m)
    # full reclamation on every worker after the churn
    for inst in eng.instances:
        inst.alloc.check()
        assert inst.alloc.num_free == inst.alloc.num_allocatable


def test_handoff_stage_requires_landed_first_token(family):
    eng = ENG.RealEngine(family, n_slots=2, max_len=32, kv_layout="paged",
                         roles=(1, 1))
    eng.configure(_graph())
    pre = next(i for i in eng.instances if i.role == "prefill")
    eng.submit(InferenceRequest(rid=0, prompt=_prompts((7,))[0],
                                max_new_tokens=4))
    # step until the prefill worker holds the sequence mid-prefill or with
    # its first token still in flight — staging then must be refused
    eng.step()
    seqs = [q for q in pre.rows if q is not None]
    if seqs and not (seqs[0].prefilled and seqs[0].pending_first is None):
        with pytest.raises(AssertionError):
            BlockHandoff.stage(pre, seqs[0])
    eng.drain()
    assert eng.stats()["handoffs"] == 1


# =============================================================================
# sharding rules + mesh helpers (unit; real meshes in multidev scenarios)
# =============================================================================
def test_arena_spec_explicit_error_on_non_divisible_heads():
    from jax.sharding import PartitionSpec as P
    glm4 = get_smoke_config("glm4-9b")          # n_kv_heads=2, GQA
    assert glm4.n_kv_heads == 2
    # divisible: KV heads shard over model, block-map dims stay host-side
    assert SR.arena_spec(_FakeMesh(data=4, model=2), glm4) == \
        P(None, None, None, "model", None)
    # model axis 1: fully replicated (the single-device serving path)
    assert SR.arena_spec(_FakeMesh(data=8, model=1), glm4) == \
        P(None, None, None, None, None)
    # non-divisible: an explicit error, not silent GSPMD padding
    with pytest.raises(ValueError, match="n_kv_heads"):
        SR.arena_spec(_FakeMesh(data=2, model=4), glm4)


def test_make_mesh_for_sizing_and_errors():
    mesh = make_mesh_for(1)
    assert mesh.axis_names == ("data", "model")
    assert mesh.shape["data"] == 1 and mesh.shape["model"] == 1
    with pytest.raises(ValueError, match="does not divide"):
        make_mesh_for(8, model_parallel=3)
    with pytest.raises(ValueError, match="does not divide"):
        make_mesh_for(4, model_parallel=8)


def test_single_device_mesh_paged_parity(family):
    """mesh= on a 1-device mesh runs the whole sharded-arena code path
    (committed arena, sharded params cache, row placement) and must be
    token-identical to the unsharded engine."""
    prompts = _prompts((7, 13, 5), seed=2)
    mono = ENG.RealEngine(family, n_slots=2, max_len=32, kv_layout="paged")
    mono.configure(_graph())
    rm = {r.rid: r for r in serve_workload(mono, _requests(prompts))}

    eng = ENG.RealEngine(family, n_slots=2, max_len=32, kv_layout="paged",
                         mesh=make_mesh_for(1))
    eng.configure(_graph())
    rs = {r.rid: r for r in serve_workload(eng, _requests(prompts))}
    for rid in rm:
        np.testing.assert_array_equal(rm[rid].tokens, rs[rid].tokens)
