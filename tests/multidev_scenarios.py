"""Multi-device test scenarios, run in a subprocess with
XLA_FLAGS=--xla_force_host_platform_device_count=8 (set by the parent BEFORE
jax initializes — conftest deliberately leaves the main process at 1 device).

Each function prints "SCENARIO OK" on success; test_multidev.py asserts it.
"""
import os
import sys

if __name__ == "__main__":
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"

import numpy as np


def scenario_lower_all_smoke_shapes():
    import jax
    import jax.numpy as jnp
    from repro.configs import get_smoke_config
    import repro.configs.shapes as SH
    SH.SHAPES = {
        "train_4k": SH.ShapeCell("train_4k", 64, 8, "train"),
        "prefill_32k": SH.ShapeCell("prefill_32k", 128, 4, "prefill"),
        "decode_32k": SH.ShapeCell("decode_32k", 128, 8, "decode"),
        "long_500k": SH.ShapeCell("long_500k", 256, 1, "decode"),
    }
    from repro.launch import steps
    mesh = jax.make_mesh((2, 2, 2), ("pod", "data", "model"))
    archs = ["qwen3-moe-30b-a3b", "gemma3-27b", "mamba2-2.7b", "zamba2-2.7b",
             "seamless-m4t-large-v2", "qwen2-vl-7b"]
    with mesh:
        for arch in archs:
            cfg = get_smoke_config(arch).with_(dtype=jnp.bfloat16)
            for shape in SH.SHAPES:
                jitted, sds = steps.build_step_for_cell(cfg, mesh, shape)
                compiled = jitted.lower(*sds).compile()
                assert compiled.memory_analysis().temp_size_in_bytes >= 0


def scenario_ddp_compressed_training():
    import jax
    import jax.numpy as jnp
    from repro.configs import get_smoke_config
    from repro.models import registry as R
    from repro.train import optimizer as O
    from repro.train import train_loop as TL
    from repro.train import data as DATA
    cfg = get_smoke_config("qwen2-0.5b").with_(
        n_layers=2, d_model=32, n_heads=4, n_kv_heads=2, d_head=8, d_ff=64,
        vocab_size=64, dtype=jnp.float32)
    mesh = jax.make_mesh((8,), ("data",))
    params = R.init_params(jax.random.PRNGKey(0), cfg)
    opt_cfg = O.AdamWConfig(lr=1e-3)
    ds = DATA.SyntheticLM(DATA.DataConfig(cfg.vocab_size, 16, 16))
    batch = {k: jnp.asarray(v) for k, v in ds.batch_at(0).items()}

    results = {}
    for comp in (None, "bf16", "int8"):
        state = {"params": R.init_params(jax.random.PRNGKey(0), cfg),
                 "opt": O.init_opt_state(params)}
        step = TL.make_ddp_train_step(cfg, opt_cfg, mesh, compressor=comp)
        with mesh:
            state, m = step(state, batch)
        results[comp] = (float(m["loss"]),
                         [np.asarray(x) for x in jax.tree.leaves(state["params"])])
    # compressed training must track f32 within tolerance after one step
    for comp in ("bf16", "int8"):
        assert abs(results[comp][0] - results[None][0]) < 1e-2
        for a, b in zip(results[comp][1], results[None][1]):
            np.testing.assert_allclose(a, b, rtol=0.1, atol=2e-3)


def scenario_elastic_checkpoint_restore():
    """Save on a (2,4) mesh layout, restore onto a (8,) mesh — device-count
    elasticity through the checkpoint path."""
    import tempfile
    import jax
    import jax.numpy as jnp
    from jax.sharding import NamedSharding, PartitionSpec as P
    from repro.configs import get_smoke_config
    from repro.models import registry as R
    from repro.sharding import rules
    from repro.train import checkpoint as CKPT
    cfg = get_smoke_config("qwen3-1.7b").with_(dtype=jnp.float32)
    mesh_a = jax.make_mesh((2, 4), ("data", "model"))
    params = R.init_params(jax.random.PRNGKey(0), cfg)
    specs = rules.param_specs(jax.eval_shape(lambda: params), cfg, mesh_a)
    sharded = jax.tree.map(
        lambda x, s: jax.device_put(x, NamedSharding(mesh_a, s)), params, specs)
    with tempfile.TemporaryDirectory() as d:
        CKPT.save({"params": sharded}, 1, d)
        mesh_b = jax.make_mesh((8,), ("model",))
        specs_b = rules.param_specs(jax.eval_shape(lambda: params), cfg, mesh_b)
        shards_b = jax.tree.map(lambda s: NamedSharding(mesh_b, s), specs_b)
        restored = CKPT.restore(d, {"params": jax.eval_shape(lambda: params)},
                                shardings={"params": shards_b})
        for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(restored["params"])):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def scenario_gspmd_vs_single_device_numerics():
    """The sharded train step computes the same loss as single-device."""
    import jax
    import jax.numpy as jnp
    from repro.configs import get_smoke_config
    import repro.configs.shapes as SH
    SH.SHAPES = {"train_4k": SH.ShapeCell("train_4k", 32, 8, "train")}
    from repro.launch import steps
    from repro.models import registry as R
    from repro.train import optimizer as O, train_loop as TL, data as DATA
    cfg = get_smoke_config("qwen3-1.7b").with_(dtype=jnp.float32)
    mesh = jax.make_mesh((2, 2, 2), ("pod", "data", "model"))
    opt_cfg = O.AdamWConfig()
    params = R.init_params(jax.random.PRNGKey(0), cfg)
    state = TL.make_train_state(params, opt_cfg)
    ds = DATA.SyntheticLM(DATA.DataConfig(cfg.vocab_size, 32, 8))
    batch = {k: jnp.asarray(v) for k, v in ds.batch_at(0).items()}

    loss_1dev = float(TL.lm_loss(params, batch, cfg)[0])
    with mesh:
        jitted, _ = steps.build_train(cfg, mesh, "train_4k", opt_cfg=opt_cfg,
                                      accum=1)
        new_state, metrics = jitted(state, batch)
        loss_sharded = float(metrics["loss"])
    assert abs(loss_sharded - loss_1dev) / loss_1dev < 5e-4, \
        (loss_sharded, loss_1dev)


def scenario_seq_sharded_decode_numerics():
    """Sequence-sharded KV decode == single-device decode logits."""
    import jax
    import jax.numpy as jnp
    from jax.sharding import NamedSharding, PartitionSpec as P
    from repro.configs import get_smoke_config
    from repro.models import registry as R
    from repro.sharding import rules
    cfg = get_smoke_config("glm4-9b").with_(dtype=jnp.float32)
    params = R.init_params(jax.random.PRNGKey(0), cfg)
    cache = R.make_cache(params, cfg, 2, 64, dtype=jnp.float32)
    toks = jnp.array([[3], [5]], dtype=jnp.int32)
    ref_logits, _ = R.decode_step(params, cache, {"tokens": toks}, cfg)

    mesh = jax.make_mesh((2, 4), ("data", "model"))
    cache_specs = rules.cache_specs(jax.eval_shape(lambda: cache), mesh, cfg,
                                    seq_shard=True)
    with mesh:
        p_specs = rules.param_specs(jax.eval_shape(lambda: params), cfg, mesh)
        ps = jax.tree.map(lambda x, s: jax.device_put(x, NamedSharding(mesh, s)),
                          params, p_specs)
        cs = jax.tree.map(lambda x, s: jax.device_put(x, NamedSharding(mesh, s)),
                          cache, cache_specs)
        logits, _ = jax.jit(lambda p, c, t: R.decode_step(p, c, {"tokens": t}, cfg))(
            ps, cs, toks)
    np.testing.assert_allclose(np.asarray(ref_logits), np.asarray(logits),
                               rtol=2e-4, atol=2e-4)


if __name__ == "__main__":
    name = sys.argv[1]
    globals()[f"scenario_{name}"]()
    print("SCENARIO OK")
