"""Multi-device test scenarios, run in a subprocess with
XLA_FLAGS=--xla_force_host_platform_device_count=8 (set by the parent BEFORE
jax initializes — conftest deliberately leaves the main process at 1 device).

Each function prints "SCENARIO OK" on success; test_multidev.py asserts it.
"""
import os
import sys

if __name__ == "__main__":
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"

import numpy as np


def scenario_lower_all_smoke_shapes():
    import jax
    import jax.numpy as jnp
    from repro.configs import get_smoke_config
    import repro.configs.shapes as SH
    SH.SHAPES = {
        "train_4k": SH.ShapeCell("train_4k", 64, 8, "train"),
        "prefill_32k": SH.ShapeCell("prefill_32k", 128, 4, "prefill"),
        "decode_32k": SH.ShapeCell("decode_32k", 128, 8, "decode"),
        "long_500k": SH.ShapeCell("long_500k", 256, 1, "decode"),
    }
    from repro.launch import steps
    mesh = jax.make_mesh((2, 2, 2), ("pod", "data", "model"))
    archs = ["qwen3-moe-30b-a3b", "gemma3-27b", "mamba2-2.7b", "zamba2-2.7b",
             "seamless-m4t-large-v2", "qwen2-vl-7b"]
    with mesh:
        for arch in archs:
            cfg = get_smoke_config(arch).with_(dtype=jnp.bfloat16)
            for shape in SH.SHAPES:
                jitted, sds = steps.build_step_for_cell(cfg, mesh, shape)
                compiled = jitted.lower(*sds).compile()
                assert compiled.memory_analysis().temp_size_in_bytes >= 0


def scenario_ddp_compressed_training():
    import jax
    import jax.numpy as jnp
    from repro.configs import get_smoke_config
    from repro.models import registry as R
    from repro.train import optimizer as O
    from repro.train import train_loop as TL
    from repro.train import data as DATA
    cfg = get_smoke_config("qwen2-0.5b").with_(
        n_layers=2, d_model=32, n_heads=4, n_kv_heads=2, d_head=8, d_ff=64,
        vocab_size=64, dtype=jnp.float32)
    mesh = jax.make_mesh((8,), ("data",))
    params = R.init_params(jax.random.PRNGKey(0), cfg)
    opt_cfg = O.AdamWConfig(lr=1e-3)
    ds = DATA.SyntheticLM(DATA.DataConfig(cfg.vocab_size, 16, 16))
    batch = {k: jnp.asarray(v) for k, v in ds.batch_at(0).items()}

    results = {}
    for comp in (None, "bf16", "int8"):
        state = {"params": R.init_params(jax.random.PRNGKey(0), cfg),
                 "opt": O.init_opt_state(params)}
        step = TL.make_ddp_train_step(cfg, opt_cfg, mesh, compressor=comp)
        with mesh:
            state, m = step(state, batch)
        results[comp] = (float(m["loss"]),
                         [np.asarray(x) for x in jax.tree.leaves(state["params"])])
    # compressed training must track f32 within tolerance after one step
    for comp in ("bf16", "int8"):
        assert abs(results[comp][0] - results[None][0]) < 1e-2
        for a, b in zip(results[comp][1], results[None][1]):
            np.testing.assert_allclose(a, b, rtol=0.1, atol=2e-3)


def scenario_elastic_checkpoint_restore():
    """Save on a (2,4) mesh layout, restore onto a (8,) mesh — device-count
    elasticity through the checkpoint path."""
    import tempfile
    import jax
    import jax.numpy as jnp
    from jax.sharding import NamedSharding, PartitionSpec as P
    from repro.configs import get_smoke_config
    from repro.models import registry as R
    from repro.sharding import rules
    from repro.train import checkpoint as CKPT
    cfg = get_smoke_config("qwen3-1.7b").with_(dtype=jnp.float32)
    mesh_a = jax.make_mesh((2, 4), ("data", "model"))
    params = R.init_params(jax.random.PRNGKey(0), cfg)
    specs = rules.param_specs(jax.eval_shape(lambda: params), cfg, mesh_a)
    sharded = jax.tree.map(
        lambda x, s: jax.device_put(x, NamedSharding(mesh_a, s)), params, specs)
    with tempfile.TemporaryDirectory() as d:
        CKPT.save({"params": sharded}, 1, d)
        mesh_b = jax.make_mesh((8,), ("model",))
        specs_b = rules.param_specs(jax.eval_shape(lambda: params), cfg, mesh_b)
        shards_b = jax.tree.map(lambda s: NamedSharding(mesh_b, s), specs_b)
        restored = CKPT.restore(d, {"params": jax.eval_shape(lambda: params)},
                                shardings={"params": shards_b})
        for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(restored["params"])):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def scenario_gspmd_vs_single_device_numerics():
    """The sharded train step computes the same loss as single-device."""
    import jax
    import jax.numpy as jnp
    from repro.configs import get_smoke_config
    import repro.configs.shapes as SH
    SH.SHAPES = {"train_4k": SH.ShapeCell("train_4k", 32, 8, "train")}
    from repro.launch import steps
    from repro.models import registry as R
    from repro.train import optimizer as O, train_loop as TL, data as DATA
    cfg = get_smoke_config("qwen3-1.7b").with_(dtype=jnp.float32)
    mesh = jax.make_mesh((2, 2, 2), ("pod", "data", "model"))
    opt_cfg = O.AdamWConfig()
    params = R.init_params(jax.random.PRNGKey(0), cfg)
    state = TL.make_train_state(params, opt_cfg)
    ds = DATA.SyntheticLM(DATA.DataConfig(cfg.vocab_size, 32, 8))
    batch = {k: jnp.asarray(v) for k, v in ds.batch_at(0).items()}

    loss_1dev = float(TL.lm_loss(params, batch, cfg)[0])
    with mesh:
        jitted, _ = steps.build_train(cfg, mesh, "train_4k", opt_cfg=opt_cfg,
                                      accum=1)
        new_state, metrics = jitted(state, batch)
        loss_sharded = float(metrics["loss"])
    assert abs(loss_sharded - loss_1dev) / loss_1dev < 5e-4, \
        (loss_sharded, loss_1dev)


def scenario_seq_sharded_decode_numerics():
    """Sequence-sharded KV decode == single-device decode logits."""
    import jax
    import jax.numpy as jnp
    from jax.sharding import NamedSharding, PartitionSpec as P
    from repro.configs import get_smoke_config
    from repro.models import registry as R
    from repro.sharding import rules
    cfg = get_smoke_config("glm4-9b").with_(dtype=jnp.float32)
    params = R.init_params(jax.random.PRNGKey(0), cfg)
    cache = R.make_cache(params, cfg, 2, 64, dtype=jnp.float32)
    toks = jnp.array([[3], [5]], dtype=jnp.int32)
    ref_logits, _ = R.decode_step(params, cache, {"tokens": toks}, cfg)

    mesh = jax.make_mesh((2, 4), ("data", "model"))
    cache_specs = rules.cache_specs(jax.eval_shape(lambda: cache), mesh, cfg,
                                    seq_shard=True)
    with mesh:
        p_specs = rules.param_specs(jax.eval_shape(lambda: params), cfg, mesh)
        ps = jax.tree.map(lambda x, s: jax.device_put(x, NamedSharding(mesh, s)),
                          params, p_specs)
        cs = jax.tree.map(lambda x, s: jax.device_put(x, NamedSharding(mesh, s)),
                          cache, cache_specs)
        logits, _ = jax.jit(lambda p, c, t: R.decode_step(p, c, {"tokens": t}, cfg))(
            ps, cs, toks)
    np.testing.assert_allclose(np.asarray(ref_logits), np.asarray(logits),
                               rtol=2e-4, atol=2e-4)


def _paged_workload(cfg, lens, seed=0, shared=12):
    rng = np.random.default_rng(seed)
    pre = rng.integers(0, cfg.vocab_size, size=shared).astype(np.int32)
    return [np.concatenate(
        [pre, rng.integers(0, cfg.vocab_size, size=int(n)).astype(np.int32)])
        for n in lens]


def scenario_sharded_paged_decode_parity():
    """One PagedInstance sharded across a ("data","model")=(4,2) mesh of 8
    host devices (params under spec_for_param, KV arena over "model" on the
    head dim, block tables host-side) is token-identical to the unsharded
    engine on a mixed-length shared-prefix workload — radix prefix sharing
    and the pipelined fused decode loop run unchanged on the sharded arena.
    Also pins the arena rule's explicit non-divisible error on a real mesh
    (glm4-like n_kv_heads=2 on a 4-way model axis)."""
    import jax.numpy as jnp
    from repro.configs import get_smoke_config
    from repro.core import config_graph as CG
    from repro.launch.mesh import make_mesh_for
    from repro.serving import engine as ENG
    from repro.sharding import rules as SR

    cfg = get_smoke_config("qwen3-1.7b").with_(n_layers=2, dtype=jnp.float32)
    fam = ENG.build_engine_family(cfg, fracs=(1.0,))
    g = CG.ConfigGraph.from_dict(cfg.name, {("x1", 16): 1})
    prompts = _paged_workload(cfg, (6, 14, 9, 22, 6, 11), seed=1)

    ref = ENG.RealEngine(fam, n_slots=4, max_len=64, kv_layout="paged",
                         block_size=8, max_seqs=4)
    ref.configure(g)
    m_ref = ref._serve_prompts(prompts, n_new=10)

    mesh = make_mesh_for(8, model_parallel=2)    # n_kv_heads=2 → divisible
    assert mesh.shape["data"] == 4 and mesh.shape["model"] == 2
    eng = ENG.RealEngine(fam, n_slots=4, max_len=64, kv_layout="paged",
                         block_size=8, max_seqs=4, mesh=mesh)
    eng.configure(g)
    m = eng._serve_prompts(prompts, n_new=10)
    assert set(ref.last_outputs) == set(eng.last_outputs)
    for rid in ref.last_outputs:
        np.testing.assert_array_equal(ref.last_outputs[rid],
                                      eng.last_outputs[rid])
    assert m["prefix_hit_tokens"] == m_ref["prefix_hit_tokens"] > 0
    # the arena really is committed over "model" (not replicated)
    inst = eng.instances[0]
    assert not inst.arena["k"].sharding.is_fully_replicated

    glm4 = get_smoke_config("glm4-9b")           # n_kv_heads=2
    try:
        SR.arena_spec(make_mesh_for(8, model_parallel=4), glm4)
    except ValueError as e:
        assert "n_kv_heads" in str(e)
    else:
        raise AssertionError("non-divisible arena sharding must error")


def scenario_disagg_vs_monolithic_parity():
    """Disaggregated prefill/decode workers on the 8-device mesh match the
    monolithic engine bit-for-bit — INCLUDING through decode-side
    preemption and partial (radix-tree-backed) swap-in on the decode
    worker — and the per-role joules split conserves exactly."""
    import jax.numpy as jnp
    from repro.configs import get_smoke_config
    from repro.core import config_graph as CG
    from repro.launch.mesh import make_mesh_for
    from repro.obs.validate import check_disagg_conservation
    from repro.serving import engine as ENG
    from repro.serving.api import InferenceRequest, serve_workload

    cfg = get_smoke_config("qwen3-1.7b").with_(n_layers=2, dtype=jnp.float32)
    fam = ENG.build_engine_family(cfg, fracs=(1.0,))
    g = CG.ConfigGraph.from_dict(cfg.name, {("x1", 16): 1})
    prompts = _paged_workload(cfg, (6, 6, 6, 6), seed=5, shared=16)
    n_new = 16

    ref = ENG.RealEngine(fam, n_slots=4, max_len=48, kv_layout="paged",
                         block_size=8, max_seqs=4, n_blocks=33)
    ref.configure(g)
    ref._serve_prompts(prompts, n_new=n_new)
    assert ref.stats()["preemptions"] == 0

    eng = ENG.RealEngine(fam, n_slots=4, max_len=48, kv_layout="paged",
                         block_size=8, max_seqs=4, n_blocks=14,
                         preemption=True, mesh=make_mesh_for(8, 2),
                         roles={"prefill": 1, "decode": 1})
    eng.configure(g)
    reqs = [InferenceRequest(rid=i, prompt=p, max_new_tokens=n_new)
            for i, p in enumerate(prompts)]
    serve_workload(eng, reqs)
    m = eng.stats()
    assert m["handoffs"] == len(prompts)
    assert m["preemptions"] >= 1, "starved decode arena must preempt"
    assert (m["swapin_pages_copied"]
            + m["partial_swapin_pages_saved"]) >= 1, "no swap-in happened"
    assert m["partial_swapin_pages_saved"] >= 1, \
        "decode-side radix tree must make the swap-in partial"
    for rid in ref.last_outputs:
        np.testing.assert_array_equal(ref.last_outputs[rid],
                                      eng.last_outputs[rid])
    check_disagg_conservation(m)
    assert m["prefill_energy_j"] > 0 and m["decode_energy_j"] > 0


def scenario_disagg_smoke():
    """Fast 8-device disagg smoke for scripts/check.sh: sharded split
    workers serve a tiny workload, hand off every sequence, and conserve
    the role energy split."""
    import jax.numpy as jnp
    from repro.configs import get_smoke_config
    from repro.core import config_graph as CG
    from repro.launch.mesh import make_mesh_for
    from repro.obs.validate import check_disagg_conservation
    from repro.serving import engine as ENG

    cfg = get_smoke_config("qwen3-1.7b").with_(n_layers=2, dtype=jnp.float32)
    fam = ENG.build_engine_family(cfg, fracs=(1.0,))
    eng = ENG.RealEngine(fam, n_slots=2, max_len=32, kv_layout="paged",
                         mesh=make_mesh_for(8, model_parallel=2),
                         roles=(1, 1))
    eng.configure(CG.ConfigGraph.from_dict(cfg.name, {("x1", 16): 1}))
    prompts = _paged_workload(cfg, (5, 9, 7), seed=0, shared=0)
    m = eng._serve_prompts(prompts, n_new=4)
    assert m["served"] == len(prompts)
    assert m["handoffs"] == len(prompts)
    check_disagg_conservation(m)


if __name__ == "__main__":
    name = sys.argv[1]
    globals()[f"scenario_{name}"]()
    print("SCENARIO OK")
