"""Configuration-graph invariants (paper §4.2) — unit + hypothesis property
tests: GED metric properties, neighbor-move soundness, additivity, catalog."""
import random

import pytest

hypothesis = pytest.importorskip(
    "hypothesis", reason="property tests need the optional 'hypothesis' dep "
                         "(see requirements-dev.txt)")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core import catalog as CAT
from repro.core import config_graph as CG
from repro.core import slices as SL

VARIANTS = CAT.get_family("efficientnet")
VNAMES = [v.name for v in VARIANTS]


def graph_strategy(max_blocks=3):
    """Random valid configuration graphs."""
    @st.composite
    def _g(draw):
        rng = random.Random(draw(st.integers(0, 10_000)))
        n_blocks = draw(st.integers(1, max_blocks))
        return CG.random_config("efficientnet", VARIANTS, n_blocks, rng), n_blocks
    return _g()


def test_partition_catalog():
    cat = SL.partition_catalog()
    assert len(cat) == 36
    assert all(sum(p) == 16 for p in cat)
    assert all(set(p) <= set(SL.SLICE_SIZES) for p in cat)
    assert (16,) in cat and (1,) * 16 in cat
    # catalog indices are stable (C1-style references in benchmarks)
    assert SL.config_number((16,)) == 0


@given(graph_strategy())
@settings(max_examples=40, deadline=None)
def test_random_config_valid(gn):
    g, n_blocks = gn
    assert g.is_valid(n_blocks, VARIANTS)
    assert g.total_chips == n_blocks * SL.BLOCK_CHIPS


@given(graph_strategy(), graph_strategy())
@settings(max_examples=40, deadline=None)
def test_ged_metric_properties(gn1, gn2):
    g1, _ = gn1
    g2, _ = gn2
    assert CG.ged(g1, g1) == 0
    assert CG.ged(g1, g2) == CG.ged(g2, g1)
    assert CG.ged(g1, g2) >= 0


@given(graph_strategy(), graph_strategy(), graph_strategy())
@settings(max_examples=25, deadline=None)
def test_ged_triangle_inequality(a, b, c):
    g1, g2, g3 = a[0], b[0], c[0]
    assert CG.ged(g1, g3) <= CG.ged(g1, g2) + CG.ged(g2, g3)


def test_ged_paper_examples():
    """Fig. 7 step 2 semantics: swapping one instance's variant = 2;
    moving one instance to another slice type = 2."""
    g1 = CG.ConfigGraph.from_dict("efficientnet", {("B1", 1): 2, ("B3", 2): 1})
    g_swap = CG.ConfigGraph.from_dict("efficientnet", {("B1", 1): 1, ("B7", 1): 1,
                                                       ("B3", 2): 1})
    assert CG.ged(g1, g_swap) == 2
    g_move = CG.ConfigGraph.from_dict("efficientnet", {("B1", 1): 2, ("B1", 2): 1})
    assert CG.ged(g1, g_move) == 2


@given(graph_strategy())
@settings(max_examples=25, deadline=None)
def test_neighbors_sound(gn):
    g, n_blocks = gn
    for nb in CG.neighbors(g, VARIANTS):
        assert CG.ged(g, nb) <= 4                       # paper's threshold
        assert nb.total_chips == g.total_chips          # chips conserved
        assert nb.is_valid(n_blocks, VARIANTS)
        assert nb.edges != g.edges


@given(graph_strategy(), graph_strategy())
@settings(max_examples=30, deadline=None)
def test_additivity(a, b):
    """Paper §4.2: adding blocks = edge-weight addition; subtract inverts."""
    g1, n1 = a
    g2, n2 = b
    s = g1.add(g2)
    assert s.total_chips == g1.total_chips + g2.total_chips
    back = s.subtract(g2)
    assert back.edges == g1.edges


def test_canonicalization():
    """Different (x^p, x^v) placements with the same slice-type multiset map
    to the same graph (Definition 1 collapse)."""
    w = {("B1", 2): 2, ("B7", 4): 3}
    g1 = CG.ConfigGraph.from_dict("efficientnet", dict(w))
    g2 = CG.ConfigGraph.from_dict("efficientnet", dict(reversed(list(w.items()))))
    assert g1.edges == g2.edges and CG.ged(g1, g2) == 0


def test_oom_edges_rejected():
    """A variant that cannot fit a slice invalidates the configuration —
    the paper disables such edges."""
    big = CAT.Variant("fam", "huge", 9, 0.99, 1e3, 2e5, 40.0)  # 40 GB > 2c HBM
    g = CG.ConfigGraph.from_dict("fam", {("huge", 2): 8})
    assert not g.is_valid(1, [big])
    g2 = CG.ConfigGraph.from_dict("fam", {("huge", 4): 4})
    assert g2.is_valid(1, [big])


def test_uniform_constructor():
    g = CG.ConfigGraph.uniform("efficientnet", "B7", 16, 10)
    assert g.n_instances == 10 and g.total_chips == 160
