"""Fleet-scope observability plane (jax-free): labeled series on the shared
catalog, bounded-memory streaming histograms with exact small-n parity,
bit-exact fleet rollup conservation, OpenMetrics round-trip identity,
snapshot-writer cadence, deterministic SLO/carbon burn-rate alerting, and
the controller consuming a firing alert as a forced re-optimization."""
import json
import math

import numpy as np
import pytest

from repro.core import catalog as CAT
from repro.core import config_graph as CG
from repro.obs import CATALOG, FleetRollup, LABEL_KEYS, MetricsRegistry, \
    PHASES, PhaseProfiler, SnapshotWriter, StreamingHistogram, \
    parse_openmetrics, to_openmetrics
from repro.obs.export import render_families
from repro.obs.metrics import Histogram
from repro.obs.slo import BurnRatePolicy, CarbonBudget, LatencyObjective, \
    SLOEvaluator, default_rules
from repro.serving import queue as Q
from repro.serving.api import DEFERRABLE, INTERACTIVE, serve_workload
from repro.fleet.workload import shaped_request_stream

VARIANTS = CAT.get_family("efficientnet")
DES_G = CG.ConfigGraph.from_dict("efficientnet", {("B3", 1): 1})


# =============================================================================
# streaming histogram: exact below max_raw, bounded sketch above
# =============================================================================
def test_streaming_small_n_parity_is_bit_exact():
    rng = np.random.default_rng(0)
    vals = rng.lognormal(0.0, 2.0, size=200)
    exact = Histogram("latency_s")
    sh = StreamingHistogram("latency_s", max_raw=4096)
    for v in vals:
        exact.observe(float(v))
        sh.observe(float(v))
    assert not sh.spilled and sh.samples == exact.samples
    assert sh.count == exact.count and sh.sum == exact.sum
    assert sh.mean == exact.mean
    for q in (0.0, 50.0, 90.0, 95.0, 99.0, 100.0):
        assert sh.percentile(q) == exact.percentile(q), q


def test_streaming_spill_accuracy_and_memory_bound():
    rng = np.random.default_rng(1)
    vals = rng.lognormal(0.0, 2.0, size=1_000_000)
    sh = StreamingHistogram("latency_s", max_raw=4096, alpha=0.01)
    sh.observe_many(vals)
    # memory bound: a million samples became a few hundred int buckets
    assert sh.spilled and sh.samples == []
    assert sh.n_buckets < 4096
    # count/sum stay exact even after the spill
    assert sh.count == 1_000_000
    assert sh.sum == float(vals.sum())
    # quantiles within the sketch's relative-accuracy contract (α = 1%,
    # doubled for the nearest-rank-vs-bucket-midpoint discretization)
    for q in (50.0, 95.0, 99.0):
        ref = float(np.quantile(vals, q / 100.0))
        assert abs(sh.percentile(q) - ref) <= 2.5e-2 * ref, q


def test_streaming_observe_many_matches_scalar_path():
    rng = np.random.default_rng(2)
    vals = rng.normal(0.0, 3.0, size=5000)   # negatives + positives
    a = StreamingHistogram("h", max_raw=64)
    b = StreamingHistogram("h", max_raw=64)
    a.observe_many(vals)
    for v in vals:
        b.observe(float(v))
    assert a.count == b.count and a._buckets == b._buckets


def test_streaming_merge_exact_and_spilled():
    rng = np.random.default_rng(3)
    small = StreamingHistogram("h", max_raw=4096)
    small.observe_many(rng.exponential(1.0, size=100))
    big = StreamingHistogram("h", max_raw=256)
    big_vals = rng.exponential(1.0, size=10_000)
    big.observe_many(big_vals)
    exact = Histogram("h")
    for v in (0.5, 1.5, 2.5):
        exact.observe(v)

    tgt = StreamingHistogram("h", max_raw=4096)
    tgt.merge(small)                    # raw ⊕ raw: still exact
    assert not tgt.spilled and tgt.count == 100
    tgt.merge(exact)                    # exact Histogram folds in too
    assert tgt.count == 103 and not tgt.spilled
    tgt.merge(big)                      # spilled side forces the sketch
    assert tgt.spilled
    assert tgt.count == small.count + exact.count + big.count
    assert tgt.sum == small.sum + exact.sum + big.sum
    with pytest.raises(AssertionError):
        tgt.merge(StreamingHistogram("h", alpha=0.05))   # α mismatch


# =============================================================================
# labeled series on the shared catalog
# =============================================================================
def test_registry_labels_do_not_change_catalog_parity():
    reg = MetricsRegistry.standard("r", labels={"region": "east"})
    reg.labeled("requests_served", slo_class="interactive").inc(3)
    reg.labeled("requests_served", slo_class="deferrable").inc(1)
    reg.labeled("latency_s", slo_class="interactive").observe(0.2)
    reg.labeled("phase_latency_s", phase="decode_dispatch").observe(1e-4)
    # the NAME set is still exactly the catalog — labels are children
    assert reg.names() == set(CATALOG)
    series = list(reg.labeled_series())
    assert len(series) == 4
    assert ("requests_served", {"slo_class": "interactive"}) in \
        [(n, d) for n, d, _ in series]
    # same (name, labels) key returns the same child
    again = reg.labeled("requests_served", slo_class="interactive")
    assert again.value == 3
    # kind follows the parent; label keys outside the schema are rejected
    assert reg.labeled("latency_s", slo_class="x").kind == "histogram"
    with pytest.raises(AssertionError):
        reg.labeled("latency_s", datacenter="x")
    assert "datacenter" not in LABEL_KEYS


def test_registry_streaming_mode_swaps_histogram_class():
    reg = MetricsRegistry.standard("r", streaming=True, max_raw_samples=8)
    h = reg.histogram("latency_s")
    assert isinstance(h, StreamingHistogram)
    for v in range(20):
        h.observe(float(v))
    assert h.spilled and h.count == 20
    assert isinstance(reg.labeled("latency_s", slo_class="interactive"),
                      StreamingHistogram)


# =============================================================================
# fleet rollup: bit-exact conservation + per-region breakdown
# =============================================================================
def test_rollup_conservation_is_bit_exact():
    rng = np.random.default_rng(4)
    rollup = FleetRollup()
    expect_e = expect_c = 0.0
    for name in ("east", "west", "north"):
        reg = MetricsRegistry.standard(name, labels={"region": name})
        e, c = float(rng.uniform(1e3, 1e5)), float(rng.uniform(0.1, 50.0))
        reg.counter("energy_j").inc(e)
        reg.counter("carbon_g").inc(c)
        reg.counter("requests_served").inc(int(rng.integers(1, 100)))
        reg.gauge("blocks_in_use").set(float(rng.integers(1, 30)))
        for _ in range(50):
            reg.histogram("latency_s").observe(float(rng.exponential(1.0)))
        reg.labeled("requests_served", slo_class="interactive").inc(2)
        rollup.add(reg)
        expect_e += e
        expect_c += c
    totals = rollup.conservation(("energy_j", "carbon_g"))
    assert totals["energy_j"] == expect_e       # ==, not approx
    assert totals["carbon_g"] == expect_c
    fleet = rollup.merged()
    assert fleet.names() == set(CATALOG)
    # gauges sum across regions; histograms keep exact count/sum
    assert fleet.gauge("blocks_in_use").value == sum(
        r.gauge("blocks_in_use").value for r in rollup.regions.values())
    assert fleet.histogram("latency_s").count == 150
    # per-region counters survive as region-labeled children
    by_label = {(n, tuple(sorted(d.items()))): m
                for n, d, m in fleet.labeled_series()}
    for name, reg in rollup.regions.items():
        child = by_label[("energy_j", (("region", name),))]
        assert child.value == reg.counter("energy_j").value
    # regions' own labeled children got re-labeled with their region
    assert ("requests_served",
            (("region", "east"), ("slo_class", "interactive"))) in by_label
    with pytest.raises(AssertionError):         # duplicate region
        rollup.add(MetricsRegistry.standard("east"))


def test_rollup_conservation_catches_tampering():
    rollup = FleetRollup()
    for name, e in (("a", 10.0), ("b", 20.0)):
        reg = MetricsRegistry.standard(name)
        reg.counter("energy_j").inc(e)
        rollup.add(reg, region=name)
    rollup.conservation(("energy_j",))
    rollup.merged().counter("energy_j").inc(1e-9)   # a joule goes missing
    with pytest.raises(AssertionError):
        rollup.conservation(("energy_j",))


# =============================================================================
# OpenMetrics exposition: round-trip identity, float exactness
# =============================================================================
def test_openmetrics_round_trip_identity_and_exact_floats():
    reg = MetricsRegistry.standard("r", labels={"region": "east"})
    odd = 0.1 + 0.2                             # classic non-decimal float
    reg.counter("energy_j").inc(odd)
    reg.gauge("blocks_in_use").set(7.0)
    reg.histogram("latency_s").observe(odd)
    reg.labeled("latency_s", slo_class="interactive").observe(1.5)
    text = to_openmetrics(reg)
    fams = parse_openmetrics(text)
    assert render_families(fams) == text        # identity, byte for byte
    assert text.endswith("# EOF\n")
    e = [v for n, _, v in fams["repro_energy_j"]["samples"]
         if n == "repro_energy_j_total"]
    assert [float(v) for v in e] == [odd]       # repr() round-trips exactly
    # constant labels ride on every sample; children add their own
    lat = fams["repro_latency_s"]["samples"]
    assert all(("region", "east") in lbl for _, lbl, _ in lat)
    assert any(("slo_class", "interactive") in lbl for _, lbl, _ in lat)
    assert fams["repro_blocks_in_use"]["type"] == "gauge"
    assert "repro_blocks_in_use_peak" in fams   # peak is its own family
    with pytest.raises(AssertionError):
        parse_openmetrics("no_help_line 1.0\n# EOF\n")
    with pytest.raises(AssertionError):
        parse_openmetrics("# HELP x y\n# TYPE x counter\nx 1.0\n")  # no EOF


def test_exporter_family_parity_des_vs_fluid():
    from repro.serving.backends import FluidBackend

    def workload():
        return shaped_request_stream(6, 0.5, vocab_size=64, shape="peak",
                                     prompt_lens=(4, 8), n_new=4, seed=9)

    des = Q.DESBackend(DES_G, VARIANTS, Q.DESConfig(jitter_sigma=0.0),
                       ci_g_per_kwh=300.0)
    serve_workload(des, workload())
    fluid = FluidBackend(DES_G, VARIANTS, sla_target_s=2.0, window_s=0.25,
                         ci_g_per_kwh=300.0)
    serve_workload(fluid, workload())
    sets = [frozenset(parse_openmetrics(to_openmetrics(b.registry)))
            for b in (des, fluid)]
    assert sets[0] == sets[1]
    # both recorded slo_class-labeled children from the live workload
    for b in (des, fluid):
        assert any(d.get("slo_class") for _, d, _ in
                   b.registry.labeled_series("latency_s"))


# =============================================================================
# snapshot writer cadence
# =============================================================================
def test_snapshot_writer_interval_gating(tmp_path):
    reg = MetricsRegistry.standard("r")
    reg.counter("requests_served").inc(1)
    path = tmp_path / "snap.jsonl"
    w = SnapshotWriter(str(path), interval_s=60.0)
    assert w.maybe_write(0.0, reg)              # first write always lands
    assert not w.maybe_write(30.0, reg)         # inside the interval
    assert not w.maybe_write(59.9, reg)
    assert w.maybe_write(60.0, reg)
    w.write(70.0, reg)                          # forced (e.g. at drain)
    assert w.writes == 3
    recs = [json.loads(x) for x in path.read_text().splitlines()]
    assert [r["t"] for r in recs] == [0.0, 60.0, 70.0]
    assert all(r["metrics"]["requests_served"] == 1 for r in recs)


# =============================================================================
# SLO / carbon burn-rate alerting: exact fire/clear ticks
# =============================================================================
POLICY = BurnRatePolicy(short_s=60.0, long_s=300.0,
                        fire_burn=2.0, clear_burn=1.0)


def test_latency_burn_rate_fire_and_clear_ticks_exact():
    # one request per second; all bad (ttft 1.0 > 0.5) during t ∈ [101, 160].
    # error budget 1 − 0.9 = 0.1, so burn = 10 × bad_fraction.
    #   fire: first eval with BOTH windows ≥ 2 — short trips at t=120
    #   (20/60 bad) but long (20/120) lags; both pass at t=130 (30/60,
    #   30/130 → 5.0 and 2.31).
    #   clear: short is clean from t=220; long needs the bad run to age
    #   out of (t−300, t] — at t=430 it is 30/300 → burn exactly 1.0 (not
    #   < 1), at t=440 it is 20/300 → 0.67.  Clear tick: 440.
    ev = SLOEvaluator([LatencyObjective("ttft", threshold_s=0.5,
                                        target=0.9)], POLICY)
    for t in range(1, 501):
        bad = 101 <= t <= 160
        ev.record_request(float(t), INTERACTIVE,
                          ttft_s=1.0 if bad else 0.1)
        if t % 10 == 0:
            ev.evaluate(float(t))
    st = ev.states["ttft"]
    assert st.transitions == [(130.0, "fire"), (440.0, "clear")]
    assert st.fire_count == 1 and not st.firing
    assert st.t_fired == 130.0 and st.t_cleared == 440.0
    assert ev.total_fires == 1 and ev.firing() == []


def test_carbon_burn_rate_fire_and_clear_ticks_exact():
    # 0.125 g (exact binary) per second for t ∈ [1, 100] against a 60 g/h
    # budget: allowance is 1 g per short window, 5 g per long window.
    #   fire at t=80: short (20,80] holds 7.5 g → 7.5×; long (…,80] holds
    #   10 g → exactly 2.0× (t=70 long is 8.75/5 = 1.75).
    #   clear when the long window drains below 5 g: at t=360 it still
    #   holds exactly 5 g (burn 1.0), at t=370 → 3.75 g (0.75).
    ev = SLOEvaluator([CarbonBudget("cb", budget_g=60.0, window_s=3600.0)],
                      POLICY)
    for t in range(1, 401):
        if t <= 100:
            ev.record_carbon(float(t), 0.125)
        if t % 10 == 0:
            ev.evaluate(float(t))
    st = ev.states["cb"]
    assert st.transitions == [(80.0, "fire"), (370.0, "clear")]
    assert st.fire_count == 1 and not st.firing


def test_evaluator_memory_is_bounded_by_the_long_window():
    ev = SLOEvaluator(default_rules(), POLICY)
    for t in range(100_000):
        ev.record_request(float(t), INTERACTIVE, ttft_s=0.1, latency_s=1.0)
        ev.record_carbon(float(t), 1e-6)
        if t % 1000 == 0:
            ev.evaluate(float(t))
    ev.evaluate(99_999.0)
    # deques hold only the long window (300 s of 1/s events), not the run
    assert all(len(dq) <= POLICY.long_s + 1 for dq in ev._lat.values())
    assert len(ev._carbon) <= POLICY.long_s + 1


def test_evaluator_rule_validation():
    with pytest.raises(AssertionError):         # duplicate rule name
        SLOEvaluator([CarbonBudget("x", 1.0), CarbonBudget("x", 2.0)])
    with pytest.raises(AssertionError):         # unknown metric
        LatencyObjective("y", threshold_s=1.0, metric="p99_s")
    with pytest.raises(AssertionError):         # degenerate policy
        BurnRatePolicy(short_s=600.0, long_s=60.0)
    names = [r.name for r in default_rules()]
    assert names == ["interactive-ttft", "deferrable-latency",
                     "hourly-carbon"]


# =============================================================================
# controller: a firing alert forces re-optimization
# =============================================================================
def test_controller_consumes_burn_alert_as_forced_reopt():
    from repro.core import controller as CTRL
    from repro.core import schemes as SCH
    from repro.serving import simulator as SIM
    ctx, _ = SIM.make_context("efficientnet", SIM.SimConfig(n_blocks=1))
    c = CTRL.Controller(SCH.make_scheme("CLOVER"), ctx)
    c.start(0.0, 300.0)
    # same CI, no alerts attached: the drift trigger stays quiet
    assert c.maybe_reoptimize(600.0, 300.0)[1] is None
    n0 = len(c.invocations)

    ev = SLOEvaluator([LatencyObjective("ttft", threshold_s=0.5,
                                        target=0.9)], POLICY)
    for ts in range(1150, 1200):                # 50 straight SLO misses
        ev.record_request(float(ts), INTERACTIVE, ttft_s=1.0)
    c.alerts = ev
    cfg, outcome = c.maybe_reoptimize(1200.0, 300.0)   # CI still flat
    assert outcome is not None and len(c.invocations) == n0 + 1
    inv = c.invocations[-1]
    assert inv.alert and not inv.predictive     # alert, not forecast
    assert c.last_alerts[0].firing
    assert ev.states["ttft"].t_fired == 1200.0
    # the SAME (still-firing) alert does not re-force every tick
    assert c.maybe_reoptimize(1210.0, 300.0)[1] is None
    assert len(c.invocations) == n0 + 1
    assert c.last_alerts[0].firing              # state still visible


# =============================================================================
# phase profiler plumbing (engine-free)
# =============================================================================
def test_phase_profiler_routes_and_detaches():
    prof = PhaseProfiler()                      # detached: every call no-ops
    prof.observe("decode_dispatch", 1.0)
    reg = MetricsRegistry.standard("r")
    prof.registry = reg
    prof.observe("decode_dispatch", 2e-3)
    with prof.span("swap_d2h"):
        math.sqrt(2.0)
    with pytest.raises(AssertionError):
        prof.observe("warmup", 1.0)             # not a canonical phase
    series = {d["phase"]: m for _, d, m in
              reg.labeled_series("phase_latency_s")}
    assert set(series) == {"decode_dispatch", "swap_d2h"}
    assert series["decode_dispatch"].count == 1
    assert series["swap_d2h"].samples[0] >= 0.0
    assert set(PHASES) == {"prefill_chunk", "decode_dispatch",
                           "decode_land", "swap_d2h", "swap_h2d"}
    prof.registry = None                        # detach again: silent
    prof.observe("decode_land", 1.0)
    assert reg.names() == set(CATALOG)
