"""Forecast-driven carbon scheduling control plane.

The three PR-4 follow-ups as one loop: (1) ``CarbonForecastPolicy`` holds
deferrable work for the *forecast valley inside its deadline runway* (ci_fn
from ``fleet.forecast``, not a raw trace lookup) and beats the raw-threshold
``CarbonAwarePolicy`` on gCO2/request at equal SLA attainment; (2) the
active policy orders the paged instance's chunked-prefill queue, so
interactive chunks preempt a long background prefill; (3) partial swap-in
restores a preempted sequence from surviving radix-tree blocks, copying back
strictly fewer pages than a full restore at token parity.  Plus the
held-request accounting contract: queue delay accrues from ARRIVAL, and
per-request joules sum exactly to engine totals when holds and partial
swap-ins interleave.
"""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.core import carbon as CB
from repro.core import catalog as CAT
from repro.core import config_graph as CG
from repro.fleet.forecast import EnsembleForecaster, ForecastCIFn
from repro.serving import engine as ENG
from repro.serving import queue as Q
from repro.serving.api import DEFERRABLE, INTERACTIVE, InferenceRequest, \
    serve_workload
from repro.serving.policies import CarbonAwarePolicy, CarbonForecastPolicy, \
    FIFOPolicy, PriorityPolicy
from repro.serving.scheduler import SchedulerCore

CFG = get_smoke_config("qwen3-1.7b").with_(n_layers=2, dtype=jnp.float32)
VARIANTS = CAT.get_family("efficientnet")
DES_G = CG.ConfigGraph.from_dict("efficientnet", {("B3", 1): 1})


@pytest.fixture(scope="module")
def family():
    return ENG.build_engine_family(CFG, fracs=(1.0,))


def _graph():
    return CG.ConfigGraph.from_dict(CFG.name, {("x1", 16): 1})


# =============================================================================
# CarbonForecastPolicy selection mechanics (unit)
# =============================================================================
def _core_with(policy, entries):
    core = SchedulerCore(policy)
    for rid, t, prio, dl, slo in entries:
        core.submit(rid, t, priority=prio, deadline_s=dl, slo=slo)
    return core


def test_forecast_policy_holds_for_falling_releases_on_rising():
    # V-shaped grid: CI falls to a valley of 380 at t=120, then recovers
    vshape = lambda now, h=0.0: 380.0 + abs(((now or 0.0) + h) - 120.0)
    pol = CarbonForecastPolicy(vshape, horizon_s=120.0, step_s=10.0)
    core = _core_with(pol, [(0, 0.0, 0, 1000.0, DEFERRABLE)])
    # a materially lower valley is reachable inside the runway: HOLD
    assert core.peek_next(now=0.0) is None
    # riding the decline into the valley, the nowcast reaches the best the
    # forecast offers: GO (tolerance band around the valley)
    assert core.peek_next(now=110.0) == (0, 0.0)

    # rising grid: now IS the valley — release immediately, where the
    # raw-threshold policy would sit out the "dirty" spell pointlessly
    rising = lambda now, h=0.0: 300.0 + ((now or 0.0) + h)
    core = _core_with(CarbonForecastPolicy(rising, horizon_s=120.0,
                                           step_s=10.0),
                      [(0, 0.0, 0, 1000.0, DEFERRABLE)])
    assert core.peek_next(now=0.0) == (0, 0.0)
    held = _core_with(CarbonAwarePolicy(lambda now: 300.0 + (now or 0.0),
                                        ci_threshold=200.0),
                      [(0, 0.0, 0, 1000.0, DEFERRABLE)])
    assert held.peek_next(now=0.0) is None     # raw threshold: parked


def test_forecast_policy_force_release_and_interactive_flow():
    falling = lambda now, h=0.0: 500.0 - ((now or 0.0) + h)
    pol = CarbonForecastPolicy(falling, horizon_s=1000.0, step_s=50.0,
                               est_service_s=5.0, deadline_margin_s=5.0)
    core = _core_with(pol, [(0, 0.0, 0, 30.0, DEFERRABLE),
                            (1, 1.0, 0, None, INTERACTIVE)])
    # interactive bypasses any hold
    assert core.pop_next(now=0.0) == (1, 1.0)
    # runway (30 − 25 − 10) < 0 at now=25: force-released despite the
    # falling forecast — a hold can never become a miss
    assert core.peek_next(now=25.0) == (0, 0.0)


# =============================================================================
# (1) forecast valley vs raw threshold on a synthetic diurnal trace (DES)
# =============================================================================
def test_forecast_policy_beats_raw_threshold_on_diurnal_trace():
    """Deferrable work arriving on the morning decline: the raw-threshold
    policy releases at the threshold crossing, the forecast policy rides the
    decline down to the valley — lower CI at service, identical interactive
    latencies, every deadline met, queue delay accrued from arrival."""
    trace = CB.make_trace("CISO-March", hours=72, seed=3)
    # find the solar valley after the forecaster has a day+ of history
    t0 = 36 * 3600.0
    ts = np.arange(t0, t0 + 24 * 3600.0, 600.0)
    cis = np.array([trace.at(float(t)) for t in ts])
    t_valley = float(ts[int(np.argmin(cis))])
    arrival = t_valley - 6 * 3600.0
    deadline = t_valley + 4 * 3600.0
    ci_arr, ci_val = trace.at(arrival), trace.at(t_valley)
    assert ci_arr > ci_val, "need a decline for the scenario to mean anything"
    threshold = 0.5 * (ci_arr + ci_val)

    # a background interactive stream spanning past the valley keeps both
    # sessions over the SAME wall-clock span (the cluster is up serving
    # either way — a session that merely ended earlier would book less of
    # the shared idle floor and confound the policy comparison)
    n_inter = 12
    inter_gap = (deadline - arrival) / n_inter

    def reqs():
        out = [InferenceRequest(rid=i, prompt=[1], max_new_tokens=8,
                                arrival_s=arrival, slo=DEFERRABLE,
                                deadline_s=deadline)
               for i in range(3)]
        out += [InferenceRequest(rid=3 + i, prompt=[1], max_new_tokens=8,
                                 arrival_s=arrival + inter_gap * i,
                                 slo=INTERACTIVE)
                for i in range(n_inter)]
        return out

    policies = {
        "raw": CarbonAwarePolicy(lambda now: trace.at(now or 0.0),
                                 ci_threshold=threshold,
                                 est_service_s=60.0,
                                 deadline_margin_s=600.0),
        "forecast": CarbonForecastPolicy(
            ForecastCIFn(EnsembleForecaster(trace)),
            horizon_s=8 * 3600.0, step_s=1800.0,
            est_service_s=60.0, deadline_margin_s=600.0),
    }
    res = {}
    for name, pol in policies.items():
        des = Q.DESBackend(DES_G, VARIANTS, Q.DESConfig(jitter_sigma=0.0),
                           policy=pol, ci_g_per_kwh=trace.at,
                           hold_retry_s=300.0)
        responses = {r.rid: r for r in serve_workload(des, reqs())}
        m = des.stats()
        assert m["served"] == 3 + n_inter and m["deadline_misses"] == 0
        # attribution exactness under time-varying CI
        assert sum(r.carbon_g for r in responses.values()) == pytest.approx(
            m["carbon_g"], rel=1e-9)
        res[name] = (responses, m)

    svc_nominal = res["raw"][0][3].latency_s
    for name, (responses, _) in res.items():
        for rid in (0, 1, 2):
            r = responses[rid]
            # held requests accrue queue delay from ARRIVAL: service starts
            # at t_arrival + queue_delay_s, hours after arrival
            assert r.t_arrival == pytest.approx(arrival)
            assert r.queue_delay_s > 1800.0, (name, rid, r.queue_delay_s)
        # equal SLA attainment: the hold never touches the interactive
        # stream — every interactive request is served within a couple of
        # service times under BOTH policies
        for rid in range(3, 3 + n_inter):
            assert responses[rid].latency_s <= 3.0 * svc_nominal, (name, rid)
    # the forecast policy serves deferrable work at a materially cleaner
    # grid than the threshold crossing...
    def defer_ci(responses):
        return np.mean([trace.at(r.t_arrival + r.queue_delay_s)
                        for rid, r in responses.items() if rid < 3])
    assert defer_ci(res["forecast"][0]) < defer_ci(res["raw"][0]) - 1.0
    # ...and with the idle floor covering the same span, that shows up as
    # strictly less total gCO2 for the same served workload
    assert res["forecast"][1]["carbon_g"] < res["raw"][1]["carbon_g"]
    assert res["forecast"][1]["carbon_g_per_req"] \
        < res["raw"][1]["carbon_g_per_req"]


# =============================================================================
# (2) policy-aware prefill queue: interactive chunks preempt background
# =============================================================================
def _prefill_race(family, policy):
    """Admit a LONG background prefill, let it start chunking, then submit a
    short interactive request; return the order in which the two requests
    emitted their first token."""
    rng = np.random.default_rng(11)
    eng = ENG.RealEngine(family, n_slots=2, max_len=192, kv_layout="paged",
                         block_size=8, max_seqs=4, chunk_blocks=1,
                         n_blocks=64, policy=policy)
    eng.configure(_graph())
    first_tokens = []

    def on_tok(rid, tok):
        if rid not in first_tokens:
            first_tokens.append(rid)

    bg = InferenceRequest(rid=0, prompt=rng.integers(0, CFG.vocab_size,
                                                     size=160),
                          max_new_tokens=4, priority=0, slo=DEFERRABLE,
                          on_token=on_tok)
    eng.submit(bg)
    eng.step()                      # background starts chunking (20 chunks)
    inter = InferenceRequest(rid=1, prompt=rng.integers(0, CFG.vocab_size,
                                                        size=12),
                             max_new_tokens=4, priority=5, slo=INTERACTIVE,
                             on_token=on_tok)
    eng.submit(inter)
    eng.drain()
    assert sorted(eng.last_outputs) == [0, 1]
    return first_tokens, eng


def test_prefill_queue_interactive_preempts_background(family):
    # FIFO: prefill runs in admission order — background finishes first
    order_fifo, _ = _prefill_race(family, FIFOPolicy())
    assert order_fifo[0] == 0, order_fifo
    # priority policy: the interactive admission's chunks jump the queue
    # MID-PROMPT and its first token lands while the background prefill is
    # still chunking
    order_prio, eng = _prefill_race(family, PriorityPolicy())
    assert order_prio[0] == 1, order_prio
    # outputs are unaffected by prefill interleaving order
    _, eng_f = _prefill_race(family, FIFOPolicy())
    for rid in (0, 1):
        np.testing.assert_array_equal(eng.last_outputs[rid],
                                      eng_f.last_outputs[rid])


# =============================================================================
# (3) partial swap-in: fewer pages copied, token parity
# =============================================================================
def _preamble_prompts(n=4, preamble=16, tail=6, seed=5):
    rng = np.random.default_rng(seed)
    pre = rng.integers(0, CFG.vocab_size, size=preamble).astype(np.int32)
    return [np.concatenate([pre, rng.integers(0, CFG.vocab_size, size=tail)
                            .astype(np.int32)]) for _ in range(n)]


def test_partial_swapin_restores_fewer_pages_token_identical(family):
    prompts = _preamble_prompts()
    n_new = 16

    ref = ENG.RealEngine(family, n_slots=2, max_len=64, kv_layout="paged",
                         block_size=8, max_seqs=4, n_blocks=41)
    ref.configure(_graph())
    ref_m = ref._serve_prompts(prompts, n_new=n_new)
    assert ref_m["preemptions"] == 0

    # 4 seqs × ceil(38/8)=5 blocks wanted at completion = 20; arena has 13:
    # admission (3 prompt blocks each) fits, decode growth runs dry and
    # preempts.  The shared 2-block preamble stays pinned by the survivors'
    # references, so the victim's resume re-acquires it from the radix tree
    # instead of copying those pages back from host.
    eng = ENG.RealEngine(family, n_slots=2, max_len=64, kv_layout="paged",
                         block_size=8, max_seqs=4, n_blocks=14,
                         preemption=True)
    eng.configure(_graph())
    responses = serve_workload(
        eng, [InferenceRequest(rid=i, prompt=p, max_new_tokens=n_new)
              for i, p in enumerate(prompts)])
    m = eng.stats()
    assert m["preemptions"] >= 1
    assert m["served"] == len(prompts)
    # partial swap-in: strictly fewer pages copied than a full restore
    full_pages = m["swapin_pages_copied"] + m["partial_swapin_pages_saved"]
    assert full_pages > 0, "no swap-in happened — scenario lost its teeth"
    assert m["partial_swapin_pages_saved"] >= 1
    assert m["swapin_pages_copied"] < full_pages
    # ... at token parity with the never-preempted reference
    for rid, toks in ref.last_outputs.items():
        np.testing.assert_array_equal(toks, eng.last_outputs[rid])
    assert sum(r.preemptions for r in responses) == m["preemptions"]
    inst = eng.instances[0]
    inst.alloc.check()


# =============================================================================
# holds + partial swap-ins interleaved: accounting stays exact
# =============================================================================
def test_attribution_exact_with_holds_and_partial_swapins(family):
    """A forecast hold parks deferrable work while interactive requests
    preempt each other under an overcommitted arena; when the grid 'cleans'
    the held work flows.  Per-request joules must STILL sum exactly to the
    engine total, and the held request's queue delay runs from arrival."""
    hold_s = 0.25

    def ci_fn(now=None, horizon_s=0.0):
        t = (now or 0.0) + horizon_s
        return 500.0 if t < hold_s else 50.0

    pol = CarbonForecastPolicy(ci_fn, horizon_s=2.0, step_s=0.05,
                               ci_threshold=200.0)
    prompts = _preamble_prompts(n=4, seed=7)
    ci = 410.0
    eng = ENG.RealEngine(family, n_slots=2, max_len=64, kv_layout="paged",
                         block_size=8, max_seqs=4, n_blocks=14,
                         preemption=True, policy=pol, ci_g_per_kwh=ci)
    eng.configure(_graph())
    reqs = [InferenceRequest(rid=i, prompt=p, max_new_tokens=16,
                             slo=INTERACTIVE, priority=1)
            for i, p in enumerate(prompts[:3])]
    reqs.append(InferenceRequest(rid=3, prompt=prompts[3], max_new_tokens=16,
                                 slo=DEFERRABLE, priority=0, deadline_s=30.0))
    responses = {r.rid: r for r in serve_workload(eng, reqs)}
    m = eng.stats()
    assert m["served"] == 4
    assert m["preemptions"] >= 1, "want swap churn under the hold"
    # the deferrable request waited out the dirty spell — and its queue
    # delay is measured from ARRIVAL, covering the whole hold
    assert responses[3].queue_delay_s >= hold_s
    # exact attribution: joules sum to the engine total, carbon = J × CI
    total_j = sum(r.energy_j for r in responses.values())
    assert total_j == pytest.approx(m["energy_j"], rel=1e-9)
    assert sum(r.carbon_g for r in responses.values()) == pytest.approx(
        m["energy_j"] / 3.6e6 * ci, rel=1e-9)
