"""Mixed-quality request path (``serving.quality``): selector unit
behavior (static pinning, greedy dirty-grid downshifting, the windowed
accuracy-floor governor, per-request hint/floor clamps), the hoisted
``best_variant``/``worst_variant`` catalog helpers, DES variant routing
with exact energy attribution, and the cross-backend conformance
contract — one workload, identical decision sequences on the real engine
(slotted AND paged), the DES, and the fluid model."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.core import catalog as CAT
from repro.core import config_graph as CG
from repro.serving import engine as ENG
from repro.serving import queue as Q
from repro.serving.api import DEFERRABLE, INTERACTIVE, InferenceRequest, \
    serve_workload
from repro.serving.backends import FluidBackend
from repro.serving.quality import AccuracyFloorGovernor, \
    GreedyDownshiftSelector, QualitySelector, StaticPinSelector, make_selector

CFG = get_smoke_config("qwen3-1.7b").with_(n_layers=2, dtype=jnp.float32)
VARIANTS = CAT.get_family("efficientnet")
MIX_G = CG.ConfigGraph.from_dict("efficientnet",
                                 {("B1", 1): 1, ("B3", 1): 1})


@pytest.fixture(scope="module")
def family():
    # two real rungs: x0.5 (quality 1, accuracy 0.80) and x1 (quality 2,
    # accuracy 0.85) — the ladder the routing tests place requests on
    return ENG.build_engine_family(CFG, fracs=(1.0, 0.5))


def _ladder():
    """The efficientnet rungs the mixed DES pool can instantiate."""
    by = {v.name: v for v in VARIANTS}
    return [by["B1"], by["B3"]]          # accuracies 0.791, 0.816


def _req(rid, slo=INTERACTIVE, arrival=None, **kw):
    return InferenceRequest(rid=rid, prompt=[1], max_new_tokens=8, slo=slo,
                            arrival_s=arrival, **kw)


# =============================================================================
# catalog helpers (satellite bugfix: duplicated max(..., key=quality) hoisted)
# =============================================================================
def test_best_worst_variant_and_tie_break():
    fam = CAT.get_family("efficientnet")
    assert CAT.best_variant(fam).name == "B7"
    assert CAT.worst_variant(fam).name == "B1"
    # equal quality ordinals: accuracy breaks the tie, then name — the
    # deterministic order every former max(..., key=lambda v: v.quality)
    # call site now shares (max() alone kept whichever came first)
    a = CAT.Variant("f", "a", 1, 0.90, 1.0, 1.0, 1.0)
    b = CAT.Variant("f", "b", 1, 0.95, 1.0, 1.0, 1.0)
    c = CAT.Variant("f", "c", 1, 0.95, 1.0, 1.0, 1.0)
    assert CAT.best_variant([a, b]) is b           # accuracy tie-break
    assert CAT.best_variant([b, a]) is b           # ... order-independent
    assert CAT.best_variant([c, b]) is c           # name tie-break
    assert CAT.worst_variant([b, a, c]) is a


# =============================================================================
# selector units
# =============================================================================
def test_static_pin_selector_pins_class_and_defaults_rest():
    sel = StaticPinSelector(pins={DEFERRABLE: "B1"})
    sel.reset(_ladder())
    d0 = sel.select(_req(0, DEFERRABLE))
    d1 = sel.select(_req(1, INTERACTIVE))
    assert (d0.variant, d0.reason) == ("B1", "pinned")
    assert (d1.variant, d1.reason) == ("B3", "default")
    assert sel.decision_sequence() == [(0, "B1", "pinned"),
                                       (1, "B3", "default")]


def test_greedy_downshifts_deferrable_when_dirty_only():
    sel = GreedyDownshiftSelector(ci_fn=lambda t: 400.0 if t < 60 else 50.0,
                                  dirty_threshold_g=300.0, sustain_s=30.0)
    sel.reset(_ladder())
    assert sel.select(_req(0, DEFERRABLE, 0.0)).reason == "downshift"
    assert sel.select(_req(1, INTERACTIVE, 10.0)).reason == "default"
    # sustained dirt (>= 30 s since t=0) drops interactive one rung too
    d = sel.select(_req(2, INTERACTIVE, 45.0))
    assert d.reason == "pressure" and d.variant == "B1"
    # clean grid restores everyone to best (and resets the sustain clock)
    assert sel.select(_req(3, DEFERRABLE, 90.0)).variant == "B3"
    assert sel.select(_req(4, INTERACTIVE, 95.0)).reason == "default"


def test_governor_refuses_floor_breaching_downshift():
    ladder = _ladder()                     # B1 0.791 / B3 0.816
    sel = AccuracyFloorGovernor(
        base=GreedyDownshiftSelector(ci_fn=lambda t: 400.0),  # always dirty
        floors={DEFERRABLE: 0.80})
    sel.reset(ladder)
    # empty window: a lone B1 would put the mean at 0.791 < 0.80 → refused
    d0 = sel.select(_req(0, DEFERRABLE, 0.0))
    assert (d0.variant, d0.reason) == ("B3", "floor")
    # window now holds 0.816: (0.816 + 0.791) / 2 = 0.8035 ≥ 0.80 → allowed
    d1 = sel.select(_req(1, DEFERRABLE, 1.0))
    assert (d1.variant, d1.reason) == ("B1", "downshift")
    # (0.816 + 0.791 + 0.791) / 3 = 0.799 < 0.80 → refused again
    d2 = sel.select(_req(2, DEFERRABLE, 2.0))
    assert (d2.variant, d2.reason) == ("B3", "floor")
    assert sel.window_mean(DEFERRABLE) >= 0.80
    # the window prunes: far in the future the refusals start over
    d3 = sel.select(_req(3, DEFERRABLE, 10 * 3600.0))
    assert (d3.variant, d3.reason) == ("B3", "floor")


def test_per_request_hint_and_min_accuracy_clamp():
    sel = GreedyDownshiftSelector(ci_fn=lambda t: 400.0)     # always dirty
    sel.reset(_ladder())
    # the hint pins even against the downshifter's choice
    d = sel.select(_req(0, DEFERRABLE, 0.0, quality_hint="B3"))
    assert (d.variant, d.reason) == ("B3", "hint")
    # an unknown hint is ignored (the rung isn't instantiable here)
    assert sel.select(_req(1, DEFERRABLE, 0.0,
                           quality_hint="B9")).variant == "B1"
    # min_accuracy is a hard clamp: B1's 0.791 < 0.8 → promoted
    d = sel.select(_req(2, DEFERRABLE, 0.0, min_accuracy=0.8))
    assert (d.variant, d.reason) == ("B3", "min_accuracy")


def test_make_selector_registry():
    assert make_selector(None) is None
    assert make_selector("off") is None
    sel = make_selector("static", pins={DEFERRABLE: "B1"},
                        ci_fn=lambda t: 0.0)       # irrelevant kwarg dropped
    assert isinstance(sel, StaticPinSelector)
    assert make_selector(sel) is sel               # instance passthrough
    assert isinstance(make_selector("greedy"), GreedyDownshiftSelector)
    assert isinstance(make_selector("governed"), AccuracyFloorGovernor)
    with pytest.raises(ValueError):
        make_selector("nope")
    with pytest.raises(NotImplementedError):
        base = QualitySelector()
        base.reset(_ladder())
        base.select(_req(0))


# =============================================================================
# DES routing: decided rung == served rung, attribution still exact
# =============================================================================
def test_des_routes_to_decided_variant_and_conserves_energy():
    sel = make_selector("greedy", ci_fn=lambda t: 400.0 if t < 60 else 50.0,
                        dirty_threshold_g=300.0)
    des = Q.DESBackend(MIX_G, VARIANTS, Q.DESConfig(jitter_sigma=0.0),
                       policy="fifo", ci_g_per_kwh=300.0,
                       quality_selector=sel)
    reqs = [_req(i, DEFERRABLE if i % 2 else INTERACTIVE, arrival=i * 10.0)
            for i in range(12)]
    responses = serve_workload(des, reqs)
    m = des.stats()
    assert m["served"] == len(reqs)
    dec_of = {d.rid: d for d in sel.decisions}
    for r in responses:
        assert r.variant == dec_of[r.rid].variant
        assert r.accuracy == dec_of[r.rid].accuracy
    # both rungs genuinely served (dirty spell downshifted deferrable work)
    assert {r.variant for r in responses} == {"B1", "B3"}
    # attribution contract survives variant routing: joules sum exactly
    assert sum(r.energy_j for r in responses) == pytest.approx(
        m["energy_j"], rel=1e-9)
    assert sum(r.carbon_g for r in responses) == pytest.approx(
        m["carbon_g"], rel=1e-9)
    # satellite: accuracy histogram carries per-class children
    for slo in (INTERACTIVE, DEFERRABLE):
        child = des.registry.labeled("accuracy", slo_class=slo)
        assert child.count == sum(1 for r in responses if r.slo == slo)


def test_des_without_selector_unchanged():
    """selector=None keeps the pre-quality dispatch path bit-identical."""
    runs = []
    for sel in (None, None):
        des = Q.DESBackend(MIX_G, VARIANTS, Q.DESConfig(jitter_sigma=0.0),
                           policy="fifo", ci_g_per_kwh=300.0,
                           quality_selector=sel)
        responses = serve_workload(
            des, [_req(i, arrival=i * 1.0) for i in range(6)])
        assert all(r.variant is not None for r in responses)
        runs.append([(r.rid, r.variant, r.latency_s, r.energy_j)
                     for r in responses])
    assert runs[0] == runs[1]


# =============================================================================
# cross-backend conformance: one workload, identical decision sequences
# =============================================================================
def _conformance_workload():
    """Arrival clocks in real wall-able range (< 1 s) so the REAL engine
    replays the same open-loop schedule the simulators do; the stepped grid
    is dirty for the first 0.2 s of decision time."""
    rng = np.random.default_rng(11)
    reqs = []
    for i in range(10):
        kw = {}
        if i == 6:
            kw["quality_hint"] = "x0.5"
        if i == 3:          # dirty window: would downshift without the floor
            kw["min_accuracy"] = 0.82
        reqs.append(InferenceRequest(
            rid=i, prompt=rng.integers(0, CFG.vocab_size, size=4)
            .astype(np.int32), max_new_tokens=4,
            slo=DEFERRABLE if i % 2 else INTERACTIVE,
            arrival_s=i * 0.05, **kw))
    return reqs


def _conformance_selector():
    return make_selector(
        "governed", ci_fn=lambda t: 500.0 if t < 0.2 else 50.0,
        dirty_threshold_g=300.0, sustain_s=0.1, floors={DEFERRABLE: 0.82})


def test_decision_sequence_identical_across_all_backends(family):
    variants = [ev.variant for ev in family]        # x0.5 / x1
    g = CG.ConfigGraph.from_dict(CFG.name, {("x0.5", 16): 1, ("x1", 16): 1})
    sequences = {}
    joules = {}

    for layout in ("slotted", "paged"):
        sel = _conformance_selector()
        eng = ENG.RealEngine(family, n_slots=2, max_len=32, kv_layout=layout,
                             block_size=8, policy="fifo", ci_g_per_kwh=100.0,
                             quality_selector=sel)
        eng.configure(g)
        responses = serve_workload(eng, _conformance_workload())
        m = eng.stats()
        assert m["served"] == 10
        dec_of = {d.rid: d for d in sel.decisions}
        for r in responses:
            # the engine ran each request on the instance the decision
            # named — served variant AND accuracy match the decision
            assert r.variant == dec_of[r.rid].variant, (layout, r.rid)
            assert r.accuracy == dec_of[r.rid].accuracy
        joules[layout] = (sum(r.energy_j for r in responses), m["energy_j"])
        sequences[layout] = sel.decision_sequence()

    sel = _conformance_selector()
    des = Q.DESBackend(g, variants, Q.DESConfig(jitter_sigma=0.0),
                       policy="fifo", ci_g_per_kwh=100.0,
                       quality_selector=sel)
    responses = serve_workload(des, _conformance_workload())
    dec_of = {d.rid: d for d in sel.decisions}
    for r in responses:
        assert r.variant == dec_of[r.rid].variant
    joules["des"] = (sum(r.energy_j for r in responses),
                     des.stats()["energy_j"])
    sequences["des"] = sel.decision_sequence()

    sel = _conformance_selector()
    fb = FluidBackend(g, variants, sla_target_s=1.0, window_s=0.25,
                      ci_g_per_kwh=100.0, quality_selector=sel)
    responses = serve_workload(fb, _conformance_workload())
    assert len(responses) == 10
    dec_of = {d.rid: d for d in sel.decisions}
    for r in responses:
        # the fluid model serves aggregates, but each response still
        # carries its decided rung (decision → attribution overlay)
        assert r.variant == dec_of[r.rid].variant
        assert r.accuracy == dec_of[r.rid].accuracy
    sequences["fluid"] = sel.decision_sequence()

    # THE contract: one workload, one decision sequence, four backends
    assert sequences["slotted"] == sequences["paged"] == sequences["des"] \
        == sequences["fluid"]
    # the sequence is non-trivial: both rungs appear, and the per-request
    # clamps fired
    chosen = {v for _, v, _ in sequences["des"]}
    reasons = {why for _, _, why in sequences["des"]}
    assert chosen == {"x0.5", "x1"}
    assert "hint" in reasons and "min_accuracy" in reasons
    # per-request joules still sum exactly to each backend's session total
    for name, (attributed, total) in joules.items():
        assert attributed == pytest.approx(total, rel=1e-9), name


def test_real_engine_labels_accuracy_by_slo_class(family):
    g = CG.ConfigGraph.from_dict(CFG.name, {("x0.5", 16): 1, ("x1", 16): 1})
    eng = ENG.RealEngine(family, n_slots=2, max_len=32, policy="fifo",
                         quality_selector=make_selector(
                             "static", pins={DEFERRABLE: "x0.5"}))
    eng.configure(g)
    rng = np.random.default_rng(13)
    reqs = [InferenceRequest(
        rid=i, prompt=rng.integers(0, CFG.vocab_size, size=4)
        .astype(np.int32), max_new_tokens=4,
        slo=DEFERRABLE if i % 2 else INTERACTIVE) for i in range(8)]
    responses = serve_workload(eng, reqs)
    reg = eng.last_registry
    for slo in (INTERACTIVE, DEFERRABLE):
        child = reg.labeled("accuracy", slo_class=slo)
        assert child.count == sum(1 for r in responses if r.slo == slo)
    # the pin held: every deferrable response served on the small rung
    assert all(r.variant == "x0.5" for r in responses if r.slo == DEFERRABLE)
    assert all(r.variant == "x1" for r in responses if r.slo == INTERACTIVE)
