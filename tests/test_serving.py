"""Serving stack: DES queue (hedging, failures), fluid simulator, schemes,
controller, carbon accounting."""
import random

import numpy as np
import pytest

from repro.core import carbon as CB
from repro.core import catalog as CAT
from repro.core import config_graph as CG
from repro.core import objective as OBJ
from repro.core import perf_model as PM
from repro.serving import queue as Q
from repro.serving import simulator as SIM

VARIANTS = CAT.get_family("efficientnet")


def test_carbon_trace_properties():
    for region in ("CISO-March", "CISO-September", "ESO-March"):
        tr = CB.make_trace(region, hours=48)
        assert tr.duration_s == pytest.approx(48 * 3600, rel=0.01)
        assert tr.intensity.min() >= 40.0
        # paper: >200 gCO2/kWh swings within half a day
        half_day = int(12 * 3600 / (tr.times_s[1] - tr.times_s[0]))
        swings = [np.ptp(tr.intensity[i:i + half_day])
                  for i in range(0, len(tr.intensity) - half_day, half_day)]
        assert max(swings) > 150.0, region


def test_carbon_accounting_identity():
    tr = CB.CarbonTrace("const", np.array([0.0, 3600.0]), np.array([360.0, 360.0]))
    acct = CB.CarbonAccountant(tr, pue=1.5)
    g = acct.add(0.0, 3600.0, 1000.0)      # 1 kW for 1 h = 1 kWh
    assert g == pytest.approx(1.0 * 360.0 * 1.5)


def test_des_matches_analytic_capacity():
    g = CG.ConfigGraph.uniform("efficientnet", "B3", 4, 1)
    res_an = OBJ.evaluate(g, VARIANTS, 1e-9)
    arrival = res_an.capacity_rps * 0.5
    des = Q.run_des(g, VARIANTS, arrival, horizon_s=60.0,
                    des=Q.DESConfig(jitter_sigma=0.01, seed=1))
    assert des.served > 0.9 * arrival * 55
    # p95 within 3x of the nominal service latency at moderate load
    nominal = PM.cached_point(VARIANTS[1], 4).latency_s
    assert des.p95() < 3.0 * nominal


def test_des_hedging_tames_stragglers():
    g = CG.ConfigGraph.uniform("efficientnet", "B3", 4, 1)
    arrival = OBJ.evaluate(g, VARIANTS, 1e-9).capacity_rps * 0.3
    cfg_no = Q.DESConfig(straggler_prob=0.03, straggler_mult=20.0, seed=2)
    cfg_hedge = Q.DESConfig(straggler_prob=0.03, straggler_mult=20.0,
                            hedge=True, hedge_factor=3.0, seed=2)
    r_no = Q.run_des(g, VARIANTS, arrival, 120.0, cfg_no)
    r_h = Q.run_des(g, VARIANTS, arrival, 120.0, cfg_hedge)
    assert r_h.hedges > 0
    assert r_h.p95() < r_no.p95(), "hedging must cut the straggler tail"


def test_des_failures_requeue_no_loss():
    g = CG.ConfigGraph.uniform("efficientnet", "B1", 1, 1)   # 16 instances
    arrival = OBJ.evaluate(g, VARIANTS, 1e-9).capacity_rps * 0.2
    des = Q.DESConfig(fail_rate_per_instance_hz=1 / 30.0, repair_time_s=5.0,
                      seed=3)
    r = Q.run_des(g, VARIANTS, arrival, 60.0, des)
    assert r.failures > 0
    assert r.served > 0.85 * arrival * 50, "failures must not lose requests"


def test_simulator_scheme_ordering():
    """Paper Figs. 9/10 structure on a short trace: CO2OPT saves the most
    carbon with the worst accuracy; CLOVER beats BLOVER on f; ORACLE ≥ CLOVER;
    all schemes meet the SLA on average."""
    tr = CB.make_trace("CISO-March", hours=4)
    rep = SIM.compare_schemes("efficientnet", tr,
                              schemes=("BASE", "CO2OPT", "BLOVER", "CLOVER",
                                       "ORACLE"),
                              sim=SIM.SimConfig(n_blocks=2))
    sv = SIM.savings_vs_base(rep)
    lam = 0.1

    def f(name):
        return (lam * sv[name]["carbon_saving_pct"]
                + (1 - lam) * sv[name]["accuracy_delta_pct"])

    assert sv["CO2OPT"]["carbon_saving_pct"] >= sv["CLOVER"]["carbon_saving_pct"]
    assert rep["CO2OPT"].accuracy < rep["CLOVER"].accuracy
    assert f("CLOVER") > f("BLOVER"), "graph optimizer must beat random search"
    assert f("ORACLE") >= f("CLOVER") - 0.3
    assert f("CLOVER") >= 0.75 * f("ORACLE"), "Clover should approach Oracle"
    assert sv["CLOVER"]["carbon_saving_pct"] > 30.0
    assert rep["CLOVER"].accuracy > 0.98 * rep["BASE"].accuracy
    assert rep["CLOVER"].p95_latency_s <= rep["CLOVER"].sla_target_s * 1.05
    assert rep["CLOVER"].opt_time_frac < 0.05


def test_controller_reinvocation_threshold():
    import random as _r
    from repro.core import annealing as SA
    from repro.core import controller as CTRL
    from repro.core import schemes as SCH
    ctx, _ = SIM.make_context("efficientnet", SIM.SimConfig(n_blocks=1))
    c = CTRL.Controller(SCH.make_scheme("CLOVER"), ctx)
    c.start(0.0, 300.0)
    n0 = len(c.invocations)
    assert not c.should_reoptimize(305.0)     # 1.7 % change: below threshold
    assert c.should_reoptimize(330.0)         # 10 % change: re-invoke
    c.maybe_reoptimize(60.0, 330.0)
    assert len(c.invocations) == n0 + 1


def test_controller_elastic_scaling():
    ctx, _ = SIM.make_context("efficientnet", SIM.SimConfig(n_blocks=2))
    from repro.core import controller as CTRL
    from repro.core import schemes as SCH
    c = CTRL.Controller(SCH.make_scheme("BASE"), ctx)
    g0 = c.start(0.0, 300.0)
    chips0 = g0.total_chips
    g1 = c.scale_blocks(+2)
    assert g1.total_chips == chips0 * 2
    g2 = c.scale_blocks(-2)
    assert g2.total_chips == chips0


def test_engine_real_generation_quality_ladder():
    """Real-execution engine: deeper variants are measurably slower."""
    import jax.numpy as jnp
    from repro.configs import get_smoke_config
    from repro.serving import engine as ENG
    base = get_smoke_config("qwen3-1.7b").with_(n_layers=8, dtype=jnp.float32)
    fam = ENG.build_engine_family(base, fracs=(1.0, 0.25))
    eng = ENG.RealEngine(fam)
    g = CG.ConfigGraph.from_dict(base.name, {("x0.25", 8): 1, ("x1", 8): 1})
    eng.configure(g)
    prompts = [np.array([[1, 2, 3, 4]], dtype=np.int32) for _ in range(4)]
    m = eng._serve_prompts(prompts, n_new=4)
    assert m["served"] == 4 and m["p95_s"] > 0 and m["energy_j"] > 0
    # depth ladder: measure each variant directly.  The one-pass engine is
    # fast enough that fixed dispatch overhead hides depth on tiny decodes,
    # so time a longer generation, best-of-3 after a jit warmup run.
    i_small = ENG.Instance(fam[0], 8, max_len=64)
    i_big = ENG.Instance(fam[1], 8, max_len=64)
    n_new = 32
    i_small.generate(prompts[0], n_new)            # warm: jit compile
    i_big.generate(prompts[0], n_new)
    t_small = min(i_small.generate(prompts[0], n_new)[1] for _ in range(3))
    t_big = min(i_big.generate(prompts[0], n_new)[1] for _ in range(3))
    assert t_big > t_small, (t_big, t_small)


def test_lm_ladders_all_archs():
    """Every assigned architecture yields a usable Clover quality ladder
    (DESIGN.md §Arch-applicability: no arch is inapplicable)."""
    from repro.configs import ARCHS
    for arch in ARCHS:
        vs = CAT.get_family(arch)
        assert len(vs) >= 3, arch
        accs = [v.accuracy for v in sorted(vs, key=lambda v: v.quality)]
        assert accs == sorted(accs), f"{arch}: ladder accuracy not monotone"
        assert all(CAT.feasible_slices(v) for v in vs), f"{arch}: OOM on all slices"
        flops = [v.flops_g for v in sorted(vs, key=lambda v: v.quality)]
        assert flops == sorted(flops), f"{arch}: ladder flops not monotone"


def test_perf_model_monotonicity():
    """Latency decreases (to a floor) with slice size for big models and
    energy/request increases with slice size at full load."""
    vs = CAT.get_family("efficientnet")
    big = vs[-1]
    lat = [PM.service_point(big, c).latency_s for c in (1, 4, 16)]
    assert lat[0] > lat[1] > lat[2] * 0.99, lat   # B7 keeps speeding up
    small = vs[0]
    e = [PM.service_point(small, c).energy_per_req_j for c in (1, 4, 16)]
    assert e[0] < e[1] < e[2], e                  # fine slices win on energy


def test_block_failure_recovery():
    """Serving-layer fault tolerance: losing a block removes exactly one
    block's worth of chips; re-optimization restores SLA for the reduced
    fleet (examples/elastic_failure.py, compact)."""
    ctx, arrival = SIM.make_context("efficientnet", SIM.SimConfig(n_blocks=2))
    from repro.core import controller as CTRL
    from repro.core import schemes as SCH
    ctrl = CTRL.Controller(SCH.make_scheme("CLOVER"), ctx)
    ctrl.start(0.0, 300.0)
    chips0 = ctrl.config.total_chips
    ctrl.scale_blocks(-1)
    assert ctrl.config.total_chips == chips0 - 16
    ctrl.last_opt_ci = None
    cfg, outcome = ctrl.maybe_reoptimize(100.0, 300.0)
    res = OBJ.evaluate(cfg, ctx.variants, arrival)
    assert res.p95_latency_s <= ctx.obj_cfg.l_tail_s * 1.05, "SLA must recover"
    ctrl.scale_blocks(+1)
    assert ctrl.config.total_chips == chips0
