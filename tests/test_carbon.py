"""Carbon accounting + trace tooling + forecaster backtests (ISSUE 1
satellite: accounting correctness against analytic integrals, CSV round-trip,
forecast error bounds on the synthetic regions)."""
import numpy as np
import pytest

from repro.core import carbon as CB
from repro.fleet import forecast as FC


def _linear_trace(a=200.0, b=0.01, horizon_s=7200.0):
    t = np.array([0.0, horizon_s])
    return CB.CarbonTrace("linear", t, a + b * t)


def test_accountant_midpoint_exact_on_linear_trace():
    """Midpoint rule integrates a linear CI exactly: for ci(t) = a + b·t and
    constant power P over [t0, t0+d],
    ∫ P·ci dt = P·d·ci(t0 + d/2)."""
    a, b = 200.0, 0.01
    tr = _linear_trace(a, b)
    acct = CB.CarbonAccountant(tr, pue=1.5)
    t0, d, p = 600.0, 1800.0, 4000.0
    g = acct.add(t0, d, p)
    exact = (p * d / 3.6e6) * (a + b * (t0 + d / 2.0)) * 1.5
    assert g == pytest.approx(exact, rel=1e-12)
    assert acct.carbon_g == pytest.approx(exact, rel=1e-12)
    assert acct.energy_j == pytest.approx(p * d)


def test_accountant_accumulates_segments():
    tr = _linear_trace()
    acct = CB.CarbonAccountant(tr)
    total = sum(acct.add(i * 600.0, 600.0, 1000.0) for i in range(6))
    assert acct.carbon_g == pytest.approx(total)
    # sum of exact segment integrals == exact integral over the union
    one = CB.CarbonAccountant(tr).add(0.0, 3600.0, 1000.0)
    assert total == pytest.approx(one, rel=1e-12)


def test_load_trace_csv_round_trip(tmp_path):
    tr = CB.make_trace("CISO-March", hours=2.0)
    path = tmp_path / "trace.csv"
    rows = ["seconds,gco2_per_kwh"] + [
        f"{t},{ci}" for t, ci in zip(tr.times_s, tr.intensity)]
    path.write_text("\n".join(rows) + "\n")
    back = CB.load_trace_csv(str(path), name="round-trip")
    np.testing.assert_allclose(back.times_s, tr.times_s)
    np.testing.assert_allclose(back.intensity, tr.intensity)
    assert back.at(1234.5) == pytest.approx(tr.at(1234.5))


def test_trace_slice_and_history():
    tr = CB.make_trace("ESO-March", hours=12.0)
    s = tr.slice(3600.0, 7200.0)
    assert s.times_s[0] == 0.0
    assert s.duration_s == pytest.approx(3600.0)
    assert s.at(0.0) == pytest.approx(tr.at(3600.0))
    assert s.at(1800.0) == pytest.approx(tr.at(5400.0))
    h = tr.history(7200.0)
    assert h.times_s[-1] <= 7200.0
    assert len(h.times_s) < len(tr.times_s)
    with pytest.raises(ValueError):
        tr.slice(5000.0, 5000.0)


def test_window_mean_matches_trapezoid():
    tr = _linear_trace(100.0, 0.02)
    # linear trace: window mean == midpoint value
    assert tr.window_mean(1000.0, 3000.0) == pytest.approx(
        100.0 + 0.02 * 2000.0, rel=1e-9)


# =============================================================================
# forecaster backtests on the synthetic regions
# =============================================================================
def test_harmonic_beats_persistence_on_solar_regions():
    """CISO's diurnal solar valley is near-periodic: with a day of history,
    the harmonic regression must beat persistence at multi-hour horizons."""
    for region in ("CISO-March", "CISO-September"):
        tr = CB.make_trace(region, hours=60.0)
        h = FC.backtest(FC.make_forecaster("harmonic", tr), 6 * 3600.0)
        p = FC.backtest(FC.make_forecaster("persistence", tr), 6 * 3600.0)
        assert h.mae < p.mae, region
        assert h.mape < 0.30, region


def test_ensemble_never_much_worse_than_best_member():
    """The inverse-error ensemble must track the better member per region —
    in particular on wind-dominated ESO, where the 24 h harmonic basis fails
    badly and pure harmonic would mislead the shifting planner."""
    for region in ("CISO-March", "ESO-March"):
        tr = CB.make_trace(region, hours=60.0)
        members = {n: FC.backtest(FC.make_forecaster(n, tr), 6 * 3600.0).mae
                   for n in ("persistence", "harmonic")}
        ens = FC.backtest(FC.make_forecaster("ensemble", tr), 6 * 3600.0).mae
        assert ens < max(members.values()), (region, members, ens)
        assert ens < 1.6 * min(members.values()), (region, members, ens)


def test_persistence_good_at_short_horizons():
    tr = CB.make_trace("CISO-March", hours=48.0)
    p = FC.backtest(FC.make_forecaster("persistence", tr), 1800.0)
    assert p.mape < 0.15


def test_forecaster_cold_start_falls_back():
    tr = CB.make_trace("CISO-March", hours=24.0)
    f = FC.make_forecaster("harmonic", tr)
    # with one sample of history the forecaster must not crash and should
    # return the persistence value
    assert f.predict(0.0, 3600.0) == pytest.approx(tr.at(0.0))


def test_predict_series_shape():
    tr = CB.make_trace("CISO-March", hours=48.0)
    f = FC.make_forecaster("harmonic", tr)
    series = f.predict_series(24 * 3600.0, 6 * 3600.0, 1800.0)
    assert len(series) == 12
    assert np.all(series >= 1.0)
