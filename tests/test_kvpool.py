"""Paged KV-cache subsystem: allocator invariants, radix prefix-cache
hit/evict properties, paged-attention kernel vs oracle, chunked-prefill /
paged-decode model parity, and paged-vs-slotted engine token parity with
full arena reclamation."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.core import config_graph as CG
from repro.models import registry as R
from repro.serving import engine as ENG
from repro.serving.kvpool import BlockAllocator, OutOfBlocks, RadixPrefixCache

CFG = get_smoke_config("qwen3-1.7b").with_(n_layers=2, dtype=jnp.float32)
KEY = jax.random.PRNGKey(0)


@pytest.fixture(scope="module")
def family():
    return ENG.build_engine_family(CFG, fracs=(1.0,))


@pytest.fixture(scope="module")
def params(family):
    return family[0].params


# =============================================================================
# block allocator
# =============================================================================
def test_allocator_alloc_free_roundtrip():
    a = BlockAllocator(9, 16)
    assert a.num_allocatable == 8 and a.num_free == 8
    bids = a.alloc(5)
    assert len(set(bids)) == 5 and 0 not in bids      # junk block never leaves
    assert a.num_free == 3 and a.blocks_in_use() == 5
    assert a.free(bids) == bids                       # all reclaimed
    assert a.num_free == 8
    a.check()


def test_allocator_refcounting_and_double_free():
    a = BlockAllocator(5, 8)
    (b1,) = a.alloc(1)
    a.incref([b1])
    assert a.refcount(b1) == 2
    assert a.free([b1]) == []                         # still one ref out
    assert a.refcount(b1) == 1
    assert a.free([b1]) == [b1]                       # last ref reclaims
    with pytest.raises(ValueError):
        a.free([b1])                                  # double free
    with pytest.raises(ValueError):
        a.incref([b1])                                # resurrect is a bug
    a.check()


def test_allocator_out_of_blocks_and_copy_on_write():
    a = BlockAllocator(4, 8)
    bids = a.alloc(3)
    with pytest.raises(OutOfBlocks):
        a.alloc(1)
    # exclusive block: COW is the identity
    assert a.copy_on_write(bids[0]) == bids[0]
    # shared block: a fresh block replaces the caller's reference
    a.free(bids[1:])                                  # make room
    a.incref([bids[0]])
    new = a.copy_on_write(bids[0])
    assert new != bids[0]
    assert a.refcount(bids[0]) == 1 and a.refcount(new) == 1
    a.free([bids[0]])
    a.free([new])
    a.check()
    assert a.num_free == a.num_allocatable


# =============================================================================
# radix prefix cache
# =============================================================================
def _seq_admit(alloc, cache, toks):
    """Admission protocol the engine uses: match, then allocate the rest of
    the FULL-sequence table.  Returns the owned block list (refs held)."""
    matched, n_cached = cache.match(toks)
    need = alloc.blocks_for_tokens(len(toks)) - len(matched)
    if need > alloc.num_free:
        cache.evict(need - alloc.num_free)
    blocks = matched + alloc.alloc(need)
    cache.insert(toks, blocks)
    return blocks


def test_radix_match_caps_one_token_short():
    a = BlockAllocator(17, 4)
    c = RadixPrefixCache(a)
    toks = list(range(8))                             # exactly 2 full blocks
    blocks = _seq_admit(a, c, toks)
    a.free(blocks)
    # identical prompt: only 1 of its 2 full blocks may match — the last
    # token must be prefilled for real logits, pinning block 2 out of reach
    m, n = c.match(toks)
    assert n == 4 and len(m) == 1
    a.free(m)


def test_radix_hit_shares_blocks_and_refcounts():
    a = BlockAllocator(33, 4)
    c = RadixPrefixCache(a)
    sys_prompt = list(range(12))                      # 3 full blocks
    s1 = _seq_admit(a, c, sys_prompt + [90, 91, 92, 93, 94])
    s2 = _seq_admit(a, c, sys_prompt + [70, 71])
    assert s2[:3] == s1[:3]                           # shared prefix blocks
    for b in s1[:3]:
        assert a.refcount(b) == 3                     # tree + two sequences
    a.free(s1)
    a.free(s2)
    for b in s1[:3]:
        assert a.refcount(b) == 1                     # cached, tree-owned
    ev = c.evictable_blocks()
    assert ev == len(c)                               # nothing pinned now
    assert c.clear() == ev
    a.check()
    assert a.num_free == a.num_allocatable


def test_radix_lru_eviction_prefers_cold_and_skips_pinned():
    a = BlockAllocator(9, 4)                          # 8 usable blocks
    c = RadixPrefixCache(a)
    cold = _seq_admit(a, c, list(range(100, 108)))    # 2 blocks
    a.free(cold)
    hot = _seq_admit(a, c, list(range(200, 208)))     # 2 blocks, still held
    # demand more than free: eviction must take the cold unreferenced leaf
    # chain and must NOT touch hot's pinned blocks
    fresh = a.alloc(a.num_free)
    c.evict(2)
    assert a.refcount(hot[0]) == 2                    # pinned survived
    assert c.evictions >= 2
    a.free(fresh)
    a.free(hot)
    c.clear()
    a.check()
    assert a.num_free == a.num_allocatable


def test_radix_evictable_counts_unpinned_branches_under_pinned_chain():
    """A pinned node (live reader of the shared prefix) must not zero the
    evictable count of its unpinned sibling branches or descendants —
    otherwise block-availability admission degrades to free-list-only
    exactly when the prefix cache is being shared."""
    a = BlockAllocator(33, 4)
    c = RadixPrefixCache(a)
    sysp = list(range(8))                             # 2-block shared chain
    s1 = _seq_admit(a, c, sysp + [50, 51, 52, 53])    # chain + suffix A
    a.free(s1)                                        # suffix A now tree-only
    s2 = _seq_admit(a, c, sysp + [60, 61, 62, 63])    # live: pins the chain
    # chain pinned by s2, s2's own suffix pinned by s2 — but s1's released
    # suffix leaf is reclaimable and must be counted (and evictable)
    assert c.evictable_blocks() == 1
    assert c.evict(1) == 1
    a.free(s2)
    c.clear()
    a.check()
    assert a.num_free == a.num_allocatable


def _radix_property_trail(ops_seed: int, n_ops: int = 60) -> None:
    """Shared property loop: random admissions/releases over a small token
    alphabet (forcing prefix collisions) with invariants checked on every
    step — the allocator partitions the id space, matches are block-aligned
    and capped one token short, eviction never frees a referenced block,
    and teardown reclaims the whole arena."""
    rng = np.random.default_rng(ops_seed)
    a = BlockAllocator(33, 4)
    c = RadixPrefixCache(a)
    live = []
    for _ in range(n_ops):
        if live and rng.random() < 0.4:
            a.free(live.pop(rng.integers(len(live))))
            a.check()
            continue
        toks = [int(t) for t in rng.integers(0, 3, size=rng.integers(1, 20))]
        matched, n_cached = c.match(toks)
        assert n_cached % a.block_size == 0
        assert n_cached <= max(len(toks) - 1, 0)
        need = a.blocks_for_tokens(len(toks)) - len(matched)
        if need > a.num_free:
            c.evict(need - a.num_free)
        if need > a.num_free:
            if matched:
                a.free(matched)                       # admission rejected
            a.check()
            continue
        blocks = matched + a.alloc(need)
        assert len(set(blocks)) == len(blocks)
        c.insert(toks, blocks)
        for b in blocks:
            assert a.refcount(b) >= 1
        live.append(blocks)
        a.check()
    for blocks in live:
        a.free(blocks)
    c.clear()
    a.check()
    assert a.num_free == a.num_allocatable
    assert len(c) == 0


def test_radix_property_trail_seeded():
    for seed in range(8):
        _radix_property_trail(seed)


try:
    from hypothesis import given, settings, strategies as st

    @settings(max_examples=25, deadline=None)
    @given(st.integers(min_value=0, max_value=10_000))
    def test_radix_property_trail_hypothesis(ops_seed):
        _radix_property_trail(ops_seed)
except ImportError:                                   # pragma: no cover
    pass                                              # seeded twin still runs


# =============================================================================
# paged attention kernel vs oracle
# =============================================================================
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("b,nb,bs,H,K,dh,n_pages", [
    (3, 9, 16, 4, 2, 64, 4),
    (1, 5, 32, 8, 8, 64, 3),
    (2, 17, 16, 6, 1, 128, 8),
])
def test_paged_decode_attention_kernel_vs_ref(b, nb, bs, H, K, dh, n_pages,
                                              dtype):
    from repro.kernels import ops, ref as REF
    q = jax.random.normal(KEY, (b, H, dh), dtype)
    ka = jax.random.normal(jax.random.fold_in(KEY, 1), (nb, bs, K, dh), dtype)
    va = jax.random.normal(jax.random.fold_in(KEY, 2), (nb, bs, K, dh), dtype)
    rng = np.random.default_rng(0)
    tables = np.zeros((b, n_pages), np.int32)
    lengths = np.zeros((b,), np.int32)
    for i in range(b):
        used = rng.integers(1, n_pages + 1)
        tables[i, :used] = rng.choice(np.arange(1, nb), size=used,
                                      replace=False)
        lengths[i] = rng.integers(1, used * bs + 1)
    out = ops.paged_decode_attention(q, ka, va, jnp.asarray(tables),
                                     jnp.asarray(lengths))
    ref = REF.paged_decode_attention_ref(q, ka, va, jnp.asarray(tables),
                                         jnp.asarray(lengths))
    rtol, atol = (2e-2, 2e-2) if dtype == jnp.bfloat16 else (3e-5, 3e-5)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32),
                               rtol=rtol, atol=atol)


def test_paged_ref_equals_gathered_contiguous_ref():
    """The paged oracle is literally gather + the slotted oracle — the two
    masking contracts cannot drift."""
    from repro.kernels import ref as REF
    b, nb, bs, H, K, dh, P = 2, 7, 8, 4, 2, 16, 3
    q = jax.random.normal(KEY, (b, H, dh))
    ka = jax.random.normal(jax.random.fold_in(KEY, 3), (nb, bs, K, dh))
    va = jax.random.normal(jax.random.fold_in(KEY, 4), (nb, bs, K, dh))
    tables = jnp.array([[1, 2, 3], [4, 5, 0]], jnp.int32)
    lengths = jnp.array([20, 11], jnp.int32)
    kc = ka[tables].reshape(b, P * bs, K, dh)
    vc = va[tables].reshape(b, P * bs, K, dh)
    np.testing.assert_allclose(
        np.asarray(REF.paged_decode_attention_ref(q, ka, va, tables, lengths)),
        np.asarray(REF.decode_attention_ref(q, kc, vc, lengths)),
        rtol=1e-6, atol=1e-6)


# =============================================================================
# model level: chunked prefill + paged decode
# =============================================================================
def test_chunked_prefill_matches_full_forward(params):
    """Prefilling in chunks through the paged arena reproduces the full
    forward's last-position logits — chunking changes scheduling, not math."""
    toks = jax.random.randint(jax.random.fold_in(KEY, 5), (1, 13), 0,
                              CFG.vocab_size)
    ref, _ = R.forward(params, {"tokens": toks}, CFG)
    bs, P, C = 4, 8, 8
    arena = R.make_block_arena(CFG, 16, bs, dtype=jnp.float32)
    table = jnp.array([1, 2, 3, 4, 5, 0, 0, 0], jnp.int32)
    n_past, last = 0, None
    while n_past < 13:
        true_c = min(C, 13 - n_past)
        chunk = np.zeros((1, C), np.int32)
        chunk[0, :true_c] = np.asarray(toks)[0, n_past:n_past + true_c]
        lg, arena = R.prefill_paged(params, {"tokens": jnp.asarray(chunk)},
                                    CFG, arena, table, n_past, true_c)
        last = lg[0, true_c - 1]
        n_past += true_c
    np.testing.assert_allclose(np.asarray(last), np.asarray(ref[0, 12]),
                               rtol=2e-4, atol=2e-4)


def test_paged_decode_matches_slotted_decode(params):
    """Greedy continuation through the paged arena equals the slotted cache
    token-for-token, junk rows riding along."""
    toks = jax.random.randint(jax.random.fold_in(KEY, 6), (1, 13), 0,
                              CFG.vocab_size)
    bs, P, n_new = 4, 8, 5
    # paged: chunked prefill, then batched decode with 2 inactive junk rows
    arena = R.make_block_arena(CFG, 16, bs, dtype=jnp.float32)
    table = np.array([1, 2, 3, 4, 5, 0, 0, 0], np.int32)   # 5 blocks: 13+5 toks
    n_past = 0
    while n_past < 13:
        true_c = min(8, 13 - n_past)
        chunk = np.zeros((1, 8), np.int32)
        chunk[0, :true_c] = np.asarray(toks)[0, n_past:n_past + true_c]
        lg, arena = R.prefill_paged(params, {"tokens": jnp.asarray(chunk)},
                                    CFG, arena, jnp.asarray(table), n_past,
                                    true_c)
        n_past += true_c
    first = int(jnp.argmax(lg[0, true_c - 1]))
    # slotted reference
    cache = R.make_slot_cache(CFG, 1, 32, dtype=jnp.float32)
    lgs, k_all, v_all = R.prefill_kv(params, {"tokens": toks}, CFG)
    cache["k"] = cache["k"].at[:, 0, :13].set(k_all[:, 0])
    cache["v"] = cache["v"].at[:, 0, :13].set(v_all[:, 0])
    cache["lengths"] = jnp.array([13], jnp.int32)
    assert int(jnp.argmax(lgs[0, 12])) == first

    tables = np.zeros((3, P), np.int32)
    tables[1] = table
    lengths = np.array([0, 13, 0], np.int32)
    active = np.array([False, True, False])
    nxt_p = np.zeros((3, 1), np.int32)
    nxt_p[1, 0] = first
    nxt_s = jnp.array([[first]], jnp.int32)
    for _ in range(n_new - 1):
        lg_s, cache = R.decode_slots(params, cache, {"tokens": nxt_s}, CFG,
                                     jnp.array([True]))
        lg_p, arena = R.decode_paged(params, arena,
                                     {"tokens": jnp.asarray(nxt_p)}, CFG,
                                     jnp.asarray(tables),
                                     jnp.asarray(lengths),
                                     jnp.asarray(active))
        ts, tp = int(jnp.argmax(lg_s[0])), int(jnp.argmax(lg_p[1]))
        assert ts == tp
        np.testing.assert_allclose(np.asarray(lg_p[1]), np.asarray(lg_s[0]),
                                   rtol=2e-4, atol=2e-4)
        lengths[1] += 1
        nxt_s = jnp.array([[ts]], jnp.int32)
        nxt_p[1, 0] = tp


# =============================================================================
# engine level: paged vs slotted parity, reclamation, open loop
# =============================================================================
def _mixed_prompts(vocab, seed=3):
    """Mixed-length prompts, the longer ones sharing a 16-token prefix."""
    rng = np.random.default_rng(seed)
    shared = rng.integers(0, vocab, size=16).astype(np.int32)
    prompts = []
    for L in (4, 10, 24, 40, 4, 24):
        p = rng.integers(0, vocab, size=L).astype(np.int32)
        if L >= 24:
            p[:16] = shared
        prompts.append(p)
    return prompts


def test_engine_paged_matches_slotted_token_for_token(family):
    """The acceptance gate: on mixed prompt lengths with a shared prefix the
    paged engine (block admission + chunked prefill + radix sharing)
    reproduces the slotted engine's greedy outputs exactly, while admitting
    more concurrency than slots would allow."""
    g = CG.ConfigGraph.from_dict(CFG.name, {("x1", 16): 1})
    prompts = _mixed_prompts(CFG.vocab_size)

    slotted = ENG.RealEngine(family, n_slots=2, max_len=48)
    slotted.configure(g)
    slotted._serve_prompts(prompts, n_new=6)
    out_s = dict(slotted.last_outputs)

    paged = ENG.RealEngine(family, n_slots=2, max_len=48, kv_layout="paged",
                           block_size=8, max_seqs=6)
    paged.configure(g)
    m = paged._serve_prompts(prompts, n_new=6)
    out_p = dict(paged.last_outputs)

    assert set(out_s) == set(out_p)
    for rid in out_s:
        np.testing.assert_array_equal(out_s[rid], out_p[rid])
    assert m["prefix_hit_tokens"] > 0          # the shared prefix was shared
    assert m["blocks_peak"] > 0
    assert m["prefill_chunks"] >= len(prompts)
    # FIFO admission order preserved under block-aware peek admission
    assert paged.last_admit_order == sorted(paged.last_admit_order)


def test_engine_paged_arena_fully_reclaimed(family):
    """After a serve, live sequences hold nothing; after dropping the prefix
    cache the allocator is whole again (refcounts hit zero, no leaks)."""
    g = CG.ConfigGraph.from_dict(CFG.name, {("x1", 16): 1})
    eng = ENG.RealEngine(family, n_slots=2, max_len=48, kv_layout="paged",
                         block_size=8, max_seqs=4)
    eng.configure(g)
    eng._serve_prompts(_mixed_prompts(CFG.vocab_size, seed=9), n_new=4)
    inst = eng.instances[0]
    inst.alloc.check()
    assert all(s is None for s in inst.rows)
    # only the prefix tree still holds blocks — and exactly its node count
    assert inst.alloc.blocks_in_use() == len(inst.prefix)
    inst.prefix.clear()
    inst.alloc.check()
    assert inst.alloc.num_free == inst.alloc.num_allocatable


def test_engine_paged_admits_beyond_slot_count(family):
    """Block-availability admission: with short prompts the paged engine
    runs more sequences concurrently than the equal-arena slotted engine has
    slots — the whole point of paging."""
    g = CG.ConfigGraph.from_dict(CFG.name, {("x1", 16): 1})
    rng = np.random.default_rng(11)
    prompts = [rng.integers(0, CFG.vocab_size, size=6).astype(np.int32)
               for _ in range(12)]
    # equal arena: slotted 2 × 48 tokens == paged 96 tokens (12 × 8 + junk)
    eng = ENG.RealEngine(family, n_slots=2, max_len=48, kv_layout="paged",
                         block_size=8, max_seqs=8)
    eng.configure(g)
    m = eng._serve_prompts(prompts, n_new=4)
    assert m["served"] == 12
    # 6-token prompt + 4 new = 2 blocks per seq → up to 6 concurrent seqs
    assert m["mean_inflight"] > 2.0


def test_engine_open_loop_reports_queueing(family):
    """Open-loop mode: staggered arrivals yield finite queueing delay and
    TTFT, and every request completes."""
    g = CG.ConfigGraph.from_dict(CFG.name, {("x1", 16): 1})
    eng = ENG.RealEngine(family, n_slots=2, max_len=48, kv_layout="paged",
                         block_size=8, max_seqs=4)
    eng.configure(g)
    m = eng.serve_poisson(rate_rps=50.0, n_requests=12,
                          prompt_lens=(4, 10, 24), n_new=4, seed=1)
    assert m["served"] == 12
    assert np.isfinite(m["queue_delay_p95_s"]) and m["queue_delay_p95_s"] >= 0
    assert m["ttft_p95_s"] > 0
    assert m["p95_s"] >= m["ttft_p95_s"] * 0.0      # sanity: both recorded


@pytest.mark.slow
def test_engine_open_loop_sla_at_sub_saturation(family):
    """Acceptance: at 0.7× the measured saturation rate the open-loop p95
    stays within an SLA derived from the single-request service time —
    queueing is bounded below saturation."""
    g = CG.ConfigGraph.from_dict(CFG.name, {("x1", 16): 1})
    eng = ENG.RealEngine(family, n_slots=4, max_len=48, kv_layout="paged",
                         block_size=8, max_seqs=8)
    eng.configure(g)
    n_new = 6
    rng = np.random.default_rng(0)
    closed = eng._serve_prompts([rng.integers(0, CFG.vocab_size, size=8)
                        .astype(np.int32) for _ in range(24)], n_new=n_new)
    sat_rps = closed["tokens_per_s"] / n_new
    solo = eng._serve_prompts([rng.integers(0, CFG.vocab_size, size=8)
                      .astype(np.int32)], n_new=n_new)
    sla_s = 8.0 * max(solo["p95_s"], 1e-3)
    m = eng.serve_poisson(rate_rps=0.7 * sat_rps, n_requests=40,
                          prompt_lens=(8,), n_new=n_new, seed=2)
    assert m["served"] == 40
    assert np.isfinite(m["queue_delay_p95_s"])
    assert m["p95_s"] <= sla_s, (m["p95_s"], sla_s)
