"""Unified request/response serving API: the ServingBackend protocol across
all backends, pluggable scheduling policies (FIFO ≡ legacy, priority, EDF,
carbon-aware deferral, forecast-driven valley scheduling), the removal of the
serve(prompts=...) shim, paged decode-time preemption with bit-exact (and
partial, tree-backed) restore, per-request energy/carbon attribution, and the
gated re-admission bugfix."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.core import catalog as CAT
from repro.core import config_graph as CG
from repro.core import objective as OBJ
from repro.serving import engine as ENG
from repro.serving import queue as Q
from repro.serving.api import DEFERRABLE, INTERACTIVE, InferenceRequest, \
    InferenceResponse, ServingBackend, serve_workload, summarize_responses
from repro.serving.policies import CarbonAwarePolicy, EDFPolicy, FIFOPolicy, \
    PriorityPolicy, make_policy
from repro.serving.scheduler import SchedulerCore

CFG = get_smoke_config("qwen3-1.7b").with_(n_layers=2, dtype=jnp.float32)
VARIANTS = CAT.get_family("efficientnet")
# ONE instance: policy orderings are only observable when service serializes
DES_G = CG.ConfigGraph.from_dict("efficientnet", {("B3", 1): 1})


@pytest.fixture(scope="module")
def family():
    return ENG.build_engine_family(CFG, fracs=(1.0,))


def _graph():
    return CG.ConfigGraph.from_dict(CFG.name, {("x1", 16): 1})


def _prompts(lens=(4, 10, 24, 40, 4, 24), seed=3):
    rng = np.random.default_rng(seed)
    return [rng.integers(0, CFG.vocab_size, size=L).astype(np.int32)
            for L in lens]


def _requests(prompts, n_new=6, **kw):
    return [InferenceRequest(rid=i, prompt=p, max_new_tokens=n_new, **kw)
            for i, p in enumerate(prompts)]


# =============================================================================
# policies (unit)
# =============================================================================
def _core_with(policy, entries):
    core = SchedulerCore(policy)
    for rid, t, prio, dl, slo in entries:
        core.submit(rid, t, priority=prio, deadline_s=dl, slo=slo)
    return core


def test_policy_orderings():
    entries = [(0, 0.0, 0, 9.0, "interactive"),
               (1, 1.0, 2, None, "interactive"),
               (2, 2.0, 1, 3.0, "interactive")]
    assert _core_with(FIFOPolicy(), entries).pop_next() == (0, 0.0)
    assert _core_with(PriorityPolicy(), entries).pop_next() == (1, 1.0)
    assert _core_with(EDFPolicy(), entries).pop_next() == (2, 2.0)
    with pytest.raises(ValueError):
        make_policy("nope")


def test_carbon_policy_interactive_flows_deferrable_holds():
    ci = {"v": 500.0}
    pol = CarbonAwarePolicy(lambda now: ci["v"], ci_threshold=200.0,
                            est_service_s=1.0, deadline_margin_s=1.0)
    core = _core_with(pol, [(0, 0.0, 0, 100.0, DEFERRABLE),
                            (1, 1.0, 0, None, INTERACTIVE)])
    # interactive bypasses the hold even though it queued second
    assert core.pop_next(now=0.0) == (1, 1.0)
    # dirty grid, wide runway: held (pending but nothing selectable)
    assert core.has_pending() and core.peek_next(now=0.0) is None
    # deadline pressure force-releases regardless of CI
    assert core.peek_next(now=99.0) == (0, 0.0)
    ci["v"] = 100.0                       # grid cleaned up: released
    assert core.pop_next(now=0.0) == (0, 0.0)


# =============================================================================
# shim removal + FIFO ≡ legacy regression
# =============================================================================
def test_serve_shim_removed(family):
    """The ``serve(prompts=...)`` deprecation shim was a one-PR bridge and
    is gone; the typed submit/drain path is the only public surface (the
    internal bulk-prompt helper stays, warning-free)."""
    assert not hasattr(ENG.RealEngine, "serve")
    import warnings

    eng = ENG.RealEngine(family, n_slots=2, max_len=48)
    eng.configure(_graph())
    with warnings.catch_warnings():
        warnings.simplefilter("error", DeprecationWarning)
        m = eng._serve_prompts(_prompts((4, 10)), n_new=4)
    assert m["served"] == 2


def test_bulk_prompts_helper_matches_submit_path(family):
    prompts = _prompts()
    legacy = ENG.RealEngine(family, n_slots=2, max_len=48)
    legacy.configure(_graph())
    m_legacy = legacy._serve_prompts(prompts, n_new=6)

    eng = ENG.RealEngine(family, n_slots=2, max_len=48, policy="fifo")
    eng.configure(_graph())
    responses = serve_workload(eng, _requests(prompts))
    m = eng.stats()
    # token-identical outputs, same FIFO admission order, same counts
    assert eng.last_admit_order == legacy.last_admit_order
    assert m["served"] == m_legacy["served"] == len(prompts)
    assert m["tokens"] == m_legacy["tokens"]
    for rid, toks in legacy.last_outputs.items():
        np.testing.assert_array_equal(toks, eng.last_outputs[rid])
        np.testing.assert_array_equal(
            toks, next(r for r in responses if r.rid == rid).tokens)


def test_stream_callback_sees_every_token_in_order(family):
    prompts = _prompts((4, 24))
    streamed = {}
    reqs = _requests(prompts, n_new=5)
    for r in reqs:
        r.on_token = lambda rid, tok: streamed.setdefault(rid, []).append(tok)
    eng = ENG.RealEngine(family, n_slots=2, max_len=48, kv_layout="paged",
                         block_size=8)
    eng.configure(_graph())
    serve_workload(eng, reqs)
    for rid, toks in eng.last_outputs.items():
        assert streamed[rid] == list(toks)


# =============================================================================
# protocol: one workload, every backend
# =============================================================================
def test_three_backends_run_one_workload_through_the_protocol(family):
    prompts = _prompts((4, 10, 24, 4))
    reqs = _requests(prompts, n_new=4)

    slotted = ENG.RealEngine(family, n_slots=2, max_len=48)
    slotted.configure(_graph())
    paged = ENG.RealEngine(family, n_slots=2, max_len=48, kv_layout="paged",
                           block_size=8)
    paged.configure(_graph())
    des = Q.DESBackend(DES_G, VARIANTS, Q.DESConfig(jitter_sigma=0.0),
                       ci_g_per_kwh=300.0)

    outs = {}
    for name, backend in (("slotted", slotted), ("paged", paged),
                          ("des", des)):
        assert isinstance(backend, ServingBackend)
        responses = serve_workload(
            backend, [InferenceRequest(rid=r.rid, prompt=r.prompt,
                                       max_new_tokens=r.max_new_tokens)
                      for r in reqs])
        assert backend.stats()["served"] == len(reqs)
        assert {r.rid for r in responses} == {r.rid for r in reqs}
        assert all(isinstance(r, InferenceResponse) for r in responses)
        outs[name] = responses
    # the two real layouts agree token-for-token; the DES is analytic
    for rid in range(len(reqs)):
        np.testing.assert_array_equal(slotted.last_outputs[rid],
                                      paged.last_outputs[rid])
    assert all(r.tokens is None for r in outs["des"])
    s = summarize_responses(outs["des"])
    assert s["served"] == len(reqs) and s["carbon_g"] > 0


# =============================================================================
# EDF meets deadlines FIFO misses (DES backend)
# =============================================================================
def _deadline_workload(svc_s):
    # three same-instant arrivals on one instance: r0 dispatches before the
    # others are even queued (no preemption in the DES), so the policy only
    # orders r1 vs r2 — r2's deadline survives second place (EDF) but not
    # third (FIFO)
    return [
        InferenceRequest(rid=0, prompt=[1], max_new_tokens=8, arrival_s=0.0,
                         deadline_s=10.0 * svc_s, slo=DEFERRABLE),
        InferenceRequest(rid=1, prompt=[1], max_new_tokens=8, arrival_s=0.0,
                         deadline_s=10.0 * svc_s, slo=DEFERRABLE),
        InferenceRequest(rid=2, prompt=[1], max_new_tokens=8, arrival_s=0.0,
                         deadline_s=2.5 * svc_s, slo=DEFERRABLE),
    ]


def test_des_edf_meets_deadline_fifo_misses():
    from repro.core import perf_model as PM
    svc = PM.cached_point(VARIANTS[1], DES_G.edges[0][0][1]).latency_s
    misses = {}
    for pol in ("fifo", "edf"):
        des = Q.DESBackend(DES_G, VARIANTS, Q.DESConfig(jitter_sigma=0.0),
                           policy=pol)
        responses = serve_workload(des, _deadline_workload(svc))
        misses[pol] = sum(not r.deadline_met for r in responses)
        assert des.stats()["served"] == 3
    assert misses["fifo"] >= 1, "FIFO should miss the tight deadline"
    assert misses["edf"] == 0, "EDF must meet every deadline here"


def test_des_carbon_policy_holds_deferrable_until_grid_cleans():
    from repro.core import perf_model as PM
    svc = PM.cached_point(VARIANTS[1], DES_G.edges[0][0][1]).latency_s
    # CI is dirty until t=120 s, then clean; deferrable deadline is far out
    pol = CarbonAwarePolicy(lambda now: 500.0 if (now or 0) < 120.0 else 50.0,
                            ci_threshold=200.0)
    des = Q.DESBackend(DES_G, VARIANTS, Q.DESConfig(jitter_sigma=0.0),
                       policy=pol, hold_retry_s=30.0)
    reqs = [InferenceRequest(rid=0, prompt=[1], arrival_s=0.0, slo=DEFERRABLE,
                             deadline_s=10_000.0),
            InferenceRequest(rid=1, prompt=[1], arrival_s=1.0,
                             slo=INTERACTIVE)]
    responses = {r.rid: r for r in serve_workload(des, reqs)}
    assert responses[1].t_finish < 120.0       # interactive never held
    assert responses[0].t_finish >= 120.0      # deferrable waited for clean
    assert responses[0].deadline_met


def test_real_engine_carbon_policy_sees_session_relative_clock(family):
    """The policy's ``now`` is session-relative on the REAL engine too (not
    a raw perf_counter epoch), so one CarbonAwarePolicy drives both
    backends: a trace-shaped ci_fn keyed on seconds-since-start must hold a
    deferrable request exactly until the simulated grid cleans up."""
    seen = []

    def ci_fn(now):
        seen.append(now)
        return 500.0 if (now or 0.0) < 0.25 else 50.0

    pol = CarbonAwarePolicy(ci_fn, ci_threshold=200.0)
    eng = ENG.RealEngine(family, n_slots=2, max_len=32, policy=pol)
    eng.configure(_graph())
    reqs = [InferenceRequest(rid=0, prompt=_prompts((6,))[0],
                             max_new_tokens=4, slo=DEFERRABLE,
                             deadline_s=60.0),
            InferenceRequest(rid=1, prompt=_prompts((6,))[0],
                             max_new_tokens=4, slo=INTERACTIVE)]
    responses = {r.rid: r for r in serve_workload(eng, reqs)}
    assert all(0.0 <= t < 60.0 for t in seen if t is not None), \
        "policy must see session-relative seconds, not wall epochs"
    assert responses[1].t_finish < 0.25          # interactive never held
    assert responses[0].queue_delay_s >= 0.25    # deferrable waited it out
    assert responses[0].t_finish >= 0.25


# =============================================================================
# priority policy on the real engine
# =============================================================================
def test_priority_policy_admits_high_priority_first(family):
    prompts = _prompts((6, 6, 6, 6))
    reqs = _requests(prompts, n_new=4)
    reqs[3].priority = 5                  # submitted last, highest priority
    eng = ENG.RealEngine(family, n_slots=1, max_len=32, policy="priority")
    eng.configure(_graph())
    serve_workload(eng, reqs)
    # rid 3 jumps the three earlier submissions (single slot serializes)
    assert eng.last_admit_order[0] == 3


# =============================================================================
# preemption: swap-out / restore, token parity
# =============================================================================
def test_paged_preemption_forced_and_token_identical(family):
    prompts = _prompts((6, 6, 6, 6), seed=5)
    n_new = 20

    ref = ENG.RealEngine(family, n_slots=2, max_len=48, kv_layout="paged",
                         block_size=8, max_seqs=4, n_blocks=33)
    ref.configure(_graph())
    ref_m = ref._serve_prompts(prompts, n_new=n_new)
    assert ref_m["preemptions"] == 0

    # 4 seqs × ceil(26/8) = 16 blocks wanted, arena has 8 allocatable:
    # admission (prompt-only reservation) overcommits, decode growth runs
    # the arena dry and MUST preempt — outputs must not change
    eng = ENG.RealEngine(family, n_slots=2, max_len=48, kv_layout="paged",
                         block_size=8, max_seqs=4, n_blocks=9,
                         preemption=True, prefix_caching=False)
    eng.configure(_graph())
    responses = serve_workload(eng, _requests(prompts, n_new=n_new))
    m = eng.stats()
    assert m["preemptions"] >= 1
    assert m["served"] == len(prompts)
    for rid, toks in ref.last_outputs.items():
        np.testing.assert_array_equal(toks, eng.last_outputs[rid])
    assert sum(r.preemptions for r in responses) == m["preemptions"]
    # full reclamation after the swap churn
    inst = eng.instances[0]
    inst.alloc.check()
    assert inst.alloc.num_free == inst.alloc.num_allocatable


def test_preemption_victim_is_lowest_priority(family):
    prompts = _prompts((6, 6, 6), seed=7)
    reqs = _requests(prompts, n_new=16)
    reqs[0].priority = 0                  # the designated victim
    reqs[1].priority = 3
    reqs[2].priority = 3
    eng = ENG.RealEngine(family, n_slots=2, max_len=48, kv_layout="paged",
                         block_size=8, max_seqs=4, n_blocks=8,
                         policy="priority", preemption=True,
                         prefix_caching=False)
    eng.configure(_graph())
    responses = {r.rid: r for r in serve_workload(eng, reqs)}
    assert eng.stats()["preemptions"] >= 1
    high_pre = responses[1].preemptions + responses[2].preemptions
    assert responses[0].preemptions >= 1, "low-priority victim swaps out"
    assert responses[0].preemptions >= high_pre


# =============================================================================
# per-request attribution: joules sum to engine total, gCO2 = J × CI
# =============================================================================
@pytest.mark.parametrize("kv_layout", ["slotted", "paged"])
def test_real_engine_attribution_sums_to_total(family, kv_layout):
    ci = 420.0
    eng = ENG.RealEngine(family, n_slots=2, max_len=48, kv_layout=kv_layout,
                         block_size=8, ci_g_per_kwh=ci)
    eng.configure(_graph())
    responses = serve_workload(eng, _requests(_prompts(), n_new=5))
    m = eng.stats()
    total_j = sum(r.energy_j for r in responses)
    assert total_j == pytest.approx(m["energy_j"], rel=1e-9)
    assert sum(r.carbon_g for r in responses) == pytest.approx(
        m["energy_j"] / 3.6e6 * ci, rel=1e-9)
    assert m["carbon_g"] == pytest.approx(m["energy_j"] / 3.6e6 * ci)
    assert all(r.energy_j > 0 for r in responses)


def test_des_backend_attribution_sums_to_total():
    ci = 350.0
    des = Q.DESBackend(DES_G, VARIANTS, Q.DESConfig(jitter_sigma=0.05),
                       ci_g_per_kwh=ci)
    rng = np.random.default_rng(0)
    reqs = [InferenceRequest(rid=i, prompt=[1], max_new_tokens=8,
                             arrival_s=float(a))
            for i, a in enumerate(np.sort(rng.uniform(0, 5.0, size=12)))]
    responses = serve_workload(des, reqs)
    m = des.stats()
    assert sum(r.energy_j for r in responses) == pytest.approx(
        m["energy_j"], rel=1e-9)
    assert sum(r.carbon_g for r in responses) == pytest.approx(
        m["energy_j"] / 3.6e6 * ci, rel=1e-9)


def test_fluid_backend_protocol_smoke():
    from repro.serving.backends import FluidBackend
    res_an = OBJ.evaluate(DES_G, VARIANTS, 1e-9)
    fb = FluidBackend(DES_G, VARIANTS, sla_target_s=1.0, window_s=10.0,
                      ci_g_per_kwh=300.0)
    assert isinstance(fb, ServingBackend)
    n = max(int(res_an.capacity_rps * 5.0), 2)    # ~0.5 load over 10 s
    reqs = [InferenceRequest(rid=i, prompt=[1], arrival_s=i * 10.0 / n)
            for i in range(n)]
    responses = serve_workload(fb, reqs)
    assert len(responses) == n
    assert fb.stats()["served"] == n
    assert all(r.carbon_g > 0 for r in responses)


# =============================================================================
# bugfix: failed paged admission is gated on free-capacity change
# =============================================================================
def test_failed_admission_gated_until_capacity_changes(family):
    prompts = _prompts((24, 24, 24), seed=9)
    eng = ENG.RealEngine(family, n_slots=2, max_len=48, kv_layout="paged",
                         block_size=8, max_seqs=2)
    eng.configure(_graph())
    inst = eng.instances[0]
    calls = {"n": 0}
    orig = inst.can_admit

    def counting_can_admit(prompt_len, n_new):
        calls["n"] += 1
        return orig(prompt_len, n_new)

    inst.can_admit = counting_can_admit
    m = eng._serve_prompts(prompts, n_new=16)
    assert m["served"] == 3
    # without gating every one of the ~50 decode ticks re-peeks the blocked
    # head; gated, an attempt only happens when the head or the free
    # capacity changes — admissions + a handful of completion-driven retries
    assert calls["n"] <= 2 * len(prompts) + 4, \
        (calls["n"], m["decode_steps"])
    assert m["decode_steps"] > calls["n"]
