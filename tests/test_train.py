"""Training substrate: loss decreases on real data, grad-accumulation
equivalence, checkpoint round-trip + crash atomicity, compression bounds."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.models import registry as R
from repro.train import checkpoint as CKPT
from repro.train import data as DATA
from repro.train import optimizer as O
from repro.train import train_loop as TL

KEY = jax.random.PRNGKey(0)


def _tiny_cfg():
    return get_smoke_config("qwen2-0.5b").with_(n_layers=2, d_model=32,
                                                n_heads=4, n_kv_heads=2,
                                                d_head=8, d_ff=64,
                                                vocab_size=64,
                                                dtype=jnp.float32)


def test_train_loss_decreases():
    cfg = _tiny_cfg()
    params = R.init_params(KEY, cfg)
    opt_cfg = O.AdamWConfig(lr=3e-3, warmup_steps=5, total_steps=60)
    state = TL.make_train_state(params, opt_cfg)
    step = jax.jit(TL.make_train_step(cfg, opt_cfg))
    ds = DATA.SyntheticLM(DATA.DataConfig(cfg.vocab_size, 32, 8))
    losses = []
    for i, batch in zip(range(50), ds.batches()):
        state, m = step(state, {k: jnp.asarray(v) for k, v in batch.items()})
        losses.append(float(m["loss"]))
    assert losses[-1] < losses[0] * 0.8, (losses[0], losses[-1])
    assert np.isfinite(losses).all()


def test_grad_accum_equivalence():
    """accum=2 must equal accum=1 on the same global batch (up to fp error)."""
    cfg = _tiny_cfg()
    params = R.init_params(KEY, cfg)
    opt_cfg = O.AdamWConfig(lr=1e-3)
    batch = DATA.SyntheticLM(DATA.DataConfig(cfg.vocab_size, 16, 4)).batch_at(0)
    batch = {k: jnp.asarray(v) for k, v in batch.items()}

    s1 = TL.make_train_state(R.init_params(KEY, cfg), opt_cfg)
    s2 = TL.make_train_state(R.init_params(KEY, cfg), opt_cfg)
    step1 = jax.jit(TL.make_train_step(cfg, opt_cfg))
    step2 = jax.jit(TL.make_grad_accum_train_step(cfg, opt_cfg, accum=2,
                                                  batch_axes=()))
    s1, m1 = step1(s1, batch)
    s2, m2 = step2(s2, batch)
    assert float(m1["loss"]) == pytest.approx(float(m2["loss"]), rel=1e-4)
    for a, b in zip(jax.tree.leaves(s1["params"]), jax.tree.leaves(s2["params"])):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=2e-4, atol=2e-5)


def test_adamw_schedule():
    cfg = O.AdamWConfig(lr=1.0, warmup_steps=10, total_steps=100, min_lr_ratio=0.1)
    assert float(O.schedule(cfg, jnp.int32(5))) == pytest.approx(0.5)
    assert float(O.schedule(cfg, jnp.int32(10))) == pytest.approx(1.0, rel=1e-2)
    assert float(O.schedule(cfg, jnp.int32(100))) == pytest.approx(0.1, rel=1e-2)


def test_checkpoint_roundtrip(tmp_path):
    cfg = _tiny_cfg()
    params = R.init_params(KEY, cfg)
    state = TL.make_train_state(params, O.AdamWConfig())
    d = str(tmp_path / "ckpt")
    CKPT.save(state, 7, d)
    assert CKPT.latest_step(d) == 7
    sds = jax.eval_shape(lambda: state)
    restored = CKPT.restore(d, sds)
    for a, b in zip(jax.tree.leaves(state), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_checkpoint_latest_pointer_and_gc(tmp_path):
    cfg = _tiny_cfg()
    state = {"params": R.init_params(KEY, cfg)}
    d = str(tmp_path / "ckpt")
    ck = CKPT.AsyncCheckpointer(d, keep=2)
    for s in (1, 2, 3):
        ck.submit(state, s)
        ck.wait()
    ck.close()
    assert CKPT.latest_step(d) == 3
    steps = sorted(x for x in os.listdir(d) if x.startswith("step_"))
    assert steps == ["step_00000002", "step_00000003"]   # GC kept 2


def test_checkpoint_crash_atomicity(tmp_path):
    """A partially-written checkpoint never becomes LATEST."""
    cfg = _tiny_cfg()
    state = {"params": R.init_params(KEY, cfg)}
    d = str(tmp_path / "ckpt")
    CKPT.save(state, 1, d)
    # simulate a crash: stray temp dir left behind
    os.makedirs(os.path.join(d, ".tmp_ckpt_crashed"), exist_ok=True)
    assert CKPT.latest_step(d) == 1
    restored = CKPT.restore(d, jax.eval_shape(lambda: state))
    assert restored is not None


def test_data_pipeline_determinism_and_restart():
    ds = DATA.SyntheticLM(DATA.DataConfig(100, 16, 4, seed=42))
    b3a = ds.batch_at(3)
    it = ds.batches(start_step=3)
    b3b = next(it)
    np.testing.assert_array_equal(b3a["tokens"], b3b["tokens"])
    assert b3a["tokens"].shape == (4, 16)
    # labels are the next-token shift
    np.testing.assert_array_equal(b3a["labels"][:, :-1], b3a["tokens"][:, 1:])


def test_chunked_xent_matches_dense():
    cfg = _tiny_cfg()
    b, s, d, v = 2, 8, 16, cfg.padded_vocab
    x = jax.random.normal(KEY, (b, s, d))
    w = jax.random.normal(jax.random.fold_in(KEY, 1), (d, v)) * 0.1
    labels = jax.random.randint(jax.random.fold_in(KEY, 2), (b, s), 0, 60)
    got = TL.chunked_xent(x, w, labels, v, chunk=4)
    logits = (x @ w).astype(jnp.float32)
    ref = jnp.mean(jax.nn.logsumexp(logits, -1)
                   - jnp.take_along_axis(logits, labels[..., None], -1)[..., 0])
    assert float(got) == pytest.approx(float(ref), rel=1e-5)
