"""Continuous-batching real-execution engine: slotted-cache decode parity
with the sequential reference, mid-flight FIFO admission, warm
reconfiguration identity, batched generate, and the shared scheduler core."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.core import config_graph as CG
from repro.models import registry as R
from repro.serving import engine as ENG
from repro.serving.scheduler import SchedulerCore, latency_percentile

CFG = get_smoke_config("qwen3-1.7b").with_(n_layers=4, dtype=jnp.float32)
KEY = jax.random.PRNGKey(0)


@pytest.fixture(scope="module")
def family():
    return ENG.build_engine_family(CFG, fracs=(1.0, 0.5))


@pytest.fixture(scope="module")
def params():
    return R.init_params(KEY, CFG)


# =============================================================================
# scheduler core
# =============================================================================
def test_latency_percentile_nearest_rank():
    lats = [1.0, 2.0, 3.0, 4.0]
    assert latency_percentile(lats, 50.0) == 2.0
    assert latency_percentile(lats, 95.0) == 4.0
    assert latency_percentile([7.0], 99.0) == 7.0
    assert np.isnan(latency_percentile([], 95.0))


def test_scheduler_core_fifo_and_first_completion_wins():
    core = SchedulerCore()
    for i in range(4):
        core.submit(i, float(i))
    assert core.pop_next() == (0, 0.0)
    core.hedge_front(0, 0.0)                 # duplicate at head
    assert core.pop_next() == (0, 0.0)       # duplicate dispatches first
    assert core.complete(0, 0.0, 5.0, accuracy=0.9)
    assert not core.complete(0, 0.0, 6.0)    # hedge twin is a no-op
    assert core.latencies == [5.0]
    assert core.pop_next() == (1, 1.0)       # done entries skipped
    core.complete(1, 1.0, 7.0)
    # an in-flight request lost to a failure re-enters at the HEAD
    assert core.pop_next() == (2, 2.0)
    core.requeue_front(2, 2.0)               # instance died mid-service
    assert core.pop_next() == (2, 2.0)       # precedes 3, arrival preserved
    core.complete(2, 2.0, 9.0)
    assert core.pop_next() == (3, 3.0)
    assert core.pop_next() is None
    assert core.hedges == 1 and core.requeues == 1 and core.served == 3


def test_des_result_percentiles():
    from repro.serving import queue as Q
    r = Q.DESResult([4.0, 1.0, 3.0, 2.0], 0.0, 4, 0.0, 0, 0, 0)
    assert r.p50() == 2.0
    assert r.p95() == 4.0
    assert r.p99() == 4.0
    empty = Q.DESResult([], 0.0, 0, 0.0, 0, 0, 0)
    assert empty.p95() == 0.0


# =============================================================================
# slotted KV cache vs sequential reference
# =============================================================================
def _write_slot(cache, k_all, v_all, slot, true_len):
    s = k_all.shape[2]
    return {
        "k": cache["k"].at[:, slot, :s].set(k_all[:, 0]),
        "v": cache["v"].at[:, slot, :s].set(v_all[:, 0]),
        "lengths": cache["lengths"].at[slot].set(true_len),
    }


def _sequential_reference(params, row_toks, n_new):
    """Greedy continuation logits via the existing scalar-pos decode path."""
    cache = R.make_cache(params, CFG, 1, row_toks.shape[1] + n_new,
                         dtype=jnp.float32)
    for t in range(row_toks.shape[1]):
        lg, cache = R.decode_step(params, cache,
                                  {"tokens": row_toks[:, t:t + 1]}, CFG)
    outs = []
    nxt = jnp.argmax(lg, -1)[:, None]
    for _ in range(n_new):
        lg, cache = R.decode_step(params, cache, {"tokens": nxt}, CFG)
        outs.append(lg)
        nxt = jnp.argmax(lg, -1)[:, None]
    return jnp.stack(outs, 1)


def test_batched_decode_matches_sequential_reference_per_slot(params):
    """Slots of different lengths decode exactly what the per-request
    sequential path decodes, including a slot admitted mid-flight."""
    S = 8
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, S), 0,
                              CFG.vocab_size)
    cache = R.make_slot_cache(CFG, 3, S + 6, dtype=jnp.float32)
    lgA, kA, vA = R.prefill_kv(params, {"tokens": toks[:1]}, CFG)
    cache = _write_slot(cache, kA, vA, 0, S)
    lgB, kB, vB = R.prefill_kv(params, {"tokens": toks[1:, :5]}, CFG)
    cache = _write_slot(cache, kB, vB, 2, 5)

    refA = _sequential_reference(params, toks[:1], 3)
    refB = _sequential_reference(params, toks[1:, :5], 3)

    active = jnp.array([True, False, True])
    nxt = jnp.array([[int(jnp.argmax(lgA[0, S - 1]))], [0],
                     [int(jnp.argmax(lgB[0, 4]))]], jnp.int32)
    outs = []
    for _ in range(3):
        lg, cache = R.decode_slots(params, cache, {"tokens": nxt}, CFG,
                                   active)
        outs.append(lg)
        nxt = jnp.where(active, jnp.argmax(lg, -1), 0)[:, None].astype(jnp.int32)
    dec = jnp.stack(outs, 1)
    np.testing.assert_allclose(np.asarray(dec[0]), np.asarray(refA[0]),
                               rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(np.asarray(dec[2]), np.asarray(refB[0]),
                               rtol=2e-4, atol=2e-4)

    # mid-flight admission into the free slot: running slots keep decoding,
    # the admitted slot reproduces its own sequential reference
    lgC, kC, vC = R.prefill_kv(params, {"tokens": toks[1:, :6]}, CFG)
    cache = _write_slot(cache, kC, vC, 1, 6)
    refC = _sequential_reference(params, toks[1:, :6], 2)
    active = jnp.array([True, True, True])
    nxt = jnp.argmax(dec[:, -1], -1)[:, None].astype(jnp.int32)
    nxt = nxt.at[1, 0].set(int(jnp.argmax(lgC[0, 5])))
    outs2 = []
    for _ in range(2):
        lg, cache = R.decode_slots(params, cache, {"tokens": nxt}, CFG,
                                   active)
        outs2.append(lg)
        nxt = jnp.argmax(lg, -1)[:, None].astype(jnp.int32)
    np.testing.assert_allclose(np.asarray(jnp.stack(outs2, 1)[1]),
                               np.asarray(refC[0]), rtol=2e-4, atol=2e-4)


def test_prefill_kv_matches_forward_logits(params):
    toks = jax.random.randint(jax.random.PRNGKey(2), (2, 8), 0,
                              CFG.vocab_size)
    ref, _ = R.forward(params, {"tokens": toks}, CFG)
    lg, k_all, v_all = R.prefill_kv(params, {"tokens": toks}, CFG)
    np.testing.assert_allclose(np.asarray(lg), np.asarray(ref),
                               rtol=2e-4, atol=2e-4)
    assert k_all.shape == (CFG.n_layers, 2, 8, CFG.n_kv_heads, CFG.d_head)


def test_ref_kernel_per_row_lengths():
    """kernels/ref decode oracle: a (b,) length vector equals per-row scalar
    calls (the masking contract the slotted cache relies on)."""
    from repro.kernels import ref as REF
    key = jax.random.PRNGKey(3)
    b, S, H, K, dh = 3, 16, 4, 2, 8
    q = jax.random.normal(key, (b, H, dh))
    kc = jax.random.normal(jax.random.fold_in(key, 1), (b, S, K, dh))
    vc = jax.random.normal(jax.random.fold_in(key, 2), (b, S, K, dh))
    lengths = jnp.array([3, 16, 9], jnp.int32)
    out = REF.decode_attention_ref(q, kc, vc, lengths)
    for i in range(b):
        row = REF.decode_attention_ref(q[i:i + 1], kc[i:i + 1], vc[i:i + 1],
                                       int(lengths[i]))
        np.testing.assert_allclose(np.asarray(out[i]), np.asarray(row[0]),
                                   rtol=1e-5, atol=1e-5)


# =============================================================================
# engine: admission, warm reconfiguration, generate
# =============================================================================
def _prompts(n, length=6, seed=0):
    rng = np.random.default_rng(seed)
    return [rng.integers(0, CFG.vocab_size, size=(1, length)).astype(np.int32)
            for _ in range(n)]


def test_continuous_batching_fifo_admission(family):
    """Mid-flight admission preserves FIFO fairness: requests enter slots in
    submission order, every request completes, occupancy stays high."""
    eng = ENG.RealEngine(family, n_slots=2, max_len=32)
    eng.configure(CG.ConfigGraph.from_dict(CFG.name, {("x1", 16): 1}))
    prompts = _prompts(5)
    m = eng._serve_prompts(prompts, n_new=4)
    assert eng.last_admit_order == [0, 1, 2, 3, 4]
    assert m["served"] == 5
    assert m["tokens"] == 20
    assert 0.0 < m["mean_occupancy"] <= 1.0
    assert m["p95_s"] >= m["p50_s"] > 0
    assert m["energy_j"] > 0
    # with 2 slots and 5 requests the 5th admits only after a completion
    assert m["decode_steps"] >= 6
    assert all(len(t) == 4 for t in eng.last_outputs.values())


def test_slot_isolation_outputs_independent_of_slot_count(family):
    """Greedy outputs are a property of the request, not of who shares the
    batch: n_slots=1 (pure sequential) and n_slots=4 agree token-for-token."""
    prompts = _prompts(4, seed=5)
    outs = {}
    for n_slots in (1, 4):
        eng = ENG.RealEngine(family, n_slots=n_slots, max_len=32)
        eng.configure(CG.ConfigGraph.from_dict(CFG.name, {("x1", 16): 1}))
        eng._serve_prompts(prompts, n_new=4)
        outs[n_slots] = dict(eng.last_outputs)
    for rid in range(4):
        np.testing.assert_array_equal(outs[1][rid], outs[4][rid])


def test_warm_configure_identical_outputs_and_faster(family):
    """Reconfiguring back to a previous graph reuses pooled instances and
    compiled functions: much faster than cold, and token-identical."""
    eng = ENG.RealEngine(family, n_slots=2, max_len=32)
    g1 = CG.ConfigGraph.from_dict(CFG.name, {("x0.5", 8): 1, ("x1", 8): 1})
    g2 = CG.ConfigGraph.from_dict(CFG.name, {("x1", 16): 1})
    t_cold = eng.configure(g1)
    prompts = _prompts(6, seed=7)
    eng._serve_prompts(prompts, n_new=4)
    cold_out = dict(eng.last_outputs)
    eng.configure(g2)                      # move away ...
    t_warm = eng.configure(g1)             # ... and warm-return
    eng._serve_prompts(prompts, n_new=4)
    warm_out = eng.last_outputs
    assert set(cold_out) == set(warm_out)
    for rid, toks in cold_out.items():
        np.testing.assert_array_equal(toks, warm_out[rid])
    assert t_warm < t_cold / 10, (t_warm, t_cold)
    assert eng.last_reconfig_s == t_warm


def test_warmup_covers_every_serve_bucket_no_recompiles(family):
    """``warmup`` must compile EXACTLY the jit specialisations ``serve`` can
    reach (``serve_buckets``): a missed bucket re-jits on the first real
    request at that length, polluting its measured first-token latency.
    Serve a prompt at every reachable bucket (including a non-power-of-two
    max_len's top bucket) and assert the per-variant jit caches are frozen."""
    eng = ENG.RealEngine(family, n_slots=2, max_len=42)   # non-power-of-two
    eng.configure(CG.ConfigGraph.from_dict(CFG.name, {("x1", 16): 1}))
    assert ENG.serve_buckets(42) == [8, 16, 32, 64]
    fns = eng.family["x1"].fns
    before = {k: fns[k]._cache_size() for k in ("prefill", "decode", "write")}
    rng = np.random.default_rng(0)
    prompts = [rng.integers(0, CFG.vocab_size, size=(1, L)).astype(np.int32)
               for L in (3, 8, 13, 27, 41)]              # one per bucket
    eng._serve_prompts(prompts, n_new=1)
    after = {k: fns[k]._cache_size() for k in ("prefill", "decode", "write")}
    assert after == before, f"serve re-jitted: {before} -> {after}"


def test_generate_batched_rows_decode_their_own_argmax(family):
    """The old engine hard-coded lg[0]/scalar tokens, so every row of a
    batched prompt decoded row 0's continuation.  Each row must match its
    own single-row generation."""
    inst = ENG.Instance(family[1], 8, n_slots=2, max_len=32)
    rng = np.random.default_rng(11)
    prompt = rng.integers(0, CFG.vocab_size, size=(3, 6)).astype(np.int32)
    batched, _ = inst.generate(prompt, n_new=5)
    assert batched.shape == (3, 5)
    for i in range(3):
        single, _ = inst.generate(prompt[i:i + 1], n_new=5)
        np.testing.assert_array_equal(batched[i], single[0])
    # rows differ (argmax is per-row, not broadcast from row 0)
    assert not (batched[0] == batched[1]).all() \
        or not (batched[0] == batched[2]).all()
