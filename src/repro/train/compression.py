"""Compressed gradient collectives (distributed-optimization substrate).

Methods (selected per train config):
  * None    — f32 psum (baseline).
  * "bf16"  — cast to bf16 before the all-reduce: 2× wire bytes saved, f32
              accumulation error bounded by one rounding per hop.
  * "int8"  — per-tensor scale quantization with *error feedback* (residual
              carried across steps, Seide et al. / 1-bit-SGD style): 4× wire
              bytes saved; the EF residual keeps convergence unbiased.

All methods are exact-shape drop-ins used inside shard_map; the collective
bytes show up in lowered HLO and are measured by the roofline harness.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp


def _psum_mean(x, axis):
    return jax.lax.pmean(x, axis)


def all_reduce_mean(grads, axis: str, method: Optional[str] = None):
    if method is None or method == "f32":
        return jax.tree.map(lambda g: _psum_mean(g.astype(jnp.float32), axis), grads)
    if method == "bf16":
        return jax.tree.map(
            lambda g: _psum_mean(g.astype(jnp.bfloat16), axis).astype(jnp.float32),
            grads)
    if method == "int8":
        return jax.tree.map(lambda g: _int8_allreduce(g, axis), grads)
    raise ValueError(f"unknown compressor {method!r}")


def _int8_allreduce(g: jnp.ndarray, axis: str) -> jnp.ndarray:
    gf = g.astype(jnp.float32)
    scale = jnp.maximum(jnp.max(jnp.abs(gf)), 1e-12) / 127.0
    scale = jax.lax.pmax(scale, axis)            # shared scale across replicas
    q = jnp.clip(jnp.round(gf / scale), -127, 127).astype(jnp.int8)
    # int8 payload on the wire; accumulate in int32 (no overflow ≤ 2^24 replicas)
    summed = jax.lax.psum(q.astype(jnp.int32), axis)
    n = jax.lax.psum(jnp.ones((), jnp.int32), axis)
    return summed.astype(jnp.float32) * scale / n.astype(jnp.float32)


class ErrorFeedback:
    """Residual accumulator for biased compressors (int8): the quantization
    error of step t is added back to the gradient of step t+1."""

    @staticmethod
    def init(params):
        return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)

    @staticmethod
    def compress_with_feedback(grads, residual, axis: str):
        def one(g, r):
            gf = g.astype(jnp.float32) + r
            scale = jnp.maximum(jnp.max(jnp.abs(gf)), 1e-12) / 127.0
            q = jnp.clip(jnp.round(gf / scale), -127, 127)
            deq = q * scale
            new_r = gf - deq
            summed = jax.lax.psum(q.astype(jnp.int32), axis)
            n = jax.lax.psum(jnp.ones((), jnp.int32), axis)
            return summed.astype(jnp.float32) * scale / n.astype(jnp.float32), new_r
        flat, treedef = jax.tree.flatten(grads)
        rflat = jax.tree.leaves(residual)
        out = [one(g, r) for g, r in zip(flat, rflat)]
        gs = treedef.unflatten([o[0] for o in out])
        rs = treedef.unflatten([o[1] for o in out])
        return gs, rs
