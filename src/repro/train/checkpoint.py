"""Fault-tolerant checkpointing: atomic writes, async save thread, and
*elastic* restore (a checkpoint written on one mesh restores onto any other
mesh / device count — specs are recomputed from the sharding rules, not
stored per-device).

Layout:  <dir>/step_<n>/arrays.npz + manifest.json ; a top-level LATEST file
is updated last (rename is atomic on POSIX), so a crash mid-save never
corrupts the restore point.
"""
from __future__ import annotations

import json
import os
import queue
import shutil
import tempfile
import threading
from typing import Any, Callable, Optional

import jax
import numpy as np


def _flatten(tree):
    leaves, treedef = jax.tree_util.tree_flatten_with_path(tree)
    out = {}
    for path, leaf in leaves:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p))) for p in path)
        out[key] = np.asarray(jax.device_get(leaf))
    return out


def save(state, step: int, ckpt_dir: str) -> str:
    """Synchronous atomic save.  Returns the step directory."""
    os.makedirs(ckpt_dir, exist_ok=True)
    final = os.path.join(ckpt_dir, f"step_{step:08d}")
    tmp = tempfile.mkdtemp(prefix=".tmp_ckpt_", dir=ckpt_dir)
    try:
        arrays = _flatten(state)
        np.savez(os.path.join(tmp, "arrays.npz"), **arrays)
        manifest = {
            "step": step,
            "keys": sorted(arrays.keys()),
            "dtypes": {k: str(v.dtype) for k, v in arrays.items()},
            "shapes": {k: list(v.shape) for k, v in arrays.items()},
        }
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump(manifest, f)
        if os.path.exists(final):
            shutil.rmtree(final)
        os.rename(tmp, final)
    except BaseException:
        shutil.rmtree(tmp, ignore_errors=True)
        raise
    # LATEST pointer: write-temp + rename = atomic
    latest_tmp = os.path.join(ckpt_dir, ".LATEST.tmp")
    with open(latest_tmp, "w") as f:
        f.write(f"step_{step:08d}")
    os.rename(latest_tmp, os.path.join(ckpt_dir, "LATEST"))
    return final


def latest_step(ckpt_dir: str) -> Optional[int]:
    path = os.path.join(ckpt_dir, "LATEST")
    if not os.path.exists(path):
        return None
    with open(path) as f:
        return int(f.read().strip().split("_")[1])


def restore(ckpt_dir: str, target_tree, step: Optional[int] = None,
            shardings=None):
    """Restore into the structure of ``target_tree``.  ``shardings`` (optional
    pytree of NamedSharding for the *current* mesh) enables elastic restore:
    host arrays are device_put with the new layout regardless of the layout
    at save time."""
    if step is None:
        step = latest_step(ckpt_dir)
        if step is None:
            raise FileNotFoundError(f"no checkpoint under {ckpt_dir}")
    d = os.path.join(ckpt_dir, f"step_{step:08d}")
    data = np.load(os.path.join(d, "arrays.npz"))

    leaves, treedef = jax.tree_util.tree_flatten_with_path(target_tree)
    shard_leaves = (jax.tree.leaves(shardings) if shardings is not None
                    else [None] * len(leaves))
    out = []
    for (path, leaf), shard in zip(leaves, shard_leaves):
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p))) for p in path)
        arr = data[key]
        assert tuple(arr.shape) == tuple(leaf.shape), (key, arr.shape, leaf.shape)
        arr = arr.astype(leaf.dtype)
        out.append(jax.device_put(arr, shard) if shard is not None else jax.device_put(arr))
    return jax.tree_util.tree_unflatten(treedef, out)


class AsyncCheckpointer:
    """Background save thread: the train loop hands off host copies and keeps
    stepping; ``wait()`` drains before exit.  One in-flight save at a time
    (a second enqueue blocks) — bounded memory, never drops a checkpoint."""

    def __init__(self, ckpt_dir: str, keep: int = 3):
        self.ckpt_dir = ckpt_dir
        self.keep = keep
        self._q: "queue.Queue" = queue.Queue(maxsize=1)
        self._err: Optional[BaseException] = None
        self._thread = threading.Thread(target=self._worker, daemon=True)
        self._thread.start()

    def _worker(self):
        while True:
            item = self._q.get()
            if item is None:
                return
            state_host, step = item
            try:
                save(state_host, step, self.ckpt_dir)
                self._gc()
            except BaseException as e:  # surfaced on next submit/wait
                self._err = e
            finally:
                self._q.task_done()

    def _gc(self):
        steps = sorted(d for d in os.listdir(self.ckpt_dir) if d.startswith("step_"))
        for d in steps[:-self.keep]:
            shutil.rmtree(os.path.join(self.ckpt_dir, d), ignore_errors=True)

    def submit(self, state, step: int):
        if self._err:
            raise self._err
        host = jax.tree.map(lambda x: np.asarray(jax.device_get(x)), state)
        self._q.put((host, step))

    def wait(self):
        self._q.join()
        if self._err:
            raise self._err

    def close(self):
        self.wait()
        self._q.put(None)
        self._thread.join()
