"""Train-step builders: GSPMD (jit + shardings) and explicit-DP (shard_map
with compressed gradient collectives).

The GSPMD path is what the multi-pod dry-run lowers; the shard_map DDP path
exists to exercise gradient compression / straggler-tolerant semantics
explicitly and is covered by tests on host devices.
"""
from __future__ import annotations

import functools
from typing import Callable, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models import registry as R
from repro.models.config import ModelConfig
from repro.train import optimizer as O


# =============================================================================
# loss
# =============================================================================
def chunked_xent(x: jnp.ndarray, w: jnp.ndarray, labels: jnp.ndarray,
                 vocab: int, chunk: int = 512) -> jnp.ndarray:
    """Mean next-token NLL, computed seq-chunk-by-chunk with per-chunk remat.

    Never materializes the full (b, s, V) logits in f32: one (b, chunk, V)
    slab is live at a time (forward *and* backward).  The label term is a
    one-hot contraction over V — vocab-sharding safe (partial sums +
    all-reduce) instead of a gather that would all-gather the logits.
    """
    b, s, d = x.shape
    nc = max(s // chunk, 1)
    chunk = s // nc
    assert s % chunk == 0, (s, chunk)
    xc = x.reshape(b, nc, chunk, d).transpose(1, 0, 2, 3)
    lc = labels.reshape(b, nc, chunk).transpose(1, 0, 2)

    def body(acc, inp):
        xi, li = inp
        logits = (xi @ w).astype(jnp.float32)                    # (b, c, V)
        lse = jax.nn.logsumexp(logits, axis=-1)
        onehot = jax.nn.one_hot(li, vocab, dtype=logits.dtype)
        label_logit = jnp.einsum("bcv,bcv->bc", logits, onehot)
        return acc + jnp.sum(lse - label_logit), None

    body = jax.checkpoint(body)
    total, _ = jax.lax.scan(body, jnp.zeros((), jnp.float32), (xc, lc))
    return total / (b * s)


def lm_loss(params, batch, cfg: ModelConfig) -> Tuple[jnp.ndarray, dict]:
    """Next-token cross-entropy over the final hidden states (LM head applied
    inside the chunked loss — see chunked_xent)."""
    hidden, aux = R.forward(params, batch, cfg, train=True, return_hidden=True)
    w = R.head_weights(params, cfg)
    nll = chunked_xent(hidden, w, batch["labels"], cfg.padded_vocab)
    loss = nll + aux
    metrics = {"loss": loss, "aux_loss": aux, "ppl_proxy": nll}
    return loss, metrics


# =============================================================================
# GSPMD train step
# =============================================================================
def make_train_state(params, opt_cfg: O.AdamWConfig) -> dict:
    return {"params": params, "opt": O.init_opt_state(params)}


def make_train_step(cfg: ModelConfig, opt_cfg: O.AdamWConfig) -> Callable:
    def train_step(state, batch):
        (loss, metrics), grads = jax.value_and_grad(
            lambda p: lm_loss(p, batch, cfg), has_aux=True)(state["params"])
        new_params, new_opt, opt_metrics = O.adamw_update(
            opt_cfg, grads, state["opt"], state["params"])
        metrics = {**metrics, **opt_metrics}
        return {"params": new_params, "opt": new_opt}, metrics
    return train_step


def make_grad_accum_train_step(cfg: ModelConfig, opt_cfg: O.AdamWConfig,
                               accum: int, batch_axes=("data",)) -> Callable:
    """Microbatched gradient accumulation: the (global_batch, ...) batch is
    reshaped to (accum, global_batch/accum, ...) and scanned, dividing live
    activation memory by ``accum``.  Each microbatch keeps the batch dim
    sharded over the data axes (sharding constraint after the reshape).
    Gradients accumulate in f32; the optimizer runs once."""
    from jax.sharding import PartitionSpec as P

    def split(x, batch_dim=0):
        b = x.shape[batch_dim]
        assert b % accum == 0, (b, accum)
        shp = list(x.shape)
        shp[batch_dim:batch_dim + 1] = [accum, b // accum]
        y = x.reshape(shp)
        y = jnp.moveaxis(y, batch_dim, 0)
        if not batch_axes:
            return y
        spec = [None] * y.ndim
        spec[1 + batch_dim] = batch_axes
        return jax.lax.with_sharding_constraint(y, P(*spec))

    def train_step(state, batch):
        mbs = {k: split(v, 1 if k == "mrope_positions" else 0)
               for k, v in batch.items()}

        def micro(carry, mb):
            g_acc, l_acc = carry
            (loss, _), grads = jax.value_and_grad(
                lambda p: lm_loss(p, mb, cfg), has_aux=True)(state["params"])
            g_acc = jax.tree.map(lambda a, g: a + g.astype(jnp.float32) / accum,
                                 g_acc, grads)
            return (g_acc, l_acc + loss / accum), None

        g0 = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), state["params"])
        (grads, loss), _ = jax.lax.scan(micro, (g0, jnp.zeros((), jnp.float32)), mbs)
        new_params, new_opt, opt_metrics = O.adamw_update(
            opt_cfg, grads, state["opt"], state["params"])
        metrics = {"loss": loss, "aux_loss": jnp.zeros((), jnp.float32),
                   "ppl_proxy": loss, **opt_metrics}
        return {"params": new_params, "opt": new_opt}, metrics
    return train_step


# =============================================================================
# explicit-DP (shard_map) with compressed gradient all-reduce
# =============================================================================
def make_ddp_train_step(cfg: ModelConfig, opt_cfg: O.AdamWConfig, mesh,
                        compressor: Optional[str] = None) -> Callable:
    """Pure data-parallel step over mesh axis 'data' with an explicit,
    optionally compressed, gradient all-reduce (see train.compression)."""
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P
    from repro.train import compression as C

    axis = "data"

    def step(state, batch):
        (loss, metrics), grads = jax.value_and_grad(
            lambda p: lm_loss(p, batch, cfg), has_aux=True)(state["params"])
        grads = C.all_reduce_mean(grads, axis, method=compressor)
        loss = jax.lax.pmean(loss, axis)
        new_params, new_opt, opt_metrics = O.adamw_update(
            opt_cfg, grads, state["opt"], state["params"])
        return {"params": new_params, "opt": new_opt}, {"loss": loss, **opt_metrics}

    state_spec = jax.tree.map(lambda _: P(), jax.tree.leaves([0]))  # placeholder

    def wrapped(state, batch):
        pspec = jax.tree.map(lambda _: P(), state)
        bspec = jax.tree.map(lambda _: P(axis), batch)
        f = shard_map(step, mesh=mesh,
                      in_specs=(pspec, bspec),
                      out_specs=(pspec, jax.tree.map(lambda _: P(), {"loss": 0, "grad_norm": 0, "lr": 0})),
                      check_rep=False)
        return jax.jit(f)(state, batch)   # shard_map bodies with named remat
                                          # (checkpoint_name) require jit

    return wrapped
