"""AdamW + global-norm clipping + cosine schedule (no optax dependency).

Optimizer state mirrors the param pytree, so GSPMD shards it with the same
PartitionSpecs as the parameters (ZeRO-style sharding can be layered by
passing model-axis specs for m/v — see sharding.rules.param_specs).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Tuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10000
    min_lr_ratio: float = 0.1


def schedule(cfg: AdamWConfig, step: jnp.ndarray) -> jnp.ndarray:
    step = step.astype(jnp.float32)
    warm = step / jnp.maximum(cfg.warmup_steps, 1)
    prog = jnp.clip((step - cfg.warmup_steps)
                    / jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1), 0.0, 1.0)
    cos = cfg.min_lr_ratio + (1 - cfg.min_lr_ratio) * 0.5 * (1 + jnp.cos(jnp.pi * prog))
    return cfg.lr * jnp.where(step < cfg.warmup_steps, warm, cos)


def init_opt_state(params) -> dict:
    zeros = lambda p: jnp.zeros_like(p, dtype=jnp.float32)
    return {
        "m": jax.tree.map(zeros, params),
        "v": jax.tree.map(zeros, params),
        "step": jnp.zeros((), jnp.int32),
    }


def global_norm(tree) -> jnp.ndarray:
    leaves = [jnp.sum(jnp.square(x.astype(jnp.float32))) for x in jax.tree.leaves(tree)]
    return jnp.sqrt(jnp.sum(jnp.stack(leaves)))


def adamw_update(cfg: AdamWConfig, grads, opt_state, params
                 ) -> Tuple[Any, dict, dict]:
    """Returns (new_params, new_opt_state, metrics)."""
    step = opt_state["step"] + 1
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.clip_norm / (gnorm + 1e-9))
    grads = jax.tree.map(lambda g: g.astype(jnp.float32) * scale, grads)

    b1, b2 = cfg.b1, cfg.b2
    m = jax.tree.map(lambda m, g: b1 * m + (1 - b1) * g, opt_state["m"], grads)
    v = jax.tree.map(lambda v, g: b2 * v + (1 - b2) * jnp.square(g), opt_state["v"], grads)
    t = step.astype(jnp.float32)
    bc1 = 1 - b1 ** t
    bc2 = 1 - b2 ** t
    lr = schedule(cfg, step)

    def upd(p, m, v):
        mh = m / bc1
        vh = v / bc2
        delta = mh / (jnp.sqrt(vh) + cfg.eps)
        if p.ndim >= 2:  # decoupled weight decay on matrices only
            delta = delta + cfg.weight_decay * p.astype(jnp.float32)
        # cast the (ZeRO-sharded) update to the param dtype BEFORE it crosses
        # shards: the post-update re-gather then moves bf16, not f32
        # (measured 4.8 GiB/step of f32 weight all-gathers on gemma3 —
        # EXPERIMENTS.md §Perf A6; bf16-delta rounding is the standard
        # mixed-precision trade and is covered by the convergence test).
        return p - (lr * delta).astype(p.dtype)

    new_params = jax.tree.map(upd, params, m, v)
    metrics = {"grad_norm": gnorm, "lr": lr}
    return new_params, {"m": m, "v": v, "step": step}, metrics
