"""Deterministic synthetic token pipeline (shardable, restart-exact).

A real deployment would swap in a tokenized corpus reader; the interface is
identical: ``batches(start_step)`` is a pure function of (seed, step), so a
restart from checkpoint step N reproduces the exact stream — this is the
data-side half of fault tolerance.
"""
from __future__ import annotations

import dataclasses
from typing import Iterator, Optional

import jax
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.models.config import ModelConfig


@dataclasses.dataclass(frozen=True)
class DataConfig:
    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 1234


class SyntheticLM:
    """Markov-ish synthetic stream: tokens correlate with position and the
    previous token so a real model can actually reduce loss on it."""

    def __init__(self, cfg: DataConfig):
        self.cfg = cfg

    def batch_at(self, step: int) -> dict:
        c = self.cfg
        rng = np.random.default_rng(np.uint64(c.seed) + np.uint64(step))
        b, s, v = c.global_batch, c.seq_len, c.vocab_size
        base = rng.integers(0, v, size=(b, 1), dtype=np.int32)
        drift = rng.integers(1, 7, size=(b, s), dtype=np.int32)
        toks = (base + np.cumsum(drift, axis=1)) % v
        tokens = toks.astype(np.int32)
        labels = np.roll(tokens, -1, axis=1)
        labels[:, -1] = tokens[:, 0]
        return {"tokens": tokens, "labels": labels}

    def batches(self, start_step: int = 0) -> Iterator[dict]:
        step = start_step
        while True:
            yield self.batch_at(step)
            step += 1


def shard_batch(batch: dict, mesh, data_axes=("data",)) -> dict:
    """Host batch -> device arrays, batch dim over the data axes."""
    def put(x):
        spec = P(data_axes, *([None] * (x.ndim - 1)))
        return jax.device_put(x, NamedSharding(mesh, spec))
    return {k: put(v) for k, v in batch.items()}
