"""Clover optimization objective (paper Eq. 1–5) + the analytic service model
used to evaluate a configuration graph at a given arrival rate.

  ΔAccuracy = (A − A_base)/A_base · 100          (≤ 0)
  ΔCarbon   = (C_base − E·ci)/C_base · 100
  f = λ · ΔCarbon + (1 − λ) · ΔAccuracy           (maximize)
  s.t. L_p95 ≤ L_tail

The service model: work-conserving FIFO feeding heterogeneous instances —
per-instance rate share ∝ service rate; power via the slice utilization
model; p95 via weighted service percentile + a Sakasegawa M/G/c waiting-time
approximation.  The DES replays chosen configs at request granularity for the
reported end-to-end numbers; this analytic form is what the *online optimizer*
sees during an evaluation window (mirroring the paper's live measurements).
"""
from __future__ import annotations

import dataclasses
import math
from typing import Dict, Optional, Sequence

from repro.core import perf_model as PM
from repro.core import slices as SL
from repro.core.catalog import Variant
from repro.core.config_graph import ConfigGraph


@dataclasses.dataclass(frozen=True)
class EvalResult:
    accuracy: float
    capacity_rps: float
    rho: float                  # offered load / capacity
    p95_latency_s: float
    power_w: float
    energy_per_req_j: float

    def carbon_per_req_g(self, ci: float, pue: float = 1.5) -> float:
        return self.energy_per_req_j / 3.6e6 * ci * pue


def evaluate(g: ConfigGraph, variants: Sequence[Variant],
             arrival_rps: float) -> EvalResult:
    by_name = {v.name: v for v in variants}
    pts, accs, rates = [], [], []
    for (vname, chips), w in g.edges:
        v = by_name[vname]
        sp = PM.cached_point(v, chips)
        for _ in range(w):
            pts.append((v, chips, sp))
            rates.append(sp.throughput_rps)
            accs.append(v.accuracy)
    if not pts:
        return EvalResult(0.0, 0.0, float("inf"), float("inf"), 0.0, float("inf"))

    capacity = sum(rates)
    rho = arrival_rps / capacity if capacity > 0 else float("inf")
    served_frac = [r / capacity for r in rates]
    accuracy = sum(s * a for s, a in zip(served_frac, accs))

    rho_c = min(rho, 1.0)      # work-conserving: every instance busy ρ of the time
    power = sum(PM.instance_power_w(chips, rho_c) for (_, chips, sp) in pts)
    served_rps = min(arrival_rps, capacity)
    energy_per_req = power / served_rps if served_rps > 0 else float("inf")

    # --- p95: weighted service-latency percentile + queueing tail ----------------
    lat_share = sorted((sp.latency_s, s) for (_, _, sp), s in zip(pts, served_frac))
    cum, p95_service = 0.0, lat_share[-1][0]
    for lat, s in lat_share:
        cum += s
        if cum >= 0.95:
            p95_service = lat
            break
    n = len(pts)
    mean_service = sum(sp.latency_s * s for (_, _, sp), s in zip(pts, served_frac))
    if rho < 1.0:
        wq = (rho ** (math.sqrt(2.0 * (n + 1))) / (n * (1.0 - rho))) * mean_service
        p95 = p95_service + 3.0 * wq               # ~exp tail of the wait
    else:
        p95 = p95_service * (1.0 + 10.0 * (rho - 1.0) + 1.0)  # overload: divergent
    return EvalResult(accuracy, capacity, rho, p95, power, energy_per_req)


# =============================================================================
# objective
# =============================================================================
@dataclasses.dataclass(frozen=True)
class ObjectiveConfig:
    lam: float                      # λ in Eq. 3
    a_base: float                   # accuracy of BASE (highest-quality) config
    c_base: float                   # gCO2/request baseline (Eq. 2, fixed)
    l_tail_s: float                 # SLA: p95 target measured on BASE
    pue: float = 1.5
    max_accuracy_loss_pct: Optional[float] = None   # optional hard threshold


def delta_accuracy(acc: float, cfg: ObjectiveConfig) -> float:
    return (acc - cfg.a_base) / cfg.a_base * 100.0


def delta_carbon(energy_per_req_j: float, ci: float, cfg: ObjectiveConfig) -> float:
    c = energy_per_req_j / 3.6e6 * ci * cfg.pue
    return (cfg.c_base - c) / cfg.c_base * 100.0


def objective_f(res: EvalResult, ci: float, cfg: ObjectiveConfig) -> float:
    da = delta_accuracy(res.accuracy, cfg)
    dc = delta_carbon(res.energy_per_req_j, ci, cfg)
    if (cfg.max_accuracy_loss_pct is not None
            and -da > cfg.max_accuracy_loss_pct):
        # provider-specified accuracy threshold (paper Fig. 14b): hard wall
        return -1e6 - (-da)
    return cfg.lam * dc + (1.0 - cfg.lam) * da


def sa_energy(res: EvalResult, ci: float, cfg: ObjectiveConfig) -> float:
    """Paper Eq. 6: h(x) = −f(x) · min(1, L_tail / L(x)).  SLA-violating
    configs are scaled toward zero, keeping the landscape smooth."""
    f = objective_f(res, ci, cfg)
    scale = min(1.0, cfg.l_tail_s / max(res.p95_latency_s, 1e-9))
    return -f * scale


def meets_sla(res: EvalResult, cfg: ObjectiveConfig) -> bool:
    return res.p95_latency_s <= cfg.l_tail_s
