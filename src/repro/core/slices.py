"""TPU slice types and partition catalog — the MIG analogue (DESIGN.md §2).

An A100 exposes 5 MIG slice types (1g..7g) and 19 partition configurations of
its 7 compute slots.  Our serving unit is a 16-chip v5e block (4×4 sub-torus);
slice types are power-of-two sub-meshes 1c/2c/4c/8c/16c (the tensor-parallel
degree of the hosted instance), and a partition configuration is a multiset of
slice sizes summing to 16.  Because every size divides the block, any such
multiset tiles the block exactly (first-fit-decreasing argument), so the
catalog is complete and every configuration is realizable on the torus.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Dict, List, Sequence, Tuple

BLOCK_CHIPS = 16
SLICE_SIZES = (1, 2, 4, 8, 16)
HBM_PER_CHIP_GB = 16.0

# v5e chip power model (nameplate ~220 W; ~60 % draw at idle-clock serving)
CHIP_POWER_PEAK_W = 220.0
CHIP_POWER_IDLE_W = 75.0
PEAK_FLOPS_BF16 = 197e12          # per chip
HBM_BW = 819e9                    # B/s per chip
ICI_BW = 50e9                     # B/s per link


def slice_name(chips: int) -> str:
    return f"{chips}c"


@functools.lru_cache(maxsize=None)
def partition_catalog(block: int = BLOCK_CHIPS) -> Tuple[Tuple[int, ...], ...]:
    """All multisets of SLICE_SIZES summing to ``block`` (descending order).
    For block=16 this yields 36 configurations — the MIG-19 analogue."""
    sizes = [s for s in SLICE_SIZES if s <= block]

    def rec(remaining: int, max_size: int) -> List[Tuple[int, ...]]:
        if remaining == 0:
            return [()]
        out = []
        for s in (x for x in sizes if x <= min(remaining, max_size)):
            for tail in rec(remaining - s, s):
                out.append((s,) + tail)
        return out

    # descending-first enumeration gives canonical (sorted desc) multisets
    return tuple(sorted({tuple(sorted(p, reverse=True)) for p in rec(block, block)},
                        reverse=True))


def config_number(partition: Sequence[int]) -> int:
    """Stable catalog index of a partition (the paper's 'configuration 1..19')."""
    return partition_catalog().index(tuple(sorted(partition, reverse=True)))


def slice_counts(partition: Sequence[int]) -> Dict[int, int]:
    out: Dict[int, int] = {}
    for s in partition:
        out[s] = out.get(s, 0) + 1
    return out


def fits(mem_gb: float, chips: int, headroom: float = 0.9) -> bool:
    """HBM feasibility of hosting a variant on a slice (the paper's OOM-edge
    removal, §4.2)."""
    return mem_gb <= chips * HBM_PER_CHIP_GB * headroom


def power_w(chips: int, utilization: float) -> float:
    """Slice power draw at a given utilization (linear idle→peak model)."""
    u = min(max(utilization, 0.0), 1.0)
    return chips * (CHIP_POWER_IDLE_W + (CHIP_POWER_PEAK_W - CHIP_POWER_IDLE_W) * u)
