"""Clover configuration graph (paper Definition 1) + graph edit distance.

A configuration graph is a weighted directed bipartite graph: variant vertices
→ slice-type vertices, integer edge weight = number of instances of that
variant hosted on that slice type.  Properties the paper exploits — and that
our tests assert:

  * canonicalization: all (x^p, x^v) placements with identical edge weights
    collapse to one graph (slice-type isolation ⇒ identical objective);
  * GED(g1, g2) = Σ |w1(e) − w2(e)|  (variant swap = 2, slice move = 2);
  * additivity: adding/removing serving blocks = edge-weight add/subtract
    (the elastic-scaling path);
  * feasibility: Σ instances·chips = blocks·16, every edge HBM-feasible.

Implemented over plain dicts with a networkx export for interop (the paper
implements the optimizer with networkx; our hot path avoids it).
"""
from __future__ import annotations

import dataclasses
import random
from typing import Dict, Iterable, List, Optional, Sequence, Tuple

from repro.core import slices as SL
from repro.core.catalog import Variant, best_variant, feasible_slices

Edge = Tuple[str, int]                 # (variant name, slice chips)


@dataclasses.dataclass(frozen=True)
class ConfigGraph:
    family: str
    edges: Tuple[Tuple[Edge, int], ...]      # sorted ((variant, chips), weight)

    # --- constructors --------------------------------------------------------
    @staticmethod
    def from_dict(family: str, weights: Dict[Edge, int]) -> "ConfigGraph":
        items = tuple(sorted((e, int(w)) for e, w in weights.items() if w > 0))
        return ConfigGraph(family, items)

    @staticmethod
    def uniform(family: str, variant: str, chips_per_slice: int,
                n_blocks: int) -> "ConfigGraph":
        per_block = SL.BLOCK_CHIPS // chips_per_slice
        return ConfigGraph.from_dict(
            family, {(variant, chips_per_slice): per_block * n_blocks})

    # --- views ----------------------------------------------------------------
    def weights(self) -> Dict[Edge, int]:
        return dict(self.edges)

    @property
    def n_instances(self) -> int:
        return sum(w for _, w in self.edges)

    @property
    def total_chips(self) -> int:
        return sum(e[1] * w for e, w in self.edges)

    def instances(self) -> List[Edge]:
        out: List[Edge] = []
        for e, w in self.edges:
            out.extend([e] * w)
        return out

    # --- algebra (paper §4.2: additivity) ----------------------------------------
    def add(self, other: "ConfigGraph") -> "ConfigGraph":
        w = self.weights()
        for e, dw in other.edges:
            w[e] = w.get(e, 0) + dw
        return ConfigGraph.from_dict(self.family, w)

    def subtract(self, other: "ConfigGraph") -> "ConfigGraph":
        w = self.weights()
        for e, dw in other.edges:
            w[e] = w.get(e, 0) - dw
            if w[e] < 0:
                raise ValueError(f"negative weight on {e}")
        return ConfigGraph.from_dict(self.family, w)

    # --- validity ------------------------------------------------------------------
    def is_valid(self, n_blocks: int, variants: Sequence[Variant]) -> bool:
        if self.total_chips != n_blocks * SL.BLOCK_CHIPS:
            return False
        by_name = {v.name: v for v in variants}
        for (vname, chips), w in self.edges:
            v = by_name.get(vname)
            if v is None or chips not in SL.SLICE_SIZES:
                return False
            if not SL.fits(v.mem_gb, chips):
                return False                      # OOM edge (paper §4.2)
        return True

    def to_networkx(self):
        import networkx as nx
        g = nx.DiGraph()
        for (vname, chips), w in self.edges:
            g.add_edge(f"variant:{vname}", f"slice:{SL.slice_name(chips)}", weight=w)
        return g


def ged(a: ConfigGraph, b: ConfigGraph) -> int:
    """Weighted graph edit distance: Σ |w_a(e) − w_b(e)| (paper Fig. 7 step 2:
    vertex sets are fixed, only edge weights differ)."""
    wa, wb = a.weights(), b.weights()
    keys = set(wa) | set(wb)
    return sum(abs(wa.get(k, 0) - wb.get(k, 0)) for k in keys)


# =============================================================================
# neighborhood (GED ≤ 4 — the paper's threshold)
# =============================================================================
def _repaint_moves(g: ConfigGraph, variants: Sequence[Variant]) -> List[ConfigGraph]:
    """Swap one instance's variant (GED 2)."""
    out = []
    w = g.weights()
    for (vname, chips), count in g.edges:
        for v2 in variants:
            if v2.name == vname or not SL.fits(v2.mem_gb, chips):
                continue
            w2 = dict(w)
            w2[(vname, chips)] -= 1
            w2[(v2.name, chips)] = w2.get((v2.name, chips), 0) + 1
            out.append(ConfigGraph.from_dict(g.family, w2))
    return out


def _split_moves(g: ConfigGraph, variants: Sequence[Variant]) -> List[ConfigGraph]:
    """Split one slice 2k → k + k, keeping the variant (GED 3) or repainting
    one half (GED ≤ 4)."""
    out = []
    w = g.weights()
    by_name = {v.name: v for v in variants}
    for (vname, chips), count in g.edges:
        if chips == 1:
            continue
        k = chips // 2
        if not SL.fits(by_name[vname].mem_gb, k):
            continue
        w2 = dict(w)
        w2[(vname, chips)] -= 1
        w2[(vname, k)] = w2.get((vname, k), 0) + 2
        out.append(ConfigGraph.from_dict(g.family, w2))
    return out


def _merge_moves(g: ConfigGraph, variants: Sequence[Variant]) -> List[ConfigGraph]:
    """Merge two k-slices into one 2k-slice (GED 3)."""
    out = []
    w = g.weights()
    sizes: Dict[int, int] = {}
    for (vname, chips), count in g.edges:
        sizes[chips] = sizes.get(chips, 0) + count
    for (vname, chips), count in g.edges:
        if chips == SL.BLOCK_CHIPS:
            continue
        if sizes.get(chips, 0) < 2:
            continue
        # partner slice of same size: same or different variant
        for (v2name, c2), count2 in g.edges:
            if c2 != chips:
                continue
            if v2name == vname and count < 2:
                continue
            w2 = dict(w)
            w2[(vname, chips)] -= 1
            w2[(v2name, chips)] -= 1
            if min(w2[(vname, chips)], w2[(v2name, chips)]) < 0:
                continue
            w2[(vname, 2 * chips)] = w2.get((vname, 2 * chips), 0) + 1
            out.append(ConfigGraph.from_dict(g.family, w2))
    return out


def neighbors(g: ConfigGraph, variants: Sequence[Variant],
              max_ged: int = 4) -> List[ConfigGraph]:
    """All single-move neighbors (every move keeps total chips constant and
    has GED ≤ 4); deduplicated."""
    cands = (_repaint_moves(g, variants) + _split_moves(g, variants)
             + _merge_moves(g, variants))
    seen, out = set(), []
    for c in cands:
        if c.edges in seen or c.edges == g.edges:
            continue
        if ged(g, c) > max_ged:
            continue
        seen.add(c.edges)
        out.append(c)
    return out


def sample_neighbor(g: ConfigGraph, variants: Sequence[Variant],
                    rng: random.Random, max_ged: int = 4) -> ConfigGraph:
    ns = neighbors(g, variants, max_ged)
    if not ns:
        return g
    return rng.choice(ns)


def random_config(family: str, variants: Sequence[Variant], n_blocks: int,
                  rng: random.Random) -> ConfigGraph:
    """Uniformly random valid configuration (used by BLOVER's random search:
    random partition per block, random feasible variant per slice)."""
    weights: Dict[Edge, int] = {}
    for _ in range(n_blocks):
        part = rng.choice(SL.partition_catalog())
        for chips in part:
            feas = [v for v in variants if SL.fits(v.mem_gb, chips)]
            if not feas:       # no variant fits a 1c slice → upgrade to 2c pairs
                continue
            v = rng.choice(feas)
            e = (v.name, chips)
            weights[e] = weights.get(e, 0) + 1
    g = ConfigGraph.from_dict(family, weights)
    # repair chip count if some slices were dropped for infeasibility
    deficit = n_blocks * SL.BLOCK_CHIPS - g.total_chips
    if deficit > 0:
        big = best_variant(variants)
        size = max(s for s in SL.SLICE_SIZES
                   if s <= deficit and SL.fits(big.mem_gb, s))
        w = g.weights()
        while deficit >= size:
            w[(big.name, size)] = w.get((big.name, size), 0) + 1
            deficit -= size
        g = ConfigGraph.from_dict(family, w)
    return g
