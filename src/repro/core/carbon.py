"""Carbon-intensity traces and carbon accounting (paper §2, Fig. 4/8).

Carbon Footprint = Energy × Carbon Intensity (× PUE), the identity used by
the paper (and refs [17, 18] therein).  Real CISO/ESO traces are not bundled
offline, so the generators reproduce the *statistical structure* the paper
reports for each grid/season (Fig. 8): diurnal solar valleys for California
(deep in March, shallower in September), wind-driven irregular oscillation
for the UK, >200 gCO2/kWh intra-day swings.  A CSV loader accepts real traces
with identical downstream behaviour.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Callable, List, Optional, Sequence, Tuple

import numpy as np

PUE_DEFAULT = 1.5          # Uptime Institute 2022 survey value used by the paper


@dataclasses.dataclass
class CarbonTrace:
    """Piecewise-linear carbon intensity over time (gCO2/kWh)."""
    name: str
    times_s: np.ndarray          # (n,) seconds, ascending
    intensity: np.ndarray        # (n,) gCO2/kWh

    def at(self, t: float) -> float:
        return float(np.interp(t, self.times_s, self.intensity))

    @property
    def duration_s(self) -> float:
        return float(self.times_s[-1])

    def mean(self) -> float:
        return float(np.trapezoid(self.intensity, self.times_s) / self.duration_s)

    # --- forecast hooks (fleet/forecast.py builds on these) -----------------
    def history(self, t: float) -> "CarbonTrace":
        """Samples observable at wall-clock ``t`` (times_s ≤ t) — the only
        view an *honest* online forecaster may fit on."""
        n = int(np.searchsorted(self.times_s, t, side="right"))
        n = max(n, 1)
        return CarbonTrace(self.name, self.times_s[:n], self.intensity[:n])

    def slice(self, t0: float, t1: float, rebase: bool = True) -> "CarbonTrace":
        """Sub-trace over [t0, t1] with interpolated endpoints; ``rebase``
        shifts the time axis so the slice starts at 0 (what a per-region
        backtest or a re-planning window wants)."""
        t0 = max(t0, float(self.times_s[0]))
        t1 = min(t1, float(self.times_s[-1]))
        if t1 <= t0:
            raise ValueError(f"empty slice [{t0}, {t1}] of {self.name}")
        inner = (self.times_s > t0) & (self.times_s < t1)
        ts = np.concatenate(([t0], self.times_s[inner], [t1]))
        ci = np.concatenate(([self.at(t0)], self.intensity[inner], [self.at(t1)]))
        if rebase:
            ts = ts - t0
        return CarbonTrace(self.name, ts, ci)

    def window_mean(self, t0: float, t1: float) -> float:
        """Time-averaged intensity over [t0, t1] (trapezoid rule) — the CI a
        fluid window serving uniformly across the interval actually sees."""
        s = self.slice(t0, t1, rebase=False)
        return float(np.trapezoid(s.intensity, s.times_s) / (s.times_s[-1] - s.times_s[0]))


def _diurnal(hours: np.ndarray, base: float, solar_dip: float, noise: float,
             wind: float, seed: int, dip_width: float = 4.0,
             dip_center: float = 13.0) -> np.ndarray:
    """base - solar midday dip + slow wind oscillation + AR(1) noise."""
    rng = np.random.default_rng(seed)
    tod = hours % 24.0
    dip = solar_dip * np.exp(-0.5 * ((tod - dip_center) / dip_width) ** 2)
    slow = wind * np.sin(2 * np.pi * hours / 37.0 + rng.uniform(0, 2 * np.pi))
    ar = np.zeros_like(hours)
    e = rng.normal(0, noise, size=len(hours))
    for i in range(1, len(hours)):
        ar[i] = 0.92 * ar[i - 1] + e[i]
    evening = 0.25 * solar_dip * np.exp(-0.5 * ((tod - 20.0) / 2.0) ** 2)
    return np.clip(base - dip + evening + slow + ar, 40.0, None)


def make_trace(region: str = "CISO-March", hours: float = 48.0,
               step_s: float = 300.0, seed: int = 7) -> CarbonTrace:
    """Synthetic trace calibrated to the paper's Fig. 8 envelopes."""
    t = np.arange(0.0, hours * 3600.0 + step_s, step_s)
    h = t / 3600.0
    if region == "CISO-March":        # deep solar valleys: ~100-320
        ci = _diurnal(h, base=290.0, solar_dip=190.0, noise=6.0, wind=18.0, seed=seed)
    elif region == "CISO-September":  # hotter, shallower valleys: ~180-380
        ci = _diurnal(h, base=340.0, solar_dip=140.0, noise=7.0, wind=20.0,
                      seed=seed + 1, dip_width=3.2)
    elif region == "ESO-March":       # UK wind-driven, irregular: ~80-300
        ci = _diurnal(h, base=210.0, solar_dip=60.0, noise=10.0, wind=70.0,
                      seed=seed + 2, dip_width=3.0)
    else:
        raise KeyError(f"unknown region {region!r}")
    return CarbonTrace(region, t, ci)


_TIME_COL_HINTS = ("datetime", "timestamp", "date", "time", "seconds")
_CI_COL_HINTS = ("carbon_intensity", "carbon intensity", "gco2", "co2",
                 "intensity")


def _parse_time_s(value: str) -> Tuple[float, bool]:
    """(seconds, was_datetime) from a CSV cell: plain numbers pass through;
    ISO-8601 timestamps (ElectricityMaps exports, with or without a trailing
    Z) become epoch seconds.  Naive stamps are taken as UTC — resolving them
    in the host's local timezone would make the same file load differently
    per machine and corrupt spacing across DST transitions."""
    try:
        return float(value), False
    except ValueError:
        pass
    import datetime as _dt
    v = value.strip().replace("Z", "+00:00")
    dt = _dt.datetime.fromisoformat(v)
    if dt.tzinfo is None:
        dt = dt.replace(tzinfo=_dt.timezone.utc)
    return dt.timestamp(), True


def load_trace_csv(path: str, name: Optional[str] = None,
                   time_col: Optional[str] = None,
                   ci_col: Optional[str] = None) -> CarbonTrace:
    """Load a carbon-intensity trace from CSV.

    Accepts both the repo's own ``seconds,gco2_per_kwh`` format and
    ElectricityMaps-style exports: a timestamp column (ISO-8601 datetimes
    *or* plain seconds — sniffed by header name, overridable via
    ``time_col``) plus a gCO2/kWh column (any header containing "carbon
    intensity"/"gco2"/…, overridable via ``ci_col``), with arbitrary extra
    columns, irregular sample spacing and unsorted rows.  Datetime stamps
    are rebased so the trace starts at t = 0; duplicate timestamps keep the
    last sample."""
    import csv

    with open(path, newline="") as f:
        rows = list(csv.DictReader(f))
    if not rows:
        raise ValueError(f"empty trace CSV {path}")
    cols = list(rows[0].keys())

    def find(requested: Optional[str], hints) -> str:
        if requested is not None:
            if requested not in cols:
                raise KeyError(f"column {requested!r} not in {cols}")
            return requested
        for hint in hints:
            for c in cols:
                if c is not None and hint in c.strip().lower():
                    return c
        raise KeyError(f"no column matching {hints} in {cols}")

    tc = find(time_col, _TIME_COL_HINTS)
    cc = find(ci_col, _CI_COL_HINTS)
    samples = {}
    any_datetime = False
    for row in rows:
        t_raw, ci_raw = row.get(tc), row.get(cc)
        if not t_raw or not t_raw.strip() or not ci_raw or not ci_raw.strip():
            continue                    # gaps in real exports: skip the row
        t_s, was_dt = _parse_time_s(t_raw)
        any_datetime |= was_dt
        samples[t_s] = float(ci_raw)
    if len(samples) < 2:
        raise ValueError(f"{path}: fewer than 2 usable samples")
    ts = np.array(sorted(samples))
    ci = np.array([samples[t] for t in ts])
    if any_datetime:
        ts = ts - ts[0]                 # epoch stamps → trace-relative seconds
    return CarbonTrace(name or path, ts, ci)


# =============================================================================
# accounting
# =============================================================================
@dataclasses.dataclass
class CarbonAccountant:
    """Integrates energy → operational carbon at time-varying intensity.
    Mirrors the paper's carbontracker-based measurement service."""
    trace: CarbonTrace
    pue: float = PUE_DEFAULT
    energy_j: float = 0.0
    carbon_g: float = 0.0
    # optional streaming telemetry (repro.obs.carbon_feed.CarbonFeed): every
    # add() forwards its EXACT joules/grams, so feed totals equal the
    # accountant's with no re-derivation (conservation by construction)
    feed: Optional[object] = None

    def add(self, t_start: float, duration_s: float, power_w: float) -> float:
        """Account ``power_w`` drawn for ``duration_s`` starting at t_start.
        Returns grams CO2 emitted."""
        e_j = power_w * duration_s
        ci = self.trace.at(t_start + 0.5 * duration_s)   # midpoint rule
        g = (e_j / 3.6e6) * ci * self.pue                # J → kWh → gCO2
        self.energy_j += e_j
        self.carbon_g += g
        if self.feed is not None:
            self.feed.record_segment(t_start, duration_s, e_j, g)
        return g

    def grams_for(self, energy_j: float, ci: float) -> float:
        return (energy_j / 3.6e6) * ci * self.pue
