"""Simulated annealing in the configuration-graph space (paper §4.2, Eq. 6–7).

Faithful parameters: T0 = 1, cooling 0.05 per iteration down to T = 0.1;
acceptance  P = exp(−(h(x') − h(x)) / T)  for worse candidates; termination at
a wall-time limit (5 simulated minutes by default) or 5 consecutive
evaluations without improvement.  Each candidate evaluation costs
``eval_window_s`` of live serving time — the paper measures candidates on the
real system, and the simulator charges this overhead identically.
"""
from __future__ import annotations

import dataclasses
import math
import random
from typing import Callable, List, Optional, Sequence, Tuple

from repro.core import config_graph as CG
from repro.core import objective as OBJ
from repro.core.catalog import Variant


@dataclasses.dataclass(frozen=True)
class SAConfig:
    t_initial: float = 1.0
    cooling: float = 0.05
    t_min: float = 0.1
    stale_limit: int = 5
    time_limit_s: float = 300.0
    eval_window_s: float = 5.0
    max_ged: int = 4


@dataclasses.dataclass
class Evaluation:
    graph: CG.ConfigGraph
    result: OBJ.EvalResult
    f: float
    h: float
    sla_ok: bool
    t_offset_s: float            # when (relative to invocation start) evaluated


@dataclasses.dataclass
class SAOutcome:
    best: CG.ConfigGraph
    best_f: float
    evaluations: List[Evaluation]
    duration_s: float

    @property
    def n_evals(self) -> int:
        return len(self.evaluations)

    @property
    def sla_compliant_frac(self) -> float:
        if not self.evaluations:
            return 1.0
        return sum(e.sla_ok for e in self.evaluations) / len(self.evaluations)


def anneal(start: CG.ConfigGraph,
           variants: Sequence[Variant],
           evaluator: Callable[[CG.ConfigGraph], OBJ.EvalResult],
           ci: float,
           obj_cfg: OBJ.ObjectiveConfig,
           sa_cfg: SAConfig = SAConfig(),
           rng: Optional[random.Random] = None) -> SAOutcome:
    """One Clover optimization invocation.  ``start`` is the previous best
    (warm start — the paper's Fig. 13 invocation chaining)."""
    rng = rng or random.Random(0)
    evals: List[Evaluation] = []
    t = 0.0

    def run_eval(g: CG.ConfigGraph) -> Evaluation:
        nonlocal t
        t += sa_cfg.eval_window_s
        res = evaluator(g)
        f = OBJ.objective_f(res, ci, obj_cfg)
        h = OBJ.sa_energy(res, ci, obj_cfg)
        ev = Evaluation(g, res, f, h, OBJ.meets_sla(res, obj_cfg), t)
        evals.append(ev)
        return ev

    current = run_eval(start)
    best = current
    temp = sa_cfg.t_initial
    stale = 0

    while t < sa_cfg.time_limit_s and stale < sa_cfg.stale_limit:
        cand_graph = CG.sample_neighbor(current.graph, variants, rng,
                                        sa_cfg.max_ged)
        if cand_graph.edges == current.graph.edges:
            break                      # no neighbors at all
        cand = run_eval(cand_graph)

        accept = cand.h <= current.h
        if not accept:
            p = math.exp(-(cand.h - current.h) / max(temp, 1e-9))
            accept = rng.random() < p
        if accept:
            current = cand
        # track best among SLA-compliant configs; fall back to best-h
        improved = False
        if cand.sla_ok and (not best.sla_ok or cand.f > best.f):
            best, improved = cand, True
        elif not best.sla_ok and cand.h < best.h:
            best, improved = cand, True
        stale = 0 if improved else stale + 1
        temp = max(temp - sa_cfg.cooling, sa_cfg.t_min)

    return SAOutcome(best.graph, best.f, evals, t)
