"""Model-variant catalogs (paper Table 1 + the assigned LM architectures).

Paper applications carry *published* accuracy / FLOPs / parameter numbers
(EfficientNet: Tan & Le 2019; ALBERT: Lan et al. 2019, SQuAD2.0 dev F1;
YOLOv5: Ultralytics release tables, COCO mAP50-95).  The assigned LM archs get
AutoML-style quality ladders: depth/width-reduced ModelConfigs whose FLOPs and
parameter counts are *computed exactly* from the config, with a documented
log-parameter quality proxy standing in for task accuracy.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Dict, List, Sequence

from repro.core import slices as SL


@dataclasses.dataclass(frozen=True)
class Variant:
    family: str
    name: str
    quality: int               # ordinal within family, 1 = lowest (paper §4.1)
    accuracy: float            # task metric in [0, 1]
    flops_g: float             # GFLOPs per inference request
    params_m: float            # parameters (millions)
    mem_gb: float              # serving footprint (weights + working set)

    @property
    def key(self) -> str:
        return f"{self.family}:{self.name}"


def _v(family, name, q, acc, gf, pm):
    mem = pm * 1e6 * 2 / 2**30 * 1.4 + 0.5      # bf16 weights + 40% act + runtime
    return Variant(family, name, q, acc, gf, pm, mem)


# --- paper Table 1 families ---------------------------------------------------
EFFICIENTNET = (
    _v("efficientnet", "B1", 1, 0.791, 0.70, 7.8),
    _v("efficientnet", "B3", 2, 0.816, 1.8, 12.0),
    _v("efficientnet", "B5", 3, 0.836, 9.9, 30.0),
    _v("efficientnet", "B7", 4, 0.843, 37.0, 66.0),
)

ALBERT = (                     # SQuAD2.0 F1/100, seq 384
    _v("albert", "v2-base", 1, 0.800, 9.2, 12.0),
    _v("albert", "v2-large", 2, 0.823, 27.0, 18.0),
    _v("albert", "v2-xlarge", 3, 0.861, 88.0, 60.0),
    _v("albert", "v2-xxlarge", 4, 0.898, 340.0, 235.0),
)

YOLOV5 = (                     # COCO mAP50-95/100
    _v("yolov5", "l", 1, 0.490, 109.0, 46.5),
    _v("yolov5", "x", 2, 0.507, 205.0, 86.7),
    _v("yolov5", "x6", 3, 0.550, 839.0, 140.7),
)

PAPER_FAMILIES: Dict[str, Sequence[Variant]] = {
    "efficientnet": EFFICIENTNET,
    "albert": ALBERT,
    "yolov5": YOLOV5,
}


# --- LM architecture ladders ---------------------------------------------------
def lm_ladder(arch: str, seq_len: int = 1024, gen_tokens: int = 128) -> List[Variant]:
    """AutoML-style quality ladder for an assigned architecture: the full
    config plus depth-reduced variants (1, 3/4, 1/2, 1/4 of the layers).

    FLOPs/request = forward flops for a (seq_len prefill + gen_tokens decode)
    request, computed exactly from the reduced ModelConfig.  Accuracy proxy:
    quality(N) = 1 - 0.35 · (N_active/N_full)^(-0.12) + 0.35, a log-parameter
    scaling-law surrogate normalized to 0.92 at full size (documented —
    real deployments substitute measured task accuracy here).
    """
    from repro.configs import get_config
    full = get_config(arch)
    fracs = [(1.0, "full"), (0.75, "3q"), (0.5, "half"), (0.25, "quarter")]
    out: List[Variant] = []
    n_full_active = full.active_param_count()
    for i, (frac, tag) in enumerate(fracs):
        n_layers = max(int(round(full.n_layers * frac)), 1)
        if full.family == "hybrid" and full.attn_every:
            n_layers = max(full.attn_every,
                           (n_layers // full.attn_every) * full.attn_every)
        cfg = full.with_(n_layers=n_layers, name=f"{arch}-{tag}")
        n_act = cfg.active_param_count()
        fl_req = (cfg.flops_per_token(seq_len) * seq_len
                  + cfg.flops_per_token(seq_len, decode=True) * gen_tokens)
        acc = 0.92 - 0.35 * ((n_act / n_full_active) ** (-0.12) - 1.0)
        mem = cfg.param_count() * 2 / 2**30 * 1.2 + 1.0
        out.append(Variant(arch, tag, len(fracs) - i, acc, fl_req / 1e9,
                           cfg.param_count() / 1e6, mem))
    out.sort(key=lambda v: v.quality)
    return out


def _rank(v: Variant) -> tuple:
    """Total order on a ladder: quality ordinal, then accuracy, then name.
    The name tie-break makes best/worst deterministic for equal-quality
    variants regardless of the input ordering (``max`` alone would return
    whichever duplicate happened to come first)."""
    return (v.quality, v.accuracy, v.name)


def best_variant(variants: Sequence[Variant]) -> Variant:
    """Highest-quality variant of a ladder (deterministic tie-break)."""
    return max(variants, key=_rank)


def worst_variant(variants: Sequence[Variant]) -> Variant:
    """Lowest-quality variant of a ladder (deterministic tie-break)."""
    return min(variants, key=_rank)


def get_family(name: str) -> Sequence[Variant]:
    if name in PAPER_FAMILIES:
        return PAPER_FAMILIES[name]
    return tuple(lm_ladder(name))


def feasible_slices(v: Variant) -> List[int]:
    """Slice sizes that can host this variant (the OOM-edge filter)."""
    return [s for s in SL.SLICE_SIZES if SL.fits(v.mem_gb, s)]
