"""Clover and the paper's competing schemes (§5.1):

  BASE    — highest-quality variant, unpartitioned blocks (carbon-unaware).
  CO2OPT  — finest feasible partition, smallest variant (carbon-minimal).
  BLOVER  — carbon-aware random search in the raw (x^p, x^v) space: all of
            Clover's machinery except the configuration-graph optimizer.
  CLOVER  — graph-space simulated annealing (annealing.py), warm-started.
  ORACLE  — instant argmax-f over the standardized offline-profiled space
            (uniform partition + per-slice-type variant across blocks),
            zero optimization time — the paper's infeasible upper bound.
"""
from __future__ import annotations

import dataclasses
import itertools
import math
import random
from typing import Callable, Dict, List, Optional, Sequence, Tuple

from repro.core import annealing as SA
from repro.core import config_graph as CG
from repro.core import objective as OBJ
from repro.core import slices as SL
from repro.core.catalog import Variant, best_variant, worst_variant


@dataclasses.dataclass
class SchemeContext:
    family: str
    variants: Sequence[Variant]
    n_blocks: int
    arrival_rps: float
    obj_cfg: OBJ.ObjectiveConfig
    sa_cfg: SA.SAConfig
    rng: random.Random

    def evaluator(self) -> Callable[[CG.ConfigGraph], OBJ.EvalResult]:
        return lambda g: OBJ.evaluate(g, self.variants, self.arrival_rps)


def base_config(ctx: SchemeContext) -> CG.ConfigGraph:
    best = best_variant(ctx.variants)
    return CG.ConfigGraph.uniform(ctx.family, best.name, SL.BLOCK_CHIPS,
                                  ctx.n_blocks)


def co2opt_config(ctx: SchemeContext) -> CG.ConfigGraph:
    small = worst_variant(ctx.variants)
    chips = min(s for s in SL.SLICE_SIZES if SL.fits(small.mem_gb, s))
    return CG.ConfigGraph.uniform(ctx.family, small.name, chips, ctx.n_blocks)


class Scheme:
    name = "abstract"
    carbon_aware = False

    def initial(self, ctx: SchemeContext) -> CG.ConfigGraph:
        raise NotImplementedError

    def reoptimize(self, ctx: SchemeContext, ci: float,
                   current: CG.ConfigGraph
                   ) -> Tuple[CG.ConfigGraph, Optional[SA.SAOutcome]]:
        return current, None


class Base(Scheme):
    name = "BASE"

    def initial(self, ctx):
        return base_config(ctx)


class CO2Opt(Scheme):
    name = "CO2OPT"

    def initial(self, ctx):
        return co2opt_config(ctx)


class Clover(Scheme):
    name = "CLOVER"
    carbon_aware = True

    def initial(self, ctx):
        return base_config(ctx)

    def reoptimize(self, ctx, ci, current):
        out = SA.anneal(current, ctx.variants, ctx.evaluator(), ci,
                        ctx.obj_cfg, ctx.sa_cfg, ctx.rng)
        return out.best, out


class Blover(Scheme):
    """Random search over raw (x^p, x^v): same eval budget and termination
    rules as Clover, no graph neighborhood structure (paper §5.1)."""
    name = "BLOVER"
    carbon_aware = True

    def initial(self, ctx):
        return base_config(ctx)

    def reoptimize(self, ctx, ci, current):
        evaluator = ctx.evaluator()
        evals: List[SA.Evaluation] = []
        t = 0.0

        def run_eval(g):
            nonlocal t
            t += ctx.sa_cfg.eval_window_s
            res = evaluator(g)
            f = OBJ.objective_f(res, ci, ctx.obj_cfg)
            h = OBJ.sa_energy(res, ci, ctx.obj_cfg)
            ev = SA.Evaluation(g, res, f, h, OBJ.meets_sla(res, ctx.obj_cfg), t)
            evals.append(ev)
            return ev

        best = run_eval(current)
        stale = 0
        while t < ctx.sa_cfg.time_limit_s and stale < ctx.sa_cfg.stale_limit:
            cand = run_eval(CG.random_config(ctx.family, ctx.variants,
                                             ctx.n_blocks, ctx.rng))
            improved = False
            if cand.sla_ok and (not best.sla_ok or cand.f > best.f):
                best, improved = cand, True
            elif not best.sla_ok and cand.h < best.h:
                best, improved = cand, True
            stale = 0 if improved else stale + 1
        return best.graph, SA.SAOutcome(best.graph, best.f, evals, t)


class Oracle(Scheme):
    """Exhaustive offline profile over the standardized space (the paper
    limits ORACLE to uniform per-block configurations; it still took two
    weeks of wall-time on their testbed — here the profile is analytic)."""
    name = "ORACLE"
    carbon_aware = True

    def __init__(self):
        self._space: Optional[List[CG.ConfigGraph]] = None

    def _build_space(self, ctx: SchemeContext) -> List[CG.ConfigGraph]:
        graphs: Dict = {}
        for part in SL.partition_catalog():
            sizes = sorted(set(part), reverse=True)
            feas = {s: [v for v in ctx.variants if SL.fits(v.mem_gb, s)]
                    for s in sizes}
            if any(not feas[s] for s in sizes):
                continue
            for choice in itertools.product(*(feas[s] for s in sizes)):
                weights: Dict = {}
                vmap = dict(zip(sizes, choice))
                for s in part:
                    e = (vmap[s].name, s)
                    weights[e] = weights.get(e, 0) + ctx.n_blocks
                g = CG.ConfigGraph.from_dict(ctx.family, weights)
                graphs[g.edges] = g
        return list(graphs.values())

    def initial(self, ctx):
        return base_config(ctx)

    def reoptimize(self, ctx, ci, current):
        if self._space is None:
            self._space = self._build_space(ctx)
        evaluator = ctx.evaluator()
        best_g, best_f = current, -float("inf")
        for g in self._space:
            res = evaluator(g)
            if not OBJ.meets_sla(res, ctx.obj_cfg):
                continue
            f = OBJ.objective_f(res, ci, ctx.obj_cfg)
            if f > best_f:
                best_g, best_f = g, f
        return best_g, None          # zero optimization time (oracular)


SCHEMES = {s.name: s for s in (Base(), CO2Opt(), Blover(), Clover(), Oracle())}


def make_scheme(name: str) -> Scheme:
    cls = {"BASE": Base, "CO2OPT": CO2Opt, "BLOVER": Blover,
           "CLOVER": Clover, "ORACLE": Oracle}[name]
    return cls()
