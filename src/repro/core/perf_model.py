"""Latency / power / energy model for (variant × slice) — calibrated to the
paper's measured phenomena, applied to v5e slices.

On real hardware these numbers come from the measurement service (the paper
modifies carbontracker and times requests); in this CPU container the model
is analytic, with three calibrated mechanisms that reproduce the paper's
motivation figures:

  1. Batch-1 inference achieves a few-percent MXU utilization that *grows*
     with model size (eff1 ∝ FLOPs^0.55, the observed trend across model
     families).  t1 = FLOPs / (peak × eff1).
  2. Model-parallel scaling across a slice follows Amdahl (parallel fraction
     α = W/(W + 2 GF)) plus a per-hop ICI sync term — spreading a small model
     thin *increases* latency (paper Fig. 3's latency cost), while large
     variants still speed up on big slices (BASE = lowest latency, §5.1).
  3. A chip serving a request draws "busy" power (210 W) regardless of how
     well the request uses the MXU; an idle-but-allocated chip draws 25 W
     (idle/busy ≈ 0.12 — calibrated so the BASE→CO2OPT fleet-level span
     matches the paper's measured 80-85 % bound; EXPERIMENTS.md §Calibration
     reports the sensitivity of every headline number to this ratio).
     Fine partitions keep fewer chips busy per request → the ~30-40 %
     carbon/request reduction of Fig. 3 at identical offered load.

Peak power (220 W) is only approached by large-batch training and never at
batch-1 serving; constants are documented in EXPERIMENTS.md §Calibration.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Dict, Tuple

from repro.core import slices as SL
from repro.core.catalog import Variant

P_IDLE_W = 25.0
P_BUSY_W = 210.0


@dataclasses.dataclass(frozen=True)
class ServicePoint:
    latency_s: float          # single-request service latency on the slice
    throughput_rps: float     # sustained rate of the instance (1/latency)
    busy_power_w: float       # slice power while serving
    energy_per_req_j: float   # at full load
    utilization: float        # MXU utilization while busy (roofline fraction)


def _eff1(v: Variant) -> float:
    """Single-chip batch-1 MXU utilization (grows with model size)."""
    w_g = max(v.flops_g, 1e-3)
    return min(1.2e-3 * w_g ** 0.55, 0.35)


def _alpha(v: Variant) -> float:
    """Amdahl parallel fraction of the per-request work."""
    w = v.flops_g * 1e9
    return w / (w + 2e9)


def _layers_proxy(v: Variant) -> float:
    return 2.0 * math.log2(1.0 + v.params_m)


def latency_s(v: Variant, chips: int) -> float:
    w = v.flops_g * 1e9
    t1 = w / (SL.PEAK_FLOPS_BF16 * _eff1(v))
    a = _alpha(v)
    t = t1 * ((1.0 - a) + a / chips)
    sync = 2.0e-5 * (chips - 1) * _layers_proxy(v)
    return t + sync + 5e-4                     # + host dispatch overhead


def service_point(v: Variant, chips: int) -> ServicePoint:
    lat = latency_s(v, chips)
    tput = 1.0 / lat
    p_busy = chips * P_BUSY_W
    energy = p_busy * lat
    util = (v.flops_g * 1e9) / (chips * SL.PEAK_FLOPS_BF16 * lat)
    return ServicePoint(lat, tput, p_busy, energy, util)


def instance_power_w(chips: int, busy_frac: float) -> float:
    b = min(max(busy_frac, 0.0), 1.0)
    return chips * (P_IDLE_W + (P_BUSY_W - P_IDLE_W) * b)


_CACHE: Dict[Tuple[str, int], ServicePoint] = {}


def cached_point(v: Variant, chips: int) -> ServicePoint:
    key = (v.key, chips)
    if key not in _CACHE:
        _CACHE[key] = service_point(v, chips)
    return _CACHE[key]


def reconfig_seconds(v: Variant, chips: int) -> float:
    """Instance re-instantiation cost: weight reload over DCN (25 GB/s
    aggregate per block) + runtime restart — the paper's repartition +
    service-reinit overhead, charged on every reconfiguration."""
    weight_bytes = v.params_m * 1e6 * 2.0
    return 5.0 + weight_bytes / 25e9
