"""Clover master controller (paper §4.3 + Fig. 5): monitors grid carbon
intensity, re-invokes the optimizer on configurable triggers, and tracks the
serving configuration over a trace.

Re-invocation triggers (paper §4.2): carbon-intensity change beyond a
threshold (default 5 %), accuracy-threshold violation, SLA-limit change, or a
λ-parameter change.  The controller is driven by the simulator (or by the
real-execution engine) through ``maybe_reoptimize``.

On top of the paper's reactive trigger, an optional *predictive* trigger
(fleet layer) consults a carbon-intensity forecaster: if the forecast CI at
``t + forecast_horizon_s`` departs from the last-optimized CI beyond the same
threshold, the controller re-optimizes *ahead* of the swing against a blend
of current and forecast intensity — so the config is already right when the
solar valley (or its evening ramp) arrives, instead of one threshold-crossing
late.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, List, Optional, Protocol, Tuple

from repro.core import annealing as SA
from repro.core import catalog as CAT
from repro.core import config_graph as CG
from repro.core import schemes as SCH


class CIForecaster(Protocol):
    """Duck type implemented by fleet.forecast.Forecaster subclasses."""

    def predict(self, t: float, horizon_s: float) -> float: ...


@dataclasses.dataclass
class Invocation:
    t_s: float
    ci: float
    outcome: Optional[SA.SAOutcome]
    config: CG.ConfigGraph
    predictive: bool = False            # fired by the forecast trigger
    alert: bool = False                 # fired by an SLO/carbon burn alert


@dataclasses.dataclass
class Controller:
    scheme: SCH.Scheme
    ctx: SCH.SchemeContext
    ci_threshold: float = 0.05          # 5 % change re-invokes (paper §5.2.2)
    forecaster: Optional[CIForecaster] = None
    forecast_horizon_s: float = 3600.0
    forecast_blend: float = 0.5         # weight of forecast CI when acting early
    config: Optional[CG.ConfigGraph] = None
    last_opt_ci: Optional[float] = None        # observed CI at last invocation
    last_opt_hat: Optional[float] = None       # forecast CI at last invocation
    invocations: List[Invocation] = dataclasses.field(default_factory=list)
    # serving-backend hook: called with the new graph whenever the active
    # config changes (start / reoptimize / elastic scaling).  The real
    # engine's warm ``configure`` attaches here, so a fleet loop drives live
    # instances through the exact same path the simulator exercises.
    on_config_change: Optional[Callable[[CG.ConfigGraph], None]] = None
    # optional streaming telemetry (repro.obs.carbon_feed.CarbonFeed): when
    # attached, ``maybe_reoptimize(t)`` may omit ``ci`` and act on the
    # feed's latest measured snapshot instead of a trace lookup — the
    # "controller consumes the telemetry plane" coupling (codecarbon idiom)
    feed: Optional[object] = None
    # optional SLO/carbon burn-rate alerting (repro.obs.slo.SLOEvaluator):
    # when attached, every ``maybe_reoptimize(t)`` first advances the
    # evaluator at ``t``; a rule *starting* to fire forces a re-invocation
    # even when carbon intensity has not drifted — an exhausted error
    # budget is the controller's signal that the current config is wrong
    # regardless of what the grid is doing.
    alerts: Optional[object] = None
    last_alerts: List[object] = dataclasses.field(default_factory=list)
    _alert_fires_seen: int = 0

    def _notify(self, prev: Optional[CG.ConfigGraph]) -> None:
        if self.on_config_change is not None and self.config is not None \
                and (prev is None or prev.edges != self.config.edges):
            self.on_config_change(self.config)

    def start(self, t: float, ci: float) -> CG.ConfigGraph:
        self.config = self.scheme.initial(self.ctx)
        if self.scheme.carbon_aware:
            self.config, outcome = self.scheme.reoptimize(self.ctx, ci, self.config)
            self.invocations.append(Invocation(t, ci, outcome, self.config))
            self.last_opt_ci = ci
            self.last_opt_hat = (self.forecaster.predict(t, self.forecast_horizon_s)
                                 if self.forecaster is not None else ci)
        self._notify(None)
        return self.config

    def _drifted(self, anchor: Optional[float], ci: float) -> bool:
        if anchor is None:
            return True
        return abs(ci - anchor) / max(anchor, 1e-9) > self.ci_threshold

    def _forecast_ci(self, t: Optional[float]) -> Optional[float]:
        if self.forecaster is None or t is None:
            return None
        return self.forecaster.predict(t, self.forecast_horizon_s)

    def should_reoptimize(self, ci: float, t: Optional[float] = None) -> bool:
        """Reactive trigger: observed CI drifted from the observed CI at the
        last invocation (paper §4.2).  Predictive trigger: the forecast CI at
        t + horizon drifted from the forecast at the last invocation.  Each
        trigger compares against its *own* anchor — comparing the live
        observation against a stored blend would re-trip the threshold every
        window for as long as observation and forecast disagree (trigger
        ping-pong)."""
        if not self.scheme.carbon_aware:
            return False
        if self._drifted(self.last_opt_ci, ci):
            return True
        ci_hat = self._forecast_ci(t)
        return ci_hat is not None and self._drifted(self.last_opt_hat, ci_hat)

    def maybe_reoptimize(self, t: float, ci: Optional[float] = None
                         ) -> Tuple[CG.ConfigGraph, Optional[SA.SAOutcome]]:
        """Returns (active config, SA outcome if an invocation ran).

        ``ci`` may be omitted when a :class:`~repro.obs.carbon_feed.
        CarbonFeed` is attached: the controller then acts on the feed's
        latest *measured* snapshot (its window-end carbon intensity).  An
        explicit ``ci`` always wins, so existing callers are unchanged."""
        if ci is None:
            assert self.feed is not None, \
                "maybe_reoptimize needs an explicit ci or an attached feed"
            snap = self.feed.latest()
            assert snap is not None, \
                "carbon feed has no snapshot yet (heartbeat it first)"
            ci = snap.ci_g_per_kwh
        alert_fired = False
        if self.alerts is not None:
            self.last_alerts = list(self.alerts.evaluate(t))
            fires = sum(s.fire_count for s in self.last_alerts)
            if fires > self._alert_fires_seen:
                alert_fired = True
            self._alert_fires_seen = fires
        if not alert_fired and not self.should_reoptimize(ci, t):
            return self.config, None
        predictive = (not alert_fired
                      and not self._drifted(self.last_opt_ci, ci))
        ci_hat = self._forecast_ci(t)
        ci_opt = ci
        if predictive:
            b = self.forecast_blend
            ci_opt = (1.0 - b) * ci + b * ci_hat   # lead the trace
        prev = self.config
        new_cfg, outcome = self.scheme.reoptimize(self.ctx, ci_opt, self.config)
        self.config = new_cfg
        self.last_opt_ci = ci
        self.last_opt_hat = ci_hat if ci_hat is not None else ci
        self.invocations.append(Invocation(t, ci_opt, outcome, new_cfg,
                                           predictive, alert=alert_fired))
        self._notify(prev)
        return new_cfg, outcome

    # --- elastic scaling (graph additivity, paper §4.2) -------------------------
    def scale_blocks(self, delta_blocks: int, template: Optional[CG.ConfigGraph] = None):
        """Add/remove serving blocks by edge-weight arithmetic.

        Removal greedily subtracts instances summing to exactly 16 chips per
        lost block (an exact cover always exists: slice sizes divide the block
        and the graph is block-packable by construction) — modelling the
        instances a failed block actually hosted.  Addition brings the new
        block up with the highest-quality variant unpartitioned (``template``
        overrides); the caller re-optimizes right after, exactly as the
        controller does on any capacity event."""
        assert self.config is not None
        from repro.core import slices as SL
        g = self.config
        if delta_blocks < 0:
            for _ in range(-delta_blocks):
                remaining = SL.BLOCK_CHIPS
                w = g.weights()
                while remaining > 0:
                    # largest instance that still fits the remaining quota
                    cands = [(chips, e) for e, c in w.items()
                             for chips in [e[1]] if c > 0 and chips <= remaining]
                    assert cands, "graph not block-packable"
                    chips, e = max(cands)
                    w[e] -= 1
                    remaining -= chips
                g = CG.ConfigGraph.from_dict(g.family, w)
        elif delta_blocks > 0:
            if template is None:
                best = CAT.best_variant(self.ctx.variants)
                template = CG.ConfigGraph.uniform(g.family, best.name,
                                                  SL.BLOCK_CHIPS, 1)
            for _ in range(delta_blocks):
                g = g.add(template)
        self.ctx.n_blocks += delta_blocks
        prev, self.config = self.config, g
        self._notify(prev)
        return g
