"""Core NN building blocks (pure JAX, explicit param pytrees — no flax).

Every block is an (init_*, *_apply) function pair.  Params are plain dicts of
jnp arrays so they stack cleanly under ``jax.vmap`` for scan-over-layers and
shard cleanly under GSPMD via the logical rules in ``repro.sharding.rules``.
"""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig

Params = dict


def truncated_normal(key, shape, dtype, stddev):
    return (stddev * jax.random.truncated_normal(key, -2.0, 2.0, shape)).astype(dtype)


# =============================================================================
# Norms
# =============================================================================
def init_rmsnorm(key, dim: int, dtype) -> Params:
    del key
    return {"scale": jnp.ones((dim,), dtype=dtype)}


def rmsnorm_apply(p: Params, x: jnp.ndarray, eps: float = 1e-6) -> jnp.ndarray:
    dt = x.dtype
    xf = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
    y = xf * jax.lax.rsqrt(var + eps)
    return y.astype(dt) * p["scale"]


def init_layernorm(key, dim: int, dtype) -> Params:
    del key
    return {"scale": jnp.ones((dim,), dtype=dtype), "bias": jnp.zeros((dim,), dtype=dtype)}


def layernorm_apply(p: Params, x: jnp.ndarray, eps: float = 1e-6) -> jnp.ndarray:
    dt = x.dtype
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.mean(jnp.square(xf - mu), axis=-1, keepdims=True)
    y = (xf - mu) * jax.lax.rsqrt(var + eps)
    return y.astype(dt) * p["scale"] + p["bias"]


# =============================================================================
# Dense
# =============================================================================
def init_dense(key, d_in: int, d_out: int, dtype, bias: bool = False,
               stddev: Optional[float] = None) -> Params:
    stddev = stddev if stddev is not None else d_in ** -0.5
    p = {"w": truncated_normal(key, (d_in, d_out), dtype, stddev)}
    if bias:
        p["b"] = jnp.zeros((d_out,), dtype=dtype)
    return p


def dense_apply(p: Params, x: jnp.ndarray) -> jnp.ndarray:
    y = x @ p["w"]
    if "b" in p:
        y = y + p["b"]
    return y


# =============================================================================
# Rotary position embeddings (RoPE / partial rotary / M-RoPE)
# =============================================================================
def rope_table(positions: jnp.ndarray, d_rot: int, theta: float) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """cos/sin tables.  positions: (..., s) int32 -> (..., s, d_rot//2) f32."""
    half = d_rot // 2
    freqs = 1.0 / (theta ** (jnp.arange(0, half, dtype=jnp.float32) / half))
    ang = positions.astype(jnp.float32)[..., None] * freqs
    return jnp.cos(ang), jnp.sin(ang)


def mrope_table(positions: jnp.ndarray, d_rot: int, theta: float,
                sections: Tuple[int, ...]) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Multimodal RoPE (qwen2-vl).  positions: (3, b, s) — temporal/height/width
    streams; ``sections`` partitions the d_rot//2 frequency dims among streams."""
    assert sum(sections) == d_rot // 2, (sections, d_rot)
    cos_all, sin_all = rope_table(positions, d_rot, theta)  # (3, b, s, half)
    cos_parts, sin_parts = [], []
    off = 0
    for i, sec in enumerate(sections):
        cos_parts.append(cos_all[i, ..., off:off + sec])
        sin_parts.append(sin_all[i, ..., off:off + sec])
        off += sec
    return jnp.concatenate(cos_parts, -1), jnp.concatenate(sin_parts, -1)


def apply_rope(x: jnp.ndarray, cos: jnp.ndarray, sin: jnp.ndarray,
               partial: float = 1.0) -> jnp.ndarray:
    """x: (b, s, h, d).  cos/sin: (b, s, d_rot//2) or (s, d_rot//2)."""
    d = x.shape[-1]
    d_rot = int(d * partial)
    x_rot, x_pass = x[..., :d_rot], x[..., d_rot:]
    x1, x2 = jnp.split(x_rot, 2, axis=-1)
    if cos.ndim == 2:           # (s, half) -> broadcast over batch & heads
        cos_b = cos[None, :, None, :]
        sin_b = sin[None, :, None, :]
    else:                        # (b, s, half)
        cos_b = cos[:, :, None, :]
        sin_b = sin[:, :, None, :]
    cos_b = cos_b.astype(x.dtype)
    sin_b = sin_b.astype(x.dtype)
    r1 = x1 * cos_b - x2 * sin_b
    r2 = x2 * cos_b + x1 * sin_b
    out = jnp.concatenate([r1, r2], axis=-1)
    if d_rot < d:
        out = jnp.concatenate([out, x_pass], axis=-1)
    return out


# =============================================================================
# Attention (GQA + qk-norm + bias + sliding window), blocked for memory
# =============================================================================
def init_attention(key, cfg: ModelConfig) -> Params:
    ks = jax.random.split(key, 6)
    d, dh, H, K = cfg.d_model, cfg.d_head, cfg.n_heads, cfg.n_kv_heads
    p = {
        "wq": init_dense(ks[0], d, H * dh, cfg.dtype, bias=cfg.qkv_bias),
        "wk": init_dense(ks[1], d, K * dh, cfg.dtype, bias=cfg.qkv_bias),
        "wv": init_dense(ks[2], d, K * dh, cfg.dtype, bias=cfg.qkv_bias),
        "wo": init_dense(ks[3], H * dh, d, cfg.dtype, stddev=(H * dh) ** -0.5 / (2 * cfg.n_layers) ** 0.5),
    }
    if cfg.qk_norm:
        p["q_norm"] = init_rmsnorm(ks[4], dh, cfg.dtype)
        p["k_norm"] = init_rmsnorm(ks[5], dh, cfg.dtype)
    return p


def _project_qkv(p: Params, x: jnp.ndarray, cfg: ModelConfig, cos, sin):
    b, s, _ = x.shape
    q = dense_apply(p["wq"], x).reshape(b, s, cfg.n_heads, cfg.d_head)
    k = dense_apply(p["wk"], x).reshape(b, s, cfg.n_kv_heads, cfg.d_head)
    v = dense_apply(p["wv"], x).reshape(b, s, cfg.n_kv_heads, cfg.d_head)
    if cfg.qk_norm:
        q = rmsnorm_apply(p["q_norm"], q, cfg.norm_eps)
        k = rmsnorm_apply(p["k_norm"], k, cfg.norm_eps)
    if cos is not None:
        q = apply_rope(q, cos, sin, cfg.partial_rotary)
        k = apply_rope(k, cos, sin, cfg.partial_rotary)
    return q, k, v


def blocked_attention(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
                      *, causal: bool, window=None,
                      q_offset: int = 0, block_q: int = 512) -> jnp.ndarray:
    """Memory-bounded attention: scan over q blocks against full K/V.

    q: (b, sq, H, dh); k,v: (b, skv, K, dh).  GQA via head-group reshape.
    ``window``: None = full attention; otherwise an int *or traced scalar*
    (gemma3 scans a per-layer window through the layer stack) where a value
    <= 0 also means full attention.  Softmax in f32.
    O(block_q · skv) live score memory instead of O(sq · skv).
    """
    b, sq, H, dh = q.shape
    skv, K = k.shape[1], k.shape[2]
    g = H // K
    scale = dh ** -0.5
    nb = max(sq // block_q, 1)
    block_q = sq // nb
    assert sq % block_q == 0, (sq, block_q)

    kg = k.transpose(0, 2, 1, 3)                    # (b, K, skv, dh)
    vg = v.transpose(0, 2, 1, 3)
    qb = q.reshape(b, nb, block_q, K, g, dh).transpose(1, 0, 3, 4, 2, 5)
    # qb: (nb, b, K, g, block_q, dh)
    kv_pos = jnp.arange(skv)

    def one_block(carry, inp):
        qi, blk_idx = inp
        q_pos = q_offset + blk_idx * block_q + jnp.arange(block_q)
        s = jnp.einsum("bkgqd,bknd->bkgqn", (qi * scale).astype(kg.dtype), kg,
                       preferred_element_type=jnp.float32)
        mask = jnp.ones((block_q, skv), dtype=bool)
        if causal:
            mask &= q_pos[:, None] >= kv_pos[None, :]
        if window is not None:
            eff = jnp.where(jnp.asarray(window) > 0, jnp.asarray(window, jnp.int32),
                            jnp.int32(2**30))
            mask &= (q_pos[:, None] - kv_pos[None, :]) < eff
        s = jnp.where(mask[None, None, None], s, -1e30)
        p = jax.nn.softmax(s, axis=-1)
        o = jnp.einsum("bkgqn,bknd->bkgqd", p.astype(vg.dtype), vg,
                       preferred_element_type=jnp.float32)
        return carry, o.astype(q.dtype)

    # remat each q-block: without this, the scan's backward saves the f32
    # (block_q × skv) score/prob tensors of *every* block — O(sq·skv) memory,
    # exactly what blocking is meant to avoid.  Forward-only paths unaffected.
    one_block = jax.checkpoint(one_block)
    _, ob = jax.lax.scan(one_block, None, (qb, jnp.arange(nb)))
    # ob: (nb, b, K, g, block_q, dh)
    out = ob.transpose(1, 0, 4, 2, 3, 5).reshape(b, sq, H, dh)
    return out


def decode_attention_ref(q: jnp.ndarray, k_cache: jnp.ndarray, v_cache: jnp.ndarray,
                         length: jnp.ndarray, *, window=None) -> jnp.ndarray:
    """Single-position attention against a KV cache.

    q: (b, 1, H, dh); caches: (b, S, K, dh); length: () shared valid length,
    or (b,) per-row valid lengths — the slotted continuous-batching decode,
    same masking contract as ``kernels.decode_attention`` with
    ``kernels.ref`` as the CPU oracle.  The new token's position is
    length - 1 (per row).  ``window`` as in :func:`blocked_attention`.
    Returns (b, 1, H, dh).
    """
    b, _, H, dh = q.shape
    S, K = k_cache.shape[1], k_cache.shape[2]
    g = H // K
    scale = dh ** -0.5
    qg = q.reshape(b, K, g, dh)
    s = jnp.einsum("bkgd,bnkd->bkgn", (qg * scale).astype(k_cache.dtype),
                   k_cache, preferred_element_type=jnp.float32)
    pos = jnp.arange(S)[None, :]
    ln = jnp.asarray(length).reshape(-1, 1)     # () -> (1,1); (b,) -> (b,1)
    mask = pos < ln
    if window is not None:
        eff = jnp.where(jnp.asarray(window) > 0, jnp.asarray(window, jnp.int32),
                        jnp.int32(2**30))
        mask &= pos > (ln - 1 - eff)
    s = jnp.where(mask[:, None, None, :], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bkgn,bnkd->bkgd", p.astype(v_cache.dtype), v_cache,
                   preferred_element_type=jnp.float32)
    return o.reshape(b, 1, H, dh).astype(q.dtype)


def attention_apply(p: Params, x: jnp.ndarray, cfg: ModelConfig, cos, sin,
                    *, causal: bool = True, window=None,
                    kv: Optional[Tuple[jnp.ndarray, jnp.ndarray]] = None) -> jnp.ndarray:
    """Full-sequence attention (training / prefill).  ``kv`` overrides the
    self-attention K/V (cross-attention when not None)."""
    q, k, v = _project_qkv(p, x, cfg, cos, sin)
    if kv is not None:
        k, v = kv
        causal, window = False, None
    o = blocked_attention(q, k, v, causal=causal, window=window)
    b, s = x.shape[:2]
    return dense_apply(p["wo"], o.reshape(b, s, cfg.n_heads * cfg.d_head))


def attention_prefill_apply(p: Params, x: jnp.ndarray, cfg: ModelConfig,
                            cos, sin, *, causal: bool = True, window=None
                            ) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Full-sequence attention that also returns the rotated K/V so a prefill
    pass can populate a decode cache in one forward (no teacher-forcing
    replay).  Returns (out (b,s,d), k (b,s,K,dh), v (b,s,K,dh))."""
    q, k, v = _project_qkv(p, x, cfg, cos, sin)
    o = blocked_attention(q, k, v, causal=causal, window=window)
    b, s = x.shape[:2]
    return dense_apply(p["wo"], o.reshape(b, s, cfg.n_heads * cfg.d_head)), k, v


def attention_decode_slots_apply(p: Params, x: jnp.ndarray, cfg: ModelConfig,
                                 cos, sin, cache_k: jnp.ndarray,
                                 cache_v: jnp.ndarray, lengths: jnp.ndarray,
                                 *, window=None):
    """One continuous-batching decode step: each row scatters its new K/V at
    its own position ``lengths[i]`` and attends over its own valid prefix.
    x: (b, 1, d); caches (b, S, K, dh); lengths (b,) i32.
    Returns (out (b,1,d), new_cache_k, new_cache_v)."""
    q, k, v = _project_qkv(p, x, cfg, cos, sin)
    b = x.shape[0]
    rows = jnp.arange(b)
    cache_k = cache_k.at[rows, lengths].set(k[:, 0].astype(cache_k.dtype),
                                            mode="drop")
    cache_v = cache_v.at[rows, lengths].set(v[:, 0].astype(cache_v.dtype),
                                            mode="drop")
    o = decode_attention_ref(q, cache_k, cache_v, lengths + 1, window=window)
    out = dense_apply(p["wo"], o.reshape(b, 1, cfg.n_heads * cfg.d_head))
    return out, cache_k, cache_v


def gather_paged_kv(arena_k: jnp.ndarray, arena_v: jnp.ndarray,
                    tables: jnp.ndarray):
    """Materialize per-row contiguous KV views from a paged arena.

    arenas: (n_blocks, block_size, K, dh); tables: (b, n_pages) i32 arena
    block ids (0-padded — block 0 is the junk sink, masked by lengths at the
    attention).  Returns (k, v) shaped (b, n_pages·block_size, K, dh).  On
    TPU the Pallas ``kernels.paged_attention`` kernel performs this gather
    inside the BlockSpec index map instead of materializing it."""
    b, n_pages = tables.shape
    _, bs, K, dh = arena_k.shape
    k = arena_k[tables].reshape(b, n_pages * bs, K, dh)
    v = arena_v[tables].reshape(b, n_pages * bs, K, dh)
    return k, v


def attention_decode_paged_apply(p: Params, x: jnp.ndarray, cfg: ModelConfig,
                                 cos, sin, arena_k: jnp.ndarray,
                                 arena_v: jnp.ndarray, tables: jnp.ndarray,
                                 lengths: jnp.ndarray, write_bid: jnp.ndarray,
                                 write_off: jnp.ndarray, *, window=None):
    """One paged continuous-batching decode step: each row scatters its new
    K/V into arena block ``write_bid[i]`` at offset ``write_off[i]`` (the
    junk block 0 for inactive rows) and attends over its own block table's
    valid prefix.  x: (b, 1, d); arenas (n_blocks, bs, K, dh); tables
    (b, n_pages); lengths (b,) i32.  Returns (out, arena_k, arena_v)."""
    q, k, v = _project_qkv(p, x, cfg, cos, sin)
    b = x.shape[0]
    arena_k = arena_k.at[write_bid, write_off].set(k[:, 0].astype(arena_k.dtype))
    arena_v = arena_v.at[write_bid, write_off].set(v[:, 0].astype(arena_v.dtype))
    kc, vc = gather_paged_kv(arena_k, arena_v, tables)
    o = decode_attention_ref(q, kc, vc, lengths + 1, window=window)
    out = dense_apply(p["wo"], o.reshape(b, 1, cfg.n_heads * cfg.d_head))
    return out, arena_k, arena_v


def attention_prefill_paged_apply(p: Params, x: jnp.ndarray, cfg: ModelConfig,
                                  cos, sin, arena_k: jnp.ndarray,
                                  arena_v: jnp.ndarray, table: jnp.ndarray,
                                  positions: jnp.ndarray,
                                  write_bid: jnp.ndarray,
                                  write_off: jnp.ndarray, *, window=None):
    """One chunk of chunked prefill for a single sequence against the paged
    arena: the chunk's rotated K/V scatter into their arena slots (junk
    block 0 for the padded tail), then the chunk's queries attend causally
    over the whole gathered table — cached prefix blocks included, so a
    prefix-cache hit never replays shared tokens.

    x: (1, C, d); table: (n_pages,) i32; positions: (C,) absolute token
    positions of the chunk.  Returns (out (1, C, d), arena_k, arena_v)."""
    q, k, v = _project_qkv(p, x, cfg, cos, sin)
    C = x.shape[1]
    arena_k = arena_k.at[write_bid, write_off].set(k[0].astype(arena_k.dtype))
    arena_v = arena_v.at[write_bid, write_off].set(v[0].astype(arena_v.dtype))
    kc, vc = gather_paged_kv(arena_k, arena_v, table[None])   # (1, S, K, dh)
    S, K = kc.shape[1], kc.shape[2]
    H, dh = cfg.n_heads, cfg.d_head
    g = H // K
    qg = q.reshape(1, C, K, g, dh)
    s = jnp.einsum("bqkgd,bnkd->bkgqn", (qg * dh ** -0.5).astype(kc.dtype),
                   kc, preferred_element_type=jnp.float32)
    kv_pos = jnp.arange(S)
    mask = kv_pos[None, :] <= positions[:, None]              # causal (C, S)
    if window is not None:
        eff = jnp.where(jnp.asarray(window) > 0,
                        jnp.asarray(window, jnp.int32), jnp.int32(2**30))
        mask &= (positions[:, None] - kv_pos[None, :]) < eff
    s = jnp.where(mask[None, None, None], s, -1e30)
    pr = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bkgqn,bnkd->bqkgd", pr.astype(vc.dtype), vc,
                   preferred_element_type=jnp.float32)
    out = dense_apply(p["wo"], o.astype(q.dtype).reshape(1, C, H * dh))
    return out, arena_k, arena_v


def cross_kv(p: Params, memory: jnp.ndarray, cfg: ModelConfig):
    """Precompute cross-attention K/V from encoder memory."""
    b, s, _ = memory.shape
    k = dense_apply(p["wk"], memory).reshape(b, s, cfg.n_kv_heads, cfg.d_head)
    v = dense_apply(p["wv"], memory).reshape(b, s, cfg.n_kv_heads, cfg.d_head)
    if cfg.qk_norm:
        k = rmsnorm_apply(p["k_norm"], k, cfg.norm_eps)
    return k, v


def decode_attention_parts(q: jnp.ndarray, k_cache: jnp.ndarray,
                           v_cache: jnp.ndarray, length, *,
                           pos_offset=0, query_pos=None, window=None):
    """Unnormalized single-position attention over one KV segment: returns
    (acc (b,K,g,dh) f32, m (b,K,g) f32, l (b,K,g) f32) for online-softmax
    combination across segments (flash-decode partials).

    ``pos_offset`` — absolute position of the segment's slot 0 (suffix
    segments sit after the prefix); ``query_pos`` — absolute position of the
    query token (for windowed masks)."""
    b, _, H, dh = q.shape
    S, K = k_cache.shape[1], k_cache.shape[2]
    g = H // K
    scale = dh ** -0.5
    qg = q.reshape(b, K, g, dh)
    s = jnp.einsum("bkgd,bnkd->bkgn", (qg * scale).astype(k_cache.dtype),
                   k_cache, preferred_element_type=jnp.float32)
    pos = pos_offset + jnp.arange(S)
    mask = pos[None, :] < (pos_offset + length)
    if window is not None and query_pos is not None:
        eff = jnp.where(jnp.asarray(window) > 0, jnp.asarray(window, jnp.int32),
                        jnp.int32(2**30))
        mask &= pos[None, :] > (query_pos - eff)
    s = jnp.where(mask[:, None, None, :], s, -jnp.inf)
    m = jnp.max(s, axis=-1)
    p = jnp.exp(s - jnp.maximum(m[..., None], -1e30))
    l = jnp.sum(p, axis=-1)
    acc = jnp.einsum("bkgn,bnkd->bkgd", p.astype(v_cache.dtype), v_cache,
                     preferred_element_type=jnp.float32)
    return acc, jnp.maximum(m, -1e30), l


def combine_attention_parts(parts):
    """Merge flash-decode partials [(acc, m, l), ...] into (b, K, g, dh)."""
    m = parts[0][1]
    for _, mi, _ in parts[1:]:
        m = jnp.maximum(m, mi)
    acc = sum(a * jnp.exp(mi - m)[..., None] for a, mi, _ in parts)
    l = sum(li * jnp.exp(mi - m) for _, mi, li in parts)
    return acc / jnp.maximum(l, 1e-30)[..., None]


def attention_decode_split_apply(p: Params, x: jnp.ndarray, cfg: ModelConfig,
                                 cos, sin, prefix_k, prefix_v, sk, sv,
                                 pos: jnp.ndarray, prefix_len: jnp.ndarray,
                                 *, window=None):
    """Append-buffer decode (§Perf): the big prefix cache is read-only (so it
    can be sequence-sharded with zero update cost); the new token's K/V goes
    into a small replicated suffix ring via a local dynamic-update-slice.
    Returns (out, new_sk, new_sv) — prefix buffers are untouched."""
    q, k, v = _project_qkv(p, x, cfg, cos, sin)
    slot = pos - prefix_len
    sk = jax.lax.dynamic_update_slice(sk, k.astype(sk.dtype), (0, slot, 0, 0))
    sv = jax.lax.dynamic_update_slice(sv, v.astype(sv.dtype), (0, slot, 0, 0))
    part_prefix = decode_attention_parts(q, prefix_k, prefix_v, prefix_len,
                                         pos_offset=0, query_pos=pos,
                                         window=window)
    part_suffix = decode_attention_parts(q, sk, sv, slot + 1,
                                         pos_offset=prefix_len, query_pos=pos,
                                         window=window)
    o = combine_attention_parts([part_prefix, part_suffix]).astype(q.dtype)
    b = x.shape[0]
    out = dense_apply(p["wo"], o.reshape(b, 1, cfg.n_heads * cfg.d_head))
    return out, sk, sv


def attention_decode_apply(p: Params, x: jnp.ndarray, cfg: ModelConfig, cos, sin,
                           cache_k: jnp.ndarray, cache_v: jnp.ndarray,
                           pos: jnp.ndarray, *, window=None):
    """One-token decode.  x: (b, 1, d); caches (b, S, K, dh); pos: () int32.

    Returns (out (b,1,d), new_cache_k, new_cache_v)."""
    q, k, v = _project_qkv(p, x, cfg, cos, sin)
    cache_k = jax.lax.dynamic_update_slice(cache_k, k.astype(cache_k.dtype), (0, pos, 0, 0))
    cache_v = jax.lax.dynamic_update_slice(cache_v, v.astype(cache_v.dtype), (0, pos, 0, 0))
    o = decode_attention_ref(q, cache_k, cache_v, pos + 1, window=window)
    b = x.shape[0]
    out = dense_apply(p["wo"], o.reshape(b, 1, cfg.n_heads * cfg.d_head))
    return out, cache_k, cache_v


# =============================================================================
# MLP (SwiGLU or plain GELU)
# =============================================================================
def init_mlp(key, cfg: ModelConfig, d_ff: Optional[int] = None) -> Params:
    d_ff = d_ff or cfg.d_ff
    ks = jax.random.split(key, 3)
    d = cfg.d_model
    out_std = d_ff ** -0.5 / (2 * cfg.n_layers) ** 0.5
    if cfg.act == "silu":
        return {
            "wi_gate": init_dense(ks[0], d, d_ff, cfg.dtype),
            "wi_up": init_dense(ks[1], d, d_ff, cfg.dtype),
            "wo": init_dense(ks[2], d_ff, d, cfg.dtype, stddev=out_std),
        }
    return {
        "wi_up": init_dense(ks[1], d, d_ff, cfg.dtype),
        "wo": init_dense(ks[2], d_ff, d, cfg.dtype, stddev=out_std),
    }


def mlp_apply(p: Params, x: jnp.ndarray, cfg: ModelConfig) -> jnp.ndarray:
    up = dense_apply(p["wi_up"], x)
    if "wi_gate" in p:
        h = jax.nn.silu(dense_apply(p["wi_gate"], x)) * up
    else:
        h = jax.nn.gelu(up)
    return dense_apply(p["wo"], h)


# =============================================================================
# Embedding
# =============================================================================
def init_embedding(key, vocab: int, d: int, dtype) -> Params:
    return {"table": truncated_normal(key, (vocab, d), dtype, 1.0)}


def embedding_apply(p: Params, ids: jnp.ndarray, one_hot: bool = False) -> jnp.ndarray:
    """``one_hot=True`` (training): lookup as a one-hot contraction.  The
    gather's backward is a scatter-add into the vocab-sharded table, which
    GSPMD implements by all-gathering the full f32 hidden cotangent across the
    data axis (measured 5 GiB/microbatch on gemma3 — EXPERIMENTS.md §Perf A5);
    the contraction form keeps everything as partial-summed matmuls."""
    if one_hot:
        table = p["table"]
        oh = jax.nn.one_hot(ids, table.shape[0], dtype=table.dtype)
        return oh @ table
    return jnp.take(p["table"], ids, axis=0)


def embedding_logits(p: Params, x: jnp.ndarray) -> jnp.ndarray:
    """Tied-softmax readout."""
    return x @ p["table"].T
