"""Decoder-only LM stack (dense / MoE / SSM / hybrid / VLM) with
scan-over-layers so HLO size — and XLA compile time at 512 devices — is O(1)
in depth.  Layer params are stacked on a leading L axis via vmap'd init.

Three entry points per model:
  * forward_lm     — full-sequence (training / prefill) -> (logits, aux_loss)
  * init_kv_cache  — allocate decode state (KV caches / SSM states)
  * decode_step_lm — one-token decode against the cache

Heterogeneous layer stacks (gemma3's 5 local : 1 global pattern) stay inside a
single scan by passing the per-layer window / rope-selector as *scanned data*
rather than unrolling the stack.
"""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.ad_checkpoint
import jax.numpy as jnp
import numpy as np

from repro.models import layers as L
from repro.models import moe as M
from repro.models import ssm as S
from repro.models.config import ModelConfig

Params = dict


def _maybe_remat(fn, cfg: ModelConfig):
    """Per-layer activation checkpointing.  "full" = nothing saveable (layer
    inputs only — memory-lean default), "dots" = save matmul outputs (less
    recompute, more HBM), "collectives" = save the post-all-reduce block
    outputs so the backward's remat never re-runs the TP collectives (the
    §Perf collective-bound fix), "none" = no remat."""
    if not cfg.remat or cfg.remat_policy == "none":
        return fn
    if cfg.remat_policy == "dots":
        return jax.checkpoint(fn, policy=jax.checkpoint_policies.dots_with_no_batch_dims_saveable)
    if cfg.remat_policy == "collectives":
        return jax.checkpoint(
            fn, policy=jax.checkpoint_policies.save_only_these_names(
                "attn_out", "block_out"))
    return jax.checkpoint(fn)


def _sp_constraint(x, cfg: ModelConfig):
    """Megatron-style sequence parallelism: keep the residual stream sharded
    over the model axis on the sequence dim between blocks.  GSPMD turns the
    per-block TP all-reduce into reduce-scatter (+ all-gather at the next
    block's entry) and the saved scan carries shrink by the TP degree."""
    if not cfg.seq_parallel:
        return x
    from jax.sharding import PartitionSpec as P
    U = P.UNCONSTRAINED
    return jax.lax.with_sharding_constraint(x, P(U, "model", U))


# =============================================================================
# per-layer pattern (windows / local-global rope selection)
# =============================================================================
def layer_pattern(cfg: ModelConfig) -> Tuple[np.ndarray, np.ndarray]:
    """Returns (windows (L,), is_global (L,)) as host arrays.

    gemma3: pattern of ``local_global_ratio`` local layers followed by one
    global layer; local layers use sliding_window + rope_theta, global layers
    use full attention + global_rope_theta.
    """
    n = cfg.n_layers
    if cfg.local_global_ratio > 0:
        r = cfg.local_global_ratio
        is_global = np.array([(i % (r + 1)) == r for i in range(n)])
        windows = np.where(is_global, 0, cfg.sliding_window).astype(np.int32)
    else:
        is_global = np.ones((n,), dtype=bool)
        windows = np.full((n,), cfg.sliding_window, dtype=np.int32)
    return windows, is_global


def _has_window(cfg: ModelConfig) -> bool:
    return cfg.sliding_window > 0


# =============================================================================
# init
# =============================================================================
def init_decoder_layer(key, cfg: ModelConfig) -> Params:
    ks = jax.random.split(key, 4)
    p = {
        "ln1": L.init_rmsnorm(None, cfg.d_model, cfg.dtype),
        "attn": L.init_attention(ks[0], cfg),
        "ln2": L.init_rmsnorm(None, cfg.d_model, cfg.dtype),
    }
    if cfg.is_moe:
        p["moe"] = M.init_moe(ks[1], cfg)
    else:
        p["mlp"] = L.init_mlp(ks[1], cfg)
    return p


def init_ssm_layer(key, cfg: ModelConfig) -> Params:
    return {
        "ln": L.init_rmsnorm(None, cfg.d_model, cfg.dtype),
        "mamba": S.init_mamba2(key, cfg),
    }


def init_lm(key, cfg: ModelConfig) -> Params:
    k_emb, k_layers, k_shared, k_head = jax.random.split(key, 4)
    params: Params = {
        "embed": L.init_embedding(k_emb, cfg.padded_vocab, cfg.d_model, cfg.dtype),
        "final_norm": L.init_rmsnorm(None, cfg.d_model, cfg.dtype),
    }
    layer_keys = jax.random.split(k_layers, cfg.n_layers)
    if cfg.family == "ssm":
        params["layers"] = jax.vmap(lambda k: init_ssm_layer(k, cfg))(layer_keys)
    elif cfg.family == "hybrid":
        params["layers"] = jax.vmap(lambda k: init_ssm_layer(k, cfg))(layer_keys)
        params["shared"] = {
            "ln1": L.init_rmsnorm(None, cfg.d_model, cfg.dtype),
            "attn": L.init_attention(jax.random.fold_in(k_shared, 0), cfg),
            "ln2": L.init_rmsnorm(None, cfg.d_model, cfg.dtype),
            "mlp": L.init_mlp(jax.random.fold_in(k_shared, 1), cfg),
        }
    else:
        params["layers"] = jax.vmap(lambda k: init_decoder_layer(k, cfg))(layer_keys)
    if not cfg.tie_embeddings:
        params["lm_head"] = L.init_dense(k_head, cfg.d_model, cfg.padded_vocab, cfg.dtype)
    return params


# =============================================================================
# rope tables
# =============================================================================
def _rope_tables(cfg: ModelConfig, positions: jnp.ndarray,
                 mrope_positions: Optional[jnp.ndarray] = None):
    """Returns ((cos_l, sin_l), (cos_g, sin_g)) — local/global theta tables.
    Non-gemma archs get identical tables for both."""
    d_rot = int(cfg.d_head * cfg.partial_rotary)
    if cfg.mrope_sections and mrope_positions is not None:
        cos, sin = L.mrope_table(mrope_positions, d_rot, cfg.rope_theta, cfg.mrope_sections)
        return (cos, sin), (cos, sin)
    cos_l, sin_l = L.rope_table(positions, d_rot, cfg.rope_theta)
    if cfg.local_global_ratio > 0:
        cos_g, sin_g = L.rope_table(positions, d_rot, cfg.global_rope_theta)
    else:
        cos_g, sin_g = cos_l, sin_l
    return (cos_l, sin_l), (cos_g, sin_g)


# =============================================================================
# forward (train / prefill)
# =============================================================================
def forward_lm(params: Params, tokens: jnp.ndarray, cfg: ModelConfig, *,
               mrope_positions: Optional[jnp.ndarray] = None,
               train: bool = False,
               inputs_embeds: Optional[jnp.ndarray] = None,
               return_hidden: bool = False) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """tokens: (b, s) int32 -> (logits (b, s, V), aux_loss ()).
    ``return_hidden`` skips the LM head and returns the final hidden states
    (the chunked training loss applies the head chunk-by-chunk instead)."""
    if inputs_embeds is not None:
        x = inputs_embeds.astype(cfg.dtype)
        b, s = x.shape[:2]
    else:
        b, s = tokens.shape
        x = L.embedding_apply(params["embed"], tokens)
    positions = jnp.arange(s, dtype=jnp.int32)
    (cos_l, sin_l), (cos_g, sin_g) = _rope_tables(cfg, positions, mrope_positions)
    windows_np, is_global_np = layer_pattern(cfg)
    windows = jnp.asarray(windows_np)
    is_global = jnp.asarray(is_global_np)
    has_win = _has_window(cfg)

    if cfg.family == "ssm":
        def body(x, p):
            x = x + S.mamba2_apply(p["mamba"], L.rmsnorm_apply(p["ln"], x, cfg.norm_eps), cfg)
            return x, None
        if train:
            body = _maybe_remat(body, cfg)
        x, _ = jax.lax.scan(body, x, params["layers"])
        aux = jnp.zeros((), jnp.float32)

    elif cfg.family == "hybrid":
        per = cfg.attn_every
        G = cfg.n_layers // per
        grouped = jax.tree.map(lambda a: a.reshape(G, per, *a.shape[1:]), params["layers"])
        sh = params["shared"]

        def group_body(x, gp):
            def mbody(x, p):
                x = x + S.mamba2_apply(p["mamba"], L.rmsnorm_apply(p["ln"], x, cfg.norm_eps), cfg)
                return x, None
            x, _ = jax.lax.scan(mbody, x, gp)
            h = L.rmsnorm_apply(sh["ln1"], x, cfg.norm_eps)
            x = x + L.attention_apply(sh["attn"], h, cfg, cos_l, sin_l, causal=True)
            h = L.rmsnorm_apply(sh["ln2"], x, cfg.norm_eps)
            x = x + L.mlp_apply(sh["mlp"], h, cfg)
            return x, None

        if train:
            group_body = _maybe_remat(group_body, cfg)
        x, _ = jax.lax.scan(group_body, x, grouped)
        aux = jnp.zeros((), jnp.float32)

    else:
        def body(carry, xs):
            x, aux = carry
            p, win, isg = xs
            cos = jnp.where(isg, cos_g, cos_l)
            sin = jnp.where(isg, sin_g, sin_l)
            x = _sp_constraint(x, cfg)
            h = L.rmsnorm_apply(p["ln1"], x, cfg.norm_eps)
            a_out = L.attention_apply(p["attn"], h, cfg, cos, sin, causal=True,
                                      window=win if has_win else None)
            x = x + jax.ad_checkpoint.checkpoint_name(a_out, "attn_out")
            h = L.rmsnorm_apply(p["ln2"], x, cfg.norm_eps)
            if cfg.is_moe:
                y, a = M.moe_apply(p["moe"], h, cfg)
                aux = aux + a
            else:
                y = L.mlp_apply(p["mlp"], h, cfg)
            out = _sp_constraint(x + y, cfg)
            return (jax.ad_checkpoint.checkpoint_name(out, "block_out"), aux), None

        if train:
            body = _maybe_remat(body, cfg)
        (x, aux), _ = jax.lax.scan(body, (x, jnp.zeros((), jnp.float32)),
                                   (params["layers"], windows, is_global))

    x = L.rmsnorm_apply(params["final_norm"], x, cfg.norm_eps)
    if return_hidden:
        return x, aux
    if cfg.tie_embeddings:
        logits = L.embedding_logits(params["embed"], x)
    else:
        logits = L.dense_apply(params["lm_head"], x)
    return logits, aux


# =============================================================================
# decode
# =============================================================================
def init_kv_cache(cfg: ModelConfig, batch: int, max_len: int,
                  dtype=jnp.bfloat16) -> Params:
    """Allocate decode state.  Attention families: stacked (L, b, S, K, dh) KV;
    SSM families: O(1) conv + state buffers; hybrid: both (one KV per shared-
    attention application)."""
    K, dh = cfg.n_kv_heads, cfg.d_head
    if cfg.family == "ssm":
        caches = jax.vmap(lambda _: S.init_ssm_cache(cfg, batch, dtype))(
            jnp.arange(cfg.n_layers))
        return {"ssm": caches, "pos": jnp.zeros((), jnp.int32)}
    if cfg.family == "hybrid":
        G = cfg.n_layers // cfg.attn_every
        ssm = jax.vmap(lambda _: S.init_ssm_cache(cfg, batch, dtype))(
            jnp.arange(cfg.n_layers))
        return {
            "ssm": ssm,
            "k": jnp.zeros((G, batch, max_len, K, dh), dtype),
            "v": jnp.zeros((G, batch, max_len, K, dh), dtype),
            "pos": jnp.zeros((), jnp.int32),
        }
    cache = {
        "k": jnp.zeros((cfg.n_layers, batch, max_len, K, dh), dtype),
        "v": jnp.zeros((cfg.n_layers, batch, max_len, K, dh), dtype),
        "pos": jnp.zeros((), jnp.int32),
    }
    if cfg.decode_window > 0:
        # append-buffer decode (§Perf): read-only prefix + small write suffix
        W = cfg.decode_window
        cache["sk"] = jnp.zeros((cfg.n_layers, batch, W, K, dh), dtype)
        cache["sv"] = jnp.zeros((cfg.n_layers, batch, W, K, dh), dtype)
        cache["prefix_len"] = jnp.zeros((), jnp.int32)
    return cache


def decode_step_lm(params: Params, cache: Params, tokens: jnp.ndarray,
                   cfg: ModelConfig, *,
                   mrope_positions: Optional[jnp.ndarray] = None
                   ) -> Tuple[jnp.ndarray, Params]:
    """One decode step.  tokens: (b, 1) int32.  Returns (logits (b, V), cache)."""
    pos = cache["pos"]
    x = L.embedding_apply(params["embed"], tokens)        # (b, 1, d)
    positions = pos[None].astype(jnp.int32)               # (1,)
    (cos_l, sin_l), (cos_g, sin_g) = _rope_tables(cfg, positions, mrope_positions)
    windows_np, is_global_np = layer_pattern(cfg)
    has_win = _has_window(cfg)

    if cfg.family == "ssm":
        def body(x, xs):
            p, c = xs
            y, c2 = S.mamba2_decode_step(p["mamba"], L.rmsnorm_apply(p["ln"], x, cfg.norm_eps), c, cfg)
            return x + y, c2
        x, new_ssm = jax.lax.scan(body, x, (params["layers"], cache["ssm"]))
        new_cache = {"ssm": new_ssm, "pos": pos + 1}

    elif cfg.family == "hybrid":
        per = cfg.attn_every
        G = cfg.n_layers // per
        grouped_p = jax.tree.map(lambda a: a.reshape(G, per, *a.shape[1:]), params["layers"])
        grouped_c = jax.tree.map(lambda a: a.reshape(G, per, *a.shape[1:]), cache["ssm"])
        sh = params["shared"]

        def group_body(x, xs):
            gp, gc, kc, vc = xs
            def mbody(x, inner):
                p, c = inner
                y, c2 = S.mamba2_decode_step(p["mamba"], L.rmsnorm_apply(p["ln"], x, cfg.norm_eps), c, cfg)
                return x + y, c2
            x, gc2 = jax.lax.scan(mbody, x, (gp, gc))
            h = L.rmsnorm_apply(sh["ln1"], x, cfg.norm_eps)
            a, kc2, vc2 = L.attention_decode_apply(sh["attn"], h, cfg, cos_l, sin_l, kc, vc, pos)
            x = x + a
            h = L.rmsnorm_apply(sh["ln2"], x, cfg.norm_eps)
            x = x + L.mlp_apply(sh["mlp"], h, cfg)
            return x, (gc2, kc2, vc2)

        x, (ssm2, k2, v2) = jax.lax.scan(group_body, x,
                                         (grouped_p, grouped_c, cache["k"], cache["v"]))
        ssm2 = jax.tree.map(lambda a: a.reshape(cfg.n_layers, *a.shape[2:]), ssm2)
        new_cache = {"ssm": ssm2, "k": k2, "v": v2, "pos": pos + 1}

    else:
        windows = jnp.asarray(windows_np)
        is_global = jnp.asarray(is_global_np)
        split = cfg.decode_window > 0

        def body(x, xs):
            if split:
                p, kc, vc, sk, sv, win, isg = xs
            else:
                p, kc, vc, win, isg = xs
            cos = jnp.where(isg, cos_g, cos_l)
            sin = jnp.where(isg, sin_g, sin_l)
            h = L.rmsnorm_apply(p["ln1"], x, cfg.norm_eps)
            if split:
                a, sk2, sv2 = L.attention_decode_split_apply(
                    p["attn"], h, cfg, cos, sin, kc, vc, sk, sv, pos,
                    cache["prefix_len"], window=win if has_win else None)
                ys = (sk2, sv2)
            else:
                a, kc2, vc2 = L.attention_decode_apply(
                    p["attn"], h, cfg, cos, sin, kc, vc, pos,
                    window=win if has_win else None)
                ys = (kc2, vc2)
            x = x + a
            h = L.rmsnorm_apply(p["ln2"], x, cfg.norm_eps)
            if cfg.is_moe:
                y, _ = M.moe_apply(p["moe"], h, cfg)
            else:
                y = L.mlp_apply(p["mlp"], h, cfg)
            return x + y, ys

        if split:
            x, (sk2, sv2) = jax.lax.scan(
                body, x, (params["layers"], cache["k"], cache["v"],
                          cache["sk"], cache["sv"], windows, is_global))
            new_cache = {"k": cache["k"], "v": cache["v"], "sk": sk2,
                         "sv": sv2, "prefix_len": cache["prefix_len"],
                         "pos": pos + 1}
        else:
            x, (k2, v2) = jax.lax.scan(
                body, x, (params["layers"], cache["k"], cache["v"], windows,
                          is_global))
            new_cache = {"k": k2, "v": v2, "pos": pos + 1}

    x = L.rmsnorm_apply(params["final_norm"], x, cfg.norm_eps)
    if cfg.tie_embeddings:
        logits = L.embedding_logits(params["embed"], x)
    else:
        logits = L.dense_apply(params["lm_head"], x)
    return logits[:, 0, :], new_cache


# =============================================================================
# slotted continuous-batching decode (serving engine)
# =============================================================================
def supports_slots(cfg: ModelConfig) -> bool:
    """Families the slotted batched KV cache covers: pure-attention decoders
    (dense / MoE) with a classic DUS cache — no encoder, no SSM state, no
    per-stream M-RoPE positions."""
    return (cfg.family in ("dense", "moe") and cfg.n_enc_layers == 0
            and not cfg.mrope_sections)


def init_slot_cache(cfg: ModelConfig, n_slots: int, max_len: int,
                    dtype=jnp.bfloat16) -> Params:
    """Fixed-capacity batched KV cache: ``n_slots`` independent sequences,
    each with its own valid-prefix ``lengths[i]`` (the continuous-batching
    analogue of ``init_kv_cache``'s single scalar ``pos``)."""
    assert supports_slots(cfg), f"slotted cache unsupported for {cfg.family}"
    K, dh = cfg.n_kv_heads, cfg.d_head
    return {
        "k": jnp.zeros((cfg.n_layers, n_slots, max_len, K, dh), dtype),
        "v": jnp.zeros((cfg.n_layers, n_slots, max_len, K, dh), dtype),
        "lengths": jnp.zeros((n_slots,), jnp.int32),
    }


def prefill_kv_lm(params: Params, tokens: jnp.ndarray, cfg: ModelConfig
                  ) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Full-sequence forward that also emits every layer's rotated K/V, so a
    serving engine populates a decode cache in ONE pass instead of replaying
    the prompt token-by-token through ``decode_step_lm``.

    tokens: (b, s) i32 -> (logits (b, s, V), k (L, b, s, K, dh), v (...))."""
    assert supports_slots(cfg), f"prefill-kv unsupported for {cfg.family}"
    b, s = tokens.shape
    x = L.embedding_apply(params["embed"], tokens)
    positions = jnp.arange(s, dtype=jnp.int32)
    (cos_l, sin_l), (cos_g, sin_g) = _rope_tables(cfg, positions)
    windows_np, is_global_np = layer_pattern(cfg)
    windows = jnp.asarray(windows_np)
    is_global = jnp.asarray(is_global_np)
    has_win = _has_window(cfg)

    def body(x, xs):
        p, win, isg = xs
        cos = jnp.where(isg, cos_g, cos_l)
        sin = jnp.where(isg, sin_g, sin_l)
        h = L.rmsnorm_apply(p["ln1"], x, cfg.norm_eps)
        a_out, k, v = L.attention_prefill_apply(
            p["attn"], h, cfg, cos, sin, causal=True,
            window=win if has_win else None)
        x = x + a_out
        h = L.rmsnorm_apply(p["ln2"], x, cfg.norm_eps)
        if cfg.is_moe:
            y, _ = M.moe_apply(p["moe"], h, cfg)
        else:
            y = L.mlp_apply(p["mlp"], h, cfg)
        return x + y, (k, v)

    x, (k_all, v_all) = jax.lax.scan(body, x,
                                     (params["layers"], windows, is_global))
    x = L.rmsnorm_apply(params["final_norm"], x, cfg.norm_eps)
    if cfg.tie_embeddings:
        logits = L.embedding_logits(params["embed"], x)
    else:
        logits = L.dense_apply(params["lm_head"], x)
    return logits, k_all, v_all


def decode_slots_lm(params: Params, cache: Params, tokens: jnp.ndarray,
                    cfg: ModelConfig, active: jnp.ndarray
                    ) -> Tuple[jnp.ndarray, Params]:
    """One batched decode step over ALL slots of a slot cache.

    tokens: (n_slots, 1) i32 — one column across slots; ``active``:
    (n_slots,) bool — slots currently serving a request.  Free slots still
    compute (the batch shape is static for jit) but their cache writes land
    on positions a future admission's prefill overwrites, and their lengths
    do not advance.  Returns (logits (n_slots, V), new_cache)."""
    lengths = cache["lengths"]
    x = L.embedding_apply(params["embed"], tokens)
    positions = lengths[:, None]               # (n_slots, 1) per-slot position
    (cos_l, sin_l), (cos_g, sin_g) = _rope_tables(cfg, positions)
    windows_np, is_global_np = layer_pattern(cfg)
    windows = jnp.asarray(windows_np)
    is_global = jnp.asarray(is_global_np)
    has_win = _has_window(cfg)

    def body(x, xs):
        p, kc, vc, win, isg = xs
        cos = jnp.where(isg, cos_g, cos_l)
        sin = jnp.where(isg, sin_g, sin_l)
        h = L.rmsnorm_apply(p["ln1"], x, cfg.norm_eps)
        a, kc2, vc2 = L.attention_decode_slots_apply(
            p["attn"], h, cfg, cos, sin, kc, vc, lengths,
            window=win if has_win else None)
        x = x + a
        h = L.rmsnorm_apply(p["ln2"], x, cfg.norm_eps)
        if cfg.is_moe:
            y, _ = M.moe_apply(p["moe"], h, cfg)
        else:
            y = L.mlp_apply(p["mlp"], h, cfg)
        return x + y, (kc2, vc2)

    x, (k2, v2) = jax.lax.scan(body, x, (params["layers"], cache["k"],
                                         cache["v"], windows, is_global))
    x = L.rmsnorm_apply(params["final_norm"], x, cfg.norm_eps)
    if cfg.tie_embeddings:
        logits = L.embedding_logits(params["embed"], x)
    else:
        logits = L.dense_apply(params["lm_head"], x)
    new_cache = {"k": k2, "v": v2,
                 "lengths": lengths + active.astype(jnp.int32)}
    return logits[:, 0, :], new_cache


# =============================================================================
# paged KV arena (kvpool serving engine)
# =============================================================================
def init_block_arena(cfg: ModelConfig, n_blocks: int, block_size: int,
                     dtype=jnp.bfloat16, mesh=None) -> Params:
    """Paged KV arena: every sequence's cache is a list of fixed-size blocks
    carved from this one allocation (``serving.kvpool`` owns the map: free
    list, refcounts, block tables).  Block 0 is the junk sink for masked
    writes — it is never handed to a sequence.

    With ``mesh`` the arena comes back committed under the GSPMD rule
    (KV heads over "model", block dims unsharded — ``sharding.rules.
    arena_spec``) so the serving engine's donated prefill/decode jits
    specialize to the sharded layout."""
    assert supports_slots(cfg), f"paged arena unsupported for {cfg.family}"
    K, dh = cfg.n_kv_heads, cfg.d_head
    shape = (cfg.n_layers, n_blocks, block_size, K, dh)
    if mesh is None:
        return {"k": jnp.zeros(shape, dtype), "v": jnp.zeros(shape, dtype)}
    import jax
    from repro.sharding import rules as SR
    sh = SR.arena_shardings(mesh, cfg)
    zeros = jax.jit(lambda: jnp.zeros(shape, dtype), out_shardings=sh)
    return {"k": zeros(), "v": zeros()}


def prefill_paged_lm(params: Params, tokens: jnp.ndarray, cfg: ModelConfig,
                     arena: Params, table: jnp.ndarray, n_past: jnp.ndarray,
                     true_c: jnp.ndarray) -> Tuple[jnp.ndarray, Params]:
    """One CHUNK of chunked prefill for a single sequence.

    tokens: (1, C) i32 — the chunk, zero-padded past ``true_c``; table:
    (n_pages,) i32 block table (covers the whole sequence, 0-padded);
    n_past: scalar i32 tokens already in the arena (prefix-cache hits plus
    previously prefilled chunks); true_c: scalar i32 real chunk length.
    The chunk's K/V land at absolute positions ``n_past .. n_past+true_c-1``
    (padded tail rows scatter into the junk block); its queries attend
    causally over everything cached so far, which is exactly full-sequence
    causal attention computed incrementally — chunking changes scheduling,
    not math.  Returns (logits (1, C, V), new_arena)."""
    assert supports_slots(cfg), f"paged prefill unsupported for {cfg.family}"
    _, C = tokens.shape
    bs = arena["k"].shape[2]
    n_pages = table.shape[0]
    x = L.embedding_apply(params["embed"], tokens)
    positions = n_past + jnp.arange(C, dtype=jnp.int32)
    (cos_l, sin_l), (cos_g, sin_g) = _rope_tables(cfg, positions)
    windows_np, is_global_np = layer_pattern(cfg)
    has_win = _has_window(cfg)
    valid = positions < n_past + true_c
    write_bid = jnp.where(
        valid, table[jnp.clip(positions // bs, 0, n_pages - 1)], 0)
    write_off = positions % bs

    windows = jnp.asarray(windows_np)
    is_global = jnp.asarray(is_global_np)

    def body(x, xs):
        p, ak, av, win, isg = xs
        cos = jnp.where(isg, cos_g, cos_l)
        sin = jnp.where(isg, sin_g, sin_l)
        h = L.rmsnorm_apply(p["ln1"], x, cfg.norm_eps)
        a, ak2, av2 = L.attention_prefill_paged_apply(
            p["attn"], h, cfg, cos, sin, ak, av, table, positions,
            write_bid, write_off, window=win if has_win else None)
        x = x + a
        h = L.rmsnorm_apply(p["ln2"], x, cfg.norm_eps)
        if cfg.is_moe:
            y, _ = M.moe_apply(p["moe"], h, cfg)
        else:
            y = L.mlp_apply(p["mlp"], h, cfg)
        return x + y, (ak2, av2)

    x, (k2, v2) = jax.lax.scan(body, x, (params["layers"], arena["k"],
                                         arena["v"], windows, is_global))
    x = L.rmsnorm_apply(params["final_norm"], x, cfg.norm_eps)
    if cfg.tie_embeddings:
        logits = L.embedding_logits(params["embed"], x)
    else:
        logits = L.dense_apply(params["lm_head"], x)
    return logits, {"k": k2, "v": v2}


def decode_paged_lm(params: Params, arena: Params, tokens: jnp.ndarray,
                    cfg: ModelConfig, tables: jnp.ndarray,
                    lengths: jnp.ndarray, active: jnp.ndarray
                    ) -> Tuple[jnp.ndarray, Params]:
    """One batched decode step over all rows of a paged batch.

    tokens: (b, 1) i32; tables: (b, n_pages) i32; lengths: (b,) i32 valid
    token counts; active: (b,) bool.  Inactive rows ride along for static
    shapes but scatter into the junk block and their outputs are garbage —
    the host does not advance them (``lengths`` stay host-managed, unlike
    the slotted cache's device-side vector).  Returns (logits (b, V),
    new_arena)."""
    bs = arena["k"].shape[2]
    n_pages = tables.shape[1]
    b = tokens.shape[0]
    x = L.embedding_apply(params["embed"], tokens)
    positions = lengths[:, None]
    (cos_l, sin_l), (cos_g, sin_g) = _rope_tables(cfg, positions)
    windows_np, is_global_np = layer_pattern(cfg)
    has_win = _has_window(cfg)
    page = jnp.clip(lengths // bs, 0, n_pages - 1)
    write_bid = jnp.where(
        active, jnp.take_along_axis(tables, page[:, None], axis=1)[:, 0], 0)
    write_off = jnp.where(active, lengths % bs, 0)

    windows = jnp.asarray(windows_np)
    is_global = jnp.asarray(is_global_np)

    def body(x, xs):
        p, ak, av, win, isg = xs
        cos = jnp.where(isg, cos_g, cos_l)
        sin = jnp.where(isg, sin_g, sin_l)
        h = L.rmsnorm_apply(p["ln1"], x, cfg.norm_eps)
        a, ak2, av2 = L.attention_decode_paged_apply(
            p["attn"], h, cfg, cos, sin, ak, av, tables, lengths,
            write_bid, write_off, window=win if has_win else None)
        x = x + a
        h = L.rmsnorm_apply(p["ln2"], x, cfg.norm_eps)
        if cfg.is_moe:
            y, _ = M.moe_apply(p["moe"], h, cfg)
        else:
            y = L.mlp_apply(p["mlp"], h, cfg)
        return x + y, (ak2, av2)

    x, (k2, v2) = jax.lax.scan(body, x, (params["layers"], arena["k"],
                                         arena["v"], windows, is_global))
    x = L.rmsnorm_apply(params["final_norm"], x, cfg.norm_eps)
    if cfg.tie_embeddings:
        logits = L.embedding_logits(params["embed"], x)
    else:
        logits = L.dense_apply(params["lm_head"], x)
    return logits[:, 0, :], {"k": k2, "v": v2}


def decode_paged_multi_lm(params: Params, arena: Params, tokens: jnp.ndarray,
                          cfg: ModelConfig, tables: jnp.ndarray,
                          lengths: jnp.ndarray, active: jnp.ndarray,
                          n_steps: int
                          ) -> Tuple[jnp.ndarray, Params, jnp.ndarray,
                                     jnp.ndarray]:
    """``n_steps`` fused greedy decode steps over a paged batch — the
    device-resident decode loop.

    Each iteration is exactly one :func:`decode_paged_lm` step followed by
    the greedy feedback the serving engine used to run on the host: the
    argmax token becomes the next input for active rows and their lengths
    advance by one, all inside a single ``lax.fori_loop`` so the host never
    sees intermediate state.  Inactive rows keep their token/length and
    scatter into the junk block.  The caller guarantees every active row's
    block table covers ``lengths + n_steps`` positions and no row finishes
    mid-loop (``remaining >= n_steps``).

    tokens: (b, 1) i32; returns ``(toks (n_steps, b) i32, new_arena,
    next (b, 1) i32, lengths (b,) i32)`` — the greedy tokens of every step
    plus the advanced loop state, bit-identical to ``n_steps`` separate
    ``decode_paged_lm`` calls with host feedback."""
    act_col = active[:, None]
    act_i = active.astype(jnp.int32)

    def body(i, carry):
        arena, nxt, ln, toks = carry
        logits, arena = decode_paged_lm(params, arena, nxt, cfg, tables,
                                        ln, active)
        tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        nxt = jnp.where(act_col, tok[:, None], nxt)
        ln = ln + act_i
        toks = jax.lax.dynamic_update_index_in_dim(toks, tok, i, 0)
        return (arena, nxt, ln, toks)

    toks0 = jnp.zeros((n_steps, tokens.shape[0]), jnp.int32)
    arena, nxt, lengths, toks = jax.lax.fori_loop(
        0, n_steps, body, (arena, tokens, lengths, toks0))
    return toks, arena, nxt, lengths


# =============================================================================
# VLM helper — merge precomputed patch embeddings into the token stream
# =============================================================================
def merge_patch_embeds(token_embeds: jnp.ndarray, patch_embeds: jnp.ndarray,
                       image_mask: jnp.ndarray) -> jnp.ndarray:
    """Scatter patch embeddings over positions where image_mask is set.

    token_embeds: (b, s, d); patch_embeds: (b, n_patch, d);
    image_mask: (b, s) bool with exactly n_patch True per row (stub frontend:
    the vision tower output arrives precomputed, per the assignment spec).
    """
    b, s, d = token_embeds.shape
    idx = jnp.cumsum(image_mask.astype(jnp.int32), axis=1) - 1
    idx = jnp.clip(idx, 0, patch_embeds.shape[1] - 1)
    gathered = jnp.take_along_axis(patch_embeds, idx[..., None], axis=1)
    return jnp.where(image_mask[..., None], gathered, token_embeds)


def default_mrope_positions(batch: int, seq: int) -> jnp.ndarray:
    """Text-only M-RoPE positions: all three streams equal (qwen2-vl)."""
    pos = jnp.broadcast_to(jnp.arange(seq, dtype=jnp.int32)[None], (batch, seq))
    return jnp.broadcast_to(pos[None], (3, batch, seq))
