"""Mamba2 (state-space duality) block — chunked SSD in pure JAX.

Follows the minimal SSD formulation of arXiv:2405.21060 §6: the sequence is
tiled into chunks; within-chunk terms use the quadratic (attention-dual) form,
across-chunk terms use the linear recurrence over chunk states.  The
within-chunk contraction is the compute hot-spot and has a Pallas kernel
(`repro.kernels.ssd_scan`) validated against `ssd_ref` here.

TPU sharding note: the canonical fused ``in_proj`` is split into separate
z / x / B / C / dt projections so the SSM head dimension shards cleanly on the
``model`` mesh axis (heads × head_dim are contiguous per projection), instead
of GSPMD halo-exchanging across a fused output that mixes shard-unaligned
channel groups.

Decode is the exact O(1) recurrence: h ← h·exp(Δ·A) + Δ·B·x, y = C·h + D·x,
plus a rolling depthwise-conv buffer.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig
from repro.models import layers as L

Params = dict


# =============================================================================
# init
# =============================================================================
def init_mamba2(key, cfg: ModelConfig) -> Params:
    ks = jax.random.split(key, 8)
    d, di = cfg.d_model, cfg.d_inner
    G, N, H, k = cfg.ssm_groups, cfg.ssm_state, cfg.n_ssm_heads, cfg.ssm_conv
    out_std = di ** -0.5 / (2 * cfg.n_layers) ** 0.5
    return {
        "wz": L.init_dense(ks[0], d, di, cfg.dtype),
        "wx": L.init_dense(ks[1], d, di, cfg.dtype),
        "wB": L.init_dense(ks[2], d, G * N, cfg.dtype),
        "wC": L.init_dense(ks[3], d, G * N, cfg.dtype),
        "wdt": L.init_dense(ks[4], d, H, cfg.dtype),
        "conv_x": L.truncated_normal(ks[5], (k, di), cfg.dtype, k ** -0.5),
        "conv_B": L.truncated_normal(ks[6], (k, G * N), cfg.dtype, k ** -0.5),
        "conv_C": L.truncated_normal(ks[7], (k, G * N), cfg.dtype, k ** -0.5),
        "conv_bx": jnp.zeros((di,), cfg.dtype),
        "conv_bB": jnp.zeros((G * N,), cfg.dtype),
        "conv_bC": jnp.zeros((G * N,), cfg.dtype),
        "A_log": jnp.log(jnp.linspace(1.0, 16.0, H)).astype(jnp.float32),
        "D": jnp.ones((H,), jnp.float32),
        "dt_bias": jnp.zeros((H,), jnp.float32),
        "gate_norm": L.init_rmsnorm(None, di, cfg.dtype),
        "out_proj": L.init_dense(ks[4], di, d, cfg.dtype, stddev=out_std),
    }


# =============================================================================
# chunked SSD reference (pure jnp oracle; also the training path)
# =============================================================================
def _segsum(x: jnp.ndarray) -> jnp.ndarray:
    """x: (..., T) -> (..., T, T) with out[i, j] = sum(x[j+1..i]); -inf for j > i."""
    T = x.shape[-1]
    cs = jnp.cumsum(x, axis=-1)
    d = cs[..., :, None] - cs[..., None, :]
    mask = jnp.tril(jnp.ones((T, T), dtype=bool), k=0)
    return jnp.where(mask, d, -jnp.inf)


def ssd_ref(x: jnp.ndarray, dt: jnp.ndarray, A: jnp.ndarray, B: jnp.ndarray,
            C: jnp.ndarray, chunk: int,
            init_state: Optional[jnp.ndarray] = None) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Chunked state-space-duality scan.

    x:  (b, s, H, P)   head inputs
    dt: (b, s, H)      positive step sizes (already softplus'd + biased)
    A:  (H,)           negative decay rates
    B:  (b, s, G, N); C: (b, s, G, N)  (G groups broadcast over heads)
    Returns (y (b, s, H, P) f32, final_state (b, H, P, N) f32).
    """
    b, s, H, P = x.shape
    G, N = B.shape[2], B.shape[3]
    assert s % chunk == 0, (s, chunk)
    nc = s // chunk
    hpg = H // G

    xf = (x.astype(jnp.float32) * dt.astype(jnp.float32)[..., None])
    dA = dt.astype(jnp.float32) * A[None, None, :]                    # (b, s, H)

    xc = xf.reshape(b, nc, chunk, H, P)
    Bc = B.astype(jnp.float32).reshape(b, nc, chunk, G, N)
    Cc = C.astype(jnp.float32).reshape(b, nc, chunk, G, N)
    dAc = dA.reshape(b, nc, chunk, H).transpose(0, 3, 1, 2)           # (b, H, nc, l)
    dA_cs = jnp.cumsum(dAc, axis=-1)

    # ---- 1. within-chunk (quadratic dual form) ------------------------------
    Lmat = jnp.exp(_segsum(dAc))                                      # (b, H, nc, l, l)
    scores = jnp.einsum("bclgn,bcsgn->bgcls", Cc, Bc)                 # (b, G, nc, l, l)
    scores = jnp.repeat(scores, hpg, axis=1)                          # (b, H, nc, l, l)
    Y_diag = jnp.einsum("bhcls,bcshp->bclhp", scores * Lmat, xc)

    # ---- 2. per-chunk states -------------------------------------------------
    decay_states = jnp.exp(dA_cs[..., -1:] - dA_cs)                   # (b, H, nc, l)
    Bh = jnp.repeat(Bc, hpg, axis=3)                                  # (b, nc, l, H, N)
    states = jnp.einsum("bclhn,bhcl,bclhp->bchpn", Bh, decay_states, xc)

    # ---- 3. inter-chunk recurrence (scan over chunks) --------------------------
    chunk_decay = jnp.exp(dA_cs[..., -1])                             # (b, H, nc)
    if init_state is None:
        init_state = jnp.zeros((b, H, P, N), jnp.float32)

    def step(h, inp):
        st, dec = inp                                                  # (b,H,P,N), (b,H)
        prev = h
        h = h * dec[..., None, None] + st
        return h, prev

    final_state, prev_states = jax.lax.scan(
        step, init_state.astype(jnp.float32),
        (states.transpose(1, 0, 2, 3, 4), chunk_decay.transpose(2, 0, 1)))
    prev_states = prev_states.transpose(1, 0, 2, 3, 4)                # (b, nc, H, P, N)

    # ---- 4. off-diagonal (state → output) ----------------------------------------
    state_decay_out = jnp.exp(dA_cs)                                  # (b, H, nc, l)
    Ch = jnp.repeat(Cc, hpg, axis=3)                                  # (b, nc, l, H, N)
    Y_off = jnp.einsum("bclhn,bchpn,bhcl->bclhp", Ch, prev_states, state_decay_out)

    y = (Y_diag + Y_off).reshape(b, s, H, P)
    return y, final_state


# =============================================================================
# projections + causal depthwise conv
# =============================================================================
def _conv_causal(x: jnp.ndarray, w: jnp.ndarray, b: jnp.ndarray) -> jnp.ndarray:
    """Depthwise causal conv.  x: (b, s, c); w: (k, c)."""
    s = x.shape[1]
    k = w.shape[0]
    xp = jnp.pad(x, ((0, 0), (k - 1, 0), (0, 0)))
    out = sum(xp[:, i:i + s, :] * w[i][None, None, :] for i in range(k))
    return out + b


def _project(p: Params, x: jnp.ndarray, cfg: ModelConfig):
    z = L.dense_apply(p["wz"], x)
    xs = L.dense_apply(p["wx"], x)
    B = L.dense_apply(p["wB"], x)
    C = L.dense_apply(p["wC"], x)
    dt = L.dense_apply(p["wdt"], x)
    return z, xs, B, C, dt


def mamba2_apply(p: Params, x: jnp.ndarray, cfg: ModelConfig,
                 use_kernel: bool = False) -> jnp.ndarray:
    """Full-sequence Mamba2 block.  x: (b, s, d) -> (b, s, d)."""
    b, s, d = x.shape
    di, G, N, H, P = cfg.d_inner, cfg.ssm_groups, cfg.ssm_state, cfg.n_ssm_heads, cfg.ssm_head_dim
    z, xs, B, C, dt = _project(p, x, cfg)
    xs = jax.nn.silu(_conv_causal(xs, p["conv_x"], p["conv_bx"]))
    B = jax.nn.silu(_conv_causal(B, p["conv_B"], p["conv_bB"]))
    C = jax.nn.silu(_conv_causal(C, p["conv_C"], p["conv_bC"]))

    xs = xs.reshape(b, s, H, P)
    B = B.reshape(b, s, G, N)
    C = C.reshape(b, s, G, N)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"][None, None, :])
    A = -jnp.exp(p["A_log"])

    if use_kernel:
        from repro.kernels import ops as kops   # lazy import
        y, _ = kops.ssd_chunked(xs, dt, A, B, C, chunk=cfg.ssm_chunk)
    else:
        y, _ = ssd_ref(xs, dt, A, B, C, chunk=cfg.ssm_chunk)
    y = y + xs.astype(jnp.float32) * p["D"][None, None, :, None]
    y = y.reshape(b, s, di).astype(x.dtype)

    y = L.rmsnorm_apply(p["gate_norm"], y, cfg.norm_eps) * jax.nn.silu(z)
    return L.dense_apply(p["out_proj"], y)


# =============================================================================
# decode (exact O(1) recurrence)
# =============================================================================
def init_ssm_cache(cfg: ModelConfig, batch: int, dtype=jnp.bfloat16):
    di, G, N, k = cfg.d_inner, cfg.ssm_groups, cfg.ssm_state, cfg.ssm_conv
    return {
        "conv_x": jnp.zeros((batch, k - 1, di), dtype),
        "conv_B": jnp.zeros((batch, k - 1, G * N), dtype),
        "conv_C": jnp.zeros((batch, k - 1, G * N), dtype),
        "state": jnp.zeros((batch, cfg.n_ssm_heads, cfg.ssm_head_dim, N), jnp.float32),
    }


def _conv_step(hist: jnp.ndarray, new: jnp.ndarray, w: jnp.ndarray, b: jnp.ndarray):
    """hist: (b, k-1, c); new: (b, c).  Returns (out (b, c), new_hist)."""
    full = jnp.concatenate([hist, new[:, None, :].astype(hist.dtype)], axis=1)
    out = jnp.einsum("bkc,kc->bc", full.astype(jnp.float32), w.astype(jnp.float32)) + b.astype(jnp.float32)
    return out, full[:, 1:, :]


def mamba2_decode_step(p: Params, x: jnp.ndarray, cache: dict, cfg: ModelConfig):
    """x: (b, 1, d).  Returns (y (b, 1, d), new_cache)."""
    b = x.shape[0]
    di, G, N, H, P = cfg.d_inner, cfg.ssm_groups, cfg.ssm_state, cfg.n_ssm_heads, cfg.ssm_head_dim
    z, xs, B, C, dt = _project(p, x[:, 0, :], cfg)

    xs, conv_x = _conv_step(cache["conv_x"], xs, p["conv_x"], p["conv_bx"])
    B, conv_B = _conv_step(cache["conv_B"], B, p["conv_B"], p["conv_bB"])
    C, conv_C = _conv_step(cache["conv_C"], C, p["conv_C"], p["conv_bC"])
    xs, B, C = jax.nn.silu(xs), jax.nn.silu(B), jax.nn.silu(C)

    xs = xs.reshape(b, H, P)
    B = B.reshape(b, G, N)
    C = C.reshape(b, G, N)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"][None, :])     # (b, H)
    A = -jnp.exp(p["A_log"])
    dA = jnp.exp(dt * A[None, :])                                            # (b, H)

    hpg = H // G
    Bh = jnp.repeat(B, hpg, axis=1)                                          # (b, H, N)
    Ch = jnp.repeat(C, hpg, axis=1)
    state = cache["state"] * dA[..., None, None] + (
        (dt[..., None] * xs)[..., None] * Bh[:, :, None, :])                 # (b,H,P,N)
    y = jnp.einsum("bhpn,bhn->bhp", state, Ch) + xs * p["D"][None, :, None]
    y = y.reshape(b, di).astype(x.dtype)
    y = L.rmsnorm_apply(p["gate_norm"], y, cfg.norm_eps) * jax.nn.silu(z)
    out = L.dense_apply(p["out_proj"], y)[:, None, :]
    return out, {"conv_x": conv_x, "conv_B": conv_B, "conv_C": conv_C, "state": state}


def ssd_sequential_ref(x, dt, A, B, C, init_state=None):
    """Token-by-token oracle for ssd_ref / the Pallas kernel (slow, exact)."""
    b, s, H, P = x.shape
    G, N = B.shape[2], B.shape[3]
    hpg = H // G
    state = jnp.zeros((b, H, P, N), jnp.float32) if init_state is None else init_state
    xf = x.astype(jnp.float32)
    dtf = dt.astype(jnp.float32)
    Bf = jnp.repeat(B.astype(jnp.float32), hpg, axis=2)
    Cf = jnp.repeat(C.astype(jnp.float32), hpg, axis=2)

    def step(state, t):
        dA = jnp.exp(dtf[:, t] * A[None, :])                                  # (b, H)
        xt = xf[:, t] * dtf[:, t][..., None]                                  # (b, H, P)
        state = state * dA[..., None, None] + xt[..., None] * Bf[:, t][:, :, None, :]
        y = jnp.einsum("bhpn,bhn->bhp", state, Cf[:, t])
        return state, y

    state, ys = jax.lax.scan(step, state, jnp.arange(s))
    return ys.transpose(1, 0, 2, 3), state
