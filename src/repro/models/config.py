"""Architecture configuration for every model family in the pool.

A single dataclass covers dense / MoE / SSM / hybrid / enc-dec / VLM / audio
backbones; the registry (`models/registry.py`) interprets the fields.  All
assigned-pool architectures are instantiated exactly (see src/repro/configs/).
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                      # dense | moe | ssm | hybrid | encdec | vlm | audio
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_head: int
    d_ff: int
    vocab_size: int

    # --- attention options -------------------------------------------------
    qk_norm: bool = False            # qwen3 / gemma3: RMSNorm on q,k per head
    qkv_bias: bool = False           # qwen2 family
    rope_theta: float = 1.0e4
    partial_rotary: float = 1.0      # glm4: 0.5 (rope on half the head dims)
    sliding_window: int = 0          # 0 = full attention (local layers only)
    local_global_ratio: int = 0      # gemma3: 5 -> pattern [5 local, 1 global]
    global_rope_theta: float = 1.0e6
    mrope_sections: Tuple[int, ...] = ()   # qwen2-vl M-RoPE half-dim sections

    # --- MoE ----------------------------------------------------------------
    n_experts: int = 0
    n_shared_experts: int = 0
    top_k: int = 0
    d_expert_ff: int = 0
    moe_group_size: int = 512        # GShard dispatch group (tokens)
    capacity_factor: float = 1.25
    decode_capacity_factor: float = 4.0   # decode headroom (bounded by group)
    router_aux_weight: float = 0.001  # load-balance auxiliary loss

    # --- SSM (Mamba2 / SSD) --------------------------------------------------
    ssm_state: int = 0
    ssm_conv: int = 4
    ssm_expand: int = 2
    ssm_head_dim: int = 64
    ssm_chunk: int = 256
    ssm_groups: int = 1

    # --- hybrid (zamba2) ------------------------------------------------------
    attn_every: int = 0              # one *shared* attention block per k SSM layers

    # --- enc-dec (seamless backbone) -------------------------------------------
    n_enc_layers: int = 0            # >0 => encoder-decoder; n_layers = decoder

    # --- misc -------------------------------------------------------------------
    tie_embeddings: bool = False
    norm_eps: float = 1.0e-6
    act: str = "silu"                # silu (SwiGLU) | gelu (non-gated)
    dtype: jnp.dtype = jnp.bfloat16
    max_seq_len: int = 32768         # rope table length (dry-run overrides)
    remat: bool = True               # activation checkpointing for train_step
    remat_policy: str = "full"       # full | dots | collectives | none
    seq_parallel: bool = False       # Megatron-SP residual stream (§Perf)
    decode_window: int = 0           # >0: append-buffer decode cache (§Perf)

    # ---------------------------------------------------------------------------
    @property
    def is_moe(self) -> bool:
        return self.n_experts > 0

    @property
    def is_attention_free(self) -> bool:
        return self.family == "ssm"

    @property
    def d_inner(self) -> int:
        """SSM inner width."""
        return self.ssm_expand * self.d_model

    @property
    def n_ssm_heads(self) -> int:
        return self.d_inner // self.ssm_head_dim

    @property
    def q_per_kv(self) -> int:
        return self.n_heads // max(self.n_kv_heads, 1)

    @property
    def padded_experts(self) -> int:
        """Expert count padded for even expert-parallel sharding (qwen2-moe's
        60 routed experts → 64 on a 16-way model axis; padding experts are
        router-masked and never receive tokens)."""
        if self.n_experts >= 16 and self.n_experts % 16:
            return ((self.n_experts + 15) // 16) * 16
        return self.n_experts

    @property
    def padded_vocab(self) -> int:
        """Embedding-table vocab padded for even sharding over the model axis
        (standard framework practice; cfg.vocab_size stays the logical size).
        Full-size configs pad to a multiple of 512 (covers model-parallel
        degrees up to 512); tiny smoke configs to a multiple of 16."""
        mult = 512 if self.vocab_size >= 4096 else 16
        return ((self.vocab_size + mult - 1) // mult) * mult

    def with_(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)

    # --- analytic parameter / FLOP accounting (used by the perf model & tests) --
    def param_count(self) -> int:
        d, dh, H, K = self.d_model, self.d_head, self.n_heads, self.n_kv_heads
        attn = d * dh * H + 2 * d * dh * K + dh * H * d       # q,k,v,o
        if self.act == "silu":
            mlp = 3 * d * self.d_ff
        else:
            mlp = 2 * d * self.d_ff
        norms = 2 * d
        per_layer = 0
        n_attn_layers = self.n_layers
        if self.family == "ssm":
            n_attn_layers = 0
        if self.family == "hybrid" and self.attn_every:
            n_attn_layers = self.n_layers // self.attn_every  # shared block applications
        if self.family in ("ssm", "hybrid"):
            di, N, Hs = self.d_inner, self.ssm_state, self.n_ssm_heads
            ssm = (d * (2 * di + 2 * self.ssm_groups * N + Hs)   # in_proj
                   + self.ssm_conv * (di + 2 * self.ssm_groups * N)  # conv
                   + Hs * 2 + di                                    # A, D, dt_bias… + norm
                   + di * d)                                        # out_proj
            n_ssm = self.n_layers
            total_layers = n_ssm * (ssm + norms)
            if self.family == "hybrid":
                # ONE shared attention+mlp block (weights reused)
                total_layers += attn + mlp + norms
        elif self.is_moe:
            dff = self.d_expert_ff
            moe = self.n_experts * 3 * d * dff + d * self.n_experts
            if self.n_shared_experts:
                moe += self.n_shared_experts * 3 * d * dff
            per_layer = attn + moe + norms
            total_layers = self.n_layers * per_layer
        else:
            per_layer = attn + mlp + norms
            total_layers = self.n_layers * per_layer
        emb = self.vocab_size * d
        head = 0 if self.tie_embeddings else self.vocab_size * d
        enc = 0
        if self.n_enc_layers:
            enc = self.n_enc_layers * (attn + mlp + norms)
            # decoder cross-attention adds another attn block per layer
            total_layers += self.n_layers * attn
        return total_layers + enc + emb + head + d

    def active_param_count(self) -> int:
        """Params touched per token (MoE: only routed top-k + shared experts)."""
        if not self.is_moe:
            return self.param_count()
        d, dh, H, K = self.d_model, self.d_head, self.n_heads, self.n_kv_heads
        attn = d * dh * H + 2 * d * dh * K + dh * H * d
        dff = self.d_expert_ff
        active_moe = (self.top_k + self.n_shared_experts) * 3 * d * dff + d * self.n_experts
        per_layer = attn + active_moe + 2 * d
        emb = self.vocab_size * d
        head = 0 if self.tie_embeddings else self.vocab_size * d
        return self.n_layers * per_layer + emb + head + d

    def flops_per_token(self, seq_len: int = 0, decode: bool = False) -> float:
        """Approximate forward FLOPs/token: 2*N_active + attention term."""
        base = 2.0 * self.active_param_count()
        if self.family == "ssm":
            return base + 2.0 * self.n_layers * self.n_ssm_heads * self.ssm_head_dim * self.ssm_state * 4
        attn_layers = self.n_layers if self.family != "hybrid" else self.n_layers // max(self.attn_every, 1)
        ctx = seq_len if decode else seq_len / 2.0  # causal average
        if self.local_global_ratio and self.sliding_window:
            r = self.local_global_ratio
            local = attn_layers * r // (r + 1)
            glob = attn_layers - local
            ctx_local = min(ctx, self.sliding_window)
            attn_f = 4.0 * (local * ctx_local + glob * ctx) * self.n_heads * self.d_head
        else:
            attn_f = 4.0 * attn_layers * ctx * self.n_heads * self.d_head
        return base + attn_f
