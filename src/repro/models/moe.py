"""Mixture-of-Experts layer — group-wise GShard dense dispatch.

TPU-native formulation: tokens are tiled into groups of ``moe_group_size`` so
the one-hot dispatch/combine tensors stay bounded at
``T × E × C_group`` (MaxText-style), which GSPMD shards as
(group → data axis, expert → model axis) inserting the expected all-to-alls.

Supports shared experts (qwen2-moe: 4 shared + 60 routed top-4) and a
load-balance auxiliary loss (Switch-style).
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig
from repro.models import layers as L

Params = dict


def init_moe(key, cfg: ModelConfig) -> Params:
    ks = jax.random.split(key, 5)
    d, E, F = cfg.d_model, cfg.padded_experts, cfg.d_expert_ff
    out_std = F ** -0.5 / (2 * cfg.n_layers) ** 0.5
    p = {
        "router": L.truncated_normal(ks[0], (d, E), jnp.float32, d ** -0.5),
        "w_gate": L.truncated_normal(ks[1], (E, d, F), cfg.dtype, d ** -0.5),
        "w_up": L.truncated_normal(ks[2], (E, d, F), cfg.dtype, d ** -0.5),
        "w_down": L.truncated_normal(ks[3], (E, F, d), cfg.dtype, out_std),
    }
    if cfg.n_shared_experts:
        p["shared"] = L.init_mlp(ks[4], cfg, d_ff=cfg.n_shared_experts * F)
    return p


def _capacity(tokens_per_group: int, cfg: ModelConfig) -> int:
    c = int(tokens_per_group * cfg.top_k / cfg.padded_experts * cfg.capacity_factor)
    return max(c, 1)


def moe_apply(p: Params, x: jnp.ndarray, cfg: ModelConfig) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """x: (b, s, d) -> (y, aux_loss).

    Routing: softmax over experts, top-k per token, renormalized gates;
    capacity-truncated dense dispatch within each token group.
    """
    b, s, d = x.shape
    T = b * s
    E, k = cfg.padded_experts, cfg.top_k
    gsz = min(cfg.moe_group_size, T)
    while T % gsz:
        gsz //= 2
    G = T // gsz
    C = _capacity(gsz, cfg)
    if s == 1:
        # decode: generous capacity headroom (decode_capacity_factor ≈ 4×
        # the mean load, clamped to the group size so tiny groups are exactly
        # drop-free).  C = gsz would be adversarially drop-free but scales the
        # dense-dispatch einsums ~10× (measured — EXPERIMENTS.md §Perf C2).
        c_head = int(gsz * cfg.top_k / cfg.padded_experts
                     * cfg.decode_capacity_factor)
        C = min(gsz, max(c_head, cfg.top_k, 1))

    xg = x.reshape(G, gsz, d)
    logits = (xg.astype(jnp.float32) @ p["router"])            # (G, t, E)
    if E > cfg.n_experts:      # router-mask the EP padding experts
        pad_mask = jnp.arange(E) >= cfg.n_experts
        logits = jnp.where(pad_mask[None, None, :], -1e30, logits)
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, gate_idx = jax.lax.top_k(probs, k)              # (G, t, k)
    gate_vals = gate_vals / jnp.sum(gate_vals, -1, keepdims=True)

    # --- load-balance aux loss (Switch eq.4) --------------------------------
    me = jnp.mean(probs, axis=(0, 1))                          # mean prob per expert
    top1 = jax.nn.one_hot(gate_idx[..., 0], E)
    ce = jnp.mean(top1, axis=(0, 1))                           # fraction routed
    aux = cfg.router_aux_weight * E * jnp.sum(me * ce)

    # --- capacity-based positions -------------------------------------------
    # expert_mask: (G, t, k, E) one-hot of chosen experts
    expert_mask = jax.nn.one_hot(gate_idx, E, dtype=jnp.int32)
    # position of each (token, slot) within its expert queue, ordered by token
    flat = expert_mask.reshape(G, gsz * k, E)
    pos_in_expert = jnp.cumsum(flat, axis=1) * flat - 1        # (G, t*k, E)
    pos_in_expert = pos_in_expert.reshape(G, gsz, k, E)
    keep = (pos_in_expert < C) & (expert_mask > 0)

    # dispatch: (G, t, E, C) one-hot over capacity slot
    pos_clip = jnp.clip(pos_in_expert, 0, C - 1)
    disp = (jax.nn.one_hot(pos_clip, C, dtype=x.dtype)
            * keep[..., None].astype(x.dtype) * expert_mask[..., None].astype(x.dtype))
    dispatch = jnp.sum(disp, axis=2)                           # (G, t, E, C)
    combine = jnp.sum(disp * gate_vals[..., None, None].astype(x.dtype), axis=2)

    # --- expert compute ------------------------------------------------------
    xin = jnp.einsum("gtec,gtd->gecd", dispatch, xg)           # (G, E, C, d)
    h = jnp.einsum("gecd,edf->gecf", xin, p["w_gate"])
    u = jnp.einsum("gecd,edf->gecf", xin, p["w_up"])
    h = jax.nn.silu(h) * u
    out = jnp.einsum("gecf,efd->gecd", h, p["w_down"])
    y = jnp.einsum("gtec,gecd->gtd", combine, out).reshape(b, s, d)

    if "shared" in p:
        y = y + L.mlp_apply(p["shared"], x, cfg)
    return y, aux


def moe_apply_dense_ref(p: Params, x: jnp.ndarray, cfg: ModelConfig) -> jnp.ndarray:
    """Oracle: run *every* expert densely, weight by renormalized top-k gates.
    Equals moe_apply when capacity is unbounded (no token drops)."""
    b, s, d = x.shape
    E = cfg.padded_experts
    logits = x.astype(jnp.float32) @ p["router"]
    if E > cfg.n_experts:
        logits = jnp.where(jnp.arange(E)[None, None, :] >= cfg.n_experts,
                           -1e30, logits)
    probs = jax.nn.softmax(logits, -1)
    gv, gi = jax.lax.top_k(probs, cfg.top_k)
    gv = gv / jnp.sum(gv, -1, keepdims=True)
    gates = jnp.zeros_like(probs).at[
        jnp.arange(b)[:, None, None], jnp.arange(s)[None, :, None], gi
    ].set(gv)                                                   # (b, s, E)
    h = jnp.einsum("bsd,edf->bsef", x, p["w_gate"])
    u = jnp.einsum("bsd,edf->bsef", x, p["w_up"])
    out = jnp.einsum("bsef,efd->bsed", jax.nn.silu(h) * u, p["w_down"])
    y = jnp.einsum("bse,bsed->bsd", gates.astype(x.dtype), out)
    if "shared" in p:
        y = y + L.mlp_apply(p["shared"], x, cfg)
    return y
