"""Encoder-decoder backbone (seamless-m4t-large-v2 assignment).

The modality frontend is a stub per the assignment spec: ``src_embeds`` are
precomputed audio frame embeddings (b, s_src, d).  The encoder is a
bidirectional transformer; the decoder adds causal self-attention plus
cross-attention whose K/V are precomputed once per request (prefill) and
reused across decode steps.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models import layers as L
from repro.models.config import ModelConfig

Params = dict


def _maybe_remat(fn, cfg: ModelConfig):
    """Per-layer activation checkpointing.  "full" = nothing saveable (layer
    inputs only — memory-lean default), "dots" = save matmul outputs (less
    recompute, more HBM — a §Perf knob), "none" = no remat."""
    if not cfg.remat or cfg.remat_policy == "none":
        return fn
    if cfg.remat_policy == "dots":
        return jax.checkpoint(fn, policy=jax.checkpoint_policies.dots_with_no_batch_dims_saveable)
    return jax.checkpoint(fn)


def init_encoder_layer(key, cfg: ModelConfig) -> Params:
    ks = jax.random.split(key, 2)
    return {
        "ln1": L.init_rmsnorm(None, cfg.d_model, cfg.dtype),
        "attn": L.init_attention(ks[0], cfg),
        "ln2": L.init_rmsnorm(None, cfg.d_model, cfg.dtype),
        "mlp": L.init_mlp(ks[1], cfg),
    }


def init_decoder_layer(key, cfg: ModelConfig) -> Params:
    ks = jax.random.split(key, 3)
    return {
        "ln1": L.init_rmsnorm(None, cfg.d_model, cfg.dtype),
        "attn": L.init_attention(ks[0], cfg),
        "lnx": L.init_rmsnorm(None, cfg.d_model, cfg.dtype),
        "xattn": L.init_attention(ks[1], cfg),
        "ln2": L.init_rmsnorm(None, cfg.d_model, cfg.dtype),
        "mlp": L.init_mlp(ks[2], cfg),
    }


def init_encdec(key, cfg: ModelConfig) -> Params:
    k_emb, k_enc, k_dec, k_head = jax.random.split(key, 4)
    enc_keys = jax.random.split(k_enc, cfg.n_enc_layers)
    dec_keys = jax.random.split(k_dec, cfg.n_layers)
    params = {
        "embed": L.init_embedding(k_emb, cfg.padded_vocab, cfg.d_model, cfg.dtype),
        "enc_layers": jax.vmap(lambda k: init_encoder_layer(k, cfg))(enc_keys),
        "dec_layers": jax.vmap(lambda k: init_decoder_layer(k, cfg))(dec_keys),
        "enc_norm": L.init_rmsnorm(None, cfg.d_model, cfg.dtype),
        "final_norm": L.init_rmsnorm(None, cfg.d_model, cfg.dtype),
    }
    if not cfg.tie_embeddings:
        params["lm_head"] = L.init_dense(k_head, cfg.d_model, cfg.padded_vocab, cfg.dtype)
    return params


def encode(params: Params, src_embeds: jnp.ndarray, cfg: ModelConfig,
           train: bool = False) -> jnp.ndarray:
    """src_embeds: (b, s_src, d) -> encoder memory (b, s_src, d)."""
    x = src_embeds.astype(cfg.dtype)
    s = x.shape[1]
    cos, sin = L.rope_table(jnp.arange(s, dtype=jnp.int32),
                            int(cfg.d_head * cfg.partial_rotary), cfg.rope_theta)

    def body(x, p):
        h = L.rmsnorm_apply(p["ln1"], x, cfg.norm_eps)
        x = x + L.attention_apply(p["attn"], h, cfg, cos, sin, causal=False)
        h = L.rmsnorm_apply(p["ln2"], x, cfg.norm_eps)
        return x + L.mlp_apply(p["mlp"], h, cfg), None

    if train:
        body = _maybe_remat(body, cfg)
    x, _ = jax.lax.scan(body, x, params["enc_layers"])
    return L.rmsnorm_apply(params["enc_norm"], x, cfg.norm_eps)


def forward_encdec(params: Params, src_embeds: jnp.ndarray, tokens: jnp.ndarray,
                   cfg: ModelConfig, train: bool = False,
                   return_hidden: bool = False) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Teacher-forced seq2seq forward -> (logits (b, s_tgt, V), aux=0)."""
    memory = encode(params, src_embeds, cfg, train=train)
    b, s = tokens.shape
    x = L.embedding_apply(params["embed"], tokens)
    cos, sin = L.rope_table(jnp.arange(s, dtype=jnp.int32),
                            int(cfg.d_head * cfg.partial_rotary), cfg.rope_theta)

    def body(x, p):
        h = L.rmsnorm_apply(p["ln1"], x, cfg.norm_eps)
        x = x + L.attention_apply(p["attn"], h, cfg, cos, sin, causal=True)
        h = L.rmsnorm_apply(p["lnx"], x, cfg.norm_eps)
        kv = L.cross_kv(p["xattn"], memory, cfg)
        x = x + L.attention_apply(p["xattn"], h, cfg, None, None, kv=kv)
        h = L.rmsnorm_apply(p["ln2"], x, cfg.norm_eps)
        return x + L.mlp_apply(p["mlp"], h, cfg), None

    if train:
        body = _maybe_remat(body, cfg)
    x, _ = jax.lax.scan(body, x, params["dec_layers"])
    x = L.rmsnorm_apply(params["final_norm"], x, cfg.norm_eps)
    if return_hidden:
        return x, jnp.zeros((), jnp.float32)
    if cfg.tie_embeddings:
        logits = L.embedding_logits(params["embed"], x)
    else:
        logits = L.dense_apply(params["lm_head"], x)
    return logits, jnp.zeros((), jnp.float32)


# =============================================================================
# decode
# =============================================================================
def init_encdec_cache(params: Params, src_embeds: jnp.ndarray, cfg: ModelConfig,
                      max_len: int, dtype=jnp.bfloat16) -> Params:
    """Run the encoder once and precompute per-layer cross-attention K/V."""
    memory = encode(params, src_embeds, cfg)
    b = memory.shape[0]

    def xkv(_, p):
        k, v = L.cross_kv(p["xattn"], memory, cfg)
        return None, (k.astype(dtype), v.astype(dtype))

    _, (xk, xv) = jax.lax.scan(xkv, None, params["dec_layers"])
    K, dh = cfg.n_kv_heads, cfg.d_head
    return {
        "xk": xk, "xv": xv,                                  # (L, b, s_src, K, dh)
        "k": jnp.zeros((cfg.n_layers, b, max_len, K, dh), dtype),
        "v": jnp.zeros((cfg.n_layers, b, max_len, K, dh), dtype),
        "pos": jnp.zeros((), jnp.int32),
    }


def decode_step_encdec(params: Params, cache: Params, tokens: jnp.ndarray,
                       cfg: ModelConfig) -> Tuple[jnp.ndarray, Params]:
    """One decoder step with cached self-attn KV and fixed cross KV."""
    pos = cache["pos"]
    x = L.embedding_apply(params["embed"], tokens)
    cos, sin = L.rope_table(pos[None].astype(jnp.int32),
                            int(cfg.d_head * cfg.partial_rotary), cfg.rope_theta)

    def body(x, xs):
        p, kc, vc, xk, xv = xs
        h = L.rmsnorm_apply(p["ln1"], x, cfg.norm_eps)
        a, kc2, vc2 = L.attention_decode_apply(p["attn"], h, cfg, cos, sin, kc, vc, pos)
        x = x + a
        h = L.rmsnorm_apply(p["lnx"], x, cfg.norm_eps)
        q, _, _ = L._project_qkv(p["xattn"], h, cfg, None, None)
        o = L.decode_attention_ref(q, xk, xv, jnp.int32(xk.shape[1]))
        b = x.shape[0]
        x = x + L.dense_apply(p["xattn"]["wo"], o.reshape(b, 1, cfg.n_heads * cfg.d_head))
        h = L.rmsnorm_apply(p["ln2"], x, cfg.norm_eps)
        return x + L.mlp_apply(p["mlp"], h, cfg), (kc2, vc2)

    x, (k2, v2) = jax.lax.scan(
        body, x, (params["dec_layers"], cache["k"], cache["v"], cache["xk"], cache["xv"]))
    x = L.rmsnorm_apply(params["final_norm"], x, cfg.norm_eps)
    if cfg.tie_embeddings:
        logits = L.embedding_logits(params["embed"], x)
    else:
        logits = L.dense_apply(params["lm_head"], x)
    new_cache = dict(cache)
    new_cache.update({"k": k2, "v": v2, "pos": pos + 1})
    return logits[:, 0, :], new_cache
