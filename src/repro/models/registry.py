"""Uniform model interface over all architecture families.

Batch conventions (all jnp arrays / ShapeDtypeStructs):
  lm / moe / ssm / hybrid : {"tokens": (b, s) i32}            (+ "labels" for train)
  vlm                     : + {"mrope_positions": (3, b, s) i32}
  encdec / audio          : {"src_embeds": (b, s_src, d) bf16, "tokens": (b, s) i32}

Decode batches carry a single token column: {"tokens": (b, 1)}.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax.numpy as jnp

from repro.models import encdec as ED
from repro.models import transformer as T
from repro.models.config import ModelConfig

Params = dict


def init_params(key, cfg: ModelConfig) -> Params:
    if cfg.n_enc_layers > 0:
        return ED.init_encdec(key, cfg)
    return T.init_lm(key, cfg)


def forward(params: Params, batch: dict, cfg: ModelConfig,
            train: bool = False, return_hidden: bool = False
            ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Full-sequence forward -> (logits (b, s, V), aux_loss ())."""
    if cfg.n_enc_layers > 0:
        return ED.forward_encdec(params, batch["src_embeds"], batch["tokens"], cfg,
                                 train=train, return_hidden=return_hidden)
    return T.forward_lm(params, batch["tokens"], cfg,
                        mrope_positions=batch.get("mrope_positions"),
                        train=train, return_hidden=return_hidden)


def head_weights(params: Params, cfg: ModelConfig) -> jnp.ndarray:
    """LM-head matrix (d, V) — the tied path reuses the embedding table."""
    if cfg.tie_embeddings:
        return params["embed"]["table"].T
    return params["lm_head"]["w"]


def make_cache(params: Params, cfg: ModelConfig, batch_size: int, max_len: int,
               src_embeds: Optional[jnp.ndarray] = None,
               dtype=jnp.bfloat16) -> Params:
    if cfg.n_enc_layers > 0:
        assert src_embeds is not None, "enc-dec decode needs encoder inputs"
        return ED.init_encdec_cache(params, src_embeds, cfg, max_len, dtype)
    return T.init_kv_cache(cfg, batch_size, max_len, dtype)


def decode_step(params: Params, cache: Params, batch: dict,
                cfg: ModelConfig) -> Tuple[jnp.ndarray, Params]:
    """One-token decode -> (logits (b, V), new_cache)."""
    if cfg.n_enc_layers > 0:
        return ED.decode_step_encdec(params, cache, batch["tokens"], cfg)
    return T.decode_step_lm(params, cache, batch["tokens"], cfg,
                            mrope_positions=batch.get("mrope_positions"))


# --- slotted continuous-batching decode (serving engine) ----------------------
def supports_slots(cfg: ModelConfig) -> bool:
    """True when the family can serve through the slotted batched KV cache."""
    return cfg.n_enc_layers == 0 and T.supports_slots(cfg)


def make_slot_cache(cfg: ModelConfig, n_slots: int, max_len: int,
                    dtype=jnp.bfloat16) -> Params:
    """Fixed-capacity batched KV cache with a per-slot ``lengths`` vector."""
    return T.init_slot_cache(cfg, n_slots, max_len, dtype)


def prefill_kv(params: Params, batch: dict, cfg: ModelConfig
               ) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """One-pass prefill -> (logits (b, s, V), k (L, b, s, K, dh), v) so the
    engine populates slot caches without token-by-token prompt replay."""
    return T.prefill_kv_lm(params, batch["tokens"], cfg)


def decode_slots(params: Params, cache: Params, batch: dict, cfg: ModelConfig,
                 active: jnp.ndarray) -> Tuple[jnp.ndarray, Params]:
    """Batched decode over all slots -> (logits (n_slots, V), new_cache)."""
    return T.decode_slots_lm(params, cache, batch["tokens"], cfg, active)


# --- paged KV arena (kvpool serving engine) -----------------------------------
def supports_paged(cfg: ModelConfig) -> bool:
    """The paged arena covers exactly the slotted families (pure-attention
    decoders): the block table is a map over the same DUS-style cache."""
    return supports_slots(cfg)


def make_block_arena(cfg: ModelConfig, n_blocks: int, block_size: int,
                     dtype=jnp.bfloat16, mesh=None) -> Params:
    """Paged KV arena (block 0 = junk sink); ``serving.kvpool`` owns the
    free-list / refcount / block-table map of it.  ``mesh`` commits the
    arena under the GSPMD arena rule (KV heads → "model")."""
    return T.init_block_arena(cfg, n_blocks, block_size, dtype, mesh=mesh)


def shard_params(params: Params, cfg: ModelConfig, mesh) -> Params:
    """Mesh-aware entry point: place a params tree under the GSPMD rules
    (``sharding.rules.spec_for_param`` — Megatron column→row pairs, head
    guards, expert parallelism).  Host/replicated trees come back committed;
    jitted model fns called on the result specialize to the sharded layout."""
    import jax
    from repro.sharding import rules as SR
    shardings = SR.param_shardings(
        jax.eval_shape(lambda: params), cfg, mesh)
    return jax.device_put(params, shardings)


def prefill_paged(params: Params, batch: dict, cfg: ModelConfig,
                  arena: Params, table: jnp.ndarray, n_past: jnp.ndarray,
                  true_c: jnp.ndarray) -> Tuple[jnp.ndarray, Params]:
    """One chunk of chunked prefill for one sequence -> (logits (1, C, V),
    new_arena).  Positions n_past..n_past+true_c-1; the padded chunk tail
    scatters into the junk block."""
    return T.prefill_paged_lm(params, batch["tokens"], cfg, arena, table,
                              n_past, true_c)


def decode_paged(params: Params, arena: Params, batch: dict, cfg: ModelConfig,
                 tables: jnp.ndarray, lengths: jnp.ndarray,
                 active: jnp.ndarray) -> Tuple[jnp.ndarray, Params]:
    """Batched paged decode step -> (logits (b, V), new_arena).  ``lengths``
    are host-managed; inactive rows write to the junk block."""
    return T.decode_paged_lm(params, arena, batch["tokens"], cfg, tables,
                             lengths, active)


def decode_paged_multi(params: Params, arena: Params, batch: dict,
                       cfg: ModelConfig, tables: jnp.ndarray,
                       lengths: jnp.ndarray, active: jnp.ndarray,
                       n_steps: int
                       ) -> Tuple[jnp.ndarray, Params, jnp.ndarray,
                                  jnp.ndarray]:
    """``n_steps`` fused greedy paged decode steps with on-device token
    feedback -> (toks (n_steps, b), new_arena, next (b, 1), lengths (b,)).
    Bit-identical to ``n_steps`` host-fed :func:`decode_paged` calls; the
    caller guarantees block-table headroom and ``remaining >= n_steps``."""
    return T.decode_paged_multi_lm(params, arena, batch["tokens"], cfg,
                                   tables, lengths, active, n_steps)
