"""Prefill/decode disaggregated serving.

Splits :class:`~repro.serving.engine.RealEngine` into PREFILL workers and
DECODE workers behind the same ``ServingBackend`` protocol — callers (and
the fleet's ``probe_window``) drive it unchanged.

Why split: prefill is compute-bound, decode is bandwidth-bound.  Running
both phases on one worker makes long prompts stall running decodes (and
vice versa); splitting them lets each pool batch its own phase — and, per
EcoServe (PAPERS.md), makes the split a CARBON lever: compute-heavy
prefill workers can ride low-CI windows while the decode pool holds the
SLA.  The per-role joules split the engine reports (``prefill_energy_j``
/ ``decode_energy_j`` / ``handoff_energy_j``, plus ``energy_by_role`` on
every response) is what makes CI-aware placement of the two pools
measurable.

The lifecycle:

  1. a fresh request admits onto a PREFILL worker (``RealEngine._takes``
     routes by role); chunked prefill runs exactly as in the monolithic
     engine, radix prefix sharing included, and the final chunk's argmax
     becomes the first generated token (async, pipelined);
  2. once that first token LANDS, the disagg layer extracts the sequence
     as an explicit :class:`BlockHandoff` — block table + filled pages
     (the staged host image of ``PagedInstance.handoff_out``, an async
     D2H gather) + first token — freeing the prefill worker's row and
     blocks for the next admission;
  3. the handoff is placed on a DECODE worker of the same variant through
     the ordinary ``can_resume``/``resume`` path (handoff is a planned
     swap: same bit-exact page restore, no preemption counted), and its
     prompt is registered in the decode-side radix tree so concurrent
     handoffs sharing a prefix share blocks again;
  4. decode, preemption/swap and partial swap-in proceed on the decode
     worker exactly as in the monolithic paged engine — greedy outputs
     are handoff-invariant (token-identical, enforced by the multi-device
     parity suite and the ``disagg_serving`` bench).

Handoff wall time is charged at busy power under the ``"handoff"`` role
tag on both ends, so prefill + decode + handoff joules sum exactly to the
session total (``obs.validate.check_disagg_conservation``).
"""
from __future__ import annotations

import dataclasses
import time
from collections import deque
from typing import Deque, List, Optional

from repro.core import perf_model as PM
from repro.obs import PhaseProfiler
from repro.serving.api import InferenceResponse
from repro.serving.engine import PagedInstance, RealEngine, _PagedSeq, \
    _SwapState

__all__ = ["BlockHandoff", "DisaggEngine"]


@dataclasses.dataclass
class BlockHandoff:
    """One prefill→decode transfer: everything a decode worker needs to
    continue the sequence bit-exactly.

    The "filled pages" travel as the staged host image inside ``swap``
    (``_SwapState`` — the same async-D2H machinery preemption uses): the
    prefill worker's physical block ids are released at staging, and the
    decode worker's allocator assigns fresh ones at placement, re-acquiring
    radix-tree-resident prefix pages by reference instead of copying when
    its tree is warm.  ``table`` snapshots the prefill-side block table for
    observability (page count + ordering), not for reuse."""
    rid: int
    variant: str                 # ladder rung — placement must match it
    table: List[int]             # prefill-side block table at staging
    n_pages: int                 # filled pages in the image
    first_token: int             # the prefill's generated token (landed)
    n_prompt: int
    swap: _SwapState             # staged page image + request state
    t_staged: float

    @classmethod
    def stage(cls, inst: PagedInstance, seq: _PagedSeq) -> "BlockHandoff":
        table = list(seq.blocks)
        swap = inst.handoff_out(seq)
        return cls(rid=swap.rid, variant=inst.ev.variant.name, table=table,
                   n_pages=swap.nb, first_token=swap.next_token,
                   n_prompt=len(swap.prompt), swap=swap,
                   t_staged=time.perf_counter())


class DisaggEngine(RealEngine):
    """Role-split real engine: ``roles={"prefill": P, "decode": D}`` workers
    per ConfigGraph instance (a graph edge of weight ``w`` builds ``w``
    disagg cells).  Constructed directly or — transparently — by
    ``RealEngine(..., roles=...)``."""

    def __init__(self, family, n_slots: int = 4, max_len: int = 96, *,
                 roles=None, **kw):
        kw.setdefault("kv_layout", "paged")
        assert kw["kv_layout"] == "paged", \
            "disaggregation requires the paged KV layout (block handoff)"
        if roles is None:
            roles = {"prefill": 1, "decode": 1}
        if isinstance(roles, (tuple, list)):
            roles = {"prefill": int(roles[0]), "decode": int(roles[1])}
        assert set(roles) == {"prefill", "decode"} and \
            all(int(n) >= 1 for n in roles.values()), \
            f"roles must map prefill/decode to counts >= 1: {roles}"
        super().__init__(family, n_slots, max_len, **kw)
        self.roles = {r: int(n) for r, n in roles.items()}
        # per-role phase profilers: the same PHASES catalog, labeled by
        # role, so phase latency splits prefill-pool vs decode-pool
        self.profilers = {r: PhaseProfiler(role=r)
                          for r in ("prefill", "decode")}
        self._handoffq: Deque[BlockHandoff] = deque()

    # --- engine hooks --------------------------------------------------------
    def _profilers(self):
        return (self.profiler,) + tuple(self.profilers.values())

    def _takes(self, inst, resuming: bool) -> bool:
        if inst.role == "prefill":
            return not resuming
        if inst.role == "decode":
            return resuming
        return True

    def _extra_pending(self) -> bool:
        return bool(self._handoffq)

    def configure(self, graph) -> float:
        """Warm-pooled by (variant, chips) exactly like the base engine;
        each graph instance expands to ``roles["prefill"]`` prefill +
        ``roles["decode"]`` decode workers of that (variant, chips)."""
        assert self._session is None, "configure during an open serve session"
        t0 = time.perf_counter()
        for inst in self.instances:
            self._pool.setdefault((inst.ev.variant.name, inst.chips),
                                  []).append(inst)
        self.instances = []
        for (vname, chips), w in graph.edges:
            for _ in range(w):
                for role in ("prefill", "decode"):
                    for _i in range(self.roles[role]):
                        warm = self._pool.get((vname, chips), [])
                        if warm:
                            inst = warm.pop()
                            inst.reset()
                        else:
                            inst = self._new_instance(self.family[vname],
                                                      chips, role=role)
                            inst.warmup()
                        inst.role = role     # pooled workers switch roles
                        inst.profiler = self.profilers[role]
                        self.instances.append(inst)
        self.last_reconfig_s = time.perf_counter() - t0
        return self.last_reconfig_s

    def _post_tick(self, completed: List[InferenceResponse]) -> None:
        s = self._session
        if s is None:
            return
        # 1. EXTRACT: fully-prefilled sequences whose first token landed
        # (one tick after the final chunk — the async readback overlapped
        # host work, so extraction never forces a blocking sync)
        for inst in self.instances:
            if inst.role != "prefill":
                continue
            for seq in [q for q in inst.rows if q is not None]:
                if (seq.prefilled and seq.remaining > 0
                        and seq.pending_first is None):
                    t0 = time.perf_counter()
                    h = BlockHandoff.stage(inst, seq)
                    dt = time.perf_counter() - t0
                    e = inst.chips * PM.P_BUSY_W * dt
                    s.charge("handoff", e)
                    s.meter(h.rid, "handoff", e)
                    s.accounted_s[id(inst)] += dt
                    s.handoffs += 1
                    s.handoff_pages += h.n_pages
                    s.progressed = True
                    self._handoffq.append(h)
                    if s.tracer is not None:
                        s.tracer.instant("handoff_out",
                                         s.rel(time.perf_counter()),
                                         rid=h.rid, pages=h.n_pages)
        # 2. PLACE: FIFO over in-transit handoffs onto decode workers of
        # the matching variant; ones that do not fit yet wait for decode
        # completions to free rows/blocks
        if not self._handoffq:
            return
        waiting: Deque[BlockHandoff] = deque()
        while self._handoffq:
            h = self._handoffq.popleft()
            if self._place(h) is None:
                waiting.append(h)
        self._handoffq = waiting

    def _place(self, h: BlockHandoff) -> Optional[PagedInstance]:
        s = self._session
        targets = [i for i in self.instances
                   if i.role == "decode" and i.ev.variant.name == h.variant]
        if not targets:
            raise RuntimeError(
                f"no decode worker serves variant {h.variant!r} "
                f"(handoff rid {h.rid})")
        for inst in targets:
            if not inst.can_resume(h.swap):
                continue
            t0 = time.perf_counter()
            seq, _ = inst.resume(h.swap)
            # register the prompt in the decode-side radix tree: later
            # handoffs sharing the prefix re-acquire these pages by
            # reference (match_full at resume) instead of copying
            if inst.prefix is not None:
                inst.prefix.insert(h.swap.prompt, seq.blocks)
            dt = time.perf_counter() - t0
            e = inst.chips * PM.P_BUSY_W * dt
            s.charge("handoff", e)
            s.meter(h.rid, "handoff", e)
            s.accounted_s[id(inst)] += dt
            s.progressed = True
            if s.tracer is not None:
                s.tracer.instant("handoff_in", s.rel(time.perf_counter()),
                                 rid=h.rid, pages=h.n_pages)
            return inst
        if all(not i.busy for i in targets):
            raise RuntimeError(
                f"handoff rid {h.rid} needs {h.n_pages} pages but fits no "
                f"idle decode worker — decode arena too small for the "
                f"handed-off sequence")
        return None
