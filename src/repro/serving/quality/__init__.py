"""Mixed-quality request path: per-request variant selection.

Clover's central mechanism — trading a little model quality for a lot of
operational carbon under an accuracy constraint — existed only at the
*pool* level (``core/schemes.Clover`` re-mixes instance counts).  This
subsystem moves the knob onto the request path: a
:class:`~repro.serving.quality.selectors.QualitySelector` sits between the
scheduling policy (which decides *when* a request runs) and the engine
instances (which decide *where*) and picks *at what quality* — a ladder
rung from ``build_engine_family`` / ``core.catalog`` — for every request
at admission time.  All three serving backends (``RealEngine`` slotted and
paged, ``DESBackend``, ``FluidBackend``) honor the same selector contract,
so one decision sequence replays identically across execution substrates.

Selectors (``make_selector``): ``static`` per-SLO-class pinning, ``greedy``
dirty-grid downshifting over a ``ci_fn``, and ``governed`` — the greedy
downshifter behind a windowed per-class accuracy-floor governor that
refuses downshifts which would breach the configured floor.  This package
is deliberately jax-free (stdlib only): the DES/fluid paths and
``scripts/check.sh``'s ``repro.obs.validate`` run it with no device stack.
"""
from __future__ import annotations

from repro.serving.quality.selectors import AccuracyFloorGovernor, \
    GreedyDownshiftSelector, QualityDecision, QualitySelector, \
    StaticPinSelector, make_selector

__all__ = ["AccuracyFloorGovernor", "GreedyDownshiftSelector",
           "QualityDecision", "QualitySelector", "StaticPinSelector",
           "make_selector"]
