"""Per-request quality selectors (the mixed-quality request path).

A selector owns a LADDER — the variants actually instantiable on the
backend it serves (the engine's ``build_engine_family`` rungs, a catalog
family's rungs on the DES/fluid) — and maps each request to one rung at
admission time.  The contract that makes one decision sequence replay
bit-identically across the real engine, the DES and the fluid model:

  * decisions are a pure function of (request metadata, the decision
    clock, the selector's own prior decisions).  The decision clock is the
    request's ``arrival_s`` (0 when unset) — a backend-independent number,
    NOT the backend's wall/simulated clock, so the same workload produces
    the same sequence everywhere;
  * grid pressure is read through the policies' ``ci_fn(now)`` contract
    (``fleet.forecast.ForecastCIFn`` or any callable) sampled at the
    decision clock;
  * served-accuracy feedback (the governor's floor window) accumulates at
    decision time with the DECIDED variant's accuracy — routing enforces
    the decision, so this is the served mix, known before service.

Every selector honors the per-request API knobs: ``quality_hint`` pins a
named rung when the ladder has it, and ``min_accuracy`` is a hard floor no
choice may cross.  Decisions append to ``selector.decisions``;
``decision_sequence()`` is the comparable (rid, variant, reason) trace the
conformance tests assert across backends.

The accuracy-floor governor reuses the sliding-window shape of the SLO
burn-rate evaluator (``obs/slo.py``): one pruned ``(t, accuracy)`` deque
per SLO class, so memory is bounded by the window length.
"""
from __future__ import annotations

import dataclasses
from collections import deque
from typing import Callable, Deque, Dict, List, Optional, Sequence, Tuple, \
    Union

__all__ = ["QualityDecision", "QualitySelector", "StaticPinSelector",
           "GreedyDownshiftSelector", "AccuracyFloorGovernor",
           "make_selector", "SELECTORS"]


@dataclasses.dataclass(frozen=True)
class QualityDecision:
    """One request's quality routing: the rung it will be served on and
    why.  ``reason`` vocabulary: ``pinned`` (static per-class pin),
    ``default`` (clean grid / no rule engaged), ``downshift`` (dirty-grid
    deferrable drop), ``pressure`` (sustained-dirty interactive drop),
    ``floor`` (governor refused a deeper downshift), ``hint``
    (``quality_hint`` pin), ``min_accuracy`` (per-request floor clamp)."""
    rid: int
    variant: str
    accuracy: float
    reason: str
    slo: str
    t: float                           # the decision clock (arrival_s)


class QualitySelector:
    """Base selector: ladder bookkeeping, per-request clamps, the decision
    log.  Subclasses implement ``_choose(req, t) -> (variant, reason)``."""

    name = "abstract"

    def __init__(self) -> None:
        self._ladder: List = []                 # worst → best
        self._by_name: Dict[str, object] = {}
        self.decisions: List[QualityDecision] = []

    # --- lifecycle -----------------------------------------------------------
    def reset(self, variants: Sequence) -> None:
        """(Re)bind the selector to the variants a backend can actually
        instantiate, worst rung first.  Backends call this when a serve
        session opens — decisions are always clamped to this set, so a
        routed request can never name a variant with no instance."""
        assert variants, "empty quality ladder"
        self._ladder = sorted(variants,
                              key=lambda v: (v.quality, v.accuracy, v.name))
        self._by_name = {v.name: v for v in self._ladder}
        self.decisions = []

    @property
    def best(self):
        return self._ladder[-1]

    @property
    def worst(self):
        return self._ladder[0]

    # --- the per-request decision --------------------------------------------
    def select(self, req, now: Optional[float] = None) -> QualityDecision:
        """Decide the request's rung.  ``now`` overrides the decision clock
        (backends leave it None → ``req.arrival_s``)."""
        assert self._ladder, "reset(variants) before select()"
        t = float(req.arrival_s or 0.0) if now is None else float(now)
        v, reason = self._choose(req, t)
        hint = getattr(req, "quality_hint", None)
        if hint is not None and hint in self._by_name:
            v, reason = self._by_name[hint], "hint"
        floor = getattr(req, "min_accuracy", None)
        if floor is not None and v.accuracy < floor:
            v, reason = self._lowest_at_least(floor), "min_accuracy"
        dec = QualityDecision(req.rid, v.name, v.accuracy, reason, req.slo, t)
        self.decisions.append(dec)
        self._note(dec)
        return dec

    def decision_sequence(self) -> List[Tuple[int, str, str]]:
        """The cross-backend comparable trace."""
        return [(d.rid, d.variant, d.reason) for d in self.decisions]

    # --- subclass hooks ------------------------------------------------------
    def _choose(self, req, t: float):
        raise NotImplementedError

    def _note(self, dec: QualityDecision) -> None:
        """Post-decision feedback (the governor's window); default no-op."""

    # --- helpers -------------------------------------------------------------
    def _lowest_at_least(self, acc_floor: float):
        """Cheapest rung whose accuracy clears ``acc_floor`` (best rung if
        none does — the least-bad violation)."""
        for v in self._ladder:
            if v.accuracy >= acc_floor:
                return v
        return self.best


class StaticPinSelector(QualitySelector):
    """Per-SLO-class pinning: ``pins = {"deferrable": "B1"}`` serves every
    deferrable request on rung B1; unpinned classes ride the best rung.
    The degenerate selector — no grid input — and the operating point that
    separates *having* a request-path knob from *using* it."""

    name = "static"

    def __init__(self, pins: Optional[Dict[str, str]] = None):
        super().__init__()
        self.pins = dict(pins or {})

    def _choose(self, req, t: float):
        pin = self.pins.get(req.slo)
        if pin is not None and pin in self._by_name:
            return self._by_name[pin], "pinned"
        return self.best, "default"


class GreedyDownshiftSelector(QualitySelector):
    """Dirty-grid downshifter over the policies' ``ci_fn`` contract.

    Deferrable requests drop to the WORST rung whenever the nowcast CI
    exceeds ``dirty_threshold_g`` — deferred batch work is exactly the
    traffic whose quality the operator said they'd trade.  Interactive
    requests only move under *sustained* pressure: once the grid has been
    continuously dirty for ``sustain_s`` of decision time they drop ONE
    rung below best (never to the bottom — tail-latency traffic keeps most
    of its accuracy).  A clean nowcast restores everyone to best."""

    name = "greedy"

    def __init__(self, ci_fn: Optional[Callable[[float], float]] = None,
                 dirty_threshold_g: float = 300.0,
                 sustain_s: float = 1800.0):
        super().__init__()
        self.ci_fn = ci_fn
        self.dirty_threshold_g = dirty_threshold_g
        self.sustain_s = sustain_s
        self._dirty_since: Optional[float] = None

    def reset(self, variants: Sequence) -> None:
        super().reset(variants)
        self._dirty_since = None

    def _dirty(self, t: float) -> bool:
        ci = float(self.ci_fn(t)) if self.ci_fn is not None else 0.0
        if ci > self.dirty_threshold_g:
            if self._dirty_since is None:
                self._dirty_since = t
            return True
        self._dirty_since = None
        return False

    def _choose(self, req, t: float):
        if not self._dirty(t):
            return self.best, "default"
        if req.slo == "deferrable":
            return self.worst, "downshift"
        if t - self._dirty_since >= self.sustain_s and len(self._ladder) > 1:
            return self._ladder[-2], "pressure"
        return self.best, "default"


class AccuracyFloorGovernor(QualitySelector):
    """Accuracy-floor governor over a base selector (greedy by default).

    Tracks a windowed request-weighted mean accuracy per SLO class — one
    pruned ``(t, accuracy)`` deque per class, the ``obs/slo.py`` burn-rate
    window shape — and REFUSES any downshift that would drag the window
    mean below the class's configured floor: the candidate is promoted to
    the cheapest rung that keeps ``(window_sum + acc) / (n + 1) ≥ floor``
    (reason ``floor``).  The greedy selector's carbon savings thus come
    with Clover's accuracy constraint enforced per class, online."""

    name = "governed"

    def __init__(self, base: Optional[QualitySelector] = None,
                 floors: Optional[Dict[str, float]] = None,
                 default_floor: float = 0.0, window_s: float = 4 * 3600.0,
                 ci_fn: Optional[Callable[[float], float]] = None,
                 dirty_threshold_g: float = 300.0,
                 sustain_s: float = 1800.0):
        super().__init__()
        self.base = base if base is not None else GreedyDownshiftSelector(
            ci_fn=ci_fn, dirty_threshold_g=dirty_threshold_g,
            sustain_s=sustain_s)
        self.floors = dict(floors or {})
        self.default_floor = default_floor
        self.window_s = window_s
        self._win: Dict[str, Deque[Tuple[float, float]]] = {}

    def reset(self, variants: Sequence) -> None:
        super().reset(variants)
        self.base.reset(variants)
        self._win = {}

    def floor_for(self, slo: str) -> float:
        return self.floors.get(slo, self.default_floor)

    def window_mean(self, slo: str) -> float:
        win = self._win.get(slo)
        if not win:
            return self.best.accuracy
        return sum(a for _, a in win) / len(win)

    def _prune(self, slo: str, t: float) -> None:
        win = self._win.setdefault(slo, deque())
        while win and win[0][0] <= t - self.window_s:
            win.popleft()

    def _choose(self, req, t: float):
        v, reason = self.base._choose(req, t)
        floor = self.floor_for(req.slo)
        if floor <= 0.0:
            return v, reason
        self._prune(req.slo, t)
        win = self._win[req.slo]
        acc_sum, n = sum(a for _, a in win), len(win)
        if (acc_sum + v.accuracy) / (n + 1) >= floor:
            return v, reason
        # refuse the downshift: cheapest rung that keeps the window mean
        # at or above the floor (the best rung is the last resort)
        for cand in self._ladder:
            if cand.accuracy > v.accuracy \
                    and (acc_sum + cand.accuracy) / (n + 1) >= floor:
                return cand, "floor"
        return self.best, "floor"

    def _note(self, dec: QualityDecision) -> None:
        self._win.setdefault(dec.slo, deque()).append((dec.t, dec.accuracy))


SELECTORS: Dict[str, type] = {
    StaticPinSelector.name: StaticPinSelector,
    GreedyDownshiftSelector.name: GreedyDownshiftSelector,
    AccuracyFloorGovernor.name: AccuracyFloorGovernor,
}


def make_selector(spec: Union[str, QualitySelector, None], **kw
                  ) -> Optional[QualitySelector]:
    """Resolve a selector spec the way ``make_policy`` resolves policies:
    None / "off" / "none" → no selector, a name → a fresh instance (extra
    kwargs forwarded; ``ci_fn`` is dropped for selectors that take none),
    an instance → itself."""
    if spec is None or isinstance(spec, QualitySelector):
        return spec
    name = spec.lower()
    if name in ("off", "none", ""):
        return None
    if name not in SELECTORS:
        raise ValueError(f"unknown quality selector {spec!r} "
                         f"(have {sorted(SELECTORS)})")
    cls = SELECTORS[name]
    if cls is StaticPinSelector:
        kw = {k: v for k, v in kw.items()
              if k not in ("ci_fn", "dirty_threshold_g", "sustain_s",
                           "floors", "default_floor", "window_s")}
    elif cls is GreedyDownshiftSelector:
        kw = {k: v for k, v in kw.items()
              if k not in ("floors", "default_floor", "window_s", "pins")}
    return cls(**kw)
