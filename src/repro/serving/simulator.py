"""48-hour serving simulation over a carbon trace (paper §5 evaluation rig).

Fluid-window discrete-event simulation: arrivals are Poisson with rate set so
BASE runs at ~70 % utilization ("neither starvation nor idle GPUs", §5.1);
each window serves min(backlog + arrivals, capacity·Δ) under the active
configuration's analytic service model.  All Clover/Blover *online evaluation
windows are served under the candidate being evaluated* and reconfiguration
downtime is charged — the paper includes both overheads in every result.

Per-request exact DES lives in serving/queue.py (used by tests and the
real-execution engine); at 48 h × production rates the fluid model is the
tractable equivalent and matches the DES on short horizons (tested).
"""
from __future__ import annotations

import dataclasses
import random
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core import annealing as SA
from repro.core import carbon as CB
from repro.core import config_graph as CG
from repro.core import controller as CTRL
from repro.core import objective as OBJ
from repro.core import perf_model as PM
from repro.core import schemes as SCH
from repro.core.catalog import Variant, get_family


@dataclasses.dataclass
class SimConfig:
    n_blocks: int = 4                  # 4 blocks × 16 chips (≈ paper's 10 GPUs)
    window_s: float = 60.0
    target_rho: float = 0.7            # BASE operating point
    lam: float = 0.1                   # objective λ (paper default)
    ci_threshold: float = 0.05
    seed: int = 0
    reconfig_cost: bool = True
    accuracy_threshold_pct: Optional[float] = None
    sla_target_s: Optional[float] = None   # override the derived p95 target
                                           # (fleet baselines pin it so fleet
                                           # and single runs share one SLA)
    sa: SA.SAConfig = dataclasses.field(default_factory=SA.SAConfig)


@dataclasses.dataclass
class SimReport:
    scheme: str
    family: str
    carbon_g: float
    served: float
    dropped_backlog: float
    accuracy: float                    # request-weighted mean accuracy
    p95_latency_s: float
    sla_target_s: float
    sla_violation_frac: float          # fraction of served windows over target
    opt_time_s: float
    opt_time_frac: float
    n_evals: int
    evals_sla_ok: int
    n_invocations: int
    timeline: Dict[str, np.ndarray]    # t, ci, f, accuracy, power, carbon_rate

    def carbon_per_req_g(self) -> float:
        return self.carbon_g / max(self.served, 1.0)


def weighted_p95(lat_samples: Sequence[Tuple[float, float]]) -> float:
    """Request-weighted 95th percentile over (latency, weight) samples —
    shared by per-cluster servers and fleet-wide merges."""
    if not lat_samples:
        return 0.0
    samples = sorted(lat_samples)
    total = sum(w for _, w in samples)
    cum = 0.0
    for lat, w in samples:
        cum += w
        if cum >= 0.95 * total:
            return lat
    return samples[-1][0]


@dataclasses.dataclass
class SegmentResult:
    """One fluid window's outcome (returned so callers can build timelines)."""
    res: OBJ.EvalResult
    ci: float
    served: float                  # interactive requests served this window
    defer_served: float            # deferrable requests served this window
    carbon_g: float
    p95_s: float


class FluidServer:
    """The fluid-window service model, factored out of ``run_trace`` so the
    multi-region fleet simulator (repro.fleet.fleet_sim) reuses it instead of
    duplicating the backlog/SLA/carbon bookkeeping.

    Two work classes: *interactive* requests count toward the p95/SLA
    statistics and are served first; *deferrable* work (``defer_rps``) only
    consumes capacity left over in the window and never enters the latency
    books — it has a deadline, not an SLA.  With ``defer_rps=0`` the model is
    exactly the original single-class ``serve_segment``.
    """

    def __init__(self, variants: Sequence[Variant], acct: CB.CarbonAccountant,
                 sla_target_s: float, sla_slack: float = 1.001):
        self.variants = variants
        self.acct = acct
        self.sla_target_s = sla_target_s
        self.sla_slack = sla_slack
        self.backlog = 0.0
        self.defer_backlog = 0.0
        self.served_total = 0.0
        self.defer_served_total = 0.0
        self.acc_weighted = 0.0
        self.lat_samples: List[Tuple[float, float]] = []   # (p95, weight)
        self.sla_over = 0
        self.sla_windows = 0

    def serve_segment(self, g: CG.ConfigGraph, start: float, dur: float,
                      arrival_rps: float, defer_rps: float = 0.0,
                      extra_latency_s: float = 0.0) -> SegmentResult:
        res = OBJ.evaluate(g, self.variants, arrival_rps + defer_rps)
        ci = self.acct.trace.at(start + dur / 2.0)
        cap = res.capacity_rps * dur
        work = self.backlog + arrival_rps * dur
        served = min(work, cap)
        self.backlog = work - served
        defer_work = self.defer_backlog + defer_rps * dur
        defer_served = min(defer_work, cap - served)
        self.defer_backlog = defer_work - defer_served
        wait = self.backlog / max(res.capacity_rps, 1e-9)
        p95 = res.p95_latency_s + wait + extra_latency_s
        carbon_g = self.acct.add(start, dur, res.power_w)
        self.served_total += served
        self.defer_served_total += defer_served
        self.acc_weighted += res.accuracy * (served + defer_served)
        if served > 0:
            self.lat_samples.append((p95, served))
            self.sla_windows += 1
            if p95 > self.sla_target_s * self.sla_slack:
                self.sla_over += 1
        return SegmentResult(res, ci, served, defer_served, carbon_g, p95)

    def weighted_p95(self) -> float:
        return weighted_p95(self.lat_samples)

    @property
    def mean_accuracy(self) -> float:
        return self.acc_weighted / max(self.served_total
                                       + self.defer_served_total, 1e-9)

    @property
    def sla_violation_frac(self) -> float:
        return self.sla_over / max(self.sla_windows, 1)


def make_context(family: str, sim: SimConfig,
                 variants: Optional[Sequence[Variant]] = None
                 ) -> Tuple[SCH.SchemeContext, float]:
    """Builds the scheme context; returns (ctx, arrival_rps).

    ``variants`` overrides the catalog lookup — the real-execution fleet
    backend optimizes over its engine ladder's variants instead of a
    catalog family."""
    variants = list(variants) if variants is not None else get_family(family)
    rng = random.Random(sim.seed)
    # BASE capacity determines the arrival rate and the SLA
    tmp_ctx = SCH.SchemeContext(family, variants, sim.n_blocks, 1.0,
                                None, sim.sa, rng)
    base_g = SCH.base_config(tmp_ctx)
    base_eval_unloaded = OBJ.evaluate(base_g, variants, 1e-9)
    arrival = base_eval_unloaded.capacity_rps * sim.target_rho
    base_eval = OBJ.evaluate(base_g, variants, arrival)
    obj = OBJ.ObjectiveConfig(
        lam=sim.lam,
        a_base=base_eval.accuracy,
        c_base=base_eval.carbon_per_req_g(380.0),   # baseline avg US intensity
        l_tail_s=(sim.sla_target_s if sim.sla_target_s is not None
                  else base_eval.p95_latency_s),
        max_accuracy_loss_pct=sim.accuracy_threshold_pct,
    )
    ctx = SCH.SchemeContext(family, variants, sim.n_blocks, arrival, obj,
                            sim.sa, rng)
    return ctx, arrival


def run_trace(scheme_name: str, family: str, trace: CB.CarbonTrace,
              sim: SimConfig = SimConfig()) -> SimReport:
    ctx, arrival = make_context(family, sim)
    scheme = SCH.make_scheme(scheme_name)
    controller = CTRL.Controller(scheme, ctx, ci_threshold=sim.ci_threshold)
    acct = CB.CarbonAccountant(trace)
    variants = ctx.variants
    server = FluidServer(variants, acct, ctx.obj_cfg.l_tail_s)

    t = 0.0
    ci0 = trace.at(0.0)
    config = controller.start(t, ci0)
    # charge the initial optimization run's evaluation windows
    opt_time = 0.0
    n_evals = evals_ok = 0
    tl_t, tl_ci, tl_f, tl_acc, tl_pow, tl_cg = [], [], [], [], [], []

    def serve_segment(g: CG.ConfigGraph, start: float, dur: float):
        seg = server.serve_segment(g, start, dur, arrival)
        tl_t.append(start)
        tl_ci.append(seg.ci)
        tl_f.append(OBJ.objective_f(seg.res, seg.ci, ctx.obj_cfg))
        tl_acc.append(seg.res.accuracy)
        tl_pow.append(seg.res.power_w)
        tl_cg.append(seg.carbon_g / max(dur, 1e-9))
        return seg.res

    def charge_invocation(outcome: Optional[SA.SAOutcome], start: float) -> float:
        """Serve each SA evaluation window under its candidate config."""
        nonlocal opt_time, n_evals, evals_ok
        if outcome is None:
            return 0.0
        tt = start
        for ev in outcome.evaluations:
            serve_segment(ev.graph, tt, ctx.sa_cfg.eval_window_s)
            tt += ctx.sa_cfg.eval_window_s
        opt_time += outcome.duration_s
        n_evals += outcome.n_evals
        evals_ok += sum(e.sla_ok for e in outcome.evaluations)
        return outcome.duration_s

    if controller.invocations:
        t += charge_invocation(controller.invocations[-1].outcome, t)
        config = controller.config

    prev_config = config
    while t < trace.duration_s:
        ci = trace.at(t)
        if controller.should_reoptimize(ci, t):
            new_cfg, outcome = controller.maybe_reoptimize(t, ci)
            t += charge_invocation(outcome, t)
            if sim.reconfig_cost and new_cfg.edges != prev_config.edges:
                # dead time: instance reload (parallel across slices)
                by_name = {v.name: v for v in variants}
                dt = max((PM.reconfig_seconds(by_name[vn], c)
                          for (vn, c), _ in new_cfg.edges), default=0.0)
                idle_power = sum(PM.instance_power_w(c, 0.0) * w
                                 for (vn, c), w in new_cfg.edges)
                acct.add(t, dt, idle_power)
                server.backlog += arrival * dt
                t += dt
            prev_config = config = new_cfg
            continue
        dur = min(sim.window_s, trace.duration_s - t)
        serve_segment(config, t, dur)
        t += dur

    return SimReport(
        scheme=scheme_name, family=family,
        carbon_g=acct.carbon_g, served=server.served_total,
        dropped_backlog=server.backlog,
        accuracy=server.mean_accuracy, p95_latency_s=server.weighted_p95(),
        sla_target_s=ctx.obj_cfg.l_tail_s,
        sla_violation_frac=server.sla_violation_frac,
        opt_time_s=opt_time, opt_time_frac=opt_time / trace.duration_s,
        n_evals=n_evals, evals_sla_ok=evals_ok,
        n_invocations=len(controller.invocations),
        timeline={"t": np.array(tl_t), "ci": np.array(tl_ci),
                  "f": np.array(tl_f), "accuracy": np.array(tl_acc),
                  "power_w": np.array(tl_pow),
                  "carbon_gps": np.array(tl_cg)},
    )


def compare_schemes(family: str, trace: CB.CarbonTrace,
                    schemes: Sequence[str] = ("BASE", "CO2OPT", "BLOVER",
                                              "CLOVER", "ORACLE"),
                    sim: SimConfig = SimConfig()) -> Dict[str, SimReport]:
    return {s: run_trace(s, family, trace, sim) for s in schemes}


def savings_vs_base(reports: Dict[str, SimReport]) -> Dict[str, Dict[str, float]]:
    base = reports["BASE"]
    out = {}
    for name, r in reports.items():
        out[name] = {
            "carbon_saving_pct": (1.0 - r.carbon_per_req_g()
                                  / base.carbon_per_req_g()) * 100.0,
            "accuracy_delta_pct": (r.accuracy - base.accuracy)
                                  / base.accuracy * 100.0,
            "p95_vs_sla": r.p95_latency_s / r.sla_target_s,
            "opt_time_frac_pct": r.opt_time_frac * 100.0,
        }
    return out
