"""Host-side block allocator for the paged KV arena.

The device arena is one preallocated ``(L, n_blocks, block_size, K, dh)``
tensor pair per instance (``models.registry.make_block_arena``); this module
owns the *map* of it: which blocks are free, who references each block, and
which physical blocks make up each sequence's logical token range (the block
table).  All state is plain Python — the allocator never touches jax.

Conventions:

  * block 0 is reserved as the **junk sink**: padded block-table entries and
    inactive batch rows point at it, so masked-out device writes always have
    a legal target.  It is never allocated and never freed.
  * blocks are reference counted.  A sequence holds one reference on every
    block in its table; the radix prefix tree holds one reference on every
    block it caches.  ``free`` is decref: the block returns to the free list
    only when the last reference drops.
  * ``copy_on_write`` gives a sequence a private copy of a shared block
    (refcount > 1): a fresh block is allocated, the caller copies the device
    contents, and the shared block loses one reference.  With block-aligned
    prefix sharing the engine never actually triggers it in steady state —
    shared blocks are always full and writes only land past the valid end —
    but the allocator supports it so forked/beam decoding can build on it.
"""
from __future__ import annotations

from typing import Dict, List, Sequence

__all__ = ["OutOfBlocks", "BlockAllocator"]

JUNK_BLOCK = 0


class OutOfBlocks(RuntimeError):
    """Raised when an allocation cannot be satisfied from the free list."""


class BlockAllocator:
    """Free-list + refcount bookkeeping over ``n_blocks`` fixed-size blocks.

    Block ids are ints in ``[1, n_blocks)`` (block 0 is the junk sink).
    """

    def __init__(self, n_blocks: int, block_size: int):
        assert n_blocks >= 2, "need at least one allocatable block + junk"
        assert block_size >= 1
        self.n_blocks = n_blocks
        self.block_size = block_size
        # LIFO free list: recently freed blocks are re-used first (their
        # arena pages are warm in cache)
        self._free: List[int] = list(range(n_blocks - 1, 0, -1))
        self._ref: Dict[int, int] = {}
        # bumped on EVERY refcount mutation (alloc/free/incref/COW): an O(1)
        # change fingerprint for the engine's admission gate — a failed
        # block-aware admission is only retried once this moves, instead of
        # re-walking the prefix tree's evictable set every tick
        self.version = 0

    # --- queries -------------------------------------------------------------
    @property
    def num_free(self) -> int:
        return len(self._free)

    @property
    def num_allocatable(self) -> int:
        """Total blocks the allocator manages (excludes the junk sink)."""
        return self.n_blocks - 1

    def refcount(self, bid: int) -> int:
        return self._ref.get(bid, 0)

    def blocks_in_use(self) -> int:
        return self.num_allocatable - self.num_free

    def blocks_for_tokens(self, n_tokens: int) -> int:
        """Blocks needed to hold ``n_tokens`` KV entries."""
        return -(-max(n_tokens, 0) // self.block_size)

    # --- allocation ----------------------------------------------------------
    def alloc(self, n: int) -> List[int]:
        """Take ``n`` blocks off the free list with refcount 1 each."""
        if n > len(self._free):
            raise OutOfBlocks(f"need {n} blocks, {len(self._free)} free")
        out = [self._free.pop() for _ in range(n)]
        for bid in out:
            self._ref[bid] = 1
        self.version += 1
        return out

    def incref(self, bids: Sequence[int]) -> None:
        for bid in bids:
            if bid == JUNK_BLOCK:
                continue
            if self._ref.get(bid, 0) <= 0:
                raise ValueError(f"incref of unallocated block {bid}")
            self._ref[bid] += 1
        self.version += 1

    def free(self, bids: Sequence[int]) -> List[int]:
        """Drop one reference per block; returns the blocks actually
        reclaimed (refcount hit zero).  Freeing an unallocated block is a
        double-free and raises."""
        reclaimed: List[int] = []
        for bid in bids:
            if bid == JUNK_BLOCK:
                continue
            r = self._ref.get(bid, 0)
            if r <= 0:
                raise ValueError(f"double free of block {bid}")
            if r == 1:
                del self._ref[bid]
                self._free.append(bid)
                reclaimed.append(bid)
            else:
                self._ref[bid] = r - 1
        self.version += 1
        return reclaimed

    def copy_on_write(self, bid: int) -> int:
        """Private-copy protocol for writing into a possibly-shared block.

        refcount == 1: the caller already owns the block exclusively — the
        same id comes back and no device copy is needed.  refcount > 1: a
        fresh block is allocated (the caller must copy the arena contents
        ``bid`` → returned id) and ``bid`` loses the caller's reference."""
        if self._ref.get(bid, 0) <= 0:
            raise ValueError(f"copy_on_write of unallocated block {bid}")
        if self._ref[bid] == 1:
            return bid
        new = self.alloc(1)[0]
        self._ref[bid] -= 1
        self.version += 1
        return new

    # --- invariant check (tests / debugging) ---------------------------------
    def check(self) -> None:
        """Internal consistency: free list and refcounted set partition the
        allocatable id space, no block is both free and referenced."""
        free = set(self._free)
        held = set(self._ref)
        assert not (free & held), f"blocks both free and held: {free & held}"
        assert free | held == set(range(1, self.n_blocks)), \
            "leaked blocks: " + str(set(range(1, self.n_blocks)) - free - held)
        assert all(r > 0 for r in self._ref.values())
