"""Radix-tree prefix cache over the paged KV arena (block granularity).

Requests in a serving window overwhelmingly share prompt prefixes — the
system prompt, few-shot preambles, the conversation so far.  With a paged
arena those shared tokens only need to be prefilled ONCE: this tree maps
token-chunk paths (one edge = exactly ``block_size`` tokens) to the arena
block holding that chunk's K/V.  An admitted request walks its prompt down
the tree, takes a reference on every matched block, and only prefills the
unmatched tail.

Sharing is block-aligned on purpose: only FULL blocks are ever shared, so a
shared block is immutable by construction (writes only land past a
sequence's valid end, which lies beyond every full shared block) and the
engine's copy-on-write hook stays a no-op in steady state.

Eviction is LRU over *evictable* nodes — leaves whose block carries no
reference but the tree's own.  Interior nodes become evictable once their
children go; a node whose block a live sequence still references is pinned,
and so are its ancestors (dropping an ancestor would orphan a reachable
child).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence, Tuple

from repro.serving.kvpool.allocator import BlockAllocator

__all__ = ["RadixPrefixCache"]

Chunk = Tuple[int, ...]


@dataclasses.dataclass
class _Node:
    chunk: Chunk                      # the block_size tokens this edge spells
    block: int                        # arena block holding their K/V
    parent: Optional["_Node"]
    children: Dict[Chunk, "_Node"] = dataclasses.field(default_factory=dict)
    last_used: int = 0


class RadixPrefixCache:
    """Block-granular prompt-prefix dedup over a :class:`BlockAllocator`.

    The tree holds one allocator reference per cached node; callers that
    match get their own references (released through the allocator when the
    sequence finishes, as with any other block in its table)."""

    def __init__(self, allocator: BlockAllocator):
        self.alloc = allocator
        self.block_size = allocator.block_size
        self._root: Dict[Chunk, _Node] = {}
        self._clock = 0
        self.hits = 0                  # blocks served from cache
        self.misses = 0                # admissions with zero matched blocks
        self.evictions = 0             # nodes evicted (blocks returned)

    # --- internals -----------------------------------------------------------
    def _chunks(self, tokens: Sequence[int], n: int) -> List[Chunk]:
        bs = self.block_size
        return [tuple(int(t) for t in tokens[i * bs:(i + 1) * bs])
                for i in range(n)]

    def _nodes(self) -> List[_Node]:
        out, stack = [], list(self._root.values())
        while stack:
            n = stack.pop()
            out.append(n)
            stack.extend(n.children.values())
        return out

    def _walk(self, tokens: Sequence[int], n: int) -> List[_Node]:
        """Tree nodes along the longest cached path of the first ``n``
        block-chunks of ``tokens`` (pure read: no refs, no LRU bump)."""
        nodes: List[_Node] = []
        level = self._root
        for chunk in self._chunks(tokens, n):
            node = level.get(chunk)
            if node is None:
                break
            nodes.append(node)
            level = node.children
        return nodes

    # --- lookup --------------------------------------------------------------
    def match(self, tokens: Sequence[int]) -> Tuple[List[int], int]:
        """Longest cached block-aligned prefix of ``tokens``.

        Returns ``(blocks, n_cached_tokens)`` with one caller-owned reference
        taken on every returned block.  Matching is capped one token short of
        the prompt so at least one token always remains to prefill — the
        first generated token must come from real last-position logits."""
        usable = max((len(tokens) - 1) // self.block_size, 0)
        self._clock += 1
        nodes = self._walk(tokens, usable)
        blocks: List[int] = []
        for node in nodes:
            node.last_used = self._clock
            blocks.append(node.block)
        if blocks:
            self.alloc.incref(blocks)
            self.hits += len(blocks)
        else:
            self.misses += 1
        return blocks, len(blocks) * self.block_size

    # --- block liveness (partial swap-in) ------------------------------------
    def live_prefix_blocks(self, tokens: Sequence[int],
                           limit: Optional[int] = None) -> int:
        """How many leading FULL block-chunks of ``tokens`` are tree-resident
        right now.  Pure liveness query — no references taken, no LRU bump —
        used at swap-out to record which of a victim's pages the tree still
        backs (the candidates for a partial swap-in)."""
        n = len(tokens) // self.block_size
        if limit is not None:
            n = min(n, limit)
        return len(self._walk(tokens, n))

    def match_full(self, tokens: Sequence[int],
                   max_blocks: Optional[int] = None) -> List[int]:
        """Re-acquire the tree-resident prefix of an already-prefilled
        prompt, over ALL its full blocks (no one-token-short cap — the
        caller already owns real last-position logits from its original
        prefill).  One caller-owned reference is taken per returned block;
        LRU recency is bumped.  This is the swap-in path: every block
        returned is a page whose K/V the engine does NOT have to copy back
        from the host image."""
        n = len(tokens) // self.block_size
        if max_blocks is not None:
            n = min(n, max_blocks)
        self._clock += 1
        nodes = self._walk(tokens, n)
        blocks: List[int] = []
        for node in nodes:
            node.last_used = self._clock
            blocks.append(node.block)
        if blocks:
            self.alloc.incref(blocks)
            self.hits += len(blocks)
        return blocks

    # --- registration --------------------------------------------------------
    def insert(self, tokens: Sequence[int], block_table: Sequence[int]) -> int:
        """Register a prefilled prompt's full blocks for future sharing.

        ``block_table[i]`` must hold the K/V of tokens ``[i·bs, (i+1)·bs)``.
        Chunks already present keep their existing node (the caller's copy
        stays private — dedup only helps *future* admissions); new nodes take
        a tree-owned reference on the caller's block.  Returns the number of
        nodes added."""
        full = min(len(tokens) // self.block_size, len(block_table))
        added = 0
        self._clock += 1
        level, parent = self._root, None
        for i, chunk in enumerate(self._chunks(tokens, full)):
            node = level.get(chunk)
            if node is None:
                node = _Node(chunk, int(block_table[i]), parent,
                             last_used=self._clock)
                self.alloc.incref([node.block])
                level[chunk] = node
                added += 1
            else:
                node.last_used = self._clock
            parent, level = node, node.children
        return added

    # --- eviction ------------------------------------------------------------
    def _evictable(self) -> List[_Node]:
        """Leaves whose block only the tree references, LRU first."""
        out = [n for n in self._nodes()
               if not n.children and self.alloc.refcount(n.block) == 1]
        out.sort(key=lambda n: n.last_used)
        return out

    def evictable_blocks(self) -> int:
        """Blocks reclaimable by (repeated leaves-first) eviction right now:
        a node is reclaimable iff its own block carries no external
        reference AND its entire subtree is reclaimable (children must be
        evicted before their parent).  A pinned node blocks its ancestors
        but NOT its reclaimable siblings or their subtrees."""
        def walk(n: _Node) -> Tuple[int, bool]:
            cnt, all_ok = 0, True
            for c in n.children.values():
                c_cnt, c_ok = walk(c)
                cnt += c_cnt
                all_ok = all_ok and c_ok
            if all_ok and self.alloc.refcount(n.block) == 1:
                return cnt + 1, True
            return cnt, False
        return sum(walk(n)[0] for n in self._root.values())

    def evict(self, n_blocks: int) -> int:
        """LRU-evict unreferenced nodes until ``n_blocks`` arena blocks are
        reclaimed (or nothing evictable remains).  Returns blocks freed."""
        freed = 0
        while freed < n_blocks:
            leaves = self._evictable()
            if not leaves:
                break
            for node in leaves:
                if freed >= n_blocks:
                    break
                self._drop(node)
                freed += 1
        return freed

    def _drop(self, node: _Node) -> None:
        assert not node.children
        siblings = (node.parent.children if node.parent is not None
                    else self._root)
        del siblings[node.chunk]
        self.alloc.free([node.block])
        self.evictions += 1

    def clear(self) -> int:
        """Evict everything evictable (end-of-serve teardown)."""
        return self.evict(self.alloc.num_allocatable)

    def __len__(self) -> int:
        return len(self._nodes())
