"""Paged KV-cache memory subsystem for the real-execution engine.

The slotted cache (PR 2) reserves ``max_len`` tokens per sequence no matter
how short the prompt is; this package replaces that with vLLM-style paging:

  * ``allocator``  — fixed-size KV blocks carved from one preallocated arena,
    free-list allocation, refcounting, copy-on-write;
  * ``prefix``     — a radix tree over prompt tokens at block granularity,
    deduplicating shared prefixes across admitted requests with LRU eviction
    of unreferenced nodes.

The device-side arena itself lives in ``models.registry.make_block_arena``;
the Pallas gather kernel is ``kernels.paged_attention``; the serving loop
(`serving.engine.PagedInstance`) wires all of it together.
"""
from repro.serving.kvpool.allocator import BlockAllocator, OutOfBlocks
from repro.serving.kvpool.prefix import RadixPrefixCache

__all__ = ["BlockAllocator", "OutOfBlocks", "RadixPrefixCache"]
