"""Pluggable per-region serving backends for the fleet simulator.

The fleet loop (``repro.fleet.fleet_sim``) does its routing / shifting /
elastic-scaling arithmetic against the analytic fluid-window model — at 48 h
and production rates that is the only tractable choice.  What was missing is
an execution-grounded variant: this module lets a region serve through the
REAL continuous-batching engine (``serving.engine.RealEngine``) so a
short-horizon acceptance run validates the whole control loop — controller
re-optimization, warm reconfiguration, slot-level continuous batching,
measured latencies and energy — against actual JAX execution instead of the
fluid model alone.

Both region backends speak the unified request/response API
(``serving.api``):

  * ``RealWindowServer`` keeps the FluidServer bookkeeping (capacity,
    backlog, SLA windows) and, per serving window, applies the controller's
    active config through the warm ``configure`` path and runs a probe
    batch of typed ``InferenceRequest``s through the engine — the engine's
    ``ci_g_per_kwh`` is set to the window's carbon intensity first, so
    every probe response carries its attributed gCO2;
  * ``FluidBackend`` wraps the analytic ``FluidServer`` in the
    ``ServingBackend`` protocol (submit/step/drain/stats): requests
    aggregate into per-window arrival rates, responses come back with the
    window's p95 as their latency and an equal share of the window's
    energy/carbon — the cheapest member of the three-backend family
    (real slotted / real paged / DES / fluid) that one workload script can
    sweep.
"""
from __future__ import annotations

from typing import Dict, List, Optional, Sequence, Union

import numpy as np

from repro.core import carbon as CB
from repro.core import config_graph as CG
from repro.core.catalog import Variant
from repro.obs import MetricsRegistry, Telemetry
from repro.serving import simulator as SIM
from repro.serving.api import DEFERRABLE, DONE, INTERACTIVE, \
    InferenceRequest, InferenceResponse, serve_workload
from repro.serving.quality import make_selector
from repro.serving.scheduler import latency_percentile


def build_real_family(arch: str = "qwen3-1.7b", n_layers: int = 4,
                      fracs=(1.0, 0.5, 0.25), seed: int = 0):
    """Reduced-depth engine ladder for fleet acceptance runs (lazy jax
    import: the fluid fleet path must stay importable without touching jax)."""
    import jax.numpy as jnp

    from repro.configs import get_smoke_config
    from repro.serving import engine as ENG

    base = get_smoke_config(arch).with_(n_layers=n_layers, dtype=jnp.float32)
    return ENG.build_engine_family(base, fracs=fracs, seed=seed)


class RealWindowServer(SIM.FluidServer):
    """FluidServer bookkeeping + a real continuous-batching engine in the
    serving loop (see module docstring)."""

    def __init__(self, variants: Sequence[Variant], acct: CB.CarbonAccountant,
                 sla_target_s: float, *, engine, probe_requests: int = 4,
                 prompt_len: int = 6, n_new: int = 4, seed: int = 0,
                 sla_slack: float = 1.001, ci_fn=None,
                 deferrable_frac: float = 0.0, probe_deadline_s: float = 2.0):
        super().__init__(variants, acct, sla_target_s, sla_slack)
        self.engine = engine
        self.probe_requests = probe_requests
        self.prompt_len = prompt_len
        self.n_new = n_new
        # forecaster-driven policy support: ``ci_fn`` is the
        # fleet.forecast.ForecastCIFn the engine's carbon policy reads;
        # probe_window re-anchors its epoch to each window's trace time so
        # the policy's session-relative clock lands on the right grid
        self.ci_fn = ci_fn
        self.deferrable_frac = deferrable_frac
        self.probe_deadline_s = probe_deadline_s
        self._rng = np.random.default_rng(seed)
        self._vocab = next(iter(engine.family.values())).cfg.vocab_size
        self._configured_edges = None
        self._rid = 0
        # measured, real-execution stats
        self.real_latencies: List[float] = []
        self.real_served = 0
        self.real_tokens = 0
        self.real_energy_j = 0.0
        self.real_carbon_g = 0.0       # per-request attributed, window CI
        self.real_preemptions = 0
        self.real_occupancy: List[float] = []
        self.reconfig_s_total = 0.0
        self.n_reconfigs = 0
        # per-SLO-class served-accuracy accumulators (mixed-quality mix)
        self.real_acc_sum: Dict[str, float] = {}
        self.real_acc_n: Dict[str, int] = {}

    # --- controller hook -----------------------------------------------------
    def apply_config(self, g: CG.ConfigGraph) -> None:
        """Warm-reconfigure the engine to the controller's active graph.
        Suspended regions (0 chips) simply drop all instances."""
        if self._configured_edges == g.edges:
            return
        self.reconfig_s_total += self.engine.configure(g)
        self.n_reconfigs += 1
        self._configured_edges = g.edges

    # --- real probe ----------------------------------------------------------
    def probe_window(self, g: CG.ConfigGraph,
                     t: float = 0.0) -> Optional[Dict[str, float]]:
        """Serve a probe batch of typed requests under the active config and
        record measured latency/energy plus per-request carbon attributed at
        the window's CI.  Returns the engine stats (None for a suspended
        region)."""
        if g.total_chips == 0:
            return None
        self.apply_config(g)
        self.engine.ci_g_per_kwh = self.acct.trace.at(t)
        if self.ci_fn is not None:
            # the carbon policy's session clock starts at ~0 every probe:
            # anchor the forecaster onto this window's trace time
            self.ci_fn.set_epoch(t)
        n_defer = int(round(self.probe_requests * self.deferrable_frac))
        reqs = []
        for i in range(self.probe_requests):
            defer = i < n_defer
            reqs.append(InferenceRequest(
                rid=self._rid,
                prompt=self._rng.integers(0, self._vocab,
                                          size=(self.prompt_len,)
                                          ).astype(np.int32),
                max_new_tokens=self.n_new,
                slo=DEFERRABLE if defer else INTERACTIVE,
                deadline_s=self.probe_deadline_s if defer else None))
            self._rid += 1
        responses = serve_workload(self.engine, reqs)
        m = self.engine.stats()
        for r in responses:
            self.real_acc_sum[r.slo] = (self.real_acc_sum.get(r.slo, 0.0)
                                        + r.accuracy)
            self.real_acc_n[r.slo] = self.real_acc_n.get(r.slo, 0) + 1
        self.real_latencies.extend(self.engine.last_latencies)
        self.real_served += int(m["served"])
        self.real_tokens += int(m["tokens"])
        self.real_energy_j += m["energy_j"]
        self.real_carbon_g += sum(r.carbon_g for r in responses)
        self.real_preemptions += int(m.get("preemptions", 0))
        self.real_occupancy.append(m["mean_occupancy"])
        return m

    def real_p95(self) -> float:
        return (latency_percentile(self.real_latencies, 95.0)
                if self.real_latencies else 0.0)

    def accuracy_mix(self) -> Dict[str, float]:
        """Request-weighted mean served accuracy per SLO class, over every
        probe response this server has measured."""
        return {slo: self.real_acc_sum[slo] / self.real_acc_n[slo]
                for slo in sorted(self.real_acc_n) if self.real_acc_n[slo]}


class FluidBackend:
    """The analytic fluid-window model behind the ``ServingBackend``
    protocol.

    Requests aggregate into per-window arrival rates split by SLO class
    (interactive vs deferrable — deferrable work only consumes leftover
    window capacity, exactly the FluidServer contract); completions drain
    FIFO from each class's pending queue as the window's fluid service
    allows.  A response's latency is its completion window's p95 (+ the
    backlog wait already folded in by the model); its energy/carbon is an
    equal share of that window's power × duration at the window's CI.  No
    tokens are generated."""

    def __init__(self, g: CG.ConfigGraph, variants: Sequence[Variant],
                 sla_target_s: float, trace: Optional[CB.CarbonTrace] = None,
                 window_s: float = 60.0, ci_g_per_kwh: float = 0.0,
                 telemetry: Optional[Telemetry] = None,
                 quality_selector=None):
        self.g = g
        self.window_s = window_s
        if trace is None:
            trace = CB.CarbonTrace("flat", np.array([0.0, 365 * 24 * 3600.0]),
                                   np.array([ci_g_per_kwh, ci_g_per_kwh]))
        self.acct = CB.CarbonAccountant(trace)
        self.server = SIM.FluidServer(variants, self.acct, sla_target_s)
        # single-session backend: one registry for its whole life
        self.telemetry = telemetry
        self.registry = MetricsRegistry.standard("fluid")
        if telemetry is not None:
            telemetry.registry = self.registry
        self.tracer = telemetry.tracer if telemetry is not None else None
        # mixed-quality request path: the fluid model serves aggregate
        # rates, so the selector is a decision + attribution overlay — the
        # SAME decision sequence as the event-level backends, with each
        # response carrying its decided rung's name and accuracy
        self.quality_selector = make_selector(quality_selector)
        self._dec: Dict[int, tuple] = {}     # rid → (variant, accuracy)
        if self.quality_selector is not None:
            self.quality_selector.reset(list(variants))
        self.now = 0.0
        self._pending: Dict[str, List[InferenceRequest]] = {
            INTERACTIVE: [], DEFERRABLE: []}
        self._arrived: Dict[str, int] = {INTERACTIVE: 0, DEFERRABLE: 0}
        self._all: List[InferenceRequest] = []
        self._released: set = set()
        self._responses: List[InferenceResponse] = []
        self._stats: Dict[str, float] = {}

    # --- protocol ------------------------------------------------------------
    def submit(self, req: InferenceRequest) -> None:
        self._all.append(req)
        if self.quality_selector is not None:
            d = self.quality_selector.select(req)
            self._dec[req.rid] = (d.variant, d.accuracy)
        self.registry.counter("requests_submitted").inc()

    def step(self) -> List[InferenceResponse]:
        """Serve one fluid window: release arrivals due by its end, serve
        the two-class rates through ``FluidServer.serve_segment``, complete
        as much pending work as the window's fluid service covered."""
        t0, t1 = self.now, self.now + self.window_s
        for req in self._all:
            if (req.arrival_s or 0.0) < t1 and req.rid not in self._released:
                self._released.add(req.rid)
                self._pending[req.slo].append(req)
                self._arrived[req.slo] += 1
        rates = {slo: self._arrived[slo] / self.window_s
                 for slo in self._arrived}
        self._arrived = {INTERACTIVE: 0, DEFERRABLE: 0}
        seg = self.server.serve_segment(self.g, t0, self.window_s,
                                        rates[INTERACTIVE],
                                        rates[DEFERRABLE])
        self.now = t1
        out: List[InferenceResponse] = []
        n_done = (int(round(seg.served)) + int(round(seg.defer_served)))
        window_j = seg.res.power_w * self.window_s
        share_j = window_j / max(n_done, 1)
        ci = seg.ci
        for slo, served in ((INTERACTIVE, int(round(seg.served))),
                            (DEFERRABLE, int(round(seg.defer_served)))):
            q = self._pending[slo]
            for req in q[:served]:
                lat = seg.p95_s
                dec = self._dec.get(req.rid)
                resp = InferenceResponse(
                    rid=req.rid, tokens=None, slo=req.slo,
                    priority=req.priority, state=DONE,
                    t_arrival=req.arrival_s or 0.0, t_finish=t1,
                    queue_delay_s=max(lat, 0.0), ttft_s=lat, latency_s=lat,
                    energy_j=share_j, carbon_g=share_j / 3.6e6 * ci,
                    accuracy=dec[1] if dec is not None else seg.res.accuracy,
                    variant=dec[0] if dec is not None else None,
                    deadline_s=req.deadline_s)
                out.append(resp)
                reg = self.registry
                reg.counter("requests_served").inc()
                reg.histogram("latency_s").observe(resp.latency_s)
                reg.labeled("latency_s",
                            slo_class=req.slo).observe(resp.latency_s)
                reg.histogram("queue_delay_s").observe(resp.queue_delay_s)
                reg.histogram("ttft_s").observe(resp.ttft_s)
                reg.labeled("ttft_s", slo_class=req.slo).observe(resp.ttft_s)
                reg.histogram("accuracy").observe(resp.accuracy)
                reg.labeled("accuracy",
                            slo_class=req.slo).observe(resp.accuracy)
                if not resp.deadline_met:
                    reg.counter("deadline_misses").inc()
                if self.tracer is not None:
                    # fluid latencies are window aggregates, not a real
                    # timeline — span the completion window and carry the
                    # final attribution directly (no post-hoc annotate)
                    self.tracer.span("request", resp.t_arrival, t1,
                                     rid=resp.rid, slo=resp.slo, n_tokens=0,
                                     energy_j=resp.energy_j,
                                     carbon_g=resp.carbon_g)
            del q[:served]
        if self.tracer is not None and n_done:
            self.tracer.span("window", t0, t1, served=n_done,
                             power_w=seg.res.power_w, ci=ci)
        self._responses.extend(out)
        return out

    def drain(self) -> List[InferenceResponse]:
        limit = 10_000                     # windows; the fluid model always
        while limit and (any(self._pending.values())
                         or len(self._released) < len(self._all)):
            self.step()                    # converges — backlog is served
            limit -= 1                     # at capacity every window
        reg = self.registry
        total_j = sum(r.energy_j for r in self._responses)
        total_g = sum(r.carbon_g for r in self._responses)
        reg.counter("energy_j").inc(total_j)
        reg.counter("carbon_g").inc(total_g)
        reg.gauge("wall_s").set(self.now)
        if self.telemetry is not None and self.telemetry.feed is not None:
            self.telemetry.feed.record_segment(0.0, self.now, total_j,
                                               total_g)
        self._stats = {
            "served": int(reg.value("requests_served")),
            "p95_s": self.server.weighted_p95(),
            # with a selector the served mix defines accuracy (each response
            # carries its decided rung); without one, the pool mean
            "mean_accuracy": (reg.histogram("accuracy").mean
                              if self.quality_selector is not None
                              else self.server.mean_accuracy),
            # attributed totals: sums of the per-response shares, so the
            # joules-sum / carbon = J × CI contract holds for this backend
            # too.  The accountant's trace total (which also counts windows
            # that completed nothing) is reported separately.
            "energy_j": reg.value("energy_j"),
            "carbon_g": reg.value("carbon_g"),
            "trace_carbon_g": self.acct.carbon_g,
            "wall_s": self.now,
            "sla_violation_frac": self.server.sla_violation_frac,
            "preemptions": 0,
        }
        return list(self._responses)

    def stats(self) -> Dict[str, float]:
        return dict(self._stats)