"""Pluggable per-region serving backends for the fleet simulator.

The fleet loop (``repro.fleet.fleet_sim``) does its routing / shifting /
elastic-scaling arithmetic against the analytic fluid-window model — at 48 h
and production rates that is the only tractable choice.  What was missing is
an execution-grounded variant: this module lets a region serve through the
REAL continuous-batching engine (``serving.engine.RealEngine``) so a
short-horizon acceptance run validates the whole control loop — controller
re-optimization, warm reconfiguration, slot-level continuous batching,
measured latencies and energy — against actual JAX execution instead of the
fluid model alone.

``RealWindowServer`` keeps the FluidServer bookkeeping (capacity, backlog,
SLA windows) and adds, per serving window:

  * the controller's active config is applied to the region's engine via the
    warm ``configure`` path (attached to ``Controller.on_config_change``, so
    reconfigurations flow through ``Controller.maybe_reoptimize`` exactly as
    on a pod);
  * a probe batch of real requests runs through the slotted engine,
    recording measured wall latencies, tokens and occupancy-scaled energy.
"""
from __future__ import annotations

from typing import Dict, List, Optional, Sequence

import numpy as np

from repro.core import carbon as CB
from repro.core import config_graph as CG
from repro.core.catalog import Variant
from repro.serving import simulator as SIM
from repro.serving.scheduler import latency_percentile


def build_real_family(arch: str = "qwen3-1.7b", n_layers: int = 4,
                      fracs=(1.0, 0.5, 0.25), seed: int = 0):
    """Reduced-depth engine ladder for fleet acceptance runs (lazy jax
    import: the fluid fleet path must stay importable without touching jax)."""
    import jax.numpy as jnp

    from repro.configs import get_smoke_config
    from repro.serving import engine as ENG

    base = get_smoke_config(arch).with_(n_layers=n_layers, dtype=jnp.float32)
    return ENG.build_engine_family(base, fracs=fracs, seed=seed)


class RealWindowServer(SIM.FluidServer):
    """FluidServer bookkeeping + a real continuous-batching engine in the
    serving loop (see module docstring)."""

    def __init__(self, variants: Sequence[Variant], acct: CB.CarbonAccountant,
                 sla_target_s: float, *, engine, probe_requests: int = 4,
                 prompt_len: int = 6, n_new: int = 4, seed: int = 0,
                 sla_slack: float = 1.001):
        super().__init__(variants, acct, sla_target_s, sla_slack)
        self.engine = engine
        self.probe_requests = probe_requests
        self.prompt_len = prompt_len
        self.n_new = n_new
        self._rng = np.random.default_rng(seed)
        self._vocab = next(iter(engine.family.values())).cfg.vocab_size
        self._configured_edges = None
        # measured, real-execution stats
        self.real_latencies: List[float] = []
        self.real_served = 0
        self.real_tokens = 0
        self.real_energy_j = 0.0
        self.real_occupancy: List[float] = []
        self.reconfig_s_total = 0.0
        self.n_reconfigs = 0

    # --- controller hook -----------------------------------------------------
    def apply_config(self, g: CG.ConfigGraph) -> None:
        """Warm-reconfigure the engine to the controller's active graph.
        Suspended regions (0 chips) simply drop all instances."""
        if self._configured_edges == g.edges:
            return
        self.reconfig_s_total += self.engine.configure(g)
        self.n_reconfigs += 1
        self._configured_edges = g.edges

    # --- real probe ----------------------------------------------------------
    def probe_window(self, g: CG.ConfigGraph) -> Optional[Dict[str, float]]:
        """Serve a probe batch of real requests under the active config and
        record measured latency/energy.  Returns the engine metrics (None
        for a suspended region)."""
        if g.total_chips == 0:
            return None
        self.apply_config(g)
        prompts = [self._rng.integers(0, self._vocab,
                                      size=(1, self.prompt_len)
                                      ).astype(np.int32)
                   for _ in range(self.probe_requests)]
        m = self.engine.serve(prompts, n_new=self.n_new)
        self.real_latencies.extend(self.engine.last_latencies)
        self.real_served += int(m["served"])
        self.real_tokens += int(m["tokens"])
        self.real_energy_j += m["energy_j"]
        self.real_occupancy.append(m["mean_occupancy"])
        return m

    def real_p95(self) -> float:
        return (latency_percentile(self.real_latencies, 95.0)
                if self.real_latencies else 0.0)
