"""Real-execution serving engine: continuous batching over slotted or PAGED
KV caches, driven through the unified request/response API.

This is the end-to-end validation path for Clover on this CPU container: the
variants are reduced-config LMs (a real quality ladder — fewer layers →
measurably lower quality and lower latency/energy), instances map to "slices"
(on CPU every slice is the host device; the slice size feeds the energy
model), and the Clover controller drives reconfiguration exactly as it would
on a pod.  Examples/serve_clover.py runs the full loop.

Two KV layouts share the serving loop (``RealEngine(kv_layout=...)``):

  * ``"slotted"`` (PR 2) — every ``Instance`` owns a fixed-capacity batched
    cache (``models.registry.make_slot_cache``): ``n_slots`` sequences, each
    reserving ``max_len`` tokens regardless of its prompt, per-slot valid-
    prefix ``lengths`` masking (``kernels/decode_attention.py`` contract);
  * ``"paged"`` (PR 3) — every ``PagedInstance`` owns one block **arena**
    (``models.registry.make_block_arena``) mapped by the ``serving.kvpool``
    allocator: sequences hold exactly the fixed-size blocks their tokens
    need, **admission is by block availability** (not slot count), a radix
    **prefix cache** (``kvpool.prefix``) lets requests share common prompt-
    prefix blocks by refcount, prefill is **chunked** (long prompts advance
    one chunk per tick, interleaved with decode, so occupied sequences never
    stall behind a long admission), and attention gathers K/V through block
    tables (``kernels/paged_attention.py``; ``kernels/ref.py`` on CPU).

The serving surface is the ``ServingBackend`` protocol (``serving.api``):
``submit`` typed :class:`InferenceRequest`s, ``step`` one scheduler tick,
``drain`` to completion, ``stats`` for the session aggregates.  On top:

  * **pluggable admission** (``serving.policies``): FIFO (bit-identical to
    the PR 2/3 behavior), priority, EDF over deadlines, and the carbon-aware
    two-class policy, all layered on the shared ``SchedulerCore``.  A failed
    block-aware admission is **gated**: the engine only re-attempts once the
    instance's free capacity (slots / free+evictable blocks) or the queue
    head actually changed, instead of re-peeking every tick;
  * **per-request attribution**: every decode tick's occupancy-scaled energy
    is split over the rows that held the batch, prefill chunks are charged
    to the prefilling request, the session's idle floor is spread across its
    responses — so per-request joules sum to the engine total, and
    ``carbon_g = joules × ci_g_per_kwh`` is a per-request quantity the fleet
    layer can aggregate (EcoServe-style attribution);
  * **paged preemption** (``preemption=True``): admission reserves only the
    prompt's blocks and decode grows block tables on demand; when the arena
    runs dry mid-decode the engine victim-selects the lowest-priority /
    youngest sequence, swaps its K/V blocks to HOST memory, re-queues it,
    and restores it bit-exactly on re-admission — greedy outputs are
    preemption-invariant, replacing the conservative whole-sequence
    reservation;
  * **policy-aware prefill queue** (paged): inside a tick's chunked-prefill
    burst budget the active policy orders the instance's prefill queue, so
    an interactive admission's chunks preempt a long background prefill
    mid-prompt instead of queueing behind it in admission order;
  * **partial swap-in**: a preempted sequence whose prompt blocks the radix
    tree still holds is restored by re-referencing those pages and copying
    back only the evicted tail (``partial_swapin_pages_saved`` in stats) —
    still bit-exact, a tree eviction just degrades to the full restore;
  * **open-loop serving**: requests with ``arrival_s`` release on a wall-
    clock schedule (``serve_poisson`` draws one), so queueing delay and TTFT
    are measured at sub-saturation loads instead of only closed-batch
    makespan;
  * energy per decode tick scales with row occupancy
    (``PM.instance_power_w(chips, occupied / capacity)``); prefill work is
    charged at full busy power; unaccounted wall time draws idle power;
  * ``configure`` is **warm**: instances pool by (variant, chips) and jitted
    functions live on the ``EngineVariant``; ``warmup`` compiles exactly the
    shape set the serve loop can reach (``serve_buckets``) so a probe
    window's first token never pays a trace.
"""
from __future__ import annotations

import dataclasses
import heapq
import time
from collections import deque
from typing import Deque, Dict, List, Optional, Sequence, Tuple, Union

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import perf_model as PM
from repro.core.catalog import Variant
from repro.models import registry as R
from repro.models.config import ModelConfig
from repro.obs import MetricsRegistry, PhaseProfiler, Telemetry, TraceRecorder
from repro.serving.api import DONE, InferenceRequest, InferenceResponse, \
    serve_prompts
from repro.serving.kvpool import BlockAllocator, RadixPrefixCache
from repro.serving.policies import SchedulerPolicy, make_policy
from repro.serving.quality import make_selector
from repro.serving.scheduler import SchedulerCore, latency_percentile

__all__ = ["latency_percentile", "EngineVariant", "build_engine_family",
           "Instance", "PagedInstance", "RealEngine", "serve_buckets"]


@dataclasses.dataclass
class EngineVariant:
    variant: Variant
    cfg: ModelConfig
    params: dict
    # jitted entry points, shared by every Instance of this variant (warm
    # reconfiguration: re-instantiating an instance never re-traces)
    fns: dict = dataclasses.field(default_factory=dict, repr=False)


def build_engine_family(base_cfg: ModelConfig, fracs=(1.0, 0.5, 0.25),
                        seed: int = 0) -> List[EngineVariant]:
    """Instantiate a real quality ladder by depth reduction."""
    out = []
    for i, frac in enumerate(sorted(fracs)):
        n_layers = max(int(base_cfg.n_layers * frac), 1)
        cfg = base_cfg.with_(n_layers=n_layers,
                             name=f"{base_cfg.name}-x{frac:g}")
        params = R.init_params(jax.random.PRNGKey(seed), cfg)
        n_params = sum(int(np.prod(x.shape)) for x in jax.tree.leaves(params))
        v = Variant(family=base_cfg.name, name=f"x{frac:g}", quality=i + 1,
                    accuracy=0.80 + 0.05 * i, flops_g=n_params * 2 / 1e9,
                    params_m=n_params / 1e6, mem_gb=n_params * 4 / 2**30 + 0.1)
        out.append(EngineVariant(v, cfg, params))
    return out


def _write_slot(cache_k, cache_v, lengths, k_all, v_all, slot, true_len):
    """Write one prefill's K/V into a slot and set its length (jitted so the
    two cache updates fuse into one dispatch; slot/true_len stay traced)."""
    cache_k = jax.lax.dynamic_update_slice(cache_k, k_all, (0, slot, 0, 0, 0))
    cache_v = jax.lax.dynamic_update_slice(cache_v, v_all, (0, slot, 0, 0, 0))
    return cache_k, cache_v, lengths.at[slot].set(true_len)


def _variant_fns(ev: EngineVariant) -> dict:
    """Jitted prefill/decode for one variant, built once and cached on the
    EngineVariant (jax's jit cache then handles per-shape specialisation)."""
    if "prefill" not in ev.fns:
        cfg = ev.cfg
        ev.fns["prefill"] = jax.jit(
            lambda p, t: R.prefill_kv(p, {"tokens": t}, cfg))
        ev.fns["decode"] = jax.jit(
            lambda p, c, t, a: R.decode_slots(p, c, {"tokens": t}, cfg, a))
        ev.fns["write"] = jax.jit(_write_slot)
    return ev.fns


def _paged_fns(ev: EngineVariant) -> dict:
    """Jitted chunked-prefill / paged-decode entry points (same per-variant
    sharing discipline as ``_variant_fns``).  The arena is DONATED: every
    call scatters a handful of K/V rows into a buffer that is megabytes —
    without donation XLA copies the whole arena per step, and the copy
    dominates the decode tick on large pools.  Callers must treat the
    passed-in arena as consumed (the instance reassigns from the result).

    ``decode_multi`` is the device-resident hot path: ``k`` fused greedy
    steps (static — one compile per (bucket, k)) with on-device argmax
    feedback, returning the advanced ``next``/``lengths`` loop buffers so
    steady-state decode never uploads host state.  ``next`` and ``lengths``
    are donated alongside the arena (updated in place); ``tables`` and the
    active mask are reused read-only across ticks.  ``restore_paged`` /
    ``gather_pages`` are the swap staging pair: a donated in-place page
    scatter for swap-in (the un-jitted ``.at[].set`` copied the whole
    arena) and a page gather whose result is copied device→host
    asynchronously at swap-out."""
    if "prefill_paged" not in ev.fns:
        cfg = ev.cfg
        ev.fns["prefill_paged"] = jax.jit(
            lambda p, t, ar, tb, np_, tc: R.prefill_paged(
                p, {"tokens": t}, cfg, ar, tb, np_, tc),
            donate_argnums=(2,))
        ev.fns["decode_paged"] = jax.jit(
            lambda p, ar, t, tb, ln, act: R.decode_paged(
                p, ar, {"tokens": t}, cfg, tb, ln, act),
            donate_argnums=(1,))
        ev.fns["decode_multi"] = jax.jit(
            lambda p, ar, t, tb, ln, act, k: R.decode_paged_multi(
                p, ar, {"tokens": t}, cfg, tb, ln, act, k),
            static_argnames=("k",), donate_argnums=(1, 2, 4))
        ev.fns["restore_paged"] = jax.jit(
            lambda ar, idx, hk, hv: {"k": ar["k"].at[:, idx].set(hk),
                                     "v": ar["v"].at[:, idx].set(hv)},
            donate_argnums=(0,))
        ev.fns["gather_pages"] = jax.jit(
            lambda ar, idx: (ar["k"][:, idx], ar["v"][:, idx]))
    return ev.fns


def _sharded_params(ev: EngineVariant, mesh) -> dict:
    """Mesh-sharded copy of a variant's params, cached on the EngineVariant
    per mesh (same sharing discipline as the jitted fns: every instance of
    the variant on the same mesh reuses one device_put, so warm
    reconfiguration never re-places weights)."""
    key = ("sharded_params", id(mesh))
    if key not in ev.fns:
        ev.fns[key] = R.shard_params(ev.params, ev.cfg, mesh)
    return ev.fns[key]


def _bucket(n: int) -> int:
    """Prompt padding bucket (next power of two, floor 8) so prefill jit
    specialisations stay bounded as prompt lengths vary."""
    b = 8
    while b < n:
        b *= 2
    return b


def _pow2_bucket(n: int, cap: int) -> int:
    """Smallest power of two ≥ n, clamped to ``cap`` — ALWAYS a member of
    ``_bucket_ladder(cap)``, so a shape chosen at serve time is guaranteed
    to be one that warmup compiled."""
    b = 1
    while b < n:
        b *= 2
    return min(b, cap)


def _bucket_ladder(cap: int) -> List[int]:
    """All values ``_pow2_bucket`` can produce for a given cap: powers of
    two below it, plus the cap itself.  Warmup walks exactly this ladder."""
    out: List[int] = []
    b = 1
    while b < cap:
        out.append(b)
        b *= 2
    out.append(cap)
    return out


def serve_buckets(max_len: int) -> List[int]:
    """Every prompt bucket the serve loop can reach on a cache of
    ``max_len``: admitted prompts have ``true_len <= max_len - n_new <=
    max_len - 1``, so the reachable set is exactly
    ``{_bucket(n) for n in 1..max_len-1}``.

    ``Instance.warmup`` compiles precisely this set — a missed bucket means
    the first real request at that length pays a jit trace (polluting a
    probe window's measured first-token latency), an extra bucket is wasted
    cold-``configure`` compile time.  Keeping the walk next to ``_bucket``
    is what makes the two definitions impossible to drift apart."""
    out: List[int] = []
    b = 8
    while True:
        out.append(b)
        if b >= max_len - 1:
            break
        b *= 2
    return out


@dataclasses.dataclass
class _SlotState:
    """Host-side request state of one occupied slot."""
    rid: int
    t_arrival: float
    remaining: int                 # decode steps still to run
    tokens: List[int]              # generated token ids (prefill token first)
    t_first: Optional[float] = None   # wall time of the first generated token
    priority: int = 0
    preempts: int = 0              # slotted sequences never preempt (uniform
                                   # field so the engine reads one shape)


@dataclasses.dataclass
class _SwapState:
    """Host-side image of a preempted paged sequence: everything needed to
    restore it bit-exactly — request identity, generated tokens, the next
    decode token, and the K/V contents of the blocks it held (``n_ctx``
    valid positions).  Restoring writes the pages back into freshly
    allocated arena blocks, so greedy decode continues on identical state
    and outputs are preemption-invariant.

    ``tree_blocks`` records how many of the sequence's leading pages were
    radix-tree-resident at swap-out (full prompt blocks the prefix cache
    still holds).  On re-admission those pages are re-acquired from the
    tree instead of copied from ``host_k``/``host_v`` — a PARTIAL swap-in
    that restores only the evicted tail.  The host image still covers every
    page, so a tree eviction between swap-out and resume just degrades back
    to a full restore.

    The device→host copy is STAGED: ``img_k``/``img_v`` start as device
    arrays (a jitted page gather) with an async host copy already in
    flight, so swap-out never blocks the decode loop — the transfer
    overlaps subsequent decode ticks and only materialises as numpy when
    ``host_k``/``host_v`` are first read (normally at resume, after the
    copy has long landed)."""
    rid: int
    t_arrival: float
    prompt: np.ndarray
    n_new: int
    priority: int
    tokens: List[int]
    remaining: int
    n_ctx: int                     # K/V positions already in the arena
    next_token: int
    t_first: Optional[float]
    cached_tokens: int
    preempts: int
    img_k: object                  # (L, >=n_blocks, bs, K, dh) device or np
    img_v: object
    nb: int                        # pages actually used (img may be padded)
    tree_blocks: int = 0           # leading pages tree-backed at swap-out
    slo: str = "interactive"
    deadline_s: Optional[float] = None

    @property
    def n_blocks(self) -> int:
        return self.nb

    @property
    def host_k(self) -> np.ndarray:
        """(L, n_blocks, bs, K, dh) host image — materialises (and caches)
        the staged device copy on first read."""
        if not isinstance(self.img_k, np.ndarray):
            self.img_k = np.asarray(self.img_k)[:, :self.nb]
        return self.img_k

    @property
    def host_v(self) -> np.ndarray:
        if not isinstance(self.img_v, np.ndarray):
            self.img_v = np.asarray(self.img_v)[:, :self.nb]
        return self.img_v


@dataclasses.dataclass
class _PendingDecode:
    """One dispatched-but-not-landed decode call of the pipelined loop: the
    (k, B) greedy-token device array (async host copy already in flight),
    the dispatch-time (seq, row) snapshot that maps token columns back to
    sequences, and enough accounting to charge the work when it lands.
    Landing in a LATER tick than ``tick_id`` means the readback overlapped
    a full tick of host work (free); landing in the same tick is a forced
    flush and counts as a ``host_syncs`` blocking round-trip."""
    toks: object                          # (k, B) i32 device array
    rows: List[Tuple["_PagedSeq", int]]   # (seq, dispatch-time row)
    k: int
    occupied: int
    dispatch_s: float
    tick_id: int


@dataclasses.dataclass
class _PendingFirst:
    """A prefill's first generated token, still on device: the final
    chunk's last-position argmax with an async host copy in flight.  The
    device scalar is scattered into the uploaded ``next`` buffer whenever
    loop state is pushed (so decode never waits on its value); the host
    only reads it to record ``seq.tokens[0]`` — one tick later, or
    immediately (a counted sync) when the request is n_new == 1."""
    seq: "_PagedSeq"
    tok: object                           # () i32 device array
    tick_id: int


def _tick_info(prefill_s: float = 0.0, decode_s: float = 0.0,
               decode_steps: int = 0, occupied: int = 0,
               blocks_in_use: int = 0, prefill_rids=None, decode_rids=None,
               emitted=None, preempted=None) -> Dict[str, object]:
    return {"prefill_s": prefill_s, "decode_s": decode_s,
            "decode_steps": decode_steps, "occupied": occupied,
            "blocks_in_use": blocks_in_use,
            "prefill_rids": prefill_rids or [],   # [(rid, seconds), ...]
            "decode_rids": decode_rids or [],     # rows sharing the decode
            "emitted": emitted or [],             # [(rid, token), ...]
            "preempted": preempted or []}         # [_SwapState, ...]


def _note_shape(inst, key: Tuple) -> None:
    """Compile-retrace accounting: every jitted entry the serve loop hits
    registers its shape key here; a key not pre-seeded by ``warmup``
    (``inst._shapes``) is a post-warmup jit trace — the exact event the
    bucket ladders exist to prevent — and increments the instance's
    lifetime ``retraces`` counter (sessions report the delta)."""
    if key not in inst._shapes:
        inst._shapes.add(key)
        inst.retraces += 1


# disabled-by-default phase profiler: instances constructed outside a
# RealEngine observe into this shared no-op (registry=None) shim; the
# engine overrides ``inst.profiler`` with its own at configure()
_NULL_PROFILER = PhaseProfiler()


class Instance:
    """One serving instance: a slotted batched KV cache plus the variant's
    shared jitted one-pass prefill and batched decode step."""

    profiler: PhaseProfiler = _NULL_PROFILER
    role: str = "both"                   # slotted instances never split

    def __init__(self, ev: EngineVariant, chips: int, n_slots: int = 4,
                 max_len: int = 96):
        self.ev = ev
        self.chips = chips
        self.n_slots = n_slots
        self.max_len = max_len
        self._fns = _variant_fns(ev)
        self.cache = R.make_slot_cache(ev.cfg, n_slots, max_len,
                                       dtype=jnp.float32)
        self.slots: List[Optional[_SlotState]] = [None] * n_slots
        self._next = np.zeros((n_slots, 1), np.int32)   # next decode token
        self._shapes: set = set()        # jit shape keys seen (see _note_shape)
        self.retraces = 0                # lifetime post-warmup shape misses
        # host↔device traffic (lifetime; sessions report deltas): the
        # slotted loop is synchronous by design — 2 uploads + 1 blocking
        # readback per decode step — which is exactly the baseline the
        # paged pipelined loop is measured against
        self.host_syncs = 0
        self.h2d_transfers = 0
        self.decode_dispatches = 0

    # --- lifecycle -----------------------------------------------------------
    def reset(self) -> None:
        """Recycle from the warm pool: clear per-slot state.  Cache contents
        are stale but masked out (lengths = 0) until the next prefill."""
        self.cache["lengths"] = jnp.zeros((self.n_slots,), jnp.int32)
        self.slots = [None] * self.n_slots
        self._next[:] = 0

    def warmup(self) -> None:
        """Trigger jit compilation at EXACTLY the shapes the serve loop can
        reach — every prompt bucket from ``serve_buckets`` plus one decode
        step — so cold ``configure`` bears the whole compile cost and the
        first real request never re-jits (a probe window's measured
        first-token latency must not include a trace)."""
        for b in serve_buckets(self.max_len):
            self._shapes.add(("prefill", b))
            dummy = np.zeros((1, b), np.int32)
            lg, k_all, v_all = self._fns["prefill"](self.ev.params,
                                                    jnp.asarray(dummy))
            lg.block_until_ready()
            w = min(b, self.max_len)
            # zero-write into slot 0 at length 0: compiles the slot writer
            # for this bucket without touching logical state
            self.cache["k"], self.cache["v"], self.cache["lengths"] = \
                self._fns["write"](self.cache["k"], self.cache["v"],
                                   self.cache["lengths"], k_all[:, :, :w],
                                   v_all[:, :, :w], 0, 0)
        self._shapes.add(("decode",))
        logits, _ = self._fns["decode"](
            self.ev.params, self.cache, jnp.asarray(self._next),
            jnp.zeros((self.n_slots,), bool))
        logits.block_until_ready()

    # --- slot management -----------------------------------------------------
    def free_slots(self) -> List[int]:
        return [i for i, s in enumerate(self.slots) if s is None]

    @property
    def occupied(self) -> int:
        return sum(1 for s in self.slots if s is not None)

    @property
    def capacity(self) -> int:
        return self.n_slots

    @property
    def busy(self) -> bool:
        return self.occupied > 0

    def admission_signature(self) -> Tuple:
        """Free-capacity fingerprint for admission gating: a failed admission
        is only re-attempted once this changes (a slot was freed)."""
        return (len(self.free_slots()),)

    # --- serving -------------------------------------------------------------
    def can_admit(self, prompt_len: int, n_new: int) -> bool:
        assert prompt_len + n_new <= self.max_len, \
            f"prompt {prompt_len} + n_new {n_new} > max_len {self.max_len}"
        return any(s is None for s in self.slots)

    def admit_next(self, rid: int, t_arrival: float, prompt: np.ndarray,
                   n_new: int, priority: int = 0, slo: str = "interactive",
                   deadline_s: Optional[float] = None
                   ) -> Tuple[_SlotState, float]:
        """Admit into the first free slot; returns (state, prefill seconds)
        — the engine charges prefill at full busy power.  ``slo`` /
        ``deadline_s`` are accepted for the uniform instance surface; the
        slotted layout prefills at admission, so there is no prefill queue
        for a policy to order."""
        slot = self.free_slots()[0]
        t1 = time.perf_counter()
        state = self.admit(slot, rid, t_arrival, prompt, n_new,
                           priority=priority)
        state.t_first = time.perf_counter()
        return state, state.t_first - t1

    def admit(self, slot: int, rid: int, t_arrival: float,
              prompt: np.ndarray, n_new: int, priority: int = 0
              ) -> _SlotState:
        """One-pass prefill of ``prompt`` into ``slot``.  The prompt's
        last-position logits yield the first generated token immediately —
        the prefill forward is never discarded."""
        prompt = np.asarray(prompt, np.int32).reshape(-1)
        true_len = int(prompt.shape[0])
        assert true_len + n_new <= self.max_len, \
            f"prompt {true_len} + n_new {n_new} > max_len {self.max_len}"
        pad = _bucket(true_len)
        _note_shape(self, ("prefill", pad))
        padded = np.zeros((1, pad), np.int32)
        padded[0, :true_len] = prompt
        self.h2d_transfers += 1
        logits, k_all, v_all = self._fns["prefill"](self.ev.params,
                                                    jnp.asarray(padded))
        write = min(pad, self.max_len)   # padded tail beyond capacity is junk
        self.cache["k"], self.cache["v"], self.cache["lengths"] = \
            self._fns["write"](self.cache["k"], self.cache["v"],
                               self.cache["lengths"], k_all[:, :, :write],
                               v_all[:, :, :write], slot, true_len)
        self.host_syncs += 1             # blocking first-token readback
        first = int(jnp.argmax(logits[0, true_len - 1]))
        state = _SlotState(rid, t_arrival, remaining=n_new - 1,
                           tokens=[first], priority=priority)
        self._next[slot, 0] = first
        if state.remaining > 0:
            self.slots[slot] = state
        return state

    def step(self) -> Tuple[List[_SlotState], List[Tuple[int, int]]]:
        """One batched decode step over ALL slots; returns (completed
        requests — their slots are freed for mid-flight admission — and the
        (rid, token) emissions of every active row for streaming)."""
        active = np.array([s is not None for s in self.slots])
        _note_shape(self, ("decode",))
        self.h2d_transfers += 2          # next-token + active-mask uploads
        t_d0 = time.perf_counter()
        logits, self.cache = self._fns["decode"](
            self.ev.params, self.cache, jnp.asarray(self._next),
            jnp.asarray(active))
        self.host_syncs += 1             # blocking per-step token readback
        self.decode_dispatches += 1
        t_l0 = time.perf_counter()
        toks = np.asarray(jnp.argmax(logits, axis=-1))
        self.profiler.observe("decode_dispatch", t_l0 - t_d0)
        self.profiler.observe("decode_land", time.perf_counter() - t_l0)
        finished: List[_SlotState] = []
        emitted: List[Tuple[int, int]] = []
        for i, s in enumerate(self.slots):
            if s is None:
                continue
            s.tokens.append(int(toks[i]))
            emitted.append((s.rid, int(toks[i])))
            s.remaining -= 1
            self._next[i, 0] = int(toks[i])
            if s.remaining <= 0:
                finished.append(s)
                self.slots[i] = None
        return finished, emitted

    def tick(self, now: Optional[float] = None, allow_fused: bool = True
             ) -> Tuple[List[_SlotState], Dict[str, object]]:
        """One scheduler tick = one batched decode step (slotted prefill
        runs at admission; ``now`` / ``allow_fused`` are unused here —
        uniform tick surface with :class:`PagedInstance`)."""
        occ = self.occupied
        if occ == 0:
            return [], _tick_info()
        rids = [s.rid for s in self.slots if s is not None]
        t1 = time.perf_counter()
        finished, emitted = self.step()
        dt = time.perf_counter() - t1
        return finished, _tick_info(decode_s=dt, decode_steps=1, occupied=occ,
                                    decode_rids=rids, emitted=emitted)

    def generate(self, prompt: np.ndarray, n_new: int = 8
                 ) -> Tuple[np.ndarray, float]:
        """Greedy generation for a (possibly batched) prompt.

        prompt: (b, s) int32.  Returns (tokens (b, n_new), wall seconds).
        One-pass prefill + batched decode; each row takes its own argmax
        (the old engine hard-coded ``lg[0]`` and a scalar token feed, so
        every row beyond the first decoded row 0's tokens)."""
        t0 = time.perf_counter()
        prompt = np.asarray(prompt, np.int32)
        b, s = prompt.shape
        fns = self._fns
        logits, k_all, v_all = fns["prefill"](self.ev.params,
                                              jnp.asarray(prompt))
        max_len = s + n_new
        K, dh = self.ev.cfg.n_kv_heads, self.ev.cfg.d_head
        L = self.ev.cfg.n_layers
        cache = {
            "k": jnp.zeros((L, b, max_len, K, dh), jnp.float32
                           ).at[:, :, :s].set(k_all.astype(jnp.float32)),
            "v": jnp.zeros((L, b, max_len, K, dh), jnp.float32
                           ).at[:, :, :s].set(v_all.astype(jnp.float32)),
            "lengths": jnp.full((b,), s, jnp.int32),
        }
        active = jnp.ones((b,), bool)
        tok = jnp.argmax(logits[:, s - 1], axis=-1)          # (b,) per-row
        out = [tok]
        for _ in range(n_new - 1):
            lg, cache = fns["decode"](self.ev.params, cache,
                                      tok[:, None].astype(jnp.int32), active)
            tok = jnp.argmax(lg, axis=-1)
            out.append(tok)
        toks = np.asarray(jnp.stack(out, axis=1))
        return toks, time.perf_counter() - t0


# =============================================================================
# paged instance (kvpool)
# =============================================================================
@dataclasses.dataclass
class _PagedSeq:
    """Host-side state of one sequence in a paged instance.

    Carries the request's scheduling metadata (``priority``/``slo``/
    ``deadline_s``) plus a stable admission counter ``seq``, matching the
    attribute contract of ``scheduler._Entry`` — so the engine's active
    :class:`~repro.serving.policies.SchedulerPolicy` can order the
    instance-level chunked-prefill queue with the same ``select`` it uses
    for admission."""
    rid: int
    t_arrival: float
    prompt: np.ndarray
    n_new: int
    row: int                        # batch row (static decode shape)
    blocks: List[int]               # owned block refs (shared prefix + fresh)
    n_done: int                     # prompt tokens whose K/V are in the arena
    cached_tokens: int              # prefix-cache hit size at admission
    remaining: int = 0
    tokens: List[int] = dataclasses.field(default_factory=list)
    t_first: Optional[float] = None
    priority: int = 0
    preempts: int = 0               # times this sequence was swapped out
    pending_steps: int = 0          # decode steps dispatched but not landed
                                    # (``remaining`` is decremented at
                                    # DISPATCH; completion waits for landing)
    pending_first: Optional["_PendingFirst"] = None
    slo: str = "interactive"
    deadline_s: Optional[float] = None
    seq: int = 0                    # admission order (policy tie-break)

    @property
    def prefilled(self) -> bool:
        return self.n_done >= len(self.prompt)


class PagedInstance:
    """One serving instance over a paged KV arena.

    Memory is ``n_blocks`` fixed-size blocks (``kvpool.BlockAllocator`` owns
    the map); a sequence holds exactly ``ceil((prompt+n_new)/block_size)``
    blocks, minus whatever the radix prefix cache already has.  The decode
    batch is ``max_seqs`` static rows; admission is bounded by *blocks*, not
    rows — short prompts pack far more concurrency into the same arena than
    the slotted cache's per-slot ``max_len`` reservation.

    With ``preemption=True`` the whole-sequence reservation is dropped:
    admission reserves only the PROMPT's blocks and decode grows each
    sequence's table on demand; when the arena runs dry mid-decode the
    lowest-priority / youngest sequence is swapped out to host memory
    (``_SwapState``) for the engine to re-queue and later restore
    bit-exactly.

    With ``mesh`` the instance is SHARDED: params are placed under the
    GSPMD rules (tensor-parallel attention/MLP over "model"), the arena is
    committed with KV heads over "model" (``sharding.rules.arena_spec`` —
    an explicit error for non-divisible head counts), and uploaded loop
    buffers shard their row dim over "data" when divisible.  Block tables
    and the allocator stay host-side; the pipelined loop, fused dispatch
    and donation discipline are unchanged — jit just specializes to the
    sharded layouts.

    ``role`` splits the serving loop for disaggregation (``serving.
    disagg``): a ``"prefill"`` worker runs chunked prefill only (its tick
    never dispatches decode, admission reserves prompt blocks only) and
    fully-prefilled sequences are extracted via :meth:`handoff_out`; a
    ``"decode"`` worker receives them through ``resume``.  The default
    ``"both"`` is the monolithic engine."""

    profiler: PhaseProfiler = _NULL_PROFILER

    def __init__(self, ev: EngineVariant, chips: int, n_blocks: int,
                 block_size: int = 16, max_seqs: int = 8, max_len: int = 96,
                 chunk_blocks: int = 2, prefix_caching: bool = True,
                 cache_watermark: float = 0.25, chunk_burst: int = 4,
                 preemption: bool = False,
                 policy: Optional[SchedulerPolicy] = None,
                 pipeline: bool = True, fused_steps: int = 8,
                 mesh=None, role: str = "both"):
        assert role in ("both", "prefill", "decode"), role
        self.ev = ev
        self.chips = chips
        self.mesh = mesh
        self.role = role
        self.block_size = block_size
        self.max_len = max_len
        self.max_seqs = max_seqs
        self.n_pages = -(-max_len // block_size)
        self.chunk_tokens = chunk_blocks * block_size
        self.chunk_burst = chunk_burst   # max prefill chunks per tick when
                                         # the batch is decode-starved
        # keep this fraction of the arena free of *cache-only* blocks: a
        # tree that grows to fill the arena makes every admission evict —
        # and LRU eviction under full-arena pressure throws away exactly
        # the chains the next FIFO request was about to hit (cache thrash)
        self.cache_watermark = cache_watermark
        self.preemption = preemption
        # the engine's admission policy also orders THIS instance's chunked-
        # prefill queue (None / is_fifo → admission-order, the old behavior)
        self.policy = policy
        self._fns = _paged_fns(ev)
        # sharded instances run the SAME jitted fns — computation follows
        # the committed params/arena, specializing per sharding layout
        self.params = (ev.params if mesh is None
                       else _sharded_params(ev, mesh))
        self.arena = R.make_block_arena(ev.cfg, n_blocks, block_size,
                                        dtype=jnp.float32, mesh=mesh)
        self.alloc = BlockAllocator(n_blocks, block_size)
        self.prefix: Optional[RadixPrefixCache] = (
            RadixPrefixCache(self.alloc) if prefix_caching else None)
        self.rows: List[Optional[_PagedSeq]] = [None] * max_seqs
        self.tables = np.zeros((max_seqs, self.n_pages), np.int32)
        self.lengths = np.zeros((max_seqs,), np.int32)
        self._next = np.zeros((max_seqs, 1), np.int32)
        self._prefillq: Deque[_PagedSeq] = deque()
        self._adm_seq = 0                # admission counter (policy tie-break)
        self.prefill_chunks = 0
        self.prefix_hit_tokens = 0
        self.preemptions = 0
        # disaggregation traffic (lifetime; session deltas): sequences this
        # worker staged out for a decode worker, and the pages that moved
        self.handoffs_out = 0
        self.handoff_pages = 0
        # swap-in page accounting: ``total`` counts the pages a FULL restore
        # would have written back, ``copied`` the pages actually written —
        # the gap is what the radix tree's surviving blocks saved
        self.swapin_pages_total = 0
        self.swapin_pages_copied = 0
        self._shapes: set = set()        # jit shape keys seen (see _note_shape)
        self.retraces = 0                # lifetime post-warmup shape misses
        # --- device-resident decode hot path ---------------------------------
        # ``pipeline=False`` is the synchronous reference loop: loop state is
        # re-uploaded every tick and every dispatch lands in its own tick —
        # the pre-pipelining behavior, kept as the greedy-parity oracle.
        self.pipeline = pipeline
        self.fused_steps = max(int(fused_steps), 1)
        # device mirrors of (next, tables, lengths, active): uploaded only
        # when an EVENT (admission, prefill completion, release, preemption,
        # table growth, compaction) dirties the host copies — steady-state
        # decode runs entirely on device
        self._dev: Optional[dict] = None
        self._dev_B = 0
        self._dev_active: Optional[np.ndarray] = None
        self._dirty = True
        self._inflight: Deque[_PendingDecode] = deque()
        self._pending_first: List[_PendingFirst] = []
        self._tick_id = 0
        # per-tick landing accumulators (reset at each tick() entry; the
        # flush helpers append here so _swap_out can force-land mid-tick)
        self._ev_emitted: List[Tuple[int, int]] = []
        self._ev_finished: List[_PagedSeq] = []
        self._ld_s = 0.0                 # landed decode seconds this tick
        self._ld_steps = 0
        self._ld_occ = 0
        self._ld_rids: List[int] = []
        # host↔device traffic + dispatch counters (lifetime; session deltas)
        self.host_syncs = 0
        self.h2d_transfers = 0
        self.decode_dispatches = 0

    # --- lifecycle -----------------------------------------------------------
    def reset(self) -> None:
        """Recycle from the warm pool: fresh allocator/prefix state; arena
        contents are stale but unreachable (no tables point at them)."""
        self.alloc = BlockAllocator(self.alloc.n_blocks, self.block_size)
        if self.prefix is not None:
            self.prefix = RadixPrefixCache(self.alloc)
        self.rows = [None] * self.max_seqs
        self.tables[:] = 0
        self.lengths[:] = 0
        self._next[:] = 0
        self._prefillq.clear()
        self._inflight.clear()
        self._pending_first.clear()
        self._dev = None
        self._dev_B = 0
        self._dev_active = None
        self._dirty = True

    def _put_rows(self, arr: np.ndarray):
        """Upload one (B, ...) loop-state buffer.  Under a mesh the leading
        row dim shards over "data" when divisible (replicated otherwise) so
        the decode batch splits across data-parallel devices; without a mesh
        this is a plain ``jnp.asarray`` (the PR 7 behavior, bit-identical)."""
        if self.mesh is None:
            return jnp.asarray(arr)
        from jax.sharding import NamedSharding, PartitionSpec
        nd = self.mesh.shape.get("data", 1)
        ax = "data" if nd > 1 and arr.shape[0] % nd == 0 else None
        spec = PartitionSpec(ax, *([None] * (arr.ndim - 1)))
        return jax.device_put(arr, NamedSharding(self.mesh, spec))

    def warmup(self) -> None:
        """Compile every shape the serve loop can reach: the (single)
        fixed-size prefill chunk plus, per power-of-two row bucket
        (``_row_buckets`` — the batch-axis analogue of ``serve_buckets``),
        the fused decode at both step counts the loop dispatches (k = 1
        pipelined single-step, k = ``fused_steps`` when eligible).
        ``true_c = 0`` / an all-False mask route every warmup write to the
        junk block, so logical state is untouched."""
        dummy = jnp.zeros((1, self.chunk_tokens), jnp.int32)
        for span in self._page_buckets():
            self._shapes.add(("prefill_paged", span))
            lg, self.arena = self._fns["prefill_paged"](
                self.params, dummy, self.arena,
                jnp.zeros((span,), jnp.int32), 0, 0)
            lg.block_until_ready()
        ks = sorted({1, self.fused_steps})
        for B in self._row_buckets():
            for k in ks:
                self._shapes.add(("decode_multi", B, k))
                toks, self.arena, _, _ = self._fns["decode_multi"](
                    self.params, self.arena, self._put_rows(self._next[:B]),
                    self._put_rows(self.tables[:B]),
                    self._put_rows(self.lengths[:B]),
                    self._put_rows(np.zeros((B,), bool)), k=k)
                toks.block_until_ready()

    # --- capacity ------------------------------------------------------------
    @property
    def occupied(self) -> int:
        return sum(1 for s in self.rows if s is not None)

    @property
    def capacity(self) -> int:
        return self.max_seqs

    @property
    def busy(self) -> bool:
        return self.occupied > 0

    def _avail_blocks(self) -> int:
        return self.alloc.num_free + (self.prefix.evictable_blocks()
                                      if self.prefix else 0)

    def admission_signature(self) -> Tuple:
        """Free-capacity fingerprint for admission gating: a failed
        block-aware admission is only re-attempted once the allocator state
        (free list OR any refcount — the prefix tree's evictable set is a
        pure function of refcounts) or a batch row changed.  The allocator
        ``version`` makes this O(1): re-peeking + re-walking the evictable
        set every tick when nothing was freed is pure waste."""
        return (sum(1 for s in self.rows if s is None), self.alloc.version)

    def can_admit(self, prompt_len: int, n_new: int) -> bool:
        """Admission control by BLOCK availability: a free batch row plus
        enough free-or-evictable blocks.  Without preemption the worst case
        (no prefix hit) of the WHOLE sequence is reserved; with preemption
        only the prompt needs to fit now — decode grows on demand and block
        pressure is resolved by swapping victims out."""
        assert prompt_len + n_new <= self.max_len, \
            f"prompt {prompt_len} + n_new {n_new} > max_len {self.max_len}"
        # a prefill worker never decodes here: only the prompt's blocks are
        # ever written before handoff, so that is all admission reserves
        reserve = (prompt_len if self.preemption or self.role == "prefill"
                   else prompt_len + n_new)
        need = self.alloc.blocks_for_tokens(reserve)
        assert need <= self.alloc.num_allocatable, \
            f"request needs {need} blocks > arena {self.alloc.num_allocatable}"
        if all(s is not None for s in self.rows):
            return False
        return self._avail_blocks() >= need

    # --- admission -----------------------------------------------------------
    def admit_next(self, rid: int, t_arrival: float, prompt: np.ndarray,
                   n_new: int, priority: int = 0, slo: str = "interactive",
                   deadline_s: Optional[float] = None
                   ) -> Tuple[_PagedSeq, float]:
        """Reserve blocks + a batch row; NO forward pass happens here —
        prefill is chunked across subsequent ticks (so admission never
        stalls sequences that are already decoding).  Shared prompt-prefix
        blocks come from the radix cache already prefilled."""
        prompt = np.asarray(prompt, np.int32).reshape(-1)
        true_len = int(prompt.shape[0])
        row = self.rows.index(None)
        matched: List[int] = []
        n_cached = 0
        if self.prefix is not None:
            matched, n_cached = self.prefix.match(prompt)
        reserve = (true_len if self.preemption or self.role == "prefill"
                   else true_len + n_new)
        need = self.alloc.blocks_for_tokens(reserve) - len(matched)
        if need > self.alloc.num_free and self.prefix is not None:
            self.prefix.evict(need - self.alloc.num_free)
        blocks = matched + self.alloc.alloc(need)
        seq = _PagedSeq(rid, t_arrival, prompt, n_new, row, blocks,
                        n_done=n_cached, cached_tokens=n_cached,
                        remaining=n_new, priority=priority, slo=slo,
                        deadline_s=deadline_s, seq=self._adm_seq)
        self._adm_seq += 1
        self.tables[row, :len(blocks)] = blocks
        self.tables[row, len(blocks):] = 0
        self.lengths[row] = 0            # row inactive until prefill completes
        self._next[row, 0] = 0
        self.rows[row] = seq
        self._prefillq.append(seq)
        self.prefix_hit_tokens += n_cached
        self._dirty = True               # admission event: mirrors changed
        return seq, 0.0

    # --- preemption / swap ---------------------------------------------------
    def can_resume(self, swap: _SwapState) -> bool:
        """Re-admission check for a swapped-out sequence: a free row plus
        its saved block count (decode re-grows past that on demand)."""
        if all(s is not None for s in self.rows):
            return False
        return self._avail_blocks() >= swap.n_blocks

    def resume(self, swap: _SwapState) -> Tuple[_PagedSeq, float]:
        """Restore a preempted sequence — PARTIALLY when the radix tree
        still holds its prompt blocks.

        The leading pages recorded tree-backed at swap-out are re-acquired
        from the prefix cache (``match_full``: a reference per block, no
        device copy — their K/V never left the arena); only the evicted
        tail pages are written back from the host image.  If the tree
        dropped the nodes in the meantime the match comes back short and
        the difference is restored from host — bit-exact either way, so
        greedy decode continues on identical state."""
        row = self.rows.index(None)
        nb = swap.n_blocks
        reused: List[int] = []
        if self.prefix is not None and swap.tree_blocks > 0:
            reused = self.prefix.match_full(
                swap.prompt, max_blocks=min(swap.tree_blocks, nb))
        n_tail = nb - len(reused)
        if n_tail > self.alloc.num_free and self.prefix is not None:
            self.prefix.evict(n_tail - self.alloc.num_free)
        tail = self.alloc.alloc(n_tail)
        if n_tail:
            # jitted donated page scatter: the un-jitted ``.at[].set`` copied
            # the WHOLE arena per restore.  The tail count is padded to its
            # bucket (extra slots write zero pages into junk block 0, which
            # is garbage by contract) so restore compiles per bucket, not
            # per tail length.
            pb = _pow2_bucket(n_tail, self.n_pages)
            idx = np.zeros((pb,), np.int32)
            idx[:n_tail] = tail
            hk = swap.host_k[:, len(reused):]
            hv = swap.host_v[:, len(reused):]
            if pb != n_tail:
                pad = [(0, 0)] * hk.ndim
                pad[1] = (0, pb - n_tail)
                hk = np.pad(hk, pad)
                hv = np.pad(hv, pad)
            self.h2d_transfers += 3      # index vector + K + V page uploads
            t_h2d = time.perf_counter()
            self.arena = self._fns["restore_paged"](
                self.arena, jnp.asarray(idx), jnp.asarray(hk),
                jnp.asarray(hv))
            self.profiler.observe("swap_h2d", time.perf_counter() - t_h2d)
        blocks = reused + tail
        self.swapin_pages_total += nb
        self.swapin_pages_copied += n_tail
        seq = _PagedSeq(swap.rid, swap.t_arrival, swap.prompt, swap.n_new,
                        row, blocks, n_done=len(swap.prompt),
                        cached_tokens=swap.cached_tokens,
                        remaining=swap.remaining, tokens=list(swap.tokens),
                        t_first=swap.t_first, priority=swap.priority,
                        preempts=swap.preempts, slo=swap.slo,
                        deadline_s=swap.deadline_s, seq=self._adm_seq)
        self._adm_seq += 1
        self.tables[row, :nb] = blocks
        self.tables[row, nb:] = 0
        self.lengths[row] = swap.n_ctx
        self._next[row, 0] = swap.next_token
        self.rows[row] = seq
        self._dirty = True               # swap-in event: mirrors changed
        return seq, 0.0

    def _select_victim(self, exclude: _PagedSeq) -> Optional[_PagedSeq]:
        """Preemption victim: lowest priority first, youngest (latest
        arrival) within a level; only fully-prefilled decoding sequences
        qualify (mid-prefill rows sit in the prefill queue)."""
        cands = [s for s in self.rows
                 if s is not None and s.prefilled and s.remaining > 0
                 and s is not exclude]
        if not cands:
            return None
        return min(cands, key=lambda s: (s.priority, -s.t_arrival))

    def _swap_out(self, seq: _PagedSeq) -> _SwapState:
        """Swap a sequence's K/V pages to host memory and release its arena
        blocks + batch row.  The engine re-queues the returned image.

        ``tree_blocks`` snapshots how many leading pages the radix tree
        backs at this instant (full prompt blocks the cache still maps):
        those are the pages ``resume`` will try to re-acquire by reference
        instead of copying back.  The host image still saves every page —
        the snapshot is a ceiling, not a promise, because LRU eviction may
        drop the nodes before re-admission.

        The page copy is STAGED: a jitted (bucket-padded) device gather
        with ``copy_to_host_async`` started immediately, so the transfer
        overlaps the decode ticks between swap-out and resume instead of
        blocking the loop here.  Any in-flight decode work is landed first
        (the image must contain the sequence's true tokens/lengths)."""
        return self._stage_out(seq, count_preempt=True)

    def handoff_out(self, seq: _PagedSeq) -> _SwapState:
        """Stage a FULLY-PREFILLED sequence out for prefill→decode handoff
        (``serving.disagg``): same staged page gather + row/block release
        as a swap-out, but it is a planned transfer, not a preemption — the
        sequence's ``preempts`` count and this instance's ``preemptions``
        counter stay untouched; ``handoffs_out``/``handoff_pages`` record
        the traffic instead.  Only the sequence's own pending first token
        is landed (there are no in-flight decodes on a prefill worker), so
        extracting one handoff never force-flushes its neighbours."""
        assert seq.prefilled and seq.pending_first is None, \
            f"handoff of rid {seq.rid} before its first token landed"
        swap = self._stage_out(seq, count_preempt=False)
        self.handoffs_out += 1
        self.handoff_pages += swap.nb
        return swap

    def _stage_out(self, seq: _PagedSeq, *,
                   count_preempt: bool) -> _SwapState:
        if count_preempt:
            self._flush_all()            # pending tokens become part of image
        elif seq.pending_first is not None:
            self._land_first(seq.pending_first)
        n_ctx = int(self.lengths[seq.row])
        nb = self.alloc.blocks_for_tokens(max(n_ctx, 1))
        pb = _pow2_bucket(nb, self.n_pages)
        idx = np.zeros((pb,), np.int32)  # pad with junk pages: gathered then
        idx[:nb] = seq.blocks[:nb]       # sliced off at materialisation
        tree_blocks = 0
        if self.prefix is not None:
            tree_blocks = self.prefix.live_prefix_blocks(seq.prompt, limit=nb)
        t_d2h = time.perf_counter()
        img_k, img_v = self._fns["gather_pages"](self.arena, jnp.asarray(idx))
        for img in (img_k, img_v):
            try:
                img.copy_to_host_async()
            except AttributeError:       # non-jax array stand-ins in tests
                pass
        self.profiler.observe("swap_d2h", time.perf_counter() - t_d2h)
        swap = _SwapState(
            rid=seq.rid, t_arrival=seq.t_arrival, prompt=seq.prompt,
            n_new=seq.n_new, priority=seq.priority, tokens=list(seq.tokens),
            remaining=seq.remaining, n_ctx=n_ctx,
            next_token=int(self._next[seq.row, 0]), t_first=seq.t_first,
            cached_tokens=seq.cached_tokens,
            preempts=seq.preempts + (1 if count_preempt else 0),
            img_k=img_k, img_v=img_v, nb=nb,
            tree_blocks=tree_blocks, slo=seq.slo, deadline_s=seq.deadline_s)
        self.alloc.free(seq.blocks)      # decref: prefix-tree refs survive
        self._clear_row(seq)
        if count_preempt:
            self.preemptions += 1
        return swap

    def _ensure_decode_capacity(self) -> List[_SwapState]:
        """Pre-decode pass under ``preemption=True``: grow every decoding
        row's block table to cover its next token write, swapping out
        victims when the arena (free list + evictable prefix blocks) runs
        dry.  Restarts after every mutation — ``_compact`` reshuffles rows,
        so cached indices would go stale."""
        swapped: List[_SwapState] = []
        while True:
            needy = None
            for i, s in enumerate(self.rows):
                if (s is not None and s.prefilled and s.remaining > 0
                        and self.alloc.blocks_for_tokens(
                            int(self.lengths[i]) + 1) > len(s.blocks)):
                    needy = s
                    break
            if needy is None:
                return swapped
            if self.alloc.num_free < 1 and self.prefix is not None:
                self.prefix.evict(1)
            if self.alloc.num_free >= 1:
                bid = self.alloc.alloc(1)[0]
                needy.blocks.append(bid)
                self.tables[needy.row, len(needy.blocks) - 1] = bid
                self._dirty = True       # table growth: mirrors changed
                continue
            if self._inflight or self._pending_first:
                # land in-flight work before choosing a victim: a pending
                # completion may release its blocks and spare the swap
                self._flush_all()
                continue
            victim = self._select_victim(exclude=needy) or needy
            swapped.append(self._swap_out(victim))

    def _release(self, seq: _PagedSeq) -> None:
        self.alloc.free(seq.blocks)      # decref: prefix-tree refs survive
        self._clear_row(seq)
        self._enforce_watermark()

    def _clear_row(self, seq: _PagedSeq) -> None:
        self.rows[seq.row] = None
        self.tables[seq.row, :] = 0
        self.lengths[seq.row] = 0
        self._next[seq.row, 0] = 0
        self._compact(seq.row)
        self._dirty = True               # release event: mirrors changed

    def _compact(self, hole: int) -> None:
        """Keep occupied rows a contiguous prefix: move the highest occupied
        row into the freed hole (host bookkeeping only — arena blocks never
        move).  Compactness is what lets ``tick`` decode over a power-of-two
        row bucket instead of all ``max_seqs`` static rows: a batch with 5
        live sequences pays for 8 rows of gather+compute, not 16."""
        last = max((i for i, s in enumerate(self.rows) if s is not None),
                   default=-1)
        if last <= hole:
            return
        seq = self.rows[last]
        self.rows[hole], self.rows[last] = seq, None
        seq.row = hole
        self.tables[hole] = self.tables[last]
        self.tables[last, :] = 0
        self.lengths[hole] = self.lengths[last]
        self.lengths[last] = 0
        self._next[hole, 0] = self._next[last, 0]
        self._next[last, 0] = 0

    def _row_buckets(self) -> List[int]:
        """Decode-batch buckets (batch-axis analogue of ``serve_buckets``):
        the ``_bucket_ladder`` over ``max_seqs``."""
        return _bucket_ladder(self.max_seqs)

    def _page_buckets(self) -> List[int]:
        """Prefill KV-span buckets: the ``_bucket_ladder`` over ``n_pages``.
        A chunk's queries can only see the first ``n_past + true_c``
        positions, so gathering/attending over the full table width wastes
        ~4× compute on the early chunks of a long prompt — the span is
        sliced to the smallest covering bucket."""
        return _bucket_ladder(self.n_pages)

    def _enforce_watermark(self) -> None:
        """Trim cache-only blocks until the free watermark holds, so the
        next admission draws from the free list instead of fighting the
        tree for whatever LRU eviction happens to surrender."""
        if self.prefix is None:
            return
        target = int(self.cache_watermark * self.alloc.num_allocatable)
        if self.alloc.num_free < target:
            self.prefix.evict(target - self.alloc.num_free)

    # --- serving -------------------------------------------------------------
    def _prefill_chunk(self, seq: _PagedSeq) -> None:
        """Advance one chunk of ``seq``'s prompt through the arena.  The
        final chunk's last-position logits yield the first generated token
        (never discarded), and the prompt's full blocks register in the
        prefix tree for future sharing.

        The first token STAYS ON DEVICE: its argmax is dispatched (with an
        async host copy) instead of the old blocking ``int(jnp.argmax(...))``
        per final chunk, and the pending device scalar is scattered into the
        ``next`` buffer at the following upload — the host records its value
        through the pipelined landing path (``_land_first``)."""
        start = seq.n_done
        true_c = min(self.chunk_tokens, len(seq.prompt) - start)
        padded = np.zeros((1, self.chunk_tokens), np.int32)
        padded[0, :true_c] = seq.prompt[start:start + true_c]
        # slice the visible KV span to its page bucket: this chunk's queries
        # end at start + true_c, so later pages are causally invisible
        span = _pow2_bucket(-(-(start + true_c) // self.block_size),
                            self.n_pages)
        _note_shape(self, ("prefill_paged", span))
        self.h2d_transfers += 2          # padded chunk + table-slice uploads
        logits, self.arena = self._fns["prefill_paged"](
            self.params, jnp.asarray(padded), self.arena,
            jnp.asarray(self.tables[seq.row][:span]), start, true_c)
        seq.n_done += true_c
        self.prefill_chunks += 1
        if seq.prefilled:
            tok = jnp.argmax(logits[0, true_c - 1]).astype(jnp.int32)
            try:
                tok.copy_to_host_async()
            except AttributeError:
                pass
            pf = _PendingFirst(seq, tok, self._tick_id)
            self._pending_first.append(pf)
            seq.pending_first = pf
            seq.remaining -= 1
            seq.t_first = time.perf_counter()
            self.lengths[seq.row] = len(seq.prompt)
            self._dirty = True           # row activation: mirrors changed
            if self.prefix is not None:
                self.prefix.insert(seq.prompt, seq.blocks)

    # --- pipelined landing ----------------------------------------------------
    def _land_first(self, pf: _PendingFirst) -> None:
        """Record a pending first token on the host.  Landing in the tick
        that created it is a forced (blocking) round-trip and counts as a
        ``host_syncs``; landing later overlapped host work for free."""
        seq = pf.seq
        if pf.tick_id == self._tick_id:
            self.host_syncs += 1
        first = int(np.asarray(pf.tok))
        seq.tokens.append(first)         # tokens[0]: decode landings wait
        self._ev_emitted.append((seq.rid, first))
        seq.pending_first = None
        if pf in self._pending_first:
            self._pending_first.remove(pf)
        if self.rows[seq.row] is seq:
            self._next[seq.row, 0] = first

    def _land_item(self, item: _PendingDecode) -> None:
        """Land one dispatched decode call: block on its (k, B) token
        readback, append tokens in dispatch order, advance the landing
        accumulators, and complete sequences whose final tokens arrived.
        A sequence's pending first token (if any) lands first — per-request
        token order is part of the greedy-parity contract."""
        if item.tick_id == self._tick_id:
            self.host_syncs += 1         # same-tick landing: no overlap
        t0 = time.perf_counter()
        toks = np.asarray(item.toks)     # blocks until the async copy lands
        t_land = time.perf_counter() - t0
        self._ld_s += item.dispatch_s + t_land
        self.profiler.observe("decode_dispatch", item.dispatch_s)
        self.profiler.observe("decode_land", t_land)
        self._ld_steps += item.k
        self._ld_occ = max(self._ld_occ, item.occupied)
        done: List[_PagedSeq] = []
        for s, col in item.rows:
            if s.pending_first is not None:
                self._land_first(s.pending_first)
            self._ld_rids.append(s.rid)
            for i in range(item.k):
                t = int(toks[i, col])
                s.tokens.append(t)
                self._ev_emitted.append((s.rid, t))
            s.pending_steps -= item.k
            self._next[s.row, 0] = int(toks[item.k - 1, col])
            if s.remaining <= 0 and s.pending_steps <= 0:
                done.append(s)
        for s in done:                   # release AFTER the sweep: _compact
            self._ev_finished.append(s)  # moves rows and would skew columns
            self._release(s)

    def _land_ready(self) -> None:
        """Collect readbacks dispatched BEFORE this tick — their async
        copies overlapped at least one full tick of host work, so these
        landings are free (no ``host_syncs``)."""
        for pf in list(self._pending_first):
            if pf.tick_id < self._tick_id:
                self._land_first(pf)
        while self._inflight and self._inflight[0].tick_id < self._tick_id:
            self._land_item(self._inflight.popleft())

    def _flush_decodes(self) -> None:
        """Force-land every in-flight decode call (upload precondition:
        host mirrors must equal device state).  Pending FIRST tokens stay
        pending — the upload scatters their device scalars into ``next``
        instead of blocking on them."""
        while self._inflight:
            self._land_item(self._inflight.popleft())

    def _flush_all(self) -> None:
        """Force-land everything, first tokens included — the swap-out /
        victim-selection path, where the host image must carry the true
        tokens, lengths, and next-token of every sequence."""
        self._flush_decodes()
        for pf in list(self._pending_first):
            self._land_first(pf)

    def _upload(self, B: int, active: np.ndarray) -> None:
        """Push fresh loop state (next, tables, lengths, active) for row
        bucket ``B`` — the EVENT path.  Unlanded first tokens are scattered
        into the uploaded ``next`` buffer as device scalars, so a prefill
        completion never blocks the loop on its own argmax."""
        assert not self._inflight, "upload with stale in-flight decodes"
        nxt = self._put_rows(self._next[:B])
        for pf in self._pending_first:
            nxt = nxt.at[pf.seq.row, 0].set(pf.tok)
        self._dev = {"next": nxt,
                     "tables": self._put_rows(self.tables[:B]),
                     "lengths": self._put_rows(self.lengths[:B]),
                     "active": self._put_rows(active[:B])}
        self.h2d_transfers += 4
        self._dev_B = B
        self._dev_active = active[:B].copy()
        self._dirty = False

    def _choose_k(self, act_rows: List[_PagedSeq]) -> int:
        """Fused-dispatch step count: ``fused_steps`` when every active row
        can absorb k on-device steps with zero host intervention — no
        prefill work queued, ``remaining >= k`` (no completion inside the
        window), and block-table headroom for k more tokens (no growth or
        swap inside the window) — else 1.  Fusion is bit-identical to k
        single steps (`decode_paged_multi`), so eligibility only shapes
        dispatch granularity, never tokens."""
        k = self.fused_steps
        if k <= 1 or not self.pipeline or self._prefillq:
            return 1
        bs = self.block_size
        for s in act_rows:
            if (s.remaining < k
                    or len(s.blocks) * bs < int(self.lengths[s.row]) + k):
                return 1
        return k

    def _decodable(self) -> int:
        return sum(1 for s in self.rows
                   if s is not None and s.prefilled and s.remaining > 0)

    def _next_prefill(self, now: Optional[float]) -> int:
        """Index into ``_prefillq`` of the next chunk to advance, delegated
        to the engine's admission policy: under ``priority``/``edf``/the
        carbon policies an interactive admission's chunks preempt a long
        background prefill *mid-prompt* inside the same burst budget,
        instead of queueing behind it in admission order.  FIFO (or no
        policy) keeps the original head-first behavior; a policy hold falls
        back to the head too (``select_prefill`` — admitted work holds
        blocks, parking it only strands memory)."""
        if (self.policy is None or getattr(self.policy, "is_fifo", False)
                or len(self._prefillq) == 1):
            return 0
        return self.policy.select_prefill(list(self._prefillq), now)

    def tick(self, now: Optional[float] = None, allow_fused: bool = True
             ) -> Tuple[List[_PagedSeq], Dict[str, object]]:
        """One scheduler tick of the PIPELINED decode loop: an adaptive
        prefill budget, then one batched decode DISPATCH over all decoding
        rows (fused to ``fused_steps`` device-fed steps when eligible),
        then the LANDING of whatever readbacks finished overlapping earlier
        ticks.  ``now`` is the engine's session-relative clock, passed
        through to the policy ordering the prefill queue.

        Steady state touches the host ZERO times per tick: loop state lives
        on device (``_dev``), the greedy token feeds back inside the jitted
        call, and tick N's (k, B) token block lands while tick N+1's
        dispatch is already queued.  Only EVENTS (admission, prefill
        completion, release, growth, preemption) dirty the mirrors and
        trigger a flush + one re-upload.  The tick info therefore describes
        LANDED decode work — possibly dispatched an earlier tick — while
        the prefill fields stay dispatch-accounted.

        Prefill policy: while the batch is decode-starved (fewer decodable
        rows than half the row capacity), burst up to ``chunk_burst``
        policy-ordered chunks — stalling nobody, since there is little to
        stall — and back off to a SINGLE chunk per tick once decode
        concurrency is healthy, so a 512-token admission interleaves with
        running decodes instead of pausing them for its whole prefill."""
        self._tick_id += 1
        self._ev_emitted = []
        self._ev_finished = []
        self._ld_s = 0.0
        self._ld_steps = 0
        self._ld_occ = 0
        self._ld_rids = []
        prefill_rids: List[Tuple[int, float]] = []
        prefill_s = 0.0
        if self._prefillq:
            burst = 0
            while self._prefillq:
                if burst >= self.chunk_burst:
                    break
                if burst > 0 and self._decodable() >= max(
                        1, min(self.occupied, self.max_seqs // 2)):
                    break                        # decode is busy: yield
                qi = self._next_prefill(now)
                seq = self._prefillq[qi]
                tc = time.perf_counter()
                self._prefill_chunk(seq)
                dtc = time.perf_counter() - tc
                prefill_rids.append((seq.rid, dtc))
                prefill_s += dtc
                burst += 1
                if seq.prefilled:
                    del self._prefillq[qi]
                    if seq.remaining <= 0:       # n_new == 1: the request IS
                        self._land_first(seq.pending_first)  # its first token
                        self._ev_finished.append(seq)
                        self._release(seq)
        if self.role == "prefill":
            # a prefill worker never dispatches decode: land first-token
            # readbacks whose async copies overlapped an earlier tick (the
            # disagg layer extracts those sequences via handoff_out), skip
            # table growth / decode dispatch / preemption entirely
            self._land_ready()
            return self._ev_finished, _tick_info(
                prefill_s=prefill_s,
                blocks_in_use=self.alloc.blocks_in_use(),
                prefill_rids=prefill_rids, emitted=self._ev_emitted)
        # decode-time block pressure: grow tables on demand, swap victims
        # out when the arena is dry (PREEMPTED lifecycle state)
        preempted = self._ensure_decode_capacity() if self.preemption else []
        active = np.array([s is not None and s.prefilled and s.remaining > 0
                           for s in self.rows])
        occ = int(active.sum())
        B = self._dev_B
        if occ:
            # occupied rows are a compact prefix (see _compact): decode over
            # the smallest power-of-two row bucket covering them, so 5 live
            # sequences cost 8 rows of gather+compute, not max_seqs
            B = _pow2_bucket(self.occupied, self.max_seqs)
            if (self._dirty or self._dev is None or B != self._dev_B
                    or not self.pipeline
                    or not np.array_equal(active[:B], self._dev_active)):
                # EVENT path: land in-flight work (mirrors must equal the
                # device state), then push fresh loop state once
                self._flush_decodes()    # may release rows -> recompute
                active = np.array([s is not None and s.prefilled
                                   and s.remaining > 0 for s in self.rows])
                occ = int(active.sum())
                if occ:
                    B = _pow2_bucket(self.occupied, self.max_seqs)
                    self._upload(B, active)
        if occ:
            act_rows = [s for s in self.rows[:B]
                        if s is not None and s.prefilled and s.remaining > 0]
            k = self._choose_k(act_rows) if allow_fused else 1
            _note_shape(self, ("decode_multi", B, k))
            t1 = time.perf_counter()
            toks, self.arena, nxt, ln = self._fns["decode_multi"](
                self.params, self.arena, self._dev["next"],
                self._dev["tables"], self._dev["lengths"],
                self._dev["active"], k=k)
            self._dev["next"], self._dev["lengths"] = nxt, ln
            try:
                toks.copy_to_host_async()
            except AttributeError:       # non-jax stand-ins in tests
                pass
            dispatch_s = time.perf_counter() - t1
            self.decode_dispatches += 1
            for s in act_rows:           # predictive mirrors: decremented at
                s.remaining -= k         # dispatch; truth lands later
                s.pending_steps += k
                self.lengths[s.row] += k
            self._inflight.append(_PendingDecode(
                toks, [(s, s.row) for s in act_rows], k, occ, dispatch_s,
                self._tick_id))
        # LANDING: readbacks dispatched before this tick overlapped a full
        # tick of host work — collect them for free; the synchronous
        # reference mode (pipeline=False) lands everything immediately
        if self.pipeline:
            self._land_ready()
        else:
            self._flush_all()
        return self._ev_finished, _tick_info(
            prefill_s=prefill_s, decode_s=self._ld_s,
            decode_steps=self._ld_steps, occupied=self._ld_occ,
            blocks_in_use=self.alloc.blocks_in_use(),
            prefill_rids=prefill_rids, decode_rids=self._ld_rids,
            emitted=self._ev_emitted, preempted=preempted)


# =============================================================================
# engine
# =============================================================================
class _Session:
    """One serve session's bookkeeping: the policy queue, the open-loop
    release schedule, per-request energy meters, swapped-out images, the
    admission gate, and the aggregate counters ``stats`` reports."""

    def __init__(self, core: SchedulerCore, instances,
                 registry: Optional[MetricsRegistry] = None,
                 tracer: Optional[TraceRecorder] = None) -> None:
        self.core = core
        self.registry = (registry if registry is not None
                         else MetricsRegistry.standard("real"))
        self.tracer = tracer
        self.span_ids: Dict[int, int] = {}       # rid → "request" span sid
        self.preempt_sids: Dict[int, int] = {}   # rid → open "preempted" sid
        self.t0 = time.perf_counter()
        self.future: List[Tuple[float, int, int]] = []   # (t_abs, seq, rid)
        self._fseq = 0
        self.requests: Dict[int, InferenceRequest] = {}
        self.meters: Dict[int, float] = {}
        self.swapped: Dict[int, _SwapState] = {}
        self.variant_of: Dict[int, str] = {}     # rid → decided ladder rung
        self.admit_gate: Dict[int, Tuple] = {}           # id(inst) → (rid, sig)
        self.admit_t: Dict[int, float] = {}
        self.responses: List[InferenceResponse] = []
        self.admit_order: List[int] = []
        self.queue_delays: List[float] = []
        self.ttfts: List[float] = []
        self.energy = 0.0
        # per-role joules (disaggregation accounting): every charge is
        # tagged with the instance's role — "both" for monolithic engines,
        # "prefill"/"decode"/"handoff" under serving.disagg.  ``charge`` +
        # ``meter`` keep the ``energy``/``meters`` accumulation order
        # IDENTICAL to the untagged path, so monolithic numbers are
        # bit-for-bit unchanged and role sums conserve by construction.
        self.role_energy: Dict[str, float] = {}
        self.meters_role: Dict[int, Dict[str, float]] = {}
        self.handoffs = 0
        self.handoff_pages = 0
        self.decode_steps = 0
        self.occ_frac_sum = 0.0
        self.inflight_sum = 0
        self.admitted_sum = 0
        self.tick_samples = 0
        self.blocks_peak = 0
        self.preempt_total = 0
        self.progressed = False
        # wall seconds already charged per instance (prefill + decode); the
        # remainder of the serve wall is charged at idle power at drain, so
        # an allocated-but-idle instance is never free (same convention as
        # the DES's idle_chip_s accounting)
        self.accounted_s = {id(i): 0.0 for i in instances}
        # instance counters are lifetime (they survive reset/warm reuse);
        # stats report THIS session's delta
        self.chunks0 = sum(getattr(i, "prefill_chunks", 0) for i in instances)
        self.hits0 = sum(getattr(i, "prefix_hit_tokens", 0)
                         for i in instances)
        self.swap_total0 = sum(getattr(i, "swapin_pages_total", 0)
                               for i in instances)
        self.swap_copied0 = sum(getattr(i, "swapin_pages_copied", 0)
                                for i in instances)
        self.retraces0 = sum(getattr(i, "retraces", 0) for i in instances)
        self.syncs0 = sum(getattr(i, "host_syncs", 0) for i in instances)
        self.h2d0 = sum(getattr(i, "h2d_transfers", 0) for i in instances)
        self.dispatches0 = sum(getattr(i, "decode_dispatches", 0)
                               for i in instances)

    def charge(self, role: str, joules: float) -> None:
        """Add session energy under a role tag (see ``role_energy``)."""
        self.energy += joules
        self.role_energy[role] = self.role_energy.get(role, 0.0) + joules

    def meter(self, rid: int, role: str, joules: float) -> None:
        """Add per-request energy under a role tag (see ``meters_role``)."""
        self.meters[rid] += joules
        mr = self.meters_role.setdefault(rid, {})
        mr[role] = mr.get(role, 0.0) + joules

    def schedule(self, req: InferenceRequest) -> None:
        if req.arrival_s is None:
            self.core.submit(req.rid, self.t0, priority=req.priority,
                             deadline_s=req.deadline_s, slo=req.slo)
        else:
            heapq.heappush(self.future,
                           (self.t0 + float(req.arrival_s), self._fseq,
                            req.rid))
            self._fseq += 1

    def rel(self, now: float) -> float:
        """Session-relative seconds — the clock policies see.  Deadlines
        stay as submitted (relative to session start), so the SAME policy
        object behaves identically here and on the DES's simulated clock."""
        return now - self.t0


class RealEngine:
    """Maps a ConfigGraph onto real instances and serves
    :class:`InferenceRequest`s with continuous batching through the
    ``ServingBackend`` protocol, measuring wall latencies and attributing
    occupancy-scaled energy (the calibrated stand-in for TPU telemetry) and
    carbon (``ci_g_per_kwh``) per request.

    ``mesh=`` shards every paged instance across a ("data", "model") device
    mesh (``launch.mesh.make_mesh_for``); ``roles=`` splits the engine into
    prefill and decode workers — constructing ``RealEngine(..., roles=...)``
    transparently builds a :class:`serving.disagg.DisaggEngine` (same
    ``ServingBackend`` surface, so callers and the fleet's ``probe_window``
    drive it unchanged)."""

    def __new__(cls, *args, **kwargs):
        if cls is RealEngine and kwargs.get("roles"):
            from repro.serving.disagg import DisaggEngine
            return super().__new__(DisaggEngine)
        return super().__new__(cls)

    def __init__(self, family: Sequence[EngineVariant], n_slots: int = 4,
                 max_len: int = 96, *, kv_layout: str = "slotted",
                 block_size: int = 16, n_blocks: Optional[int] = None,
                 max_seqs: Optional[int] = None, chunk_blocks: int = 2,
                 prefix_caching: bool = True,
                 policy: Union[str, SchedulerPolicy, None] = "fifo",
                 preemption: bool = False, ci_g_per_kwh: float = 0.0,
                 telemetry: Optional[Telemetry] = None,
                 decode_pipeline: bool = True, fused_steps: int = 8,
                 quality_selector=None, mesh=None, roles=None):
        assert kv_layout in ("slotted", "paged"), kv_layout
        assert not (preemption and kv_layout == "slotted"), \
            "preemption requires the paged KV layout (slots never grow)"
        assert mesh is None or kv_layout == "paged", \
            "mesh sharding requires the paged KV layout"
        assert not roles, \
            "roles= is the DisaggEngine's (serving.disagg) — RealEngine " \
            "dispatches there via __new__; do not pass roles to a subclass"
        self.mesh = mesh
        self.family = {ev.variant.name: ev for ev in family}
        self.instances: List[Instance] = []
        self.n_slots = n_slots
        self.max_len = max_len
        self.kv_layout = kv_layout
        self.block_size = block_size
        # equal-arena default: the paged pool holds exactly the KV tokens the
        # slotted cache would reserve (n_slots × max_len), plus the junk block
        self.n_blocks = (n_blocks if n_blocks is not None
                         else -(-n_slots * max_len // block_size) + 1)
        self.max_seqs = max_seqs if max_seqs is not None else 4 * n_slots
        self.chunk_blocks = chunk_blocks
        self.prefix_caching = prefix_caching
        self.policy = make_policy(policy)
        # mixed-quality request path: the selector decides each request's
        # ladder rung at submit; admission then only places it on instances
        # of that variant (serving.quality — name, instance, or None)
        self.quality_selector = make_selector(quality_selector)
        self.preemption = preemption
        # decode hot path: ``decode_pipeline=False`` selects the synchronous
        # reference loop (re-upload + blocking readback every tick) — the
        # greedy-parity oracle; ``fused_steps`` bounds on-device step fusion
        self.decode_pipeline = decode_pipeline
        self.fused_steps = fused_steps
        self.ci_g_per_kwh = ci_g_per_kwh
        # optional unified-telemetry bundle: the engine repoints its
        # ``registry`` at every session open (per-session registries) and
        # emits lifecycle spans into its persistent ``tracer``; its ``feed``
        # receives one exact (wall, joules, grams) segment per session
        self.telemetry = telemetry
        # one engine-owned phase profiler shared by every instance; its
        # registry is repointed at each session open (and set to None when
        # no telemetry bundle is attached, so the un-instrumented hot path
        # stays a single attribute check)
        self.profiler = PhaseProfiler()
        self.last_registry: Optional[MetricsRegistry] = None
        self._feed_clock = 0.0           # feed-time seconds across sessions
        self._pool: Dict[Tuple[str, int], List[Instance]] = {}
        self._session: Optional[_Session] = None
        self._last_stats: Dict[str, float] = {}
        self.last_reconfig_s = 0.0
        self.last_admit_order: List[int] = []
        self.last_outputs: Dict[int, np.ndarray] = {}
        self.last_latencies: List[float] = []
        self.last_responses: List[InferenceResponse] = []

    def _new_instance(self, ev: EngineVariant, chips: int,
                      role: str = "both"):
        if self.kv_layout == "paged":
            return PagedInstance(ev, chips, n_blocks=self.n_blocks,
                                 block_size=self.block_size,
                                 max_seqs=self.max_seqs,
                                 max_len=self.max_len,
                                 chunk_blocks=self.chunk_blocks,
                                 prefix_caching=self.prefix_caching,
                                 preemption=self.preemption,
                                 policy=self.policy,
                                 pipeline=self.decode_pipeline,
                                 fused_steps=self.fused_steps,
                                 mesh=self.mesh, role=role)
        return Instance(ev, chips, self.n_slots, self.max_len)

    def configure(self, graph) -> float:
        """Apply a configuration graph; returns reconfig seconds (measured).

        Warm path: instances are returned to a (variant, chips) pool and
        reused — weights, KV arenas and compiled functions survive
        controller re-invocations; only genuinely new (variant, chips) pairs
        pay allocation + compile."""
        assert self._session is None, "configure during an open serve session"
        t0 = time.perf_counter()
        for inst in self.instances:
            self._pool.setdefault((inst.ev.variant.name, inst.chips),
                                  []).append(inst)
        self.instances = []
        for (vname, chips), w in graph.edges:
            for _ in range(w):
                warm = self._pool.get((vname, chips), [])
                if warm:
                    inst = warm.pop()
                    inst.reset()
                else:
                    inst = self._new_instance(self.family[vname], chips)
                    inst.warmup()
                inst.profiler = self.profiler
                self.instances.append(inst)
        self.last_reconfig_s = time.perf_counter() - t0
        return self.last_reconfig_s

    # --- disaggregation hooks (overridden by serving.disagg) -----------------
    def _profilers(self):
        """Every phase profiler the engine repoints per session."""
        return (self.profiler,)

    def _takes(self, inst, resuming: bool) -> bool:
        """Whether ``inst`` participates in admitting the queue head (the
        DisaggEngine routes fresh work to prefill workers and swapped-out
        images to decode workers; monolithic instances take everything)."""
        return True

    def _post_tick(self, completed: List[InferenceResponse]) -> None:
        """End-of-step hook: the DisaggEngine extracts finished prefills
        into ``BlockHandoff``s and places them on decode workers here."""

    def _extra_pending(self) -> bool:
        """Work the drain loop must wait on beyond queues and busy
        instances (the DisaggEngine's in-transit handoff queue)."""
        return False

    # --- ServingBackend protocol ---------------------------------------------
    def submit(self, req: InferenceRequest) -> None:
        """Enqueue a typed request.  The first submit after idle opens a
        session (t0 = now); ``arrival_s`` schedules an open-loop release
        relative to it."""
        assert self.instances, "configure() first"
        if self._session is None:
            reg = MetricsRegistry.standard(f"real-{self.kv_layout}",
                                           labels={"kv_layout":
                                                   self.kv_layout})
            tel = self.telemetry
            if tel is not None:
                tel.registry = reg       # per-session registry (see obs)
            # phase profiling rides the telemetry opt-in: without a bundle
            # the profiler stays disabled and the hot path pays nothing
            for prof in self._profilers():
                prof.registry = reg if tel is not None else None
            self.policy.reset_holds()    # rids repeat across sessions
            self._session = _Session(
                SchedulerCore(self.policy), self.instances, registry=reg,
                tracer=tel.tracer if tel is not None else None)
            self.last_registry = reg
            self.last_admit_order = []
            self.last_outputs = {}
            if self.quality_selector is not None:
                # bind the selector to the rungs this configuration can
                # actually serve (deduped by name, any instance count)
                ladder = {inst.ev.variant.name: inst.ev.variant
                          for inst in self.instances}
                self.quality_selector.reset(list(ladder.values()))
        s = self._session
        assert req.rid not in s.requests, f"duplicate rid {req.rid}"
        s.requests[req.rid] = req
        s.meters[req.rid] = 0.0
        if self.quality_selector is not None:
            dec = self.quality_selector.select(req)
            s.variant_of[req.rid] = dec.variant
        s.registry.counter("requests_submitted").inc()
        s.schedule(req)

    def step(self) -> List[InferenceResponse]:
        """One scheduler pass: release due arrivals, run policy admission
        over every instance (gated re-attempts), then one tick (≤ one
        prefill chunk burst + one batched decode step) per busy instance.
        Returns the requests that completed on this pass."""
        s = self._session
        if s is None:
            return []
        now = time.perf_counter()
        now_rel = s.rel(now)
        s.progressed = False
        completed: List[InferenceResponse] = []
        while s.future and s.future[0][0] <= now:
            t_arr, _, rid = heapq.heappop(s.future)
            req = s.requests[rid]
            s.core.submit(rid, t_arr, priority=req.priority,
                          deadline_s=req.deadline_s, slo=req.slo)
        # 1. admission: peek the policy's next choice and place it on the
        #    first instance with capacity (slots or blocks) — mid-flight, so
        #    rows/blocks freed by the previous tick's completions refill.
        #    A failed fit is GATED per instance: no re-attempt until the
        #    queue head or the instance's free capacity actually changes.
        for inst in self.instances:
            while True:
                nxt = s.core.peek_next(now_rel)
                if nxt is None:
                    break
                rid, t_arr = nxt
                # mixed-quality routing: the queue head only admits onto
                # instances of its decided rung (head-of-line blocking on a
                # variant-busy head is deliberate — identical on the DES).
                # Also keeps preempted swap images on their own variant.
                want = s.variant_of.get(rid)
                if want is not None and inst.ev.variant.name != want:
                    break
                # role routing (disagg): fresh work → prefill workers,
                # swapped/handed-off images → decode workers
                if not self._takes(inst, rid in s.swapped):
                    break
                sig = inst.admission_signature()
                if s.admit_gate.get(id(inst)) == (rid, sig):
                    break                # nothing changed since last failure
                req = s.requests[rid]
                swap = s.swapped.get(rid)
                fits = (inst.can_resume(swap) if swap is not None
                        else inst.can_admit(req.prompt_len,
                                            req.max_new_tokens))
                if not fits:
                    s.admit_gate[id(inst)] = (rid, sig)
                    break
                s.admit_gate.pop(id(inst), None)
                s.core.pop_next(now_rel)
                t1 = time.perf_counter()
                if swap is not None:
                    state, dt = inst.resume(swap)
                    del s.swapped[rid]
                    if s.tracer is not None:
                        t_res = s.rel(time.perf_counter())
                        sid = s.preempt_sids.pop(rid, None)
                        if sid is not None:
                            s.tracer.close_span(sid, t_res,
                                                pages=swap.n_blocks)
                        s.tracer.instant("swap_in", t_res, rid=rid)
                else:
                    state, dt = inst.admit_next(rid, t_arr, req.prompt,
                                                req.max_new_tokens,
                                                priority=req.priority,
                                                slo=req.slo,
                                                deadline_s=req.deadline_s)
                    s.admit_t[rid] = t1
                    s.queue_delays.append(t1 - t_arr)
                    s.admit_order.append(rid)
                    self.last_admit_order.append(rid)
                    if state.tokens and req.on_token is not None:
                        req.on_token(rid, state.tokens[0])   # slotted first
                    if s.tracer is not None:
                        s.tracer.instant("admit", s.rel(t1), rid=rid)
                if dt > 0:               # slotted layout prefills at admit
                    self.profiler.observe("prefill_chunk", dt)
                e_pf = inst.chips * PM.P_BUSY_W * dt   # prefill: busy power
                s.charge(inst.role, e_pf)
                s.meter(rid, inst.role, e_pf)
                s.accounted_s[id(inst)] += dt
                s.progressed = True
                if state.remaining <= 0 and state.tokens:    # n_new == 1
                    completed.append(self._finish(state, inst))
        # 2. one tick per busy instance (≤ 1 prefill burst + 1 decode)
        for inst in self.instances:
            if not inst.busy:
                continue
            s.progressed = True
            s.admitted_sum += inst.occupied   # holding cache memory now
            s.tick_samples += 1
            t_tick = time.perf_counter()
            # fused multi-step dispatch stays off while timed arrivals are
            # outstanding: an open-loop session measures admission latency,
            # and a k-step device window would delay a mid-window arrival's
            # prefill behind k queued decode steps
            done, info = inst.tick(s.rel(t_tick), allow_fused=not s.future)
            s.charge(inst.role, inst.chips * PM.P_BUSY_W * info["prefill_s"])
            for rid, dtc in info["prefill_rids"]:
                s.meter(rid, inst.role, inst.chips * PM.P_BUSY_W * dtc)
                inst.profiler.observe("prefill_chunk", dtc)
            if info["decode_steps"]:
                # info describes LANDED decode work: ``decode_steps`` model
                # steps (>= 1 per landed dispatch, k per fused dispatch)
                # sharing ``decode_s`` wall seconds — aggregates stay
                # step-weighted so occupancy/inflight means are comparable
                # across fused and single-step sessions
                ksteps = info["decode_steps"]
                occ = info["occupied"]
                e_dec = PM.instance_power_w(
                    inst.chips, occ / inst.capacity) * info["decode_s"]
                s.charge(inst.role, e_dec)
                share = e_dec / max(len(info["decode_rids"]), 1)
                for rid in info["decode_rids"]:
                    s.meter(rid, inst.role, share)
                s.decode_steps += ksteps
                s.occ_frac_sum += (occ / inst.capacity) * ksteps
                s.inflight_sum += occ * ksteps
            s.accounted_s[id(inst)] += info["prefill_s"] + info["decode_s"]
            s.blocks_peak = max(s.blocks_peak, int(info["blocks_in_use"]))
            s.registry.gauge("occupied_rows").set(info["occupied"])
            s.registry.gauge("blocks_in_use").set(info["blocks_in_use"])
            if s.tracer is not None:
                tr = s.tracer
                # chunks ran back-to-back from the tick start; the decode
                # step follows them — lay the spans out on that timeline
                cursor = s.rel(t_tick)
                for rid, dtc in info["prefill_rids"]:
                    tr.span("prefill_chunk", cursor, cursor + dtc, rid=rid)
                    cursor += dtc
                # one span per LANDED model step (a fused dispatch lands k
                # steps at once): ``decode_tick`` span count stays equal to
                # the session's ``decode_steps`` counter
                if info["decode_steps"]:
                    dt_step = info["decode_s"] / info["decode_steps"]
                    for _ in range(info["decode_steps"]):
                        tr.span("decode_tick", cursor, cursor + dt_step,
                                rids=info["decode_rids"], n=info["occupied"])
                        cursor += dt_step
                # memory/power pressure counter tracks on the engine track
                # (Perfetto renders them alongside the request spans): the
                # arena/slot occupancy plus this tick's instantaneous power
                # draw under the same model that charges the energy
                if info["decode_steps"]:
                    p_w = PM.instance_power_w(
                        inst.chips, info["occupied"] / inst.capacity)
                elif info["prefill_s"] > 0:
                    p_w = inst.chips * PM.P_BUSY_W
                else:
                    p_w = inst.chips * PM.P_IDLE_W
                tr.counter("blocks_in_use", cursor, info["blocks_in_use"])
                tr.counter("occupied_rows", cursor, info["occupied"])
                tr.counter("power_w", cursor, p_w)
            for rid, tok in info["emitted"]:
                cb = s.requests[rid].on_token
                if cb is not None:
                    cb(rid, tok)
            for swap in info["preempted"]:
                req = s.requests[swap.rid]
                s.swapped[swap.rid] = swap
                s.preempt_total += 1
                if s.tracer is not None:
                    t_sw = s.rel(time.perf_counter())
                    s.tracer.instant("swap_out", t_sw, rid=swap.rid,
                                     pages=swap.n_blocks)
                    s.preempt_sids[swap.rid] = s.tracer.open_span(
                        "preempted", t_sw, rid=swap.rid)
                s.core.requeue_front(swap.rid, swap.t_arrival,
                                     priority=req.priority,
                                     deadline_s=req.deadline_s,
                                     slo=req.slo)
            for state in done:
                completed.append(self._finish(state, inst))
        self._post_tick(completed)
        return completed

    def drain(self) -> List[InferenceResponse]:
        """Run the session to completion; returns every response.  Closes
        the session: the idle-power floor is spread across the responses,
        carbon is attributed at ``ci_g_per_kwh``, and ``stats()`` reports
        the aggregates."""
        s = self._session
        if s is None:
            return []
        stalled_once = False
        while s.future or s.core.has_pending() \
                or any(i.busy for i in self.instances) \
                or self._extra_pending():
            self.step()
            if s.progressed:
                stalled_once = False
                continue
            now = time.perf_counter()
            if s.future and not s.core.has_pending():
                # open-loop idle gap: nothing in flight, next arrival in
                # the future — sleep up to it instead of busy-spinning
                time.sleep(min(max(s.future[0][0] - now, 0.0), 0.01))
            elif s.core.has_pending():
                if s.core.peek_next(s.rel(now)) is None:
                    # policy hold (carbon-aware deferral): wait for the
                    # clock/CI to move, the queue is intentionally parked
                    time.sleep(0.001)
                elif not stalled_once:
                    # a policy hold may have crossed its release boundary
                    # in the gap between step()'s select and this peek —
                    # a releasable head is only a STALL if another full
                    # step still cannot place it
                    stalled_once = True
                else:
                    raise RuntimeError(
                        "admission stalled: head request fits no instance")
        self._finalize(s)
        return s.responses

    def stats(self) -> Dict[str, float]:
        """Aggregate metrics of the last drained session."""
        return dict(self._last_stats)

    # --- internals -----------------------------------------------------------
    def _finish(self, state, inst) -> InferenceResponse:
        s = self._session
        req = s.requests[state.rid]
        t_fin = time.perf_counter()
        s.core.complete(state.rid, state.t_arrival, t_fin,
                        inst.ev.variant.accuracy)
        self.last_outputs[state.rid] = np.asarray(state.tokens, np.int64)
        ttft = (state.t_first - state.t_arrival
                if state.t_first is not None else 0.0)
        if state.t_first is not None:
            s.ttfts.append(ttft)
        hold = self.policy.hold_info(state.rid)
        resp = InferenceResponse(
            rid=state.rid, tokens=np.asarray(state.tokens, np.int64),
            slo=req.slo, priority=req.priority, state=DONE,
            t_arrival=state.t_arrival - s.t0, t_finish=t_fin - s.t0,
            queue_delay_s=s.admit_t[state.rid] - state.t_arrival,
            ttft_s=ttft, latency_s=t_fin - state.t_arrival,
            energy_j=s.meters[state.rid], preemptions=state.preempts,
            accuracy=inst.ev.variant.accuracy,
            variant=inst.ev.variant.name, deadline_s=req.deadline_s,
            held_s=hold[1] - hold[0] if hold is not None else 0.0,
            release_reason=hold[2] if hold is not None else None)
        s.responses.append(resp)
        reg = s.registry
        reg.counter("requests_served").inc()
        reg.counter("tokens_generated").inc(resp.n_tokens)
        reg.histogram("latency_s").observe(resp.latency_s)
        reg.labeled("latency_s", slo_class=req.slo).observe(resp.latency_s)
        reg.histogram("queue_delay_s").observe(resp.queue_delay_s)
        if state.t_first is not None:
            reg.histogram("ttft_s").observe(ttft)
            reg.labeled("ttft_s", slo_class=req.slo).observe(ttft)
        reg.histogram("accuracy").observe(resp.accuracy)
        reg.labeled("accuracy", slo_class=req.slo).observe(resp.accuracy)
        if not resp.deadline_met:
            reg.counter("deadline_misses").inc()
        if hold is not None:
            reg.counter("holds_released").inc()
            reg.histogram("held_s").observe(resp.held_s)
        if s.tracer is not None:
            # the root lifecycle span, reconstructed now that the request's
            # bounds are known; _finalize annotates the final joules/grams
            # (the idle-floor share only exists at drain)
            sid = s.tracer.span(
                "request", state.t_arrival - s.t0, t_fin - s.t0,
                rid=state.rid, slo=req.slo, n_tokens=resp.n_tokens,
                queue_delay_s=resp.queue_delay_s,
                preemptions=state.preempts)
            s.span_ids[state.rid] = sid
            if hold is not None:
                s.tracer.span("hold", hold[0], hold[1], rid=state.rid,
                              reason=hold[2])
        return resp

    def _finalize(self, s: _Session) -> None:
        wall = time.perf_counter() - s.t0
        for inst in self.instances:       # idle floor for unaccounted wall
            idle_s = max(wall - s.accounted_s[id(inst)], 0.0)
            s.charge(inst.role, inst.chips * PM.P_IDLE_W * idle_s)
        # attribute the idle floor + carbon: per-request joules sum to the
        # engine total, gCO2 = joules × the serving window's intensity
        attributed = sum(r.energy_j for r in s.responses)
        idle_share = ((s.energy - attributed) / len(s.responses)
                      if s.responses else 0.0)
        # per-role idle remainders: whatever each role charged beyond its
        # metered per-request work (its idle floor + fp dust) spreads the
        # same way, so a response's energy_by_role sums to its energy_j and
        # role totals conserve against the session (disagg conservation)
        n_resp = max(len(s.responses), 1)
        role_rem = {
            role: (total - sum(mr.get(role, 0.0)
                               for mr in s.meters_role.values())) / n_resp
            for role, total in s.role_energy.items()}
        for r in s.responses:
            r.energy_j += idle_share
            mr = s.meters_role.get(r.rid, {})
            r.energy_by_role = {role: mr.get(role, 0.0) + rem
                                for role, rem in role_rem.items()}
            r.carbon_g = r.energy_j / 3.6e6 * self.ci_g_per_kwh
            if s.tracer is not None and r.rid in s.span_ids:
                s.tracer.annotate(s.span_ids[r.rid], energy_j=r.energy_j,
                                  carbon_g=r.carbon_g)
        self.last_latencies = s.core.latencies
        self.last_responses = s.responses
        # session deltas of the instances' lifetime counters (instances
        # survive warm reconfiguration)
        chunks = sum(getattr(i, "prefill_chunks", 0)
                     for i in self.instances) - s.chunks0
        hits = sum(getattr(i, "prefix_hit_tokens", 0)
                   for i in self.instances) - s.hits0
        copied = sum(getattr(i, "swapin_pages_copied", 0)
                     for i in self.instances) - s.swap_copied0
        saved = (sum(getattr(i, "swapin_pages_total", 0)
                     for i in self.instances) - s.swap_total0) - copied
        retraces = sum(getattr(i, "retraces", 0)
                       for i in self.instances) - s.retraces0
        syncs = sum(getattr(i, "host_syncs", 0)
                    for i in self.instances) - s.syncs0
        h2d = sum(getattr(i, "h2d_transfers", 0)
                  for i in self.instances) - s.h2d0
        dispatches = sum(getattr(i, "decode_dispatches", 0)
                         for i in self.instances) - s.dispatches0
        total_g = s.energy / 3.6e6 * self.ci_g_per_kwh
        # fold the session totals into the registry; ``_last_stats`` below
        # is a *view* over it (same samples + same nearest-rank percentile
        # as the legacy SchedulerCore path, so the numbers are identical)
        reg = s.registry
        reg.counter("energy_j").inc(s.energy)
        reg.counter("carbon_g").inc(total_g)
        reg.counter("decode_steps").inc(s.decode_steps)
        reg.counter("preemptions").inc(s.preempt_total)
        reg.counter("prefill_chunks").inc(chunks)
        reg.counter("prefix_hit_tokens").inc(hits)
        reg.counter("swapin_pages_copied").inc(copied)
        reg.counter("swapin_pages_saved").inc(saved)
        reg.counter("compile_retraces").inc(retraces)
        reg.counter("host_syncs").inc(syncs)
        reg.counter("h2d_transfers").inc(h2d)
        reg.counter("decode_dispatches").inc(dispatches)
        reg.counter("handoffs").inc(s.handoffs)
        reg.counter("handoff_pages").inc(s.handoff_pages)
        reg.gauge("wall_s").set(wall)
        served = int(reg.value("requests_served"))
        total_tokens = int(reg.value("tokens_generated"))
        lat = reg.histogram("latency_s")
        self._last_stats = {
            "served": served,
            "p50_s": lat.percentile(50.0),
            "p95_s": lat.percentile(95.0),
            "p99_s": lat.percentile(99.0),
            "mean_accuracy": reg.histogram("accuracy").mean,
            "energy_j": reg.value("energy_j"),
            "carbon_g": reg.value("carbon_g"),
            "wall_s": wall,
            "tokens": total_tokens,
            "tokens_per_s": total_tokens / max(wall, 1e-9),
            "j_per_token": s.energy / max(total_tokens, 1),
            "decode_steps": s.decode_steps,
            "mean_occupancy": (s.occ_frac_sum / s.decode_steps
                               if s.decode_steps else 0.0),
            "mean_inflight": (s.inflight_sum / s.decode_steps
                              if s.decode_steps else 0.0),
            # sequences holding cache memory per tick (decoding OR mid-
            # chunked-prefill) — the "sustained admitted concurrency" a
            # memory layout actually achieves on a given arena
            "mean_admitted": (s.admitted_sum / s.tick_samples
                              if s.tick_samples else 0.0),
            "queue_delay_p95_s":
                reg.histogram("queue_delay_s").percentile(95.0),
            "ttft_p95_s": reg.histogram("ttft_s").percentile(95.0),
            "blocks_peak": s.blocks_peak,
            "preemptions": s.preempt_total,
            "prefill_chunks": chunks,
            "prefix_hit_tokens": hits,
            # partial swap-in: pages a full restore would have copied vs
            # pages actually written back (the gap = tree-resident reuse)
            "swapin_pages_copied": copied,
            "partial_swapin_pages_saved": saved,
            "compile_retraces": retraces,
            # decode-hot-path traffic: blocking host round-trips, explicit
            # H2D uploads (event-driven only under pipelining), and jitted
            # decode dispatches (< decode_steps when fusion engaged)
            "host_syncs": syncs,
            "h2d_transfers": h2d,
            "decode_dispatches": dispatches,
            # disaggregation: sequences handed prefill→decode, pages moved,
            # and the per-role joules split (all zero on monolithic engines;
            # "both" carries the whole total there).  prefill + decode +
            # handoff + both == energy_j exactly — the conservation check
            # ``obs.validate.check_disagg_conservation`` enforces it.
            "handoffs": s.handoffs,
            "handoff_pages": s.handoff_pages,
            "prefill_energy_j": s.role_energy.get("prefill", 0.0),
            "decode_energy_j": s.role_energy.get("decode", 0.0),
            "handoff_energy_j": s.role_energy.get("handoff", 0.0),
            "both_energy_j": s.role_energy.get("both", 0.0),
        }
        if self.telemetry is not None and self.telemetry.feed is not None:
            # one exact segment per session: feed totals stay equal to the
            # engine's charged joules/grams with no re-derivation
            self.telemetry.feed.record_segment(self._feed_clock, wall,
                                               s.energy, total_g)
        self._feed_clock += wall
        self._session = None

    # --- bulk-prompt convenience ---------------------------------------------
    def _serve_prompts(self, prompts: Sequence[np.ndarray], n_new: int = 8,
                       arrival_s: Optional[Sequence[float]] = None
                       ) -> Dict[str, float]:
        """Method shorthand for :func:`serving.api.serve_prompts` — kept
        for ``serve_poisson`` and tests that only care about prompts; the
        public surface is the typed ``ServingBackend`` protocol (the
        ``serve(prompts=...)`` deprecation shim is gone)."""
        return serve_prompts(self, prompts, n_new, arrival_s)

    def serve_poisson(self, rate_rps: float, n_requests: int,
                      prompt_lens: Sequence[int] = (6,), n_new: int = 8,
                      seed: int = 0) -> Dict[str, float]:
        """Open-loop serving under Poisson arrivals at ``rate_rps``.

        Prompts cycle through ``prompt_lens`` (random tokens); inter-arrival
        gaps are exponential.  Returns the session stats plus the offered
        rate — at sub-saturation loads ``queue_delay_p95_s`` stays bounded,
        at saturation it grows with the run length."""
        rng = np.random.default_rng(seed)
        vocab = next(iter(self.family.values())).cfg.vocab_size
        prompts = [rng.integers(0, vocab,
                                size=(int(prompt_lens[i % len(prompt_lens)]),)
                                ).astype(np.int32)
                   for i in range(n_requests)]
        arrivals = np.cumsum(rng.exponential(1.0 / rate_rps, n_requests))
        m = self._serve_prompts(prompts, n_new=n_new,
                                arrival_s=arrivals.tolist())
        m["offered_rps"] = rate_rps
        return m
