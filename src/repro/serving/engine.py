"""Real-execution serving engine: hosts actual JAX model variants and serves
token-generation requests with measured wall-clock latencies.

This is the end-to-end validation path for Clover on this CPU container: the
variants are reduced-config LMs (a real quality ladder — fewer layers →
measurably lower loss of quality and lower latency/energy), instances map to
"slices" (on CPU every slice is the host device; the slice size feeds the
energy model), and the Clover controller drives reconfiguration exactly as it
would on a pod.  Examples/serve_clover.py runs the full loop.
"""
from __future__ import annotations

import dataclasses
import math
import time
from typing import Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import perf_model as PM
from repro.core.catalog import Variant
from repro.models import registry as R
from repro.models.config import ModelConfig


def latency_percentile(lats: Sequence[float], q: float) -> float:
    """Percentile of a latency sample with correct rank rounding.

    Nearest-rank on the sorted sample: rank = ceil(q/100 · n), clamped to
    [1, n] — so p50 of [1, 2, 3, 4] is 2 (not 3, as naive ``n//2`` indexing
    gives) and p95 never reads past the end of the list."""
    if not lats:
        return float("nan")
    s = sorted(lats)
    rank = math.ceil(q / 100.0 * len(s))
    return s[min(max(rank, 1), len(s)) - 1]


@dataclasses.dataclass
class EngineVariant:
    variant: Variant
    cfg: ModelConfig
    params: dict


def build_engine_family(base_cfg: ModelConfig, fracs=(1.0, 0.5, 0.25),
                        seed: int = 0) -> List[EngineVariant]:
    """Instantiate a real quality ladder by depth reduction."""
    out = []
    for i, frac in enumerate(sorted(fracs)):
        n_layers = max(int(base_cfg.n_layers * frac), 1)
        cfg = base_cfg.with_(n_layers=n_layers,
                             name=f"{base_cfg.name}-x{frac:g}")
        params = R.init_params(jax.random.PRNGKey(seed), cfg)
        n_params = sum(int(np.prod(x.shape)) for x in jax.tree.leaves(params))
        v = Variant(family=base_cfg.name, name=f"x{frac:g}", quality=i + 1,
                    accuracy=0.80 + 0.05 * i, flops_g=n_params * 2 / 1e9,
                    params_m=n_params / 1e6, mem_gb=n_params * 4 / 2**30 + 0.1)
        out.append(EngineVariant(v, cfg, params))
    return out


class Instance:
    """One serving instance: jitted prefill + decode for its variant."""

    def __init__(self, ev: EngineVariant, chips: int):
        self.ev = ev
        self.chips = chips
        cfg = ev.cfg
        self._decode = jax.jit(
            lambda p, c, t: R.decode_step(p, c, {"tokens": t}, cfg))
        self._prefill = jax.jit(
            lambda p, t: R.forward(p, {"tokens": t}, cfg)[0])

    def generate(self, prompt: np.ndarray, n_new: int = 8) -> Tuple[np.ndarray, float]:
        """Greedy generation; returns (tokens, wall seconds)."""
        t0 = time.perf_counter()
        cfg = self.ev.cfg
        b = prompt.shape[0]
        logits = self._prefill(self.ev.params, jnp.asarray(prompt))
        cache = R.make_cache(self.ev.params, cfg, b,
                             prompt.shape[1] + n_new, dtype=jnp.float32)
        # replay prompt through the cache (teacher forcing), then generate
        for t in range(prompt.shape[1]):
            lg, cache = self._decode(self.ev.params, cache, jnp.asarray(prompt[:, t:t + 1]))
        toks = [int(jnp.argmax(lg[0]))]
        for _ in range(n_new - 1):
            lg, cache = self._decode(self.ev.params, cache,
                                     jnp.asarray([[toks[-1]]], dtype=jnp.int32))
            toks.append(int(jnp.argmax(lg[0])))
        dt = time.perf_counter() - t0
        return np.array(toks), dt


class RealEngine:
    """Maps a ConfigGraph onto real instances and serves requests FIFO,
    measuring wall latencies and estimating energy via the slice power model
    (CPU wall time × slice power — the calibrated stand-in for TPU telemetry)."""

    def __init__(self, family: Sequence[EngineVariant]):
        self.family = {ev.variant.name: ev for ev in family}
        self.instances: List[Instance] = []

    def configure(self, graph) -> float:
        """Apply a configuration graph; returns reconfig seconds (measured)."""
        t0 = time.perf_counter()
        self.instances = []
        for (vname, chips), w in graph.edges:
            for _ in range(w):
                self.instances.append(Instance(self.family[vname], chips))
        return time.perf_counter() - t0

    def serve(self, prompts: Sequence[np.ndarray], n_new: int = 8
              ) -> Dict[str, float]:
        """Round-robin the prompts across instances; returns metrics."""
        assert self.instances, "configure() first"
        lats, accs, energy = [], [], 0.0
        for i, p in enumerate(prompts):
            inst = self.instances[i % len(self.instances)]
            _, dt = inst.generate(p, n_new)
            lats.append(dt)
            accs.append(inst.ev.variant.accuracy)
            energy += inst.chips * PM.P_BUSY_W * dt
        return {
            "served": len(prompts),
            "p50_s": latency_percentile(lats, 50.0),
            "p95_s": latency_percentile(lats, 95.0),
            "p99_s": latency_percentile(lats, 99.0),
            "mean_accuracy": float(np.mean(accs)),
            "energy_j": energy,
        }
