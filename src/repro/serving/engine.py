"""Real-execution serving engine: continuous batching over slotted KV caches.

This is the end-to-end validation path for Clover on this CPU container: the
variants are reduced-config LMs (a real quality ladder — fewer layers →
measurably lower quality and lower latency/energy), instances map to "slices"
(on CPU every slice is the host device; the slice size feeds the energy
model), and the Clover controller drives reconfiguration exactly as it would
on a pod.  Examples/serve_clover.py runs the full loop.

Serving architecture (vs. the original batch-1 engine):

  * every ``Instance`` owns a fixed-capacity **slotted KV cache**
    (``models.registry.make_slot_cache``): ``n_slots`` independent sequences,
    each with its own valid-prefix ``lengths[i]`` — the same masking contract
    as ``kernels/decode_attention.py`` (``kernels/ref.py`` is the CPU path);
  * **prefill populates the cache in ONE forward pass**
    (``registry.prefill_kv``) and the prompt's last-position logits yield the
    first generated token — no teacher-forcing replay, no discarded prefill
    compute;
  * **decode is a single jitted batched step over all occupied slots**
    (``registry.decode_slots``); free slots ride along (static shapes for
    jit) but never advance;
  * the serve loop is **event-driven continuous batching**: requests admit
    into free slots mid-flight through the FIFO admission core shared with
    the DES (``serving.scheduler.SchedulerCore``), so a finishing slot is
    refilled while its neighbours keep decoding;
  * **energy is accounted per decode step from the occupied-slot count**
    (``PM.instance_power_w(chips, occupied / n_slots)``), not from
    whole-instance wall time — a half-empty batch draws less than a full
    one.  Prefill is charged at full busy power (the forward saturates the
    slice);
  * ``configure`` is **warm**: instances are pooled by (variant, chips) and
    jitted prefill/decode functions live on the ``EngineVariant`` — a
    controller re-invocation that returns to a previous configuration reuses
    weights, caches and compiled functions instead of rebuilding.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import perf_model as PM
from repro.core.catalog import Variant
from repro.models import registry as R
from repro.models.config import ModelConfig
from repro.serving.scheduler import SchedulerCore, latency_percentile

__all__ = ["latency_percentile", "EngineVariant", "build_engine_family",
           "Instance", "RealEngine"]


@dataclasses.dataclass
class EngineVariant:
    variant: Variant
    cfg: ModelConfig
    params: dict
    # jitted entry points, shared by every Instance of this variant (warm
    # reconfiguration: re-instantiating an instance never re-traces)
    fns: dict = dataclasses.field(default_factory=dict, repr=False)


def build_engine_family(base_cfg: ModelConfig, fracs=(1.0, 0.5, 0.25),
                        seed: int = 0) -> List[EngineVariant]:
    """Instantiate a real quality ladder by depth reduction."""
    out = []
    for i, frac in enumerate(sorted(fracs)):
        n_layers = max(int(base_cfg.n_layers * frac), 1)
        cfg = base_cfg.with_(n_layers=n_layers,
                             name=f"{base_cfg.name}-x{frac:g}")
        params = R.init_params(jax.random.PRNGKey(seed), cfg)
        n_params = sum(int(np.prod(x.shape)) for x in jax.tree.leaves(params))
        v = Variant(family=base_cfg.name, name=f"x{frac:g}", quality=i + 1,
                    accuracy=0.80 + 0.05 * i, flops_g=n_params * 2 / 1e9,
                    params_m=n_params / 1e6, mem_gb=n_params * 4 / 2**30 + 0.1)
        out.append(EngineVariant(v, cfg, params))
    return out


def _write_slot(cache_k, cache_v, lengths, k_all, v_all, slot, true_len):
    """Write one prefill's K/V into a slot and set its length (jitted so the
    two cache updates fuse into one dispatch; slot/true_len stay traced)."""
    cache_k = jax.lax.dynamic_update_slice(cache_k, k_all, (0, slot, 0, 0, 0))
    cache_v = jax.lax.dynamic_update_slice(cache_v, v_all, (0, slot, 0, 0, 0))
    return cache_k, cache_v, lengths.at[slot].set(true_len)


def _variant_fns(ev: EngineVariant) -> dict:
    """Jitted prefill/decode for one variant, built once and cached on the
    EngineVariant (jax's jit cache then handles per-shape specialisation)."""
    if not ev.fns:
        cfg = ev.cfg
        ev.fns["prefill"] = jax.jit(
            lambda p, t: R.prefill_kv(p, {"tokens": t}, cfg))
        ev.fns["decode"] = jax.jit(
            lambda p, c, t, a: R.decode_slots(p, c, {"tokens": t}, cfg, a))
        ev.fns["write"] = jax.jit(_write_slot)
    return ev.fns


def _bucket(n: int) -> int:
    """Prompt padding bucket (next power of two, floor 8) so prefill jit
    specialisations stay bounded as prompt lengths vary."""
    b = 8
    while b < n:
        b *= 2
    return b


@dataclasses.dataclass
class _SlotState:
    """Host-side request state of one occupied slot."""
    rid: int
    t_arrival: float
    remaining: int                 # decode steps still to run
    tokens: List[int]              # generated token ids (prefill token first)


class Instance:
    """One serving instance: a slotted batched KV cache plus the variant's
    shared jitted one-pass prefill and batched decode step."""

    def __init__(self, ev: EngineVariant, chips: int, n_slots: int = 4,
                 max_len: int = 96):
        self.ev = ev
        self.chips = chips
        self.n_slots = n_slots
        self.max_len = max_len
        self._fns = _variant_fns(ev)
        self.cache = R.make_slot_cache(ev.cfg, n_slots, max_len,
                                       dtype=jnp.float32)
        self.slots: List[Optional[_SlotState]] = [None] * n_slots
        self._next = np.zeros((n_slots, 1), np.int32)   # next decode token

    # --- lifecycle -----------------------------------------------------------
    def reset(self) -> None:
        """Recycle from the warm pool: clear per-slot state.  Cache contents
        are stale but masked out (lengths = 0) until the next prefill."""
        self.cache["lengths"] = jnp.zeros((self.n_slots,), jnp.int32)
        self.slots = [None] * self.n_slots
        self._next[:] = 0

    def warmup(self) -> None:
        """Trigger jit compilation — prefill at EVERY prompt bucket this
        instance can admit, plus one decode step — so cold ``configure``
        bears the compile cost, not the first served request (a probe
        window's measured p95 must never include a trace)."""
        b = 8
        while True:
            dummy = np.zeros((1, b), np.int32)
            lg, k_all, v_all = self._fns["prefill"](self.ev.params,
                                                    jnp.asarray(dummy))
            lg.block_until_ready()
            w = min(b, self.max_len)
            # zero-write into slot 0 at length 0: compiles the slot writer
            # for this bucket without touching logical state
            self.cache["k"], self.cache["v"], self.cache["lengths"] = \
                self._fns["write"](self.cache["k"], self.cache["v"],
                                   self.cache["lengths"], k_all[:, :, :w],
                                   v_all[:, :, :w], 0, 0)
            if b >= self.max_len:
                break
            b *= 2
        logits, _ = self._fns["decode"](
            self.ev.params, self.cache, jnp.asarray(self._next),
            jnp.zeros((self.n_slots,), bool))
        logits.block_until_ready()

    # --- slot management -----------------------------------------------------
    def free_slots(self) -> List[int]:
        return [i for i, s in enumerate(self.slots) if s is None]

    @property
    def occupied(self) -> int:
        return sum(1 for s in self.slots if s is not None)

    # --- serving -------------------------------------------------------------
    def admit(self, slot: int, rid: int, t_arrival: float,
              prompt: np.ndarray, n_new: int) -> _SlotState:
        """One-pass prefill of ``prompt`` into ``slot``.  The prompt's
        last-position logits yield the first generated token immediately —
        the prefill forward is never discarded."""
        prompt = np.asarray(prompt, np.int32).reshape(-1)
        true_len = int(prompt.shape[0])
        assert true_len + n_new <= self.max_len, \
            f"prompt {true_len} + n_new {n_new} > max_len {self.max_len}"
        pad = _bucket(true_len)
        padded = np.zeros((1, pad), np.int32)
        padded[0, :true_len] = prompt
        logits, k_all, v_all = self._fns["prefill"](self.ev.params,
                                                    jnp.asarray(padded))
        write = min(pad, self.max_len)   # padded tail beyond capacity is junk
        self.cache["k"], self.cache["v"], self.cache["lengths"] = \
            self._fns["write"](self.cache["k"], self.cache["v"],
                               self.cache["lengths"], k_all[:, :, :write],
                               v_all[:, :, :write], slot, true_len)
        first = int(jnp.argmax(logits[0, true_len - 1]))
        state = _SlotState(rid, t_arrival, remaining=n_new - 1,
                           tokens=[first])
        self._next[slot, 0] = first
        if state.remaining > 0:
            self.slots[slot] = state
        return state

    def step(self) -> List[_SlotState]:
        """One batched decode step over ALL slots; returns the requests that
        completed on this step (their slots are freed for mid-flight
        admission)."""
        active = np.array([s is not None for s in self.slots])
        logits, self.cache = self._fns["decode"](
            self.ev.params, self.cache, jnp.asarray(self._next),
            jnp.asarray(active))
        toks = np.asarray(jnp.argmax(logits, axis=-1))
        finished: List[_SlotState] = []
        for i, s in enumerate(self.slots):
            if s is None:
                continue
            s.tokens.append(int(toks[i]))
            s.remaining -= 1
            self._next[i, 0] = int(toks[i])
            if s.remaining <= 0:
                finished.append(s)
                self.slots[i] = None
        return finished

    def generate(self, prompt: np.ndarray, n_new: int = 8
                 ) -> Tuple[np.ndarray, float]:
        """Greedy generation for a (possibly batched) prompt.

        prompt: (b, s) int32.  Returns (tokens (b, n_new), wall seconds).
        One-pass prefill + batched decode; each row takes its own argmax
        (the old engine hard-coded ``lg[0]`` and a scalar token feed, so
        every row beyond the first decoded row 0's tokens)."""
        t0 = time.perf_counter()
        prompt = np.asarray(prompt, np.int32)
        b, s = prompt.shape
        fns = self._fns
        logits, k_all, v_all = fns["prefill"](self.ev.params,
                                              jnp.asarray(prompt))
        max_len = s + n_new
        K, dh = self.ev.cfg.n_kv_heads, self.ev.cfg.d_head
        L = self.ev.cfg.n_layers
        cache = {
            "k": jnp.zeros((L, b, max_len, K, dh), jnp.float32
                           ).at[:, :, :s].set(k_all.astype(jnp.float32)),
            "v": jnp.zeros((L, b, max_len, K, dh), jnp.float32
                           ).at[:, :, :s].set(v_all.astype(jnp.float32)),
            "lengths": jnp.full((b,), s, jnp.int32),
        }
        active = jnp.ones((b,), bool)
        tok = jnp.argmax(logits[:, s - 1], axis=-1)          # (b,) per-row
        out = [tok]
        for _ in range(n_new - 1):
            lg, cache = fns["decode"](self.ev.params, cache,
                                      tok[:, None].astype(jnp.int32), active)
            tok = jnp.argmax(lg, axis=-1)
            out.append(tok)
        toks = np.asarray(jnp.stack(out, axis=1))
        return toks, time.perf_counter() - t0


class RealEngine:
    """Maps a ConfigGraph onto real instances and serves requests with
    continuous batching, measuring wall latencies and estimating energy via
    the slice power model scaled by slot occupancy (the calibrated stand-in
    for TPU telemetry)."""

    def __init__(self, family: Sequence[EngineVariant], n_slots: int = 4,
                 max_len: int = 96):
        self.family = {ev.variant.name: ev for ev in family}
        self.instances: List[Instance] = []
        self.n_slots = n_slots
        self.max_len = max_len
        self._pool: Dict[Tuple[str, int], List[Instance]] = {}
        self.last_reconfig_s = 0.0
        self.last_admit_order: List[int] = []
        self.last_outputs: Dict[int, np.ndarray] = {}
        self.last_latencies: List[float] = []

    def configure(self, graph) -> float:
        """Apply a configuration graph; returns reconfig seconds (measured).

        Warm path: instances are returned to a (variant, chips) pool and
        reused — weights, slot caches and compiled functions survive
        controller re-invocations; only genuinely new (variant, chips) pairs
        pay allocation + compile."""
        t0 = time.perf_counter()
        for inst in self.instances:
            self._pool.setdefault((inst.ev.variant.name, inst.chips),
                                  []).append(inst)
        self.instances = []
        for (vname, chips), w in graph.edges:
            for _ in range(w):
                warm = self._pool.get((vname, chips), [])
                if warm:
                    inst = warm.pop()
                    inst.reset()
                else:
                    inst = Instance(self.family[vname], chips,
                                    self.n_slots, self.max_len)
                    inst.warmup()
                self.instances.append(inst)
        self.last_reconfig_s = time.perf_counter() - t0
        return self.last_reconfig_s

    def serve(self, prompts: Sequence[np.ndarray], n_new: int = 8
              ) -> Dict[str, float]:
        """Continuous-batching serve: FIFO admission into free slots
        mid-flight (shared ``SchedulerCore``), one batched decode step per
        instance per scheduler tick, per-step occupancy-scaled energy."""
        assert self.instances, "configure() first"
        core = SchedulerCore()
        t0 = time.perf_counter()
        payload: Dict[int, np.ndarray] = {}
        for i, p in enumerate(prompts):
            core.submit(i, t0)
            payload[i] = np.asarray(p, np.int32).reshape(-1)
        self.last_admit_order = []
        self.last_outputs = {}
        energy = 0.0
        decode_steps = 0
        occ_sum = 0
        # wall seconds already charged per instance (prefill + decode); the
        # remainder of the serve wall is charged at idle power below, so an
        # allocated-but-idle instance is never free (same convention as the
        # DES's idle_chip_s accounting)
        accounted_s = {id(i): 0.0 for i in self.instances}

        def finish(state: _SlotState, inst: Instance) -> None:
            core.complete(state.rid, state.t_arrival, time.perf_counter(),
                          inst.ev.variant.accuracy)
            self.last_outputs[state.rid] = np.asarray(state.tokens, np.int64)

        while core.has_pending() or any(i.occupied for i in self.instances):
            # 1. admission: fill every free slot FIFO (mid-flight — slots
            #    freed by the previous tick's completions refill here)
            for inst in self.instances:
                for slot in inst.free_slots():
                    nxt = core.pop_next()
                    if nxt is None:
                        break
                    rid, t_arr = nxt
                    t1 = time.perf_counter()
                    state = inst.admit(slot, rid, t_arr, payload[rid], n_new)
                    dt = time.perf_counter() - t1
                    energy += inst.chips * PM.P_BUSY_W * dt   # prefill: busy
                    accounted_s[id(inst)] += dt
                    self.last_admit_order.append(rid)
                    if state.remaining <= 0:                  # n_new == 1
                        finish(state, inst)
            # 2. one batched decode step per occupied instance
            for inst in self.instances:
                occ = inst.occupied
                if occ == 0:
                    continue
                t1 = time.perf_counter()
                done = inst.step()
                dt = time.perf_counter() - t1
                energy += PM.instance_power_w(inst.chips,
                                              occ / inst.n_slots) * dt
                accounted_s[id(inst)] += dt
                decode_steps += 1
                occ_sum += occ
                for state in done:
                    finish(state, inst)

        wall = time.perf_counter() - t0
        for inst in self.instances:       # idle floor for unaccounted wall
            idle_s = max(wall - accounted_s[id(inst)], 0.0)
            energy += inst.chips * PM.P_IDLE_W * idle_s
        self.last_latencies = core.latencies
        served = core.served
        total_tokens = served * n_new
        return {
            "served": served,
            "p50_s": core.percentile(50.0),
            "p95_s": core.percentile(95.0),
            "p99_s": core.percentile(99.0),
            "mean_accuracy": core.acc_weighted / max(served, 1),
            "energy_j": energy,
            "wall_s": wall,
            "tokens": total_tokens,
            "tokens_per_s": total_tokens / max(wall, 1e-9),
            "j_per_token": energy / max(total_tokens, 1),
            "decode_steps": decode_steps,
            "mean_occupancy": (occ_sum / decode_steps / self.n_slots
                               if decode_steps else 0.0),
        }
