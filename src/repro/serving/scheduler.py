"""Shared scheduler core for the serving layer (paper §4.3 load balancer).

Both serving paths need the same admission machinery and used to duplicate
it: the analytic DES (``serving.queue``) and the real-execution
continuous-batching engine (``serving.engine.RealEngine``).  This module is
the single implementation both build on:

  * an admission queue with lazy completion skipping (a hedged or re-queued
    request may already be done by the time it reaches the head);
  * **pluggable ordering** (``serving.policies.SchedulerPolicy``): entries
    carry priority / deadline / SLO-class metadata and the policy picks the
    next admission — or holds the queue (carbon-aware deferral).  Without a
    policy (or with FIFO) the core runs its original deque fast path,
    bit-identical to the pre-policy behavior;
  * first-completion-wins bookkeeping (hedges dispatch duplicates; only the
    first finish records a latency and an accuracy credit);
  * hedge / fail-repair / preemption requeue counters;
  * nearest-rank latency percentiles (the correct rank rounding — p50 of
    [1, 2, 3, 4] is 2, and p95 never indexes past the end of the sample).

The DES drives it from a simulated-time event heap; the real engine drives
it from wall-clock decode steps.  Neither knows about the other's notion of
time — the core only ever receives timestamps, and passes ``now`` through
to the policy for deadline/CI decisions.
"""
from __future__ import annotations

import dataclasses
import math
from collections import deque
from typing import Deque, Dict, List, Optional, Sequence, Tuple

from repro.serving.policies import SchedulerPolicy


def latency_percentile(lats: Sequence[float], q: float) -> float:
    """Percentile of a latency sample with correct rank rounding.

    Nearest-rank on the sorted sample: rank = ceil(q/100 · n), clamped to
    [1, n] — so p50 of [1, 2, 3, 4] is 2 (not 3, as naive ``n//2`` indexing
    gives) and p95 never reads past the end of the list."""
    if not lats:
        return float("nan")
    s = sorted(lats)
    rank = math.ceil(q / 100.0 * len(s))
    return s[min(max(rank, 1), len(s)) - 1]


@dataclasses.dataclass
class _Entry:
    """One queued admission: request id + the metadata policies order by.
    ``seq`` is a monotonic submission counter — the stable FIFO tie-break
    within a priority level / deadline."""
    rid: int
    t_arrival: float
    seq: int
    priority: int = 0
    deadline_s: Optional[float] = None
    slo: str = "interactive"


class SchedulerCore:
    """Admission queue + completion/hedge/requeue bookkeeping.

    Queue entries are ``(request id, arrival time)`` plus policy metadata;
    the payload (prompt, analytic work size, …) stays with the caller,
    keyed by request id.  ``policy=None`` (or any ``is_fifo`` policy) keeps
    the original FIFO deque semantics exactly."""

    def __init__(self, policy: Optional[SchedulerPolicy] = None):
        self.policy = policy
        self._fifo = policy is None or getattr(policy, "is_fifo", False)
        self._queue: Deque[_Entry] = deque()
        self._seq = 0
        self.done: Dict[int, bool] = {}
        self.latencies: List[float] = []
        self.acc_weighted: float = 0.0
        self.served: int = 0
        self.hedges: int = 0
        self.requeues: int = 0

    # --- admission -----------------------------------------------------------
    def submit(self, rid: int, t_arrival: float, *, priority: int = 0,
               deadline_s: Optional[float] = None,
               slo: str = "interactive") -> None:
        """Enqueue a new request at the tail (submission order is the FIFO
        order and every policy's tie-break)."""
        self._queue.append(_Entry(rid, t_arrival, self._seq, priority,
                                  deadline_s, slo))
        self._seq += 1

    def _prune(self) -> None:
        """Drop completed entries.  FIFO only ever needs the head pruned
        (original lazy behavior); policies scan the whole queue, so stale
        interior entries must go before selection."""
        if self._fifo:
            while self._queue and self.done.get(self._queue[0].rid):
                self._queue.popleft()
        else:
            if any(self.done.get(e.rid) for e in self._queue):
                self._queue = deque(e for e in self._queue
                                    if not self.done.get(e.rid))

    def _select(self, now: Optional[float]) -> Optional[int]:
        """Index of the next admission under the policy, or None (empty
        queue, or the policy is holding everything)."""
        self._prune()
        if not self._queue:
            return None
        if self._fifo:
            return 0
        return self.policy.select(list(self._queue), now)

    def pop_next(self, now: Optional[float] = None
                 ) -> Optional[Tuple[int, float]]:
        """Next admission under the policy, or None.  Entries whose request
        already completed (hedge duplicates, stale requeues) are dropped on
        the way — the caller never sees them."""
        idx = self._select(now)
        if idx is None:
            return None
        if idx == 0:
            e = self._queue.popleft()
        else:
            e = self._queue[idx]
            del self._queue[idx]
        return e.rid, e.t_arrival

    def peek_next(self, now: Optional[float] = None
                  ) -> Optional[Tuple[int, float]]:
        """The next admission WITHOUT popping it — admission control that
        depends on the request (does this prompt fit the instance's free
        blocks?) peeks first and only pops once a home is found, so a
        temporarily unadmittable request keeps its queue position."""
        idx = self._select(now)
        if idx is None:
            return None
        e = self._queue[idx]
        return e.rid, e.t_arrival

    def has_pending(self) -> bool:
        """Live entries remain (the policy may still be HOLDING them all —
        ``peek_next`` returning None distinguishes a hold from empty)."""
        self._prune()
        return bool(self._queue)

    # --- priority re-entry ---------------------------------------------------
    def hedge_front(self, rid: int, t_arrival: float, *, priority: int = 0,
                    deadline_s: Optional[float] = None,
                    slo: str = "interactive") -> None:
        """Duplicate a slow in-flight request at the head of the queue; the
        first completion wins (the duplicate's finish becomes a no-op).
        Metadata must match the original submission or a policy would
        mis-order the duplicate (e.g. EDF sorting a deadline-less twin
        behind every deadlined entry)."""
        self._queue.appendleft(_Entry(rid, t_arrival, -self._seq, priority,
                                      deadline_s, slo))
        self._seq += 1
        self.hedges += 1

    def requeue_front(self, rid: int, t_arrival: float, *, priority: int = 0,
                      deadline_s: Optional[float] = None,
                      slo: str = "interactive") -> None:
        """Re-queue a request lost to an instance failure — or swapped out
        by a preemption — at the head (no request loss, original arrival
        time preserved for its latency).  The negative ``seq`` keeps it
        ahead of every same-key entry under any policy's tie-break."""
        self._queue.appendleft(_Entry(rid, t_arrival, -self._seq, priority,
                                      deadline_s, slo))
        self._seq += 1
        self.requeues += 1

    # --- completion ----------------------------------------------------------
    def complete(self, rid: int, t_arrival: float, now: float,
                 accuracy: float = 0.0) -> bool:
        """Record a finish.  Returns True for the first completion of ``rid``
        (latency + accuracy recorded), False for hedge duplicates."""
        if self.done.get(rid):
            return False
        self.done[rid] = True
        self.latencies.append(now - t_arrival)
        self.acc_weighted += accuracy
        self.served += 1
        return True

    # --- stats ---------------------------------------------------------------
    def percentile(self, q: float) -> float:
        return latency_percentile(self.latencies, q) if self.latencies else 0.0
