"""Shared scheduler core for the serving layer (paper §4.3 load balancer).

Both serving paths need the same admission machinery and used to duplicate
it: the analytic DES (``serving.queue.run_des``) and the real-execution
continuous-batching engine (``serving.engine.RealEngine``).  This module is
the single implementation both build on:

  * a FIFO admission queue with lazy completion skipping (a hedged or
    re-queued request may already be done by the time it reaches the head);
  * first-completion-wins bookkeeping (hedges dispatch duplicates; only the
    first finish records a latency and an accuracy credit);
  * hedge / fail-repair requeue counters;
  * nearest-rank latency percentiles (the correct rank rounding — p50 of
    [1, 2, 3, 4] is 2, and p95 never indexes past the end of the sample).

The DES drives it from a simulated-time event heap; the real engine drives
it from wall-clock decode steps.  Neither knows about the other's notion of
time — the core only ever receives timestamps.
"""
from __future__ import annotations

import dataclasses
import math
from collections import deque
from typing import Deque, Dict, List, Optional, Sequence, Tuple


def latency_percentile(lats: Sequence[float], q: float) -> float:
    """Percentile of a latency sample with correct rank rounding.

    Nearest-rank on the sorted sample: rank = ceil(q/100 · n), clamped to
    [1, n] — so p50 of [1, 2, 3, 4] is 2 (not 3, as naive ``n//2`` indexing
    gives) and p95 never reads past the end of the list."""
    if not lats:
        return float("nan")
    s = sorted(lats)
    rank = math.ceil(q / 100.0 * len(s))
    return s[min(max(rank, 1), len(s)) - 1]


@dataclasses.dataclass
class SchedulerCore:
    """FIFO admission queue + completion/hedge/requeue bookkeeping.

    Queue entries are ``(request id, arrival time)``; the payload (prompt,
    analytic work size, …) stays with the caller, keyed by request id."""

    _queue: Deque[Tuple[int, float]] = dataclasses.field(default_factory=deque)
    done: Dict[int, bool] = dataclasses.field(default_factory=dict)
    latencies: List[float] = dataclasses.field(default_factory=list)
    acc_weighted: float = 0.0
    served: int = 0
    hedges: int = 0
    requeues: int = 0

    # --- admission -----------------------------------------------------------
    def submit(self, rid: int, t_arrival: float) -> None:
        """Enqueue a new request at the tail (FIFO order = arrival order)."""
        self._queue.append((rid, t_arrival))

    def pop_next(self) -> Optional[Tuple[int, float]]:
        """Head-of-line request that is still live, or None.  Entries whose
        request already completed (hedge duplicates, stale requeues) are
        dropped on the way — the caller never sees them."""
        while self._queue:
            rid, t_arr = self._queue.popleft()
            if not self.done.get(rid):
                return rid, t_arr
        return None

    def peek_next(self) -> Optional[Tuple[int, float]]:
        """Head-of-line live request WITHOUT popping it — admission control
        that depends on the request (does this prompt fit the instance's
        free blocks?) peeks first and only pops once a home is found, so a
        temporarily unadmittable request keeps its FIFO position."""
        return self._queue[0] if self.has_pending() else None

    def has_pending(self) -> bool:
        while self._queue and self.done.get(self._queue[0][0]):
            self._queue.popleft()
        return bool(self._queue)

    # --- priority re-entry ---------------------------------------------------
    def hedge_front(self, rid: int, t_arrival: float) -> None:
        """Duplicate a slow in-flight request at the head of the queue; the
        first completion wins (the duplicate's finish becomes a no-op)."""
        self._queue.appendleft((rid, t_arrival))
        self.hedges += 1

    def requeue_front(self, rid: int, t_arrival: float) -> None:
        """Re-queue a request lost to an instance failure at the head (no
        request loss, original arrival time preserved for its latency)."""
        self._queue.appendleft((rid, t_arrival))
        self.requeues += 1

    # --- completion ----------------------------------------------------------
    def complete(self, rid: int, t_arrival: float, now: float,
                 accuracy: float = 0.0) -> bool:
        """Record a finish.  Returns True for the first completion of ``rid``
        (latency + accuracy recorded), False for hedge duplicates."""
        if self.done.get(rid):
            return False
        self.done[rid] = True
        self.latencies.append(now - t_arrival)
        self.acc_weighted += accuracy
        self.served += 1
        return True

    # --- stats ---------------------------------------------------------------
    def percentile(self, q: float) -> float:
        return latency_percentile(self.latencies, q) if self.latencies else 0.0
