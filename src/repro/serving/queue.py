"""Per-request discrete-event serving queue (paper §4.3 load balancer).

Producer/consumer FIFO exactly as the paper describes: requests enter a FIFO
queue; whenever an instance finishes it notifies the consumer, which feeds it
the head-of-line request.  Extensions for scale (DESIGN.md §5 fault
tolerance):

  * lognormal service-time jitter + a heavy straggler tail;
  * hedged requests: if a request has been in service longer than
    ``hedge_factor × p95`` of that instance's nominal latency, a duplicate is
    dispatched to the next free instance and the first completion wins;
  * fail/repair: instances fail (Poisson) and respawn after a repair time;
    their in-flight request is re-queued at the head (no loss).

Used by tests (validates the fluid simulator on short horizons), by
benchmarks for short-span exact replays, and by the real-execution engine
(which substitutes measured service times).  The admission machinery
(done-skipping queue, first-completion-wins, hedge/requeue counters,
pluggable :mod:`repro.serving.policies`) lives in
``serving.scheduler.SchedulerCore``, shared with the real engine.

Two surfaces: :func:`run_des` is the closed-form rate-driven simulation the
fluid-model validation uses; :class:`DESBackend` exposes the same event
machinery through the unified ``ServingBackend`` protocol
(``serving.api``) — typed ``InferenceRequest``s in, per-request responses
with attributed energy/carbon out, any scheduling policy in between.
"""
from __future__ import annotations

import dataclasses
import heapq
import math
import random
from typing import Callable, Dict, List, Optional, Sequence, Tuple, Union

from repro.core import config_graph as CG
from repro.core import perf_model as PM
from repro.core.catalog import Variant
from repro.obs import MetricsRegistry, Telemetry
from repro.serving.api import DONE, InferenceRequest, InferenceResponse
from repro.serving.policies import SchedulerPolicy, make_policy
from repro.serving.quality import make_selector
from repro.serving.scheduler import SchedulerCore, latency_percentile


@dataclasses.dataclass
class DESConfig:
    jitter_sigma: float = 0.08          # lognormal sigma on service times
    straggler_prob: float = 0.0         # P[service time × straggler_mult]
    straggler_mult: float = 8.0
    hedge: bool = False
    hedge_factor: float = 3.0
    fail_rate_per_instance_hz: float = 0.0
    repair_time_s: float = 30.0
    seed: int = 0


@dataclasses.dataclass
class DESResult:
    latencies: List[float]
    accuracy_weighted: float
    served: int
    energy_j: float
    hedges: int
    failures: int
    requeues: int

    def _pct(self, q: float) -> float:
        return latency_percentile(self.latencies, q) if self.latencies else 0.0

    def p50(self) -> float:
        return self._pct(50.0)

    def p95(self) -> float:
        return self._pct(95.0)

    def p99(self) -> float:
        return self._pct(99.0)

    def mean_accuracy(self) -> float:
        return self.accuracy_weighted / max(self.served, 1)


class _Instance:
    __slots__ = ("idx", "variant", "chips", "nominal", "busy", "alive",
                 "busy_until", "current")

    def __init__(self, idx: int, variant: Variant, chips: int, nominal: float):
        self.idx = idx
        self.variant = variant
        self.chips = chips
        self.nominal = nominal
        self.busy = False
        self.alive = True
        self.busy_until = 0.0
        self.current: Optional[Tuple[int, float]] = None   # (req id, start)


def run_des(g: CG.ConfigGraph, variants: Sequence[Variant],
            arrival_rps: float, horizon_s: float,
            des: DESConfig = DESConfig(),
            service_time_fn: Optional[Callable] = None) -> DESResult:
    """Event-driven simulation of one configuration for ``horizon_s``."""
    rng = random.Random(des.seed)
    by_name = {v.name: v for v in variants}
    instances: List[_Instance] = []
    for (vname, chips), w in g.edges:
        v = by_name[vname]
        sp = PM.cached_point(v, chips)
        for _ in range(w):
            instances.append(_Instance(len(instances), v, chips, sp.latency_s))

    def sample_service(inst: _Instance) -> float:
        if service_time_fn is not None:
            return service_time_fn(inst.variant, inst.chips)
        t = inst.nominal * math.exp(rng.gauss(0.0, des.jitter_sigma))
        if des.straggler_prob and rng.random() < des.straggler_prob:
            t *= des.straggler_mult
        return t

    # event heap: (time, seq, kind, payload)
    ARRIVE, FINISH, FAIL, REPAIR, HEDGE_CHECK = range(5)
    heap: List[Tuple[float, int, int, tuple]] = []
    seq = 0

    def push(t, kind, payload):
        nonlocal seq
        heapq.heappush(heap, (t, seq, kind, payload))
        seq += 1

    push(rng.expovariate(arrival_rps), ARRIVE, ())
    for inst in instances:
        if des.fail_rate_per_instance_hz > 0:
            push(rng.expovariate(des.fail_rate_per_instance_hz), FAIL, (inst.idx,))

    core = SchedulerCore()
    req_id = 0
    energy = 0.0
    failures = 0

    def dispatch(now: float):
        nonlocal energy
        free = [i for i in instances if i.alive and not i.busy]
        for inst in free:
            nxt = core.pop_next()
            if nxt is None:
                break
            rid, t_arr = nxt
            svc = sample_service(inst)
            inst.busy = True
            inst.busy_until = now + svc
            inst.current = (rid, t_arr)
            energy += inst.chips * PM.P_BUSY_W * svc
            push(now + svc, FINISH, (inst.idx, rid, t_arr))
            if des.hedge:
                push(now + inst.nominal * des.hedge_factor, HEDGE_CHECK,
                     (inst.idx, rid, t_arr))

    while heap:
        now, _, kind, payload = heapq.heappop(heap)
        if now > horizon_s:
            break
        if kind == ARRIVE:
            core.submit(req_id, now)
            req_id += 1
            push(now + rng.expovariate(arrival_rps), ARRIVE, ())
            dispatch(now)
        elif kind == FINISH:
            idx, rid, t_arr = payload
            inst = instances[idx]
            if inst.current and inst.current[0] == rid and inst.alive:
                inst.busy = False
                inst.current = None
                core.complete(rid, t_arr, now, inst.variant.accuracy)
                dispatch(now)
        elif kind == HEDGE_CHECK:
            idx, rid, t_arr = payload
            if not core.done.get(rid) and instances[idx].current \
                    and instances[idx].current[0] == rid:
                core.hedge_front(rid, t_arr)     # duplicate at head of queue
                dispatch(now)
        elif kind == FAIL:
            (idx,) = payload
            inst = instances[idx]
            if inst.alive:
                inst.alive = False
                failures += 1
                if inst.current is not None:     # re-queue in-flight work
                    rid, t_arr = inst.current
                    if not core.done.get(rid):
                        core.requeue_front(rid, t_arr)
                    inst.current = None
                    inst.busy = False
                push(now + des.repair_time_s, REPAIR, (idx,))
        elif kind == REPAIR:
            (idx,) = payload
            instances[idx].alive = True
            if des.fail_rate_per_instance_hz > 0:
                push(now + rng.expovariate(des.fail_rate_per_instance_hz),
                     FAIL, (idx,))
            dispatch(now)

    # total = busy chip-seconds at P_BUSY + remaining chip-seconds at P_IDLE
    busy_j = energy
    busy_chip_s = busy_j / PM.P_BUSY_W
    idle_chip_s = max(g.total_chips * horizon_s - busy_chip_s, 0.0)
    energy = busy_j + idle_chip_s * PM.P_IDLE_W

    return DESResult(core.latencies, core.acc_weighted, core.served, energy,
                     core.hedges, failures, core.requeues)


# =============================================================================
# ServingBackend protocol over the DES (unified request/response API)
# =============================================================================
class DESBackend:
    """Per-request discrete-event simulation behind the unified
    ``ServingBackend`` protocol (``serving.api``).

    The same typed :class:`~repro.serving.api.InferenceRequest` workload the
    real engine executes runs here analytically: arrivals release on the
    SIMULATED clock (``arrival_s``), a pluggable
    :class:`~repro.serving.policies.SchedulerPolicy` orders admissions
    through the shared :class:`SchedulerCore`, service time is the
    instance's nominal latency scaled by the request's decode budget
    (lognormal jitter from :class:`DESConfig`), and responses carry the
    same per-request attribution contract: busy joules charged to the
    request that burned them, the idle floor spread across the session's
    responses at drain, ``carbon_g = joules × ci_g_per_kwh``.

    Tokens are never generated (``response.tokens is None``) — this backend
    answers scheduling questions (policy orderings, deadline attainment,
    carbon accounting) six orders of magnitude faster than real execution.

    ``ci_g_per_kwh`` may be a constant or a ``ci(now) → gCO2/kWh`` callable
    on the simulated clock (e.g. ``trace.at``): with a time-varying grid a
    request's busy joules are attributed at the CI of its own service
    midpoint and the idle floor at the session-mean CI — so holding work
    into a cleaner window (the carbon policies' whole point) is visible in
    per-request ``carbon_g``, and the responses still sum exactly to
    ``stats()['carbon_g']``.
    """

    _ARRIVE, _FINISH = 0, 1

    def __init__(self, g: CG.ConfigGraph, variants: Sequence[Variant],
                 des: DESConfig = DESConfig(),
                 policy: Union[str, SchedulerPolicy, None] = "fifo",
                 ci_g_per_kwh: Union[float, Callable[[float], float]] = 0.0,
                 tokens_ref: int = 8,
                 hold_retry_s: float = 60.0,
                 telemetry: Optional[Telemetry] = None,
                 quality_selector=None):
        self.g = g
        self.des = des
        self.policy = make_policy(policy)
        self.policy.reset_holds()
        # single-session backend: one registry for its whole life; the
        # tracer (if any) is the caller's persistent recorder
        self.telemetry = telemetry
        self.registry = MetricsRegistry.standard("des")
        if telemetry is not None:
            telemetry.registry = self.registry
        self.tracer = telemetry.tracer if telemetry is not None else None
        self._span_ids: Dict[int, int] = {}     # rid → "request" span sid
        self.ci_g_per_kwh = ci_g_per_kwh
        self.tokens_ref = tokens_ref       # decode budget the nominal maps to
        self.hold_retry_s = hold_retry_s   # clock hop when the policy parks
                                           # the whole queue (carbon hold)
        self._rng = random.Random(des.seed)
        by_name = {v.name: v for v in variants}
        self._instances: List[_Instance] = []
        for (vname, chips), w in g.edges:
            v = by_name[vname]
            sp = PM.cached_point(v, chips)
            for _ in range(w):
                self._instances.append(
                    _Instance(len(self._instances), v, chips, sp.latency_s))
        # mixed-quality request path (serving.quality): decisions at submit
        # on the request's arrival clock, dispatch matches the decided rung
        self.quality_selector = make_selector(quality_selector)
        self._variant_of: Dict[int, str] = {}
        if self.quality_selector is not None:
            ladder = {i.variant.name: i.variant for i in self._instances}
            self.quality_selector.reset(list(ladder.values()))
        self.core = SchedulerCore(self.policy)
        self.now = 0.0
        self._heap: List[Tuple[float, int, int, tuple]] = []
        self._seq = 0
        self._reqs: Dict[int, InferenceRequest] = {}
        self._meters: Dict[int, float] = {}
        self._carbon: Dict[int, float] = {}      # busy gCO2 at service-time CI
        self._starts: Dict[int, float] = {}
        self._responses: List[InferenceResponse] = []   # step's delta buffer
        self._done: List[InferenceResponse] = []        # whole session
        self._busy_j = 0.0
        self._stats: Dict[str, float] = {}

    # --- protocol ------------------------------------------------------------
    def submit(self, req: InferenceRequest) -> None:
        assert req.rid not in self._reqs, f"duplicate rid {req.rid}"
        self._reqs[req.rid] = req
        self._meters[req.rid] = 0.0
        self._carbon[req.rid] = 0.0
        if self.quality_selector is not None:
            dec = self.quality_selector.select(req)
            self._variant_of[req.rid] = dec.variant
        self.registry.counter("requests_submitted").inc()
        self._push(req.arrival_s or 0.0, self._ARRIVE, (req.rid,))

    # --- carbon intensity ----------------------------------------------------
    def _ci_at(self, t: float) -> float:
        ci = self.ci_g_per_kwh
        return float(ci(t)) if callable(ci) else float(ci)

    def _ci_mean(self, t_end: float) -> float:
        """Session-mean CI for the idle floor (trapezoid over the session
        span; exact for a constant grid)."""
        if not callable(self.ci_g_per_kwh):
            return float(self.ci_g_per_kwh)
        if t_end <= 0.0:
            return self._ci_at(0.0)
        import numpy as _np
        ts = _np.linspace(0.0, t_end, 65)
        return float(_np.trapezoid([self._ci_at(float(t)) for t in ts], ts)
                     / t_end)

    def step(self) -> List[InferenceResponse]:
        """Process one event off the heap (advancing the simulated clock).
        When the heap is empty but the policy still parks live work (carbon
        hold), the clock hops ``hold_retry_s`` forward and re-dispatches —
        time passing is what changes the policy's mind."""
        if not self._heap:
            if self.core.has_pending():
                self.now += self.hold_retry_s
                self._dispatch()
            return self._drain_completed()
        t, _, kind, payload = heapq.heappop(self._heap)
        self.now = max(self.now, t)
        if kind == self._ARRIVE:
            (rid,) = payload
            req = self._reqs[rid]
            self.core.submit(rid, self.now, priority=req.priority,
                             deadline_s=req.deadline_s, slo=req.slo)
            self._dispatch()
        elif kind == self._FINISH:
            idx, rid, t_arr = payload
            inst = self._instances[idx]
            if inst.current and inst.current[0] == rid:
                inst.busy = False
                inst.current = None
                self._complete(rid, t_arr, inst)
                self._dispatch()
        return self._drain_completed()

    def drain(self) -> List[InferenceResponse]:
        """Run every submitted request to completion and return ALL of the
        session's responses (including ones a prior ``step`` already
        handed out — the idle floor and carbon attribution must cover the
        whole session, not just the drain-collected tail)."""
        while self._heap or self.core.has_pending() \
                or any(i.busy for i in self._instances):
            self.step()
        self._finalize(self._done)
        return list(self._done)

    def stats(self) -> Dict[str, float]:
        return dict(self._stats)

    # --- internals -----------------------------------------------------------
    def _push(self, t: float, kind: int, payload: tuple) -> None:
        heapq.heappush(self._heap, (t, self._seq, kind, payload))
        self._seq += 1

    def _service_s(self, inst: _Instance, req: InferenceRequest) -> float:
        base = inst.nominal * (req.max_new_tokens / self.tokens_ref)
        if self.des.jitter_sigma > 0:
            base *= math.exp(self._rng.gauss(0.0, self.des.jitter_sigma))
        return base

    def _assign(self, inst: _Instance, rid: int, t_arr: float) -> None:
        req = self._reqs[rid]
        svc = self._service_s(inst, req)
        inst.busy = True
        inst.current = (rid, t_arr)
        self._starts[rid] = self.now
        busy_j = inst.chips * PM.P_BUSY_W * svc
        self._meters[rid] += busy_j
        self._carbon[rid] += busy_j / 3.6e6 * self._ci_at(self.now
                                                          + 0.5 * svc)
        self._busy_j += busy_j
        self._push(self.now + svc, self._FINISH, (inst.idx, rid, t_arr))

    def _dispatch(self) -> None:
        if self.quality_selector is not None:
            # mixed-quality routing: the queue head only runs on instances
            # of its decided rung; a variant-busy head blocks the line —
            # the same head-of-line discipline as the real engine's
            # admission loop, so decision → placement replays identically
            while True:
                nxt = self.core.peek_next(self.now)
                if nxt is None:
                    break
                rid, t_arr = nxt
                want = self._variant_of.get(rid)
                inst = next(
                    (i for i in self._instances
                     if i.alive and not i.busy
                     and (want is None or i.variant.name == want)), None)
                if inst is None:
                    break
                self.core.pop_next(self.now)
                self._assign(inst, rid, t_arr)
            return
        for inst in self._instances:
            if inst.busy or not inst.alive:
                continue
            nxt = self.core.pop_next(self.now)
            if nxt is None:
                break
            rid, t_arr = nxt
            self._assign(inst, rid, t_arr)

    def _complete(self, rid: int, t_arr: float, inst: _Instance) -> None:
        req = self._reqs[rid]
        self.core.complete(rid, t_arr, self.now, inst.variant.accuracy)
        start = self._starts.get(rid, t_arr)
        hold = self.policy.hold_info(rid)
        resp = InferenceResponse(
            rid=rid, tokens=None, slo=req.slo, priority=req.priority,
            state=DONE, t_arrival=t_arr, t_finish=self.now,
            queue_delay_s=start - t_arr, ttft_s=self.now - t_arr,
            latency_s=self.now - t_arr, energy_j=self._meters[rid],
            accuracy=inst.variant.accuracy, variant=inst.variant.name,
            deadline_s=req.deadline_s,
            held_s=hold[1] - hold[0] if hold is not None else 0.0,
            release_reason=hold[2] if hold is not None else None)
        self._responses.append(resp)
        self._done.append(resp)
        reg = self.registry
        reg.counter("requests_served").inc()
        reg.histogram("latency_s").observe(resp.latency_s)
        reg.labeled("latency_s", slo_class=req.slo).observe(resp.latency_s)
        reg.histogram("queue_delay_s").observe(resp.queue_delay_s)
        reg.histogram("ttft_s").observe(resp.ttft_s)
        reg.labeled("ttft_s", slo_class=req.slo).observe(resp.ttft_s)
        reg.histogram("accuracy").observe(resp.accuracy)
        reg.labeled("accuracy", slo_class=req.slo).observe(resp.accuracy)
        if not resp.deadline_met:
            reg.counter("deadline_misses").inc()
        if hold is not None:
            reg.counter("holds_released").inc()
            reg.histogram("held_s").observe(resp.held_s)
        if self.tracer is not None:
            tr = self.tracer
            self._span_ids[rid] = tr.span(
                "request", t_arr, self.now, rid=rid, slo=req.slo,
                queue_delay_s=resp.queue_delay_s, n_tokens=0)
            tr.span("service", start, self.now, rid=rid,
                    instance=inst.idx, variant=inst.variant.name)
            if hold is not None:
                tr.span("hold", hold[0], hold[1], rid=rid, reason=hold[2])

    def _drain_completed(self) -> List[InferenceResponse]:
        out, self._responses = self._responses, []
        return out

    def _finalize(self, responses: List[InferenceResponse]) -> None:
        idle_chip_s = max(self.g.total_chips * self.now
                          - self._busy_j / PM.P_BUSY_W, 0.0)
        idle_j = idle_chip_s * PM.P_IDLE_W
        total_j = self._busy_j + idle_j
        share = idle_j / len(responses) if responses else 0.0
        idle_g = idle_j / 3.6e6 * self._ci_mean(self.now)
        share_g = idle_g / len(responses) if responses else 0.0
        for r in responses:
            r.energy_j += share
            # busy gCO2 at each dispatch's service-midpoint CI + an equal
            # share of the idle floor at session-mean CI; for a constant
            # grid this is exactly energy_j × ci
            r.carbon_g = self._carbon.get(r.rid, 0.0) + share_g
            if self.tracer is not None and r.rid in self._span_ids:
                self.tracer.annotate(self._span_ids[r.rid],
                                     energy_j=r.energy_j,
                                     carbon_g=r.carbon_g)
        carbon_total = sum(r.carbon_g for r in responses)
        core = self.core
        reg = self.registry
        reg.counter("energy_j").inc(total_j)
        reg.counter("carbon_g").inc(carbon_total)
        reg.gauge("wall_s").set(self.now)
        if self.telemetry is not None and self.telemetry.feed is not None:
            self.telemetry.feed.record_segment(0.0, self.now, total_j,
                                               carbon_total)
        self._stats = {
            "served": core.served,
            "p50_s": reg.histogram("latency_s").percentile(50.0),
            "p95_s": reg.histogram("latency_s").percentile(95.0),
            "p99_s": reg.histogram("latency_s").percentile(99.0),
            "mean_accuracy": core.acc_weighted / max(core.served, 1),
            "energy_j": reg.value("energy_j"),
            "carbon_g": reg.value("carbon_g"),
            "carbon_g_per_req": carbon_total / max(core.served, 1),
            "wall_s": self.now,
            "deadline_misses": int(reg.value("deadline_misses")),
            "preemptions": 0,
        }
