"""Per-request discrete-event serving queue (paper §4.3 load balancer).

Producer/consumer FIFO exactly as the paper describes: requests enter a FIFO
queue; whenever an instance finishes it notifies the consumer, which feeds it
the head-of-line request.  Extensions for scale (DESIGN.md §5 fault
tolerance):

  * lognormal service-time jitter + a heavy straggler tail;
  * hedged requests: if a request has been in service longer than
    ``hedge_factor × p95`` of that instance's nominal latency, a duplicate is
    dispatched to the next free instance and the first completion wins;
  * fail/repair: instances fail (Poisson) and respawn after a repair time;
    their in-flight request is re-queued at the head (no loss).

Used by tests (validates the fluid simulator on short horizons), by
benchmarks for short-span exact replays, and by the real-execution engine
(which substitutes measured service times).  The FIFO admission machinery
(done-skipping queue, first-completion-wins, hedge/requeue counters) lives
in ``serving.scheduler.SchedulerCore``, shared with the real engine.
"""
from __future__ import annotations

import dataclasses
import heapq
import math
import random
from typing import Callable, List, Optional, Sequence, Tuple

from repro.core import config_graph as CG
from repro.core import perf_model as PM
from repro.core.catalog import Variant
from repro.serving.scheduler import SchedulerCore, latency_percentile


@dataclasses.dataclass
class DESConfig:
    jitter_sigma: float = 0.08          # lognormal sigma on service times
    straggler_prob: float = 0.0         # P[service time × straggler_mult]
    straggler_mult: float = 8.0
    hedge: bool = False
    hedge_factor: float = 3.0
    fail_rate_per_instance_hz: float = 0.0
    repair_time_s: float = 30.0
    seed: int = 0


@dataclasses.dataclass
class DESResult:
    latencies: List[float]
    accuracy_weighted: float
    served: int
    energy_j: float
    hedges: int
    failures: int
    requeues: int

    def _pct(self, q: float) -> float:
        return latency_percentile(self.latencies, q) if self.latencies else 0.0

    def p50(self) -> float:
        return self._pct(50.0)

    def p95(self) -> float:
        return self._pct(95.0)

    def p99(self) -> float:
        return self._pct(99.0)

    def mean_accuracy(self) -> float:
        return self.accuracy_weighted / max(self.served, 1)


class _Instance:
    __slots__ = ("idx", "variant", "chips", "nominal", "busy", "alive",
                 "busy_until", "current")

    def __init__(self, idx: int, variant: Variant, chips: int, nominal: float):
        self.idx = idx
        self.variant = variant
        self.chips = chips
        self.nominal = nominal
        self.busy = False
        self.alive = True
        self.busy_until = 0.0
        self.current: Optional[Tuple[int, float]] = None   # (req id, start)


def run_des(g: CG.ConfigGraph, variants: Sequence[Variant],
            arrival_rps: float, horizon_s: float,
            des: DESConfig = DESConfig(),
            service_time_fn: Optional[Callable] = None) -> DESResult:
    """Event-driven simulation of one configuration for ``horizon_s``."""
    rng = random.Random(des.seed)
    by_name = {v.name: v for v in variants}
    instances: List[_Instance] = []
    for (vname, chips), w in g.edges:
        v = by_name[vname]
        sp = PM.cached_point(v, chips)
        for _ in range(w):
            instances.append(_Instance(len(instances), v, chips, sp.latency_s))

    def sample_service(inst: _Instance) -> float:
        if service_time_fn is not None:
            return service_time_fn(inst.variant, inst.chips)
        t = inst.nominal * math.exp(rng.gauss(0.0, des.jitter_sigma))
        if des.straggler_prob and rng.random() < des.straggler_prob:
            t *= des.straggler_mult
        return t

    # event heap: (time, seq, kind, payload)
    ARRIVE, FINISH, FAIL, REPAIR, HEDGE_CHECK = range(5)
    heap: List[Tuple[float, int, int, tuple]] = []
    seq = 0

    def push(t, kind, payload):
        nonlocal seq
        heapq.heappush(heap, (t, seq, kind, payload))
        seq += 1

    push(rng.expovariate(arrival_rps), ARRIVE, ())
    for inst in instances:
        if des.fail_rate_per_instance_hz > 0:
            push(rng.expovariate(des.fail_rate_per_instance_hz), FAIL, (inst.idx,))

    core = SchedulerCore()
    req_id = 0
    energy = 0.0
    failures = 0

    def dispatch(now: float):
        nonlocal energy
        free = [i for i in instances if i.alive and not i.busy]
        for inst in free:
            nxt = core.pop_next()
            if nxt is None:
                break
            rid, t_arr = nxt
            svc = sample_service(inst)
            inst.busy = True
            inst.busy_until = now + svc
            inst.current = (rid, t_arr)
            energy += inst.chips * PM.P_BUSY_W * svc
            push(now + svc, FINISH, (inst.idx, rid, t_arr))
            if des.hedge:
                push(now + inst.nominal * des.hedge_factor, HEDGE_CHECK,
                     (inst.idx, rid, t_arr))

    while heap:
        now, _, kind, payload = heapq.heappop(heap)
        if now > horizon_s:
            break
        if kind == ARRIVE:
            core.submit(req_id, now)
            req_id += 1
            push(now + rng.expovariate(arrival_rps), ARRIVE, ())
            dispatch(now)
        elif kind == FINISH:
            idx, rid, t_arr = payload
            inst = instances[idx]
            if inst.current and inst.current[0] == rid and inst.alive:
                inst.busy = False
                inst.current = None
                core.complete(rid, t_arr, now, inst.variant.accuracy)
                dispatch(now)
        elif kind == HEDGE_CHECK:
            idx, rid, t_arr = payload
            if not core.done.get(rid) and instances[idx].current \
                    and instances[idx].current[0] == rid:
                core.hedge_front(rid, t_arr)     # duplicate at head of queue
                dispatch(now)
        elif kind == FAIL:
            (idx,) = payload
            inst = instances[idx]
            if inst.alive:
                inst.alive = False
                failures += 1
                if inst.current is not None:     # re-queue in-flight work
                    rid, t_arr = inst.current
                    if not core.done.get(rid):
                        core.requeue_front(rid, t_arr)
                    inst.current = None
                    inst.busy = False
                push(now + des.repair_time_s, REPAIR, (idx,))
        elif kind == REPAIR:
            (idx,) = payload
            instances[idx].alive = True
            if des.fail_rate_per_instance_hz > 0:
                push(now + rng.expovariate(des.fail_rate_per_instance_hz),
                     FAIL, (idx,))
            dispatch(now)

    # total = busy chip-seconds at P_BUSY + remaining chip-seconds at P_IDLE
    busy_j = energy
    busy_chip_s = busy_j / PM.P_BUSY_W
    idle_chip_s = max(g.total_chips * horizon_s - busy_chip_s, 0.0)
    energy = busy_j + idle_chip_s * PM.P_IDLE_W

    return DESResult(core.latencies, core.acc_weighted, core.served, energy,
                     core.hedges, failures, core.requeues)
