"""Unified request/response serving API (the request-centric surface).

Clover's runtime is fundamentally request-centric — SLA attainment, accuracy
mix and carbon are all properties of individual requests flowing through the
system — yet the execution paths historically exposed three incompatible
surfaces: the real engine took bare token lists, the DES took synthetic rate
parameters, and the fluid simulator took aggregate RPS.  This module is the
one surface all of them serve:

  * :class:`InferenceRequest` — a typed request: prompt tokens, decode
    budget, SLO class (interactive vs deferrable), priority, deadline,
    arrival time on the backend's clock, and an optional per-token stream
    callback;
  * :class:`InferenceResponse` — the full per-request account: generated
    tokens, queue delay, TTFT, end-to-end latency, **attributed energy and
    carbon** (occupancy-weighted tick energy × the serving window's carbon
    intensity), and the preemption count;
  * :class:`ServingBackend` — the ``submit / step / drain / stats`` protocol
    implemented by ``RealEngine`` (both KV layouts), the per-request DES
    (``serving.queue.DESBackend``) and the fluid-window model
    (``serving.backends.FluidBackend``), so the fleet layer and the Clover
    controller drive all three through one interface.

Backends own their clocks: the real engine measures wall seconds, the DES
and the fluid model advance simulated seconds.  ``arrival_s`` and
``deadline_s`` are expressed on that backend clock, relative to the start of
the serve session.  This module is deliberately jax-free (numpy only) so the
fleet layer can build workloads without touching the device stack.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, List, Optional, Protocol, Sequence, \
    runtime_checkable

import numpy as np

__all__ = ["INTERACTIVE", "DEFERRABLE", "QUEUED", "RUNNING", "PREEMPTED",
           "DONE", "InferenceRequest", "InferenceResponse", "ServingBackend",
           "serve_workload", "serve_prompts", "summarize_responses"]

# SLO classes (paper's two-class workload: tail-latency vs deadline)
INTERACTIVE = "interactive"
DEFERRABLE = "deferrable"

# request lifecycle states:  QUEUED → RUNNING → DONE, with RUNNING →
# PREEMPTED → QUEUED when a paged engine swaps a victim out under
# decode-time block pressure (the K/V pages move to host memory and the
# request re-enters the queue; on re-admission they are restored bit-exactly
# so greedy outputs are preemption-invariant)
QUEUED = "queued"
RUNNING = "running"
PREEMPTED = "preempted"
DONE = "done"


@dataclasses.dataclass
class InferenceRequest:
    """One inference request on a backend's clock.

    ``arrival_s`` is the release time relative to the serve session start
    (None = visible immediately); ``deadline_s`` is an absolute completion
    deadline on the same clock (only EDF / carbon-aware policies read it).
    ``on_token`` is invoked as ``on_token(rid, token)`` for every generated
    token as the engine emits it — real backends stream, analytic backends
    (DES / fluid) never call it.

    Mixed-quality serving (``serving.quality``): ``min_accuracy`` is a hard
    per-request floor — a quality selector never places the request on a
    variant whose accuracy proxy falls below it; ``quality_hint`` pins the
    request to a named ladder rung when that rung is available (the
    "Greening AI Inference" per-request quality-class API shape).  Both are
    ignored by a backend running without a selector."""
    rid: int
    prompt: np.ndarray
    max_new_tokens: int = 8
    slo: str = INTERACTIVE
    priority: int = 0                  # larger = more important
    deadline_s: Optional[float] = None
    arrival_s: Optional[float] = None
    on_token: Optional[Callable[[int, int], None]] = None
    min_accuracy: Optional[float] = None   # hard per-request accuracy floor
    quality_hint: Optional[str] = None     # pin to a named variant if present

    def __post_init__(self) -> None:
        self.prompt = np.asarray(self.prompt, np.int32).reshape(-1)
        assert self.max_new_tokens >= 1, "need at least one generated token"
        assert self.slo in (INTERACTIVE, DEFERRABLE), self.slo
        assert self.min_accuracy is None or 0.0 <= self.min_accuracy <= 1.0

    @property
    def prompt_len(self) -> int:
        return int(self.prompt.shape[0])


@dataclasses.dataclass
class InferenceResponse:
    """Per-request outcome, including the attributed energy/carbon account.

    ``energy_j`` is the request's share of every tick it held resources for
    (decode tick energy split over the occupant rows, prefill charged to the
    prefilling request, plus an equal share of the session's idle floor);
    summed over a session's responses it equals the backend's total energy.
    ``carbon_g`` is that energy × the backend's serving-window carbon
    intensity (gCO2/kWh)."""
    rid: int
    tokens: Optional[np.ndarray]       # None for analytic backends (DES/fluid)
    slo: str = INTERACTIVE
    priority: int = 0
    state: str = DONE
    t_arrival: float = 0.0             # backend-clock timestamps
    t_finish: float = 0.0
    queue_delay_s: float = 0.0         # arrival → first admission
    ttft_s: float = 0.0                # arrival → first generated token
    latency_s: float = 0.0             # arrival → completion
    energy_j: float = 0.0
    carbon_g: float = 0.0
    preemptions: int = 0
    accuracy: float = 0.0              # the SERVED variant's accuracy proxy
    variant: Optional[str] = None      # ladder rung the request actually ran
                                       # on (None when no selector routed it)
    deadline_s: Optional[float] = None
    held_s: float = 0.0                # policy-hold portion of queue_delay_s
    release_reason: Optional[str] = None   # "valley"/"threshold"/"runway"
    # role-split joules (serving.disagg): {"prefill": J, "decode": J,
    # "handoff": J} on a disaggregated engine ({"both": J} monolithic);
    # values sum to energy_j, so per-phase carbon is per-request too
    energy_by_role: Dict[str, float] = dataclasses.field(
        default_factory=dict)

    @property
    def n_tokens(self) -> int:
        return 0 if self.tokens is None else int(len(self.tokens))

    @property
    def deadline_met(self) -> bool:
        return self.deadline_s is None or self.t_finish <= self.deadline_s


@runtime_checkable
class ServingBackend(Protocol):
    """The one serving surface: submit requests, advance, collect responses.

    ``submit`` enqueues a request (its ``arrival_s`` gates visibility on the
    backend's clock); ``step`` advances the backend by one scheduling unit
    (one engine tick / one DES event / one fluid window) and returns the
    requests that completed on it; ``drain`` runs until every submitted
    request has completed and returns all responses of the session;
    ``stats`` reports the last session's aggregate metrics."""

    def submit(self, req: InferenceRequest) -> None: ...

    def step(self) -> List[InferenceResponse]: ...

    def drain(self) -> List[InferenceResponse]: ...

    def stats(self) -> Dict[str, float]: ...


def serve_workload(backend: ServingBackend,
                   requests: Sequence[InferenceRequest]
                   ) -> List[InferenceResponse]:
    """Submit a whole workload and run it to completion (the one-call path
    the examples and the fleet probe use on every backend)."""
    for req in requests:
        backend.submit(req)
    return backend.drain()


def serve_prompts(backend: ServingBackend, prompts: Sequence,
                  n_new: int = 8, arrival_s: Optional[Sequence[float]] = None
                  ) -> Dict[str, float]:
    """Bulk-prompt convenience over the typed path: wrap bare token lists
    into :class:`InferenceRequest`s (rid = position), drain, and return the
    backend's session stats.  The one-liner examples/benchmarks use now
    that the engine's ``serve(prompts=...)`` shim is gone — callers that
    need per-request metadata build their own requests."""
    if arrival_s is not None:
        assert len(arrival_s) == len(prompts)
    for i, p in enumerate(prompts):
        backend.submit(InferenceRequest(
            rid=i, prompt=np.asarray(p, np.int32).reshape(-1),
            max_new_tokens=n_new,
            arrival_s=None if arrival_s is None else float(arrival_s[i])))
    backend.drain()
    return backend.stats()


def summarize_responses(responses: Sequence[InferenceResponse]
                        ) -> Dict[str, float]:
    """Cross-backend workload summary (per-class tails + attribution sums)."""
    from repro.serving.scheduler import latency_percentile

    inter = [r for r in responses if r.slo == INTERACTIVE]
    defer = [r for r in responses if r.slo == DEFERRABLE]
    out = {
        "served": len(responses),
        "energy_j": sum(r.energy_j for r in responses),
        "carbon_g": sum(r.carbon_g for r in responses),
        "preemptions": sum(r.preemptions for r in responses),
        "deadline_misses": sum(not r.deadline_met for r in responses),
        "p95_s": (latency_percentile([r.latency_s for r in responses], 95.0)
                  if responses else 0.0),
    }
    if inter:
        out["interactive_p95_s"] = latency_percentile(
            [r.latency_s for r in inter], 95.0)
        out["interactive_ttft_p95_s"] = latency_percentile(
            [r.ttft_s for r in inter], 95.0)
    if defer:
        out["deferrable_served"] = len(defer)
        # the carbon policies move exactly this number: deferrable work's
        # attributed gCO2 (held work served in a cleaner window shows here)
        out["deferrable_carbon_g"] = sum(r.carbon_g for r in defer)
        out["deferrable_queue_delay_p95_s"] = latency_percentile(
            [r.queue_delay_s for r in defer], 95.0)
    return out
