"""Pluggable scheduling policies over the shared ``SchedulerCore``.

Admission order used to be hard-wired FIFO in both the real engine and the
DES.  A :class:`SchedulerPolicy` turns it into a strategy: given the live
queue entries (in submission order) and the backend's current clock, pick
the entry to admit next — or hold everything (return ``None``) when nothing
*should* run right now, which is how the carbon-aware policy parks
deferrable work under a dirty grid.

Policies are pure selection: they never mutate the queue, never see device
state, and work identically under the real engine's wall clock and the
DES's simulated clock.  ``SchedulerCore`` keeps its plain deque fast path
for FIFO (``is_fifo`` policies), so today's behavior stays bit-identical.
"""
from __future__ import annotations

from typing import Callable, Optional, Sequence

__all__ = ["SchedulerPolicy", "FIFOPolicy", "PriorityPolicy", "EDFPolicy",
           "CarbonAwarePolicy", "make_policy"]


class SchedulerPolicy:
    """Selection strategy over pending queue entries.

    ``entries`` arrive in queue order (head first — for FIFO semantics the
    first entry IS the choice); each entry exposes ``rid, t_arrival, seq,
    priority, deadline_s, slo`` (see ``scheduler._Entry``).  Return the index
    of the entry to admit next, or ``None`` to hold the queue."""

    name = "base"
    is_fifo = False                    # True → SchedulerCore's deque fast path

    def select(self, entries: Sequence, now: Optional[float] = None
               ) -> Optional[int]:
        raise NotImplementedError


class FIFOPolicy(SchedulerPolicy):
    """Arrival order — today's behavior, bit-identical (the core keeps its
    deque fast path and never calls ``select``)."""

    name = "fifo"
    is_fifo = True

    def select(self, entries, now=None):
        return 0 if entries else None


class PriorityPolicy(SchedulerPolicy):
    """Highest ``priority`` first; FIFO (submission order) within a level."""

    name = "priority"

    def select(self, entries, now=None):
        if not entries:
            return None
        return min(range(len(entries)),
                   key=lambda i: (-entries[i].priority, entries[i].seq))


class EDFPolicy(SchedulerPolicy):
    """Earliest deadline first; requests without a deadline queue FIFO
    behind every deadlined request."""

    name = "edf"

    def select(self, entries, now=None):
        if not entries:
            return None
        inf = float("inf")
        return min(range(len(entries)),
                   key=lambda i: (entries[i].deadline_s
                                  if entries[i].deadline_s is not None
                                  else inf, entries[i].seq))


class CarbonAwarePolicy(SchedulerPolicy):
    """Two-class carbon-aware admission: interactive requests always flow
    (FIFO), deferrable requests are **held while the grid is dirty**
    (``ci_fn(now) > ci_threshold``) and released EDF when it cleans up — or
    force-released regardless of CI once their deadline runway
    (``deadline_s − now``) shrinks below the estimated service time plus
    margin, so a long dirty spell can never turn a hold into a miss."""

    name = "carbon"

    def __init__(self, ci_fn: Callable[[Optional[float]], float],
                 ci_threshold: float, est_service_s: float = 0.0,
                 deadline_margin_s: float = 0.0):
        self.ci_fn = ci_fn
        self.ci_threshold = ci_threshold
        self.est_service_s = est_service_s
        self.deadline_margin_s = deadline_margin_s

    def _must_release(self, e, now: Optional[float]) -> bool:
        if now is None or e.deadline_s is None:
            return False
        runway = e.deadline_s - now
        return runway <= self.est_service_s + self.deadline_margin_s

    def select(self, entries, now=None):
        for i, e in enumerate(entries):        # interactive: plain FIFO
            if e.slo == "interactive":
                return i
        if not entries:
            return None
        clean = self.ci_fn(now) <= self.ci_threshold
        inf = float("inf")
        candidates = [i for i, e in enumerate(entries)
                      if clean or self._must_release(e, now)]
        if not candidates:
            return None                        # hold: grid dirty, runway wide
        return min(candidates,
                   key=lambda i: (entries[i].deadline_s
                                  if entries[i].deadline_s is not None
                                  else inf, entries[i].seq))


def make_policy(name, **kwargs) -> SchedulerPolicy:
    """Resolve a policy by name (``SchedulerPolicy`` instances pass
    through).  ``carbon`` requires ``ci_fn`` and ``ci_threshold``."""
    if isinstance(name, SchedulerPolicy):
        return name
    if name is None:
        return FIFOPolicy()
    table = {"fifo": FIFOPolicy, "priority": PriorityPolicy, "edf": EDFPolicy,
             "carbon": CarbonAwarePolicy}
    key = str(name).lower()
    if key not in table:
        raise ValueError(f"unknown scheduling policy {name!r} "
                         f"(have {sorted(table)})")
    return table[key](**kwargs)
