"""Pluggable scheduling policies over the shared ``SchedulerCore``.

Admission order used to be hard-wired FIFO in both the real engine and the
DES.  A :class:`SchedulerPolicy` turns it into a strategy: given the live
queue entries (in submission order) and the backend's current clock, pick
the entry to admit next — or hold everything (return ``None``) when nothing
*should* run right now, which is how the carbon-aware policy parks
deferrable work under a dirty grid.

Policies are pure selection: they never mutate the queue, never see device
state, and work identically under the real engine's wall clock and the
DES's simulated clock.  ``SchedulerCore`` keeps its plain deque fast path
for FIFO (``is_fifo`` policies), so today's behavior stays bit-identical.
"""
from __future__ import annotations

from typing import Callable, Optional, Sequence

__all__ = ["SchedulerPolicy", "FIFOPolicy", "PriorityPolicy", "EDFPolicy",
           "CarbonAwarePolicy", "CarbonForecastPolicy", "make_policy"]


class SchedulerPolicy:
    """Selection strategy over pending queue entries.

    ``entries`` arrive in queue order (head first — for FIFO semantics the
    first entry IS the choice); each entry exposes ``rid, t_arrival, seq,
    priority, deadline_s, slo`` (see ``scheduler._Entry``).  Return the index
    of the entry to admit next, or ``None`` to hold the queue."""

    name = "base"
    is_fifo = False                    # True → SchedulerCore's deque fast path

    def select(self, entries: Sequence, now: Optional[float] = None
               ) -> Optional[int]:
        raise NotImplementedError

    # --- hold bookkeeping (observability) ------------------------------------
    # The carbon policies call these from ``select``: ``note_hold`` the first
    # time an entry is parked, ``note_release`` the first time it becomes a
    # candidate again (with the reason: "valley" / "threshold" / "runway").
    # Backends read ``hold_info`` at completion to surface ``held_s`` and the
    # release reason on the InferenceResponse and to emit the "hold" trace
    # span.  Timestamps are whatever clock ``select`` received (session-
    # relative on both the real engine and the DES), so ``held_s`` is a plain
    # duration either way.
    def reset_holds(self) -> None:
        """Forget hold state (the real engine calls this at session open —
        request ids may repeat across serve sessions)."""
        self.__dict__["_holds"] = {}

    def note_hold(self, rid: int, now: Optional[float]) -> None:
        if now is None:
            return
        holds = self.__dict__.setdefault("_holds", {})
        if rid not in holds:
            holds[rid] = [float(now), None, None]   # t_first, t_release, why

    def note_release(self, rid: int, now: Optional[float],
                     reason: str) -> None:
        if now is None:
            return
        rec = self.__dict__.setdefault("_holds", {}).get(rid)
        if rec is not None and rec[1] is None:
            rec[1] = float(now)
            rec[2] = reason

    def hold_info(self, rid: int):
        """(t_first_hold, t_release, reason) for a request that was held and
        released, else None (never held, or still parked)."""
        rec = self.__dict__.get("_holds", {}).get(rid)
        if rec is None or rec[1] is None:
            return None
        return (rec[0], rec[1], rec[2])

    def select_prefill(self, entries: Sequence, now: Optional[float] = None
                       ) -> int:
        """Ordering for an instance's chunked-prefill queue.

        Same selection as admission, with one difference: a prefill queue
        can never be HELD — every entry is already admitted and is holding
        arena blocks, so parking it (as the carbon policies park deferrable
        *admissions* under a dirty grid) would only strand memory.  A
        policy that would hold falls back to the FIFO head."""
        idx = self.select(entries, now)
        return 0 if idx is None else idx


class FIFOPolicy(SchedulerPolicy):
    """Arrival order — today's behavior, bit-identical (the core keeps its
    deque fast path and never calls ``select``)."""

    name = "fifo"
    is_fifo = True

    def select(self, entries, now=None):
        return 0 if entries else None


class PriorityPolicy(SchedulerPolicy):
    """Highest ``priority`` first; FIFO (submission order) within a level."""

    name = "priority"

    def select(self, entries, now=None):
        if not entries:
            return None
        return min(range(len(entries)),
                   key=lambda i: (-entries[i].priority, entries[i].seq))


class EDFPolicy(SchedulerPolicy):
    """Earliest deadline first; requests without a deadline queue FIFO
    behind every deadlined request."""

    name = "edf"

    def select(self, entries, now=None):
        if not entries:
            return None
        inf = float("inf")
        return min(range(len(entries)),
                   key=lambda i: (entries[i].deadline_s
                                  if entries[i].deadline_s is not None
                                  else inf, entries[i].seq))


class CarbonAwarePolicy(SchedulerPolicy):
    """Two-class carbon-aware admission: interactive requests always flow
    (FIFO), deferrable requests are **held while the grid is dirty**
    (``ci_fn(now) > ci_threshold``) and released EDF when it cleans up — or
    force-released regardless of CI once their deadline runway
    (``deadline_s − now``) shrinks below the estimated service time plus
    margin, so a long dirty spell can never turn a hold into a miss.

    ``ci_fn`` may be any ``ci_fn(now) → gCO2/kWh`` callable — a raw trace
    lookup, or a :class:`repro.fleet.forecast.ForecastCIFn` nowcast so this
    policy and :class:`CarbonForecastPolicy` share one CI source."""

    name = "carbon"

    def __init__(self, ci_fn: Callable[[Optional[float]], float],
                 ci_threshold: float, est_service_s: float = 0.0,
                 deadline_margin_s: float = 0.0):
        self.ci_fn = ci_fn
        self.ci_threshold = ci_threshold
        self.est_service_s = est_service_s
        self.deadline_margin_s = deadline_margin_s

    def _must_release(self, e, now: Optional[float]) -> bool:
        if now is None or e.deadline_s is None:
            return False
        runway = e.deadline_s - now
        return runway <= self.est_service_s + self.deadline_margin_s

    def select(self, entries, now=None):
        for i, e in enumerate(entries):        # interactive: plain FIFO
            if e.slo == "interactive":
                return i
        if not entries:
            return None
        clean = self.ci_fn(now) <= self.ci_threshold
        inf = float("inf")
        candidates = []
        for i, e in enumerate(entries):
            if self._must_release(e, now):
                candidates.append(i)
                self.note_release(e.rid, now, "runway")
            elif clean:
                candidates.append(i)
                self.note_release(e.rid, now, "threshold")
            else:
                self.note_hold(e.rid, now)
        if not candidates:
            return None                        # hold: grid dirty, runway wide
        return min(candidates,
                   key=lambda i: (entries[i].deadline_s
                                  if entries[i].deadline_s is not None
                                  else inf, entries[i].seq))


class CarbonForecastPolicy(SchedulerPolicy):
    """Forecast-driven two-class admission (the Clover/EcoServe coupling:
    act on *predicted* carbon, not the instantaneous grid).

    Interactive requests always flow (FIFO).  Each deferrable request is
    scheduled against the **forecast valley inside its own deadline
    runway**: ``ci_fn(now, h)`` is sampled every ``step_s`` out to
    ``min(horizon_s, runway)``, where runway = ``deadline_s − now −
    est_service_s − deadline_margin_s`` (deadline-less requests get the
    full horizon).  The request is released when

      * the nowcast is already within ``valley_tolerance`` of the best
        forecast CI it can still reach — waiting cannot pay; this includes
        a forecast that is flat or *rising* through the whole runway, where
        the raw-threshold policy would still sit out the dirty spell; or
      * the nowcast is under ``ci_threshold`` (optional absolute clean-grid
        fast path, matching :class:`CarbonAwarePolicy`); or
      * the runway is exhausted (force-release — a wrong forecast can never
        turn a hold into a deadline miss).

    Released candidates drain EDF.  ``ci_fn`` must accept ``(now,
    horizon_s)`` — :class:`repro.fleet.forecast.ForecastCIFn` adapts the
    fleet's forecaster ensemble to exactly this contract.  Forecast series
    are memoized per (now, runway) quantized to ``step_s``, so a busy
    engine tick doesn't re-run the forecaster per queued entry."""

    name = "carbon_forecast"

    def __init__(self, ci_fn: Callable[..., float], horizon_s: float,
                 step_s: Optional[float] = None,
                 est_service_s: float = 0.0, deadline_margin_s: float = 0.0,
                 valley_tolerance: float = 0.05,
                 ci_threshold: Optional[float] = None):
        assert horizon_s > 0.0, "need a positive forecast horizon"
        self.ci_fn = ci_fn
        self.horizon_s = horizon_s
        self.step_s = step_s if step_s is not None else horizon_s / 12.0
        self.est_service_s = est_service_s
        self.deadline_margin_s = deadline_margin_s
        self.valley_tolerance = valley_tolerance
        self.ci_threshold = ci_threshold
        self._memo: dict = {}          # (now_q, runway_q) → valley CI

    def _runway(self, e, now: float) -> float:
        if e.deadline_s is None:
            return self.horizon_s
        return (e.deadline_s - now - self.est_service_s
                - self.deadline_margin_s)

    def _valley(self, now: float, runway: float) -> float:
        """Lowest forecast CI reachable within ``runway`` seconds.  The memo
        key includes the ci_fn's epoch (``ForecastCIFn.t0``): a re-anchored
        clock (fleet probe windows) must not serve valleys forecast for a
        different stretch of the grid."""
        h_max = min(runway, self.horizon_s)
        key = (round(now / self.step_s), round(h_max / self.step_s),
               getattr(self.ci_fn, "t0", 0.0))
        hit = self._memo.get(key)
        if hit is not None:
            return hit
        valley = float("inf")
        h = self.step_s
        while h <= h_max + 1e-9:
            valley = min(valley, self.ci_fn(now, h))
            h += self.step_s
        if valley == float("inf"):
            # runway shorter than one step: still consult the forecast at
            # the runway's end instead of skipping the valley check entirely
            valley = self.ci_fn(now, h_max)
        if len(self._memo) > 4096:     # bounded: one serve session's worth
            self._memo.clear()
        self._memo[key] = valley
        return valley

    def _release_reason(self, e, now: float,
                        ci_now: float) -> Optional[str]:
        """Why this entry may run now — "runway" / "threshold" / "valley" —
        or None while it should keep waiting for a better valley."""
        runway = self._runway(e, now)
        if runway <= 0.0:
            return "runway"                          # force-release
        if self.ci_threshold is not None and ci_now <= self.ci_threshold:
            return "threshold"                       # grid already clean
        valley = self._valley(now, runway)
        if ci_now <= valley * (1.0 + self.valley_tolerance):
            return "valley"
        return None

    def _release(self, e, now: float, ci_now: float) -> bool:
        return self._release_reason(e, now, ci_now) is not None

    def select(self, entries, now=None):
        for i, e in enumerate(entries):        # interactive: plain FIFO
            if e.slo == "interactive":
                return i
        if not entries:
            return None
        now_f = float(now) if now is not None else 0.0
        ci_now = self.ci_fn(now_f, 0.0)
        candidates = []
        for i, e in enumerate(entries):
            reason = self._release_reason(e, now_f, ci_now)
            if reason is not None:
                candidates.append(i)
                self.note_release(e.rid, now, reason)
            else:
                self.note_hold(e.rid, now)
        if not candidates:
            return None                        # hold: a better valley is near
        inf = float("inf")
        return min(candidates,
                   key=lambda i: (entries[i].deadline_s
                                  if entries[i].deadline_s is not None
                                  else inf, entries[i].seq))


def make_policy(name, **kwargs) -> SchedulerPolicy:
    """Resolve a policy by name (``SchedulerPolicy`` instances pass
    through).  ``carbon`` requires ``ci_fn`` and ``ci_threshold``;
    ``carbon_forecast`` requires ``ci_fn`` and ``horizon_s``."""
    if isinstance(name, SchedulerPolicy):
        return name
    if name is None:
        return FIFOPolicy()
    table = {"fifo": FIFOPolicy, "priority": PriorityPolicy, "edf": EDFPolicy,
             "carbon": CarbonAwarePolicy,
             "carbon_forecast": CarbonForecastPolicy}
    key = str(name).lower()
    if key not in table:
        raise ValueError(f"unknown scheduling policy {name!r} "
                         f"(have {sorted(table)})")
    return table[key](**kwargs)
