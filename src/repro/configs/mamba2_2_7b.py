"""mamba2-2.7b — attention-free SSD: 64L d_model=2560 ssm_state=128
vocab=50280 (expand=2 -> d_inner=5120, 80 heads of 64).  [arXiv:2405.21060]"""
import jax.numpy as jnp
from repro.models.config import ModelConfig

FULL = ModelConfig(
    name="mamba2-2.7b", family="ssm",
    n_layers=64, d_model=2560, n_heads=0, n_kv_heads=0, d_head=0,
    d_ff=0, vocab_size=50280,
    ssm_state=128, ssm_head_dim=64, ssm_expand=2, ssm_chunk=128,
    tie_embeddings=True,
)

SMOKE = FULL.with_(
    name="mamba2-2.7b-smoke",
    n_layers=4, d_model=64, vocab_size=256, ssm_state=16, ssm_head_dim=8,
    ssm_chunk=8, dtype=jnp.float32, max_seq_len=64,
)
