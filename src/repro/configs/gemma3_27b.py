"""gemma3-27b — 62L d_model=5376 32H (GQA kv=16) d_ff=21504 vocab=262144,
5:1 local(window 1024):global attention, qk_norm, dual rope thetas.
[hf:google/gemma-3-27b family]"""
import jax.numpy as jnp
from repro.models.config import ModelConfig

FULL = ModelConfig(
    name="gemma3-27b", family="dense",
    n_layers=62, d_model=5376, n_heads=32, n_kv_heads=16, d_head=128,
    d_ff=21504, vocab_size=262144,
    qk_norm=True, sliding_window=1024, local_global_ratio=5,
    rope_theta=1e4, global_rope_theta=1e6,
)

SMOKE = FULL.with_(
    name="gemma3-27b-smoke",
    n_layers=6, d_model=64, n_heads=4, n_kv_heads=2, d_head=16,
    d_ff=128, vocab_size=256, sliding_window=8, dtype=jnp.float32,
    max_seq_len=64,
)
