"""zamba2-2.7b — hybrid: 54 Mamba2 layers (d_model=2560, ssm_state=64) with a
*shared* attention+MLP block (32H kv=32, d_ff=10240) applied every 6 layers.
[arXiv:2411.15242]"""
import jax.numpy as jnp
from repro.models.config import ModelConfig

FULL = ModelConfig(
    name="zamba2-2.7b", family="hybrid",
    n_layers=54, d_model=2560, n_heads=32, n_kv_heads=32, d_head=80,
    d_ff=10240, vocab_size=32000,
    ssm_state=64, ssm_head_dim=64, ssm_expand=2, ssm_chunk=128,
    attn_every=6,
)

SMOKE = FULL.with_(
    name="zamba2-2.7b-smoke",
    n_layers=4, d_model=64, n_heads=4, n_kv_heads=4, d_head=16,
    d_ff=128, vocab_size=256, ssm_state=8, ssm_head_dim=8, ssm_chunk=8,
    attn_every=2, dtype=jnp.float32, max_seq_len=64,
)
