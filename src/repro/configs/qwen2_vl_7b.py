"""qwen2-vl-7b — 28L d_model=3584 28H (GQA kv=4) d_ff=18944 vocab=152064,
M-RoPE (sections 16/24/24 over half-dims), QKV bias.  Vision frontend stubbed:
patch embeddings arrive precomputed.  [arXiv:2409.12191]"""
import jax.numpy as jnp
from repro.models.config import ModelConfig

FULL = ModelConfig(
    name="qwen2-vl-7b", family="vlm",
    n_layers=28, d_model=3584, n_heads=28, n_kv_heads=4, d_head=128,
    d_ff=18944, vocab_size=152064,
    qkv_bias=True, mrope_sections=(16, 24, 24), rope_theta=1e6,
)

SMOKE = FULL.with_(
    name="qwen2-vl-7b-smoke",
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, d_head=16,
    d_ff=128, vocab_size=256, mrope_sections=(4, 2, 2),
    dtype=jnp.float32, max_seq_len=64,
)
