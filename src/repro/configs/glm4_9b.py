"""glm4-9b — 40L d_model=4096 32H (GQA kv=2) d_ff=13696 vocab=151552,
partial rotary (0.5), QKV bias.  [hf:THUDM/glm-4-9b]"""
import jax.numpy as jnp
from repro.models.config import ModelConfig

FULL = ModelConfig(
    name="glm4-9b", family="dense",
    n_layers=40, d_model=4096, n_heads=32, n_kv_heads=2, d_head=128,
    d_ff=13696, vocab_size=151552,
    partial_rotary=0.5, qkv_bias=True, rope_theta=1e4,
)

SMOKE = FULL.with_(
    name="glm4-9b-smoke",
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, d_head=16,
    d_ff=128, vocab_size=256, dtype=jnp.float32, max_seq_len=64,
)
