"""Architecture config registry — ``--arch <id>`` resolution."""
from __future__ import annotations

import importlib
from typing import Dict, List

from repro.models.config import ModelConfig

_MODULES: Dict[str, str] = {
    "qwen3-moe-30b-a3b": "qwen3_moe_30b_a3b",
    "qwen2-moe-a2.7b": "qwen2_moe_a2_7b",
    "qwen3-1.7b": "qwen3_1_7b",
    "glm4-9b": "glm4_9b",
    "gemma3-27b": "gemma3_27b",
    "qwen2-0.5b": "qwen2_0_5b",
    "seamless-m4t-large-v2": "seamless_m4t_large_v2",
    "zamba2-2.7b": "zamba2_2_7b",
    "qwen2-vl-7b": "qwen2_vl_7b",
    "mamba2-2.7b": "mamba2_2_7b",
}

ARCHS: List[str] = list(_MODULES)


def _module(name: str):
    if name not in _MODULES:
        raise KeyError(f"unknown arch {name!r}; available: {ARCHS}")
    return importlib.import_module(f"repro.configs.{_MODULES[name]}")


def get_config(name: str) -> ModelConfig:
    return _module(name).FULL


def get_smoke_config(name: str) -> ModelConfig:
    return _module(name).SMOKE


def all_configs() -> Dict[str, ModelConfig]:
    return {a: get_config(a) for a in ARCHS}


# --- §Perf optimized variants (EXPERIMENTS.md hillclimb log) -----------------
# Only the *measured-confirmed* changes survive here (see §Perf for the
# refuted hypotheses: seq-parallel constraints, one-hot embeddings, and
# collectives-remat on wide models all regressed and were reverted).
#   * remat_policy="collectives" — skip re-running TP collectives in the
#     backward; net win only where per-layer activations are small
#     (d_model ≤ ~2.5k): +0.1 GiB on qwen2-moe vs +20 GiB on gemma3.
#   * decode_window — append-buffer KV cache: read-only seq-shardable prefix
#     (required for the 500k cells; removes the per-step cache rewrite).
OPTIMIZED_OVERRIDES: Dict[str, dict] = {
    "qwen3-moe-30b-a3b": dict(remat_policy="collectives", decode_window=256),
    "qwen2-moe-a2.7b": dict(remat_policy="collectives"),
    "qwen3-1.7b": dict(remat_policy="collectives"),
    "qwen2-0.5b": dict(remat_policy="collectives"),
    "seamless-m4t-large-v2": dict(remat_policy="collectives"),
    "zamba2-2.7b": dict(remat_policy="collectives"),
    "mamba2-2.7b": dict(remat_policy="collectives"),
    "glm4-9b": dict(decode_window=256),
    "gemma3-27b": dict(decode_window=256),
    "qwen2-vl-7b": dict(decode_window=256),
}


def get_optimized_config(name: str) -> ModelConfig:
    return get_config(name).with_(**OPTIMIZED_OVERRIDES.get(name, {}))
