"""Assigned input-shape cells and ShapeDtypeStruct stand-ins for the dry-run.

Each LM-family architecture is paired with four cells:
  train_4k     seq=4096,   global_batch=256   -> train_step
  prefill_32k  seq=32768,  global_batch=32    -> serve prefill (full forward)
  decode_32k   seq=32768,  global_batch=128   -> serve_step (1 token + KV cache)
  long_500k    seq=524288, global_batch=1     -> serve_step, sub-quadratic archs only

``input_specs`` allocates nothing — everything is jax.ShapeDtypeStruct.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig


@dataclasses.dataclass(frozen=True)
class ShapeCell:
    name: str
    seq_len: int
    global_batch: int
    kind: str            # "train" | "prefill" | "decode"


SHAPES: Dict[str, ShapeCell] = {
    "train_4k": ShapeCell("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeCell("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeCell("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeCell("long_500k", 524288, 1, "decode"),
}

# archs that can run 500k-context decode (sub-quadratic / bounded-window);
# pure full-attention archs skip this cell (see DESIGN.md §Arch-applicability).
LONG_CONTEXT_ARCHS = ("mamba2-2.7b", "zamba2-2.7b", "gemma3-27b")


def cell_applicable(cfg: ModelConfig, shape: str) -> bool:
    if shape == "long_500k":
        return cfg.name in LONG_CONTEXT_ARCHS
    return True


def sds(shape, dtype):
    return jax.ShapeDtypeStruct(tuple(shape), dtype)


def input_specs(cfg: ModelConfig, shape: str) -> dict:
    """ShapeDtypeStruct stand-ins for the model-facing batch of one cell.

    For ``train``: tokens + labels.  For ``prefill``: tokens.  For ``decode``:
    a single token column (the KV-cache spec comes from ``cache_specs_for``).
    Modality frontends are stubs: seamless gets precomputed frame embeddings,
    qwen2-vl gets M-RoPE position streams (patch embeds are exercised in the
    smoke tests, not the dry run, where the backbone is the assignment).
    """
    cell = SHAPES[shape]
    b, s = cell.global_batch, cell.seq_len
    if cell.kind == "decode":
        batch = {"tokens": sds((b, 1), jnp.int32)}
        if cfg.mrope_sections:
            batch["mrope_positions"] = sds((3, b, 1), jnp.int32)
        return batch
    batch = {"tokens": sds((b, s), jnp.int32)}
    if cell.kind == "train":
        batch["labels"] = sds((b, s), jnp.int32)
    if cfg.mrope_sections:
        batch["mrope_positions"] = sds((3, b, s), jnp.int32)
    if cfg.n_enc_layers > 0:
        batch["src_embeds"] = sds((b, s, cfg.d_model), jnp.bfloat16)
    return batch


def src_embeds_spec(cfg: ModelConfig, shape: str) -> Optional[jax.ShapeDtypeStruct]:
    """Encoder-input spec for enc-dec decode cells (encoder memory length = seq)."""
    if cfg.n_enc_layers == 0:
        return None
    cell = SHAPES[shape]
    return sds((cell.global_batch, cell.seq_len, cfg.d_model), jnp.bfloat16)
