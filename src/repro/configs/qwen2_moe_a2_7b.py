"""qwen2-moe-a2.7b — 24L d_model=2048 16H (GQA kv=16) expert_ff=1408
vocab=151936, MoE 60 routed top-4 + 4 shared.  [hf:Qwen/Qwen1.5-MoE-A2.7B]"""
import jax.numpy as jnp
from repro.models.config import ModelConfig

FULL = ModelConfig(
    name="qwen2-moe-a2.7b", family="moe",
    n_layers=24, d_model=2048, n_heads=16, n_kv_heads=16, d_head=128,
    d_ff=0, vocab_size=151936,
    n_experts=60, top_k=4, d_expert_ff=1408, n_shared_experts=4,
    qkv_bias=True, rope_theta=1e6,
)

SMOKE = FULL.with_(
    name="qwen2-moe-a2.7b-smoke",
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=4, d_head=16,
    vocab_size=256, n_experts=6, top_k=2, d_expert_ff=32, n_shared_experts=2,
    moe_group_size=64, dtype=jnp.float32, max_seq_len=64,
)
