"""seamless-m4t-large-v2 — enc-dec backbone: 24 enc + 24 dec layers,
d_model=1024 16H (kv=16) d_ff=8192 vocab=256206.  Audio frontend stubbed:
inputs are precomputed frame embeddings.  [arXiv:2308.11596]"""
import jax.numpy as jnp
from repro.models.config import ModelConfig

FULL = ModelConfig(
    name="seamless-m4t-large-v2", family="audio",
    n_layers=24, n_enc_layers=24,
    d_model=1024, n_heads=16, n_kv_heads=16, d_head=64,
    d_ff=8192, vocab_size=256206, act="gelu",
)

SMOKE = FULL.with_(
    name="seamless-m4t-large-v2-smoke",
    n_layers=2, n_enc_layers=2, d_model=64, n_heads=4, n_kv_heads=4,
    d_head=16, d_ff=128, vocab_size=256, dtype=jnp.float32, max_seq_len=64,
)
