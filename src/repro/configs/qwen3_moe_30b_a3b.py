"""qwen3-moe-30b-a3b — 48L d_model=2048 32H (GQA kv=4) expert_ff=768
vocab=151936, MoE 128 experts top-8.  [hf:Qwen/Qwen3-30B-A3B]"""
import jax.numpy as jnp
from repro.models.config import ModelConfig

FULL = ModelConfig(
    name="qwen3-moe-30b-a3b", family="moe",
    n_layers=48, d_model=2048, n_heads=32, n_kv_heads=4, d_head=128,
    d_ff=0, vocab_size=151936,
    n_experts=128, top_k=8, d_expert_ff=768,
    qk_norm=True, rope_theta=1e6,
)

SMOKE = FULL.with_(
    name="qwen3-moe-30b-a3b-smoke",
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, d_head=16,
    vocab_size=256, n_experts=8, top_k=2, d_expert_ff=32,
    moe_group_size=64, dtype=jnp.float32, max_seq_len=64,
)
