"""Loop-aware HLO analysis: FLOPs and collective wire bytes with while-loop
trip-count scaling.

XLA's ``compiled.cost_analysis()`` counts a while-loop body ONCE, so any stat
derived from it underestimates a scan-over-layers model by ~L×.  This module
re-derives the two roofline inputs that matter directly from the scheduled
HLO text:

  * matmul FLOPs       — every ``dot`` op: 2 × |result| × Π(contracted dims),
                         scaled by the product of enclosing-loop trip counts
                         (``backend_config known_trip_count``, with a
                         condition-constant fallback);
  * collective bytes   — ring-model wire bytes per device per kind, scaled the
                         same way.

Validated in tests against hand-computable programs (scan of k matmuls etc.).
"""
from __future__ import annotations

import json
import re
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {"f64": 8, "s64": 8, "u64": 8, "c64": 8, "f32": 4, "s32": 4,
                "u32": 4, "f16": 2, "bf16": 2, "s16": 2, "u16": 2,
                "f8e4m3fn": 1, "f8e5m2": 1, "s8": 1, "u8": 1, "pred": 1}
_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
_COLL_KINDS = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
               "collective-permute")


def _shape_info(s: str) -> Tuple[str, List[int]]:
    m = _SHAPE_RE.search(s)
    if not m:
        return "f32", []
    dt, dims = m.groups()
    return dt, [int(d) for d in dims.split(",") if d]


def _numel(dims: List[int]) -> int:
    n = 1
    for d in dims:
        n *= d
    return n


def _nbytes(s: str) -> int:
    dt, dims = _shape_info(s)
    return _numel(dims) * _DTYPE_BYTES.get(dt, 4)


# =============================================================================
# parsing
# =============================================================================
_COMP_HEADER = re.compile(r"^(ENTRY\s+)?%?([\w.\-]+)\s+\(.*\)\s*->\s*.*\{")


def split_computations(hlo: str) -> Tuple[Dict[str, List[str]], Optional[str]]:
    comps: Dict[str, List[str]] = {}
    entry = None
    cur: Optional[str] = None
    for line in hlo.splitlines():
        if cur is None:
            m = _COMP_HEADER.match(line)
            if m:
                cur = m.group(2)
                comps[cur] = []
                if m.group(1):
                    entry = cur
        else:
            if line.startswith("}"):
                cur = None
            else:
                comps[cur].append(line.strip())
    return comps, entry


_WHILE_RE = re.compile(
    r"while\(.*?\).*?condition=%?([\w.\-]+),\s*body=%?([\w.\-]+)")
_TRIP_RE = re.compile(r'"known_trip_count":\{"n":"(\d+)"\}')
_CALL_RE = re.compile(r"(?:calls|to_apply|body|condition|branch_computations)=%?\{?([\w.\-, %]+)\}?")
_DOT_RE = re.compile(
    r"=\s+([a-z0-9]+\[[0-9,]*\])\S*\s+dot\(([^)]*)\)(.*)$")
_CONTRACT_RE = re.compile(r"lhs_contracting_dims=\{([0-9,]*)\}")
# one dot operand: an optional inline shape (``f32[64,64]{1,0}``) followed by
# the op name.  Scheduled HLO prints operands either way depending on the
# computation (while bodies inline the shape, fusions name bare parameters).
_OPERAND_RE = re.compile(
    r"\s*(?:([a-z0-9]+\[[0-9,]*\])\S*\s+)?%?([\w.\-]+)")
_DEF_RE = re.compile(r"^%?([\w.\-]+)\s+=\s+((?:\([^)]*\))|(?:[a-z0-9]+\[[0-9,]*\]))")


def _trip_count(while_line: str, cond_lines: List[str]) -> int:
    m = _TRIP_RE.search(while_line)
    if m:
        return int(m.group(1))
    # fallback: the loop-condition constant (scan lowers to counter < N)
    consts = []
    for l in cond_lines:
        if "compare" in l or "constant" in l:
            consts += [int(c) for c in re.findall(r"constant\((\d+)\)", l)]
    return max(consts) if consts else 1


def _symbol_table(lines: List[str]) -> Dict[str, str]:
    """op name -> result shape string (scheduled HLO prints operands by name)."""
    table: Dict[str, str] = {}
    for l in lines:
        m = _DEF_RE.match(l)
        if m:
            table[m.group(1)] = m.group(2)
    return table


def _dot_flops(line: str, symbols: Dict[str, str]) -> float:
    m = _DOT_RE.search(line)
    if not m:
        return 0.0
    result_shape, operands, attrs = m.groups()
    _, rdims = _shape_info(result_shape)
    # the lhs operand: splitting on "," would cut an inline shape's dims
    # list in half (``f32[64,64]{1,0} %x`` → ``f32[64``), silently dropping
    # the contraction dimension for every dot inside a while/scan body
    om = _OPERAND_RE.match(operands)
    if om and om.group(1):
        _, ldims = _shape_info(om.group(1))    # inline operand shape
    else:
        lhs = om.group(2) if om else operands.strip().lstrip("%")
        _, ldims = _shape_info(symbols.get(lhs, ""))
    cm = _CONTRACT_RE.search(attrs)
    k = 1
    if cm and cm.group(1):
        for idx in cm.group(1).split(","):
            k *= ldims[int(idx)] if int(idx) < len(ldims) else 1
    return 2.0 * _numel(rdims) * k


def _group_size(line: str, total_devices: int) -> int:
    m = re.search(r"replica_groups=\[(\d+),(\d+)\]", line)
    if m:
        return int(m.group(2))
    m = re.search(r"replica_groups=\{\{([^}]*)\}", line)
    if m:
        return len(m.group(1).split(","))
    return total_devices


def _collective_wire_bytes(line: str, op: str, n_dev: int,
                           symbols: Dict[str, str]) -> float:
    g = _group_size(line, n_dev)
    if g <= 1:
        return 0.0
    m = re.match(r"%?[\w.\-]+ = ((?:\([^)]*\))|(?:[a-z0-9]+\[[0-9,]*\][^ ]*))", line)
    result_b = 0
    if m:
        rs = m.group(1)
        if rs.startswith("("):
            result_b = sum(_nbytes(p) for p in rs[1:-1].split(",") if "[" in p)
        else:
            result_b = _nbytes(rs)
    paren = line.find("(", line.find(op))
    operand_b = 0
    if paren >= 0:
        ops_str = line[paren:line.find(")", paren) + 1]
        inline = sum(_nbytes(x.group(0)) for x in _SHAPE_RE.finditer(ops_str))
        if inline:
            operand_b = inline
        else:  # operands by name: resolve via symbol table
            for tok in ops_str[1:-1].split(","):
                operand_b += _nbytes(symbols.get(tok.strip().lstrip("%"), ""))
    operand_b = operand_b or result_b
    if op == "all-gather":
        return result_b * (g - 1) / g
    if op == "all-reduce":
        return 2.0 * operand_b * (g - 1) / g
    if op in ("reduce-scatter", "all-to-all"):
        return operand_b * (g - 1) / g
    return float(operand_b)            # collective-permute


# =============================================================================
# loop-tree accumulation
# =============================================================================
class HloStats:
    def __init__(self, dot_flops: float, coll_bytes: Dict[str, float],
                 coll_counts: Dict[str, float]):
        self.dot_flops = dot_flops
        self.coll_bytes = coll_bytes
        self.coll_counts = coll_counts

    @property
    def total_coll_bytes(self) -> float:
        return float(sum(self.coll_bytes.values()))

    def as_dict(self) -> Dict:
        return {"dot_flops": self.dot_flops,
                "collective_wire_bytes": dict(self.coll_bytes),
                "collective_counts": dict(self.coll_counts),
                "total_collective_bytes": self.total_coll_bytes}


def analyze(hlo: str, n_devices: int) -> HloStats:
    comps, entry = split_computations(hlo)
    if entry is None:
        entry = max(comps, key=lambda c: len(comps[c])) if comps else None
    memo: Dict[str, Tuple[float, Dict[str, float], Dict[str, float]]] = {}

    def total(name: str, stack=()) -> Tuple[float, Dict[str, float], Dict[str, float]]:
        if name in memo:
            return memo[name]
        if name not in comps or name in stack:
            return 0.0, {}, {}
        flops = 0.0
        cb = {k: 0.0 for k in _COLL_KINDS}
        cc = {k: 0.0 for k in _COLL_KINDS}
        symbols = _symbol_table(comps[name])
        for line in comps[name]:
            wm = _WHILE_RE.search(line)
            if wm:
                cond, body = wm.groups()
                trip = _trip_count(line, comps.get(cond, []))
                bf, bcb, bcc = total(body, stack + (name,))
                flops += trip * bf
                for k in _COLL_KINDS:
                    cb[k] += trip * bcb.get(k, 0.0)
                    cc[k] += trip * bcc.get(k, 0.0)
                continue
            # async collectives appear as <kind>-start / -done; count -start only
            matched_coll = False
            for kind in _COLL_KINDS:
                if re.search(rf"\b{kind}(-start)?\(", line):
                    if f"{kind}-done" in line:
                        break
                    cb[kind] += _collective_wire_bytes(line, kind, n_devices, symbols)
                    cc[kind] += 1
                    matched_coll = True
                    break
            if matched_coll:
                continue
            if " dot(" in line:
                flops += _dot_flops(line, symbols)
                continue
            if "fusion(" in line or re.search(r"\bcall\(", line) or "conditional(" in line:
                cm = _CALL_RE.search(line)
                if cm:
                    for callee in re.split(r",\s*", cm.group(1)):
                        callee = callee.strip().lstrip("%")
                        if callee in comps:
                            f2, cb2, cc2 = total(callee, stack + (name,))
                            flops += f2
                            for k in _COLL_KINDS:
                                cb[k] += cb2.get(k, 0.0)
                                cc[k] += cc2.get(k, 0.0)
        memo[name] = (flops, cb, cc)
        return memo[name]

    f, cb, cc = total(entry) if entry else (0.0, {}, {})
    return HloStats(f, cb, cc)
