"""Production mesh factories.

Functions, not module-level constants, so importing never touches jax device
state (the dry-run must set XLA_FLAGS before any jax initialization).

Production topology: TPU v5e, 16×16 = 256 chips per pod; the multi-pod mesh
adds a leading "pod" axis (2 pods = 512 chips) connected over DCN.  Axes:
  pod   — pure data parallelism across pods (gradient all-reduce over DCN)
  data  — within-pod data parallelism / sequence sharding for long context
  model — tensor / expert parallelism
"""
from __future__ import annotations

import jax

# XLA flags a real TPU deployment would launch with (latency-hiding overlap of
# collectives with compute; documented here, applied by launch scripts).
TPU_XLA_PERF_FLAGS = (
    "--xla_tpu_enable_async_collective_fusion=true "
    "--xla_tpu_enable_async_collective_fusion_fuse_all_gather=true "
    "--xla_tpu_overlap_compute_collective_tc=true "
    "--xla_enable_async_all_gather=true "
    "--xla_enable_async_collective_permute=true "
)


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_test_mesh(data: int = 2, model: int = 2, pod: int = 0):
    """Small host-device mesh for unit tests (requires
    XLA_FLAGS=--xla_force_host_platform_device_count >= data*model*max(pod,1))."""
    if pod:
        return jax.make_mesh((pod, data, model), ("pod", "data", "model"))
    return jax.make_mesh((data, model), ("data", "model"))


def make_slice_mesh(devices, model_parallel: int):
    """Mesh over a *sub-slice* of a pod (Clover serving instance): the given
    devices become a (1, model_parallel) (data, model) mesh."""
    import numpy as np
    devs = np.asarray(devices).reshape(1, model_parallel)
    from jax.sharding import Mesh
    return Mesh(devs, ("data", "model"))
