"""Production mesh factories.

Functions, not module-level constants, so importing never touches jax device
state (the dry-run must set XLA_FLAGS before any jax initialization).

Production topology: TPU v5e, 16×16 = 256 chips per pod; the multi-pod mesh
adds a leading "pod" axis (2 pods = 512 chips) connected over DCN.  Axes:
  pod   — pure data parallelism across pods (gradient all-reduce over DCN)
  data  — within-pod data parallelism / sequence sharding for long context
  model — tensor / expert parallelism
"""
from __future__ import annotations

import jax

# XLA flags a real TPU deployment would launch with (latency-hiding overlap of
# collectives with compute; documented here, applied by launch scripts).
TPU_XLA_PERF_FLAGS = (
    "--xla_tpu_enable_async_collective_fusion=true "
    "--xla_tpu_enable_async_collective_fusion_fuse_all_gather=true "
    "--xla_tpu_overlap_compute_collective_tc=true "
    "--xla_enable_async_all_gather=true "
    "--xla_enable_async_collective_permute=true "
)


def make_mesh_for(n_devices: int | None = None,
                  model_parallel: int | None = None):
    """Size a ("data", "model") serving mesh to the devices that exist.

    The production factory below hard-codes the 16×16 pod shape and can only
    run on that topology; everything else — engines, tests, the host-platform
    smoke — goes through this so the device count is discovered, not assumed.

      n_devices       total devices to use (default: all visible devices)
      model_parallel  size of the "model" axis (default: all of them — pure
                      tensor parallelism; must divide n_devices)
    """
    n = int(n_devices) if n_devices else len(jax.devices())
    m = int(model_parallel) if model_parallel else n
    if m <= 0 or n % m != 0:
        raise ValueError(
            f"model_parallel={m} does not divide n_devices={n}")
    return jax.make_mesh((n // m, m), ("data", "model"))


def make_production_mesh(*, multi_pod: bool = False):
    if multi_pod:
        return jax.make_mesh((2, 16, 16), ("pod", "data", "model"))
    return make_mesh_for(256, model_parallel=16)


def make_test_mesh(data: int = 2, model: int = 2, pod: int = 0):
    """Small host-device mesh for unit tests (requires
    XLA_FLAGS=--xla_force_host_platform_device_count >= data*model*max(pod,1))."""
    if pod:
        return jax.make_mesh((pod, data, model), ("pod", "data", "model"))
    return jax.make_mesh((data, model), ("data", "model"))


def make_slice_mesh(devices, model_parallel: int):
    """Mesh over a *sub-slice* of a pod (Clover serving instance): the given
    devices become a (1, model_parallel) (data, model) mesh."""
    import numpy as np
    devs = np.asarray(devices).reshape(1, model_parallel)
    from jax.sharding import Mesh
    return Mesh(devs, ("data", "model"))
