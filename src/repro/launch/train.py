"""Training driver: real training of a (reduced) assigned architecture with
checkpoint/restart fault tolerance and the full substrate (AdamW, schedule,
grad accumulation, async checkpointing, deterministic data).

Usage:
  PYTHONPATH=src python -m repro.launch.train --arch qwen2-0.5b --steps 200 \
      --ckpt-dir /tmp/ckpt [--resume] [--simulate-crash-at 100]
"""
from __future__ import annotations

import argparse
import sys
import time


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-0.5b")
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--scale", type=float, default=1.0,
                    help="depth/width scale of the smoke config")
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--accum", type=int, default=1)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--simulate-crash-at", type=int, default=0)
    args = ap.parse_args(argv)

    import jax
    import jax.numpy as jnp

    from repro.configs import get_smoke_config
    from repro.models import registry as R
    from repro.train import checkpoint as CKPT
    from repro.train import data as DATA
    from repro.train import optimizer as O
    from repro.train import train_loop as TL

    cfg = get_smoke_config(args.arch)
    if args.scale != 1.0:
        cfg = cfg.with_(n_layers=max(int(cfg.n_layers * args.scale), 1))
    cfg = cfg.with_(dtype=jnp.float32)
    opt_cfg = O.AdamWConfig(lr=args.lr, warmup_steps=20, total_steps=args.steps)

    ds = DATA.SyntheticLM(DATA.DataConfig(cfg.vocab_size, args.seq, args.batch))
    start_step = 0
    params = R.init_params(jax.random.PRNGKey(0), cfg)
    state = TL.make_train_state(params, opt_cfg)
    if args.resume and CKPT.latest_step(args.ckpt_dir) is not None:
        start_step = CKPT.latest_step(args.ckpt_dir)
        state = CKPT.restore(args.ckpt_dir, jax.eval_shape(lambda: state))
        print(f"[train] resumed from step {start_step}")

    if args.accum > 1:
        step_fn = jax.jit(TL.make_grad_accum_train_step(cfg, opt_cfg,
                                                        args.accum,
                                                        batch_axes=()))
    else:
        step_fn = jax.jit(TL.make_train_step(cfg, opt_cfg))
    ckpt = CKPT.AsyncCheckpointer(args.ckpt_dir, keep=3)

    t0 = time.perf_counter()
    n_params = sum(x.size for x in jax.tree.leaves(state["params"]))
    print(f"[train] {cfg.name}: {n_params/1e6:.1f}M params, "
          f"{args.steps} steps from {start_step}")
    for step, batch in zip(range(start_step, args.steps),
                           ds.batches(start_step)):
        state, m = step_fn(state, {k: jnp.asarray(v) for k, v in batch.items()})
        if args.simulate_crash_at and step + 1 == args.simulate_crash_at:
            ckpt.submit(state, step + 1)
            ckpt.wait()
            print(f"[train] simulated crash at step {step + 1} "
                  f"(checkpoint durable; rerun with --resume)")
            return 0
        if (step + 1) % args.ckpt_every == 0 or step + 1 == args.steps:
            ckpt.submit(state, step + 1)
        if (step + 1) % 20 == 0 or step == start_step:
            dt = time.perf_counter() - t0
            print(f"[train] step {step+1:5d} loss={float(m['loss']):.4f} "
                  f"lr={float(m['lr']):.2e} gnorm={float(m['grad_norm']):.2f} "
                  f"({dt:.0f}s)", flush=True)
    ckpt.close()
    print(f"[train] done: final loss {float(m['loss']):.4f}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
