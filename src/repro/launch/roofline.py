"""Three-term roofline analysis over dry-run artifacts (EXPERIMENTS.md §Roofline).

  compute    = dot_FLOPs/dev ÷ 197 TF/s          (bf16 MXU peak, v5e)
  memory     = HBM traffic/dev ÷ 819 GB/s
  collective = wire bytes/dev ÷ 50 GB/s           (per-link ICI)

dot_FLOPs and wire bytes come from the loop-aware HLO analysis (exact, trip-
count-scaled).  HBM traffic is a documented estimate built from the compiled
memory footprint, because XLA's bytes-accessed also suffers the loop-body
undercount:
  train    : 3×args + 2×temps   (fwd + remat-fwd + bwd weight reads; live
                                  activation write+read; opt read-modify-write)
  prefill  : 1×args + 2×temps
  decode   : 1×args + 1×temps   (weights + KV cache are the arguments and are
                                  each streamed once — the exact decode bound)

MODEL_FLOPS: 6·N_active·tokens (train), 2·N_active·tokens (prefill),
2·N_active·batch + attention·KV (decode); the ratio to compiled dot-FLOPs
surfaces remat/redundancy waste (a ratio ≪ 1 means the compiled graph does
that much more work than the math requires).
"""
from __future__ import annotations

import json
from typing import Dict, List, Optional

from repro.configs import get_config
from repro.configs import shapes as SH

PEAK_FLOPS = 197e12
HBM_BW = 819e9
ICI_BW = 50e9

_TRAFFIC_COEF = {"train": (3.0, 2.0), "prefill": (1.0, 2.0), "decode": (1.0, 1.0)}


def model_flops_per_device(arch: str, shape: str, devices: int) -> float:
    cfg = get_config(arch)
    cell = SH.SHAPES[shape]
    n_act = cfg.active_param_count()
    if cell.kind == "train":
        tokens = cell.global_batch * cell.seq_len
        total = 6.0 * n_act * tokens
    elif cell.kind == "prefill":
        tokens = cell.global_batch * cell.seq_len
        total = 2.0 * n_act * tokens + cfg.flops_per_token(cell.seq_len) * tokens \
            - 2.0 * n_act * tokens  # flops_per_token already includes 2·N
        total = cfg.flops_per_token(cell.seq_len) * tokens
    else:
        total = cfg.flops_per_token(cell.seq_len, decode=True) * cell.global_batch
    return total / devices


def roofline_row(rec: Dict) -> Optional[Dict]:
    if rec.get("skipped"):
        return None
    kind = SH.SHAPES[rec["shape"]].kind
    ka, kt = _TRAFFIC_COEF[kind]
    mem = rec["memory"]
    traffic = ka * mem["argument_bytes"] + kt * mem["temp_bytes"]

    t_compute = rec["dot_flops_per_device"] / PEAK_FLOPS
    t_memory = traffic / HBM_BW
    t_coll = rec["collectives"]["total_collective_bytes"] / ICI_BW
    terms = {"compute": t_compute, "memory": t_memory, "collective": t_coll}
    dominant = max(terms, key=terms.get)
    mf = model_flops_per_device(rec["arch"], rec["shape"], rec["devices"])
    bound = max(terms.values())
    return {
        "arch": rec["arch"], "shape": rec["shape"], "mesh": rec["mesh"],
        "t_compute_s": t_compute, "t_memory_s": t_memory,
        "t_collective_s": t_coll, "dominant": dominant,
        "model_flops_per_device": mf,
        "useful_flops_ratio": mf / max(rec["dot_flops_per_device"], 1.0),
        "step_time_bound_s": bound,
        "roofline_fraction": (mf / PEAK_FLOPS) / bound if bound > 0 else 0.0,
        "mem_footprint_gib": mem["peak_per_device_bytes"] / 2**30,
        "fits_hbm": mem["peak_per_device_bytes"] <= 16 * 2**30,
    }


def analyze_file(path: str, mesh: str = "16x16") -> List[Dict]:
    rows = []
    for rec in json.load(open(path)):
        if rec.get("skipped") or rec.get("mesh") != mesh:
            continue
        row = roofline_row(rec)
        if row:
            rows.append(row)
    return rows


def to_markdown(rows: List[Dict]) -> str:
    hdr = ("| arch | shape | compute s | memory s | collective s | dominant "
           "| MODEL/HLO flops | roofline frac | mem GiB | fits |\n"
           "|---|---|---|---|---|---|---|---|---|---|\n")
    out = [hdr]
    for r in rows:
        out.append(
            f"| {r['arch']} | {r['shape']} | {r['t_compute_s']:.3e} "
            f"| {r['t_memory_s']:.3e} | {r['t_collective_s']:.3e} "
            f"| **{r['dominant']}** | {r['useful_flops_ratio']:.2f} "
            f"| {r['roofline_fraction']:.3f} | {r['mem_footprint_gib']:.1f} "
            f"| {'✓' if r['fits_hbm'] else '✗'} |\n")
    return "".join(out)


def main(argv=None):
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--in", dest="inp", default="dryrun_results.json")
    ap.add_argument("--mesh", default="16x16")
    ap.add_argument("--json-out", default=None)
    args = ap.parse_args(argv)
    rows = analyze_file(args.inp, args.mesh)
    print(to_markdown(rows))
    if args.json_out:
        with open(args.json_out, "w") as f:
            json.dump(rows, f, indent=1)


if __name__ == "__main__":
    main()
