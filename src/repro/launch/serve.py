"""Clover serving driver: run the full carbon-aware serving loop.

Modes:
  --mode sim    48 h trace simulation for any (--family, --scheme) pair —
                the paper's evaluation rig.
  --mode real   real JAX execution of a reduced LM quality ladder on this
                host (measured wall latencies feed the controller).

Usage:
  PYTHONPATH=src python -m repro.launch.serve --mode sim --family efficientnet
  PYTHONPATH=src python -m repro.launch.serve --mode real --arch qwen3-1.7b
"""
from __future__ import annotations

import argparse
import json
import sys


def run_sim(args) -> int:
    from repro.core import carbon as CB
    from repro.serving import simulator as SIM
    tr = CB.make_trace(args.region, hours=args.hours)
    rep = SIM.run_trace(args.scheme, args.family, tr,
                        SIM.SimConfig(n_blocks=args.blocks, lam=args.lam))
    base = SIM.run_trace("BASE", args.family, tr,
                         SIM.SimConfig(n_blocks=args.blocks, lam=args.lam))
    out = {
        "scheme": args.scheme,
        "family": args.family,
        "region": args.region,
        "carbon_saving_pct": (1 - rep.carbon_per_req_g()
                              / base.carbon_per_req_g()) * 100,
        "accuracy_delta_pct": (rep.accuracy - base.accuracy)
                              / base.accuracy * 100,
        "p95_vs_sla": rep.p95_latency_s / rep.sla_target_s,
        "opt_time_pct": rep.opt_time_frac * 100,
        "invocations": rep.n_invocations,
    }
    print(json.dumps(out, indent=1))
    return 0


def run_real(args) -> int:
    import random

    import jax.numpy as jnp
    import numpy as np

    from repro.configs import get_smoke_config
    from repro.core import annealing as SA
    from repro.core import carbon as CB
    from repro.core import config_graph as CG
    from repro.core import objective as OBJ
    from repro.serving import engine as ENG
    from repro.serving.api import serve_prompts as serve

    base_cfg = get_smoke_config(args.arch).with_(n_layers=8, dtype=jnp.float32)
    fam = ENG.build_engine_family(base_cfg, fracs=(1.0, 0.5, 0.25))
    eng = ENG.RealEngine(fam)
    variants = [ev.variant for ev in fam]
    trace = CB.make_trace(args.region, hours=1.0)
    rng = random.Random(0)

    g = CG.ConfigGraph.from_dict(base_cfg.name,
                                 {(variants[-1].name, 8): 2})
    print(f"[serve] initial config: {dict(g.edges)}")
    eng.configure(g)
    prompts = [np.array([[1, 5, 9, 2]], dtype=np.int32) for _ in range(args.requests)]
    m0 = serve(eng, prompts, 4)
    print(f"[serve] BASE-quality: p95={m0['p95_s']*1e3:.0f}ms "
          f"energy={m0['energy_j']:.1f}J acc={m0['mean_accuracy']:.2f}")

    # one Clover invocation against the measured latencies
    obj = OBJ.ObjectiveConfig(lam=args.lam, a_base=m0["mean_accuracy"],
                              c_base=m0["energy_j"] / m0["served"] / 3.6e6 * 380 * 1.5,
                              l_tail_s=m0["p95_s"] * 1.2)

    def evaluator(graph):
        dt = eng.configure(graph)
        m = serve(eng, prompts[: max(4, args.requests // 4)], 4)
        cap = m["served"] / max(sum(x for x in (m["p95_s"],)), 1e-9)
        return OBJ.EvalResult(m["mean_accuracy"], 1.0 / m["p50_s"], 0.5,
                              m["p95_s"], 0.0,
                              m["energy_j"] / m["served"])

    out = SA.anneal(g, variants, evaluator, ci=trace.at(0), obj_cfg=obj,
                    sa_cfg=SA.SAConfig(stale_limit=3, eval_window_s=0.0),
                    rng=rng)
    print(f"[serve] Clover chose {dict(out.best.edges)} after {out.n_evals} "
          f"real evaluations; f={out.best_f:.2f}")
    eng.configure(out.best)
    m1 = serve(eng, prompts, 4)
    print(f"[serve] CLOVER: p95={m1['p95_s']*1e3:.0f}ms "
          f"energy={m1['energy_j']:.1f}J acc={m1['mean_accuracy']:.2f} "
          f"(energy saving {100*(1-m1['energy_j']/m0['energy_j']):.0f}%)")
    return 0


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--mode", choices=("sim", "real"), default="sim")
    ap.add_argument("--family", default="efficientnet")
    ap.add_argument("--arch", default="qwen3-1.7b")
    ap.add_argument("--scheme", default="CLOVER")
    ap.add_argument("--region", default="CISO-March")
    ap.add_argument("--hours", type=float, default=48.0)
    ap.add_argument("--blocks", type=int, default=4)
    ap.add_argument("--lam", type=float, default=0.1)
    ap.add_argument("--requests", type=int, default=16)
    args = ap.parse_args(argv)
    return run_sim(args) if args.mode == "sim" else run_real(args)


if __name__ == "__main__":
    sys.exit(main())
