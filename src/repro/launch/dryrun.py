import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# ^ MUST be the first lines, before any jax import: jax locks the device count
#   on first backend init.  512 host devices stand in for 2 pods x 256 chips.

# Multi-pod dry-run: lower + compile every (architecture × input-shape × mesh)
# cell on the production meshes, extract memory / FLOP / collective statistics,
# and emit the roofline table inputs.
#
# Usage:
#   PYTHONPATH=src python -m repro.launch.dryrun                      # all cells
#   PYTHONPATH=src python -m repro.launch.dryrun --arch glm4-9b --shape decode_32k
#   PYTHONPATH=src python -m repro.launch.dryrun --multi-pod-only --out out.json

import argparse
import json
import re
import sys
import time
from typing import Dict, Optional

import jax
import numpy as np

from repro.configs import ARCHS, get_config, get_optimized_config
from repro.configs import shapes as SH
from repro.launch import hlo_analysis as HA
from repro.launch import mesh as MESH
from repro.launch import steps


# =============================================================================
# one cell
# =============================================================================
def run_cell(arch: str, shape: str, multi_pod: bool, *,
             seq_shard: Optional[bool] = None, verbose: bool = True,
             optimized: bool = False) -> Dict:
    cfg = get_optimized_config(arch) if optimized else get_config(arch)
    if not SH.cell_applicable(cfg, shape):
        return {"arch": arch, "shape": shape, "skipped": True,
                "reason": "full-attention arch skips long_500k (DESIGN.md)"}
    mesh = MESH.make_production_mesh(multi_pod=multi_pod)
    n_dev = mesh.size
    t0 = time.time()
    with mesh:
        kw = {}
        if SH.SHAPES[shape].kind == "decode" and seq_shard is not None:
            kw["seq_shard"] = seq_shard
        jitted, sds = steps.build_step_for_cell(cfg, mesh, shape, **kw)
        lowered = jitted.lower(*sds)
        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower

        ca = compiled.cost_analysis() or {}
        ma = compiled.memory_analysis()
        hlo = compiled.as_text()
        stats = HA.analyze(hlo, n_dev)

    res = {
        "arch": arch,
        "shape": shape,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "devices": n_dev,
        "lower_s": round(t_lower, 1),
        "compile_s": round(t_compile, 1),
        # loop-aware (trip-count-scaled) stats — the roofline inputs:
        "dot_flops_per_device": stats.dot_flops,
        "collectives": stats.as_dict(),
        # raw XLA cost analysis (loop bodies counted once — reference only):
        "xla_flops_per_device_raw": float(ca.get("flops", 0.0)),
        "xla_bytes_accessed_raw": float(ca.get("bytes accessed", 0.0)),
        "memory": {
            "argument_bytes": ma.argument_size_in_bytes,
            "output_bytes": ma.output_size_in_bytes,
            "temp_bytes": ma.temp_size_in_bytes,
            "alias_bytes": ma.alias_size_in_bytes,
            "peak_per_device_bytes": ma.argument_size_in_bytes
                                     + ma.output_size_in_bytes
                                     + ma.temp_size_in_bytes
                                     - ma.alias_size_in_bytes,
        },
    }
    if verbose:
        mem_gb = res["memory"]["peak_per_device_bytes"] / 2**30
        print(f"[dryrun] {arch:24s} {shape:12s} {res['mesh']:8s} "
              f"dotflops/dev={stats.dot_flops:.3e} "
              f"mem/dev={mem_gb:6.2f}GiB "
              f"coll={stats.total_coll_bytes/2**20:9.1f}MiB "
              f"(lower {t_lower:.0f}s compile {t_compile:.0f}s)",
              flush=True)
    return res


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None, choices=ARCHS + [None])
    ap.add_argument("--shape", default=None, choices=list(SH.SHAPES) + [None])
    ap.add_argument("--single-pod-only", action="store_true")
    ap.add_argument("--multi-pod-only", action="store_true")
    ap.add_argument("--seq-shard", default=None,
                    choices=[None, "on", "off"],
                    help="override KV sequence sharding for decode cells")
    ap.add_argument("--optimized", action="store_true",
                    help="apply the §Perf optimized per-arch overrides")
    ap.add_argument("--out", default="dryrun_results.json")
    args = ap.parse_args(argv)

    archs = [args.arch] if args.arch else ARCHS
    shape_names = [args.shape] if args.shape else list(SH.SHAPES)
    meshes = [False, True]
    if args.single_pod_only:
        meshes = [False]
    if args.multi_pod_only:
        meshes = [True]
    seq_shard = None if args.seq_shard is None else (args.seq_shard == "on")

    results, failures = [], []
    for multi_pod in meshes:
        for arch in archs:
            for shape in shape_names:
                try:
                    results.append(run_cell(arch, shape, multi_pod,
                                            seq_shard=seq_shard,
                                            optimized=args.optimized))
                except Exception as e:  # a failure here is a bug in the system
                    failures.append((arch, shape, multi_pod, repr(e)))
                    print(f"[dryrun] FAIL {arch} {shape} multi_pod={multi_pod}: {e}",
                          flush=True)

    with open(args.out, "w") as f:
        json.dump(results, f, indent=1)
    n_run = sum(1 for r in results if not r.get("skipped"))
    n_skip = sum(1 for r in results if r.get("skipped"))
    print(f"[dryrun] wrote {args.out}: {n_run} compiled, {n_skip} skipped, "
          f"{len(failures)} failed")
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())
