"""Builders that assemble (step_fn, abstract inputs, shardings) triples for
train / prefill / decode, shared by the dry-run, the drivers and tests.

Everything here is allocation-free: abstract params come from
``jax.eval_shape`` over the initializers, inputs are ShapeDtypeStructs.
"""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import shapes as SH
from repro.models import registry as R
from repro.models.config import ModelConfig
from repro.sharding import rules
from repro.train import optimizer as O
from repro.train import train_loop as TL


def abstract_params(cfg: ModelConfig):
    key = jax.ShapeDtypeStruct((2,), jnp.uint32)
    return jax.eval_shape(functools.partial(R.init_params, cfg=cfg), key)


def named(tree_specs, mesh):
    return jax.tree.map(lambda s: NamedSharding(mesh, s), tree_specs,
                        is_leaf=lambda x: isinstance(x, P))


# =============================================================================
# train
# =============================================================================
def default_accum(cfg: ModelConfig, mesh, shape: str) -> int:
    """Microbatch count: target ≤4 sequences per device per microbatch."""
    cell = SH.SHAPES[shape]
    dp = rules.axes_size(mesh, rules.data_axes(mesh))
    b_local = max(cell.global_batch // dp, 1)
    accum = max(b_local // 4, 1)
    while cell.global_batch % (accum * dp) and accum > 1:
        accum //= 2
    return accum


def build_train(cfg: ModelConfig, mesh, shape: str = "train_4k",
                opt_cfg: Optional[O.AdamWConfig] = None,
                accum: Optional[int] = None,
                zero1: bool = True):
    """Returns (step_fn_jitted, (state_sds, batch_sds)).

    ``accum``: gradient-accumulation microbatches (None = auto: ≤4 seqs per
    device per microbatch).  ``zero1``: shard AdamW m/v over the data axes."""
    opt_cfg = opt_cfg or O.AdamWConfig()
    params_sds = abstract_params(cfg)
    opt_sds = jax.eval_shape(O.init_opt_state, params_sds)
    state_sds = {"params": params_sds, "opt": opt_sds}

    p_specs = rules.param_specs(params_sds, cfg, mesh)
    mv_specs = (rules.opt_state_specs(params_sds, cfg, mesh) if zero1 else p_specs)
    state_specs = {
        "params": p_specs,
        "opt": {"m": mv_specs, "v": mv_specs, "step": P()},
    }
    batch_sds = SH.input_specs(cfg, shape)
    batch_specs = rules.batch_specs(batch_sds, mesh)

    if accum is None:
        accum = default_accum(cfg, mesh, shape)
    if accum > 1:
        step = TL.make_grad_accum_train_step(cfg, opt_cfg, accum,
                                             batch_axes=rules.data_axes(mesh))
    else:
        step = TL.make_train_step(cfg, opt_cfg)
    metric_specs = {"loss": P(), "aux_loss": P(), "ppl_proxy": P(),
                    "grad_norm": P(), "lr": P()}
    jitted = jax.jit(
        step,
        in_shardings=(named(state_specs, mesh), named(batch_specs, mesh)),
        out_shardings=(named(state_specs, mesh), named(metric_specs, mesh)),
        donate_argnums=(0,),
    )
    return jitted, (state_sds, batch_sds)


# =============================================================================
# serve: prefill
# =============================================================================
def build_prefill(cfg: ModelConfig, mesh, shape: str = "prefill_32k"):
    params_sds = abstract_params(cfg)
    p_specs = rules.param_specs(params_sds, cfg, mesh)
    batch_sds = SH.input_specs(cfg, shape)
    batch_specs = rules.batch_specs(batch_sds, mesh)

    def prefill(params, batch):
        logits, _ = R.forward(params, batch, cfg, train=False)
        return logits

    cell = SH.SHAPES[shape]
    batch_axes = rules.fit_axes(mesh, rules.data_axes(mesh), cell.global_batch)
    out_spec = P(batch_axes, None, rules.MODEL_AXIS)
    jitted = jax.jit(
        prefill,
        in_shardings=(named(p_specs, mesh), named(batch_specs, mesh)),
        out_shardings=NamedSharding(mesh, out_spec),
    )
    return jitted, (params_sds, batch_sds)


# =============================================================================
# serve: decode
# =============================================================================
def build_decode(cfg: ModelConfig, mesh, shape: str = "decode_32k",
                 seq_shard: Optional[bool] = None):
    """serve_step: one new token against a seq_len KV cache.

    ``seq_shard`` — shard the KV sequence dim over (data, model) instead of
    batch/heads; defaults on for the 500k cell (batch too small to shard).
    """
    cell = SH.SHAPES[shape]
    if seq_shard is None:
        seq_shard = cell.global_batch < 8
    params_sds = abstract_params(cfg)
    p_specs = rules.param_specs(params_sds, cfg, mesh)

    batch_sds = SH.input_specs(cfg, shape)
    batch_specs = rules.batch_specs(batch_sds, mesh)

    src_sds = SH.src_embeds_spec(cfg, shape)
    cache_sds = jax.eval_shape(
        functools.partial(R.make_cache, cfg=cfg, batch_size=cell.global_batch,
                          max_len=cell.seq_len),
        params_sds, src_embeds=src_sds)
    cache_specs = rules.cache_specs(cache_sds, mesh, cfg, seq_shard=seq_shard)

    def serve_step(params, cache, batch):
        return R.decode_step(params, cache, batch, cfg)

    batch_axes = rules.fit_axes(mesh, rules.data_axes(mesh), cell.global_batch)
    logits_out = NamedSharding(mesh, P(batch_axes, rules.MODEL_AXIS))
    jitted = jax.jit(
        serve_step,
        in_shardings=(named(p_specs, mesh), named(cache_specs, mesh),
                      named(batch_specs, mesh)),
        out_shardings=(logits_out, named(cache_specs, mesh)),
        donate_argnums=(1,),
    )
    return jitted, (params_sds, cache_sds, batch_sds)


def build_step_for_cell(cfg: ModelConfig, mesh, shape: str, **kw):
    kind = SH.SHAPES[shape].kind
    if kind == "train":
        return build_train(cfg, mesh, shape, **kw)
    if kind == "prefill":
        return build_prefill(cfg, mesh, shape, **kw)
    return build_decode(cfg, mesh, shape, **kw)
