"""GSPMD sharding rules: parameter-path → PartitionSpec.

Logical layout on the production mesh (pod, data, model):
  * parameters replicated over (pod, data); tensor-parallel / expert-parallel
    over ``model`` (Megatron-style column→row pairs; MoE experts over model).
  * batch over (pod, data); long-context KV optionally sequence-sharded (SP).

Non-divisible cases (14 heads / 16-way model, vocab 256206) rely on GSPMD
padding — correct, with the padding overhead surfaced by the dry-run's
memory analysis and discussed in EXPERIMENTS.md.
"""
from __future__ import annotations

import re
from typing import Optional, Sequence, Tuple

import jax
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.models.config import ModelConfig

MODEL_AXIS = "model"

# Ordered (regex on 'a/b/c' param path, spec for the *unstacked* leaf).
# First match wins.  ``M`` marks the model axis position.
#
# Head-alignment guards (resolved against cfg × mesh in spec_for_param):
#   * attention q projections shard over model ONLY if n_heads    % model == 0
#   * attention k/v projections            ONLY if n_kv_heads % model == 0
#   (otherwise GSPMD slices *inside* d_head and partial-dh dot products get
#    all-reduced at activation size — observed 1.5 GiB per layer on glm4-like
#    configs.  Replicated KV projections = the standard GQA TP fallback.)
#   * MoE experts shard over model if n_experts % model == 0 (EP), else the
#     expert-FF dim shards (TP-within-expert; qwen2-moe's 60 experts on a
#     16-way axis).
_RULES: Sequence[Tuple[str, Tuple[Optional[str], ...]]] = (
    (r"embed/table$",            ("M", None)),          # vocab-sharded
    (r"lm_head/w$",              (None, "M")),
    (r"wq/w$",                   (None, "Q")),
    (r"wq/b$",                   ("Q",)),
    (r"(wk|wv)/w$",              (None, "K")),
    (r"(wk|wv)/b$",              ("K",)),
    (r"attn/wo/w$",              ("Q", None)),
    (r"xattn/wo/w$",             ("Q", None)),
    (r"(q_norm|k_norm)/scale$",  (None,)),
    (r"(wi_gate|wi_up)/w$",      (None, "M")),
    (r"mlp/wo/w$",               ("M", None)),
    (r"shared/wo/w$",            ("M", None)),          # MoE shared-expert down
    (r"moe/router$",             (None, None)),
    (r"(w_gate|w_up)$",          ("E", None, "F")),     # expert- or FF-parallel
    (r"w_down$",                 ("E", "F", None)),
    # --- mamba2 ---------------------------------------------------------
    (r"(wz|wx|wdt)/w$",          (None, "M")),
    (r"(wB|wC)/w$",              (None, None)),
    (r"conv_x$",                 (None, "M")),
    (r"(conv_B|conv_C)$",        (None, None)),
    (r"conv_bx$",                ("M",)),
    (r"(conv_bB|conv_bC)$",      (None,)),
    (r"(A_log|D|dt_bias)$",      ("M",)),
    (r"out_proj/w$",             ("M", None)),
    # --- norms / default ---------------------------------------------------
    (r"scale$",                  (None,)),
    (r"bias$",                   (None,)),
    (r"b$",                      (None,)),
)

_STACKED_PREFIXES = ("layers/", "enc_layers/", "dec_layers/")


def _path_str(path) -> str:
    parts = []
    for k in path:
        if hasattr(k, "key"):
            parts.append(str(k.key))
        else:
            parts.append(str(k))
    return "/".join(parts)


def spec_for_param(path_str: str, ndim: int, cfg: ModelConfig,
                   n_model: int) -> P:
    stacked = path_str.startswith(_STACKED_PREFIXES)
    eff_ndim = ndim - 1 if stacked else ndim
    q_ok = cfg.n_heads > 0 and cfg.n_heads % n_model == 0
    k_ok = cfg.n_kv_heads > 0 and cfg.n_kv_heads % n_model == 0
    e_ok = cfg.n_experts > 0 and cfg.padded_experts % n_model == 0

    def resolve(a):
        if a == "M":
            return MODEL_AXIS
        if a == "Q":
            return MODEL_AXIS if q_ok else None
        if a == "K":
            return MODEL_AXIS if k_ok else None
        if a == "E":
            return MODEL_AXIS if e_ok else None
        if a == "F":
            return None if e_ok else MODEL_AXIS
        return None

    for pat, spec in _RULES:
        if re.search(pat, path_str):
            axes = tuple(resolve(a) for a in spec)
            if len(axes) != eff_ndim:
                # rank-mismatched rule (e.g. scalar norm) → replicate
                axes = (None,) * eff_ndim
            if stacked:
                axes = (None,) + axes
            return P(*axes)
    return P(*([None] * ndim))


def param_specs(params_shape_tree, cfg: ModelConfig, mesh) -> dict:
    """PartitionSpec pytree matching an (eval_shape'd) params tree."""
    n_model = mesh.shape[MODEL_AXIS]
    return jax.tree_util.tree_map_with_path(
        lambda path, leaf: spec_for_param(_path_str(path), len(leaf.shape),
                                          cfg, n_model),
        params_shape_tree)


def param_shardings(params_shape_tree, cfg: ModelConfig, mesh) -> dict:
    return jax.tree.map(lambda s: NamedSharding(mesh, s),
                        param_specs(params_shape_tree, cfg, mesh))


def opt_state_specs(params_shape_tree, cfg: ModelConfig, mesh) -> dict:
    """ZeRO-1: AdamW m/v shards over the data axes *in addition to* the model
    axis.  Per leaf, greedily (a) extend the model-sharded dim across
    (pod, data) when divisible, else (b) shard the largest replicated dim over
    the data axes.  GSPMD inserts the reduce-scatter / all-gather pair this
    implies around the optimizer update — the ZeRO-1 communication pattern."""
    p_specs = param_specs(params_shape_tree, cfg, mesh)
    dp = data_axes(mesh)
    dp_size = axes_size(mesh, dp)
    n_model = mesh.shape[MODEL_AXIS]

    def extend(spec: P, leaf) -> P:
        if dp_size == 1 or not leaf.shape:
            return spec
        dims = list(spec) + [None] * (len(leaf.shape) - len(spec))
        for i, ax in enumerate(dims):          # (a) widen the model dim
            if ax == MODEL_AXIS and leaf.shape[i] % (n_model * dp_size) == 0:
                dims[i] = (MODEL_AXIS,) + dp
                return P(*dims)
        order = sorted(range(len(dims)), key=lambda i: -leaf.shape[i])
        for i in order:                        # (b) shard a replicated dim
            if dims[i] is None and leaf.shape[i] % dp_size == 0 and leaf.shape[i] >= dp_size:
                dims[i] = dp
                return P(*dims)
        return spec

    return jax.tree.map(extend, p_specs, params_shape_tree)


# =============================================================================
# activation / batch / cache specs
# =============================================================================
def data_axes(mesh) -> Tuple[str, ...]:
    """Batch-parallel mesh axes: ('pod', 'data') when pod axis exists."""
    names = mesh.axis_names
    return tuple(a for a in ("pod", "data") if a in names)


def axes_size(mesh, axes) -> int:
    n = 1
    for a in (axes if isinstance(axes, (tuple, list)) else [axes]):
        n *= mesh.shape[a]
    return n


def fit_axes(mesh, axes, size: int):
    """Largest prefix of ``axes`` whose product divides ``size`` (batch=1
    long-context cells keep the batch dim unsharded)."""
    chosen = []
    for a in (axes if isinstance(axes, (tuple, list)) else [axes]):
        if size % (axes_size(mesh, chosen + [a])) == 0:
            chosen.append(a)
    if not chosen:
        return None
    return tuple(chosen)


def batch_specs(batch_shape_tree, mesh) -> dict:
    """Shard the leading batch dim over (pod, data); mrope positions have the
    batch dim second.  Falls back to fewer/no axes when not divisible."""
    dp = data_axes(mesh)

    def spec(path, leaf):
        name = _path_str(path)
        nd = len(leaf.shape)
        if "mrope" in name:                       # (3, b, s)
            ax = fit_axes(mesh, dp, leaf.shape[1])
            return P(None, ax, *([None] * (nd - 2)))
        ax = fit_axes(mesh, dp, leaf.shape[0])
        return P(ax, *([None] * (nd - 1)))

    return jax.tree_util.tree_map_with_path(spec, batch_shape_tree)


def cache_specs(cache_shape_tree, mesh, cfg: ModelConfig, *,
                seq_shard: bool = False) -> dict:
    """Decode-state sharding.

    Default: batch → (pod, data); KV heads → model (GSPMD pads non-divisible
    head counts).  ``seq_shard=True`` (long-context, batch too small to
    data-shard): KV sequence dim → (data, model) jointly — the SP layout.
    SSM states: batch → (pod, data); head/channel dims → model.
    """
    dp = data_axes(mesh)

    def spec(path, leaf):
        name = _path_str(path)
        nd = len(leaf.shape)
        if name in ("pos", "prefix_len"):
            return P()
        if name in ("sk", "sv"):
            # append-buffer suffix: small, replicated over model (local DUS)
            ax = fit_axes(mesh, dp, leaf.shape[1])
            return P(None, ax, None, None, None)
        if "ssm" in name:
            # stacked (L, b, ...) buffers: conv_* (L,b,k-1,C) / state (L,b,H,P,N)
            ax = fit_axes(mesh, dp, leaf.shape[1])
            if name.endswith("state"):
                return P(None, ax, MODEL_AXIS, None, None)
            if name.endswith("conv_x"):
                return P(None, ax, None, MODEL_AXIS)
            return P(None, ax, None, None)
        # KV caches, (L, b, S, K, dh) (self or cross)
        if nd == 5:
            if seq_shard:
                sp = fit_axes(mesh, ("data", MODEL_AXIS), leaf.shape[2])
                return P(None, None, sp, None, None)
            ax = fit_axes(mesh, dp, leaf.shape[1])
            if leaf.shape[3] % mesh.shape[MODEL_AXIS] == 0:
                return P(None, ax, None, MODEL_AXIS, None)   # KV heads → model
            # few-KV-head archs (glm4 kv=2, qwen3-moe kv=4): sequence → model
            sp = fit_axes(mesh, (MODEL_AXIS,), leaf.shape[2])
            return P(None, ax, sp, None, None)
        return P(*([None] * nd))

    return jax.tree_util.tree_map_with_path(spec, cache_shape_tree)


def arena_spec(mesh, cfg: ModelConfig) -> P:
    """PartitionSpec for the paged KV arena ``(L, n_blocks, bs, K, dh)``.

    KV heads shard over ``model``; the block map dims (n_blocks, bs) stay
    unsharded because block tables live host-side and index whole pages.

    Unlike :func:`cache_specs`, non-divisible head counts are an explicit
    ERROR here rather than a silent fallback: a paged arena has no
    contiguous sequence dim to sequence-shard (blocks *are* the map), and
    letting GSPMD pad inside the trailing head dims would resolve
    ``d_head % model != 0`` by slicing partial-dh dot products that get
    all-reduced at activation size on every donated decode step — the
    glm4-like (n_kv_heads=2) failure mode the dense rules warn about.
    """
    n_model = mesh.shape[MODEL_AXIS]
    if n_model == 1:
        return P(None, None, None, None, None)
    if cfg.n_kv_heads % n_model == 0:
        return P(None, None, None, MODEL_AXIS, None)
    raise ValueError(
        f"paged KV arena cannot shard on the head dim: n_kv_heads="
        f"{cfg.n_kv_heads} is not divisible by the mesh's model axis "
        f"({n_model}), and padding would slice inside d_head "
        f"({cfg.d_head} % {n_model} = {cfg.d_head % n_model}) — GSPMD would "
        f"silently all-reduce partial-head products every decode step. "
        f"Build the mesh with launch.mesh.make_mesh_for(n, model_parallel=m) "
        f"for an m dividing n_kv_heads.")


def arena_shardings(mesh, cfg: ModelConfig) -> NamedSharding:
    """NamedSharding for every leaf of a paged arena ``{"k","v"}`` tree."""
    return NamedSharding(mesh, arena_spec(mesh, cfg))


def logits_spec(mesh) -> P:
    return P(data_axes(mesh), None, MODEL_AXIS)
