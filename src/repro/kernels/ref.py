"""Pure-jnp oracles for every Pallas kernel (the allclose targets).

These are also the implementations the models use on non-TPU backends, so
kernel == ref is both a correctness gate and a backend-parity guarantee.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp


def flash_attention_ref(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
                        *, causal: bool = True,
                        window: int = 0) -> jnp.ndarray:
    """Exact softmax attention.  q: (b, sq, H, dh); k, v: (b, skv, K, dh);
    GQA by head grouping; window > 0 = sliding window.  f32 softmax."""
    b, sq, H, dh = q.shape
    skv, K = k.shape[1], k.shape[2]
    g = H // K
    qg = q.reshape(b, sq, K, g, dh)
    s = jnp.einsum("bqkgd,bnkd->bkgqn", qg.astype(jnp.float32),
                   k.astype(jnp.float32)) * dh ** -0.5
    qpos = jnp.arange(sq)[:, None]
    kpos = jnp.arange(skv)[None, :]
    mask = jnp.ones((sq, skv), dtype=bool)
    if causal:
        mask &= qpos >= kpos
    if window > 0:
        mask &= (qpos - kpos) < window
    s = jnp.where(mask[None, None, None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bkgqn,bnkd->bqkgd", p, v.astype(jnp.float32))
    return o.reshape(b, sq, H, dh).astype(q.dtype)


def decode_attention_ref(q: jnp.ndarray, k_cache: jnp.ndarray,
                         v_cache: jnp.ndarray, length) -> jnp.ndarray:
    """One-position attention over a KV cache.  q: (b, H, dh);
    caches: (b, S, K, dh); length: () shared valid prefix, or (b,) per-row
    valid prefixes (slotted continuous-batching decode)."""
    b, H, dh = q.shape
    S, K = k_cache.shape[1], k_cache.shape[2]
    g = H // K
    qg = q.reshape(b, K, g, dh)
    s = jnp.einsum("bkgd,bnkd->bkgn", qg.astype(jnp.float32),
                   k_cache.astype(jnp.float32)) * dh ** -0.5
    mask = jnp.arange(S)[None, :] < jnp.asarray(length).reshape(-1, 1)
    s = jnp.where(mask[:, None, None, :], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bkgn,bnkd->bkgd", p, v_cache.astype(jnp.float32))
    return o.reshape(b, H, dh).astype(q.dtype)


def paged_decode_attention_ref(q: jnp.ndarray, k_arena: jnp.ndarray,
                               v_arena: jnp.ndarray,
                               block_tables: jnp.ndarray,
                               lengths) -> jnp.ndarray:
    """One-position attention over a PAGED KV cache.  q: (b, H, dh);
    arenas: (n_blocks, block_size, K, dh); block_tables: (b, n_pages) i32
    arena block ids (0-padded — block 0 is the junk sink); lengths: (b,)
    valid token counts.  Gathers each row's pages into a contiguous cache and
    applies the same masking contract as ``decode_attention_ref``."""
    b = q.shape[0]
    _, bs, K, dh = k_arena.shape
    n_pages = block_tables.shape[1]
    kc = k_arena[block_tables].reshape(b, n_pages * bs, K, dh)
    vc = v_arena[block_tables].reshape(b, n_pages * bs, K, dh)
    return decode_attention_ref(q, kc, vc, lengths)


def ssd_ref(x: jnp.ndarray, dt: jnp.ndarray, A: jnp.ndarray, B: jnp.ndarray,
            C: jnp.ndarray, chunk: int,
            init_state: Optional[jnp.ndarray] = None
            ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Chunked SSD oracle — delegates to the model-layer reference (one
    source of truth; see repro.models.ssm.ssd_ref)."""
    from repro.models.ssm import ssd_ref as _impl
    return _impl(x, dt, A, B, C, chunk, init_state)
