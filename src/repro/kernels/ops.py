"""jit'd public wrappers for the Pallas kernels, with backend dispatch:
TPU → compiled Pallas; everything else → interpret mode (bit-accurate kernel
semantics, executed in Python; used for CI validation on CPU) or the pure-jnp
reference (fast CPU path for the models)."""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.kernels import decode_attention as _dec
from repro.kernels import flash_attention as _fa
from repro.kernels import paged_attention as _paged
from repro.kernels import ref as _ref
from repro.kernels import ssd_scan as _ssd


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


@functools.partial(jax.jit, static_argnames=("causal", "window", "use_kernel"))
def flash_attention(q, k, v, *, causal: bool = True, window: int = 0,
                    use_kernel: bool = True):
    if not use_kernel:
        return _ref.flash_attention_ref(q, k, v, causal=causal, window=window)
    return _fa.flash_attention(q, k, v, causal=causal, window=window,
                               interpret=not _on_tpu())


@functools.partial(jax.jit, static_argnames=("use_kernel",))
def decode_attention(q, k_cache, v_cache, length, *, use_kernel: bool = True):
    if not use_kernel:
        return _ref.decode_attention_ref(q, k_cache, v_cache, length)
    return _dec.decode_attention(q, k_cache, v_cache, length,
                                 interpret=not _on_tpu())


@functools.partial(jax.jit, static_argnames=("use_kernel",))
def paged_decode_attention(q, k_arena, v_arena, block_tables, lengths, *,
                           use_kernel: bool = True):
    """Paged flash-decode over block-table KV.  ``lengths`` must be >= 1 per
    row: the kernel early-skips whole pages at or past each row's length
    (``pl.when`` — zero compute for the junk-padded table tail) instead of
    masking them, which is bit-identical only for a non-empty prefix."""
    if not use_kernel:
        return _ref.paged_decode_attention_ref(q, k_arena, v_arena,
                                               block_tables, lengths)
    return _paged.paged_decode_attention(q, k_arena, v_arena, block_tables,
                                         lengths, interpret=not _on_tpu())


@functools.partial(jax.jit, static_argnames=("chunk", "use_kernel"))
def ssd_chunked(x, dt, A, B, C, *, chunk: int = 128, use_kernel: bool = True
                ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    if not use_kernel:
        return _ref.ssd_ref(x, dt, A, B, C, chunk)
    return _ssd.ssd_chunked(x, dt, A, B, C, chunk=chunk,
                            interpret=not _on_tpu())
