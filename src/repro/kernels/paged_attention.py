"""Paged flash-decode attention — Pallas TPU kernel for block-table KV.

One new token attends over a KV cache scattered across fixed-size arena
blocks (the paged KV pool): grid (batch, kv-head, page) with the page axis
sequential, carrying online-softmax state in VMEM scratch exactly like
``decode_attention``.  The physical gather happens in the BlockSpec index
map: the per-sequence block table arrives via **scalar prefetch**
(``PrefetchScalarGridSpec``), so page ``pj`` of sequence ``bi`` DMAs arena
block ``table[bi, pj]`` into VMEM — no materialized contiguous copy of the
cache ever exists.  Padded table entries point at the junk block (id 0);
their positions sit at or past ``lengths[bi]`` and are masked.

**Page-skip contract**: pages whose first position is at or past
``lengths[bi]`` (``pj * block_size >= lengths[bi]`` — exactly the junk-
padded table tail) run ZERO compute under a per-page ``pl.when`` guard
instead of compute-then-mask; only init (``pj == 0``) and finalize
(``pj == npj - 1``) stay unconditional.  This is bit-identical to the
masked path for every ``lengths[bi] >= 1``, because a fully-masked page
contributes exactly nothing to the online softmax (``alpha == 1``,
``p == 0``).  Callers must pass ``lengths >= 1`` per row — the serving
engine only decodes rows with a prefilled prompt, so a zero length never
reaches the kernel (a hypothetical ``lengths == 0`` row now outputs zeros
instead of an average over junk, both garbage by the masking contract).

``kernels/ref.py::paged_decode_attention_ref`` is the CPU oracle (gather +
``decode_attention_ref``), sharing the valid-prefix masking contract with
the slotted kernel.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels._compat import CompilerParams as _CompilerParams

NEG_INF = -1e30


def _paged_kernel(tbl_ref, len_ref, q_ref, k_ref, v_ref, o_ref,
                  m_scr, l_scr, acc_scr, *, sm_scale: float, block_size: int):
    del tbl_ref                               # consumed by the index maps
    pj = pl.program_id(2)
    npj = pl.num_programs(2)
    length = len_ref[pl.program_id(0)]        # per-row valid prefix (SMEM)

    @pl.when(pj == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    # page-skip: a page starting at or past the valid prefix is exactly the
    # junk-padded table tail — every position would mask to NEG_INF and
    # contribute nothing (alpha == 1, p == 0), so skip the dot products and
    # the softmax update entirely instead of computing-then-masking.
    # Bit-identical for lengths >= 1 (see module docstring).
    @pl.when(pj * block_size < length)
    def _page():
        q = q_ref[0, 0]                               # (g, dh)
        k = k_ref[0, 0]                               # (block_size, dh)
        v = v_ref[0, 0]
        g, _ = q.shape

        s = jax.lax.dot_general(q.astype(jnp.float32), k.astype(jnp.float32),
                                (((1,), (1,)), ((), ()))) * sm_scale  # (g, bs)
        # logical position of this page's entries in the sequence
        kpos = pj * block_size + jax.lax.broadcasted_iota(
            jnp.int32, (g, block_size), 1)
        s = jnp.where(kpos < length, s, NEG_INF)

        m_prev = m_scr[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1, keepdims=True))
        p = jnp.exp(s - m_new)
        alpha = jnp.exp(m_prev - m_new)
        l_scr[...] = alpha * l_scr[...] + jnp.sum(p, axis=1, keepdims=True)
        acc_scr[...] = acc_scr[...] * alpha + jax.lax.dot_general(
            p, v.astype(jnp.float32), (((1,), (0,)), ((), ())))
        m_scr[...] = m_new

    @pl.when(pj == npj - 1)
    def _finalize():
        o_ref[0, 0] = (acc_scr[...] / jnp.maximum(l_scr[...], 1e-30)
                       ).astype(o_ref.dtype)


def paged_decode_attention(q: jnp.ndarray, k_arena: jnp.ndarray,
                           v_arena: jnp.ndarray, block_tables: jnp.ndarray,
                           lengths, *, interpret: bool = True) -> jnp.ndarray:
    """q: (b, H, dh); arenas: (n_blocks, block_size, K, dh);
    block_tables: (b, n_pages) i32 arena block ids (0-padded past each row's
    allocation); lengths: (b,) i32 valid token counts, **each >= 1** (pages
    at or past a row's length are skipped, not masked — see the module
    docstring's page-skip contract).  Returns (b, H, dh)."""
    b, H, dh = q.shape
    _, bs, K, _ = k_arena.shape
    n_pages = block_tables.shape[1]
    g = H // K

    qr = q.reshape(b, K, g, dh)
    kr = k_arena.transpose(0, 2, 1, 3)               # (n_blocks, K, bs, dh)
    vr = v_arena.transpose(0, 2, 1, 3)
    tables = jnp.asarray(block_tables, jnp.int32)
    lengths_arr = jnp.broadcast_to(
        jnp.asarray(lengths, jnp.int32).reshape(-1), (b,))

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,                       # tables, lengths
        grid=(b, K, n_pages),
        in_specs=[
            pl.BlockSpec((1, 1, g, dh),
                         lambda bi, ki, pj, tbl, ln: (bi, ki, 0, 0)),
            # the paged gather: page pj of row bi reads arena block
            # tbl[bi, pj] (junk block 0 for padded entries — masked above)
            pl.BlockSpec((1, 1, bs, dh),
                         lambda bi, ki, pj, tbl, ln: (tbl[bi, pj], ki, 0, 0)),
            pl.BlockSpec((1, 1, bs, dh),
                         lambda bi, ki, pj, tbl, ln: (tbl[bi, pj], ki, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, g, dh),
                               lambda bi, ki, pj, tbl, ln: (bi, ki, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((g, 1), jnp.float32),
            pltpu.VMEM((g, 1), jnp.float32),
            pltpu.VMEM((g, dh), jnp.float32),
        ],
    )
    out = pl.pallas_call(
        functools.partial(_paged_kernel, sm_scale=dh ** -0.5, block_size=bs),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((b, K, g, dh), q.dtype),
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(tables, lengths_arr, qr, kr, vr)
    return out.reshape(b, H, dh)
