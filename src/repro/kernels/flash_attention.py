"""Flash attention (prefill) — Pallas TPU kernel.

HBM→VMEM tiling: grid (batch, kv-head, q-block, kv-block); the kv-block axis
is innermost (sequential on TPU), carrying the online-softmax state
(m, l, acc) in VMEM scratch across kv blocks.  Block shapes are multiples of
the MXU tile (q/kv blocks × d_head, d_head ∈ {64, 128}); GQA folds the
q-head group into the block's second-minor dim so the q·kᵀ contraction is a
(g·bq × dh) · (dh × bk) MXU matmul.

Causal + sliding-window masking is applied per block; fully-masked blocks
still run (structural simplicity; the §Perf log quantifies the causal 2×
overcount, and skipping is a recorded optimization).
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels._compat import CompilerParams as _CompilerParams

NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
                  sm_scale: float, block_q: int, block_k: int,
                  causal: bool, window: int, seq_kv: int):
    qb = pl.program_id(2)
    kb = pl.program_id(3)
    nkb = pl.num_programs(3)

    @pl.when(kb == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[0, 0]                                # (g, block_q, dh)
    k = k_ref[0, 0]                                # (block_k, dh)
    v = v_ref[0, 0]
    g, bq, dh = q.shape

    s = jax.lax.dot_general(
        q.reshape(g * bq, dh).astype(jnp.float32),
        k.astype(jnp.float32),
        (((1,), (1,)), ((), ()))) * sm_scale       # (g·bq, block_k)

    qpos = qb * block_q + jax.lax.broadcasted_iota(jnp.int32, (g * bq, block_k), 0) % bq
    # NOTE: rows are (g, bq) flattened with q position = row % bq?  rows are
    # g-major: row = gi * bq + qi, so qi = row % bq — matches the iota above.
    kpos = kb * block_k + jax.lax.broadcasted_iota(jnp.int32, (g * bq, block_k), 1)
    mask = kpos < seq_kv
    if causal:
        mask &= qpos >= kpos
    if window > 0:
        mask &= (qpos - kpos) < window
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_scr[...]                            # (g·bq, 1)
    m_cur = jnp.max(s, axis=1, keepdims=True)
    m_new = jnp.maximum(m_prev, m_cur)
    p = jnp.exp(s - m_new)                         # (g·bq, block_k)
    alpha = jnp.exp(m_prev - m_new)
    l_new = alpha * l_scr[...] + jnp.sum(p, axis=1, keepdims=True)
    acc_scr[...] = acc_scr[...] * alpha + jax.lax.dot_general(
        p, v.astype(jnp.float32), (((1,), (0,)), ((), ())))
    m_scr[...] = m_new
    l_scr[...] = l_new

    @pl.when(kb == nkb - 1)
    def _finalize():
        out = acc_scr[...] / jnp.maximum(l_scr[...], 1e-30)
        o_ref[0, 0] = out.reshape(g, bq, dh).astype(o_ref.dtype)


def flash_attention(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray, *,
                    causal: bool = True, window: int = 0,
                    block_q: int = 128, block_k: int = 128,
                    interpret: bool = True) -> jnp.ndarray:
    """q: (b, sq, H, dh); k, v: (b, skv, K, dh) -> (b, sq, H, dh)."""
    b, sq, H, dh = q.shape
    skv, K = k.shape[1], k.shape[2]
    g = H // K
    block_q = min(block_q, sq)
    block_k = min(block_k, skv)
    nq = pl.cdiv(sq, block_q)
    nk = pl.cdiv(skv, block_k)
    assert sq % block_q == 0 and skv % block_k == 0, (sq, skv, block_q, block_k)

    # layout: q (b, K, g, sq, dh); kv (b, K, skv, dh)
    qr = q.reshape(b, sq, K, g, dh).transpose(0, 2, 3, 1, 4)
    kr = k.transpose(0, 2, 1, 3)
    vr = v.transpose(0, 2, 1, 3)

    grid = (b, K, nq, nk)
    out = pl.pallas_call(
        functools.partial(_flash_kernel, sm_scale=dh ** -0.5, block_q=block_q,
                          block_k=block_k, causal=causal, window=window,
                          seq_kv=skv),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, g, block_q, dh),
                         lambda bi, ki, qi, kj: (bi, ki, 0, qi, 0)),
            pl.BlockSpec((1, 1, block_k, dh),
                         lambda bi, ki, qi, kj: (bi, ki, kj, 0)),
            pl.BlockSpec((1, 1, block_k, dh),
                         lambda bi, ki, qi, kj: (bi, ki, kj, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, g, block_q, dh),
                               lambda bi, ki, qi, kj: (bi, ki, 0, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((b, K, g, sq, dh), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((g * block_q, 1), jnp.float32),
            pltpu.VMEM((g * block_q, 1), jnp.float32),
            pltpu.VMEM((g * block_q, dh), jnp.float32),
        ],
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "parallel",
                                 "arbitrary")),
        interpret=interpret,
    )(qr, kr, vr)
    return out.transpose(0, 3, 1, 2, 4).reshape(b, sq, H, dh)
