"""Mamba2 SSD chunked scan — Pallas TPU kernel.

Grid (batch, head, chunk) with the chunk axis sequential: the inter-chunk SSM
state (P × N) lives in VMEM scratch and is carried across chunks — the whole
state-space-duality scan (within-chunk quadratic dual + across-chunk linear
recurrence, arXiv:2405.21060 §6) runs in one kernel with no HBM state
round-trips.  Per-chunk compute is three MXU matmuls:
  scores = (C Bᵀ) ⊙ exp(segsum(dA));  Y_diag = scores · (dt·x);
  Y_off  = (C · stateᵀ) ⊙ exp(cumsum dA);
  state' = exp(ΣdA)·state + (dt·x·decay)ᵀ · B.
The chunk length (default 128) × P(64)/N(64-128) tiles fit VMEM comfortably
(< 1 MiB per buffer).
"""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels._compat import CompilerParams as _CompilerParams


def _ssd_kernel(a_ref, x_ref, dt_ref, b_ref, c_ref, y_ref, state_out_ref,
                state_scr, *, chunk: int):
    ci = pl.program_id(2)
    nc = pl.num_programs(2)
    hi = pl.program_id(1)

    @pl.when(ci == 0)
    def _init():
        state_scr[...] = jnp.zeros_like(state_scr)

    A = a_ref[hi]                                        # () scalar decay rate
    x = x_ref[0, 0].astype(jnp.float32)                  # (chunk, P)
    dt = dt_ref[0, 0].astype(jnp.float32)                # (chunk,) -> reshaped
    B = b_ref[0, 0].astype(jnp.float32)                  # (chunk, N)
    C = c_ref[0, 0].astype(jnp.float32)                  # (chunk, N)

    dA = dt * A                                          # (chunk,)
    cum = jnp.cumsum(dA)                                 # (chunk,)
    # within-chunk decay matrix L[i, j] = exp(cum_i - cum_j) for j <= i
    li = cum[:, None] - cum[None, :]
    tri = (jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 0)
           >= jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 1))
    Lmat = jnp.where(tri, jnp.exp(li), 0.0)

    xdt = x * dt[:, None]                                # (chunk, P)
    scores = jax.lax.dot_general(C, B, (((1,), (1,)), ((), ())))   # (c, c)
    Y_diag = jax.lax.dot_general(scores * Lmat, xdt,
                                 (((1,), (0,)), ((), ())))         # (c, P)

    state = state_scr[...]                               # (P, N)
    Y_off = jax.lax.dot_general(C, state, (((1,), (1,)), ((), ())))
    Y_off = Y_off * jnp.exp(cum)[:, None]                # (c, P)

    decay_states = jnp.exp(cum[-1] - cum)                # (c,)
    new_state = (state * jnp.exp(cum[-1])
                 + jax.lax.dot_general(xdt * decay_states[:, None], B,
                                       (((0,), (0,)), ((), ()))))  # (P, N)
    state_scr[...] = new_state
    y_ref[0, 0] = (Y_diag + Y_off).astype(y_ref.dtype)

    @pl.when(ci == nc - 1)
    def _emit_state():
        state_out_ref[0, 0] = new_state.astype(state_out_ref.dtype)


def ssd_chunked(x: jnp.ndarray, dt: jnp.ndarray, A: jnp.ndarray,
                B: jnp.ndarray, C: jnp.ndarray, *, chunk: int = 128,
                interpret: bool = True) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """x: (b, s, H, P); dt: (b, s, H); A: (H,); B, C: (b, s, G, N).
    Returns (y (b, s, H, P) f32, final_state (b, H, P, N) f32)."""
    b, s, H, P = x.shape
    G, N = B.shape[2], B.shape[3]
    hpg = H // G
    assert s % chunk == 0, (s, chunk)
    nc = s // chunk

    # head-major layouts; broadcast groups to heads
    xr = x.transpose(0, 2, 1, 3)                                 # (b, H, s, P)
    dtr = dt.transpose(0, 2, 1)                                  # (b, H, s)
    Br = jnp.repeat(B.transpose(0, 2, 1, 3), hpg, axis=1)        # (b, H, s, N)
    Cr = jnp.repeat(C.transpose(0, 2, 1, 3), hpg, axis=1)

    y, state = pl.pallas_call(
        functools.partial(_ssd_kernel, chunk=chunk),
        grid=(b, H, nc),
        in_specs=[
            pl.BlockSpec(memory_space=pltpu.SMEM),               # A (H,)
            pl.BlockSpec((1, 1, chunk, P), lambda bi, hi, ci: (bi, hi, ci, 0)),
            pl.BlockSpec((1, 1, chunk), lambda bi, hi, ci: (bi, hi, ci)),
            pl.BlockSpec((1, 1, chunk, N), lambda bi, hi, ci: (bi, hi, ci, 0)),
            pl.BlockSpec((1, 1, chunk, N), lambda bi, hi, ci: (bi, hi, ci, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, chunk, P), lambda bi, hi, ci: (bi, hi, ci, 0)),
            pl.BlockSpec((1, 1, P, N), lambda bi, hi, ci: (bi, hi, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b, H, s, P), jnp.float32),
            jax.ShapeDtypeStruct((b, H, P, N), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((P, N), jnp.float32)],
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(A.astype(jnp.float32), xr, dtr, Br, Cr)
    return y.transpose(0, 2, 1, 3), state
