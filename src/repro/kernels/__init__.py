"""Pallas TPU kernels (pl.pallas_call + BlockSpec VMEM tiling) for the
compute hot spots, each with a jit'd wrapper (ops.py) and a pure-jnp oracle
(ref.py) asserted allclose across shape/dtype sweeps in tests/test_kernels.py:

  flash_attention   — prefill attention, online softmax over KV blocks
  decode_attention  — flash-decode: one token vs a long cache, SMEM length
  paged_attention   — flash-decode over block-table KV (scalar-prefetched
                      gather through the paged arena — the kvpool path)
  ssd_scan          — Mamba2 SSD: chunk-dual matmuls + carried VMEM state
"""
