"""Flash-decode attention — Pallas TPU kernel for one-token serving steps.

One new token attends over a long KV cache: grid (batch, kv-head, kv-block)
with the kv-block axis sequential, carrying online-softmax state in VMEM
scratch.  The valid-prefix ``length`` arrives in SMEM (scalar), masking the
cache tail.  The g grouped q-heads ride in the block's penultimate dim, so the
score contraction is a (g × dh) · (dh × bk) MXU matmul per block.

This kernel is the TPU-native replacement for GSPMD's all-gather-the-cache
fallback on sequence-sharded KV (see §Perf decode hillclimb): each shard runs
the kernel over its local KV range, then shards combine partial (m, l, acc)
with one tiny all-reduce.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels._compat import CompilerParams as _CompilerParams

NEG_INF = -1e30


def _decode_kernel(len_ref, q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr,
                   *, sm_scale: float, block_k: int):
    kb = pl.program_id(2)
    nkb = pl.num_programs(2)
    length = len_ref[pl.program_id(0)]       # per-row valid prefix (SMEM)

    @pl.when(kb == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[0, 0]                                  # (g, dh)
    k = k_ref[0, 0]                                  # (block_k, dh)
    v = v_ref[0, 0]
    g, dh = q.shape

    s = jax.lax.dot_general(q.astype(jnp.float32), k.astype(jnp.float32),
                            (((1,), (1,)), ((), ()))) * sm_scale   # (g, bk)
    kpos = kb * block_k + jax.lax.broadcasted_iota(jnp.int32, (g, block_k), 1)
    s = jnp.where(kpos < length, s, NEG_INF)

    m_prev = m_scr[...]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=1, keepdims=True))
    p = jnp.exp(s - m_new)
    alpha = jnp.exp(m_prev - m_new)
    l_scr[...] = alpha * l_scr[...] + jnp.sum(p, axis=1, keepdims=True)
    acc_scr[...] = acc_scr[...] * alpha + jax.lax.dot_general(
        p, v.astype(jnp.float32), (((1,), (0,)), ((), ())))
    m_scr[...] = m_new

    @pl.when(kb == nkb - 1)
    def _finalize():
        o_ref[0, 0] = (acc_scr[...] / jnp.maximum(l_scr[...], 1e-30)
                       ).astype(o_ref.dtype)


def decode_attention(q: jnp.ndarray, k_cache: jnp.ndarray, v_cache: jnp.ndarray,
                     length, *, block_k: int = 256,
                     interpret: bool = True) -> jnp.ndarray:
    """q: (b, H, dh); caches: (b, S, K, dh); length: () / python int shared
    across rows, or (b,) per-row valid prefixes (slotted batched decode).
    Returns (b, H, dh)."""
    b, H, dh = q.shape
    S, K = k_cache.shape[1], k_cache.shape[2]
    g = H // K
    block_k = min(block_k, S)
    assert S % block_k == 0, (S, block_k)
    nk = S // block_k

    qr = q.reshape(b, K, g, dh)
    kr = k_cache.transpose(0, 2, 1, 3)               # (b, K, S, dh)
    vr = v_cache.transpose(0, 2, 1, 3)
    length_arr = jnp.broadcast_to(
        jnp.asarray(length, jnp.int32).reshape(-1), (b,))

    out = pl.pallas_call(
        functools.partial(_decode_kernel, sm_scale=dh ** -0.5, block_k=block_k),
        grid=(b, K, nk),
        in_specs=[
            pl.BlockSpec(memory_space=pltpu.SMEM),
            pl.BlockSpec((1, 1, g, dh), lambda bi, ki, kj: (bi, ki, 0, 0)),
            pl.BlockSpec((1, 1, block_k, dh), lambda bi, ki, kj: (bi, ki, kj, 0)),
            pl.BlockSpec((1, 1, block_k, dh), lambda bi, ki, kj: (bi, ki, kj, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, g, dh), lambda bi, ki, kj: (bi, ki, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((b, K, g, dh), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((g, 1), jnp.float32),
            pltpu.VMEM((g, 1), jnp.float32),
            pltpu.VMEM((g, dh), jnp.float32),
        ],
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(length_arr, qr, kr, vr)
    return out.reshape(b, H, dh)
