"""Version shims for the Pallas TPU API, shared by every kernel module."""
from jax.experimental.pallas import tpu as pltpu

# jax<0.5 renamed: TPUCompilerParams -> CompilerParams (jax 0.5+)
CompilerParams = getattr(pltpu, "CompilerParams", None) or pltpu.TPUCompilerParams
