"""Phase-level profiling: wall-clock timers for the engine hot phases.

EcoServe's observation (PAPERS.md) is that carbon-aware decisions need
*per-phase* attribution — prefill and decode have different power and
latency profiles, and swaps are pure overhead.  This module is the
plumbing: engines call :meth:`PhaseProfiler.observe` (or wrap code in
:meth:`span`) with one of the canonical :data:`PHASES`, and each sample
lands as a ``phase``-labeled child of the ``phase_latency_s`` CATALOG
histogram on whatever registry the current session attached.

The profiler is a tiny mutable shim rather than a registry wrapper
because engine sessions swap registries per ``submit()`` call: the engine
owns ONE profiler, repoints ``profiler.registry`` at session open, and
sets it to ``None`` when no telemetry is attached — then every ``observe``
is a single attribute check, which keeps the zero-telemetry hot path at
zero cost (the overhead gate in ``benchmarks/run.py`` holds the whole
plane, profiling included, under 5% of tokens/s).

The canonical phases:

  * ``prefill_chunk``  — one prefill jit call (slotted full-prompt, paged
    chunked);
  * ``decode_dispatch`` — host time to *launch* decode step(s)
    (async dispatch; device work overlaps);
  * ``decode_land``     — blocking readback of a dispatched decode
    (the host-sync cost the pipelined path hides);
  * ``swap_d2h``        — preemption KV swap-out (device→host staging);
  * ``swap_h2d``        — resume KV swap-in (host→device restore).
"""
from __future__ import annotations

import time
from contextlib import contextmanager
from typing import Optional

from repro.obs.metrics import MetricsRegistry

__all__ = ["PHASES", "PhaseProfiler"]

PHASES = ("prefill_chunk", "decode_dispatch", "decode_land",
          "swap_d2h", "swap_h2d")


class PhaseProfiler:
    """Routes phase timings into a (swappable) registry's labeled
    ``phase_latency_s`` histogram.  ``registry=None`` disables it.

    ``role`` (disaggregated serving) adds a constant ``role`` label to
    every sample — a disagg engine owns one profiler per worker pool, so
    phase latency splits prefill-pool vs decode-pool without any change
    to the engine's observe call sites."""

    __slots__ = ("registry", "role")

    def __init__(self, registry: Optional[MetricsRegistry] = None,
                 role: Optional[str] = None):
        self.registry = registry
        self.role = role

    def observe(self, phase: str, seconds: float) -> None:
        reg = self.registry
        if reg is None:
            return
        assert phase in PHASES, f"unknown phase {phase!r}"
        if self.role is None:
            reg.labeled("phase_latency_s", phase=phase).observe(seconds)
        else:
            reg.labeled("phase_latency_s", phase=phase,
                        role=self.role).observe(seconds)

    @contextmanager
    def span(self, phase: str):
        """``with profiler.span("swap_d2h"): ...`` — times the block and
        observes it (still observed if the block raises)."""
        t0 = time.perf_counter()
        try:
            yield
        finally:
            self.observe(phase, time.perf_counter() - t0)
