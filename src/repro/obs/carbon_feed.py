"""Streaming carbon telemetry: a measure-every-N-seconds energy/CO2 feed.

codecarbon idiom: instead of one post-hoc total, energy and emissions are
*streamed* — the feed accumulates measured segments (or integrates a power
reading against the clock) and every ``interval_s`` seconds emits a
:class:`CarbonSnapshot` carrying the window's joules / gCO2 / mean power /
carbon intensity plus the running totals.  Consumers subscribe:

  * ``Controller.maybe_reoptimize`` reads :meth:`CarbonFeed.latest` to act
    on *measured* CI + load instead of a trace lookup alone;
  * ``fleet_sim`` keeps one feed per region and heartbeats it at window
    boundaries, so a fleet run yields a per-region emissions time series;
  * ``benchmarks/run.py`` folds feed snapshots into the benchmark JSON.

Conservation by construction: when a ``core.carbon.CarbonAccountant`` is
given a feed, every ``add()`` forwards its *exact* joules/grams through
:meth:`record_segment` — so ``feed.energy_j_total`` equals the accountant's
total to the last bit, and the tests assert it.

Two ingestion styles:

  * **segment** (:meth:`record_segment`): the caller already measured a
    (t_start, duration, joules) segment — the accountant path;
  * **sampler** (:meth:`sample`): the caller only knows the *current* power
    draw; the feed integrates it over the gap since the previous sample —
    the codecarbon "measure every N seconds" path.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, List, Optional, Union

__all__ = ["CarbonFeed", "CarbonSnapshot"]

_J_PER_KWH = 3.6e6


@dataclasses.dataclass
class CarbonSnapshot:
    """One emitted window of the feed (all energies joules, carbon grams)."""
    t: float                    # window end (feed clock, seconds)
    region: str
    window_s: float             # width of this window
    energy_j: float             # joules accumulated in the window
    carbon_g: float             # gCO2 accumulated in the window
    power_w: float              # mean power over the window
    ci_g_per_kwh: float         # carbon intensity at window end
    energy_j_total: float       # running totals since feed creation
    carbon_g_total: float
    sla_ok_frac: Optional[float] = None   # caller-provided SLA health, if any


class CarbonFeed:
    """Per-region streaming energy/CO2 telemetry (codecarbon idiom).

    ``ci`` is a constant (gCO2/kWh) or a callable ``ci(t)`` — e.g. a
    ``CarbonIntensityTrace.at`` bound method.  Segments whose carbon was
    not pre-computed get midpoint-CI × PUE, the ``CarbonAccountant``
    convention, so both ingestion styles land on the same accounting."""

    def __init__(self, ci: Union[float, Callable[[float], float]] = 0.0,
                 interval_s: float = 60.0, region: str = "region",
                 pue: float = 1.0):
        self.ci_fn: Callable[[float], float] = \
            ci if callable(ci) else (lambda _t, _c=float(ci): _c)
        self.interval_s = float(interval_s)
        self.region = region
        self.pue = float(pue)
        self.energy_j_total = 0.0
        self.carbon_g_total = 0.0
        self.snapshots: List[CarbonSnapshot] = []
        self._subs: List[Callable[[CarbonSnapshot], None]] = []
        # current accumulation window
        self._win_j = 0.0
        self._win_g = 0.0
        self._win_t0: Optional[float] = None  # start of the open window
        self._last_sample_t: Optional[float] = None

    # --- ingestion -----------------------------------------------------------
    def record_segment(self, t_start: float, duration_s: float,
                       energy_j: float, carbon_g: Optional[float] = None
                       ) -> None:
        """Ingest one measured segment.  ``carbon_g=None`` → midpoint-CI ×
        PUE (the accountant's own convention); an accountant wired to this
        feed passes its exact grams, making feed totals == accountant
        totals with no re-derivation."""
        if carbon_g is None:
            ci = self.ci_fn(t_start + 0.5 * duration_s)
            carbon_g = energy_j / _J_PER_KWH * ci * self.pue
        if self._win_t0 is None:
            self._win_t0 = float(t_start)
        self._win_j += float(energy_j)
        self._win_g += float(carbon_g)
        self.heartbeat(t_start + duration_s)

    def sample(self, t: float, power_w: float) -> None:
        """Sampler ingestion: integrate ``power_w`` over the gap since the
        previous sample (the first call only anchors the clock)."""
        if self._last_sample_t is not None and t > self._last_sample_t:
            dt = t - self._last_sample_t
            self.record_segment(self._last_sample_t, dt, power_w * dt)
        self._last_sample_t = float(t)

    # --- emission ------------------------------------------------------------
    def heartbeat(self, t: float, sla_ok_frac: Optional[float] = None,
                  force: bool = False) -> Optional[CarbonSnapshot]:
        """Emit a snapshot if the open window has reached ``interval_s``
        (or ``force`` — fleet window boundaries force-flush so each region
        window lands in its own snapshot).  Returns the snapshot emitted,
        if any."""
        if self._win_t0 is None:
            return None
        width = t - self._win_t0
        if not force and width < self.interval_s:
            return None
        self.energy_j_total += self._win_j
        self.carbon_g_total += self._win_g
        snap = CarbonSnapshot(
            t=float(t), region=self.region, window_s=float(width),
            energy_j=self._win_j, carbon_g=self._win_g,
            power_w=self._win_j / width if width > 0 else 0.0,
            ci_g_per_kwh=float(self.ci_fn(t)),
            energy_j_total=self.energy_j_total,
            carbon_g_total=self.carbon_g_total,
            sla_ok_frac=sla_ok_frac)
        self.snapshots.append(snap)
        self._win_j = 0.0
        self._win_g = 0.0
        self._win_t0 = None
        for cb in self._subs:
            cb(snap)
        return snap

    def flush(self, t: float, sla_ok_frac: Optional[float] = None
              ) -> Optional[CarbonSnapshot]:
        """Force-emit whatever the open window holds (end of a session)."""
        return self.heartbeat(t, sla_ok_frac=sla_ok_frac, force=True)

    # --- consumption ---------------------------------------------------------
    def subscribe(self, cb: Callable[[CarbonSnapshot], None]) -> None:
        self._subs.append(cb)

    def latest(self) -> Optional[CarbonSnapshot]:
        return self.snapshots[-1] if self.snapshots else None

    @property
    def pending_energy_j(self) -> float:
        """Joules ingested but not yet emitted in a snapshot."""
        return self._win_j
