"""OpenMetrics exposition + periodic JSON snapshots for registries/rollups.

One renderer, one parser, one invariant: ``render(parse(render(x))) ==
render(x)``.  The text format is the OpenMetrics/Prometheus subset a real
scraper understands —

  * every family gets ``# HELP`` and ``# TYPE`` lines, names prefixed
    ``repro_``; counters expose as ``<name>_total`` per the OpenMetrics
    counter convention;
  * gauges expose their value, with the observed peak as a separate
    ``<name>_peak`` gauge family (a peak is not a sample of the gauge);
  * histograms expose as OpenMetrics *summaries*: one ``quantile``-labeled
    sample per exposed percentile plus ``_count``/``_sum`` — quantiles
    because the registry's nearest-rank percentiles are exact, so shipping
    fixed buckets would only add quantization error;
  * registry constant labels (region/kv_layout/...) merge into every
    sample; labeled child series render as additional samples of the same
    family.  Labels are sorted by key (``quantile`` forced last), values
    via ``repr(float)`` so floats round-trip exactly;
  * the exposition ends with ``# EOF`` (the OpenMetrics framing marker).

Round-trip identity is by construction, not by effort: both
:func:`to_openmetrics` and re-export of a parsed exposition funnel through
the same ``_render`` over the same ordered family structure.

:class:`SnapshotWriter` is the pull-less alternative: appends the
registry's flat ``snapshot()`` dict to a JSONL file at a fixed cadence —
the scrape-by-file mode the fleet sim and long benchmarks use.
"""
from __future__ import annotations

import json
import os
from typing import Dict, List, Optional, Tuple

from repro.obs.metrics import MetricsRegistry

__all__ = ["PREFIX", "QUANTILES", "to_openmetrics", "parse_openmetrics",
           "render_families", "SnapshotWriter"]

PREFIX = "repro_"
QUANTILES = (0.5, 0.95, 0.99)

# family structure: name → {"type": str, "help": str,
#                           "samples": [(sample_name, labels, value_str)]}
# kept insertion-ordered; this is what _render consumes and parse rebuilds.


def _fmt(value: float) -> str:
    """Exact float→text: repr() round-trips any finite float."""
    return repr(float(value))


def _label_str(labels: List[Tuple[str, str]]) -> str:
    if not labels:
        return ""
    # sort by key, quantile last — stable ordering makes re-export identical
    ordered = sorted(labels, key=lambda kv: (kv[0] == "quantile", kv[0]))
    body = ",".join(f'{k}="{v}"' for k, v in ordered)
    return "{" + body + "}"


def _collect(reg: MetricsRegistry) -> Dict[str, dict]:
    """Build the ordered family structure from a registry (or a rollup —
    anything with ``merged()`` collapses to its fleet registry first)."""
    if hasattr(reg, "merged"):
        reg = reg.merged()
    const = sorted(reg.labels.items())
    families: Dict[str, dict] = {}

    def fam(name: str, mtype: str, help_: str) -> dict:
        f = families.get(name)
        if f is None:
            f = {"type": mtype, "help": help_, "samples": []}
            families[name] = f
        return f

    def emit(m, labels: List[Tuple[str, str]]) -> None:
        base = PREFIX + m.name
        lbl = list(const) + labels
        if m.kind == "counter":
            f = fam(base, "counter", f"{m.name} (counter)")
            f["samples"].append((base + "_total", list(lbl), _fmt(m.value)))
        elif m.kind == "gauge":
            f = fam(base, "gauge", f"{m.name} (gauge)")
            f["samples"].append((base, list(lbl), _fmt(m.value)))
            fp = fam(base + "_peak", "gauge", f"{m.name} observed peak")
            fp["samples"].append((base + "_peak", list(lbl), _fmt(m.peak)))
        else:
            f = fam(base, "summary", f"{m.name} (summary)")
            for q in QUANTILES:
                f["samples"].append(
                    (base, list(lbl) + [("quantile", _fmt(q))],
                     _fmt(m.percentile(q * 100.0))))
            f["samples"].append((base + "_count", list(lbl),
                                 _fmt(float(m.count))))
            f["samples"].append((base + "_sum", list(lbl), _fmt(m.sum)))

    for name in sorted(reg.names()):
        emit(reg.get(name), [])
    # labeled children group under the same family as their parent; sort
    # for a deterministic exposition regardless of observation order
    children = sorted(reg.labeled_series(),
                      key=lambda t: (t[0], sorted(t[1].items())))
    for name, labels, m in children:
        emit(m, sorted(labels.items()))
    return families


def render_families(families: Dict[str, dict]) -> str:
    lines: List[str] = []
    for name, f in families.items():
        lines.append(f"# HELP {name} {f['help']}")
        lines.append(f"# TYPE {name} {f['type']}")
        for sname, labels, value in f["samples"]:
            lines.append(f"{sname}{_label_str(labels)} {value}")
    lines.append("# EOF")
    return "\n".join(lines) + "\n"


def to_openmetrics(reg: MetricsRegistry) -> str:
    """OpenMetrics text exposition of a registry or fleet rollup."""
    return render_families(_collect(reg))


def parse_openmetrics(text: str) -> Dict[str, dict]:
    """Parse an exposition back into the ordered family structure (so
    ``render_families(parse_openmetrics(t)) == t``).  Strict about the
    subset this module emits: unknown line shapes raise."""
    families: Dict[str, dict] = {}
    cur: Optional[str] = None
    saw_eof = False
    for line in text.splitlines():
        if not line:
            continue
        assert not saw_eof, "sample after # EOF"
        if line.startswith("# HELP "):
            name, help_ = line[len("# HELP "):].split(" ", 1)
            families[name] = {"type": "untyped", "help": help_,
                              "samples": []}
            cur = name
        elif line.startswith("# TYPE "):
            name, mtype = line[len("# TYPE "):].split(" ", 1)
            assert name == cur, f"TYPE {name} without preceding HELP"
            families[name]["type"] = mtype
        elif line == "# EOF":
            saw_eof = True
        else:
            sname, labels, value = _parse_sample(line)
            # a sample belongs to the family whose name prefixes it
            # (handles _total/_count/_sum/_peak suffixes)
            fname = _family_of(sname, families)
            families[fname]["samples"].append((sname, labels, value))
    assert saw_eof, "exposition missing # EOF"
    return families


def _family_of(sample_name: str, families: Dict[str, dict]) -> str:
    if sample_name in families:
        return sample_name
    for suffix in ("_total", "_count", "_sum"):
        if sample_name.endswith(suffix):
            base = sample_name[: -len(suffix)]
            if base in families:
                return base
    raise AssertionError(f"sample {sample_name!r} matches no family")


def _parse_sample(line: str) -> Tuple[str, List[Tuple[str, str]], str]:
    if "{" in line:
        name, rest = line.split("{", 1)
        body, tail = rest.rsplit("}", 1)
        labels = []
        for part in body.split(","):
            k, v = part.split("=", 1)
            assert v.startswith('"') and v.endswith('"'), \
                f"unquoted label value in {line!r}"
            labels.append((k, v[1:-1]))
        return name, labels, tail.strip()
    name, value = line.rsplit(" ", 1)
    return name.strip(), [], value


class SnapshotWriter:
    """Periodic JSONL snapshots of a registry — the file-based 'scrape'.

    ``maybe_write(t, reg)`` appends one line at most every ``interval_s``
    of *sim/session* time; ``write`` forces one (e.g. at drain).  Each
    line is ``{"t", "backend", "labels", "metrics": reg.snapshot()}``.
    """

    def __init__(self, path: str, interval_s: float = 60.0):
        self.path = path
        self.interval_s = float(interval_s)
        self.last_t: Optional[float] = None
        self.writes = 0

    def maybe_write(self, t: float, reg: MetricsRegistry) -> bool:
        if self.last_t is not None and t - self.last_t < self.interval_s:
            return False
        self.write(t, reg)
        return True

    def write(self, t: float, reg: MetricsRegistry) -> None:
        if hasattr(reg, "merged"):
            reg = reg.merged()
        rec = {"t": float(t), "backend": reg.backend,
               "labels": dict(reg.labels), "metrics": reg.snapshot()}
        d = os.path.dirname(self.path)
        if d:
            os.makedirs(d, exist_ok=True)
        with open(self.path, "a", encoding="utf-8") as fh:
            fh.write(json.dumps(rec) + "\n")
        self.last_t = float(t)
        self.writes += 1
