"""Self-contained telemetry validation (``python -m repro.obs.validate``).

Runs a small two-class workload through the jax-free DES backend with the
FULL telemetry bundle attached — metrics registry, trace recorder, carbon
feed — under a carbon-aware hold policy on a stepped grid, then enforces
every contract the observability layer promises:

  * the metric-name set equals the shared CATALOG exactly;
  * every span closed, and span-attributed joules == the backend's session
    energy total (the conservation invariant, :func:`repro.obs.trace.
    validate_trace`);
  * per-response joules/grams also sum to the session totals;
  * held requests carry ``held_s`` ≤ their queue delay plus a release
    reason, and un-held requests carry neither;
  * the Chrome-trace export passes the Perfetto schema check and a JSON
    round-trip (written to a temp file exactly as a user would);
  * the OpenMetrics exposition round-trips exactly (export → parse →
    re-export identical) and its counter samples carry the same values the
    registry holds;
  * a fleet rollup over per-region copies conserves energy/carbon
    bit-exactly and exposes the same labeled family set as one region;
  * the mixed-quality request path (``serving.quality``): a governed
    selector on a two-rung pool downshifts deferrable work on the dirty
    spell, every response's accuracy/variant matches its decision, the
    per-class served mean never breaches the configured floor, and
    per-request joules still sum exactly to the session total;
  * disaggregated-serving conservation
    (:func:`check_disagg_conservation`): the per-role joules split a
    serving engine reports (``prefill_energy_j`` + ``decode_energy_j`` +
    ``handoff_energy_j`` + ``both_energy_j``) sums exactly to its
    ``energy_j`` session total — exercised here on synthetic stats dicts
    (both the disagg and the monolithic shape, plus a violated one that
    must be caught), and on real engine stats by ``tests/test_disagg.py``
    and the ``disagg_serving`` bench stage.

``scripts/check.sh`` runs this as its trace-schema validation step: it
needs no jax, no device, and finishes in well under a second.
"""
from __future__ import annotations

import json
import os
import sys
import tempfile

import numpy as np

from repro.core import catalog as CAT
from repro.core import config_graph as CG
from repro.obs import CarbonFeed, CATALOG, FleetRollup, MetricsRegistry, \
    Telemetry, TraceRecorder, parse_openmetrics, to_openmetrics, \
    validate_chrome_events, validate_trace
from repro.obs.export import render_families
from repro.serving import queue as Q
from repro.serving.api import DEFERRABLE, INTERACTIVE, InferenceRequest
from repro.serving.policies import CarbonAwarePolicy


ROLE_ENERGY_KEYS = ("prefill_energy_j", "decode_energy_j",
                    "handoff_energy_j", "both_energy_j")


def check_disagg_conservation(stats, rel_tol: float = 1e-9) -> float:
    """Assert the per-role joules split conserves against the session total.

    ``stats`` is any serving backend's ``stats()`` dict carrying the
    :data:`ROLE_ENERGY_KEYS` (monolithic engines put the whole total under
    ``both_energy_j``; disaggregated engines split it across prefill /
    decode / handoff).  The roles partition every charged joule by
    construction, so the check is exact up to float accumulation
    (``rel_tol`` of the total, the repo-wide conservation tolerance).
    Returns the session ``energy_j`` for convenience."""
    total = float(stats["energy_j"])
    by_role = sum(float(stats.get(k, 0.0)) for k in ROLE_ENERGY_KEYS)
    tol = rel_tol * max(total, 1e-12)
    assert abs(by_role - total) <= tol, \
        f"role energy split {by_role!r} J != session total {total!r} J " \
        f"(prefill+decode+handoff+both must conserve exactly)"
    return total


def _ci_step(t: float) -> float:
    """Dirty grid for the first minute, clean after — the hold policy parks
    deferrable work through the dirty spell and releases on "threshold"."""
    return 400.0 if t < 60.0 else 50.0


def build_backend() -> Q.DESBackend:
    variants = CAT.get_family("efficientnet")
    g = CG.ConfigGraph.from_dict("efficientnet", {("B3", 1): 1})
    policy = CarbonAwarePolicy(_ci_step, ci_threshold=100.0,
                               est_service_s=1.0)
    tel = Telemetry(tracer=TraceRecorder("des"),
                    feed=CarbonFeed(_ci_step, interval_s=30.0,
                                    region="validate"),
                    backend="des")
    return Q.DESBackend(g, variants, Q.DESConfig(jitter_sigma=0.0),
                        policy=policy, ci_g_per_kwh=_ci_step,
                        hold_retry_s=5.0, telemetry=tel)


def main() -> int:
    be = build_backend()
    rng = np.random.default_rng(0)
    rid = 0
    for a in np.linspace(0.0, 30.0, 8):          # interactive: always flow
        be.submit(InferenceRequest(
            rid=rid, prompt=rng.integers(0, 64, size=6).astype(np.int32),
            max_new_tokens=8, slo=INTERACTIVE, priority=1,
            arrival_s=float(a)))
        rid += 1
    for a in (1.0, 2.0, 3.0, 4.0):               # deferrable: held to t=60
        be.submit(InferenceRequest(
            rid=rid, prompt=rng.integers(0, 64, size=6).astype(np.int32),
            max_new_tokens=8, slo=DEFERRABLE, priority=0,
            arrival_s=a, deadline_s=a + 300.0))
        rid += 1
    responses = be.drain()
    stats = be.stats()
    tel = be.telemetry

    # 1. metric-name parity with the shared catalog
    assert tel.registry.names() == set(CATALOG), \
        f"metric names diverge from CATALOG: " \
        f"{tel.registry.names() ^ set(CATALOG)}"

    # 2. trace conservation: spans closed, joules sum to the session total
    summary = validate_trace(tel.tracer, expect_energy_j=stats["energy_j"],
                             expect_requests=int(stats["served"]))

    # 3. per-response attribution sums to the session totals too
    tol = 1e-9 * max(stats["energy_j"], 1e-12)
    assert abs(sum(r.energy_j for r in responses)
               - stats["energy_j"]) <= tol
    assert abs(sum(r.carbon_g for r in responses)
               - stats["carbon_g"]) <= 1e-9 * max(stats["carbon_g"], 1e-12)

    # 4. hold accounting: held deferrable work carries reason + held_s
    held = [r for r in responses if r.release_reason is not None]
    assert held, "stepped grid produced no holds — scenario degenerated"
    for r in held:
        assert r.slo == DEFERRABLE
        assert 0.0 <= r.held_s <= r.queue_delay_s + 1e-9, \
            f"rid {r.rid}: held_s {r.held_s} > queue_delay {r.queue_delay_s}"
    for r in responses:
        if r.release_reason is None:
            assert r.held_s == 0.0

    # 5. carbon feed streamed the exact same totals
    tel.feed.flush(stats["wall_s"])
    assert abs(tel.feed.energy_j_total - stats["energy_j"]) <= tol

    # 6. the exports themselves: JSONL + Perfetto-loadable Chrome trace
    with tempfile.TemporaryDirectory() as td:
        jl = os.path.join(td, "trace.jsonl")
        ct = os.path.join(td, "trace.json")
        tel.tracer.to_jsonl(jl)
        tel.tracer.to_chrome_trace(ct)
        with open(jl) as f:
            assert len(f.readlines()) == summary["records"]
        with open(ct) as f:
            doc = json.load(f)
        n_events = validate_chrome_events(doc["traceEvents"])

    # 7. OpenMetrics exposition round-trip: export → parse → re-export must
    # be byte-identical, and the counter samples must carry the registry's
    # exact values (repr round-trip)
    text = to_openmetrics(tel.registry)
    families = parse_openmetrics(text)
    assert render_families(families) == text, \
        "OpenMetrics round-trip diverged"
    e_samples = [v for n, lbl, v in families["repro_energy_j"]["samples"]
                 if n == "repro_energy_j_total" and "region" not in dict(lbl)]
    assert [float(v) for v in e_samples] == [stats["energy_j"]], \
        "exposition energy_j != registry energy_j"

    # 8. fleet-rollup conservation: split the session registry into two
    # synthetic regions and merge — region sums must equal fleet totals
    # EXACTLY, and the rollup must expose the same family set as a region
    rollup = FleetRollup()
    for rname, frac in (("east", 0.25), ("west", 0.75)):
        reg = MetricsRegistry.standard(rname, labels={"region": rname})
        reg.counter("energy_j").inc(frac * stats["energy_j"])
        reg.counter("carbon_g").inc(frac * stats["carbon_g"])
        reg.counter("requests_served").inc(
            round(frac * 4) + (0 if rname == "east" else stats["served"] - 4))
        rollup.add(reg)
    totals = rollup.conservation(("energy_j", "carbon_g",
                                  "requests_served"))
    fleet_families = parse_openmetrics(to_openmetrics(rollup))
    region_families = parse_openmetrics(
        to_openmetrics(rollup.regions["east"]))
    assert set(region_families) <= set(fleet_families), \
        "fleet exposition missing region families"

    # 9. mixed-quality request path: governed selector on a two-rung pool
    # under the same stepped grid — the dirty first minute must downshift
    # deferrable work, every served accuracy must equal its decision, the
    # per-class windowed mean must hold the floor, and attribution must
    # still conserve
    from repro.serving.quality import make_selector
    floor = 0.80
    sel = make_selector("governed", ci_fn=_ci_step, dirty_threshold_g=100.0,
                        floors={DEFERRABLE: floor})
    mixed_g = CG.ConfigGraph.from_dict("efficientnet",
                                       {("B1", 1): 1, ("B3", 1): 1})
    mq = Q.DESBackend(mixed_g, CAT.get_family("efficientnet"),
                      Q.DESConfig(jitter_sigma=0.0), policy="fifo",
                      ci_g_per_kwh=_ci_step, quality_selector=sel)
    rng_q = np.random.default_rng(1)
    for i in range(24):
        mq.submit(InferenceRequest(
            rid=i, prompt=rng_q.integers(0, 64, size=6).astype(np.int32),
            max_new_tokens=8, slo=DEFERRABLE if i % 2 else INTERACTIVE,
            arrival_s=i * 10.0))
    mq_responses = mq.drain()
    mq_stats = mq.stats()
    dec_of = {d.rid: d for d in sel.decisions}
    assert len(mq_responses) == 24 and len(dec_of) == 24
    for r in mq_responses:
        d = dec_of[r.rid]
        assert r.variant == d.variant and r.accuracy == d.accuracy, \
            f"rid {r.rid}: served {r.variant}/{r.accuracy} != decided " \
            f"{d.variant}/{d.accuracy}"
    downshifted = [r for r in mq_responses
                   if dec_of[r.rid].reason == "downshift"]
    assert downshifted, "dirty spell produced no downshift — degenerated"
    assert all(r.slo == DEFERRABLE and r.accuracy < sel.best.accuracy
               for r in downshifted)
    for slo in (INTERACTIVE, DEFERRABLE):
        accs = [r.accuracy for r in mq_responses if r.slo == slo]
        mean = sum(accs) / len(accs)
        assert mean >= floor - 1e-12, \
            f"{slo} served mean {mean:.4f} breached the {floor} floor"
    mq_tol = 1e-9 * max(mq_stats["energy_j"], 1e-12)
    assert abs(sum(r.energy_j for r in mq_responses)
               - mq_stats["energy_j"]) <= mq_tol, \
        "mixed-quality routing broke per-request energy conservation"

    # 10. disagg role-split conservation: the checker itself must accept
    # both stats shapes (disagg split / monolithic "both") and reject a
    # violated split — the real-engine stats are pinned by tests/test_disagg
    # and the disagg_serving bench through this same function
    check_disagg_conservation({
        "energy_j": 10.0, "prefill_energy_j": 6.0, "decode_energy_j": 3.5,
        "handoff_energy_j": 0.5, "both_energy_j": 0.0})
    check_disagg_conservation({"energy_j": stats["energy_j"],
                               "both_energy_j": stats["energy_j"]})
    try:
        check_disagg_conservation({"energy_j": 10.0,
                                   "prefill_energy_j": 6.0})
    except AssertionError:
        pass
    else:
        raise AssertionError("check_disagg_conservation accepted a "
                             "non-conserving role split")

    print(f"obs.validate OK: {int(stats['served'])} requests, "
          f"{summary['spans']} spans, {n_events} chrome events, "
          f"{len(held)} holds released, "
          f"energy {stats['energy_j']:.1f} J conserved, "
          f"openmetrics {len(families)} families round-tripped, "
          f"rollup conserved {totals['energy_j']:.1f} J over "
          f"{len(rollup.regions)} regions, "
          f"mixed-quality governed {len(downshifted)} downshifts "
          f"with the {floor} floor held, "
          f"disagg role-split conservation enforced")
    return 0


if __name__ == "__main__":
    sys.exit(main())
