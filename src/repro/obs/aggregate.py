"""Fleet-scope aggregation: labeled series, streaming histograms, rollups.

PR 6 gave every backend an identical metric catalog; this module is the
layer that makes those registries legible at FLEET scale:

  * **labels** — the canonical label schema (:data:`LABEL_KEYS`:
    ``region`` / ``slo_class`` / ``kv_layout`` / ``phase``).  A registry
    carries constant labels (e.g. its region) and any CATALOG metric can
    fan out labeled child series via ``MetricsRegistry.labeled``; the
    metric-NAME set stays exactly the CATALOG, so the cross-backend parity
    contract is untouched;
  * :class:`StreamingHistogram` — a bounded-memory, *mergeable* histogram
    behind the exact ``Histogram`` API.  Below ``max_raw`` observations it
    IS the exact histogram (raw samples, nearest-rank percentiles —
    bit-identical to :class:`~repro.obs.metrics.Histogram`); past that it
    spills into log-spaced buckets with relative accuracy ``alpha``
    (DDSketch-style), so a 10^6-request replay costs a few thousand ints
    instead of a million floats.  ``count``/``sum``/``mean`` stay exact in
    both modes — that is what makes rollup conservation bit-exact;
  * :class:`FleetRollup` — merges per-region registries into one
    fleet-scope registry: counters and gauges sum in region-insertion
    order (so ``sum(per-region) == fleet`` holds bit-exactly, not merely
    to a tolerance), histograms merge (exact concat while small, sketch
    merge at scale), and every per-region scalar survives as a
    ``region``-labeled child series for the exporter.

Deliberately jax-free (stdlib + numpy only), like the rest of ``repro.obs``.
"""
from __future__ import annotations

import math
from typing import Dict, Iterable, List, Optional, Tuple

import numpy as np

from repro.obs.metrics import Histogram, MetricsRegistry, \
    nearest_rank_percentile

__all__ = ["LABEL_KEYS", "StreamingHistogram", "FleetRollup",
           "check_conservation"]

# the canonical label schema: every labeled child series and every
# registry-level constant label uses keys from this set, so exposition and
# rollup never have to reconcile ad-hoc label vocabularies
LABEL_KEYS = ("region", "slo_class", "kv_layout", "phase", "role")


class StreamingHistogram(Histogram):
    """Bounded-memory mergeable histogram behind the ``Histogram`` API.

    Exact mode (n ≤ ``max_raw``): raw samples, nearest-rank percentiles —
    indistinguishable from the exact histogram, which is what the small-n
    parity test pins.  Spilled mode: log-spaced buckets at relative
    accuracy ``alpha`` (bucket i covers (γ^(i-1), γ^i] with
    γ = (1+α)/(1−α); a quantile estimate is off by at most α of the true
    value).  Bucket keys are clamped to ±``_KEY_LIM``, so memory is
    bounded by construction regardless of the sample count or dynamic
    range.  ``count``/``sum``/``mean`` are exact in both modes.

    Merging (:meth:`merge`) accepts exact histograms and streaming
    histograms of the same ``alpha``: counts/sums add exactly; sample
    stores concatenate while both sides are small and bucket-add once
    either side spilled — the operation :class:`FleetRollup` is built on.
    """

    __slots__ = ("max_raw", "alpha", "_gamma", "_lg", "_raw", "_count",
                 "_sum", "_spilled", "_buckets")
    kind = "histogram"

    _KEY_LIM = 2400          # |key| bound ≈ values in [1e-21, 1e21] at α=1%
    _EPS = 1e-300            # below this magnitude a value is "zero"

    def __init__(self, name: str, max_raw: int = 4096, alpha: float = 0.01):
        assert max_raw >= 1 and 0.0 < alpha < 1.0
        self.name = name
        self.max_raw = int(max_raw)
        self.alpha = float(alpha)
        self._gamma = (1.0 + alpha) / (1.0 - alpha)
        self._lg = math.log(self._gamma)
        self._raw: List[float] = []
        self._count = 0
        self._sum = 0.0
        self._spilled = False
        # (sign, idx) → count; sign 0 is the zero bucket (idx ignored)
        self._buckets: Dict[Tuple[int, int], int] = {}

    # --- the exact-Histogram surface -----------------------------------------
    @property
    def samples(self) -> List[float]:
        """Raw observations while in exact mode (empty once spilled —
        boundedness is the whole point)."""
        return self._raw

    @property
    def spilled(self) -> bool:
        return self._spilled

    @property
    def n_buckets(self) -> int:
        return len(self._buckets)

    def observe(self, value: float) -> None:
        v = float(value)
        self._count += 1
        self._sum += v
        if not self._spilled:
            self._raw.append(v)
            if len(self._raw) > self.max_raw:
                self._spill()
        else:
            k = self._key(v)
            self._buckets[k] = self._buckets.get(k, 0) + 1

    def observe_many(self, values) -> None:
        """Vectorized bulk ingest (the 10^6-scale replay path): one numpy
        pass for the count/sum and the bucket keys instead of a million
        Python-level ``observe`` calls."""
        arr = np.asarray(values, np.float64).reshape(-1)
        if arr.size == 0:
            return
        self._count += int(arr.size)
        self._sum += float(arr.sum())
        if not self._spilled and len(self._raw) + arr.size <= self.max_raw:
            self._raw.extend(float(v) for v in arr)
            return
        if not self._spilled:
            self._spill()
        mag = np.abs(arr)
        nz = mag > self._EPS
        zero_n = int((~nz).sum())
        if zero_n:
            k0 = (0, 0)
            self._buckets[k0] = self._buckets.get(k0, 0) + zero_n
        if nz.any():
            idx = np.ceil(np.log(mag[nz]) / self._lg).astype(np.int64)
            np.clip(idx, -self._KEY_LIM, self._KEY_LIM, out=idx)
            sign = np.where(arr[nz] > 0.0, 1, -1)
            keys, counts = np.unique(
                np.stack([sign, idx], axis=1), axis=0, return_counts=True)
            for (s, i), c in zip(keys.tolist(), counts.tolist()):
                k = (int(s), int(i))
                self._buckets[k] = self._buckets.get(k, 0) + int(c)

    @property
    def count(self) -> int:
        return self._count

    @property
    def sum(self) -> float:
        return self._sum

    @property
    def mean(self) -> float:
        return self._sum / self._count if self._count else 0.0

    def percentile(self, q: float) -> float:
        if not self._spilled:
            return nearest_rank_percentile(self._raw, q)
        if self._count == 0:
            return 0.0
        rank = min(max(math.ceil(q / 100.0 * self._count), 1), self._count)
        seen = 0
        for key in sorted(self._buckets, key=self._bucket_value):
            seen += self._buckets[key]
            if seen >= rank:
                return self._bucket_value(key)
        return self._bucket_value(max(self._buckets,
                                      key=self._bucket_value))

    # --- merge (the rollup primitive) ----------------------------------------
    def merge(self, other: Histogram) -> None:
        """Fold ``other`` (exact or streaming) into this histogram.
        Counts and sums add exactly; sample state concatenates while both
        sides fit ``max_raw`` and buckets add otherwise."""
        if isinstance(other, StreamingHistogram):
            assert other.alpha == self.alpha, \
                f"merging α={other.alpha} sketch into α={self.alpha}"
            self._count += other._count
            self._sum += other._sum
            if not other._spilled:
                self._absorb_raw(other._raw)
            else:
                if not self._spilled:
                    self._spill()
                for k, c in other._buckets.items():
                    self._buckets[k] = self._buckets.get(k, 0) + c
        else:
            self._count += other.count
            self._sum += other.sum
            self._absorb_raw(other.samples)

    def _absorb_raw(self, samples: Iterable[float]) -> None:
        samples = list(samples)
        if not self._spilled and len(self._raw) + len(samples) <= self.max_raw:
            self._raw.extend(samples)
            return
        if not self._spilled:
            self._spill()
        for v in samples:
            k = self._key(v)
            self._buckets[k] = self._buckets.get(k, 0) + 1

    # --- internals -----------------------------------------------------------
    def _key(self, v: float) -> Tuple[int, int]:
        mag = abs(v)
        if mag <= self._EPS:
            return (0, 0)
        idx = math.ceil(math.log(mag) / self._lg)
        idx = min(max(idx, -self._KEY_LIM), self._KEY_LIM)
        return (1 if v > 0.0 else -1, idx)

    def _bucket_value(self, key: Tuple[int, int]) -> float:
        """Representative value of a bucket: the geometric midpoint
        2γ^i/(γ+1) of (γ^(i-1), γ^i], which bounds relative error by α."""
        sign, idx = key
        if sign == 0:
            return 0.0
        return sign * 2.0 * self._gamma ** idx / (self._gamma + 1.0)

    def _spill(self) -> None:
        raw, self._raw = self._raw, []
        self._spilled = True
        for v in raw:
            k = self._key(v)
            self._buckets[k] = self._buckets.get(k, 0) + 1


# =============================================================================
# fleet rollup
# =============================================================================
class FleetRollup:
    """Merge per-region :class:`MetricsRegistry` instances to fleet scope.

    ``add`` registers a region's registry (region name from its ``region``
    constant label, falling back to its backend name); :meth:`merged`
    builds the fleet registry:

      * counters: fleet value accumulates region values in insertion
        order — exactly the order :func:`check_conservation` sums them in,
        so conservation is an ``==``, not an approx;
      * gauges: fleet value/peak are the sums of region values/peaks (a
        fleet's blocks-in-use is the sum over regions);
      * histograms: merged via :class:`StreamingHistogram` (exact concat
        while small, sketch merge at 10^6 scale) — count/sum stay exact;
      * every region scalar also lands as a ``region``-labeled child on
        the fleet registry, and the regions' own labeled children are
        re-labeled with their region, so one OpenMetrics scrape of the
        rollup shows fleet totals AND the per-region breakdown.
    """

    def __init__(self, name: str = "fleet", streaming: bool = True,
                 max_raw: int = 4096, alpha: float = 0.01):
        self.name = name
        self.streaming = streaming
        self.max_raw = max_raw
        self.alpha = alpha
        self.regions: Dict[str, MetricsRegistry] = {}
        self._merged: Optional[MetricsRegistry] = None

    def add(self, registry: MetricsRegistry,
            region: Optional[str] = None) -> None:
        region = (region or registry.labels.get("region")
                  or registry.backend)
        assert region not in self.regions, f"duplicate region {region!r}"
        self.regions[region] = registry
        self._merged = None

    def merged(self) -> MetricsRegistry:
        """The fleet-scope registry (rebuilt lazily after ``add``)."""
        if self._merged is not None:
            return self._merged
        out = MetricsRegistry.standard(self.name, streaming=self.streaming,
                                       max_raw_samples=self.max_raw,
                                       alpha=self.alpha)
        for region, reg in self.regions.items():
            for name in sorted(reg.names()):
                m = reg.get(name)
                if m.kind == "counter":
                    out.counter(name).inc(m.value)
                    out.labeled(name, region=region).inc(m.value)
                elif m.kind == "gauge":
                    g = out.gauge(name)
                    g.value += m.value
                    g.peak += m.peak
                    child = out.labeled(name, region=region)
                    child.value += m.value
                    child.peak += m.peak
                else:
                    tgt = out.histogram(name)
                    if isinstance(tgt, StreamingHistogram):
                        tgt.merge(m)
                    else:
                        tgt.samples.extend(m.samples)
            for name, labels, m in reg.labeled_series():
                labels = {"region": region, **labels}
                child = out.labeled(name, **labels)
                if m.kind == "counter":
                    child.inc(m.value)
                elif m.kind == "gauge":
                    child.value += m.value
                    child.peak += m.peak
                elif isinstance(child, StreamingHistogram):
                    child.merge(m)
                else:
                    child.samples.extend(m.samples)
        self._merged = out
        return out

    def conservation(self, names: Tuple[str, ...] = ("energy_j", "carbon_g")
                     ) -> Dict[str, float]:
        """Assert bit-exact conservation for the given counters and return
        the fleet totals.  ``sum`` walks regions in the same insertion
        order ``merged`` accumulated them, so the comparison is ``==``."""
        return check_conservation(self, names)


def check_conservation(rollup: FleetRollup,
                       names: Tuple[str, ...] = ("energy_j", "carbon_g")
                       ) -> Dict[str, float]:
    """Bit-exact conservation check: for each counter in ``names``, the
    region values summed in insertion order must equal the fleet total
    EXACTLY (same float additions in the same order — any mismatch means
    a region was double-counted or dropped, not rounding)."""
    fleet = rollup.merged()
    out: Dict[str, float] = {}
    for name in names:
        expect = 0.0
        for reg in rollup.regions.values():
            expect += reg.counter(name).value
        got = fleet.counter(name).value
        assert got == expect, \
            f"rollup conservation broken for {name!r}: fleet {got!r} != " \
            f"sum over {len(rollup.regions)} regions {expect!r}"
        # every histogram's count/sum is exact in both modes, so totals of
        # merged distributions conserve too
        out[name] = got
    return out
