"""Request-lifecycle tracing: a low-overhead span/event recorder.

One :class:`TraceRecorder` captures a serving backend's whole request
lifecycle — arrival → hold/release (policy decision + reason) → admission →
chunked-prefill chunks → decode ticks (batched: ONE event per tick carrying
the occupant set) → preempt / swap-out / partial swap-in → completion — and
exports it two ways:

  * **JSONL** (:meth:`TraceRecorder.to_jsonl`): one record per line, the
    machine-readable schema tests and offline analysis consume;
  * **Chrome-trace JSON** (:meth:`TraceRecorder.to_chrome_trace`): a
    ``{"traceEvents": [...]}`` object loadable in Perfetto
    (https://ui.perfetto.dev) — every request renders as its own track
    (tid = rid), the engine's tick/counter stream renders on track 0, and
    span args carry the request's attributed joules/gCO2, so the trace is a
    visual audit of the carbon attribution.

Record schema (JSONL; all times are backend-clock seconds, session-relative):

  span     {"kind": "span", "name": str, "rid": int|null,
            "t0": float, "t1": float, "args": {...}}
  instant  {"kind": "instant", "name": str, "rid": int|null,
            "t": float, "args": {...}}
  counter  {"kind": "counter", "name": str, "t": float, "value": float}

The **conservation invariant** (:func:`validate_trace`): every span opened
is closed, and the ``energy_j`` attributed across ``request`` spans sums to
the engine's session total exactly — an unclosed span or a joule that
appears in the engine total but in no request's span tree is an attribution
bug, not a rendering artifact.
"""
from __future__ import annotations

import json
from typing import Dict, List, Optional

__all__ = ["TraceRecorder", "validate_trace", "validate_chrome_events"]

_US = 1e6     # seconds → Chrome-trace microseconds


def _json_default(o):
    """numpy scalars/arrays → plain JSON (the recorder never imports numpy;
    callers may still pass its scalars through span args)."""
    if hasattr(o, "item"):
        return o.item()
    if hasattr(o, "tolist"):
        return o.tolist()
    raise TypeError(f"not JSON serializable: {type(o).__name__}")


class TraceRecorder:
    """Append-only span/event log for one backend.

    Overhead discipline: recording is a dict append — no I/O, no
    serialization, no clock reads (callers pass their own timestamps, so
    the recorder works identically on the real engine's wall clock and the
    DES's simulated clock).  Export and validation walk the log after the
    session.  Persistent across serve sessions: a fleet probe loop reuses
    one recorder and the traces concatenate."""

    def __init__(self, backend: str = "backend"):
        self.backend = backend
        self.records: List[dict] = []
        self._open: Dict[int, dict] = {}     # sid → record still open

    # --- recording -----------------------------------------------------------
    def open_span(self, name: str, t: float, rid: Optional[int] = None,
                  **args) -> int:
        rec = {"kind": "span", "name": name, "rid": rid,
               "t0": float(t), "t1": None, "args": args}
        sid = len(self.records)
        self.records.append(rec)
        self._open[sid] = rec
        return sid

    def close_span(self, sid: int, t: float, **args) -> None:
        rec = self._open.pop(sid)
        rec["t1"] = float(t)
        if args:
            rec["args"].update(args)

    def span(self, name: str, t0: float, t1: float,
             rid: Optional[int] = None, **args) -> int:
        """Record an already-closed span (e.g. a policy hold reconstructed
        at completion from the policy's hold log)."""
        sid = self.open_span(name, t0, rid, **args)
        self.close_span(sid, t1)
        return sid

    def instant(self, name: str, t: float, rid: Optional[int] = None,
                **args) -> None:
        self.records.append({"kind": "instant", "name": name, "rid": rid,
                             "t": float(t), "args": args})

    def counter(self, name: str, t: float, value: float) -> None:
        self.records.append({"kind": "counter", "name": name,
                             "t": float(t), "value": float(value)})

    def annotate(self, sid: int, **args) -> None:
        """Attach args to a span after the fact — how the engine writes the
        finalized per-request joules/gCO2 onto request spans that closed at
        completion time (the idle-floor share only exists at drain)."""
        self.records[sid]["args"].update(args)

    # --- introspection -------------------------------------------------------
    @property
    def open_spans(self) -> int:
        return len(self._open)

    def spans(self, name: Optional[str] = None) -> List[dict]:
        return [r for r in self.records if r["kind"] == "span"
                and (name is None or r["name"] == name)]

    def instants(self, name: Optional[str] = None) -> List[dict]:
        return [r for r in self.records if r["kind"] == "instant"
                and (name is None or r["name"] == name)]

    # --- export --------------------------------------------------------------
    def to_jsonl(self, path: str) -> None:
        with open(path, "w") as f:
            for rec in self.records:
                f.write(json.dumps(rec, default=_json_default) + "\n")

    def chrome_events(self) -> List[dict]:
        """Chrome-trace event list: spans → complete ("X") events, instants
        → thread-scoped "i", counters → "C".  One pid per recorder; request
        tracks keyed by rid (tid = rid + 1; tid 0 is the engine track)."""
        pid = 1
        ev: List[dict] = [
            {"ph": "M", "name": "process_name", "pid": pid, "tid": 0,
             "args": {"name": self.backend}},
            {"ph": "M", "name": "thread_name", "pid": pid, "tid": 0,
             "args": {"name": "engine"}},
        ]
        named_tids = set()
        for rec in self.records:
            rid = rec.get("rid")
            tid = 0 if rid is None else int(rid) + 1
            if rid is not None and tid not in named_tids:
                named_tids.add(tid)
                ev.append({"ph": "M", "name": "thread_name", "pid": pid,
                           "tid": tid, "args": {"name": f"req {rid}"}})
            if rec["kind"] == "span":
                t1 = rec["t1"] if rec["t1"] is not None else rec["t0"]
                ev.append({"ph": "X", "name": rec["name"], "pid": pid,
                           "tid": tid, "ts": rec["t0"] * _US,
                           "dur": max((t1 - rec["t0"]) * _US, 0.0),
                           "args": rec["args"]})
            elif rec["kind"] == "instant":
                ev.append({"ph": "i", "name": rec["name"], "pid": pid,
                           "tid": tid, "ts": rec["t"] * _US, "s": "t",
                           "args": rec["args"]})
            else:   # counter
                ev.append({"ph": "C", "name": rec["name"], "pid": pid,
                           "tid": 0, "ts": rec["t"] * _US,
                           "args": {"value": rec["value"]}})
        return ev

    def to_chrome_trace(self, path: str) -> None:
        with open(path, "w") as f:
            json.dump({"traceEvents": self.chrome_events(),
                       "displayTimeUnit": "ms"}, f, default=_json_default)


# =============================================================================
# validation — the instrumentation contract
# =============================================================================
def validate_trace(tr: TraceRecorder,
                   expect_energy_j: Optional[float] = None,
                   expect_requests: Optional[int] = None,
                   rel: float = 1e-9) -> Dict[str, float]:
    """Enforce the conservation invariant on a recorded trace.

    Checks (AssertionError on violation):
      1. every span opened was closed (no dangling lifecycle state);
      2. every ``request`` span carries an ``energy_j`` attribution;
      3. the span-attributed joules sum to ``expect_energy_j`` (the
         backend's session total) within ``rel`` — i.e. the trace accounts
         for every joule the engine charged, no more, no less;
      4. optional: the number of request spans matches ``expect_requests``.

    Returns a summary dict (spans, requests, attributed energy/carbon).
    """
    assert tr.open_spans == 0, \
        f"{tr.open_spans} span(s) never closed: " \
        f"{[r['name'] for r in tr._open.values()][:5]}"
    reqs = tr.spans("request")
    for r in reqs:
        assert r["t1"] is not None and r["t1"] >= r["t0"], \
            f"request {r['rid']} span has bad bounds"
        assert "energy_j" in r["args"], \
            f"request {r['rid']} span carries no energy attribution"
    total_j = sum(r["args"].get("energy_j", 0.0) for r in reqs)
    total_g = sum(r["args"].get("carbon_g", 0.0) for r in reqs)
    if expect_requests is not None:
        assert len(reqs) == expect_requests, \
            f"{len(reqs)} request spans != {expect_requests} served"
    if expect_energy_j is not None:
        tol = rel * max(abs(expect_energy_j), 1e-12)
        assert abs(total_j - expect_energy_j) <= tol, \
            f"span-attributed joules {total_j!r} != engine total " \
            f"{expect_energy_j!r} (conservation violated)"
    return {"spans": len(tr.spans()), "requests": len(reqs),
            "energy_j": total_j, "carbon_g": total_g,
            "records": len(tr.records)}


_REQUIRED = {"X": ("name", "ph", "ts", "dur", "pid", "tid"),
             "i": ("name", "ph", "ts", "pid", "tid"),
             "C": ("name", "ph", "ts", "pid", "args"),
             "M": ("name", "ph", "pid", "args")}


def validate_chrome_events(events: List[dict]) -> int:
    """Schema check for a Chrome-trace event list (what Perfetto's legacy
    JSON importer requires).  Returns the number of non-metadata events."""
    assert isinstance(events, list) and events, "empty trace"
    n = 0
    for e in events:
        ph = e.get("ph")
        assert ph in _REQUIRED, f"unknown phase {ph!r}"
        for key in _REQUIRED[ph]:
            assert key in e, f"{ph!r} event missing {key!r}: {e}"
        if ph != "M":
            n += 1
            assert isinstance(e["ts"], (int, float)) and e["ts"] >= 0, e
        if ph == "X":
            assert e["dur"] >= 0, e
    # the whole list must survive a JSON round-trip (Perfetto reads a file)
    json.loads(json.dumps(events, default=_json_default))
    return n
