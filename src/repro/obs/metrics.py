"""Metrics registry: counters, gauges, histograms under shared names.

Replaces the scattered per-backend ``stats`` dicts as the source of truth:
every serving layer emits into a :class:`MetricsRegistry` and its protocol
``stats()`` becomes a *view* over the registry.  The cross-backend metric
names live in :data:`CATALOG`; :meth:`MetricsRegistry.standard`
pre-registers the whole catalog so the metric-name *set* is identical
across backends by construction — a backend that never preempts still
reports ``preemptions == 0`` instead of omitting the name, which is what
lets one dashboard / one test read real, DES and fluid runs side by side.

Histogram percentiles are exact nearest-rank over the raw observations
(rank = ceil(q/100·n) clamped to [1, n]) — the same rounding as
``serving.scheduler.latency_percentile``, kept in sync by a test, so a
registry histogram reproduces the engine's legacy percentile numbers
bit-for-bit.
"""
from __future__ import annotations

import math
from typing import Dict, Iterable, Iterator, List, Mapping, Optional, Set, \
    Tuple

__all__ = ["CATALOG", "CORE_METRICS", "Counter", "Gauge", "Histogram",
           "MetricsRegistry", "nearest_rank_percentile"]


def nearest_rank_percentile(values: List[float], q: float) -> float:
    """Exact nearest-rank percentile (ceil(q/100·n), clamped to [1, n])."""
    if not values:
        return 0.0
    s = sorted(values)
    rank = math.ceil(q / 100.0 * len(s))
    return s[min(max(rank, 1), len(s)) - 1]


class Counter:
    """Monotonically increasing count (requests, joules, tokens, ...)."""

    __slots__ = ("name", "value")
    kind = "counter"

    def __init__(self, name: str):
        self.name = name
        self.value = 0.0

    def inc(self, amount: float = 1.0) -> None:
        assert amount >= 0, f"counter {self.name} decremented by {amount}"
        self.value += amount


class Gauge:
    """Last-written value plus its observed peak (occupancy, backlog, ...)."""

    __slots__ = ("name", "value", "peak")
    kind = "gauge"

    def __init__(self, name: str):
        self.name = name
        self.value = 0.0
        self.peak = 0.0

    def set(self, value: float) -> None:
        self.value = float(value)
        self.peak = max(self.peak, self.value)


class Histogram:
    """Raw-sample histogram with exact nearest-rank percentiles.

    Keeps every observation (serving sessions are bounded — tens to tens of
    thousands of samples); ``percentile`` is exact, not a bucket
    approximation, because the SLA numbers the paper reports are tail
    quantiles and bucketing error lands exactly there."""

    __slots__ = ("name", "samples")
    kind = "histogram"

    def __init__(self, name: str):
        self.name = name
        self.samples: List[float] = []

    def observe(self, value: float) -> None:
        self.samples.append(float(value))

    @property
    def count(self) -> int:
        return len(self.samples)

    @property
    def sum(self) -> float:
        return float(sum(self.samples))

    @property
    def mean(self) -> float:
        return self.sum / self.count if self.samples else 0.0

    def percentile(self, q: float) -> float:
        return nearest_rank_percentile(self.samples, q)


# =============================================================================
# shared metric-name catalog
# =============================================================================
# The cross-backend contract: every serving backend (real slotted, real
# paged, DES, fluid) and the fleet's per-region telemetry report under
# exactly these names.  Extending the serving layer means extending this
# table — tests assert the emitted name set equals the catalog.
CATALOG: Dict[str, str] = {
    # request flow
    "requests_submitted": "counter",
    "requests_served": "counter",
    "tokens_generated": "counter",
    "deadline_misses": "counter",
    "preemptions": "counter",
    "holds_released": "counter",    # requests a policy held then released
    # energy / carbon attribution
    "energy_j": "counter",
    "carbon_g": "counter",
    # latency distributions (seconds)
    "latency_s": "histogram",
    "queue_delay_s": "histogram",
    "ttft_s": "histogram",
    "held_s": "histogram",          # policy-hold portion of the queue delay
    "accuracy": "histogram",        # per-request serving-variant accuracy
    # engine internals (zero on analytic backends — the names still exist)
    "decode_steps": "counter",
    "decode_dispatches": "counter",  # jit decode calls (fused: 1 per k steps)
    # host↔device traffic of the decode hot path.  ``h2d_transfers`` counts
    # host→device uploads of loop state (event-driven only: steady-state
    # pipelined decode must add ZERO per tick — the regression gate of the
    # device-resident loop).  ``host_syncs`` counts *non-overlapped* blocking
    # device round-trips: a same-tick readback (slotted per-step argmax,
    # forced pipeline flushes); a landing that had a full tick of lookahead
    # overlap is not a sync.
    "host_syncs": "counter",
    "h2d_transfers": "counter",
    "prefill_chunks": "counter",
    "prefix_hit_tokens": "counter",
    "swapin_pages_copied": "counter",
    "swapin_pages_saved": "counter",
    # prefill→decode disaggregation (serving.disagg): sequences handed off
    # and the filled KV pages that moved with them (zero on monolithic and
    # analytic backends — the names still exist)
    "handoffs": "counter",
    "handoff_pages": "counter",
    "compile_retraces": "counter",  # post-warmup jit shape misses
    "blocks_in_use": "gauge",       # .peak = blocks_peak
    "occupied_rows": "gauge",
    # phase-level profiling (labeled by ``phase``: prefill_chunk /
    # decode_dispatch / decode_land / swap_d2h / swap_h2d — see obs.profile)
    "phase_latency_s": "histogram",
    # session
    "wall_s": "gauge",
}

# the subset every backend genuinely measures (used by parity tests to
# assert the values — not just the names — were filled in)
CORE_METRICS = ("requests_submitted", "requests_served", "energy_j",
                "carbon_g", "latency_s", "queue_delay_s", "wall_s")


class MetricsRegistry:
    """Named metrics under one roof; get-or-create with kind checking.

    ``labels`` are constant labels stamped on the registry itself (e.g. a
    fleet region's ``{"region": "CA"}`` or an engine session's
    ``{"kv_layout": "paged"}``) — the exporter merges them into every
    exposed sample.  :meth:`labeled` fans a CATALOG metric out into child
    series keyed by label values (``slo_class``, ``phase``, ...); children
    live in a separate table so :meth:`names` — the cross-backend parity
    contract — still returns exactly the unlabeled catalog.

    ``streaming=True`` swaps histograms for bounded-memory mergeable
    :class:`~repro.obs.aggregate.StreamingHistogram` instances (exact
    below ``max_raw_samples`` observations, log-bucket sketch above) —
    the 10^6-scale replay / fleet-rollup configuration.
    """

    def __init__(self, backend: str = "backend",
                 labels: Optional[Mapping[str, str]] = None,
                 streaming: bool = False, max_raw_samples: int = 4096,
                 alpha: float = 0.01):
        self.backend = backend
        self.labels: Dict[str, str] = dict(labels or {})
        self.streaming = streaming
        self.max_raw_samples = max_raw_samples
        self.alpha = alpha
        self._metrics: Dict[str, object] = {}
        # (name, ((k, v), ...)) → child metric; kept out of _metrics so
        # names() stays exactly the catalog
        self._labeled: Dict[Tuple[str, Tuple[Tuple[str, str], ...]],
                            object] = {}

    @classmethod
    def standard(cls, backend: str = "backend",
                 labels: Optional[Mapping[str, str]] = None,
                 streaming: bool = False, max_raw_samples: int = 4096,
                 alpha: float = 0.01) -> "MetricsRegistry":
        """A registry with the whole :data:`CATALOG` pre-registered — the
        constructor every serving backend uses, so metric-name sets are
        identical across backends by construction."""
        reg = cls(backend, labels=labels, streaming=streaming,
                  max_raw_samples=max_raw_samples, alpha=alpha)
        for name, kind in CATALOG.items():
            reg._register(name, kind)
        return reg

    # --- get-or-create -------------------------------------------------------
    def _make(self, name: str, kind: str):
        if kind == "histogram" and self.streaming:
            from repro.obs.aggregate import StreamingHistogram
            return StreamingHistogram(name, max_raw=self.max_raw_samples,
                                      alpha=self.alpha)
        ctor = {"counter": Counter, "gauge": Gauge,
                "histogram": Histogram}[kind]
        return ctor(name)

    def _register(self, name: str, kind: str):
        m = self._metrics.get(name)
        if m is not None:
            assert m.kind == kind, \
                f"metric {name!r} is a {m.kind}, requested as {kind}"
            return m
        m = self._make(name, kind)
        self._metrics[name] = m
        return m

    def labeled(self, name: str, **labels: str):
        """Child series of CATALOG metric ``name`` for the given labels
        (e.g. ``reg.labeled("ttft_s", slo_class="interactive")``).  Same
        kind as the parent; label keys must come from the canonical schema
        (:data:`~repro.obs.aggregate.LABEL_KEYS`)."""
        from repro.obs.aggregate import LABEL_KEYS
        assert labels, f"labeled({name!r}) called without labels"
        for k in labels:
            assert k in LABEL_KEYS, \
                f"unknown label key {k!r} (schema: {LABEL_KEYS})"
        parent = self._metrics.get(name)
        assert parent is not None, f"no CATALOG metric {name!r} registered"
        key = (name, tuple(sorted((k, str(v)) for k, v in labels.items())))
        m = self._labeled.get(key)
        if m is None:
            m = self._make(name, parent.kind)
            self._labeled[key] = m
        return m

    def labeled_series(self, name: Optional[str] = None
                       ) -> Iterator[Tuple[str, Dict[str, str], object]]:
        """Yield ``(name, labels, metric)`` for every labeled child
        (optionally restricted to one metric name), in insertion order."""
        for (n, lk), m in self._labeled.items():
            if name is None or n == name:
                yield n, dict(lk), m

    def counter(self, name: str) -> Counter:
        return self._register(name, "counter")

    def gauge(self, name: str) -> Gauge:
        return self._register(name, "gauge")

    def histogram(self, name: str) -> Histogram:
        return self._register(name, "histogram")

    # --- introspection -------------------------------------------------------
    def names(self) -> Set[str]:
        return set(self._metrics)

    def __contains__(self, name: str) -> bool:
        return name in self._metrics

    def get(self, name: str):
        return self._metrics.get(name)

    def value(self, name: str) -> float:
        """Scalar value of a counter/gauge (histograms: use the object)."""
        m = self._metrics[name]
        assert m.kind != "histogram", f"{name} is a histogram"
        return m.value

    def snapshot(self, percentiles: Iterable[float] = (50.0, 95.0, 99.0)
                 ) -> Dict[str, float]:
        """Flat scalar view: counters/gauges by name (gauges also emit
        ``<name>_peak``), histograms expanded to ``<name>_pNN`` +
        ``<name>_count`` / ``<name>_mean``."""
        out: Dict[str, float] = {}
        for name, m in sorted(self._metrics.items()):
            if m.kind == "histogram":
                out[f"{name}_count"] = float(m.count)
                out[f"{name}_mean"] = m.mean
                for q in percentiles:
                    out[f"{name}_p{q:g}"] = m.percentile(q)
            elif m.kind == "gauge":
                out[name] = m.value
                out[f"{name}_peak"] = m.peak
            else:
                out[name] = m.value
        return out
