"""Metrics registry: counters, gauges, histograms under shared names.

Replaces the scattered per-backend ``stats`` dicts as the source of truth:
every serving layer emits into a :class:`MetricsRegistry` and its protocol
``stats()`` becomes a *view* over the registry.  The cross-backend metric
names live in :data:`CATALOG`; :meth:`MetricsRegistry.standard`
pre-registers the whole catalog so the metric-name *set* is identical
across backends by construction — a backend that never preempts still
reports ``preemptions == 0`` instead of omitting the name, which is what
lets one dashboard / one test read real, DES and fluid runs side by side.

Histogram percentiles are exact nearest-rank over the raw observations
(rank = ceil(q/100·n) clamped to [1, n]) — the same rounding as
``serving.scheduler.latency_percentile``, kept in sync by a test, so a
registry histogram reproduces the engine's legacy percentile numbers
bit-for-bit.
"""
from __future__ import annotations

import math
from typing import Dict, Iterable, List, Optional, Set

__all__ = ["CATALOG", "CORE_METRICS", "Counter", "Gauge", "Histogram",
           "MetricsRegistry", "nearest_rank_percentile"]


def nearest_rank_percentile(values: List[float], q: float) -> float:
    """Exact nearest-rank percentile (ceil(q/100·n), clamped to [1, n])."""
    if not values:
        return 0.0
    s = sorted(values)
    rank = math.ceil(q / 100.0 * len(s))
    return s[min(max(rank, 1), len(s)) - 1]


class Counter:
    """Monotonically increasing count (requests, joules, tokens, ...)."""

    __slots__ = ("name", "value")
    kind = "counter"

    def __init__(self, name: str):
        self.name = name
        self.value = 0.0

    def inc(self, amount: float = 1.0) -> None:
        assert amount >= 0, f"counter {self.name} decremented by {amount}"
        self.value += amount


class Gauge:
    """Last-written value plus its observed peak (occupancy, backlog, ...)."""

    __slots__ = ("name", "value", "peak")
    kind = "gauge"

    def __init__(self, name: str):
        self.name = name
        self.value = 0.0
        self.peak = 0.0

    def set(self, value: float) -> None:
        self.value = float(value)
        self.peak = max(self.peak, self.value)


class Histogram:
    """Raw-sample histogram with exact nearest-rank percentiles.

    Keeps every observation (serving sessions are bounded — tens to tens of
    thousands of samples); ``percentile`` is exact, not a bucket
    approximation, because the SLA numbers the paper reports are tail
    quantiles and bucketing error lands exactly there."""

    __slots__ = ("name", "samples")
    kind = "histogram"

    def __init__(self, name: str):
        self.name = name
        self.samples: List[float] = []

    def observe(self, value: float) -> None:
        self.samples.append(float(value))

    @property
    def count(self) -> int:
        return len(self.samples)

    @property
    def sum(self) -> float:
        return float(sum(self.samples))

    @property
    def mean(self) -> float:
        return self.sum / self.count if self.samples else 0.0

    def percentile(self, q: float) -> float:
        return nearest_rank_percentile(self.samples, q)


# =============================================================================
# shared metric-name catalog
# =============================================================================
# The cross-backend contract: every serving backend (real slotted, real
# paged, DES, fluid) and the fleet's per-region telemetry report under
# exactly these names.  Extending the serving layer means extending this
# table — tests assert the emitted name set equals the catalog.
CATALOG: Dict[str, str] = {
    # request flow
    "requests_submitted": "counter",
    "requests_served": "counter",
    "tokens_generated": "counter",
    "deadline_misses": "counter",
    "preemptions": "counter",
    "holds_released": "counter",    # requests a policy held then released
    # energy / carbon attribution
    "energy_j": "counter",
    "carbon_g": "counter",
    # latency distributions (seconds)
    "latency_s": "histogram",
    "queue_delay_s": "histogram",
    "ttft_s": "histogram",
    "held_s": "histogram",          # policy-hold portion of the queue delay
    "accuracy": "histogram",        # per-request serving-variant accuracy
    # engine internals (zero on analytic backends — the names still exist)
    "decode_steps": "counter",
    "decode_dispatches": "counter",  # jit decode calls (fused: 1 per k steps)
    # host↔device traffic of the decode hot path.  ``h2d_transfers`` counts
    # host→device uploads of loop state (event-driven only: steady-state
    # pipelined decode must add ZERO per tick — the regression gate of the
    # device-resident loop).  ``host_syncs`` counts *non-overlapped* blocking
    # device round-trips: a same-tick readback (slotted per-step argmax,
    # forced pipeline flushes); a landing that had a full tick of lookahead
    # overlap is not a sync.
    "host_syncs": "counter",
    "h2d_transfers": "counter",
    "prefill_chunks": "counter",
    "prefix_hit_tokens": "counter",
    "swapin_pages_copied": "counter",
    "swapin_pages_saved": "counter",
    "compile_retraces": "counter",  # post-warmup jit shape misses
    "blocks_in_use": "gauge",       # .peak = blocks_peak
    "occupied_rows": "gauge",
    # session
    "wall_s": "gauge",
}

# the subset every backend genuinely measures (used by parity tests to
# assert the values — not just the names — were filled in)
CORE_METRICS = ("requests_submitted", "requests_served", "energy_j",
                "carbon_g", "latency_s", "queue_delay_s", "wall_s")


class MetricsRegistry:
    """Named metrics under one roof; get-or-create with kind checking."""

    def __init__(self, backend: str = "backend"):
        self.backend = backend
        self._metrics: Dict[str, object] = {}

    @classmethod
    def standard(cls, backend: str = "backend") -> "MetricsRegistry":
        """A registry with the whole :data:`CATALOG` pre-registered — the
        constructor every serving backend uses, so metric-name sets are
        identical across backends by construction."""
        reg = cls(backend)
        for name, kind in CATALOG.items():
            reg._register(name, kind)
        return reg

    # --- get-or-create -------------------------------------------------------
    def _register(self, name: str, kind: str):
        m = self._metrics.get(name)
        if m is not None:
            assert m.kind == kind, \
                f"metric {name!r} is a {m.kind}, requested as {kind}"
            return m
        ctor = {"counter": Counter, "gauge": Gauge,
                "histogram": Histogram}[kind]
        m = ctor(name)
        self._metrics[name] = m
        return m

    def counter(self, name: str) -> Counter:
        return self._register(name, "counter")

    def gauge(self, name: str) -> Gauge:
        return self._register(name, "gauge")

    def histogram(self, name: str) -> Histogram:
        return self._register(name, "histogram")

    # --- introspection -------------------------------------------------------
    def names(self) -> Set[str]:
        return set(self._metrics)

    def __contains__(self, name: str) -> bool:
        return name in self._metrics

    def get(self, name: str):
        return self._metrics.get(name)

    def value(self, name: str) -> float:
        """Scalar value of a counter/gauge (histograms: use the object)."""
        m = self._metrics[name]
        assert m.kind != "histogram", f"{name} is a histogram"
        return m.value

    def snapshot(self, percentiles: Iterable[float] = (50.0, 95.0, 99.0)
                 ) -> Dict[str, float]:
        """Flat scalar view: counters/gauges by name (gauges also emit
        ``<name>_peak``), histograms expanded to ``<name>_pNN`` +
        ``<name>_count`` / ``<name>_mean``."""
        out: Dict[str, float] = {}
        for name, m in sorted(self._metrics.items()):
            if m.kind == "histogram":
                out[f"{name}_count"] = float(m.count)
                out[f"{name}_mean"] = m.mean
                for q in percentiles:
                    out[f"{name}_p{q:g}"] = m.percentile(q)
            elif m.kind == "gauge":
                out[name] = m.value
                out[f"{name}_peak"] = m.peak
            else:
                out[name] = m.value
        return out
