"""Declarative SLO / carbon-budget rules with multi-window burn-rate alerts.

The SRE error-budget formulation, applied to both latency SLOs and the
Clover carbon budget:

  * a :class:`LatencyObjective` says "fraction of ``slo_class`` requests
    with ``metric`` ≤ ``threshold_s`` must be ≥ ``target``".  The error
    budget is ``1 − target``; the **burn rate** over a window is
    ``bad_fraction / (1 − target)`` — 1.0 means exactly on budget, 10
    means the budget burns 10× too fast;
  * a :class:`CarbonBudget` says "at most ``budget_g`` gCO2 per
    ``window_s`` of wall time".  Its burn rate over an evaluation window W
    is ``grams_in_W / (budget_g · W / window_s)`` — emitted grams over
    the pro-rated allowance;
  * alerts use the standard **multi-window** guard: fire only when the
    burn rate is ≥ ``fire_burn`` in BOTH the short and the long window
    (short = fast detection, long = deblipping), clear when both drop
    below ``clear_burn``.  With deterministic inputs the fire/clear tick
    sequence is deterministic — the synthetic-trace test pins it exactly.

:class:`SLOEvaluator` holds the rule set + sliding event windows; the
``Controller`` consumes it via ``alerts=`` and forces a re-optimization
the tick a rule starts firing (see ``core.controller``).

Pure stdlib; events are (t, is_bad) / (t, grams) deques pruned beyond the
long window, so memory is bounded by window length, not run length.
"""
from __future__ import annotations

from collections import deque
from dataclasses import dataclass, field
from typing import Deque, Dict, List, Optional, Tuple

__all__ = ["LatencyObjective", "CarbonBudget", "BurnRatePolicy",
           "AlertState", "SLOEvaluator", "default_rules"]


@dataclass(frozen=True)
class LatencyObjective:
    """``target`` fraction of ``slo_class`` requests must have
    ``metric`` ≤ ``threshold_s``."""
    name: str
    threshold_s: float
    target: float = 0.95
    metric: str = "ttft_s"            # "ttft_s" or "latency_s"
    slo_class: str = "interactive"

    def __post_init__(self):
        assert self.metric in ("ttft_s", "latency_s"), self.metric
        assert 0.0 < self.target < 1.0, self.target


@dataclass(frozen=True)
class CarbonBudget:
    """At most ``budget_g`` grams of CO2 per ``window_s`` seconds."""
    name: str
    budget_g: float
    window_s: float = 3600.0

    def __post_init__(self):
        assert self.budget_g > 0 and self.window_s > 0


@dataclass(frozen=True)
class BurnRatePolicy:
    """Multi-window burn-rate thresholds (defaults: page on 2× burn seen
    in both a 5-minute and a 1-hour window; clear below 1×)."""
    short_s: float = 300.0
    long_s: float = 3600.0
    fire_burn: float = 2.0
    clear_burn: float = 1.0

    def __post_init__(self):
        assert 0 < self.short_s <= self.long_s
        assert 0 < self.clear_burn <= self.fire_burn


@dataclass
class AlertState:
    """Deterministic alert lifecycle for one rule."""
    rule: object
    firing: bool = False
    t_fired: Optional[float] = None
    t_cleared: Optional[float] = None
    fire_count: int = 0
    burn_short: float = 0.0
    burn_long: float = 0.0
    transitions: List[Tuple[float, str]] = field(default_factory=list)

    def _update(self, t: float, policy: BurnRatePolicy) -> None:
        if not self.firing and self.burn_short >= policy.fire_burn \
                and self.burn_long >= policy.fire_burn:
            self.firing = True
            self.t_fired = t
            self.fire_count += 1
            self.transitions.append((t, "fire"))
        elif self.firing and self.burn_short < policy.clear_burn \
                and self.burn_long < policy.clear_burn:
            self.firing = False
            self.t_cleared = t
            self.transitions.append((t, "clear"))


class SLOEvaluator:
    """Sliding-window burn-rate evaluation over a declarative rule set."""

    def __init__(self, rules: List[object],
                 policy: BurnRatePolicy = BurnRatePolicy()):
        self.policy = policy
        self.rules: List[object] = list(rules)
        self.states: Dict[str, AlertState] = {}
        seen = set()
        for r in self.rules:
            assert isinstance(r, (LatencyObjective, CarbonBudget)), r
            assert r.name not in seen, f"duplicate rule name {r.name!r}"
            seen.add(r.name)
            self.states[r.name] = AlertState(rule=r)
        # per-(slo_class, metric) deque of (t, is_bad); carbon: (t, grams)
        self._lat: Dict[Tuple[str, str], Deque[Tuple[float, bool]]] = {}
        self._carbon: Deque[Tuple[float, float]] = deque()
        self.total_fires = 0

    # --- ingestion -----------------------------------------------------------
    def record_request(self, t: float, slo_class: str,
                       ttft_s: Optional[float] = None,
                       latency_s: Optional[float] = None) -> None:
        for metric, value in (("ttft_s", ttft_s), ("latency_s", latency_s)):
            if value is None:
                continue
            for r in self.rules:
                if isinstance(r, LatencyObjective) and r.metric == metric \
                        and r.slo_class == slo_class:
                    key = (slo_class, metric)
                    dq = self._lat.setdefault(key, deque())
                    dq.append((float(t), float(value) > r.threshold_s))
                    break   # one event per (class, metric) sample

    def record_carbon(self, t: float, grams: float) -> None:
        if grams > 0:
            self._carbon.append((float(t), float(grams)))

    def observe_response(self, t: float, resp) -> None:
        """Convenience: ingest an ``InferenceResponse``-shaped object."""
        self.record_request(t, getattr(resp, "slo", "interactive"),
                            ttft_s=getattr(resp, "ttft_s", None),
                            latency_s=getattr(resp, "latency_s", None))

    # --- evaluation ----------------------------------------------------------
    def evaluate(self, t: float) -> List[AlertState]:
        """Recompute burn rates at time ``t``, advance every rule's alert
        state machine, and return the states (stable rule order)."""
        self._prune(t)
        for r in self.rules:
            st = self.states[r.name]
            st.burn_short = self._burn(r, t, self.policy.short_s)
            st.burn_long = self._burn(r, t, self.policy.long_s)
            was = st.fire_count
            st._update(t, self.policy)
            self.total_fires += st.fire_count - was
        return [self.states[r.name] for r in self.rules]

    def firing(self) -> List[AlertState]:
        return [s for s in self.states.values() if s.firing]

    # --- internals -----------------------------------------------------------
    def _burn(self, rule, t: float, window_s: float) -> float:
        lo = t - window_s
        if isinstance(rule, LatencyObjective):
            dq = self._lat.get((rule.slo_class, rule.metric))
            if not dq:
                return 0.0
            n = bad = 0
            for ts, is_bad in dq:
                if ts > lo:
                    n += 1
                    bad += is_bad
            if n == 0:
                return 0.0
            return (bad / n) / (1.0 - rule.target)
        grams = sum(g for ts, g in self._carbon if ts > lo)
        allowance = rule.budget_g * (window_s / rule.window_s)
        return grams / allowance

    def _prune(self, t: float) -> None:
        lo = t - self.policy.long_s
        for dq in self._lat.values():
            while dq and dq[0][0] <= lo:
                dq.popleft()
        while self._carbon and self._carbon[0][0] <= lo:
            self._carbon.popleft()


def default_rules(ttft_s: float = 0.5, latency_s: float = 10.0,
                  carbon_g_per_h: float = 50.0) -> List[object]:
    """The rule set the CLI / fleet sim use when none is given: an
    interactive TTFT objective, a batch completion-latency objective, and
    an hourly carbon budget."""
    return [
        LatencyObjective("interactive-ttft", threshold_s=ttft_s,
                         target=0.95, metric="ttft_s",
                         slo_class="interactive"),
        LatencyObjective("deferrable-latency", threshold_s=latency_s,
                         target=0.90, metric="latency_s",
                         slo_class="deferrable"),
        CarbonBudget("hourly-carbon", budget_g=carbon_g_per_h,
                     window_s=3600.0),
    ]
