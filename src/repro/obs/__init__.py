"""Unified observability layer: metrics registry, request-lifecycle tracing,
and streaming carbon/energy telemetry.

Clover's claim — carbon reduction *while* holding SLA and accuracy — is only
as credible as the measurement plane behind it.  Before this package every
serving layer reported its own ad-hoc ``stats`` dict and recomputed
per-request attribution its own way; ``repro.obs`` is the one measurement
plane they all emit into:

  * :mod:`repro.obs.metrics` — a metrics registry (counters, gauges,
    histograms with exact nearest-rank percentiles) with a shared metric-name
    CATALOG, so ``RealEngine`` (slotted and paged), ``DESBackend``,
    ``FluidBackend`` and the fleet all report under the same names and a
    backend's ``stats()`` is a *view* over its registry;
  * :mod:`repro.obs.trace` — a low-overhead span/event recorder capturing
    each request's arrival → hold/release (with the policy's reason) →
    admission → prefill chunks → decode ticks (one event per tick with the
    occupant set) → preempt/swap → completion, exportable as JSONL and as
    Chrome-trace JSON (load it in Perfetto).  Request spans carry their
    attributed joules/gCO2, so a trace is a visual audit of the carbon
    attribution, and :func:`repro.obs.trace.validate_trace` enforces the
    conservation invariant (every span closes; span-summed joules equal the
    engine total exactly);
  * :mod:`repro.obs.carbon_feed` — a measure-every-N-seconds energy/CO2
    sampler (codecarbon idiom) that integrates power against the region's
    carbon-intensity trace per window and streams per-region snapshots that
    the controller and the benchmarks both consume;
  * :mod:`repro.obs.aggregate` — the fleet-scope layer: the canonical
    label schema (``region`` / ``slo_class`` / ``kv_layout`` / ``phase``),
    bounded-memory mergeable :class:`~repro.obs.aggregate.StreamingHistogram`
    for 10^6-scale replay, and :class:`~repro.obs.aggregate.FleetRollup`
    merging per-region registries with bit-exact conservation;
  * :mod:`repro.obs.export` — OpenMetrics/Prometheus text exposition
    (round-trip validated) and a periodic JSONL snapshot writer;
  * :mod:`repro.obs.slo` — declarative latency-SLO / carbon-budget rules
    evaluated as multi-window error-budget burn rates with deterministic
    fire/clear alert state, consumed by the core controller;
  * :mod:`repro.obs.profile` — phase timers (prefill chunks, decode
    dispatch/land, swap D2H/H2D) feeding ``phase``-labeled latency
    histograms in both engines.

The package is deliberately jax-free (stdlib + numpy only): the DES/fluid
paths and ``scripts/check.sh``'s trace-validation step must run without
touching the device stack.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

from repro.obs.aggregate import LABEL_KEYS, FleetRollup, StreamingHistogram
from repro.obs.carbon_feed import CarbonFeed, CarbonSnapshot
from repro.obs.export import SnapshotWriter, parse_openmetrics, \
    to_openmetrics
from repro.obs.metrics import CATALOG, Counter, Gauge, Histogram, \
    MetricsRegistry
from repro.obs.profile import PHASES, PhaseProfiler
from repro.obs.slo import AlertState, BurnRatePolicy, CarbonBudget, \
    LatencyObjective, SLOEvaluator, default_rules
from repro.obs.trace import TraceRecorder, validate_chrome_events, \
    validate_trace

__all__ = ["AlertState", "BurnRatePolicy", "CATALOG", "CarbonBudget",
           "CarbonFeed", "CarbonSnapshot", "Counter", "FleetRollup",
           "Gauge", "Histogram", "LABEL_KEYS", "LatencyObjective",
           "MetricsRegistry", "PHASES", "PhaseProfiler", "SLOEvaluator",
           "SnapshotWriter", "StreamingHistogram", "Telemetry",
           "TraceRecorder", "default_rules", "parse_openmetrics",
           "to_openmetrics", "validate_chrome_events", "validate_trace"]


@dataclasses.dataclass
class Telemetry:
    """The bundle a serving backend carries: its metrics registry plus the
    optional trace recorder and carbon feed.

    Lifecycle contract: ``tracer`` and ``feed`` are *persistent* — a fleet
    probe loop reuses them across serve sessions so traces concatenate and
    the feed streams continuously.  ``registry`` is *per session* on the
    real engine (each serve session opens a fresh standard registry and
    ``stats()`` reads the last one); the single-session backends (DES /
    fluid) keep one registry for their life."""

    registry: MetricsRegistry = None
    tracer: Optional[TraceRecorder] = None
    feed: Optional[CarbonFeed] = None
    backend: str = "backend"

    def __post_init__(self) -> None:
        if self.registry is None:
            self.registry = MetricsRegistry.standard(self.backend)
