"""Clover-on-TPU: carbon-aware ML inference serving (paper reproduction) +
the multi-pod JAX serving/training framework it runs on.  See DESIGN.md."""
__version__ = "1.0.0"
