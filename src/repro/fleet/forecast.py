"""Carbon-intensity forecasting over ``CarbonTrace`` (fleet layer).

Clover's controller is reactive: it re-optimizes after the grid has already
moved ≥ 5 %.  The fleet layer wants to act *ahead* of the move — shift
deferrable work into tomorrow's solar valley, pre-reconfigure before the
evening ramp — which needs a forecast of carbon intensity at t + horizon.

Two honest online baselines (both only ever read trace samples ≤ t, via
``CarbonTrace.history``):

  PersistenceForecaster      — ci_hat(t + h) = ci(t).  Strong at short
                               horizons, blind to the diurnal cycle.
  DiurnalHarmonicForecaster  — least-squares regression of the recent history
                               on a truncated Fourier basis of the 24 h cycle
                               (mean + K sin/cos harmonics).  Captures solar
                               valleys and evening ramps hours ahead; the
                               residual wind/AR noise is irreducible for it.

``backtest`` replays a forecaster over a trace and reports MAE/RMSE/MAPE per
horizon, so region×forecaster choices are data-driven rather than asserted.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Dict, Optional, Sequence

import numpy as np

from repro.core.carbon import CarbonTrace

DAY_S = 24 * 3600.0


class Forecaster:
    """Common API: ``predict(t, horizon_s)`` → forecast CI at t + horizon_s,
    fitted only on samples observable at wall-clock ``t``."""

    name = "abstract"

    def __init__(self, trace: CarbonTrace):
        self.trace = trace

    def predict(self, t: float, horizon_s: float) -> float:
        raise NotImplementedError

    def predict_series(self, t: float, horizon_s: float,
                       step_s: float) -> np.ndarray:
        """Forecast CI at t + step, t + 2·step, … up to t + horizon_s."""
        hs = np.arange(step_s, horizon_s + 0.5 * step_s, step_s)
        return np.array([self.predict(t, float(h)) for h in hs])


class PersistenceForecaster(Forecaster):
    name = "persistence"

    def predict(self, t: float, horizon_s: float) -> float:
        return self.trace.at(min(t, self.trace.duration_s))


class DiurnalHarmonicForecaster(Forecaster):
    """ci(t) ≈ β0 + Σ_k βk·sin(2πkt/24h) + γk·cos(2πkt/24h), fitted by least
    squares on a sliding history window and cached between refits."""

    name = "harmonic"

    def __init__(self, trace: CarbonTrace, n_harmonics: int = 3,
                 fit_window_s: float = 36 * 3600.0,
                 refit_every_s: float = 1800.0):
        super().__init__(trace)
        self.n_harmonics = n_harmonics
        self.fit_window_s = fit_window_s
        self.refit_every_s = refit_every_s
        self._beta: Optional[np.ndarray] = None
        self._fit_t: float = -math.inf

    def _design(self, times_s: np.ndarray) -> np.ndarray:
        cols = [np.ones_like(times_s)]
        for k in range(1, self.n_harmonics + 1):
            w = 2.0 * math.pi * k * times_s / DAY_S
            cols.append(np.sin(w))
            cols.append(np.cos(w))
        return np.stack(cols, axis=1)

    def _min_samples(self) -> int:
        return 2 * (2 * self.n_harmonics + 1)

    def _fit(self, t: float) -> None:
        hist = self.trace.history(t)
        keep = hist.times_s >= t - self.fit_window_s
        ts, ci = hist.times_s[keep], hist.intensity[keep]
        if len(ts) < self._min_samples():
            self._beta = None            # cold start → fall back to persistence
        else:
            X = self._design(ts)
            self._beta, *_ = np.linalg.lstsq(X, ci, rcond=None)
        self._fit_t = t

    def predict(self, t: float, horizon_s: float) -> float:
        if t - self._fit_t >= self.refit_every_s or t < self._fit_t:
            self._fit(t)
        if self._beta is None:
            return self.trace.at(min(t, self.trace.duration_s))
        x = self._design(np.array([t + horizon_s]))
        return max(float(x[0] @ self._beta), 1.0)


class EnsembleForecaster(Forecaster):
    """Inverse-error weighted blend of persistence and diurnal-harmonic.

    Grids differ in how forecastable they are: solar-dominated CISO is nearly
    periodic (harmonic wins), wind-dominated ESO has a ~37 h oscillation that
    a 24 h Fourier basis cannot represent (persistence wins).  Rather than
    asking the operator to know this per region, the ensemble scores each
    member on a rolling *honest* backtest (predictions issued from past
    wall-clocks using only their own history) and weights by 1/(MAE + ε), so
    each region automatically leans on whichever model its grid rewards."""

    name = "ensemble"

    def __init__(self, trace: CarbonTrace, eval_horizon_s: float = 6 * 3600.0,
                 eval_window_s: float = 24 * 3600.0,
                 eval_step_s: float = 3600.0, refit_every_s: float = 3600.0):
        super().__init__(trace)
        self.members = [PersistenceForecaster(trace),
                        DiurnalHarmonicForecaster(trace)]
        self.eval_horizon_s = eval_horizon_s
        self.eval_window_s = eval_window_s
        self.eval_step_s = eval_step_s
        self.refit_every_s = refit_every_s
        self._weights = np.full(len(self.members), 1.0 / len(self.members))
        self._fit_t: float = -math.inf

    def _reweigh(self, t: float) -> None:
        t0 = max(t - self.eval_window_s, 0.0)
        maes = []
        for m in self.members:
            errs = []
            s = t0
            while s + self.eval_horizon_s <= t:
                truth = self.trace.at(s + self.eval_horizon_s)
                errs.append(abs(m.predict(s, self.eval_horizon_s) - truth))
                s += self.eval_step_s
            maes.append(np.mean(errs) if errs else 1.0)
        inv = 1.0 / (np.array(maes) + 1e-6)
        self._weights = inv / inv.sum()
        self._fit_t = t

    def predict(self, t: float, horizon_s: float) -> float:
        if t - self._fit_t >= self.refit_every_s or t < self._fit_t:
            self._reweigh(t)
        preds = np.array([m.predict(t, horizon_s) for m in self.members])
        return float(preds @ self._weights)


FORECASTERS = {
    PersistenceForecaster.name: PersistenceForecaster,
    DiurnalHarmonicForecaster.name: DiurnalHarmonicForecaster,
    EnsembleForecaster.name: EnsembleForecaster,
}


def make_forecaster(name: str, trace: CarbonTrace, **kw) -> Forecaster:
    return FORECASTERS[name](trace, **kw)


class ForecastCIFn:
    """Adapt a :class:`Forecaster` to the scheduling policies' ``ci_fn``
    contract (``serving.policies``): ``ci_fn(now, horizon_s=0)`` → forecast
    gCO2/kWh at ``now + horizon_s``, where ``now`` is the *backend's*
    session-relative clock.

    ``time_scale`` maps backend seconds onto trace seconds (a real engine's
    wall clock crawls relative to an hour-scale trace; a DES replaying a
    compressed workload may map 1 s → 1 h).  ``set_epoch`` re-anchors the
    session origin onto the trace's absolute clock — the fleet's real
    backend calls it with each probe window's ``t``, so the same policy
    object sees the right stretch of grid across windows.

    Horizon 0 is the nowcast: the forecaster's own fitted value at ``t``
    (NOT a raw trace lookup — an honest policy only ever sees what its
    forecaster believes)."""

    def __init__(self, forecaster: Forecaster, time_scale: float = 1.0,
                 t0: float = 0.0):
        self.forecaster = forecaster
        self.time_scale = time_scale
        self.t0 = t0

    def set_epoch(self, t0: float) -> None:
        self.t0 = float(t0)

    def __call__(self, now: Optional[float] = None,
                 horizon_s: float = 0.0) -> float:
        t = self.t0 + float(now or 0.0) * self.time_scale
        return float(self.forecaster.predict(t, float(horizon_s)
                                             * self.time_scale))


# =============================================================================
# backtesting
# =============================================================================
@dataclasses.dataclass(frozen=True)
class BacktestReport:
    forecaster: str
    trace: str
    horizon_s: float
    n: int
    mae: float                     # gCO2/kWh
    rmse: float                    # gCO2/kWh
    mape: float                    # fraction (0.1 = 10 %)


def backtest(forecaster: Forecaster, horizon_s: float,
             t_start: float = 12 * 3600.0, step_s: float = 1800.0,
             t_end: Optional[float] = None) -> BacktestReport:
    """Walk the trace, predicting ci(t + horizon) from each t, and score
    against the realized trace.  Starts after ``t_start`` so history-hungry
    forecasters are past their cold start."""
    tr = forecaster.trace
    t_end = tr.duration_s - horizon_s if t_end is None else t_end
    errs, rels = [], []
    t = t_start
    while t <= t_end:
        truth = tr.at(t + horizon_s)
        pred = forecaster.predict(t, horizon_s)
        errs.append(pred - truth)
        rels.append(abs(pred - truth) / max(truth, 1e-9))
        t += step_s
    e = np.array(errs)
    return BacktestReport(
        forecaster=forecaster.name, trace=tr.name, horizon_s=horizon_s,
        n=len(e), mae=float(np.mean(np.abs(e))),
        rmse=float(np.sqrt(np.mean(e ** 2))), mape=float(np.mean(rels)))


def backtest_table(trace: CarbonTrace,
                   horizons_s: Sequence[float] = (1800.0, 3600.0, 6 * 3600.0,
                                                  12 * 3600.0),
                   names: Sequence[str] = ("persistence", "harmonic"),
                   t_start: float = 12 * 3600.0,
                   ) -> Dict[str, Dict[float, BacktestReport]]:
    """Error matrix forecaster × horizon for one region's trace."""
    out: Dict[str, Dict[float, BacktestReport]] = {}
    for name in names:
        f = make_forecaster(name, trace)
        out[name] = {h: backtest(f, h, t_start=t_start) for h in horizons_s}
    return out


def backtest_csv(path: str, name: Optional[str] = None,
                 horizons_s: Sequence[float] = (1800.0, 3600.0, 6 * 3600.0),
                 names: Sequence[str] = ("persistence", "harmonic",
                                         "ensemble"),
                 t_start: Optional[float] = None,
                 ) -> Dict[str, Dict[float, BacktestReport]]:
    """Backtest forecasters on a REAL carbon-intensity trace loaded from an
    ElectricityMaps-style CSV (``carbon.load_trace_csv``) — the data-driven
    way to pick a region's forecaster instead of trusting the synthetic
    generators.  ``t_start`` defaults to a quarter of the trace so
    history-hungry forecasters are past their cold start even on short
    exports."""
    from repro.core.carbon import load_trace_csv
    trace = load_trace_csv(path, name=name)
    if t_start is None:
        t_start = 0.25 * trace.duration_s
    return backtest_table(trace, horizons_s=horizons_s, names=names,
                          t_start=t_start)
